"""Layer-2 JAX model: a small GPT-style transformer and its training step.

The end-to-end driver (examples/ddp_train.rs) runs data-parallel training
where each Rust worker executes the AOT-compiled ``grad_step`` through the
PJRT runtime and gradients are averaged with the ZCCL Z-Allreduce. The
``grad_step_zccl`` variant additionally routes every gradient through the
Layer-1 Pallas quantize-dequantize kernel *inside the lowered graph* — the
in-graph counterpart of what the Rust collective's compression does on the
wire, used by the gradient-compression ablation.

Parameters travel as a flat list of arrays in the deterministic order of
``param_order(cfg)``; aot.py records names/shapes/offsets in the manifest
so the Rust side is fully generic.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels.lorenzo import quantize_tree


@dataclasses.dataclass(frozen=True)
class Config:
    """Transformer hyper-parameters (defaults sized for a 1-core CPU box;
    scale up via --preset)."""

    vocab: int = 256
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 2
    seq: int = 64
    batch: int = 8

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


PRESETS = {
    "tiny": Config(vocab=64, d_model=32, n_heads=2, n_layers=1, seq=16, batch=4),
    "small": Config(),
    "medium": Config(vocab=512, d_model=256, n_heads=8, n_layers=4, seq=128, batch=8),
}


def param_order(cfg: Config) -> list[str]:
    """Deterministic parameter name order for the flat calling convention."""
    names = ["embed", "pos"]
    for i in range(cfg.n_layers):
        names += [
            f"l{i}.ln1.g",
            f"l{i}.ln1.b",
            f"l{i}.attn.wqkv",
            f"l{i}.attn.bqkv",
            f"l{i}.attn.wo",
            f"l{i}.attn.bo",
            f"l{i}.ln2.g",
            f"l{i}.ln2.b",
            f"l{i}.mlp.w1",
            f"l{i}.mlp.b1",
            f"l{i}.mlp.w2",
            f"l{i}.mlp.b2",
        ]
    names += ["lnf.g", "lnf.b", "head"]
    return names


def init_params(cfg: Config, seed: int = 0) -> dict[str, jax.Array]:
    """Initialise parameters (scaled-normal init)."""
    key = jax.random.PRNGKey(seed)
    d, h = cfg.d_model, 4 * cfg.d_model
    shapes = {
        "embed": (cfg.vocab, d),
        "pos": (cfg.seq, d),
        "lnf.g": (d,),
        "lnf.b": (d,),
        "head": (d, cfg.vocab),
    }
    for i in range(cfg.n_layers):
        shapes |= {
            f"l{i}.ln1.g": (d,),
            f"l{i}.ln1.b": (d,),
            f"l{i}.attn.wqkv": (d, 3 * d),
            f"l{i}.attn.bqkv": (3 * d,),
            f"l{i}.attn.wo": (d, d),
            f"l{i}.attn.bo": (d,),
            f"l{i}.ln2.g": (d,),
            f"l{i}.ln2.b": (d,),
            f"l{i}.mlp.w1": (d, h),
            f"l{i}.mlp.b1": (h,),
            f"l{i}.mlp.w2": (h, d),
            f"l{i}.mlp.b2": (d,),
        }
    params = {}
    for name in param_order(cfg):
        shape = shapes[name]
        key, sub = jax.random.split(key)
        if name.endswith((".g",)):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith((".b", ".bo", ".bqkv", ".b1", ".b2")) or name.endswith(".ln1.b"):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            scale = 0.02 if name in ("embed", "pos") else 1.0 / jnp.sqrt(shape[0])
            params[name] = (scale * jax.random.normal(sub, shape)).astype(jnp.float32)
    return params


def _layernorm(x, g, b):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * g + b


def _attention(cfg: Config, x, wqkv, bqkv, wo, bo):
    B, T, D = x.shape
    qkv = x @ wqkv + bqkv  # (B,T,3D)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    def heads(t):
        return t.reshape(B, T, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
    q, k, v = heads(q), heads(k), heads(v)
    att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(cfg.d_head).astype(jnp.float32)
    mask = jnp.tril(jnp.ones((T, T), bool))
    att = jnp.where(mask, att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    out = (att @ v).transpose(0, 2, 1, 3).reshape(B, T, D)
    return out @ wo + bo


def forward(cfg: Config, params: dict, tokens: jax.Array) -> jax.Array:
    """Logits for token ids ``(B, T)`` -> ``(B, T, vocab)``."""
    x = params["embed"][tokens] + params["pos"][None, : tokens.shape[1]]
    for i in range(cfg.n_layers):
        p = lambda s: params[f"l{i}.{s}"]
        x = x + _attention(
            cfg, _layernorm(x, p("ln1.g"), p("ln1.b")),
            p("attn.wqkv"), p("attn.bqkv"), p("attn.wo"), p("attn.bo"),
        )
        h = _layernorm(x, p("ln2.g"), p("ln2.b"))
        h = jax.nn.gelu(h @ p("mlp.w1") + p("mlp.b1"))
        x = x + h @ p("mlp.w2") + p("mlp.b2")
    x = _layernorm(x, params["lnf.g"], params["lnf.b"])
    return x @ params["head"]


def loss_fn(cfg: Config, params: dict, x: jax.Array, y: jax.Array) -> jax.Array:
    """Mean next-token cross-entropy."""
    logits = forward(cfg, params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    return -picked.mean()


def make_grad_step(cfg: Config, compress_eb: float | None = None):
    """Build the flat-signature ``(params..., x, y) -> (loss, grads...)``
    function. With ``compress_eb`` set, every gradient is passed through
    the Pallas quantize-dequantize kernel inside the graph."""
    names = param_order(cfg)

    def fn(*args):
        flat_params = args[: len(names)]
        x, y = args[len(names)], args[len(names) + 1]
        params = dict(zip(names, flat_params))
        loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, x, y))(params)
        if compress_eb is not None:
            grads = quantize_tree(grads, compress_eb)
        return (loss, *[grads[n] for n in names])

    return fn


def example_inputs(cfg: Config, params: dict) -> list[jax.Array]:
    """Example (shape-defining) arguments for lowering grad_step."""
    names = param_order(cfg)
    x = jnp.zeros((cfg.batch, cfg.seq), jnp.int32)
    y = jnp.zeros((cfg.batch, cfg.seq), jnp.int32)
    return [params[n] for n in names] + [x, y]


@functools.lru_cache(maxsize=None)
def cached_config(preset: str) -> Config:
    return PRESETS[preset]
