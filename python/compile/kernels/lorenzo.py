"""Layer-1 Pallas kernel: fused error-bounded quantization + 1-D Lorenzo
prediction + per-block code-length analysis — the compute hot-spot of the
fZ-light compressor (paper §3.3), re-thought for a tiled accelerator.

HARDWARE ADAPTATION (DESIGN.md §Hardware-Adaptation): the original fZ-light
maps thread-blocks onto CPU cores. Here the *thread-block* becomes the
Pallas grid tile: each grid step streams one TILE of the input from HBM
into VMEM (BlockSpec), does the elementwise quantization on the VPU, the
Lorenzo delta with an in-tile shift, and a 32-wide reduction for the
per-block code length. No MXU is involved — the kernel is memory-bound,
so the schedule (double-buffered HBM->VMEM streaming) is the whole game.
VMEM footprint per grid step: TILE·4 B (x) + TILE·4 B (q) + TILE/32·4 B
(bits) ≈ 33 KB at TILE=4096 — far below the ~16 MiB budget, leaving room
for the compiler to double-buffer.

The kernel returns
  - ``xhat``: the dequantized reconstruction (``2eb * round(x / 2eb)``),
    i.e. exactly the values a receiver obtains after fZ-light decompression
    (|x - xhat| <= eb), and
  - ``bits``: per-32-value-block code lengths, from which the compressed
    size of the fZ-light frame is estimated WITHOUT running the encoder —
    the L2 model uses this to predict communication volume.

Pallas MUST run with interpret=True in this environment: real-TPU lowering
emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Values per grid tile (the "thread-block").
TILE = 4096
# Values per code-length block (matches the Rust encoder's BLOCK).
BLOCK = 32


def _kernel(x_ref, xhat_ref, bits_ref, *, twoeb: float):
    x = x_ref[...]
    # NB: divide, don't multiply by the reciprocal — the contract is
    # q = round(x / 2eb) and the two differ at .5 rounding boundaries.
    q = jnp.round(x / twoeb)
    xhat_ref[...] = (q * twoeb).astype(jnp.float32)
    # 1-D Lorenzo within the tile; the first lane predicts from 0 (the
    # tile-leading value acts as the outlier, mirroring the chunked frame).
    prev = jnp.concatenate([jnp.zeros((1,), q.dtype), q[:-1]])
    mag = jnp.abs(q - prev)
    blocks = mag.reshape(TILE // BLOCK, BLOCK)
    maxmag = blocks.max(axis=1)
    # bits(m) = ceil(log2(m + 1)); exact for the magnitudes float32 can
    # hold at the error bounds we use.
    bits = jnp.ceil(jnp.log2(maxmag + 1.0))
    bits_ref[...] = bits.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("eb",))
def lorenzo_quant(x: jax.Array, eb: float) -> tuple[jax.Array, jax.Array]:
    """Quantize-dequantize ``x`` under absolute error bound ``eb`` and
    estimate per-block fZ-light code lengths.

    ``x`` must be 1-D with length a multiple of TILE (pad with zeros).
    Returns ``(xhat, bits)`` with shapes ``(n,)`` and ``(n // BLOCK,)``.
    """
    if x.ndim != 1 or x.shape[0] % TILE != 0:
        raise ValueError(f"x must be 1-D with length % {TILE} == 0, got {x.shape}")
    n = x.shape[0]
    grid = (n // TILE,)
    return pl.pallas_call(
        functools.partial(_kernel, twoeb=2.0 * float(eb)),
        grid=grid,
        in_specs=[pl.BlockSpec((TILE,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE // BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n // BLOCK,), jnp.int32),
        ],
        interpret=True,  # CPU-PJRT execution; see module docstring
    )(x)


def estimated_frame_bytes(bits: jax.Array) -> jax.Array:
    """Estimated fZ-light payload size from per-block code lengths.

    Mirrors the Rust encoder's layout: 1 code-length byte per block;
    non-constant blocks add 4 sign bytes + BLOCK·L/8 magnitude bytes.
    """
    nonconst = (bits > 0).astype(jnp.int32)
    per_block = 1 + nonconst * (BLOCK // 8 + (BLOCK * bits) // 8)
    return jnp.sum(per_block)


def quantize_tree(tree, eb: float):
    """Apply the quantize-dequantize operator leaf-wise to a pytree (used
    by the compressed-gradient train step). Leaves are padded to TILE,
    processed by the Pallas kernel, and cropped back."""
    def one(leaf):
        flat = leaf.reshape(-1)
        pad = (-flat.shape[0]) % TILE
        padded = jnp.pad(flat, (0, pad))
        xhat, _ = lorenzo_quant(padded, eb)
        return xhat[: flat.shape[0]].reshape(leaf.shape)

    return jax.tree_util.tree_map(one, tree)
