"""Pure-jnp oracle for the Pallas kernel — the build-time correctness
signal. Intentionally written independently (no shared helpers with
lorenzo.py) so a bug must appear twice to slip through."""

import jax.numpy as jnp

TILE = 4096
BLOCK = 32


def lorenzo_quant_ref(x, eb):
    """Reference quantize-dequantize + per-block code length.

    Same contract as :func:`compile.kernels.lorenzo.lorenzo_quant`.
    """
    assert x.ndim == 1 and x.shape[0] % TILE == 0
    twoeb = 2.0 * float(eb)
    q = jnp.round(x / twoeb)
    xhat = (q * twoeb).astype(jnp.float32)

    # Per-tile Lorenzo: the first element of each TILE predicts from zero.
    tiles = q.reshape(-1, TILE)
    prev = jnp.concatenate([jnp.zeros((tiles.shape[0], 1), q.dtype), tiles[:, :-1]], axis=1)
    mag = jnp.abs(tiles - prev).reshape(-1, BLOCK)
    maxmag = mag.max(axis=1)
    bits = jnp.ceil(jnp.log2(maxmag + 1.0)).astype(jnp.int32)
    return xhat, bits


def estimated_frame_bytes_ref(bits):
    nonconst = (bits > 0).astype(jnp.int32)
    return jnp.sum(1 + nonconst * (BLOCK // 8 + (BLOCK * bits) // 8))
