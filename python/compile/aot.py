"""AOT compilation: lower the L2 model + L1 kernel to HLO **text** and
emit a manifest the Rust runtime consumes.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Outputs in --out-dir:
  - <artifact>.hlo.txt         one per artifact
  - params.bin                 initial transformer parameters (f32 LE)
  - manifest.json              artifact signatures + parameter table

Python runs ONCE at build time (``make artifacts``); the Rust binary is
self-contained afterwards.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import lorenzo


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(arrays) -> list[dict]:
    out = []
    for a in arrays:
        out.append({"shape": list(a.shape), "dtype": str(a.dtype)})
    return out


def lower_artifact(name, fn, example_args, out_dir):
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    outs = jax.eval_shape(fn, *example_args)
    if not isinstance(outs, (tuple, list)):
        outs = (outs,)
    return {
        "name": name,
        "file": f"{name}.hlo.txt",
        "inputs": _sig(example_args),
        "outputs": _sig(outs),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", default="small", choices=sorted(model.PRESETS))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--grad-eb", type=float, default=1e-4,
                    help="error bound baked into grad_step_zccl")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg = model.PRESETS[args.preset]
    params = model.init_params(cfg, args.seed)
    names = model.param_order(cfg)
    example = model.example_inputs(cfg, params)

    artifacts = []

    # 1. Plain training gradient step (DDP uses this; ZCCL compresses on
    #    the wire inside the Rust collective).
    artifacts.append(
        lower_artifact("grad_step", model.make_grad_step(cfg), example, args.out_dir)
    )

    # 2. In-graph compressed-gradient variant: the Pallas kernel
    #    quantize-dequantizes every gradient inside the lowered HLO.
    artifacts.append(
        lower_artifact(
            "grad_step_zccl",
            model.make_grad_step(cfg, compress_eb=args.grad_eb),
            example,
            args.out_dir,
        )
    )

    # 3. The standalone L1 kernel (quantize + code-length analysis),
    #    exercised directly from the Rust runtime tests.
    n = 16 * lorenzo.TILE
    artifacts.append(
        lower_artifact(
            "lorenzo_quant",
            lambda x: lorenzo.lorenzo_quant(x, 1e-3),
            [jnp.zeros((n,), jnp.float32)],
            args.out_dir,
        )
    )

    # 4. Forward-only loss (evaluation in the DDP driver).
    def eval_loss(*a):
        flat = a[: len(names)]
        x, y = a[len(names)], a[len(names) + 1]
        return (model.loss_fn(cfg, dict(zip(names, flat)), x, y),)

    artifacts.append(lower_artifact("eval_loss", eval_loss, example, args.out_dir))

    # Parameter table + initial values.
    table = []
    offset = 0
    with open(os.path.join(args.out_dir, "params.bin"), "wb") as f:
        for name in names:
            a = np.asarray(params[name], dtype=np.float32)
            b = a.tobytes()  # C-order, little-endian on this platform
            f.write(b)
            table.append(
                {"name": name, "shape": list(a.shape), "offset": offset, "bytes": len(b)}
            )
            offset += len(b)

    manifest = {
        "version": 1,
        "preset": args.preset,
        "config": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "n_layers": cfg.n_layers,
            "seq": cfg.seq,
            "batch": cfg.batch,
        },
        "grad_eb": args.grad_eb,
        "artifacts": artifacts,
        "params": table,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(artifacts)} artifacts + params.bin ({offset} bytes) to {args.out_dir}")


if __name__ == "__main__":
    main()
