"""L2 model checks: shapes, loss behaviour, grad-step signature, and the
in-graph compressed-gradient variant."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model

jax.config.update("jax_platforms", "cpu")

CFG = model.PRESETS["tiny"]


def _data(cfg, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, cfg.vocab, size=(cfg.batch, cfg.seq), dtype=np.int32)
    # Learnable task: next token = (token + 1) mod vocab.
    y = (x + 1) % cfg.vocab
    return jnp.asarray(x), jnp.asarray(y)


def test_param_order_stable_and_complete():
    names = model.param_order(CFG)
    params = model.init_params(CFG, 0)
    assert list(params.keys()) == names  # insertion order == declared order
    assert len(set(names)) == len(names)


def test_forward_shapes():
    params = model.init_params(CFG, 0)
    x, _ = _data(CFG)
    logits = model.forward(CFG, params, x)
    assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_initial_loss_near_uniform():
    params = model.init_params(CFG, 0)
    x, y = _data(CFG)
    loss = model.loss_fn(CFG, params, x, y)
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.5


def test_grad_step_flat_signature_and_descent():
    params = model.init_params(CFG, 0)
    names = model.param_order(CFG)
    x, y = _data(CFG)
    fn = jax.jit(model.make_grad_step(CFG))
    args = [params[n] for n in names] + [x, y]
    out = fn(*args)
    loss0, grads = out[0], out[1:]
    assert len(grads) == len(names)
    # One SGD step must reduce the loss on the same batch.
    lr = 0.5
    new_args = [p - lr * g for p, g in zip(args[: len(names)], grads)] + [x, y]
    loss1 = fn(*new_args)[0]
    assert float(loss1) < float(loss0)


def test_compressed_grad_step_close_to_plain():
    params = model.init_params(CFG, 0)
    names = model.param_order(CFG)
    x, y = _data(CFG)
    plain = jax.jit(model.make_grad_step(CFG))
    comp = jax.jit(model.make_grad_step(CFG, compress_eb=1e-4))
    args = [params[n] for n in names] + [x, y]
    out_p = plain(*args)
    out_c = comp(*args)
    assert abs(float(out_p[0]) - float(out_c[0])) < 1e-6  # same loss
    for gp, gc in zip(out_p[1:], out_c[1:]):
        np.testing.assert_allclose(
            np.asarray(gp), np.asarray(gc), atol=1e-4 * 1.01 + 1e-7
        )


def test_training_loop_learns_shift_task():
    cfg = CFG
    params = model.init_params(cfg, 0)
    names = model.param_order(cfg)
    fn = jax.jit(model.make_grad_step(cfg))
    flat = [params[n] for n in names]
    losses = []
    for step in range(30):
        x, y = _data(cfg, seed=step)
        out = fn(*flat, x, y)
        losses.append(float(out[0]))
        flat = [p - 0.3 * g for p, g in zip(flat, out[1:])]
    assert losses[-1] < losses[0] * 0.7, f"no learning: {losses[0]} -> {losses[-1]}"
