"""L1 correctness: the Pallas kernel against the pure-jnp oracle.

This is the CORE correctness signal for the kernel layer: hypothesis
sweeps shapes, seeds and error bounds; assert_allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.lorenzo import (
    BLOCK,
    TILE,
    estimated_frame_bytes,
    lorenzo_quant,
    quantize_tree,
)
from compile.kernels.ref import estimated_frame_bytes_ref, lorenzo_quant_ref

jax.config.update("jax_platforms", "cpu")


def field(n, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    t = np.linspace(0.0, 1.0, n, dtype=np.float64)
    x = np.zeros(n)
    for k in range(6):
        f = rng.uniform(0.5, 200.0)
        x += rng.uniform(0.1, 1.0) * np.sin(2 * np.pi * f * t + rng.uniform(0, 6.28))
    return jnp.asarray(scale * x, jnp.float32)


@settings(max_examples=12, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31),
    eb=st.sampled_from([1e-1, 1e-2, 1e-3, 1e-4]),
    scale=st.sampled_from([1e-2, 1.0, 100.0]),
)
def test_kernel_matches_ref(tiles, seed, eb, scale):
    # NOTE on ties: inside jax.jit XLA rewrites x/const into x*(1/const),
    # so values landing exactly on a .5 quantization boundary may round to
    # the neighbouring level vs the eager oracle. Both reconstructions are
    # legal (|x - xhat| <= eb); we therefore demand bit-exact agreement
    # away from ties, quantum-bounded disagreement at ties, and a tiny tie
    # fraction.
    x = field(tiles * TILE, seed, scale)
    got_xhat, got_bits = lorenzo_quant(x, eb)
    want_xhat, want_bits = lorenzo_quant_ref(x, eb)
    eb_abs = eb  # absolute bound as passed
    diff = np.abs(np.asarray(got_xhat, np.float64) - np.asarray(want_xhat, np.float64))
    # One quantization quantum plus the f32 rounding of q * 2eb itself.
    quantum = 2 * eb_abs + 4 * np.finfo(np.float32).eps * np.abs(np.asarray(x)).max()
    assert diff.max() <= quantum, f"disagreement beyond one quantum: {diff.max()}"
    tie_frac = (diff > 0).mean()
    # The reciprocal rewrite flips rounding when frac(x/2eb) lies within
    # ~q*eps of .5, so the expected flip fraction grows with the
    # quantization magnitude q_max.
    q_max = float(np.abs(np.asarray(x)).max()) / (2 * eb_abs)
    allowed = max(0.005, 8 * np.finfo(np.float32).eps * q_max)
    assert tie_frac <= allowed, f"too many ties: {tie_frac} > {allowed}"
    # Code lengths must agree wherever the block contained no tie.
    tie_blocks = (diff.reshape(-1, BLOCK) > 0).any(axis=1)
    clean = ~tie_blocks
    # A tie in block k changes that block's delta AND the next block's
    # leading delta; exclude direct successors of tie blocks too.
    clean[1:] &= ~tie_blocks[:-1]
    np.testing.assert_array_equal(
        np.asarray(got_bits)[clean], np.asarray(want_bits)[clean]
    )


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31),
    eb=st.sampled_from([1e-2, 1e-3, 1e-4]),
)
def test_error_bound_holds(seed, eb):
    x = field(2 * TILE, seed)
    xhat, _ = lorenzo_quant(x, eb)
    err = np.abs(np.asarray(xhat, np.float64) - np.asarray(x, np.float64))
    # f32 rounding of q*2eb adds up to a few ulps of |x| on top of eb.
    tol = eb * (1 + 1e-5) + 4 * np.finfo(np.float32).eps * np.abs(np.asarray(x)).max()
    assert err.max() <= tol, f"{err.max()} > {tol}"


def test_bits_zero_for_constant_input():
    x = jnp.full((TILE,), 3.25, jnp.float32)
    xhat, bits = lorenzo_quant(x, 1e-3)
    # All deltas zero except the leading outlier block.
    assert int(bits[0]) > 0 or float(x[0]) == 0.0
    assert np.all(np.asarray(bits[1:]) == 0)
    np.testing.assert_allclose(xhat, x, atol=1e-3 * 1.001)


def test_estimated_bytes_matches_ref_and_is_conservative():
    x = field(4 * TILE, 9)
    _, bits = lorenzo_quant(x, 1e-3)
    est = int(estimated_frame_bytes(bits))
    ref = int(estimated_frame_bytes_ref(bits))
    assert est == ref
    # Sanity: between the all-constant floor and raw size.
    nblocks = x.shape[0] // BLOCK
    assert nblocks <= est <= x.shape[0] * 4


def test_quantize_tree_shapes_and_bound():
    tree = {
        "a": field(100, 1).reshape(10, 10),
        "b": field(TILE + 17, 2),
    }
    out = quantize_tree(tree, 1e-3)
    assert out["a"].shape == (10, 10)
    assert out["b"].shape == (TILE + 17,)
    for k in tree:
        err = np.abs(np.asarray(out[k]) - np.asarray(tree[k]))
        assert err.max() <= 1e-3 * 1.001 + 1e-7


def test_rejects_bad_shapes():
    with pytest.raises(ValueError):
        lorenzo_quant(jnp.zeros((TILE + 1,), jnp.float32), 1e-3)
    with pytest.raises(ValueError):
        lorenzo_quant(jnp.zeros((2, TILE), jnp.float32), 1e-3)
