"""AOT path smoke tests: lowering produces parseable HLO text and a
manifest consistent with the model's signatures (tiny preset to stay
fast)."""

import json
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import lorenzo

jax.config.update("jax_platforms", "cpu")


def test_to_hlo_text_roundtrips_through_jit():
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text
    assert "f32[2,2]" in text


def test_pallas_kernel_lowers_to_hlo_text():
    n = 2 * lorenzo.TILE
    lowered = jax.jit(lambda x: lorenzo.lorenzo_quant(x, 1e-3)).lower(
        jax.ShapeDtypeStruct((n,), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # interpret=True must not leave a Mosaic custom-call behind.
    assert "mosaic" not in text.lower()


def test_full_aot_run_tiny(tmp_path: Path):
    out = tmp_path / "artifacts"
    res = subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(out),
            "--preset",
            "tiny",
        ],
        cwd=Path(__file__).resolve().parents[1],
        capture_output=True,
        text=True,
    )
    assert res.returncode == 0, res.stderr
    manifest = json.loads((out / "manifest.json").read_text())
    names = {a["name"] for a in manifest["artifacts"]}
    assert names == {"grad_step", "grad_step_zccl", "lorenzo_quant", "eval_loss"}
    cfg = model.PRESETS["tiny"]
    porder = model.param_order(cfg)
    # grad_step: params + x + y inputs; 1 + len(params) outputs.
    gs = next(a for a in manifest["artifacts"] if a["name"] == "grad_step")
    assert len(gs["inputs"]) == len(porder) + 2
    assert len(gs["outputs"]) == len(porder) + 1
    assert gs["inputs"][-1]["dtype"] == "int32"
    # Param table is contiguous and matches f32 sizes.
    off = 0
    for p in manifest["params"]:
        assert p["offset"] == off
        n = 1
        for d in p["shape"]:
            n *= d
        assert p["bytes"] == 4 * n
        off += p["bytes"]
    assert (out / "params.bin").stat().st_size == off
    for a in manifest["artifacts"]:
        text = (out / a["file"]).read_text()
        assert text.startswith("HloModule")
