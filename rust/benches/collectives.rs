//! `cargo bench --bench collectives` — real in-process collective wall
//! times across modes (the small-scale counterpart of Figs. 10–15; the
//! cluster-scale series come from `zccl bench fig*`).

use zccl::collectives::{
    allgather, allreduce, bcast, reduce_scatter, run_ranks, scatter, Mode, ReduceOp,
};
use zccl::compress::{CompressorKind, ErrorBound};
use zccl::coordinator::Metrics;
use zccl::data::fields::{Field, FieldKind};
use zccl::util::bench::Table;

fn modes() -> Vec<(&'static str, Mode)> {
    let eb = ErrorBound::Rel(1e-4);
    vec![
        ("plain", Mode::plain()),
        ("cprp2p", Mode::cprp2p(CompressorKind::FzLight, eb)),
        ("ccoll", Mode::ccoll(eb)),
        ("zccl", Mode::zccl(CompressorKind::FzLight, eb)),
    ]
}

fn bench<F>(label: &str, t: &mut Table, reps: usize, f: F)
where
    F: Fn(Mode) -> f64,
{
    for (mode_name, mode) in modes() {
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            best = best.min(f(mode));
        }
        t.row(vec![label.into(), mode_name.into(), format!("{best:.4}")]);
    }
}

fn main() {
    let n = 4;
    let values = 1 << 20; // 4 MiB per rank
    let mut t = Table::new(&["collective", "mode", "best s"]);

    bench("allreduce", &mut t, 3, |mode| {
        let out = run_ranks(n, move |c| {
            let f = Field::generate(FieldKind::Rtm, values, 3 + c.rank() as u64);
            let mut m = Metrics::default();
            let t0 = std::time::Instant::now();
            allreduce(c, &f.values, ReduceOp::Sum, &mode, &mut m).unwrap();
            t0.elapsed().as_secs_f64()
        });
        out.into_iter().fold(0.0, f64::max)
    });

    bench("allgather", &mut t, 3, |mode| {
        let out = run_ranks(n, move |c| {
            let f = Field::generate(FieldKind::Rtm, values / n, 3 + c.rank() as u64);
            let mut m = Metrics::default();
            let t0 = std::time::Instant::now();
            allgather(c, &f.values, &mode, &mut m).unwrap();
            t0.elapsed().as_secs_f64()
        });
        out.into_iter().fold(0.0, f64::max)
    });

    bench("reduce_scatter", &mut t, 3, |mode| {
        let out = run_ranks(n, move |c| {
            let f = Field::generate(FieldKind::Rtm, values, 3 + c.rank() as u64);
            let mut m = Metrics::default();
            let t0 = std::time::Instant::now();
            reduce_scatter(c, &f.values, ReduceOp::Sum, &mode, &mut m).unwrap();
            t0.elapsed().as_secs_f64()
        });
        out.into_iter().fold(0.0, f64::max)
    });

    bench("bcast", &mut t, 3, |mode| {
        let out = run_ranks(n, move |c| {
            let data =
                (c.rank() == 0).then(|| Field::generate(FieldKind::Rtm, values, 3).values);
            let mut m = Metrics::default();
            let t0 = std::time::Instant::now();
            bcast(c, data.as_deref(), 0, &mode, &mut m).unwrap();
            t0.elapsed().as_secs_f64()
        });
        out.into_iter().fold(0.0, f64::max)
    });

    bench("scatter", &mut t, 3, |mode| {
        let out = run_ranks(n, move |c| {
            let data =
                (c.rank() == 0).then(|| Field::generate(FieldKind::Rtm, values, 3).values);
            let mut m = Metrics::default();
            let t0 = std::time::Instant::now();
            scatter(c, data.as_deref(), 0, &mode, &mut m).unwrap();
            t0.elapsed().as_secs_f64()
        });
        out.into_iter().fold(0.0, f64::max)
    });

    println!("{}", t.render());
}
