//! `cargo bench --bench collectives` — real in-process collective wall
//! times across modes (the small-scale counterpart of Figs. 10–15; the
//! cluster-scale series come from `zccl bench fig*`).
//!
//! All cases drive the collectives through the persistent [`CollCtx`]
//! API; the `allreduce-iterated` / `reduce_scatter-iterated` cases
//! additionally report the context's pool counters to show that warm
//! iterations run without codec construction or scratch growth, and
//! `iallreduce-iterated` drives the same loop through the nonblocking
//! request API (launch → test-polled compute → wait), reporting the
//! exposed/hidden communication split.
//!
//! The `allgather-iterated` case exercises the pooled zero-copy receive
//! path (lease → recv_into → placement decode) and emits one
//! machine-readable `BENCH_allgather.json` line (bytes, ns/element,
//! copies-per-hop, alloc counts) next to PR 2's `BENCH_reduce.json`,
//! which the final case still produces by isolating the per-hop
//! **receive side** of a reduction collective — fused decompress–reduce
//! vs decompress-then-fold on the same frame — so both receive-path
//! trajectories are tracked from PR to PR.
//!
//! The `allreduce-hier-4x4` case runs the hierarchical allreduce over a
//! node-partitioned 4×4 fabric against flat ZCCL on the same 16 ranks
//! and emits `BENCH_hier.json`: bytes crossing the slow tier per
//! iteration, warm ns/element for both schedules, and the leader vs
//! follower compression counts (followers must be 0).

use zccl::collectives::{run_ranks, run_ranks_on, CollCtx, Mode, ReduceOp};
use zccl::compress::{Compressor, CompressorKind, ErrorBound, FzLight};
use zccl::data::fields::{Field, FieldKind};
use zccl::topology::Topology;
use zccl::util::bench::{emit_bench_line, measure, Table};
use zccl::util::json::Json;

fn modes() -> Vec<(&'static str, Mode)> {
    let eb = ErrorBound::Rel(1e-4);
    vec![
        ("plain", Mode::plain()),
        ("cprp2p", Mode::cprp2p(CompressorKind::FzLight, eb)),
        ("ccoll", Mode::ccoll(eb)),
        // Exercise the §3.5.1 fixed-pipeline knob through its builder.
        ("zccl", Mode::zccl(CompressorKind::FzLight, eb).with_pipeline_bytes(1 << 16)),
    ]
}

fn bench<F>(label: &str, t: &mut Table, reps: usize, f: F)
where
    F: Fn(Mode) -> f64,
{
    for (mode_name, mode) in modes() {
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            best = best.min(f(mode));
        }
        t.row(vec![label.into(), mode_name.into(), format!("{best:.4}")]);
    }
}

fn main() {
    let n = 4;
    let values = 1 << 20; // 4 MiB per rank
    let mut t = Table::new(&["collective", "mode", "best s"]);

    bench("allreduce", &mut t, 3, |mode| {
        let out = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, mode);
            let f = Field::generate(FieldKind::Rtm, values, 3 + ctx.rank() as u64);
            let t0 = std::time::Instant::now();
            ctx.allreduce(&f.values, ReduceOp::Sum).unwrap();
            t0.elapsed().as_secs_f64()
        });
        out.into_iter().fold(0.0, f64::max)
    });

    bench("allgather", &mut t, 3, |mode| {
        let out = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, mode);
            let f = Field::generate(FieldKind::Rtm, values / n, 3 + ctx.rank() as u64);
            let t0 = std::time::Instant::now();
            ctx.allgather(&f.values).unwrap();
            t0.elapsed().as_secs_f64()
        });
        out.into_iter().fold(0.0, f64::max)
    });

    bench("reduce_scatter", &mut t, 3, |mode| {
        let out = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, mode);
            let f = Field::generate(FieldKind::Rtm, values, 3 + ctx.rank() as u64);
            let t0 = std::time::Instant::now();
            ctx.reduce_scatter(&f.values, ReduceOp::Sum).unwrap();
            t0.elapsed().as_secs_f64()
        });
        out.into_iter().fold(0.0, f64::max)
    });

    bench("bcast", &mut t, 3, |mode| {
        let out = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, mode);
            let data =
                (ctx.rank() == 0).then(|| Field::generate(FieldKind::Rtm, values, 3).values);
            let t0 = std::time::Instant::now();
            ctx.bcast(data.as_deref(), 0).unwrap();
            t0.elapsed().as_secs_f64()
        });
        out.into_iter().fold(0.0, f64::max)
    });

    bench("scatter", &mut t, 3, |mode| {
        let out = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, mode);
            let data =
                (ctx.rank() == 0).then(|| Field::generate(FieldKind::Rtm, values, 3).values);
            let t0 = std::time::Instant::now();
            ctx.scatter(data.as_deref(), 0).unwrap();
            t0.elapsed().as_secs_f64()
        });
        out.into_iter().fold(0.0, f64::max)
    });

    // Iterated allreduce on ONE persistent context — the DDP-loop shape.
    // Reports first-iteration (cold pool) vs best warm iteration, plus the
    // pool/codec counters proving the warm path allocates nothing new.
    let iters = 6;
    for (mode_name, mode) in modes() {
        let out = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, mode);
            let f = Field::generate(FieldKind::Rtm, values, 3 + ctx.rank() as u64);
            let mut dst = Vec::new();
            let mut times = Vec::with_capacity(iters);
            for _ in 0..iters {
                let t0 = std::time::Instant::now();
                ctx.allreduce_into(&f.values, ReduceOp::Sum, &mut dst).unwrap();
                times.push(t0.elapsed().as_secs_f64());
            }
            (times, ctx.pool_stats(), ctx.codec_builds())
        });
        let cold = out.iter().map(|(ts, _, _)| ts[0]).fold(0.0, f64::max);
        let warm = out
            .iter()
            .map(|(ts, _, _)| ts[1..].iter().cloned().fold(f64::INFINITY, f64::min))
            .fold(0.0, f64::max);
        let (s, builds) = (&out[0].1, out[0].2);
        t.row(vec![
            "allreduce-iterated".into(),
            mode_name.into(),
            format!(
                "{warm:.4} (cold {cold:.4}; codec builds {builds}, pool creates {}B/{}F)",
                s.byte_buffers_created, s.f32_buffers_created
            ),
        ]);
    }

    // Iterated NONBLOCKING allreduce on one persistent context — launch,
    // synthetic compute with test() polls driving progress, wait_into.
    // Reports warm wall time plus the exposed/hidden communication split
    // from the overlap accounting.
    for (mode_name, mode) in modes() {
        let out = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, mode);
            let f = Field::generate(FieldKind::Rtm, values, 3 + ctx.rank() as u64);
            let mut dst = Vec::new();
            let mut times = Vec::with_capacity(iters);
            for _ in 0..iters {
                let t0 = std::time::Instant::now();
                let req = ctx.iallreduce(&f.values, ReduceOp::Sum).unwrap();
                let mut acc = 0.0f32;
                for i in 0..256 {
                    acc += std::hint::black_box(i as f32).sqrt();
                    ctx.test(&req).unwrap();
                }
                std::hint::black_box(acc);
                ctx.wait_into(req, &mut dst).unwrap();
                times.push(t0.elapsed().as_secs_f64());
            }
            let m = ctx.take_metrics();
            (times, m.exposed_comm_s, m.hidden_comm_s, ctx.pool_stats())
        });
        let warm = out
            .iter()
            .map(|(ts, ..)| ts[1..].iter().cloned().fold(f64::INFINITY, f64::min))
            .fold(0.0, f64::max);
        let exposed = out.iter().map(|(_, e, _, _)| *e).fold(0.0, f64::max);
        let hidden = out.iter().map(|(_, _, h, _)| *h).fold(0.0, f64::max);
        let s = &out[0].3;
        t.row(vec![
            "iallreduce-iterated".into(),
            mode_name.into(),
            format!(
                "{warm:.4} (exposed {exposed:.4} / hidden {hidden:.4}; pool creates {}B/{}F)",
                s.byte_buffers_created, s.f32_buffers_created
            ),
        ]);
    }

    // Iterated reduce-scatter — the collective whose receive side is the
    // fused decompress–reduce kernel; per-hop DecompressReduce time is
    // reported alongside the wall time.
    for (mode_name, mode) in modes() {
        let out = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, mode);
            let f = Field::generate(FieldKind::Rtm, values, 3 + ctx.rank() as u64);
            let mut times = Vec::with_capacity(iters);
            for _ in 0..iters {
                let t0 = std::time::Instant::now();
                ctx.reduce_scatter(&f.values, ReduceOp::Sum).unwrap();
                times.push(t0.elapsed().as_secs_f64());
            }
            (times, ctx.metrics().decompress_reduce_s)
        });
        let warm = out
            .iter()
            .map(|(ts, _)| ts[1..].iter().cloned().fold(f64::INFINITY, f64::min))
            .fold(0.0, f64::max);
        let fused_s = out.iter().map(|(_, s)| *s).fold(0.0, f64::max);
        t.row(vec![
            "reduce_scatter-iterated".into(),
            mode_name.into(),
            format!("{warm:.4} (decompress-reduce total {fused_s:.4})"),
        ]);
    }

    // Iterated allgather — the receive path redesigned around pooled
    // recv_into + placement decode. Reports warm wall time plus the
    // counters proving the warm receive side allocates no byte buffers
    // and performs no post-decode copies; emits BENCH_allgather.json.
    let mut allgather_json: Option<Json> = None;
    for (mode_name, mode) in modes() {
        let out = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, mode);
            let f = Field::generate(FieldKind::Rtm, values / n, 3 + ctx.rank() as u64);
            let mut dst = Vec::new();
            let mut times = Vec::with_capacity(iters);
            for _ in 0..iters {
                let t0 = std::time::Instant::now();
                ctx.allgather_into(&f.values, &mut dst).unwrap();
                times.push(t0.elapsed().as_secs_f64());
            }
            let m = ctx.take_metrics();
            (times, ctx.pool_stats(), ctx.packet_stats(), m.bytes_recv)
        });
        let warm = out
            .iter()
            .map(|(ts, _, _, _)| ts[1..].iter().cloned().fold(f64::INFINITY, f64::min))
            .fold(0.0, f64::max);
        let (pool, packets, bytes_recv) = (&out[0].1, &out[0].2, out[0].3);
        let hops = (iters * (n - 1)) as f64;
        // Post-decode copies per receive hop: staged decodes are the only
        // ones that copy (own-frame decodes are not hops but stage too —
        // the ratio is what the trajectory tracks).
        let copies_per_hop = pool.staged_decodes as f64 / hops;
        t.row(vec![
            "allgather-iterated".into(),
            mode_name.into(),
            format!(
                "{warm:.4} (pool creates {}B/{}F, packet allocs {}, \
                 placement/staged {}/{})",
                pool.byte_buffers_created,
                pool.f32_buffers_created,
                packets.allocated,
                pool.placement_decodes,
                pool.staged_decodes
            ),
        ]);
        if mode_name == "zccl" {
            let summary = Json::obj(vec![
                ("bench", Json::Str("allgather_receive_path".into())),
                ("values", Json::Num(values as f64)),
                ("ranks", Json::Num(n as f64)),
                ("iters", Json::Num(iters as f64)),
                ("bytes_recv_per_rank", Json::Num(bytes_recv as f64 / iters as f64)),
                ("warm_ns_per_element", Json::Num(warm * 1e9 / values as f64)),
                ("copies_per_hop", Json::Num(copies_per_hop)),
                ("byte_buffers_created", Json::Num(pool.byte_buffers_created as f64)),
                ("f32_buffers_created", Json::Num(pool.f32_buffers_created as f64)),
                ("packet_allocs", Json::Num(packets.allocated as f64)),
                ("placement_decodes", Json::Num(pool.placement_decodes as f64)),
                ("staged_decodes", Json::Num(pool.staged_decodes as f64)),
            ]);
            allgather_json = Some(summary);
        }
    }

    // Iterated HIERARCHICAL allreduce over a 4-node x 4-rank
    // node-partitioned fabric vs flat ZCCL on the same 16 ranks: the
    // tier ledger reports how many bytes cross the slow tier per
    // iteration, and the codec counters show compression collapsing onto
    // the leaders. Emits BENCH_hier.json.
    let hier_json = {
        let topo = Topology::blocked(4, 4);
        let hn = topo.ranks();
        let hvalues = 1 << 18; // 1 MiB per rank so 16 ranks stay snappy
        let eb = ErrorBound::Rel(1e-4);
        let run = |mode: Mode, topo: &Topology| {
            let t2 = topo.clone();
            run_ranks_on(topo, move |c| {
                let mut ctx = CollCtx::over_nodes(c, mode, t2.clone()).unwrap();
                let f = Field::generate(FieldKind::Rtm, hvalues, 3 + ctx.rank() as u64);
                let mut dst = Vec::new();
                let mut times = Vec::with_capacity(iters);
                for _ in 0..iters {
                    let t0 = std::time::Instant::now();
                    ctx.allreduce_into(&f.values, ReduceOp::Sum, &mut dst).unwrap();
                    times.push(t0.elapsed().as_secs_f64());
                }
                (times, ctx.compress_calls())
            })
        };
        let (flat_out, flat_report) =
            run(Mode::zccl(CompressorKind::FzLight, eb), &topo);
        let (hier_out, hier_report) = run(Mode::hier(CompressorKind::FzLight, eb), &topo);
        let warm = |out: &[(Vec<f64>, u64)]| {
            out.iter()
                .map(|(ts, _)| ts[1..].iter().cloned().fold(f64::INFINITY, f64::min))
                .fold(0.0, f64::max)
        };
        let (flat_warm, hier_warm) = (warm(&flat_out), warm(&hier_out));
        let compresses = |leaders: bool| -> u64 {
            hier_out
                .iter()
                .enumerate()
                .filter(|(r, _)| topo.is_leader(*r) == leaders)
                .map(|(_, o)| o.1)
                .sum()
        };
        let (leader_compresses, follower_compresses) = (compresses(true), compresses(false));
        t.row(vec![
            "allreduce-hier-4x4".into(),
            "zccl-flat".into(),
            format!(
                "{flat_warm:.4} ({:.1} MB/iter on slow tier)",
                flat_report.tier.inter_bytes as f64 / iters as f64 / 1e6
            ),
        ]);
        t.row(vec![
            "allreduce-hier-4x4".into(),
            "hier".into(),
            format!(
                "{hier_warm:.4} ({:.1} MB/iter on slow tier; \
                 {leader_compresses} leader / {follower_compresses} follower compresses)",
                hier_report.tier.inter_bytes as f64 / iters as f64 / 1e6
            ),
        ]);
        Json::obj(vec![
            ("bench", Json::Str("hier_allreduce_4x4".into())),
            ("values", Json::Num(hvalues as f64)),
            ("ranks", Json::Num(hn as f64)),
            ("nodes", Json::Num(topo.nodes() as f64)),
            ("iters", Json::Num(iters as f64)),
            ("hier_warm_ns_per_element", Json::Num(hier_warm * 1e9 / hvalues as f64)),
            ("flat_warm_ns_per_element", Json::Num(flat_warm * 1e9 / hvalues as f64)),
            (
                "hier_slow_tier_bytes_per_iter",
                Json::Num(hier_report.tier.inter_bytes as f64 / iters as f64),
            ),
            (
                "flat_slow_tier_bytes_per_iter",
                Json::Num(flat_report.tier.inter_bytes as f64 / iters as f64),
            ),
            ("leader_compress_calls", Json::Num(leader_compresses as f64)),
            ("follower_compress_calls", Json::Num(follower_compresses as f64)),
        ])
    };

    // Per-hop receive side in isolation: the same compressed partial
    // consumed fused vs unfused. The fused path must make fewer memory
    // passes (constant blocks fold as a broadcast, no partial vector).
    let codec = FzLight::default();
    let field = Field::generate(FieldKind::Hurricane, values, 11);
    let frame = codec.compress(&field.values, ErrorBound::Rel(1e-4)).unwrap();
    let base = Field::generate(FieldKind::Hurricane, values, 12).values;
    let mut acc = base.clone();
    let mut partial: Vec<f32> = Vec::new();
    let unfused = measure(1, 5, || {
        acc.copy_from_slice(&base);
        partial.clear();
        codec.decompress_into(&frame.bytes, &mut partial).unwrap();
        ReduceOp::Sum.fold(&mut acc, &partial);
    });
    let fused = measure(1, 5, || {
        acc.copy_from_slice(&base);
        codec.decompress_fold_into(&frame.bytes, ReduceOp::Sum, &mut acc).unwrap();
    });
    let per_elem = |s: f64| s * 1e9 / values as f64;
    t.row(vec![
        "receive-hop-unfused".into(),
        "fzlight".into(),
        format!("{:.4} ({:.2} ns/elem)", unfused.mean_s, per_elem(unfused.mean_s)),
    ]);
    t.row(vec![
        "receive-hop-fused".into(),
        "fzlight".into(),
        format!("{:.4} ({:.2} ns/elem)", fused.mean_s, per_elem(fused.mean_s)),
    ]);

    println!("{}", t.render());

    // Single-line machine-readable trajectory summary.
    let summary = Json::obj(vec![
        ("bench", Json::Str("reduce_receive_fused_vs_unfused".into())),
        ("values", Json::Num(values as f64)),
        ("compressed_bytes", Json::Num(frame.bytes.len() as f64)),
        ("constant_block_fraction", Json::Num(frame.stats.constant_fraction())),
        ("fused_ns_per_element", Json::Num(per_elem(fused.mean_s))),
        ("unfused_ns_per_element", Json::Num(per_elem(unfused.mean_s))),
        ("speedup", Json::Num(unfused.mean_s / fused.mean_s.max(1e-12))),
    ]);
    emit_bench_line("BENCH_reduce.json", &summary);
    if let Some(summary) = allgather_json {
        emit_bench_line("BENCH_allgather.json", &summary);
    }
    emit_bench_line("BENCH_hier.json", &hier_json);
}
