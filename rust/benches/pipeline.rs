//! `cargo bench --bench pipeline` — PIPE-fZ-light overhead: chunked
//! compression with a progress hook vs the monolithic codec, across chunk
//! sizes (the §3.5.2 design knob; paper fixes 5120 values).

use zccl::compress::{Compressor, ErrorBound, FzLight, PipeFzLight};
use zccl::data::fields::{Field, FieldKind};
use zccl::util::bench::{measure_for, Table};

fn main() {
    let f = Field::generate(FieldKind::Rtm, 1 << 21, 9);
    let bytes = f.values.len() * 4;
    let eb = ErrorBound::Rel(1e-4);
    let mut t = Table::new(&["codec", "chunk", "comp GB/s", "hook calls/iter"]);

    let mono = FzLight::default();
    let m = measure_for(0.2, || mono.compress(&f.values, eb).unwrap());
    t.row(vec![
        "fzlight (mono)".into(),
        "5120".into(),
        format!("{:.3}", m.gbps(bytes)),
        "0".into(),
    ]);

    for chunk in [1280usize, 2560, 5120, 10240, 40960] {
        let pipe = PipeFzLight::with_chunk(chunk);
        let mut calls = 0u64;
        let m = measure_for(0.2, || {
            pipe.compress_with_progress(&f.values, eb, &mut |_| calls += 1).unwrap()
        });
        t.row(vec![
            "PIPE-fzlight".into(),
            format!("{chunk}"),
            format!("{:.3}", m.gbps(bytes)),
            format!("{}", calls / m.iters as u64),
        ]);
    }
    println!("{}", t.render());
}
