//! `cargo bench --bench compressors` — codec micro-benchmarks (the
//! Tables 1–3 measurement core, custom harness; this environment has no
//! criterion).
//!
//! Besides the per-codec table below, this runs the shared
//! `codec_bench` driver (also behind `zccl bench codec`): end-to-end
//! comp/decomp GB/s for the bit-shifting codecs plus the word-parallel
//! `pack_fixed`/`unpack_fixed` kernels against the scalar
//! `BitWriter`/`BitReader` reference path, emitting the single-line
//! `BENCH_codec.json` trajectory summary (`speedup_vs_reference`) next
//! to `BENCH_reduce` / `BENCH_allgather` / `BENCH_hier`.

use zccl::compress::{self, Compressor, CompressorKind, ErrorBound, MtCompressor};
use zccl::coordinator::harness::codec_bench;
use zccl::data::fields::{Field, FieldKind};
use zccl::util::bench::{emit_bench_line, measure_for, Table};

fn main() {
    let n = 1 << 21; // 8 MiB of f32
    let budget = 0.15;
    let mut t = Table::new(&[
        "codec", "threads", "dataset", "rel", "comp GB/s", "decomp GB/s", "ratio",
    ]);
    for kind in CompressorKind::ALL {
        for fk in [FieldKind::Rtm, FieldKind::Nyx] {
            let f = Field::generate(fk, n, 42);
            let bytes = f.values.len() * 4;
            for rel in [1e-2, 1e-4] {
                for mt in [false, true] {
                    // The ZFP baselines have no chunk-parallel mode.
                    if mt && !matches!(kind, CompressorKind::FzLight | CompressorKind::Szx) {
                        continue;
                    }
                    let codec: Box<dyn Compressor> = if mt {
                        Box::new(MtCompressor::new(kind))
                    } else {
                        compress::build(kind)
                    };
                    let eb = ErrorBound::Rel(rel);
                    let frame = codec.compress(&f.values, eb).expect("compress");
                    let c = measure_for(budget, || codec.compress(&f.values, eb).unwrap());
                    let d = measure_for(budget, || codec.decompress(&frame.bytes).unwrap());
                    t.row(vec![
                        kind.name().into(),
                        if mt { "multi".into() } else { "1".into() },
                        fk.name().into(),
                        format!("{rel:.0e}"),
                        format!("{:.3}", c.gbps(bytes)),
                        format!("{:.3}", d.gbps(bytes)),
                        format!("{:.1}", frame.stats.ratio()),
                    ]);
                }
            }
        }
    }
    println!("{}", t.render());

    // Word-parallel kernel trajectory: shared driver with `zccl bench
    // codec`, smaller budget here since the table above already covers
    // the end-to-end sweep.
    let (tables, summary) = codec_bench(1 << 20, 0.05);
    for (name, table) in tables {
        println!("== {name} ==");
        println!("{}", table.render());
    }
    emit_bench_line("BENCH_codec.json", &summary);
}
