//! `cargo bench --bench compressors` — codec micro-benchmarks (the
//! Tables 1–3 measurement core, custom harness; this environment has no
//! criterion).

use zccl::compress::{self, Compressor, CompressorKind, ErrorBound, MtCompressor};
use zccl::data::fields::{Field, FieldKind};
use zccl::util::bench::{measure_for, Table};

fn main() {
    let n = 1 << 21; // 8 MiB of f32
    let budget = 0.15;
    let mut t = Table::new(&[
        "codec", "threads", "dataset", "rel", "comp GB/s", "decomp GB/s", "ratio",
    ]);
    for kind in CompressorKind::ALL {
        for fk in [FieldKind::Rtm, FieldKind::Nyx] {
            let f = Field::generate(fk, n, 42);
            let bytes = f.values.len() * 4;
            for rel in [1e-2, 1e-4] {
                for mt in [false, true] {
                    // The ZFP baselines have no chunk-parallel mode.
                    if mt && !matches!(kind, CompressorKind::FzLight | CompressorKind::Szx) {
                        continue;
                    }
                    let codec: Box<dyn Compressor> = if mt {
                        Box::new(MtCompressor::new(kind))
                    } else {
                        compress::build(kind)
                    };
                    let eb = ErrorBound::Rel(rel);
                    let frame = codec.compress(&f.values, eb).expect("compress");
                    let c = measure_for(budget, || codec.compress(&f.values, eb).unwrap());
                    let d = measure_for(budget, || codec.decompress(&frame.bytes).unwrap());
                    t.row(vec![
                        kind.name().into(),
                        if mt { "multi".into() } else { "1".into() },
                        fk.name().into(),
                        format!("{rel:.0e}"),
                        format!("{:.3}", c.gbps(bytes)),
                        format!("{:.3}", d.gbps(bytes)),
                        format!("{:.1}", frame.stats.ratio()),
                    ]);
                }
            }
        }
    }
    println!("{}", t.render());
}
