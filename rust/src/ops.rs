//! Reduction operators — the elementwise fold semantics shared by the
//! collective layer (which reduces received partials) and the compression
//! layer (whose fused decompress–reduce kernels fold values as they
//! decode, see [`crate::compress::Compressor::decompress_fold_into`]).
//! Lives below both layers so codec ↔ collective stays acyclic; the
//! canonical public path remains [`crate::collectives::ReduceOp`].

/// The reduction operators the paper analyses (§3.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    /// Elementwise sum (Theorem 1).
    Sum,
    /// Elementwise mean (Corollary 2): sum followed by a `1/n` scale.
    Avg,
    /// Elementwise maximum (Theorem 2).
    Max,
    /// Elementwise minimum (Theorem 2).
    Min,
}

impl ReduceOp {
    /// Fold `src` into `acc` elementwise.
    #[inline]
    pub fn fold(self, acc: &mut [f32], src: &[f32]) {
        debug_assert_eq!(acc.len(), src.len());
        self.apply_slice(acc, src);
    }

    /// Fold a decoded block into the matching accumulator window — the
    /// slice-granularity step of the fused decompress–reduce kernel
    /// (each decoded block folds as one straight-line loop rather than a
    /// per-value [`ReduceOp::apply`] call). Bit-identical to the
    /// corresponding lanes of [`ReduceOp::fold`], which delegates here.
    #[inline]
    pub fn apply_slice(self, acc: &mut [f32], src: &[f32]) {
        match self {
            ReduceOp::Sum | ReduceOp::Avg => {
                for (a, s) in acc.iter_mut().zip(src) {
                    *a += s;
                }
            }
            ReduceOp::Max => {
                for (a, s) in acc.iter_mut().zip(src) {
                    *a = a.max(*s);
                }
            }
            ReduceOp::Min => {
                for (a, s) in acc.iter_mut().zip(src) {
                    *a = a.min(*s);
                }
            }
        }
    }

    /// Fold a single value into one accumulator slot (used where values
    /// arrive one at a time, e.g. folding raw wire bytes). Bit-identical
    /// to the corresponding lane of [`ReduceOp::fold`].
    #[inline]
    pub fn apply(self, a: &mut f32, v: f32) {
        match self {
            ReduceOp::Sum | ReduceOp::Avg => *a += v,
            ReduceOp::Max => *a = a.max(v),
            ReduceOp::Min => *a = a.min(v),
        }
    }

    /// Fold the same value into every element of `acc` — the fused
    /// kernel's constant-block fast path: one broadcast add/max/min over
    /// the run with no per-value decode.
    #[inline]
    pub fn apply_run(self, acc: &mut [f32], v: f32) {
        match self {
            ReduceOp::Sum | ReduceOp::Avg => {
                for a in acc.iter_mut() {
                    *a += v;
                }
            }
            ReduceOp::Max => {
                for a in acc.iter_mut() {
                    *a = a.max(v);
                }
            }
            ReduceOp::Min => {
                for a in acc.iter_mut() {
                    *a = a.min(v);
                }
            }
        }
    }

    /// Final scaling (only `Avg` rescales by the communicator size).
    #[inline]
    pub fn finish(self, acc: &mut [f32], n: usize) {
        if self == ReduceOp::Avg {
            let inv = 1.0 / n as f32;
            for a in acc.iter_mut() {
                *a *= inv;
            }
        }
    }
}
