//! `zccl` CLI — leader entrypoint for the ZCCL reproduction.
//!
//! ```text
//! zccl info
//! zccl bench <id|all> [--out DIR] [--budget S]
//!                                          regenerate paper tables/figures
//! zccl run [--ranks N] [--values V] [mode flags]
//!                                          one in-process collective run
//! zccl launch --ranks N [--values V] [mode flags]
//!                                          multi-process over local TCP
//! zccl worker --rank R --peers a:p,... [--values V] [mode flags]
//! zccl train [--workers W] [--steps S] [--artifacts DIR] [mode flags]
//!                                          DDP transformer training (e2e)
//! zccl verify [--max-ranks N]              statically verify all collective
//!                                          schedules (deadlock/tag safety)
//! ```
//!
//! Mode flags: `--algo plain|cprp2p|ccoll|zccl|hier`, `--compressor
//! fzlight|szx|zfp-abs|zfp-fxr`, `--rel-eb X`, `--abs-eb X`,
//! `--multithread`, `--staged`, `--pipe-chunk N`, `--pipeline-bytes N`.

use std::path::PathBuf;
use std::time::Duration;

use zccl::collectives::{run_ranks, CollCtx, ReduceOp};
use zccl::config::mode_from_args;
use zccl::coordinator::{harness, launch, Metrics};
use zccl::data::fields::FieldKind;
use zccl::transport::tcp::TcpTransport;

struct Args {
    positional: Vec<String>,
    flags: std::collections::BTreeMap<String, String>,
    mode_flags: Vec<String>,
}

const MODE_FLAGS: &[&str] = &[
    "--algo",
    "--compressor",
    "--rel-eb",
    "--abs-eb",
    "--pipe-chunk",
    "--pipeline-bytes",
];

fn parse_args(raw: &[String]) -> Result<Args, String> {
    let mut a = Args {
        positional: Vec::new(),
        flags: Default::default(),
        mode_flags: Vec::new(),
    };
    let mut it = raw.iter().peekable();
    while let Some(arg) = it.next() {
        if arg == "--multithread" || arg == "--staged" {
            a.mode_flags.push(arg.clone());
        } else if MODE_FLAGS.contains(&arg.as_str()) {
            let v = it.next().ok_or_else(|| format!("{arg} needs a value"))?;
            a.mode_flags.push(arg.clone());
            a.mode_flags.push(v.clone());
        } else if let Some(name) = arg.strip_prefix("--") {
            let v = it.next().ok_or_else(|| format!("{arg} needs a value"))?;
            a.flags.insert(name.to_string(), v.clone());
        } else {
            a.positional.push(arg.clone());
        }
    }
    Ok(a)
}

fn usize_flag(a: &Args, name: &str, default: usize) -> usize {
    a.flags.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn real_main() -> zccl::Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let cmd = raw.first().cloned().unwrap_or_default();
    let args = parse_args(raw.get(1..).unwrap_or(&[])).map_err(zccl::Error::invalid)?;

    match cmd.as_str() {
        "info" => {
            println!("zccl {} — ZCCL reproduction", env!("CARGO_PKG_VERSION"));
            match zccl::runtime::Runtime::cpu() {
                Ok(rt) => println!("PJRT: {}", rt.platform()),
                Err(e) => println!("PJRT: unavailable ({e})"),
            }
            println!("benches: {}", harness::ALL.join(", "));
        }
        "bench" => {
            let id = args.positional.first().cloned().unwrap_or_else(|| "all".into());
            let out = PathBuf::from(
                args.flags.get("out").cloned().unwrap_or_else(|| "results".into()),
            );
            let budget = args.flags.get("budget").and_then(|v| v.parse::<f64>().ok());
            harness::run(&id, &out, budget)?;
        }
        "run" => {
            let n = usize_flag(&args, "ranks", 4);
            let values = usize_flag(&args, "values", 1 << 20);
            let mode = mode_from_args(&args.mode_flags)?;
            let field = args
                .flags
                .get("field")
                .map(|f| FieldKind::parse(f))
                .transpose()?
                .unwrap_or(FieldKind::Rtm);
            let out = run_ranks(n, move |c| {
                let mut ctx = CollCtx::over(c, mode);
                let f = zccl::data::fields::Field::generate(
                    field,
                    values,
                    1000 + ctx.rank() as u64,
                );
                let t0 = std::time::Instant::now();
                ctx.allreduce(&f.values, ReduceOp::Sum).unwrap();
                (t0.elapsed().as_secs_f64(), ctx.take_metrics())
            });
            let wall = out.iter().map(|x| x.0).fold(0.0, f64::max);
            let mut m = Metrics::default();
            for (_, mm) in &out {
                m.merge(mm);
            }
            let (c, comm, compute, other) = m.breakdown_pct();
            println!(
                "allreduce {values} values x {n} ranks: {wall:.4}s \
                 (compress {c:.1}% comm {comm:.1}% compute {compute:.1}% other {other:.1}%)"
            );
        }
        "launch" => {
            let n = usize_flag(&args, "ranks", 2);
            let values = usize_flag(&args, "values", 1 << 20);
            let port = usize_flag(&args, "port", 47000) as u16;
            launch::launch_local(n, port, values, &args.mode_flags)?;
        }
        "worker" => {
            let rank = usize_flag(&args, "rank", usize::MAX);
            let peers_s = args
                .flags
                .get("peers")
                .ok_or_else(|| zccl::Error::invalid("worker needs --peers"))?;
            let peers: Vec<std::net::SocketAddr> = peers_s
                .split(',')
                .map(|p| p.parse())
                .collect::<Result<_, _>>()
                .map_err(|e| zccl::Error::invalid(format!("bad --peers: {e}")))?;
            let values = usize_flag(&args, "values", 1 << 20);
            let spec = launch::LaunchSpec {
                peers,
                rank,
                values,
                mode: mode_from_args(&args.mode_flags)?,
                field: FieldKind::Rtm,
            };
            let (secs, _, checksum) = launch::run_rank(&spec)?;
            println!("rank {rank}: {secs:.4}s (checksum {checksum:.3e})");
        }
        "train" => {
            let workers = usize_flag(&args, "workers", 2);
            let steps = usize_flag(&args, "steps", 50);
            let dir = PathBuf::from(
                args.flags.get("artifacts").cloned().unwrap_or_else(|| "artifacts".into()),
            );
            let mode = mode_from_args(&args.mode_flags)?;
            let mut cfg = zccl::apps::ddp::DdpConfig::new(&dir, workers, steps, mode);
            if let Some(lr) = args.flags.get("lr").and_then(|v| v.parse().ok()) {
                cfg.lr = lr;
            }
            if let Some(a) = args.flags.get("grad-artifact") {
                cfg.grad_artifact = a.clone();
            }
            let report = zccl::apps::ddp::train(&cfg)?;
            println!("step,loss,allreduce_s");
            for s in &report.steps {
                println!("{},{:.4},{:.5}", s.step, s.loss, s.allreduce_s);
            }
            println!("# final param norm {:.4}", report.final_param_norm);
        }
        "verify" => {
            let max = usize_flag(&args, "max-ranks", 9);
            let report = zccl::analysis::verify::verify_sweep(max);
            println!("{}", report.to_json());
            if !report.ok() {
                std::process::exit(1);
            }
        }
        "" | "help" | "--help" | "-h" => {
            println!("{}", HELP);
        }
        other => {
            eprintln!("unknown subcommand '{other}'\n{}", HELP);
            std::process::exit(2);
        }
    }
    // Quiet unused-import warnings for transport types used only in docs.
    let _ = std::mem::size_of::<TcpTransport>();
    let _ = Duration::ZERO;
    Ok(())
}

const HELP: &str = "\
zccl — compression-accelerated collectives (ZCCL reproduction)

USAGE:
  zccl info
  zccl bench <id|all> [--out DIR] [--budget S]
  zccl run [--ranks N] [--values V] [--field rtm|nyx|cesm|hurricane] [mode flags]
  zccl launch --ranks N [--values V] [--port P] [mode flags]
  zccl worker --rank R --peers a:p,b:p,... [--values V] [mode flags]
  zccl train [--workers W] [--steps S] [--artifacts DIR] [--lr X]
             [--grad-artifact grad_step|grad_step_zccl] [mode flags]
  zccl verify [--max-ranks N]           statically verify all collective
                                        schedules (deadlock/tag/match safety)

MODE FLAGS:
  --algo plain|cprp2p|ccoll|zccl|hier (default zccl)
  --compressor fzlight|szx|zfp-abs|zfp-fxr
  --rel-eb X | --abs-eb X             (default rel 1e-4)
  --multithread
  --staged                            staged fZ-light frames (per-chunk
                                      plain/fixed/entropy selection)
  --pipe-chunk N                      (default 5120 values)
  --pipeline-bytes N                  (default 65536)
";
