//! Small deterministic PRNG (splitmix64) — no external dependency, stable
//! across platforms so every experiment is exactly reproducible from its
//! seed.

/// Splitmix64 generator with a Box–Muller normal cache.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seeded constructor.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15), spare_normal: None }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.spare_normal.take() {
            return s;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }
}
