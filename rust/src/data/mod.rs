//! Synthetic scientific-dataset substrate.
//!
//! The paper evaluates on four real application datasets (RTM seismic
//! wavefields, NYX cosmology, CESM-ATM climate, Hurricane ISABEL weather —
//! Table 5) that are multi-GB and not available here. Per DESIGN.md §2 we
//! substitute seeded synthetic fields whose *local smoothness spectra*
//! (the property compression ratio and constant-block fraction depend on)
//! are tuned per application so the cross-dataset ordering of Table 3
//! is preserved.

pub mod fields;
pub mod rng;

pub use fields::{Field, FieldKind};
