//! Seeded synthetic scientific fields mimicking the paper's four
//! application datasets (Table 5).
//!
//! Each generator synthesises a random-Fourier field
//! `x[i] = Σ_k a_k · sin(2π f_k t + φ_k) (+ per-kind shaping + noise)`
//! whose frequency spectrum and noise floor are tuned so that the
//! *compressibility ordering* of the paper's Table 3 holds:
//! RTM (very smooth seismic wavefield, ratio ≫) > Hurricane ≳ NYX ≳
//! CESM-ATM at tight bounds. The fields are deterministic in
//! `(kind, n, seed)` and generation is O(n · components).

use super::rng::Rng;

/// Which application dataset a synthetic field imitates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FieldKind {
    /// Reverse-time-migration seismic wavefield (very smooth, 95.3 GB in
    /// the paper; their default evaluation dataset).
    Rtm,
    /// NYX cosmology (multiscale, high dynamic range).
    Nyx,
    /// CESM-ATM climate (2-D banded, moderate roughness).
    Cesm,
    /// Hurricane ISABEL weather (vortical, medium-scale structure).
    Hurricane,
}

impl FieldKind {
    /// All kinds, in the paper's table order.
    pub const ALL: [FieldKind; 4] =
        [FieldKind::Rtm, FieldKind::Nyx, FieldKind::Cesm, FieldKind::Hurricane];

    /// Display name matching the paper's tables.
    pub fn name(self) -> &'static str {
        match self {
            FieldKind::Rtm => "RTM",
            FieldKind::Nyx => "NYX",
            FieldKind::Cesm => "CESM-ATM",
            FieldKind::Hurricane => "Hurricane",
        }
    }

    /// Parse a CLI name.
    pub fn parse(s: &str) -> crate::Result<FieldKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "rtm" => FieldKind::Rtm,
            "nyx" => FieldKind::Nyx,
            "cesm" | "cesm-atm" => FieldKind::Cesm,
            "hurricane" => FieldKind::Hurricane,
            other => return Err(crate::Error::invalid(format!("unknown field kind '{other}'"))),
        })
    }

    /// Spectral parameters: (components, min cycles, max cycles, spectral
    /// slope, relative white-noise amplitude, lognormal shaping).
    fn params(self) -> (usize, f64, f64, f64, f64, bool) {
        match self {
            // Long-wavelength wave packets, no noise floor; most of the
            // domain is exactly zero (the wavefront has not reached it) —
            // the defining property that makes real RTM snapshots compress
            // an order of magnitude better than the other datasets.
            FieldKind::Rtm => (16, 0.5, 18.0, 1.3, 0.0, false),
            // Many octaves, steep slope, lognormal transform for the
            // density-like dynamic range.
            FieldKind::Nyx => (48, 1.0, 3000.0, 0.9, 6.0e-4, true),
            // Banded, moderate mid-frequency content + noise.
            FieldKind::Cesm => (40, 1.0, 1500.0, 1.0, 1.0e-3, false),
            // Vortical medium scales.
            FieldKind::Hurricane => (36, 1.0, 800.0, 1.05, 5.0e-4, false),
        }
    }
}

/// A generated field: flat values plus the logical 2-D shape when the
/// field was synthesised as an image (used by the visualization figures).
#[derive(Debug, Clone)]
pub struct Field {
    /// Which dataset this imitates.
    pub kind: FieldKind,
    /// Flattened values.
    pub values: Vec<f32>,
    /// `(rows, cols)` when generated as 2-D, else `(1, n)`.
    pub dims: (usize, usize),
}

impl Field {
    /// Generate a 1-D field of `n` values.
    pub fn generate(kind: FieldKind, n: usize, seed: u64) -> Field {
        let values = synth_1d(kind, n, seed);
        Field { kind, values, dims: (1, n) }
    }

    /// Generate a 2-D field (row-major), used for the image figures
    /// (Fig. 8 / Fig. 16) and the image-stacking application.
    pub fn generate_2d(kind: FieldKind, rows: usize, cols: usize, seed: u64) -> Field {
        let values = synth_2d(kind, rows, cols, seed);
        Field { kind, values, dims: (rows, cols) }
    }

    /// Value range `max - min`.
    pub fn range(&self) -> f64 {
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &v in &self.values {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if self.values.is_empty() {
            0.0
        } else {
            (hi - lo) as f64
        }
    }
}

fn synth_1d(kind: FieldKind, n: usize, seed: u64) -> Vec<f32> {
    let (comps, fmin, fmax, slope, noise, lognorm) = kind.params();
    let mut rng = Rng::new(seed ^ (kind as u64).wrapping_mul(0x9E37_79B9));
    // Log-uniform frequencies with 1/f^slope amplitudes.
    let mut waves = Vec::with_capacity(comps);
    let lf = (fmax / fmin).ln();
    for _ in 0..comps {
        let f = fmin * (rng.uniform() * lf).exp();
        let amp = f.powf(-slope) * (0.5 + rng.uniform());
        let phase = rng.range(0.0, std::f64::consts::TAU);
        waves.push((f * std::f64::consts::TAU, amp, phase));
    }
    let norm: f64 = waves.iter().map(|w| w.1 * w.1).sum::<f64>().sqrt();
    // RTM-like fields: a few Gaussian wave packets; the rest of the domain
    // is exactly zero (untouched by the wavefront).
    let packets: Vec<(f64, f64)> = if kind == FieldKind::Rtm {
        (0..3).map(|_| (rng.uniform(), rng.range(0.02, 0.06))).collect()
    } else {
        Vec::new()
    };
    let mut out = Vec::with_capacity(n);
    let inv_n = 1.0 / n.max(1) as f64;
    for i in 0..n {
        let t = i as f64 * inv_n;
        let mut v = 0.0;
        for &(w, a, p) in &waves {
            v += a * (w * t + p).sin();
        }
        v /= norm;
        if !packets.is_empty() {
            let mut env = 0.0;
            for &(c, s) in &packets {
                let d = (t - c) / s;
                env += (-0.5 * d * d).exp();
            }
            // Truncate the far tails to exact zero.
            v *= if env > 1e-3 { env.min(1.0) } else { 0.0 };
        }
        if lognorm {
            v = (1.5 * v).exp() - 1.0;
        }
        v += noise * rng.normal();
        out.push(v as f32);
    }
    out
}

fn synth_2d(kind: FieldKind, rows: usize, cols: usize, seed: u64) -> Vec<f32> {
    let (comps, fmin, fmax, slope, noise, lognorm) = kind.params();
    let mut rng = Rng::new(seed ^ (kind as u64).wrapping_mul(0x517C_C1B7));
    let lf = (fmax.min(cols as f64) / fmin).ln();
    // Directional plane waves + a few Gaussian vortices for Hurricane/CESM
    // banding realism.
    struct Wave {
        kx: f64,
        ky: f64,
        amp: f64,
        phase: f64,
    }
    let mut waves = Vec::with_capacity(comps);
    for _ in 0..comps {
        let f = fmin * (rng.uniform() * lf).exp();
        let theta = if kind == FieldKind::Cesm {
            // Mostly zonal (east–west bands).
            rng.normal() * 0.25
        } else {
            rng.range(0.0, std::f64::consts::TAU)
        };
        let amp = f.powf(-slope) * (0.5 + rng.uniform());
        waves.push(Wave {
            kx: f * theta.cos() * std::f64::consts::TAU,
            ky: f * theta.sin() * std::f64::consts::TAU,
            amp,
            phase: rng.range(0.0, std::f64::consts::TAU),
        });
    }
    let norm: f64 = waves.iter().map(|w| w.amp * w.amp).sum::<f64>().sqrt();
    let nvort = if kind == FieldKind::Hurricane { 3 } else { 0 };
    let vorts: Vec<(f64, f64, f64, f64)> = (0..nvort)
        .map(|_| (rng.uniform(), rng.uniform(), rng.range(0.02, 0.12), rng.range(0.5, 1.5)))
        .collect();
    // RTM: circular wavefront packets, zero elsewhere.
    let packets: Vec<(f64, f64, f64)> = if kind == FieldKind::Rtm {
        (0..3)
            .map(|_| (rng.uniform(), rng.uniform(), rng.range(0.03, 0.09)))
            .collect()
    } else {
        Vec::new()
    };
    let mut out = Vec::with_capacity(rows * cols);
    for r in 0..rows {
        let y = r as f64 / rows.max(1) as f64;
        for c in 0..cols {
            let x = c as f64 / cols.max(1) as f64;
            let mut v = 0.0;
            for w in &waves {
                v += w.amp * (w.kx * x + w.ky * y + w.phase).sin();
            }
            v /= norm;
            for &(cx, cy, s, a) in &vorts {
                let d2 = (x - cx) * (x - cx) + (y - cy) * (y - cy);
                v += a * (-d2 / (2.0 * s * s)).exp();
            }
            if !packets.is_empty() {
                let mut env = 0.0;
                for &(cx, cy, s) in &packets {
                    let d2 = (x - cx) * (x - cx) + (y - cy) * (y - cy);
                    env += (-0.5 * d2 / (s * s)).exp();
                }
                v *= if env > 1e-3 { env.min(1.0) } else { 0.0 };
            }
            if lognorm {
                v = (1.5 * v).exp() - 1.0;
            }
            v += noise * rng.normal();
            out.push(v as f32);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, ErrorBound, FzLight};

    #[test]
    fn deterministic() {
        let a = Field::generate(FieldKind::Rtm, 4096, 9);
        let b = Field::generate(FieldKind::Rtm, 4096, 9);
        assert_eq!(a.values, b.values);
        let c = Field::generate(FieldKind::Rtm, 4096, 10);
        assert_ne!(a.values, c.values);
    }

    #[test]
    fn kinds_differ() {
        let a = Field::generate(FieldKind::Rtm, 1024, 9);
        let b = Field::generate(FieldKind::Nyx, 1024, 9);
        assert_ne!(a.values, b.values);
    }

    #[test]
    fn rtm_is_most_compressible() {
        // The core Table-3 character: RTM compresses far better than the
        // rougher fields at a tight bound.
        let fz = FzLight::default();
        let mut ratios = std::collections::HashMap::new();
        for kind in FieldKind::ALL {
            let f = Field::generate(kind, 1 << 17, 4);
            let c = fz.compress(&f.values, ErrorBound::Rel(1e-4)).unwrap();
            ratios.insert(kind, c.stats.ratio());
        }
        let rtm = ratios[&FieldKind::Rtm];
        for kind in [FieldKind::Nyx, FieldKind::Cesm, FieldKind::Hurricane] {
            assert!(
                rtm > 2.0 * ratios[&kind],
                "RTM ratio {rtm:.1} should dominate {:?} {:.1}",
                kind,
                ratios[&kind]
            );
        }
    }

    #[test]
    fn two_d_shape() {
        let f = Field::generate_2d(FieldKind::Cesm, 64, 128, 3);
        assert_eq!(f.values.len(), 64 * 128);
        assert_eq!(f.dims, (64, 128));
        assert!(f.range() > 0.0);
    }
}
