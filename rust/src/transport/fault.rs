//! Deterministic fault injection: the chaos rig behind the failure-mode
//! test suite and `zccl bench chaos`.
//!
//! [`FaultTransport`] wraps any [`Transport`] and perturbs its *outbound*
//! frames according to a seeded [`FaultPlan`]: drop, corrupt one bit,
//! duplicate, delay, or kill the whole endpoint after its N-th send.
//! Faults are applied to frames **after sealing** (via the transport's
//! [`Transport::seal_frame`] / [`Transport::send_frame`] split), so an
//! injected corruption hits exactly the bytes the receive-side CRC must
//! catch, a dropped frame consumes a real sequence number (surfacing
//! later as a gap or a timeout), and a duplicated frame replays a
//! genuine, verifiable wire frame.
//!
//! Every decision comes from a splitmix64 stream seeded by the plan, so
//! a failing chaos run reproduces exactly from its seed. [`FaultStats`]
//! counts what actually fired.

use std::thread;
use std::time::Duration;

use super::{PacketPool, RecvHandle, Transport, WireStats};
use crate::data::rng::Rng;
use crate::{Error, Result};

/// What a firing rule does to an outbound frame.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Seal the frame (consuming its sequence number), then swallow it.
    /// The receiver sees silence — a timeout — or, if a later frame
    /// follows on the same (peer, tag) stream, a detectable sequence gap.
    Drop,
    /// Flip one seeded-random bit of the sealed frame.
    Corrupt,
    /// Put the identical sealed frame on the wire twice.
    Duplicate,
    /// Sleep before sending (a straggler link).
    Delay(Duration),
}

/// One fault rule: a kind and firing probability, optionally scoped to a
/// destination peer and/or a tag class (half-open range).
#[derive(Debug, Clone)]
pub struct FaultRule {
    /// The fault to inject when the rule fires.
    pub kind: FaultKind,
    /// Firing probability per matching send, in `[0, 1]`.
    pub prob: f64,
    /// Destination filter (`None` = every peer).
    pub peer: Option<usize>,
    /// Tag-class filter (`None` = every tag).
    pub tags: Option<std::ops::Range<u64>>,
}

impl FaultRule {
    /// Unscoped rule firing with probability `prob`.
    pub fn new(kind: FaultKind, prob: f64) -> Self {
        FaultRule { kind, prob, peer: None, tags: None }
    }
    /// Scope the rule to sends toward `peer`.
    pub fn on_peer(mut self, peer: usize) -> Self {
        self.peer = Some(peer);
        self
    }
    /// Scope the rule to tags in `tags`.
    pub fn on_tags(mut self, tags: std::ops::Range<u64>) -> Self {
        self.tags = Some(tags);
        self
    }
    fn matches(&self, to: usize, tag: u64) -> bool {
        self.peer.is_none_or(|p| p == to) && self.tags.as_ref().is_none_or(|r| r.contains(&tag))
    }
}

/// Seeded, deterministic chaos schedule for one endpoint. Rules are
/// evaluated in insertion order; the first that matches and fires wins.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    seed: u64,
    rules: Vec<FaultRule>,
    kill_after: Option<u64>,
}

impl FaultPlan {
    /// Empty plan (no faults) drawing decisions from `seed`.
    pub fn new(seed: u64) -> Self {
        FaultPlan { seed, rules: Vec::new(), kill_after: None }
    }
    /// Append a rule.
    pub fn rule(mut self, rule: FaultRule) -> Self {
        self.rules.push(rule);
        self
    }
    /// Shorthand: drop every matching frame with probability `prob`.
    pub fn drop_frames(self, prob: f64) -> Self {
        self.rule(FaultRule::new(FaultKind::Drop, prob))
    }
    /// Shorthand: corrupt one bit with probability `prob`.
    pub fn corrupt_frames(self, prob: f64) -> Self {
        self.rule(FaultRule::new(FaultKind::Corrupt, prob))
    }
    /// Shorthand: duplicate with probability `prob`.
    pub fn duplicate_frames(self, prob: f64) -> Self {
        self.rule(FaultRule::new(FaultKind::Duplicate, prob))
    }
    /// Shorthand: delay by `by` with probability `prob`.
    pub fn delay_frames(self, prob: f64, by: Duration) -> Self {
        self.rule(FaultRule::new(FaultKind::Delay(by), prob))
    }
    /// Kill the endpoint after its `n`-th outbound message: every later
    /// send *and receive* fails — the rank is dead to the fabric.
    pub fn kill_after(mut self, n: u64) -> Self {
        self.kill_after = Some(n);
        self
    }
}

/// Counters for what the plan actually did.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Send attempts observed (including faulted ones).
    pub sent: u64,
    /// Frames swallowed by [`FaultKind::Drop`].
    pub dropped: u64,
    /// Frames bit-flipped by [`FaultKind::Corrupt`].
    pub corrupted: u64,
    /// Frames sent twice by [`FaultKind::Duplicate`].
    pub duplicated: u64,
    /// Sends stalled by [`FaultKind::Delay`].
    pub delayed: u64,
    /// Whether the kill-after-N trigger has fired.
    pub killed: bool,
}

/// A [`Transport`] wrapper that injects the faults of a [`FaultPlan`].
/// See the module docs.
pub struct FaultTransport<T: Transport> {
    inner: T,
    plan: FaultPlan,
    rng: Rng,
    stats: FaultStats,
}

impl<T: Transport> FaultTransport<T> {
    /// Wrap `inner` under `plan`.
    pub fn new(inner: T, plan: FaultPlan) -> Self {
        let rng = Rng::new(plan.seed);
        FaultTransport { inner, plan, rng, stats: FaultStats::default() }
    }

    /// What the plan has done so far.
    pub fn stats(&self) -> FaultStats {
        self.stats
    }

    /// The wrapped transport (e.g. to read its [`Transport::wire_stats`]
    /// after the run).
    pub fn inner(&self) -> &T {
        &self.inner
    }

    /// Unwrap.
    pub fn into_inner(self) -> T {
        self.inner
    }

    fn alive(&self) -> Result<()> {
        if self.stats.killed {
            return Err(Error::transport(format!(
                "rank {} killed by fault plan",
                self.inner.rank()
            )));
        }
        Ok(())
    }

    /// Per-send bookkeeping: fail if dead, count, maybe trip the kill.
    fn pre_send(&mut self) -> Result<()> {
        self.alive()?;
        self.stats.sent += 1;
        if let Some(n) = self.plan.kill_after {
            if self.stats.sent > n {
                self.stats.killed = true;
                return self.alive();
            }
        }
        Ok(())
    }

    /// First matching rule that fires for this send, if any.
    fn decide(&mut self, to: usize, tag: u64) -> Option<FaultKind> {
        for i in 0..self.plan.rules.len() {
            let rule = self.plan.rules[i].clone();
            if rule.matches(to, tag) && self.rng.uniform() < rule.prob {
                return Some(rule.kind);
            }
        }
        None
    }

    fn apply(&mut self, kind: FaultKind, to: usize, tag: u64, payload: Vec<u8>) -> Result<()> {
        match kind {
            FaultKind::Drop => {
                let frame = self.inner.seal_frame(to, tag, payload);
                self.stats.dropped += 1;
                self.inner.recycle(frame);
                Ok(())
            }
            FaultKind::Corrupt => {
                let mut frame = self.inner.seal_frame(to, tag, payload);
                let pos = self.rng.below(frame.len());
                frame[pos] ^= 1 << self.rng.below(8);
                self.stats.corrupted += 1;
                self.inner.send_frame(to, tag, frame)
            }
            FaultKind::Duplicate => {
                let frame = self.inner.seal_frame(to, tag, payload);
                self.stats.duplicated += 1;
                self.inner.send_frame(to, tag, frame.clone())?;
                self.inner.send_frame(to, tag, frame)
            }
            FaultKind::Delay(by) => {
                self.stats.delayed += 1;
                thread::sleep(by);
                self.inner.send_pooled(to, tag, payload)
            }
        }
    }
}

impl<T: Transport> Transport for FaultTransport<T> {
    fn rank(&self) -> usize {
        self.inner.rank()
    }
    fn size(&self) -> usize {
        self.inner.size()
    }
    fn set_timeout(&mut self, timeout: Option<Duration>) {
        self.inner.set_timeout(timeout);
    }
    fn timeout(&self) -> Option<Duration> {
        self.inner.timeout()
    }
    fn packet_pool(&self) -> Option<&PacketPool> {
        self.inner.packet_pool()
    }
    fn wire_stats(&self) -> WireStats {
        self.inner.wire_stats()
    }

    fn send(&mut self, to: usize, tag: u64, data: &[u8]) -> Result<()> {
        self.pre_send()?;
        match self.decide(to, tag) {
            None => self.inner.send(to, tag, data),
            Some(kind) => {
                let mut payload = self.inner.lease();
                payload.extend_from_slice(data);
                self.apply(kind, to, tag, payload)
            }
        }
    }

    fn send_pooled(&mut self, to: usize, tag: u64, data: Vec<u8>) -> Result<()> {
        self.pre_send()?;
        match self.decide(to, tag) {
            None => self.inner.send_pooled(to, tag, data),
            Some(kind) => self.apply(kind, to, tag, data),
        }
    }

    // seal/send_frame pass through un-faulted so nested fault layers (or
    // direct frame-level tests) compose predictably.
    fn seal_frame(&mut self, to: usize, tag: u64, payload: Vec<u8>) -> Vec<u8> {
        self.inner.seal_frame(to, tag, payload)
    }
    fn send_frame(&mut self, to: usize, tag: u64, frame: Vec<u8>) -> Result<()> {
        self.alive()?;
        self.inner.send_frame(to, tag, frame)
    }

    fn recv_into(&mut self, from: usize, tag: u64, buf: &mut Vec<u8>) -> Result<usize> {
        self.alive()?;
        self.inner.recv_into(from, tag, buf)
    }
    fn irecv(&mut self, from: usize, tag: u64) -> RecvHandle {
        self.inner.irecv(from, tag)
    }
    fn try_complete(&mut self, h: &mut RecvHandle) -> Result<bool> {
        self.alive()?;
        self.inner.try_complete(h)
    }
    fn progress(&mut self) -> Result<()> {
        self.alive()?;
        self.inner.progress()
    }
    fn check_abort(&mut self) -> Result<()> {
        self.alive()?;
        self.inner.check_abort()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::memchan::{MemFabric, MemTransport};

    fn pair(plan: FaultPlan) -> (FaultTransport<MemTransport>, MemTransport) {
        let mut eps = MemFabric::endpoints(2).into_iter();
        let t0 = eps.next().unwrap();
        let t1 = eps.next().unwrap();
        (FaultTransport::new(t0, plan), t1)
    }

    #[test]
    fn corrupt_rule_is_caught_by_receiver_crc() {
        let (mut f, mut t1) = pair(FaultPlan::new(7).corrupt_frames(1.0));
        f.send(1, 3, b"data").unwrap();
        let e = t1.recv(0, 3).unwrap_err();
        assert!(matches!(e, Error::Corrupt(_)), "got {e:?}");
        assert!(format!("{e}").contains("rank 0"));
        assert_eq!(f.stats().corrupted, 1);
        assert_eq!(t1.wire_stats().corrupt_frames, 1);
    }

    #[test]
    fn duplicate_rule_delivers_exactly_once() {
        let (mut f, mut t1) = pair(FaultPlan::new(11).duplicate_frames(1.0));
        f.send(1, 4, b"twin").unwrap();
        assert_eq!(f.stats().duplicated, 1);
        assert_eq!(t1.recv(0, 4).unwrap(), b"twin");
        // The replay is silently dropped; a fresh message on the same tag
        // is the next thing delivered. (Receiving it pulls the first
        // message's replay off the wire and deduplicates it.)
        f.send(1, 4, b"next").unwrap();
        assert_eq!(t1.recv(0, 4).unwrap(), b"next");
        assert_eq!(t1.wire_stats().dup_frames_dropped, 1);
    }

    #[test]
    fn drop_rule_swallows_matching_tags_only() {
        let plan = FaultPlan::new(3).rule(FaultRule::new(FaultKind::Drop, 1.0).on_tags(5..6));
        let (mut f, mut t1) = pair(plan);
        f.send(1, 5, b"gone").unwrap();
        f.send(1, 6, b"kept").unwrap();
        assert_eq!(f.stats().dropped, 1);
        assert_eq!(t1.recv(0, 6).unwrap(), b"kept");
        let mut h = t1.irecv(0, 5);
        assert!(!t1.try_complete(&mut h).unwrap(), "the dropped frame never arrives");
    }

    #[test]
    fn delay_rule_still_delivers() {
        let plan = FaultPlan::new(5).delay_frames(1.0, Duration::from_millis(2));
        let (mut f, mut t1) = pair(plan);
        f.send(1, 8, b"late").unwrap();
        assert_eq!(f.stats().delayed, 1);
        assert_eq!(t1.recv(0, 8).unwrap(), b"late");
    }

    #[test]
    fn kill_after_stops_the_endpoint() {
        let (mut f, mut t1) = pair(FaultPlan::new(1).kill_after(2));
        f.send(1, 1, b"a").unwrap();
        f.send(1, 1, b"b").unwrap();
        let e = f.send(1, 1, b"c").unwrap_err();
        assert!(format!("{e}").contains("killed by fault plan"));
        assert!(f.stats().killed);
        // Receives are dead too.
        assert!(f.recv_into(1, 9, &mut Vec::new()).is_err());
        // What shipped before death still delivers.
        assert_eq!(t1.recv(0, 1).unwrap(), b"a");
        assert_eq!(t1.recv(0, 1).unwrap(), b"b");
    }

    #[test]
    fn plans_are_deterministic_per_seed() {
        let run = |seed: u64| {
            let plan = FaultPlan::new(seed)
                .drop_frames(0.3)
                .corrupt_frames(0.3)
                .duplicate_frames(0.3);
            let (mut f, _t1) = pair(plan);
            for i in 0..100u64 {
                let _ = f.send(1, i % 4, &[i as u8; 16]);
            }
            f.stats()
        };
        assert_eq!(run(42), run(42), "same seed, same schedule");
        assert_ne!(run(42), run(43), "different seed, different schedule");
    }
}
