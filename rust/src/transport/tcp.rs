//! TCP mesh transport for genuine multi-process runs (`zccl launch` /
//! `zccl worker`).
//!
//! Wire format per message: `src: u32 | tag: u64 | len: u64 | payload`.
//! Each endpoint accepts connections from lower ranks and dials higher
//! ranks, yielding a full mesh; one reader thread per peer pushes packets
//! into a shared matched/unmatched store guarded by a mutex + condvar.
//!
//! Reader threads deposit payloads into reusable packet buffers leased
//! from the endpoint's [`PacketPool`]; the consumer's `recv_into` swap
//! returns a same-sized capacity to the pool, so a warm iterated workload
//! receives without allocator traffic (see the parent module docs).

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::Duration;

use super::{PacketPool, RecvHandle, Transport};
use crate::{Error, Result};

type Store = Mutex<HashMap<(usize, u64), VecDeque<Vec<u8>>>>;

/// One rank's endpoint of a TCP mesh.
pub struct TcpTransport {
    rank: usize,
    size: usize,
    writers: Vec<Option<Mutex<TcpStream>>>,
    store: Arc<(Store, Condvar)>,
    readers: Vec<thread::JoinHandle<()>>,
    pool: PacketPool,
}

impl TcpTransport {
    /// Establish the mesh. `addrs[i]` is the listen address of rank `i`;
    /// every process calls this with its own `rank`.
    pub fn connect(rank: usize, addrs: &[SocketAddr], timeout: Duration) -> Result<Self> {
        let size = addrs.len();
        if rank >= size {
            return Err(Error::invalid(format!("rank {rank} out of {size}")));
        }
        let listener = TcpListener::bind(addrs[rank])
            .map_err(|e| Error::transport(format!("bind {}: {e}", addrs[rank])))?;

        let store: Arc<(Store, Condvar)> =
            Arc::new((Mutex::new(HashMap::new()), Condvar::new()));
        let pool = PacketPool::default();
        let mut writers: Vec<Option<Mutex<TcpStream>>> = (0..size).map(|_| None).collect();
        let mut readers = Vec::new();

        // Dial higher ranks (with retry while peers come up).
        for peer in rank + 1..size {
            let deadline = std::time::Instant::now() + timeout;
            let stream = loop {
                match TcpStream::connect(addrs[peer]) {
                    Ok(s) => break s,
                    Err(e) => {
                        if std::time::Instant::now() > deadline {
                            return Err(Error::transport(format!(
                                "connect rank {peer} at {}: {e}",
                                addrs[peer]
                            )));
                        }
                        thread::sleep(Duration::from_millis(20));
                    }
                }
            };
            stream.set_nodelay(true).ok();
            let mut s = stream.try_clone().map_err(Error::Io)?;
            // Identify ourselves.
            s.write_all(&(rank as u32).to_le_bytes())?;
            readers.push(spawn_reader(
                stream.try_clone().map_err(Error::Io)?,
                store.clone(),
                pool.clone(),
            ));
            writers[peer] = Some(Mutex::new(stream));
        }

        // Accept from lower ranks.
        let mut pending = rank;
        listener
            .set_nonblocking(false)
            .map_err(Error::Io)?;
        while pending > 0 {
            let (stream, _) = listener.accept().map_err(Error::Io)?;
            stream.set_nodelay(true).ok();
            let mut id = [0u8; 4];
            let mut s = stream.try_clone().map_err(Error::Io)?;
            s.read_exact(&mut id)?;
            let peer = u32::from_le_bytes(id) as usize;
            if peer >= size || writers[peer].is_some() {
                return Err(Error::transport(format!("bad peer hello {peer}")));
            }
            readers.push(spawn_reader(
                stream.try_clone().map_err(Error::Io)?,
                store.clone(),
                pool.clone(),
            ));
            writers[peer] = Some(Mutex::new(stream));
            pending -= 1;
        }

        Ok(TcpTransport { rank, size, writers, store, readers, pool })
    }

    fn take(&self, from: usize, tag: u64) -> Option<Vec<u8>> {
        let mut map = self.store.0.lock().unwrap();
        let q = map.get_mut(&(from, tag))?;
        let m = q.pop_front();
        if q.is_empty() {
            map.remove(&(from, tag));
        }
        m
    }
}

fn spawn_reader(
    mut stream: TcpStream,
    store: Arc<(Store, Condvar)>,
    pool: PacketPool,
) -> thread::JoinHandle<()> {
    thread::spawn(move || {
        loop {
            // Every frame carries src, so no per-stream hello is needed
            // here (the acceptor consumed the dialer's hello already).
            let mut head = [0u8; 4 + 8 + 8];
            if stream.read_exact(&mut head).is_err() {
                break;
            }
            let src = u32::from_le_bytes(head[0..4].try_into().unwrap()) as usize;
            let tag = u64::from_le_bytes(head[4..12].try_into().unwrap());
            let len = u64::from_le_bytes(head[12..20].try_into().unwrap()) as usize;
            // Deposit into a reused packet buffer (sized exactly, so
            // circulating capacities track the message sizes). `Take` +
            // `read_to_end` appends into the reserved capacity without
            // pre-zeroing it — no memset pass per received message.
            let mut payload =
                if len == 0 { Vec::new() } else { pool.lease_with_capacity(len) };
            match stream.by_ref().take(len as u64).read_to_end(&mut payload) {
                Ok(got) if got == len => {}
                _ => break,
            }
            let (lock, cv) = &*store;
            lock.lock().unwrap().entry((src, tag)).or_default().push_back(payload);
            cv.notify_all();
        }
    })
}

impl Transport for TcpTransport {
    fn rank(&self) -> usize {
        self.rank
    }
    fn size(&self) -> usize {
        self.size
    }

    fn packet_pool(&self) -> Option<&PacketPool> {
        Some(&self.pool)
    }

    fn send(&mut self, to: usize, tag: u64, data: &[u8]) -> Result<()> {
        if to == self.rank {
            // Self-send loops back through the store (pooled like any
            // arriving packet).
            let packet = self.pool.packet_from(data);
            let (lock, cv) = &*self.store;
            lock.lock().unwrap().entry((to, tag)).or_default().push_back(packet);
            cv.notify_all();
            return Ok(());
        }
        let w = self.writers[to]
            .as_ref()
            .ok_or_else(|| Error::transport(format!("no link to rank {to}")))?;
        let mut s = w.lock().unwrap();
        let mut head = [0u8; 4 + 8 + 8];
        head[0..4].copy_from_slice(&(self.rank as u32).to_le_bytes());
        head[4..12].copy_from_slice(&tag.to_le_bytes());
        head[12..20].copy_from_slice(&(data.len() as u64).to_le_bytes());
        s.write_all(&head)?;
        s.write_all(data)?;
        Ok(())
    }

    fn send_pooled(&mut self, to: usize, tag: u64, data: Vec<u8>) -> Result<()> {
        self.pool.note_pooled_send();
        if to == self.rank {
            // Self-send: the caller's buffer becomes the stored packet
            // directly — no packet_from copy.
            let (lock, cv) = &*self.store;
            lock.lock().unwrap().entry((to, tag)).or_default().push_back(data);
            cv.notify_all();
            return Ok(());
        }
        // The socket write streams straight from the caller's buffer (no
        // intermediate packet); the buffer's capacity goes back to the
        // pool for the reader threads to reuse.
        let r = self.send(to, tag, &data);
        self.pool.release(data);
        r
    }

    fn recv_into(&mut self, from: usize, tag: u64, buf: &mut Vec<u8>) -> Result<usize> {
        let (lock, cv) = &*self.store;
        let mut map = lock.lock().unwrap();
        loop {
            if let Some(q) = map.get_mut(&(from, tag)) {
                if let Some(m) = q.pop_front() {
                    if q.is_empty() {
                        map.remove(&(from, tag));
                    }
                    drop(map);
                    return Ok(self.pool.deposit(m, buf));
                }
            }
            let (m, timeout) = cv
                .wait_timeout(map, Duration::from_secs(60))
                .map_err(|_| Error::transport("poisoned store"))?;
            map = m;
            if timeout.timed_out() {
                return Err(Error::transport(format!(
                    "recv timeout from {from} tag {tag}"
                )));
            }
        }
    }

    fn try_complete(&mut self, h: &mut RecvHandle) -> Result<bool> {
        if h.done.is_some() || h.delivered {
            return Ok(true);
        }
        if let Some(m) = self.take(h.from, h.tag) {
            h.done = Some(m);
            return Ok(true);
        }
        Ok(false)
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        for w in self.writers.iter().flatten() {
            if let Ok(s) = w.lock() {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
        while let Some(r) = self.readers.pop() {
            let _ = r.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn local_addrs(n: usize) -> Vec<SocketAddr> {
        // Bind ephemeral listeners to reserve distinct ports, then free them.
        let ls: Vec<TcpListener> =
            (0..n).map(|_| TcpListener::bind("127.0.0.1:0").unwrap()).collect();
        ls.iter().map(|l| l.local_addr().unwrap()).collect()
    }

    #[test]
    fn tcp_mesh_pingpong_and_barrier() {
        let n = 3;
        let addrs = local_addrs(n);
        let joins: Vec<_> = (0..n)
            .map(|r| {
                let addrs = addrs.clone();
                thread::spawn(move || {
                    let mut t =
                        TcpTransport::connect(r, &addrs, Duration::from_secs(10)).unwrap();
                    t.barrier(0).unwrap();
                    // Ring token pass.
                    let next = (r + 1) % n;
                    let prev = (r + n - 1) % n;
                    t.send(next, 5, &[r as u8]).unwrap();
                    let m = t.recv(prev, 5).unwrap();
                    assert_eq!(m, vec![prev as u8]);
                    t.barrier(1).unwrap();
                    r
                })
            })
            .collect();
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn tcp_nonblocking_poll() {
        let addrs = local_addrs(2);
        let a = addrs.clone();
        let j0 = thread::spawn(move || {
            let mut t = TcpTransport::connect(0, &a, Duration::from_secs(10)).unwrap();
            thread::sleep(Duration::from_millis(10));
            t.send(1, 42, b"poll-me").unwrap();
            t.barrier(0).unwrap();
        });
        let a = addrs.clone();
        let j1 = thread::spawn(move || {
            let mut t = TcpTransport::connect(1, &a, Duration::from_secs(10)).unwrap();
            let mut h = t.irecv(0, 42);
            while !t.try_complete(&mut h).unwrap() {
                std::thread::yield_now();
            }
            assert_eq!(h.take().unwrap(), b"poll-me");
            t.barrier(0).unwrap();
        });
        j0.join().unwrap();
        j1.join().unwrap();
    }

    #[test]
    fn tcp_wait_with_delayed_sender_completes() {
        // Satellite regression: a sender that shows up 60 ms late — far
        // past the bounded spin budget — must still complete the wait
        // (the waiter has downgraded to yield_now by then, not a hot spin).
        let addrs = local_addrs(2);
        let a = addrs.clone();
        let j0 = thread::spawn(move || {
            let mut t = TcpTransport::connect(0, &a, Duration::from_secs(10)).unwrap();
            thread::sleep(Duration::from_millis(60));
            t.send(1, 77, &[5u8; 2048]).unwrap();
            t.barrier(0).unwrap();
        });
        let a = addrs.clone();
        let j1 = thread::spawn(move || {
            let mut t = TcpTransport::connect(1, &a, Duration::from_secs(10)).unwrap();
            let h = t.irecv(0, 77);
            let mut buf = t.lease();
            assert_eq!(t.wait_into(h, &mut buf).unwrap(), 2048);
            assert!(buf.iter().all(|&b| b == 5));
            t.recycle(buf);
            t.barrier(0).unwrap();
        });
        j0.join().unwrap();
        j1.join().unwrap();
    }

    #[test]
    fn tcp_reader_reuses_pooled_packet_buffers() {
        // The reader thread must lease arrival buffers from the pool:
        // after a warm-up exchange, further same-sized receives allocate
        // no new packet buffers.
        let addrs = local_addrs(2);
        let a = addrs.clone();
        let j0 = thread::spawn(move || {
            let mut t = TcpTransport::connect(0, &a, Duration::from_secs(10)).unwrap();
            for i in 0..6u64 {
                t.send(1, 300 + i, &[1u8; 1024]).unwrap();
                t.recv(1, 400 + i).unwrap(); // ack paces the iterations
            }
            t.barrier(0).unwrap();
        });
        let a = addrs.clone();
        let j1 = thread::spawn(move || {
            let mut t = TcpTransport::connect(1, &a, Duration::from_secs(10)).unwrap();
            let mut buf = t.lease();
            let mut warm = 0;
            for i in 0..6u64 {
                assert_eq!(t.recv_into(0, 300 + i, &mut buf).unwrap(), 1024);
                t.send(0, 400 + i, &[0u8]).unwrap();
                if i == 1 {
                    warm = t.packet_stats().allocated;
                }
            }
            assert_eq!(
                t.packet_stats().allocated,
                warm,
                "warm receives must reuse pooled packet buffers"
            );
            t.recycle(buf);
            t.barrier(0).unwrap();
        });
        j0.join().unwrap();
        j1.join().unwrap();
    }
}
