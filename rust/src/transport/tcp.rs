//! TCP mesh transport for genuine multi-process runs (`zccl launch` /
//! `zccl worker`).
//!
//! Wire format per message: `src: u32 | tag: u64 | len: u64 | frame`,
//! where `frame` is the payload plus the 12-byte integrity trailer
//! (`seq: u64 | crc32c: u32` — see the parent module's failure-semantics
//! docs). The trailer is verified when a frame is *delivered* to the
//! consumer, before its bytes can reach a codec.
//!
//! Each endpoint accepts connections from lower ranks and dials higher
//! ranks (bounded retry with jittered exponential backoff, so a mesh
//! whose listeners come up late or restart together still forms), yielding
//! a full mesh; one reader thread per peer pushes frames into a shared
//! matched/unmatched store guarded by a mutex + condvar. A reader hitting
//! EOF or a truncated frame **poisons its peer**: every pending and future
//! wait on that peer fails immediately instead of riding out a timeout.
//!
//! Reader threads deposit payloads into reusable packet buffers leased
//! from the endpoint's [`PacketPool`]; the consumer's `recv_into` swap
//! returns a same-sized capacity to the pool, so a warm iterated workload
//! receives without allocator traffic (see the parent module docs).

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use super::{PacketPool, RecvHandle, SeqCheck, Transport, WireStats};
use super::{ABORT_TAG, WIRE_TRAILER};
use crate::data::rng::Rng;
use crate::{Error, Result};

type Store = Mutex<HashMap<(usize, u64), VecDeque<Vec<u8>>>>;

/// Hard cap on dial attempts per peer during fabric bring-up.
const CONNECT_ATTEMPTS: u32 = 64;
/// Ceiling for the exponential backoff between dial attempts.
const CONNECT_BACKOFF_CAP_MS: u64 = 100;
/// Default wait deadline: TCP peers live in other processes that can die
/// without a disconnect reaching us in time, so unlike `memchan` the mesh
/// never waits forever unless explicitly disarmed.
const DEFAULT_TIMEOUT: Duration = Duration::from_secs(60);
/// Condvar poll tick, bounding how stale the poison/abort/deadline checks
/// can get when reader threads have nothing to deliver.
const PARK_TICK: Duration = Duration::from_millis(5);

/// One rank's endpoint of a TCP mesh.
pub struct TcpTransport {
    rank: usize,
    size: usize,
    writers: Vec<Option<Mutex<TcpStream>>>,
    store: Arc<(Store, Condvar)>,
    readers: Vec<thread::JoinHandle<()>>,
    pool: PacketPool,
    /// Per-peer poison reason, set by the peer's reader thread on EOF.
    poison: Arc<Mutex<Vec<Option<String>>>>,
    /// Deadline armed on every blocking wait (default 60 s; `None` waits
    /// forever).
    timeout: Option<Duration>,
    /// Next outbound sequence number per (destination, tag).
    tx_seq: HashMap<(usize, u64), u64>,
    /// Next expected inbound sequence number per (source, tag).
    rx_seq: HashMap<(usize, u64), u64>,
    /// Wire-integrity counters (consumer-side, so no lock needed).
    wire: WireStats,
    /// Sticky abort latch: set on the first poison message observed.
    aborted: Option<String>,
}

impl TcpTransport {
    /// Establish the mesh. `addrs[i]` is the listen address of rank `i`;
    /// every process calls this with its own `rank`. Dialing a peer whose
    /// listener is not up yet retries with jittered exponential backoff,
    /// bounded by both a fixed attempt cap and `timeout`.
    pub fn connect(rank: usize, addrs: &[SocketAddr], timeout: Duration) -> Result<Self> {
        let size = addrs.len();
        if rank >= size {
            return Err(Error::invalid(format!("rank {rank} out of {size}")));
        }
        let listener = TcpListener::bind(addrs[rank])
            .map_err(|e| Error::transport(format!("bind {}: {e}", addrs[rank])))?;

        let store: Arc<(Store, Condvar)> =
            Arc::new((Mutex::new(HashMap::new()), Condvar::new()));
        let pool = PacketPool::default();
        let poison: Arc<Mutex<Vec<Option<String>>>> = Arc::new(Mutex::new(vec![None; size]));
        let mut writers: Vec<Option<Mutex<TcpStream>>> = (0..size).map(|_| None).collect();
        let mut readers = Vec::new();

        // Dial higher ranks (bounded retry while peers come up).
        for peer in rank + 1..size {
            let deadline = Instant::now() + timeout;
            // Seeded per (rank, peer) so the sleep schedule is
            // deterministic yet decorrelated across the dialing mesh.
            let mut rng = Rng::new(0x5EED_C0DE ^ ((rank as u64) << 32) ^ peer as u64);
            let mut attempt = 0u32;
            let stream = loop {
                match TcpStream::connect(addrs[peer]) {
                    Ok(s) => break s,
                    Err(e) => {
                        attempt += 1;
                        if attempt >= CONNECT_ATTEMPTS || Instant::now() >= deadline {
                            return Err(Error::transport(format!(
                                "connect rank {peer} at {} failed after {attempt} attempts: {e}",
                                addrs[peer]
                            )));
                        }
                        // Exponential backoff, half fixed + half jitter,
                        // so restarting meshes don't re-dial in lockstep.
                        let cap = CONNECT_BACKOFF_CAP_MS.min(1u64 << attempt.min(20));
                        let jitter = rng.below(cap as usize + 1) as u64;
                        thread::sleep(Duration::from_millis(cap / 2 + jitter / 2 + 1));
                    }
                }
            };
            stream.set_nodelay(true).ok();
            let mut s = stream.try_clone().map_err(Error::Io)?;
            // Identify ourselves.
            s.write_all(&(rank as u32).to_le_bytes())?;
            readers.push(spawn_reader(
                peer,
                stream.try_clone().map_err(Error::Io)?,
                store.clone(),
                pool.clone(),
                poison.clone(),
            ));
            writers[peer] = Some(Mutex::new(stream));
        }

        // Accept from lower ranks.
        let mut pending = rank;
        listener
            .set_nonblocking(false)
            .map_err(Error::Io)?;
        while pending > 0 {
            let (stream, _) = listener.accept().map_err(Error::Io)?;
            stream.set_nodelay(true).ok();
            let mut id = [0u8; 4];
            let mut s = stream.try_clone().map_err(Error::Io)?;
            s.read_exact(&mut id)?;
            let peer = u32::from_le_bytes(id) as usize;
            if peer >= size || writers[peer].is_some() {
                return Err(Error::transport(format!("bad peer hello {peer}")));
            }
            readers.push(spawn_reader(
                peer,
                stream.try_clone().map_err(Error::Io)?,
                store.clone(),
                pool.clone(),
                poison.clone(),
            ));
            writers[peer] = Some(Mutex::new(stream));
            pending -= 1;
        }

        Ok(TcpTransport {
            rank,
            size,
            writers,
            store,
            readers,
            pool,
            poison,
            timeout: Some(DEFAULT_TIMEOUT),
            tx_seq: HashMap::new(),
            rx_seq: HashMap::new(),
            wire: WireStats::default(),
            aborted: None,
        })
    }

    fn next_seq(&mut self, to: usize, tag: u64) -> u64 {
        let seq = self.tx_seq.entry((to, tag)).or_insert(0);
        let this = *seq;
        *seq += 1;
        this
    }

    /// Pop the next raw (unverified) frame buffered for `(from, tag)`.
    fn pop_packet(&self, from: usize, tag: u64) -> Option<Vec<u8>> {
        let mut map = self.store.0.lock().unwrap();
        let q = map.get_mut(&(from, tag))?;
        let m = q.pop_front();
        if q.is_empty() {
            map.remove(&(from, tag));
        }
        m
    }

    /// Verify and strip the integrity trailer of a frame pulled from the
    /// store (see `MemTransport::deliver` — same contract).
    fn deliver(&mut self, src: usize, tag: u64, mut frame: Vec<u8>) -> Result<Option<Vec<u8>>> {
        let seq = match super::unseal(src, tag, &mut frame) {
            Ok(seq) => seq,
            Err(e) => {
                self.wire.corrupt_frames += 1;
                self.pool.release(frame);
                return Err(e);
            }
        };
        match super::check_seq(&mut self.rx_seq, src, tag, seq) {
            SeqCheck::Deliver => Ok(Some(frame)),
            SeqCheck::Duplicate => {
                self.wire.dup_frames_dropped += 1;
                self.pool.release(frame);
                Ok(None)
            }
            SeqCheck::Gap { expected } => {
                self.wire.gaps_detected += 1;
                self.pool.release(frame);
                Err(Error::transport(format!(
                    "lost frame from rank {src} tag {tag}: expected seq {expected}, got {seq}"
                )))
            }
        }
    }

    /// Pop buffered frames for `(from, tag)` until one verifies (dropping
    /// duplicates) or the queue runs dry.
    fn take_verified(&mut self, from: usize, tag: u64) -> Result<Option<Vec<u8>>> {
        while let Some(m) = self.pop_packet(from, tag) {
            if let Some(payload) = self.deliver(from, tag, m)? {
                return Ok(Some(payload));
            }
        }
        Ok(None)
    }

    fn poison_of(&self, peer: usize) -> Option<String> {
        self.poison.lock().unwrap()[peer].clone()
    }
}

fn spawn_reader(
    peer: usize,
    mut stream: TcpStream,
    store: Arc<(Store, Condvar)>,
    pool: PacketPool,
    poison: Arc<Mutex<Vec<Option<String>>>>,
) -> thread::JoinHandle<()> {
    thread::spawn(move || {
        let reason = loop {
            // Every frame carries src, so no per-stream hello is needed
            // here (the acceptor consumed the dialer's hello already).
            let mut head = [0u8; 4 + 8 + 8];
            if let Err(e) = stream.read_exact(&mut head) {
                break format!("reader EOF: {e}");
            }
            let src = u32::from_le_bytes(head[0..4].try_into().unwrap()) as usize;
            let tag = u64::from_le_bytes(head[4..12].try_into().unwrap());
            let len = u64::from_le_bytes(head[12..20].try_into().unwrap()) as usize;
            // Deposit into a reused packet buffer (sized exactly, so
            // circulating capacities track the message sizes). `Take` +
            // `read_to_end` appends into the reserved capacity without
            // pre-zeroing it — no memset pass per received message.
            let mut payload =
                if len == 0 { Vec::new() } else { pool.lease_with_capacity(len) };
            match stream.by_ref().take(len as u64).read_to_end(&mut payload) {
                Ok(got) if got == len => {}
                _ => break String::from("truncated frame at socket close"),
            }
            let (lock, cv) = &*store;
            lock.lock().unwrap().entry((src, tag)).or_default().push_back(payload);
            cv.notify_all();
        };
        // Poison the peer: already-buffered frames stay deliverable (the
        // consumer checks the store before the poison flag), but pending
        // and future waits that would otherwise hang now fail fast.
        poison.lock().unwrap()[peer] = Some(reason);
        store.1.notify_all();
    })
}

impl Transport for TcpTransport {
    fn rank(&self) -> usize {
        self.rank
    }
    fn size(&self) -> usize {
        self.size
    }

    fn packet_pool(&self) -> Option<&PacketPool> {
        Some(&self.pool)
    }

    fn set_timeout(&mut self, timeout: Option<Duration>) {
        self.timeout = timeout;
    }

    fn timeout(&self) -> Option<Duration> {
        self.timeout
    }

    fn wire_stats(&self) -> WireStats {
        self.wire
    }

    fn seal_frame(&mut self, to: usize, tag: u64, mut payload: Vec<u8>) -> Vec<u8> {
        let seq = self.next_seq(to, tag);
        super::seal_into(&mut payload, self.rank, tag, seq);
        payload
    }

    fn send_frame(&mut self, to: usize, tag: u64, frame: Vec<u8>) -> Result<()> {
        if to == self.rank {
            // Self-send loops back through the store like any arriving
            // frame (verified at delivery, pooled at the swap).
            let (lock, cv) = &*self.store;
            lock.lock().unwrap().entry((to, tag)).or_default().push_back(frame);
            cv.notify_all();
            return Ok(());
        }
        let w = self.writers[to]
            .as_ref()
            .ok_or_else(|| Error::transport(format!("no link to rank {to}")))?;
        {
            let mut s = w.lock().unwrap();
            let mut head = [0u8; 4 + 8 + 8];
            head[0..4].copy_from_slice(&(self.rank as u32).to_le_bytes());
            head[4..12].copy_from_slice(&tag.to_le_bytes());
            head[12..20].copy_from_slice(&(frame.len() as u64).to_le_bytes());
            s.write_all(&head)?;
            s.write_all(&frame)?;
        }
        self.pool.release(frame);
        Ok(())
    }

    fn send(&mut self, to: usize, tag: u64, data: &[u8]) -> Result<()> {
        if to == self.rank {
            let mut packet = self.pool.lease_with_capacity(data.len() + WIRE_TRAILER);
            packet.extend_from_slice(data);
            let frame = self.seal_frame(to, tag, packet);
            return self.send_frame(to, tag, frame);
        }
        // Stream head + payload + trailer without materialising a sealed
        // frame: the checksum is computed over the same logical parts.
        let seq = self.next_seq(to, tag);
        let crc = super::frame_crc(self.rank, tag, seq, data);
        let w = self.writers[to]
            .as_ref()
            .ok_or_else(|| Error::transport(format!("no link to rank {to}")))?;
        let mut s = w.lock().unwrap();
        let mut head = [0u8; 4 + 8 + 8];
        head[0..4].copy_from_slice(&(self.rank as u32).to_le_bytes());
        head[4..12].copy_from_slice(&tag.to_le_bytes());
        head[12..20].copy_from_slice(&((data.len() + WIRE_TRAILER) as u64).to_le_bytes());
        s.write_all(&head)?;
        s.write_all(data)?;
        s.write_all(&seq.to_le_bytes())?;
        s.write_all(&crc.to_le_bytes())?;
        Ok(())
    }

    fn send_pooled(&mut self, to: usize, tag: u64, data: Vec<u8>) -> Result<()> {
        self.pool.note_pooled_send();
        // The caller's buffer becomes the wire frame directly: sealed in
        // place, streamed (or stored, for self-sends) without a copy.
        let frame = self.seal_frame(to, tag, data);
        self.send_frame(to, tag, frame)
    }

    fn recv_into(&mut self, from: usize, tag: u64, buf: &mut Vec<u8>) -> Result<usize> {
        let deadline = self.timeout.map(|t| Instant::now() + t);
        loop {
            if let Some(payload) = self.take_verified(from, tag)? {
                return Ok(self.pool.deposit(payload, buf));
            }
            self.check_abort()?;
            if let Some(why) = self.poison_of(from) {
                return Err(Error::transport(format!("connection to rank {from} lost: {why}")));
            }
            if deadline.is_some_and(|d| Instant::now() >= d) {
                return Err(Error::timeout(vec![(from, tag)]));
            }
            // Park until a reader deposits something; the tick bounds how
            // long a poison/abort/deadline can go unnoticed if the notify
            // raced our store check.
            let (lock, cv) = &*self.store;
            let map = lock.lock().unwrap();
            let _ = cv
                .wait_timeout(map, PARK_TICK)
                .map_err(|_| Error::transport("poisoned store"))?;
        }
    }

    fn try_complete(&mut self, h: &mut RecvHandle) -> Result<bool> {
        if h.done.is_some() || h.delivered {
            return Ok(true);
        }
        if let Some(m) = &h.failed {
            return Err(Error::transport(m.clone()));
        }
        match self.take_verified(h.from, h.tag) {
            Ok(Some(payload)) => {
                h.done = Some(payload);
                Ok(true)
            }
            Ok(None) => {
                if let Some(why) = self.poison_of(h.from) {
                    return Err(Error::transport(format!(
                        "connection to rank {} lost: {why}",
                        h.from
                    )));
                }
                Ok(false)
            }
            Err(e) => {
                // The matching frame was consumed by verification; latch
                // so later polls replay the failure instead of hanging.
                h.failed =
                    Some(format!("receive from rank {} tag {} failed: {e}", h.from, h.tag));
                Err(e)
            }
        }
    }

    fn check_abort(&mut self) -> Result<()> {
        if let Some(m) = &self.aborted {
            return Err(Error::transport(m.clone()));
        }
        loop {
            let key = {
                let map = self.store.0.lock().unwrap();
                map.keys().find(|(_, t)| t & ABORT_TAG != 0).copied()
            };
            let Some((src, tag)) = key else {
                return Ok(());
            };
            let frame = self.pop_packet(src, tag).expect("only the consumer pops the store");
            let text = match self.deliver(src, tag, frame) {
                Ok(Some(payload)) => {
                    let text = String::from_utf8_lossy(&payload).into_owned();
                    self.pool.release(payload);
                    text
                }
                Ok(None) => continue, // duplicate poison: drop, rescan
                Err(_) => String::from("(unreadable abort payload)"),
            };
            let msg = format!("abort from rank {src}: {text}");
            self.wire.aborts_seen += 1;
            self.aborted = Some(msg.clone());
            return Err(Error::transport(msg));
        }
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        for w in self.writers.iter().flatten() {
            if let Ok(s) = w.lock() {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
        while let Some(r) = self.readers.pop() {
            let _ = r.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn local_addrs(n: usize) -> Vec<SocketAddr> {
        // Bind ephemeral listeners to reserve distinct ports, then free them.
        let ls: Vec<TcpListener> =
            (0..n).map(|_| TcpListener::bind("127.0.0.1:0").unwrap()).collect();
        ls.iter().map(|l| l.local_addr().unwrap()).collect()
    }

    #[test]
    fn tcp_mesh_pingpong_and_barrier() {
        let n = 3;
        let addrs = local_addrs(n);
        let joins: Vec<_> = (0..n)
            .map(|r| {
                let addrs = addrs.clone();
                thread::spawn(move || {
                    let mut t =
                        TcpTransport::connect(r, &addrs, Duration::from_secs(10)).unwrap();
                    t.barrier(0).unwrap();
                    // Ring token pass.
                    let next = (r + 1) % n;
                    let prev = (r + n - 1) % n;
                    t.send(next, 5, &[r as u8]).unwrap();
                    let m = t.recv(prev, 5).unwrap();
                    assert_eq!(m, vec![prev as u8]);
                    t.barrier(1).unwrap();
                    r
                })
            })
            .collect();
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn tcp_nonblocking_poll() {
        let addrs = local_addrs(2);
        let a = addrs.clone();
        let j0 = thread::spawn(move || {
            let mut t = TcpTransport::connect(0, &a, Duration::from_secs(10)).unwrap();
            thread::sleep(Duration::from_millis(10));
            t.send(1, 42, b"poll-me").unwrap();
            t.barrier(0).unwrap();
        });
        let a = addrs.clone();
        let j1 = thread::spawn(move || {
            let mut t = TcpTransport::connect(1, &a, Duration::from_secs(10)).unwrap();
            let mut h = t.irecv(0, 42);
            while !t.try_complete(&mut h).unwrap() {
                std::thread::yield_now();
            }
            assert_eq!(h.take().unwrap(), b"poll-me");
            t.barrier(0).unwrap();
        });
        j0.join().unwrap();
        j1.join().unwrap();
    }

    #[test]
    fn tcp_wait_with_delayed_sender_completes() {
        // Satellite regression: a sender that shows up 60 ms late — far
        // past the bounded spin budget — must still complete the wait
        // (the waiter has downgraded to yield_now by then, not a hot spin).
        let addrs = local_addrs(2);
        let a = addrs.clone();
        let j0 = thread::spawn(move || {
            let mut t = TcpTransport::connect(0, &a, Duration::from_secs(10)).unwrap();
            thread::sleep(Duration::from_millis(60));
            t.send(1, 77, &[5u8; 2048]).unwrap();
            t.barrier(0).unwrap();
        });
        let a = addrs.clone();
        let j1 = thread::spawn(move || {
            let mut t = TcpTransport::connect(1, &a, Duration::from_secs(10)).unwrap();
            let h = t.irecv(0, 77);
            let mut buf = t.lease();
            assert_eq!(t.wait_into(h, &mut buf).unwrap(), 2048);
            assert!(buf.iter().all(|&b| b == 5));
            t.recycle(buf);
            t.barrier(0).unwrap();
        });
        j0.join().unwrap();
        j1.join().unwrap();
    }

    #[test]
    fn tcp_reader_reuses_pooled_packet_buffers() {
        // The reader thread must lease arrival buffers from the pool:
        // after a warm-up exchange, further same-sized receives allocate
        // no new packet buffers.
        let addrs = local_addrs(2);
        let a = addrs.clone();
        let j0 = thread::spawn(move || {
            let mut t = TcpTransport::connect(0, &a, Duration::from_secs(10)).unwrap();
            for i in 0..6u64 {
                t.send(1, 300 + i, &[1u8; 1024]).unwrap();
                t.recv(1, 400 + i).unwrap(); // ack paces the iterations
            }
            t.barrier(0).unwrap();
        });
        let a = addrs.clone();
        let j1 = thread::spawn(move || {
            let mut t = TcpTransport::connect(1, &a, Duration::from_secs(10)).unwrap();
            let mut buf = t.lease();
            let mut warm = 0;
            for i in 0..6u64 {
                assert_eq!(t.recv_into(0, 300 + i, &mut buf).unwrap(), 1024);
                t.send(0, 400 + i, &[0u8]).unwrap();
                if i == 1 {
                    warm = t.packet_stats().allocated;
                }
            }
            assert_eq!(
                t.packet_stats().allocated,
                warm,
                "warm receives must reuse pooled packet buffers"
            );
            t.recycle(buf);
            t.barrier(0).unwrap();
        });
        j0.join().unwrap();
        j1.join().unwrap();
    }

    #[test]
    fn tcp_connect_retries_until_late_listener() {
        // Satellite: rank 0 starts dialing immediately; rank 1's listener
        // does not even bind for another 150 ms. The bounded backoff must
        // ride out the refused connections and still form the mesh.
        let addrs = local_addrs(2);
        let a = addrs.clone();
        let j0 = thread::spawn(move || {
            let mut t = TcpTransport::connect(0, &a, Duration::from_secs(10)).unwrap();
            t.send(1, 9, b"early-bird").unwrap();
            t.barrier(0).unwrap();
        });
        let a = addrs.clone();
        let j1 = thread::spawn(move || {
            thread::sleep(Duration::from_millis(150));
            let mut t = TcpTransport::connect(1, &a, Duration::from_secs(10)).unwrap();
            assert_eq!(t.recv(0, 9).unwrap(), b"early-bird");
            t.barrier(0).unwrap();
        });
        j0.join().unwrap();
        j1.join().unwrap();
    }

    #[test]
    fn tcp_recv_times_out_with_pending_pair() {
        let addrs = local_addrs(2);
        let a = addrs.clone();
        let j0 = thread::spawn(move || {
            let mut t = TcpTransport::connect(0, &a, Duration::from_secs(10)).unwrap();
            // Never send on tag 13; stay alive past the peer's deadline so
            // the timeout (not a disconnect/poison) ends the wait.
            t.barrier(0).unwrap();
        });
        let a = addrs.clone();
        let j1 = thread::spawn(move || {
            let mut t = TcpTransport::connect(1, &a, Duration::from_secs(10)).unwrap();
            t.set_timeout(Some(Duration::from_millis(50)));
            let mut buf = Vec::new();
            match t.recv_into(0, 13, &mut buf) {
                Err(Error::Timeout { pending }) => assert_eq!(pending, vec![(0, 13)]),
                other => panic!("expected timeout, got {other:?}"),
            }
            t.set_timeout(Some(DEFAULT_TIMEOUT));
            t.barrier(0).unwrap();
        });
        j0.join().unwrap();
        j1.join().unwrap();
    }

    #[test]
    fn tcp_peer_death_poisons_pending_waits() {
        // Rank 0 exits without sending; its socket close reaches rank 1's
        // reader as EOF, which must convert the pending wait into a prompt
        // transport error — long before the 60 s default deadline.
        let addrs = local_addrs(2);
        let a = addrs.clone();
        let j0 = thread::spawn(move || {
            let t = TcpTransport::connect(0, &a, Duration::from_secs(10)).unwrap();
            thread::sleep(Duration::from_millis(30));
            drop(t);
        });
        let a = addrs.clone();
        let j1 = thread::spawn(move || {
            let mut t = TcpTransport::connect(1, &a, Duration::from_secs(10)).unwrap();
            let start = Instant::now();
            let mut buf = Vec::new();
            let e = t.recv_into(0, 99, &mut buf).unwrap_err();
            let msg = format!("{e}");
            assert!(msg.contains("connection to rank 0 lost"), "got: {msg}");
            assert!(start.elapsed() < Duration::from_secs(10), "poison must be prompt");
        });
        j0.join().unwrap();
        j1.join().unwrap();
    }
}
