//! Mini-MPI point-to-point substrate.
//!
//! The paper builds on MPI's blocking (`MPI_Send`/`MPI_Recv`) and
//! nonblocking (`MPI_Isend`/`MPI_Irecv` + progress polling) primitives; we
//! implement the equivalent from scratch:
//!
//! - [`memchan`] — in-process ranks (one thread each) over lock-free
//!   channels. Used by tests, examples and all real-execution benchmarks.
//! - [`tcp`] — genuine multi-process transport over a full TCP mesh, for
//!   leader/worker deployments (`zccl launch` / `zccl worker`).
//!
//! Message matching follows MPI semantics: `(source, tag)` pairs, ordered
//! per pair. Collectives allocate disjoint tag spaces per operation so
//! concurrent collectives on the same communicator never cross-match.
//!
//! ## The pooled receive path
//!
//! The receive-side API is designed so a warm iterated collective moves
//! bytes without touching the allocator:
//!
//! 1. **lease** — the consumer borrows a wire buffer from the transport's
//!    [`PacketPool`] ([`Transport::lease`]); producers (the `memchan`
//!    sender, the `tcp` reader threads) lease their packet buffers from
//!    the same pool instead of allocating fresh `Vec`s.
//! 2. **recv_into** — [`Transport::recv_into`] (and its nonblocking
//!    sibling [`Transport::try_complete_into`]) delivers an arrived
//!    packet by *swapping* it into the caller's buffer: the packet's
//!    allocation changes hands, the buffer's old capacity goes back to
//!    the pool for the next arrival. No copy, no allocation.
//! 3. **decode in place** — the collectives then run a placement decode
//!    ([`crate::compress::Compressor::decompress_into_slice`]) straight
//!    from the wire buffer into the output's final window, and
//!    [`Transport::recycle`] the buffer when done.
//!
//! The allocating [`Transport::recv`] / [`Transport::wait`] remain as
//! default-impl conveniences over the `_into` forms (mirroring the
//! compressor trait's `compress`/`compress_into` split).
//!
//! The nonblocking API is deliberately *polling-based* ([`RecvHandle`] +
//! [`Transport::try_complete`]) because the paper's §3.5.2 contribution is
//! precisely "actively pull communication progress within the compression
//! and decompression phases". Blocking waits use a bounded spin followed
//! by [`std::thread::yield_now`] ([`Backoff`]) so a slow sender does not
//! pin a full core.
//!
//! ## Failure semantics
//!
//! Large fabrics straggle, flip bits, and lose ranks mid-collective; the
//! transport layer turns each of those into a *typed, prompt* error
//! instead of silent corruption or an infinite hang:
//!
//! - **Wire integrity.** Every frame (both transports) carries a 12-byte
//!   trailer: a per-`(peer, tag)` sequence number plus a CRC32C over
//!   `(source, tag, seq, payload)`. The trailer is verified at delivery —
//!   *before* bytes ever reach the codec. A checksum mismatch yields
//!   [`crate::Error::Corrupt`] naming the sending rank and tag; a frame
//!   replayed with an already-delivered sequence number is dropped
//!   idempotently; a sequence gap (a lost frame) yields
//!   [`crate::Error::Transport`]. Counters are exposed via
//!   [`Transport::wire_stats`].
//! - **Deadlines.** [`Transport::set_timeout`] arms every blocking wait
//!   ([`Transport::recv_into`], [`Transport::wait_into`], and the
//!   collectives' completion loops) with a deadline. Expiry yields
//!   [`crate::Error::Timeout`] listing exactly which `(source, tag)`
//!   receives were still pending. `None` (the default for `memchan`)
//!   waits forever, preserving the classic MPI contract.
//! - **Abort fence.** A rank that fails mid-collective broadcasts a small
//!   poison message on the reserved [`ABORT_TAG`]; peers poll
//!   [`Transport::check_abort`] from the yield phase of every wait loop
//!   and convert their waits into prompt [`crate::Error::Transport`]
//!   aborts naming the origin rank — no riding out the full timeout. The
//!   abort latch is sticky: once seen, every later wait on the endpoint
//!   fails fast. On TCP, a reader thread hitting EOF additionally poisons
//!   that peer so pending and future waits on it error immediately.
//! - **Determinism.** [`fault::FaultTransport`] wraps any transport with
//!   a seeded [`fault::FaultPlan`] (drop / corrupt / duplicate / delay /
//!   kill-after-N) so every one of the above paths is exercised
//!   reproducibly in tests and benches.

pub mod fault;
pub mod memchan;
pub mod tcp;

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::{Error, Result};

/// Reserved tag namespace for barriers (collectives must use tags below
/// this bit).
pub const BARRIER_TAG_BASE: u64 = 1 << 62;

/// Tags a single dissemination barrier consumes from the collective tag
/// counter: [`Transport::barrier`] uses one tag per round and rounds
/// double the distance, so 64 covers any conceivable fabric. Generations
/// allocated as `fresh_tags(BARRIER_GEN_SPAN)` slices inherit the
/// counter's disjointness, so barrier tags of different calls — and of
/// sub-communicators, whose [`group_wire_tag`] translation offsets the
/// low bits by the group's own counter-allocated base — can never
/// cross-match.
pub const BARRIER_GEN_SPAN: u64 = 64;

/// The wire tag of dissemination-barrier round `round` under
/// `generation`. Pure — the single definition consumed by the default
/// [`Transport::barrier`] *and* by the static schedule verifier
/// ([`crate::analysis`]), so the analyzer cannot drift from the wire.
///
/// The low bits are `generation + round` (not a shifted generation
/// field): generations come from the same monotonic counter as every
/// collective tag slice, so additive composition keeps distinct barrier
/// calls on distinct low-bit ranges and can never carry into bit 63.
pub fn barrier_tag(generation: u64, round: u64) -> u64 {
    BARRIER_TAG_BASE | (generation + round)
}

/// Translate a sub-communicator tag onto the parent fabric's wire — the
/// single tag-translation rule of [`GroupTransport`], exported pure so
/// the static schedule verifier models group traffic exactly.
///
/// Collective tags (below bit 62) are offset by the group's reserved
/// `tag_base`. Reserved namespaces survive translation: an abort poison
/// keeps exactly [`ABORT_TAG`] (the fence is fabric-global — peers scan
/// for bit 63, and smearing `tag_base` into the low bits would split the
/// poison across per-tag sequence streams), and a barrier tag stays
/// inside the barrier namespace with its low bits offset by `tag_base`
/// (naively adding `tag_base` to the full tag would alias the parent's
/// own barrier generations: parent generation `g` at low bits `g + r`
/// collides with a group barrier whose `tag_base + r` lands on the same
/// value — precisely the overlap this function pins down).
pub fn group_wire_tag(tag_base: u64, tag: u64) -> u64 {
    if tag & ABORT_TAG != 0 {
        tag
    } else if tag & BARRIER_TAG_BASE != 0 {
        BARRIER_TAG_BASE | (tag_base + (tag & !BARRIER_TAG_BASE))
    } else {
        tag_base + tag
    }
}

/// Reserved control tag for the abort fence: a rank failing mid-collective
/// sends its error text on this tag to every peer, and
/// [`Transport::check_abort`] converts waits into prompt errors. Bit 63 is
/// disjoint from both the collective tag space (below
/// [`BARRIER_TAG_BASE`]) and the barrier namespace (bit 62).
pub const ABORT_TAG: u64 = 1 << 63;

/// Length of the integrity trailer appended to every wire frame:
/// `seq: u64 LE || crc32c: u32 LE`.
pub const WIRE_TRAILER: usize = 12;

/// CRC32C (Castagnoli) lookup table, built at compile time — the crate
/// has a no-external-dependency policy, so the checksum is in-tree.
const CRC32C_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0x82F6_3B78 } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
};

/// CRC32C over the concatenation of `parts` (reflected, init/final xor
/// `!0` — the standard Castagnoli parameterisation; check value for
/// `b"123456789"` is `0xE3069283`).
pub fn crc32c(parts: &[&[u8]]) -> u32 {
    let mut crc = !0u32;
    for part in parts {
        for &byte in *part {
            crc = (crc >> 8) ^ CRC32C_TABLE[((crc ^ byte as u32) & 0xFF) as usize];
        }
    }
    !crc
}

/// Compute the frame checksum: it covers the logical source rank, the
/// tag, the sequence number, and the payload, so a frame misrouted or
/// replayed under a different identity fails verification even when its
/// payload bytes survive intact.
pub(crate) fn frame_crc(src: usize, tag: u64, seq: u64, payload: &[u8]) -> u32 {
    crc32c(&[&(src as u32).to_le_bytes(), &tag.to_le_bytes(), &seq.to_le_bytes(), payload])
}

/// Append the integrity trailer to an outbound frame.
pub(crate) fn seal_into(frame: &mut Vec<u8>, src: usize, tag: u64, seq: u64) {
    let crc = frame_crc(src, tag, seq, frame);
    frame.extend_from_slice(&seq.to_le_bytes());
    frame.extend_from_slice(&crc.to_le_bytes());
}

/// Verify and strip the integrity trailer of an arrived frame, returning
/// its sequence number. On any mismatch the frame is left untouched and
/// the error names the sending rank and tag.
pub(crate) fn unseal(src: usize, tag: u64, frame: &mut Vec<u8>) -> Result<u64> {
    if frame.len() < WIRE_TRAILER {
        return Err(Error::corrupt(format!(
            "frame from rank {src} tag {tag}: {} bytes is shorter than the integrity trailer",
            frame.len()
        )));
    }
    let base = frame.len() - WIRE_TRAILER;
    let seq = u64::from_le_bytes(frame[base..base + 8].try_into().unwrap());
    let got = u32::from_le_bytes(frame[base + 8..].try_into().unwrap());
    let want = frame_crc(src, tag, seq, &frame[..base]);
    if got != want {
        return Err(Error::corrupt(format!(
            "crc mismatch on frame from rank {src} tag {tag} seq {seq}: \
             got {got:#010x}, computed {want:#010x}"
        )));
    }
    frame.truncate(base);
    Ok(seq)
}

/// Verdict of the per-`(source, tag)` sequence check at delivery time.
pub(crate) enum SeqCheck {
    /// In-order frame: deliver it (the expected counter has advanced).
    Deliver,
    /// Already-delivered sequence number: drop the frame idempotently.
    Duplicate,
    /// The sender skipped ahead — an earlier frame was lost in transit.
    Gap {
        /// The sequence number that should have arrived instead.
        expected: u64,
    },
}

/// Advance the receive-side sequence ledger for a frame from `(src, tag)`
/// carrying `seq`.
pub(crate) fn check_seq(
    next: &mut HashMap<(usize, u64), u64>,
    src: usize,
    tag: u64,
    seq: u64,
) -> SeqCheck {
    let expected = next.entry((src, tag)).or_insert(0);
    match seq.cmp(expected) {
        std::cmp::Ordering::Less => SeqCheck::Duplicate,
        std::cmp::Ordering::Equal => {
            *expected += 1;
            SeqCheck::Deliver
        }
        std::cmp::Ordering::Greater => SeqCheck::Gap { expected: *expected },
    }
}

/// Cumulative wire-integrity and fault counters for one endpoint, exposed
/// via [`Transport::wire_stats`] and folded into
/// [`crate::coordinator::Metrics`] by the collectives layer.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WireStats {
    /// Frames whose CRC32C failed verification at delivery.
    pub corrupt_frames: u64,
    /// Frames dropped idempotently for carrying an already-delivered
    /// sequence number.
    pub dup_frames_dropped: u64,
    /// Sequence gaps observed (a preceding frame was lost in transit).
    pub gaps_detected: u64,
    /// Abort-fence poison messages observed from peers.
    pub aborts_seen: u64,
}

/// Counters exposing a transport's packet-buffer pool, for regression
/// tests and capacity planning. All values are cumulative.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PacketPoolStats {
    /// Leases served by a fresh allocation because the free list was
    /// empty.
    pub allocated: u64,
    /// Leases served from the free list.
    pub reused: u64,
    /// Buffers returned to the pool (swapped out by a receive or
    /// explicitly recycled).
    pub recycled: u64,
    /// Sends whose payload buffer was handed over **by value**
    /// ([`Transport::send_pooled`]) on a transport that moves it to the
    /// wire without the `packet_from` copy. The send-side mirror of the
    /// zero-copy receive counters.
    pub pooled_sends: u64,
    /// High-water mark: the largest buffer capacity ever returned.
    pub capacity_hwm: usize,
}

#[derive(Debug, Default)]
struct PacketPoolInner {
    free: Vec<Vec<u8>>,
    stats: PacketPoolStats,
}

/// Thread-safe free list of wire-packet buffers shared between a
/// transport's producers (senders, reader threads) and its consumer (the
/// collectives' receive path). The transport-layer sibling of the
/// collective layer's [`crate::collectives::ScratchPool`]: same
/// lease/return discipline, but `Sync` so reader threads can deposit
/// arriving payloads into reused buffers.
#[derive(Debug, Clone, Default)]
pub struct PacketPool(Arc<Mutex<PacketPoolInner>>);

impl PacketPool {
    /// Free-list depth cap; buffers returned beyond this are dropped
    /// rather than hoarded. Sized for the widest in-process fan-out (a
    /// `memchan` fabric shares ONE pool across all ranks, so every
    /// in-flight packet of every rank counts against it).
    const MAX_FREE: usize = 256;

    /// Lease a cleared buffer, reusing pooled capacity when available.
    pub fn lease(&self) -> Vec<u8> {
        let mut inner = self.0.lock().unwrap();
        match inner.free.pop() {
            Some(b) => {
                inner.stats.reused += 1;
                b
            }
            None => {
                inner.stats.allocated += 1;
                Vec::new()
            }
        }
    }

    /// Return a buffer to the pool. Zero-capacity buffers are dropped
    /// (pooling them would serve allocation-sized leases later).
    pub fn release(&self, mut b: Vec<u8>) {
        if b.capacity() == 0 {
            return;
        }
        b.clear();
        let mut inner = self.0.lock().unwrap();
        inner.stats.recycled += 1;
        inner.stats.capacity_hwm = inner.stats.capacity_hwm.max(b.capacity());
        if inner.free.len() < Self::MAX_FREE {
            inner.free.push(b);
        }
    }

    /// Lease a cleared buffer with capacity for at least `len` bytes,
    /// reserved **exactly** (`reserve_exact`) so circulating capacities
    /// track the message sizes instead of doubling past them. The single
    /// packet-sizing policy shared by every producer (send paths and the
    /// TCP reader threads).
    pub fn lease_with_capacity(&self, len: usize) -> Vec<u8> {
        let mut p = self.lease();
        if p.capacity() < len {
            p.reserve_exact(len);
        }
        p
    }

    /// Build an outbound packet carrying `data`: empty payloads travel as
    /// capacity-free vectors (barriers must not churn the pool), real
    /// payloads ride pooled exact-sized buffers.
    pub fn packet_from(&self, data: &[u8]) -> Vec<u8> {
        if data.is_empty() {
            return Vec::new();
        }
        let mut p = self.lease_with_capacity(data.len());
        p.extend_from_slice(data);
        p
    }

    /// Deliver an arrived `packet` into the caller's lease buffer without
    /// copying: the packet's allocation is swapped in and the buffer's
    /// old capacity returns to the pool for the next arrival. Returns the
    /// payload length.
    pub fn deposit(&self, packet: Vec<u8>, buf: &mut Vec<u8>) -> usize {
        let n = packet.len();
        let old = std::mem::replace(buf, packet);
        self.release(old);
        n
    }

    /// Record a zero-copy pooled send (see
    /// [`PacketPoolStats::pooled_sends`]). Called by transports whose
    /// [`Transport::send_pooled`] genuinely moves the caller's buffer.
    pub fn note_pooled_send(&self) {
        self.0.lock().unwrap().stats.pooled_sends += 1;
    }

    /// Current counters.
    pub fn stats(&self) -> PacketPoolStats {
        self.0.lock().unwrap().stats
    }
}

/// Bounded spin-then-yield backoff for completion waits: a short
/// [`std::hint::spin_loop`] burst catches messages that are nanoseconds
/// away, then the waiter downgrades to [`std::thread::yield_now`] so a
/// genuinely slow sender (a large TCP transfer, a straggling rank) does
/// not burn a full core. An optional deadline bounds the yield phase so a
/// dead peer cannot turn the wait into an infinite hang.
#[derive(Debug, Default)]
pub struct Backoff {
    spins: u32,
    deadline: Option<Instant>,
}

impl Backoff {
    /// Spin iterations before yielding to the scheduler.
    pub const SPIN_LIMIT: u32 = 64;

    /// Fresh backoff (starts in the spin phase, no deadline).
    pub fn new() -> Self {
        Backoff::default()
    }

    /// Backoff that expires `timeout` from now (`None` waits forever).
    pub fn until(timeout: Option<Duration>) -> Self {
        Backoff { spins: 0, deadline: timeout.map(|t| Instant::now() + t) }
    }

    /// Wait one step: spin while under [`Backoff::SPIN_LIMIT`], yield
    /// afterwards.
    pub fn snooze(&mut self) {
        if self.spins < Self::SPIN_LIMIT {
            self.spins += 1;
            std::hint::spin_loop();
        } else {
            std::thread::yield_now();
        }
    }

    /// Whether the wait has downgraded to the yield phase. Deadline and
    /// abort checks belong here: the spin burst stays clock-free.
    pub fn is_yielding(&self) -> bool {
        self.spins >= Self::SPIN_LIMIT
    }

    /// Whether the deadline has passed. Always `false` while still in the
    /// spin phase (a sub-microsecond deadline still gets the spin burst)
    /// and for deadline-free backoffs.
    pub fn expired(&self) -> bool {
        self.is_yielding() && self.deadline.is_some_and(|d| Instant::now() >= d)
    }
}

/// Handle to an outstanding nonblocking receive.
#[derive(Debug)]
pub struct RecvHandle {
    /// Source rank.
    pub from: usize,
    /// Match tag.
    pub tag: u64,
    pub(crate) done: Option<Vec<u8>>,
    /// Set once the payload has been handed to a caller buffer via
    /// [`Transport::try_complete_into`]; further polls stay `true`
    /// without touching the buffer again.
    pub(crate) delivered: bool,
    /// Sticky failure: set when the matching frame was consumed but
    /// failed verification (corrupt checksum, sequence gap). The first
    /// observer gets the original typed error; because progress hooks
    /// poll opportunistically and may swallow that first `Err`, every
    /// later poll of the handle replays the failure from here instead of
    /// hanging on a frame that will never re-arrive.
    pub(crate) failed: Option<String>,
}

impl RecvHandle {
    fn new(from: usize, tag: u64) -> Self {
        RecvHandle { from, tag, done: None, delivered: false, failed: None }
    }
    /// Whether the message has already been matched.
    pub fn is_complete(&self) -> bool {
        self.done.is_some() || self.delivered
    }
    /// Take the payload after completion ([`Transport::try_complete`]
    /// path). `None` if the payload was already delivered into a caller
    /// buffer by [`Transport::try_complete_into`].
    pub fn take(self) -> Option<Vec<u8>> {
        self.done
    }
}

/// Point-to-point transport endpoint bound to one rank.
///
/// Sends are *eager*: `send` buffers and returns (matching MPI's eager
/// protocol for the message sizes the collectives use after compression).
///
/// The required receive methods are the **pooled zero-copy** `_into`
/// variants (see the module docs); the allocating [`Transport::recv`] and
/// [`Transport::wait`] are default-impl wrappers.
pub trait Transport: Send {
    /// This endpoint's rank.
    fn rank(&self) -> usize;
    /// Communicator size.
    fn size(&self) -> usize;

    /// Arm every subsequent blocking wait on this endpoint with a
    /// deadline (`None` disarms — wait forever). On expiry waits return
    /// [`crate::Error::Timeout`] naming the still-pending `(source, tag)`
    /// receives. Default: ignored (transports without deadline support
    /// keep the classic block-forever contract).
    fn set_timeout(&mut self, _timeout: Option<Duration>) {}

    /// The currently armed wait deadline, if any.
    fn timeout(&self) -> Option<Duration> {
        None
    }

    /// Eager-buffered send (completes locally).
    fn send(&mut self, to: usize, tag: u64, data: &[u8]) -> Result<()>;

    /// Seal an outbound payload into a wire frame bound for `(to, tag)`:
    /// integrity-checked transports append their sequence + checksum
    /// trailer here (consuming a sequence number), others pass the
    /// payload through. Split out from the send so fault injectors can
    /// mutate *sealed* frames — a corruption introduced after sealing is
    /// exactly what the receive-side CRC must catch.
    fn seal_frame(&mut self, _to: usize, _tag: u64, payload: Vec<u8>) -> Vec<u8> {
        payload
    }

    /// Put an already-sealed frame on the wire for `(to, tag)` without
    /// re-sealing it. `seal_frame` + `send_frame` compose to
    /// [`Transport::send_pooled`]; the split exists for fault injection.
    fn send_frame(&mut self, to: usize, tag: u64, frame: Vec<u8>) -> Result<()> {
        self.send_pooled(to, tag, frame)
    }

    /// Poll the abort fence: returns `Err` if any peer has posted a
    /// poison message on [`ABORT_TAG`] (or if one was seen earlier — the
    /// latch is sticky). Wait loops call this from their yield phase so a
    /// peer's failure converts outstanding waits into prompt typed errors
    /// instead of timeouts. Default: no fence (always `Ok`).
    fn check_abort(&mut self) -> Result<()> {
        Ok(())
    }

    /// Broadcast an abort-fence poison message carrying `msg` to every
    /// peer, best-effort: send failures (a peer already gone) are
    /// ignored — the fence accelerates failure detection, it does not
    /// guarantee delivery.
    fn send_abort(&mut self, msg: &str) {
        let me = self.rank();
        for peer in 0..self.size() {
            if peer != me {
                let _ = self.send(peer, ABORT_TAG, msg.as_bytes());
            }
        }
    }

    /// Wire-integrity counters (zeros for transports without framing).
    fn wire_stats(&self) -> WireStats {
        WireStats::default()
    }

    /// Send an already-leased pooled buffer **by value** — the send-side
    /// mirror of [`Transport::recv_into`]. The caller compresses (or
    /// serialises) straight into a buffer from [`Transport::lease`] and
    /// hands it over; pooled transports move it to the wire with no
    /// `packet_from` copy (counted in [`PacketPoolStats::pooled_sends`]).
    /// The buffer is consumed either way: the default implementation
    /// falls back to a copying [`Transport::send`] and recycles it.
    fn send_pooled(&mut self, to: usize, tag: u64, data: Vec<u8>) -> Result<()> {
        let r = self.send(to, tag, &data);
        self.recycle(data);
        r
    }

    /// The transport's packet pool, if it runs one. Transports with a
    /// pool get pooled [`Transport::lease`] / [`Transport::recycle`] /
    /// [`Transport::try_complete_into`] behaviour for free.
    fn packet_pool(&self) -> Option<&PacketPool> {
        None
    }

    /// Lease a cleared wire buffer from the packet pool (a plain `Vec`
    /// for transports without one). Pair with [`Transport::recycle`].
    fn lease(&mut self) -> Vec<u8> {
        self.packet_pool().map(PacketPool::lease).unwrap_or_default()
    }

    /// Return a wire buffer — typically one handed out by
    /// [`Transport::recv_into`] — to the packet pool.
    fn recycle(&mut self, buf: Vec<u8>) {
        if let Some(p) = self.packet_pool() {
            p.release(buf);
        }
    }

    /// Packet-pool counters (zeros for transports without a pool).
    fn packet_stats(&self) -> PacketPoolStats {
        self.packet_pool().map(PacketPool::stats).unwrap_or_default()
    }

    /// Blocking receive matching `(from, tag)`, delivering the payload
    /// into `buf` (overwritten) and returning its length. Pooled
    /// transports deliver by buffer swap — zero copies, zero allocations
    /// once the pool is warm.
    fn recv_into(&mut self, from: usize, tag: u64, buf: &mut Vec<u8>) -> Result<usize>;

    /// Blocking receive into a freshly allocated vector. Default-impl
    /// wrapper over [`Transport::recv_into`]; iterated callers should
    /// lease a buffer and use the `_into` form.
    fn recv(&mut self, from: usize, tag: u64) -> Result<Vec<u8>> {
        let mut buf = Vec::new();
        self.recv_into(from, tag, &mut buf)?;
        Ok(buf)
    }

    /// Post a nonblocking receive.
    fn irecv(&mut self, from: usize, tag: u64) -> RecvHandle {
        RecvHandle::new(from, tag)
    }

    /// Poll one outstanding receive; returns true when complete. This is
    /// the progress engine the PIPE compressor hooks into.
    fn try_complete(&mut self, h: &mut RecvHandle) -> Result<bool>;

    /// Opportunistically advance transport-internal progress without a
    /// specific handle: drain arrived packets into the matching store so
    /// later `try_complete` calls find them already buffered. Called from
    /// compression/fold progress hooks (§3.5.2) when no receive of the
    /// *current* operation is outstanding — e.g. a tree root compressing
    /// its up-link frame while children of a *concurrent* request are
    /// still sending. The default is a no-op; transports with an internal
    /// arrival queue override it. Must tolerate peers that already
    /// finished and disconnected.
    fn progress(&mut self) -> Result<()> {
        Ok(())
    }

    /// Pool-aware nonblocking completion: poll the receive and, on
    /// completion, deliver the payload into `buf` (by swap on pooled
    /// transports, by copy otherwise). Once delivered, further polls
    /// return `Ok(true)` without touching `buf`.
    fn try_complete_into(&mut self, h: &mut RecvHandle, buf: &mut Vec<u8>) -> Result<bool> {
        if h.delivered {
            return Ok(true);
        }
        if !self.try_complete(h)? {
            return Ok(false);
        }
        let payload = h.done.take().expect("completed handle has payload");
        match self.packet_pool() {
            Some(pool) => {
                pool.deposit(payload, buf);
            }
            None => {
                buf.clear();
                buf.extend_from_slice(&payload);
            }
        }
        h.delivered = true;
        Ok(true)
    }

    /// Block until the handle completes, delivering the payload into
    /// `buf` and returning its length. Uses a bounded spin then
    /// [`std::thread::yield_now`] backoff so a delayed sender cannot pin
    /// a core; the yield phase polls the abort fence and the endpoint
    /// deadline ([`Transport::set_timeout`]) so a dead peer yields a
    /// prompt typed error instead of an infinite hang.
    fn wait_into(&mut self, mut h: RecvHandle, buf: &mut Vec<u8>) -> Result<usize> {
        let mut backoff = Backoff::until(self.timeout());
        loop {
            if self.try_complete_into(&mut h, buf)? {
                return Ok(buf.len());
            }
            backoff.snooze();
            if backoff.is_yielding() {
                self.check_abort()?;
                if backoff.expired() {
                    return Err(Error::timeout(vec![(h.from, h.tag)]));
                }
            }
        }
    }

    /// Block until the handle completes and return the payload. Wrapper
    /// over [`Transport::wait_into`].
    fn wait(&mut self, h: RecvHandle) -> Result<Vec<u8>> {
        let mut buf = Vec::new();
        self.wait_into(h, &mut buf)?;
        Ok(buf)
    }

    /// Dissemination barrier over the reserved tag space. Callers should
    /// allocate `generation` as a [`BARRIER_GEN_SPAN`]-wide slice of the
    /// communicator's tag counter (as
    /// [`crate::collectives::Communicator::barrier`] does) so distinct
    /// barrier calls use disjoint [`barrier_tag`] ranges.
    fn barrier(&mut self, generation: u64) -> Result<()> {
        let n = self.size();
        let me = self.rank();
        if n <= 1 {
            return Ok(());
        }
        let mut round = 0u64;
        let mut dist = 1usize;
        while dist < n {
            let to = (me + dist) % n;
            let from = (me + n - dist) % n;
            let tag = barrier_tag(generation, round);
            self.send(to, tag, &[])?;
            self.recv(from, tag)?;
            dist *= 2;
            round += 1;
        }
        Ok(())
    }
}

/// A sub-communicator view over an existing transport: the member at
/// position `i` of `members` appears as rank `i` of a `members.len()`-rank
/// transport, and every tag is translated through [`group_wire_tag`] —
/// collective tags offset by `tag_base`, reserved barrier/abort
/// namespaces preserved — so the group's traffic cannot cross-match the
/// parent communicator's, on either side of the reserved-tag boundary.
///
/// This is how the hierarchical collectives reuse the flat schedules
/// *verbatim* on one tier: the leader tier wraps the fabric in a
/// `GroupTransport` over [`crate::topology::Topology::leaders`] and runs
/// the unchanged flat ring collectives over it. All group members must
/// construct the view with the same `members` slice and `tag_base`
/// (SPMD, like any collective).
pub struct GroupTransport<'a> {
    inner: &'a mut dyn Transport,
    members: &'a [usize],
    my_idx: usize,
    tag_base: u64,
}

impl<'a> GroupTransport<'a> {
    /// Wrap `inner` as the `members` sub-communicator. Errors if the
    /// inner rank is not a member.
    pub fn new(
        inner: &'a mut dyn Transport,
        members: &'a [usize],
        tag_base: u64,
    ) -> Result<GroupTransport<'a>> {
        let me = inner.rank();
        let my_idx = members
            .iter()
            .position(|&r| r == me)
            .ok_or_else(|| crate::Error::invalid(format!("rank {me} is not in the group")))?;
        Ok(GroupTransport { inner, members, my_idx, tag_base })
    }
}

impl Transport for GroupTransport<'_> {
    fn rank(&self) -> usize {
        self.my_idx
    }
    fn size(&self) -> usize {
        self.members.len()
    }
    fn packet_pool(&self) -> Option<&PacketPool> {
        self.inner.packet_pool()
    }
    fn set_timeout(&mut self, timeout: Option<Duration>) {
        self.inner.set_timeout(timeout);
    }
    fn timeout(&self) -> Option<Duration> {
        self.inner.timeout()
    }
    fn send(&mut self, to: usize, tag: u64, data: &[u8]) -> Result<()> {
        self.inner.send(self.members[to], group_wire_tag(self.tag_base, tag), data)
    }
    fn send_pooled(&mut self, to: usize, tag: u64, data: Vec<u8>) -> Result<()> {
        self.inner.send_pooled(self.members[to], group_wire_tag(self.tag_base, tag), data)
    }
    fn seal_frame(&mut self, to: usize, tag: u64, payload: Vec<u8>) -> Vec<u8> {
        self.inner.seal_frame(self.members[to], group_wire_tag(self.tag_base, tag), payload)
    }
    fn send_frame(&mut self, to: usize, tag: u64, frame: Vec<u8>) -> Result<()> {
        self.inner.send_frame(self.members[to], group_wire_tag(self.tag_base, tag), frame)
    }
    fn check_abort(&mut self) -> Result<()> {
        self.inner.check_abort()
    }
    fn wire_stats(&self) -> WireStats {
        self.inner.wire_stats()
    }
    fn recv_into(&mut self, from: usize, tag: u64, buf: &mut Vec<u8>) -> Result<usize> {
        self.inner.recv_into(self.members[from], group_wire_tag(self.tag_base, tag), buf)
    }
    fn irecv(&mut self, from: usize, tag: u64) -> RecvHandle {
        // Handles are issued in the PARENT's rank/tag space so the inner
        // transport's progress engine can poll them directly.
        RecvHandle::new(self.members[from], group_wire_tag(self.tag_base, tag))
    }
    fn try_complete(&mut self, h: &mut RecvHandle) -> Result<bool> {
        self.inner.try_complete(h)
    }
    fn progress(&mut self) -> Result<()> {
        self.inner.progress()
    }
}

#[cfg(test)]
mod tests {
    use super::memchan::MemFabric;
    use super::*;

    #[test]
    fn barrier_completes_all_sizes() {
        for n in [1usize, 2, 3, 5, 8] {
            let handles = MemFabric::run(n, move |t| {
                for gen in 0..3u64 {
                    t.barrier(gen).unwrap();
                }
                t.rank()
            });
            assert_eq!(handles.len(), n);
        }
    }

    #[test]
    fn packet_pool_lease_release_deposit() {
        let pool = PacketPool::default();
        let mut a = pool.lease();
        a.extend_from_slice(&[1, 2, 3]);
        let cap = a.capacity();
        pool.release(a);
        let b = pool.lease();
        assert!(b.is_empty(), "released buffers come back cleared");
        assert_eq!(b.capacity(), cap);
        let s = pool.stats();
        assert_eq!(s.allocated, 1);
        assert_eq!(s.reused, 1);
        assert_eq!(s.recycled, 1);
        assert_eq!(s.capacity_hwm, cap);
        // deposit: the packet's allocation changes hands, the old buffer
        // capacity returns to the pool.
        let mut dst = b;
        dst.extend_from_slice(&[9; 16]);
        let dst_cap = dst.capacity();
        let packet = vec![7u8; 4];
        assert_eq!(pool.deposit(packet, &mut dst), 4);
        assert_eq!(dst, vec![7u8; 4]);
        let relisted = pool.lease();
        assert_eq!(relisted.capacity(), dst_cap, "old capacity must be pooled");
        // Zero-capacity buffers are not pooled.
        pool.release(Vec::new());
        assert_eq!(pool.stats().recycled, 2, "empty release is a no-op");
    }

    #[test]
    fn wait_with_delayed_sender_completes_and_yields() {
        // Satellite regression: `wait` must complete even when the sender
        // is tens of milliseconds late — far past the bounded spin budget,
        // i.e. the wait has long since downgraded to yield_now.
        MemFabric::run(2, |t| {
            if t.rank() == 0 {
                std::thread::sleep(std::time::Duration::from_millis(50));
                t.send(1, 4, b"slow").unwrap();
            } else {
                let h = t.irecv(0, 4);
                let got = t.wait(h).unwrap();
                assert_eq!(got, b"slow");
            }
        });
    }

    #[test]
    fn wait_into_reuses_caller_buffer() {
        MemFabric::run(2, |t| {
            if t.rank() == 0 {
                t.send(1, 5, b"first").unwrap();
                t.send(1, 6, b"second!").unwrap();
            } else {
                let mut buf = t.lease();
                let h = t.irecv(0, 5);
                assert_eq!(t.wait_into(h, &mut buf).unwrap(), 5);
                assert_eq!(buf.as_slice(), b"first");
                let h = t.irecv(0, 6);
                assert_eq!(t.wait_into(h, &mut buf).unwrap(), 7);
                assert_eq!(buf.as_slice(), b"second!");
                t.recycle(buf);
            }
        });
    }

    #[test]
    fn try_complete_into_is_idempotent_after_delivery() {
        MemFabric::run(2, |t| {
            if t.rank() == 0 {
                t.send(1, 9, b"once").unwrap();
            } else {
                let mut h = t.irecv(0, 9);
                let mut buf = Vec::new();
                let mut backoff = Backoff::new();
                while !t.try_complete_into(&mut h, &mut buf).unwrap() {
                    backoff.snooze();
                }
                assert_eq!(buf.as_slice(), b"once");
                assert!(h.is_complete());
                // A second poll reports complete without clobbering the
                // caller's buffer.
                buf.extend_from_slice(b"!");
                assert!(t.try_complete_into(&mut h, &mut buf).unwrap());
                assert_eq!(buf.as_slice(), b"once!");
                assert!(h.take().is_none(), "payload was delivered, not stored");
            }
        });
    }

    #[test]
    fn backoff_spins_then_yields() {
        let mut b = Backoff::new();
        for _ in 0..Backoff::SPIN_LIMIT * 3 {
            b.snooze(); // must not hang or panic past the spin budget
        }
        assert_eq!(b.spins, Backoff::SPIN_LIMIT);
    }

    #[test]
    fn crc32c_known_vectors() {
        // Standard Castagnoli check value.
        assert_eq!(crc32c(&[b"123456789"]), 0xE306_9283);
        assert_eq!(crc32c(&[b"1234", b"56789"]), 0xE306_9283, "streaming over parts");
        assert_eq!(crc32c(&[b""]), 0);
        assert_ne!(crc32c(&[b"123456788"]), 0xE306_9283);
    }

    #[test]
    fn seal_unseal_roundtrip_and_tamper_detection() {
        let mut f = b"payload".to_vec();
        seal_into(&mut f, 3, 42, 7);
        assert_eq!(f.len(), 7 + WIRE_TRAILER);
        // A frame replayed under a different identity fails even with
        // intact bytes (the checksum covers source, tag and seq).
        assert!(unseal(2, 42, &mut f.clone()).is_err());
        assert!(unseal(3, 41, &mut f.clone()).is_err());
        // Any bit flip anywhere in the frame — payload or trailer — is
        // caught, and the error names the sending rank.
        for pos in 0..f.len() {
            let mut t = f.clone();
            t[pos] ^= 0x10;
            let e = unseal(3, 42, &mut t).unwrap_err();
            assert!(format!("{e}").contains("rank 3"), "error must name the sender");
        }
        let seq = unseal(3, 42, &mut f).unwrap();
        assert_eq!(seq, 7);
        assert_eq!(f, b"payload");
    }

    #[test]
    fn sequence_ledger_orders_dups_and_gaps() {
        let mut next = HashMap::new();
        assert!(matches!(check_seq(&mut next, 1, 5, 0), SeqCheck::Deliver));
        assert!(matches!(check_seq(&mut next, 1, 5, 1), SeqCheck::Deliver));
        // Replay of a delivered frame.
        assert!(matches!(check_seq(&mut next, 1, 5, 0), SeqCheck::Duplicate));
        // Skipping ahead means a frame was lost.
        assert!(matches!(check_seq(&mut next, 1, 5, 4), SeqCheck::Gap { expected: 2 }));
        // Independent (source, tag) streams.
        assert!(matches!(check_seq(&mut next, 2, 5, 0), SeqCheck::Deliver));
        assert!(matches!(check_seq(&mut next, 1, 6, 0), SeqCheck::Deliver));
    }

    #[test]
    fn backoff_deadline_expires_only_in_yield_phase() {
        let mut b = Backoff::until(Some(Duration::from_millis(0)));
        assert!(!b.expired(), "deadline is not checked during the spin burst");
        for _ in 0..Backoff::SPIN_LIMIT {
            b.snooze();
        }
        assert!(b.expired());
        let mut free = Backoff::new();
        for _ in 0..Backoff::SPIN_LIMIT {
            free.snooze();
        }
        assert!(!free.expired(), "deadline-free backoff never expires");
    }

    #[test]
    fn wait_times_out_with_pending_pair() {
        MemFabric::run(2, |t| {
            if t.rank() == 1 {
                t.set_timeout(Some(Duration::from_millis(30)));
                let h = t.irecv(0, 77);
                let mut buf = Vec::new();
                match t.wait_into(h, &mut buf) {
                    Err(Error::Timeout { pending }) => assert_eq!(pending, vec![(0, 77)]),
                    other => panic!("expected timeout, got {other:?}"),
                }
            } else {
                // Stay alive past the peer's deadline so the timeout (not
                // a disconnect) is what ends the wait.
                std::thread::sleep(Duration::from_millis(120));
            }
        });
    }

    #[test]
    fn abort_fence_converts_waits_into_prompt_errors() {
        MemFabric::run(3, |t| {
            if t.rank() == 0 {
                t.send_abort("synthetic failure");
            } else {
                // No deadline armed: only the abort fence can end these
                // waits (each peer waits on the OTHER non-aborting rank,
                // which never sends).
                let other = 3 - t.rank();
                let h = t.irecv(other, 55);
                let mut buf = Vec::new();
                let e = t.wait_into(h, &mut buf).unwrap_err();
                let msg = format!("{e}");
                assert!(msg.contains("abort from rank 0"), "got: {msg}");
                // The latch is sticky: later waits fail fast too.
                assert!(t.check_abort().is_err());
            }
        });
    }

    #[test]
    fn send_pooled_moves_the_buffer_without_copying() {
        // A leased buffer handed to send_pooled must travel the fabric
        // without a packet_from copy: warm round-trips allocate nothing
        // and the pooled_sends counter advances.
        let mut eps = MemFabric::endpoints(2);
        let (a, b) = eps.split_at_mut(1);
        let (t0, t1) = (&mut a[0], &mut b[0]);
        let mut got = t1.lease();
        let mut warm = 0;
        for i in 0..4u64 {
            let mut buf = t0.lease();
            buf.extend_from_slice(&[0x5A; 2048]);
            t0.send_pooled(1, 40 + i, buf).unwrap();
            assert_eq!(t1.recv_into(0, 40 + i, &mut got).unwrap(), 2048);
            if i == 1 {
                warm = t0.packet_stats().allocated;
            }
        }
        let stats = t0.packet_stats();
        assert_eq!(stats.allocated, warm, "warm pooled sends must not allocate");
        assert_eq!(stats.pooled_sends, 4, "every send_pooled is counted");
        t1.recycle(got);
    }

    #[test]
    fn group_transport_translates_ranks_and_tags() {
        // Ranks {1, 3} of a 4-rank fabric form a 2-rank group; group rank
        // 0 <-> global 1, group rank 1 <-> global 3, tags offset so the
        // parent's tag 5 and the group's tag 5 never cross-match.
        let n = 4;
        let results = MemFabric::run(n, move |t| {
            let members = [1usize, 3];
            let me = t.rank();
            if me == 1 || me == 3 {
                let mut g = GroupTransport::new(t, &members, 1000).unwrap();
                assert_eq!(g.size(), 2);
                if g.rank() == 0 {
                    g.send(1, 5, b"group").unwrap();
                    let mut buf = g.lease();
                    let h = g.irecv(1, 6);
                    g.wait_into(h, &mut buf).unwrap();
                    let out = buf.clone();
                    g.recycle(buf);
                    out
                } else {
                    let m = g.recv(0, 5).unwrap();
                    let mut reply = g.lease();
                    reply.extend_from_slice(b"back");
                    g.send_pooled(0, 6, reply).unwrap();
                    m
                }
            } else {
                // Outsiders exchange on the raw tags the group offsets
                // away from: no cross-matching.
                if me == 0 {
                    t.send(2, 5, b"flat").unwrap();
                    Vec::new()
                } else {
                    t.recv(0, 5).unwrap()
                }
            }
        });
        assert_eq!(results[1], b"back");
        assert_eq!(results[3], b"group");
        assert_eq!(results[2], b"flat");
    }

    #[test]
    fn group_transport_rejects_non_members() {
        let mut eps = MemFabric::endpoints(3);
        let members = [0usize, 2];
        assert!(GroupTransport::new(&mut eps[1], &members, 0).is_err());
        assert!(GroupTransport::new(&mut eps[2], &members, 0).is_ok());
    }

    #[test]
    fn group_wire_tag_preserves_reserved_namespaces() {
        // Collective tags are offset plainly.
        assert_eq!(group_wire_tag(1000, 5), 1005);
        assert_eq!(group_wire_tag(0, 5), 5);
        // The abort fence is fabric-global: bit 63 passes through
        // untranslated, so a group-scoped failure poisons peers on
        // exactly ABORT_TAG.
        assert_eq!(group_wire_tag(1000, ABORT_TAG), ABORT_TAG);
        // Barrier tags stay inside the barrier namespace with their low
        // bits offset — never spilling into bit 63 even at the extreme
        // corner of both spaces.
        assert_eq!(group_wire_tag(1000, barrier_tag(0, 2)), BARRIER_TAG_BASE | 1002);
        let corner = group_wire_tag(BARRIER_TAG_BASE - 1, barrier_tag(BARRIER_TAG_BASE - 65, 63));
        assert_eq!(corner & ABORT_TAG, 0, "barrier translation must never reach bit 63");
        assert_ne!(corner & BARRIER_TAG_BASE, 0, "…and must stay in the barrier namespace");
        // The pinned aliasing regression: generations and group bases
        // come from ONE per-communicator counter, so disjoint counter
        // slices must yield disjoint wire tags. Parent barrier slice
        // [0, 64) vs a group based at the next slice (64): under the old
        // `generation << 8` formula a parent generation equal to
        // `tag_base >> 8` collided with the group's round tags; under
        // additive low bits the slices translate to disjoint low ranges.
        let parent_last = barrier_tag(0, BARRIER_GEN_SPAN - 1);
        let group_first = group_wire_tag(BARRIER_GEN_SPAN, barrier_tag(0, 0));
        assert_eq!(parent_last + 1, group_first, "adjacent slices stay adjacent, not aliased");
    }

    #[test]
    fn group_abort_lands_on_exact_abort_tag() {
        // A rank failing inside a sub-communicator must poison its group
        // peers on the reserved ABORT_TAG itself — not on
        // `tag_base + ABORT_TAG` — so the fence scan and the sequence
        // ledger see ONE fabric-wide poison stream per source.
        MemFabric::run(3, |t| {
            let me = t.rank();
            if me == 0 {
                let members = [0usize, 2];
                let mut g = GroupTransport::new(t, &members, 500).unwrap();
                g.send_abort("group failure");
            } else if me == 2 {
                let m = t.recv(0, ABORT_TAG).unwrap();
                assert_eq!(m, b"group failure");
            }
        });
    }

    #[test]
    fn group_barrier_and_parent_barrier_interleave() {
        // A barrier run through a group view must complete and must not
        // cross-match a parent-fabric barrier issued right after by the
        // same ranks (disjoint generation slices → disjoint wire tags).
        MemFabric::run(4, |t| {
            let me = t.rank();
            if me == 1 || me == 3 {
                let members = [1usize, 3];
                let mut g = GroupTransport::new(t, &members, 1024).unwrap();
                g.barrier(0).unwrap();
            }
            t.barrier(1024).unwrap();
        });
    }
}
