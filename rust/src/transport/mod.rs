//! Mini-MPI point-to-point substrate.
//!
//! The paper builds on MPI's blocking (`MPI_Send`/`MPI_Recv`) and
//! nonblocking (`MPI_Isend`/`MPI_Irecv` + progress polling) primitives; we
//! implement the equivalent from scratch:
//!
//! - [`memchan`] — in-process ranks (one thread each) over lock-free
//!   channels. Used by tests, examples and all real-execution benchmarks.
//! - [`tcp`] — genuine multi-process transport over a full TCP mesh, for
//!   leader/worker deployments (`zccl launch` / `zccl worker`).
//!
//! Message matching follows MPI semantics: `(source, tag)` pairs, ordered
//! per pair. Collectives allocate disjoint tag spaces per operation so
//! concurrent collectives on the same communicator never cross-match.
//!
//! ## The pooled receive path
//!
//! The receive-side API is designed so a warm iterated collective moves
//! bytes without touching the allocator:
//!
//! 1. **lease** — the consumer borrows a wire buffer from the transport's
//!    [`PacketPool`] ([`Transport::lease`]); producers (the `memchan`
//!    sender, the `tcp` reader threads) lease their packet buffers from
//!    the same pool instead of allocating fresh `Vec`s.
//! 2. **recv_into** — [`Transport::recv_into`] (and its nonblocking
//!    sibling [`Transport::try_complete_into`]) delivers an arrived
//!    packet by *swapping* it into the caller's buffer: the packet's
//!    allocation changes hands, the buffer's old capacity goes back to
//!    the pool for the next arrival. No copy, no allocation.
//! 3. **decode in place** — the collectives then run a placement decode
//!    ([`crate::compress::Compressor::decompress_into_slice`]) straight
//!    from the wire buffer into the output's final window, and
//!    [`Transport::recycle`] the buffer when done.
//!
//! The allocating [`Transport::recv`] / [`Transport::wait`] remain as
//! default-impl conveniences over the `_into` forms (mirroring the
//! compressor trait's `compress`/`compress_into` split).
//!
//! The nonblocking API is deliberately *polling-based* ([`RecvHandle`] +
//! [`Transport::try_complete`]) because the paper's §3.5.2 contribution is
//! precisely "actively pull communication progress within the compression
//! and decompression phases". Blocking waits use a bounded spin followed
//! by [`std::thread::yield_now`] ([`Backoff`]) so a slow sender does not
//! pin a full core.

pub mod memchan;
pub mod tcp;

use std::sync::{Arc, Mutex};

use crate::Result;

/// Reserved tag namespace for barriers (collectives must use tags below
/// this bit).
pub const BARRIER_TAG_BASE: u64 = 1 << 62;

/// Counters exposing a transport's packet-buffer pool, for regression
/// tests and capacity planning. All values are cumulative.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PacketPoolStats {
    /// Leases served by a fresh allocation because the free list was
    /// empty.
    pub allocated: u64,
    /// Leases served from the free list.
    pub reused: u64,
    /// Buffers returned to the pool (swapped out by a receive or
    /// explicitly recycled).
    pub recycled: u64,
    /// Sends whose payload buffer was handed over **by value**
    /// ([`Transport::send_pooled`]) on a transport that moves it to the
    /// wire without the `packet_from` copy. The send-side mirror of the
    /// zero-copy receive counters.
    pub pooled_sends: u64,
    /// High-water mark: the largest buffer capacity ever returned.
    pub capacity_hwm: usize,
}

#[derive(Debug, Default)]
struct PacketPoolInner {
    free: Vec<Vec<u8>>,
    stats: PacketPoolStats,
}

/// Thread-safe free list of wire-packet buffers shared between a
/// transport's producers (senders, reader threads) and its consumer (the
/// collectives' receive path). The transport-layer sibling of the
/// collective layer's [`crate::collectives::ScratchPool`]: same
/// lease/return discipline, but `Sync` so reader threads can deposit
/// arriving payloads into reused buffers.
#[derive(Debug, Clone, Default)]
pub struct PacketPool(Arc<Mutex<PacketPoolInner>>);

impl PacketPool {
    /// Free-list depth cap; buffers returned beyond this are dropped
    /// rather than hoarded. Sized for the widest in-process fan-out (a
    /// `memchan` fabric shares ONE pool across all ranks, so every
    /// in-flight packet of every rank counts against it).
    const MAX_FREE: usize = 256;

    /// Lease a cleared buffer, reusing pooled capacity when available.
    pub fn lease(&self) -> Vec<u8> {
        let mut inner = self.0.lock().unwrap();
        match inner.free.pop() {
            Some(b) => {
                inner.stats.reused += 1;
                b
            }
            None => {
                inner.stats.allocated += 1;
                Vec::new()
            }
        }
    }

    /// Return a buffer to the pool. Zero-capacity buffers are dropped
    /// (pooling them would serve allocation-sized leases later).
    pub fn release(&self, mut b: Vec<u8>) {
        if b.capacity() == 0 {
            return;
        }
        b.clear();
        let mut inner = self.0.lock().unwrap();
        inner.stats.recycled += 1;
        inner.stats.capacity_hwm = inner.stats.capacity_hwm.max(b.capacity());
        if inner.free.len() < Self::MAX_FREE {
            inner.free.push(b);
        }
    }

    /// Lease a cleared buffer with capacity for at least `len` bytes,
    /// reserved **exactly** (`reserve_exact`) so circulating capacities
    /// track the message sizes instead of doubling past them. The single
    /// packet-sizing policy shared by every producer (send paths and the
    /// TCP reader threads).
    pub fn lease_with_capacity(&self, len: usize) -> Vec<u8> {
        let mut p = self.lease();
        if p.capacity() < len {
            p.reserve_exact(len);
        }
        p
    }

    /// Build an outbound packet carrying `data`: empty payloads travel as
    /// capacity-free vectors (barriers must not churn the pool), real
    /// payloads ride pooled exact-sized buffers.
    pub fn packet_from(&self, data: &[u8]) -> Vec<u8> {
        if data.is_empty() {
            return Vec::new();
        }
        let mut p = self.lease_with_capacity(data.len());
        p.extend_from_slice(data);
        p
    }

    /// Deliver an arrived `packet` into the caller's lease buffer without
    /// copying: the packet's allocation is swapped in and the buffer's
    /// old capacity returns to the pool for the next arrival. Returns the
    /// payload length.
    pub fn deposit(&self, packet: Vec<u8>, buf: &mut Vec<u8>) -> usize {
        let n = packet.len();
        let old = std::mem::replace(buf, packet);
        self.release(old);
        n
    }

    /// Record a zero-copy pooled send (see
    /// [`PacketPoolStats::pooled_sends`]). Called by transports whose
    /// [`Transport::send_pooled`] genuinely moves the caller's buffer.
    pub fn note_pooled_send(&self) {
        self.0.lock().unwrap().stats.pooled_sends += 1;
    }

    /// Current counters.
    pub fn stats(&self) -> PacketPoolStats {
        self.0.lock().unwrap().stats
    }
}

/// Bounded spin-then-yield backoff for completion waits: a short
/// [`std::hint::spin_loop`] burst catches messages that are nanoseconds
/// away, then the waiter downgrades to [`std::thread::yield_now`] so a
/// genuinely slow sender (a large TCP transfer, a straggling rank) does
/// not burn a full core.
#[derive(Debug, Default)]
pub struct Backoff {
    spins: u32,
}

impl Backoff {
    /// Spin iterations before yielding to the scheduler.
    pub const SPIN_LIMIT: u32 = 64;

    /// Fresh backoff (starts in the spin phase).
    pub fn new() -> Self {
        Backoff::default()
    }

    /// Wait one step: spin while under [`Backoff::SPIN_LIMIT`], yield
    /// afterwards.
    pub fn snooze(&mut self) {
        if self.spins < Self::SPIN_LIMIT {
            self.spins += 1;
            std::hint::spin_loop();
        } else {
            std::thread::yield_now();
        }
    }
}

/// Handle to an outstanding nonblocking receive.
#[derive(Debug)]
pub struct RecvHandle {
    /// Source rank.
    pub from: usize,
    /// Match tag.
    pub tag: u64,
    pub(crate) done: Option<Vec<u8>>,
    /// Set once the payload has been handed to a caller buffer via
    /// [`Transport::try_complete_into`]; further polls stay `true`
    /// without touching the buffer again.
    pub(crate) delivered: bool,
}

impl RecvHandle {
    fn new(from: usize, tag: u64) -> Self {
        RecvHandle { from, tag, done: None, delivered: false }
    }
    /// Whether the message has already been matched.
    pub fn is_complete(&self) -> bool {
        self.done.is_some() || self.delivered
    }
    /// Take the payload after completion ([`Transport::try_complete`]
    /// path). `None` if the payload was already delivered into a caller
    /// buffer by [`Transport::try_complete_into`].
    pub fn take(self) -> Option<Vec<u8>> {
        self.done
    }
}

/// Point-to-point transport endpoint bound to one rank.
///
/// Sends are *eager*: `send` buffers and returns (matching MPI's eager
/// protocol for the message sizes the collectives use after compression).
///
/// The required receive methods are the **pooled zero-copy** `_into`
/// variants (see the module docs); the allocating [`Transport::recv`] and
/// [`Transport::wait`] are default-impl wrappers.
pub trait Transport: Send {
    /// This endpoint's rank.
    fn rank(&self) -> usize;
    /// Communicator size.
    fn size(&self) -> usize;

    /// Eager-buffered send (completes locally).
    fn send(&mut self, to: usize, tag: u64, data: &[u8]) -> Result<()>;

    /// Send an already-leased pooled buffer **by value** — the send-side
    /// mirror of [`Transport::recv_into`]. The caller compresses (or
    /// serialises) straight into a buffer from [`Transport::lease`] and
    /// hands it over; pooled transports move it to the wire with no
    /// `packet_from` copy (counted in [`PacketPoolStats::pooled_sends`]).
    /// The buffer is consumed either way: the default implementation
    /// falls back to a copying [`Transport::send`] and recycles it.
    fn send_pooled(&mut self, to: usize, tag: u64, data: Vec<u8>) -> Result<()> {
        let r = self.send(to, tag, &data);
        self.recycle(data);
        r
    }

    /// The transport's packet pool, if it runs one. Transports with a
    /// pool get pooled [`Transport::lease`] / [`Transport::recycle`] /
    /// [`Transport::try_complete_into`] behaviour for free.
    fn packet_pool(&self) -> Option<&PacketPool> {
        None
    }

    /// Lease a cleared wire buffer from the packet pool (a plain `Vec`
    /// for transports without one). Pair with [`Transport::recycle`].
    fn lease(&mut self) -> Vec<u8> {
        self.packet_pool().map(PacketPool::lease).unwrap_or_default()
    }

    /// Return a wire buffer — typically one handed out by
    /// [`Transport::recv_into`] — to the packet pool.
    fn recycle(&mut self, buf: Vec<u8>) {
        if let Some(p) = self.packet_pool() {
            p.release(buf);
        }
    }

    /// Packet-pool counters (zeros for transports without a pool).
    fn packet_stats(&self) -> PacketPoolStats {
        self.packet_pool().map(PacketPool::stats).unwrap_or_default()
    }

    /// Blocking receive matching `(from, tag)`, delivering the payload
    /// into `buf` (overwritten) and returning its length. Pooled
    /// transports deliver by buffer swap — zero copies, zero allocations
    /// once the pool is warm.
    fn recv_into(&mut self, from: usize, tag: u64, buf: &mut Vec<u8>) -> Result<usize>;

    /// Blocking receive into a freshly allocated vector. Default-impl
    /// wrapper over [`Transport::recv_into`]; iterated callers should
    /// lease a buffer and use the `_into` form.
    fn recv(&mut self, from: usize, tag: u64) -> Result<Vec<u8>> {
        let mut buf = Vec::new();
        self.recv_into(from, tag, &mut buf)?;
        Ok(buf)
    }

    /// Post a nonblocking receive.
    fn irecv(&mut self, from: usize, tag: u64) -> RecvHandle {
        RecvHandle::new(from, tag)
    }

    /// Poll one outstanding receive; returns true when complete. This is
    /// the progress engine the PIPE compressor hooks into.
    fn try_complete(&mut self, h: &mut RecvHandle) -> Result<bool>;

    /// Opportunistically advance transport-internal progress without a
    /// specific handle: drain arrived packets into the matching store so
    /// later `try_complete` calls find them already buffered. Called from
    /// compression/fold progress hooks (§3.5.2) when no receive of the
    /// *current* operation is outstanding — e.g. a tree root compressing
    /// its up-link frame while children of a *concurrent* request are
    /// still sending. The default is a no-op; transports with an internal
    /// arrival queue override it. Must tolerate peers that already
    /// finished and disconnected.
    fn progress(&mut self) -> Result<()> {
        Ok(())
    }

    /// Pool-aware nonblocking completion: poll the receive and, on
    /// completion, deliver the payload into `buf` (by swap on pooled
    /// transports, by copy otherwise). Once delivered, further polls
    /// return `Ok(true)` without touching `buf`.
    fn try_complete_into(&mut self, h: &mut RecvHandle, buf: &mut Vec<u8>) -> Result<bool> {
        if h.delivered {
            return Ok(true);
        }
        if !self.try_complete(h)? {
            return Ok(false);
        }
        let payload = h.done.take().expect("completed handle has payload");
        match self.packet_pool() {
            Some(pool) => {
                pool.deposit(payload, buf);
            }
            None => {
                buf.clear();
                buf.extend_from_slice(&payload);
            }
        }
        h.delivered = true;
        Ok(true)
    }

    /// Block until the handle completes, delivering the payload into
    /// `buf` and returning its length. Uses a bounded spin then
    /// [`std::thread::yield_now`] backoff so a delayed sender cannot pin
    /// a core (the old behaviour was an unbounded `spin_loop`).
    fn wait_into(&mut self, mut h: RecvHandle, buf: &mut Vec<u8>) -> Result<usize> {
        let mut backoff = Backoff::new();
        loop {
            if self.try_complete_into(&mut h, buf)? {
                return Ok(buf.len());
            }
            backoff.snooze();
        }
    }

    /// Block until the handle completes and return the payload. Wrapper
    /// over [`Transport::wait_into`].
    fn wait(&mut self, h: RecvHandle) -> Result<Vec<u8>> {
        let mut buf = Vec::new();
        self.wait_into(h, &mut buf)?;
        Ok(buf)
    }

    /// Dissemination barrier over the reserved tag space.
    fn barrier(&mut self, generation: u64) -> Result<()> {
        let n = self.size();
        let me = self.rank();
        if n <= 1 {
            return Ok(());
        }
        let mut round = 0u64;
        let mut dist = 1usize;
        while dist < n {
            let to = (me + dist) % n;
            let from = (me + n - dist) % n;
            let tag = BARRIER_TAG_BASE | (generation << 8) | round;
            self.send(to, tag, &[])?;
            self.recv(from, tag)?;
            dist *= 2;
            round += 1;
        }
        Ok(())
    }
}

/// A sub-communicator view over an existing transport: the member at
/// position `i` of `members` appears as rank `i` of a `members.len()`-rank
/// transport, and every tag is offset by `tag_base` so the group's traffic
/// cannot cross-match the parent communicator's.
///
/// This is how the hierarchical collectives reuse the flat schedules
/// *verbatim* on one tier: the leader tier wraps the fabric in a
/// `GroupTransport` over [`crate::topology::Topology::leaders`] and runs
/// the unchanged flat ring collectives over it. All group members must
/// construct the view with the same `members` slice and `tag_base`
/// (SPMD, like any collective).
pub struct GroupTransport<'a> {
    inner: &'a mut dyn Transport,
    members: &'a [usize],
    my_idx: usize,
    tag_base: u64,
}

impl<'a> GroupTransport<'a> {
    /// Wrap `inner` as the `members` sub-communicator. Errors if the
    /// inner rank is not a member.
    pub fn new(
        inner: &'a mut dyn Transport,
        members: &'a [usize],
        tag_base: u64,
    ) -> Result<GroupTransport<'a>> {
        let me = inner.rank();
        let my_idx = members
            .iter()
            .position(|&r| r == me)
            .ok_or_else(|| crate::Error::invalid(format!("rank {me} is not in the group")))?;
        Ok(GroupTransport { inner, members, my_idx, tag_base })
    }
}

impl Transport for GroupTransport<'_> {
    fn rank(&self) -> usize {
        self.my_idx
    }
    fn size(&self) -> usize {
        self.members.len()
    }
    fn packet_pool(&self) -> Option<&PacketPool> {
        self.inner.packet_pool()
    }
    fn send(&mut self, to: usize, tag: u64, data: &[u8]) -> Result<()> {
        self.inner.send(self.members[to], self.tag_base + tag, data)
    }
    fn send_pooled(&mut self, to: usize, tag: u64, data: Vec<u8>) -> Result<()> {
        self.inner.send_pooled(self.members[to], self.tag_base + tag, data)
    }
    fn recv_into(&mut self, from: usize, tag: u64, buf: &mut Vec<u8>) -> Result<usize> {
        self.inner.recv_into(self.members[from], self.tag_base + tag, buf)
    }
    fn irecv(&mut self, from: usize, tag: u64) -> RecvHandle {
        // Handles are issued in the PARENT's rank/tag space so the inner
        // transport's progress engine can poll them directly.
        RecvHandle::new(self.members[from], self.tag_base + tag)
    }
    fn try_complete(&mut self, h: &mut RecvHandle) -> Result<bool> {
        self.inner.try_complete(h)
    }
    fn progress(&mut self) -> Result<()> {
        self.inner.progress()
    }
}

#[cfg(test)]
mod tests {
    use super::memchan::MemFabric;
    use super::*;

    #[test]
    fn barrier_completes_all_sizes() {
        for n in [1usize, 2, 3, 5, 8] {
            let handles = MemFabric::run(n, move |t| {
                for gen in 0..3u64 {
                    t.barrier(gen).unwrap();
                }
                t.rank()
            });
            assert_eq!(handles.len(), n);
        }
    }

    #[test]
    fn packet_pool_lease_release_deposit() {
        let pool = PacketPool::default();
        let mut a = pool.lease();
        a.extend_from_slice(&[1, 2, 3]);
        let cap = a.capacity();
        pool.release(a);
        let b = pool.lease();
        assert!(b.is_empty(), "released buffers come back cleared");
        assert_eq!(b.capacity(), cap);
        let s = pool.stats();
        assert_eq!(s.allocated, 1);
        assert_eq!(s.reused, 1);
        assert_eq!(s.recycled, 1);
        assert_eq!(s.capacity_hwm, cap);
        // deposit: the packet's allocation changes hands, the old buffer
        // capacity returns to the pool.
        let mut dst = b;
        dst.extend_from_slice(&[9; 16]);
        let dst_cap = dst.capacity();
        let packet = vec![7u8; 4];
        assert_eq!(pool.deposit(packet, &mut dst), 4);
        assert_eq!(dst, vec![7u8; 4]);
        let relisted = pool.lease();
        assert_eq!(relisted.capacity(), dst_cap, "old capacity must be pooled");
        // Zero-capacity buffers are not pooled.
        pool.release(Vec::new());
        assert_eq!(pool.stats().recycled, 2, "empty release is a no-op");
    }

    #[test]
    fn wait_with_delayed_sender_completes_and_yields() {
        // Satellite regression: `wait` must complete even when the sender
        // is tens of milliseconds late — far past the bounded spin budget,
        // i.e. the wait has long since downgraded to yield_now.
        MemFabric::run(2, |t| {
            if t.rank() == 0 {
                std::thread::sleep(std::time::Duration::from_millis(50));
                t.send(1, 4, b"slow").unwrap();
            } else {
                let h = t.irecv(0, 4);
                let got = t.wait(h).unwrap();
                assert_eq!(got, b"slow");
            }
        });
    }

    #[test]
    fn wait_into_reuses_caller_buffer() {
        MemFabric::run(2, |t| {
            if t.rank() == 0 {
                t.send(1, 5, b"first").unwrap();
                t.send(1, 6, b"second!").unwrap();
            } else {
                let mut buf = t.lease();
                let h = t.irecv(0, 5);
                assert_eq!(t.wait_into(h, &mut buf).unwrap(), 5);
                assert_eq!(buf.as_slice(), b"first");
                let h = t.irecv(0, 6);
                assert_eq!(t.wait_into(h, &mut buf).unwrap(), 7);
                assert_eq!(buf.as_slice(), b"second!");
                t.recycle(buf);
            }
        });
    }

    #[test]
    fn try_complete_into_is_idempotent_after_delivery() {
        MemFabric::run(2, |t| {
            if t.rank() == 0 {
                t.send(1, 9, b"once").unwrap();
            } else {
                let mut h = t.irecv(0, 9);
                let mut buf = Vec::new();
                let mut backoff = Backoff::new();
                while !t.try_complete_into(&mut h, &mut buf).unwrap() {
                    backoff.snooze();
                }
                assert_eq!(buf.as_slice(), b"once");
                assert!(h.is_complete());
                // A second poll reports complete without clobbering the
                // caller's buffer.
                buf.extend_from_slice(b"!");
                assert!(t.try_complete_into(&mut h, &mut buf).unwrap());
                assert_eq!(buf.as_slice(), b"once!");
                assert!(h.take().is_none(), "payload was delivered, not stored");
            }
        });
    }

    #[test]
    fn backoff_spins_then_yields() {
        let mut b = Backoff::new();
        for _ in 0..Backoff::SPIN_LIMIT * 3 {
            b.snooze(); // must not hang or panic past the spin budget
        }
        assert_eq!(b.spins, Backoff::SPIN_LIMIT);
    }

    #[test]
    fn send_pooled_moves_the_buffer_without_copying() {
        // A leased buffer handed to send_pooled must travel the fabric
        // without a packet_from copy: warm round-trips allocate nothing
        // and the pooled_sends counter advances.
        let mut eps = MemFabric::endpoints(2);
        let (a, b) = eps.split_at_mut(1);
        let (t0, t1) = (&mut a[0], &mut b[0]);
        let mut got = t1.lease();
        let mut warm = 0;
        for i in 0..4u64 {
            let mut buf = t0.lease();
            buf.extend_from_slice(&[0x5A; 2048]);
            t0.send_pooled(1, 40 + i, buf).unwrap();
            assert_eq!(t1.recv_into(0, 40 + i, &mut got).unwrap(), 2048);
            if i == 1 {
                warm = t0.packet_stats().allocated;
            }
        }
        let stats = t0.packet_stats();
        assert_eq!(stats.allocated, warm, "warm pooled sends must not allocate");
        assert_eq!(stats.pooled_sends, 4, "every send_pooled is counted");
        t1.recycle(got);
    }

    #[test]
    fn group_transport_translates_ranks_and_tags() {
        // Ranks {1, 3} of a 4-rank fabric form a 2-rank group; group rank
        // 0 <-> global 1, group rank 1 <-> global 3, tags offset so the
        // parent's tag 5 and the group's tag 5 never cross-match.
        let n = 4;
        let results = MemFabric::run(n, move |t| {
            let members = [1usize, 3];
            let me = t.rank();
            if me == 1 || me == 3 {
                let mut g = GroupTransport::new(t, &members, 1000).unwrap();
                assert_eq!(g.size(), 2);
                if g.rank() == 0 {
                    g.send(1, 5, b"group").unwrap();
                    let mut buf = g.lease();
                    let h = g.irecv(1, 6);
                    g.wait_into(h, &mut buf).unwrap();
                    let out = buf.clone();
                    g.recycle(buf);
                    out
                } else {
                    let m = g.recv(0, 5).unwrap();
                    let mut reply = g.lease();
                    reply.extend_from_slice(b"back");
                    g.send_pooled(0, 6, reply).unwrap();
                    m
                }
            } else {
                // Outsiders exchange on the raw tags the group offsets
                // away from: no cross-matching.
                if me == 0 {
                    t.send(2, 5, b"flat").unwrap();
                    Vec::new()
                } else {
                    t.recv(0, 5).unwrap()
                }
            }
        });
        assert_eq!(results[1], b"back");
        assert_eq!(results[3], b"group");
        assert_eq!(results[2], b"flat");
    }

    #[test]
    fn group_transport_rejects_non_members() {
        let mut eps = MemFabric::endpoints(3);
        let members = [0usize, 2];
        assert!(GroupTransport::new(&mut eps[1], &members, 0).is_err());
        assert!(GroupTransport::new(&mut eps[2], &members, 0).is_ok());
    }
}
