//! Mini-MPI point-to-point substrate.
//!
//! The paper builds on MPI's blocking (`MPI_Send`/`MPI_Recv`) and
//! nonblocking (`MPI_Isend`/`MPI_Irecv` + progress polling) primitives; we
//! implement the equivalent from scratch:
//!
//! - [`memchan`] — in-process ranks (one thread each) over lock-free
//!   channels. Used by tests, examples and all real-execution benchmarks.
//! - [`tcp`] — genuine multi-process transport over a full TCP mesh, for
//!   leader/worker deployments (`zccl launch` / `zccl worker`).
//!
//! Message matching follows MPI semantics: `(source, tag)` pairs, ordered
//! per pair. Collectives allocate disjoint tag spaces per operation so
//! concurrent collectives on the same communicator never cross-match.
//!
//! The nonblocking API is deliberately *polling-based* ([`RecvHandle`] +
//! [`Transport::try_complete`]) because the paper's §3.5.2 contribution is
//! precisely "actively pull communication progress within the compression
//! and decompression phases".

pub mod memchan;
pub mod tcp;

use crate::Result;

/// Reserved tag namespace for barriers (collectives must use tags below
/// this bit).
pub const BARRIER_TAG_BASE: u64 = 1 << 62;

/// Handle to an outstanding nonblocking receive.
#[derive(Debug)]
pub struct RecvHandle {
    /// Source rank.
    pub from: usize,
    /// Match tag.
    pub tag: u64,
    pub(crate) done: Option<Vec<u8>>,
}

impl RecvHandle {
    fn new(from: usize, tag: u64) -> Self {
        RecvHandle { from, tag, done: None }
    }
    /// Whether the message has already been matched.
    pub fn is_complete(&self) -> bool {
        self.done.is_some()
    }
    /// Take the payload after completion.
    pub fn take(self) -> Option<Vec<u8>> {
        self.done
    }
}

/// Point-to-point transport endpoint bound to one rank.
///
/// Sends are *eager*: `send` buffers and returns (matching MPI's eager
/// protocol for the message sizes the collectives use after compression).
pub trait Transport: Send {
    /// This endpoint's rank.
    fn rank(&self) -> usize;
    /// Communicator size.
    fn size(&self) -> usize;

    /// Eager-buffered send (completes locally).
    fn send(&mut self, to: usize, tag: u64, data: &[u8]) -> Result<()>;

    /// Blocking receive matching `(from, tag)`.
    fn recv(&mut self, from: usize, tag: u64) -> Result<Vec<u8>>;

    /// Post a nonblocking receive.
    fn irecv(&mut self, from: usize, tag: u64) -> RecvHandle {
        RecvHandle::new(from, tag)
    }

    /// Poll one outstanding receive; returns true when complete. This is
    /// the progress engine the PIPE compressor hooks into.
    fn try_complete(&mut self, h: &mut RecvHandle) -> Result<bool>;

    /// Block until the handle completes and return the payload.
    fn wait(&mut self, mut h: RecvHandle) -> Result<Vec<u8>> {
        while !self.try_complete(&mut h)? {
            std::hint::spin_loop();
        }
        Ok(h.take().expect("completed handle has payload"))
    }

    /// Dissemination barrier over the reserved tag space.
    fn barrier(&mut self, generation: u64) -> Result<()> {
        let n = self.size();
        let me = self.rank();
        if n <= 1 {
            return Ok(());
        }
        let mut round = 0u64;
        let mut dist = 1usize;
        while dist < n {
            let to = (me + dist) % n;
            let from = (me + n - dist) % n;
            let tag = BARRIER_TAG_BASE | (generation << 8) | round;
            self.send(to, tag, &[])?;
            self.recv(from, tag)?;
            dist *= 2;
            round += 1;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::memchan::MemFabric;
    use super::*;

    #[test]
    fn barrier_completes_all_sizes() {
        for n in [1usize, 2, 3, 5, 8] {
            let handles = MemFabric::run(n, move |t| {
                for gen in 0..3u64 {
                    t.barrier(gen).unwrap();
                }
                t.rank()
            });
            assert_eq!(handles.len(), n);
        }
    }
}
