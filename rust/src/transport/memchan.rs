//! In-process transport: every rank is a thread, links are unbounded
//! lock-free channels. This is the default substrate for tests, examples
//! and real-execution benchmarks (DESIGN.md §2: the paper's 128-node
//! cluster is simulated; small-scale correctness runs are real).
//!
//! All endpoints of a fabric share ONE [`PacketPool`]: a sender leases
//! its packet buffer from the pool, the buffer travels the channel, and
//! the receiver's `recv_into` swap returns a same-sized capacity to the
//! pool — so a warm iterated collective moves every byte through recycled
//! buffers with zero allocator traffic. [`Transport::send_pooled`] closes
//! the loop on the send side: an already-leased buffer is moved onto the
//! channel as-is, skipping the `packet_from` copy entirely.
//!
//! The pool is deliberately fabric-wide rather than per-endpoint: a
//! packet allocated by the sender is recycled by the *receiver*, so
//! per-endpoint free lists only stay balanced when every rank sends as
//! much as it receives — true for rings and pairwise exchanges but not
//! for tree roots (a bcast root sends `log n` packets per call and
//! receives none, so its private pool would drain and re-allocate every
//! iteration). The cost is one shared mutex, held for a `Vec` push/pop —
//! small next to the per-message channel synchronisation already paid.
//!
//! ## Node-partitioned fabrics
//!
//! [`MemFabric::endpoints_on_nodes`] / [`MemFabric::run_on_nodes`] build
//! the same fabric pinned to a [`Topology`]: every message is classified
//! by [`LinkClass`] and counted into fabric-wide [`TierTraffic`] totals,
//! and each (src, dst) pair that crosses the slow tier is recorded — so
//! tests and benches can assert, e.g., that a hierarchical collective's
//! inter-node traffic flows **only between leaders**, and report
//! bytes-crossing-the-slow-tier per iteration.

use std::collections::{BTreeMap, BTreeSet, HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use super::{Backoff, PacketPool, RecvHandle, SeqCheck, Transport, WireStats};
use super::{ABORT_TAG, WIRE_TRAILER};
use crate::topology::{LinkClass, Topology};
use crate::{Error, Result};

type Packet = (u64, Vec<u8>); // (tag, payload)

/// Fabric-wide per-tier traffic totals of a node-partitioned fabric.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TierTraffic {
    /// Messages on the fast (same-node) tier.
    pub intra_msgs: u64,
    /// Bytes on the fast tier.
    pub intra_bytes: u64,
    /// Messages crossing the slow (inter-node) tier.
    pub inter_msgs: u64,
    /// Bytes crossing the slow tier.
    pub inter_bytes: u64,
}

/// Traffic snapshot of a node-partitioned fabric.
#[derive(Debug, Clone, Default)]
pub struct TrafficReport {
    /// Per-tier totals.
    pub tier: TierTraffic,
    /// Every directed (src, dst) rank pair that crossed the slow tier.
    pub inter_pairs: Vec<(usize, usize)>,
}

/// Exact per-`(src, dst, tag)` wire-message counts of a traced fabric —
/// the ground truth the static schedule verifier's predicted message
/// graph is checked against ([`crate::analysis`]): a run traced with
/// [`MemFabric::run_traced`] must produce *precisely* the edges the
/// analyzer derives from the collective's plan, or the analyzer has
/// drifted from the executors.
pub type MessageLedger = BTreeMap<(usize, usize, u64), u64>;

/// Shared node map + traffic ledger of a node-partitioned fabric.
#[derive(Debug)]
struct NodeMap {
    topo: Topology,
    traffic: Mutex<(TierTraffic, BTreeSet<(usize, usize)>)>,
}

impl NodeMap {
    fn record(&self, from: usize, to: usize, bytes: usize) {
        let mut t = self.traffic.lock().unwrap();
        match self.topo.link_class(from, to) {
            LinkClass::Intra => {
                t.0.intra_msgs += 1;
                t.0.intra_bytes += bytes as u64;
            }
            LinkClass::Inter => {
                t.0.inter_msgs += 1;
                t.0.inter_bytes += bytes as u64;
                t.1.insert((from, to));
            }
        }
    }

    fn report(&self) -> TrafficReport {
        let t = self.traffic.lock().unwrap();
        TrafficReport { tier: t.0, inter_pairs: t.1.iter().copied().collect() }
    }
}

/// One rank's endpoint in an in-process fabric.
pub struct MemTransport {
    rank: usize,
    size: usize,
    /// Senders to each peer (index = destination rank).
    tx: Vec<Sender<Packet>>,
    /// Receivers from each peer (index = source rank).
    rx: Vec<Receiver<Packet>>,
    /// Messages that arrived but have not been matched yet, per (src, tag).
    unmatched: HashMap<(usize, u64), VecDeque<Vec<u8>>>,
    /// Fabric-wide packet pool (shared by every endpoint).
    pool: PacketPool,
    /// Node partition + traffic ledger (node-partitioned fabrics only).
    nodes: Option<Arc<NodeMap>>,
    /// Next outbound sequence number per (destination, tag). Grows with
    /// the number of distinct (peer, tag) streams ever used — bounded in
    /// practice by the collectives' tag-rationing discipline.
    tx_seq: HashMap<(usize, u64), u64>,
    /// Next expected inbound sequence number per (source, tag).
    rx_seq: HashMap<(usize, u64), u64>,
    /// Per-(src, dst, tag) message tape of a traced fabric. Recorded at
    /// [`Transport::send_frame`] — the choke point every wire message
    /// funnels through (plain, pooled and re-sent frames alike).
    tape: Option<Arc<Mutex<MessageLedger>>>,
    /// Wire-integrity counters.
    wire: WireStats,
    /// Deadline armed on every blocking wait (`None` = wait forever).
    timeout: Option<Duration>,
    /// Sticky abort latch: set on the first poison message observed.
    aborted: Option<String>,
}

/// Factory for a set of fully-connected [`MemTransport`] endpoints.
pub struct MemFabric;

impl MemFabric {
    /// Create `n` connected endpoints (sharing one packet pool).
    pub fn endpoints(n: usize) -> Vec<MemTransport> {
        Self::build(n, None, None)
    }

    /// Create one endpoint per rank of `topo`, all pinned to their nodes:
    /// every message is tier-classified and counted (see the module docs).
    pub fn endpoints_on_nodes(topo: &Topology) -> Vec<MemTransport> {
        Self::build(topo.ranks(), Some(Self::node_map(topo)), None)
    }

    fn node_map(topo: &Topology) -> Arc<NodeMap> {
        Arc::new(NodeMap {
            topo: topo.clone(),
            traffic: Mutex::new((TierTraffic::default(), BTreeSet::new())),
        })
    }

    fn build(
        n: usize,
        nodes: Option<Arc<NodeMap>>,
        tape: Option<Arc<Mutex<MessageLedger>>>,
    ) -> Vec<MemTransport> {
        // matrix[s][d] = channel from s to d.
        let mut txs: Vec<Vec<Option<Sender<Packet>>>> =
            (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
        let mut rxs: Vec<Vec<Option<Receiver<Packet>>>> =
            (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
        for s in 0..n {
            for d in 0..n {
                let (tx, rx) = channel();
                txs[s][d] = Some(tx);
                rxs[d][s] = Some(rx);
            }
        }
        let pool = PacketPool::default();
        txs.into_iter()
            .zip(rxs)
            .enumerate()
            .map(|(rank, (tx_row, rx_row))| MemTransport {
                rank,
                size: n,
                tx: tx_row.into_iter().map(Option::unwrap).collect(),
                rx: rx_row.into_iter().map(Option::unwrap).collect(),
                unmatched: HashMap::new(),
                pool: pool.clone(),
                nodes: nodes.clone(),
                tape: tape.clone(),
                tx_seq: HashMap::new(),
                rx_seq: HashMap::new(),
                wire: WireStats::default(),
                timeout: None,
                aborted: None,
            })
            .collect()
    }

    /// Spawn `n` rank threads running `f` and return their results in rank
    /// order. Panics in any rank propagate.
    pub fn run<R, F>(n: usize, f: F) -> Vec<R>
    where
        R: Send + 'static,
        F: Fn(&mut MemTransport) -> R + Send + Sync + 'static,
    {
        Self::launch(Self::endpoints(n), f)
    }

    /// [`MemFabric::run`] over a node-partitioned fabric: one thread per
    /// rank of `topo`, returning the per-rank results *and* the fabric's
    /// tier-traffic report.
    pub fn run_on_nodes<R, F>(topo: &Topology, f: F) -> (Vec<R>, TrafficReport)
    where
        R: Send + 'static,
        F: Fn(&mut MemTransport) -> R + Send + Sync + 'static,
    {
        let endpoints = Self::endpoints_on_nodes(topo);
        let nodes = endpoints[0].nodes.clone().expect("node-partitioned fabric");
        let results = Self::launch(endpoints, f);
        (results, nodes.report())
    }

    /// [`MemFabric::run`] with every wire message recorded: returns the
    /// per-rank results plus the exact per-`(src, dst, tag)` message
    /// counts. The static schedule verifier's property tests compare
    /// this ledger against the analyzer's predicted graph.
    pub fn run_traced<R, F>(n: usize, f: F) -> (Vec<R>, MessageLedger)
    where
        R: Send + 'static,
        F: Fn(&mut MemTransport) -> R + Send + Sync + 'static,
    {
        let tape = Arc::new(Mutex::new(MessageLedger::new()));
        let results = Self::launch(Self::build(n, None, Some(tape.clone())), f);
        let ledger = tape.lock().unwrap().clone();
        (results, ledger)
    }

    /// [`MemFabric::run_traced`] over a node-partitioned fabric (one rank
    /// per entry of `topo`) — the traced twin of
    /// [`MemFabric::run_on_nodes`], used to ledger-check hierarchical
    /// schedules.
    pub fn run_traced_on_nodes<R, F>(topo: &Topology, f: F) -> (Vec<R>, MessageLedger)
    where
        R: Send + 'static,
        F: Fn(&mut MemTransport) -> R + Send + Sync + 'static,
    {
        let tape = Arc::new(Mutex::new(MessageLedger::new()));
        let endpoints = Self::build(topo.ranks(), Some(Self::node_map(topo)), Some(tape.clone()));
        let results = Self::launch(endpoints, f);
        let ledger = tape.lock().unwrap().clone();
        (results, ledger)
    }

    fn launch<R, F>(endpoints: Vec<MemTransport>, f: F) -> Vec<R>
    where
        R: Send + 'static,
        F: Fn(&mut MemTransport) -> R + Send + Sync + 'static,
    {
        let f = std::sync::Arc::new(f);
        let joins: Vec<thread::JoinHandle<R>> = endpoints
            .into_iter()
            .map(|mut t| {
                let f = f.clone();
                thread::Builder::new()
                    .name(format!("rank-{}", t.rank))
                    .stack_size(8 << 20)
                    .spawn(move || f(&mut t))
                    .expect("spawn rank thread")
            })
            .collect();
        joins
            .into_iter()
            .map(|j| j.join().expect("rank thread panicked"))
            .collect()
    }
}

impl MemTransport {
    /// Drain every pending packet from `src` into the unmatched store,
    /// returning true if `(src, tag)` became available.
    fn pump(&mut self, src: usize, tag: u64) -> Result<bool> {
        loop {
            match self.rx[src].try_recv() {
                Ok((t, payload)) => {
                    if t == tag {
                        self.unmatched.entry((src, t)).or_default().push_back(payload);
                        return Ok(true);
                    }
                    self.unmatched.entry((src, t)).or_default().push_back(payload);
                }
                Err(TryRecvError::Empty) => return Ok(false),
                Err(TryRecvError::Disconnected) => {
                    return Err(Error::transport(format!(
                        "rank {} disconnected from rank {}",
                        src, self.rank
                    )))
                }
            }
        }
    }

    fn take_unmatched(&mut self, src: usize, tag: u64) -> Option<Vec<u8>> {
        let q = self.unmatched.get_mut(&(src, tag))?;
        let msg = q.pop_front();
        if q.is_empty() {
            self.unmatched.remove(&(src, tag));
        }
        msg
    }

    /// Verify and strip the integrity trailer of a frame pulled from the
    /// store — the last step before bytes reach the caller (and so the
    /// codec). `Ok(Some(payload))` delivers; `Ok(None)` means the frame
    /// was a duplicate and was dropped idempotently (pull the next one).
    fn deliver(&mut self, src: usize, tag: u64, mut frame: Vec<u8>) -> Result<Option<Vec<u8>>> {
        let seq = match super::unseal(src, tag, &mut frame) {
            Ok(seq) => seq,
            Err(e) => {
                self.wire.corrupt_frames += 1;
                self.pool.release(frame);
                return Err(e);
            }
        };
        match super::check_seq(&mut self.rx_seq, src, tag, seq) {
            SeqCheck::Deliver => Ok(Some(frame)),
            SeqCheck::Duplicate => {
                self.wire.dup_frames_dropped += 1;
                self.pool.release(frame);
                Ok(None)
            }
            SeqCheck::Gap { expected } => {
                self.wire.gaps_detected += 1;
                self.pool.release(frame);
                Err(Error::transport(format!(
                    "lost frame from rank {src} tag {tag}: expected seq {expected}, got {seq}"
                )))
            }
        }
    }

    /// Traffic snapshot of a node-partitioned fabric (`None` for fabrics
    /// built without a topology).
    pub fn traffic(&self) -> Option<TrafficReport> {
        self.nodes.as_ref().map(|n| n.report())
    }
}

impl Transport for MemTransport {
    fn rank(&self) -> usize {
        self.rank
    }
    fn size(&self) -> usize {
        self.size
    }

    fn packet_pool(&self) -> Option<&PacketPool> {
        Some(&self.pool)
    }

    fn set_timeout(&mut self, timeout: Option<Duration>) {
        self.timeout = timeout;
    }

    fn timeout(&self) -> Option<Duration> {
        self.timeout
    }

    fn wire_stats(&self) -> WireStats {
        self.wire
    }

    fn seal_frame(&mut self, to: usize, tag: u64, mut payload: Vec<u8>) -> Vec<u8> {
        let seq = self.tx_seq.entry((to, tag)).or_insert(0);
        let this = *seq;
        *seq += 1;
        super::seal_into(&mut payload, self.rank, tag, this);
        payload
    }

    fn send_frame(&mut self, to: usize, tag: u64, frame: Vec<u8>) -> Result<()> {
        if to >= self.size {
            return Err(Error::invalid(format!("send to rank {to} of {}", self.size)));
        }
        if let Some(tape) = &self.tape {
            *tape.lock().unwrap().entry((self.rank, to, tag)).or_insert(0) += 1;
        }
        self.tx[to]
            .send((tag, frame))
            .map_err(|_| Error::transport(format!("rank {to} receiver dropped")))
    }

    fn send(&mut self, to: usize, tag: u64, data: &[u8]) -> Result<()> {
        if to >= self.size {
            return Err(Error::invalid(format!("send to rank {to} of {}", self.size)));
        }
        if let Some(nodes) = &self.nodes {
            // The ledger counts logical payload bytes, not trailer bytes.
            nodes.record(self.rank, to, data.len());
        }
        // Lease with trailer headroom so sealing never reallocates (and
        // empty barrier payloads still ride pooled buffers).
        let mut packet = self.pool.lease_with_capacity(data.len() + WIRE_TRAILER);
        packet.extend_from_slice(data);
        let frame = self.seal_frame(to, tag, packet);
        self.send_frame(to, tag, frame)
    }

    fn send_pooled(&mut self, to: usize, tag: u64, data: Vec<u8>) -> Result<()> {
        if to >= self.size {
            return Err(Error::invalid(format!("send to rank {to} of {}", self.size)));
        }
        if let Some(nodes) = &self.nodes {
            nodes.record(self.rank, to, data.len());
        }
        // The caller's leased buffer IS the packet: no copy; its capacity
        // re-enters the pool at the receiver's swap.
        self.pool.note_pooled_send();
        let frame = self.seal_frame(to, tag, data);
        self.send_frame(to, tag, frame)
    }

    fn recv_into(&mut self, from: usize, tag: u64, buf: &mut Vec<u8>) -> Result<usize> {
        if from >= self.size {
            return Err(Error::invalid(format!("recv from rank {from} of {}", self.size)));
        }
        let mut backoff = Backoff::until(self.timeout);
        loop {
            while let Some(m) = self.take_unmatched(from, tag) {
                if let Some(payload) = self.deliver(from, tag, m)? {
                    return Ok(self.pool.deposit(payload, buf));
                }
                // Duplicate dropped: pull the next queued frame.
            }
            match self.rx[from].try_recv() {
                Ok((t, payload)) => {
                    self.unmatched.entry((from, t)).or_default().push_back(payload);
                    continue;
                }
                Err(TryRecvError::Empty) => {}
                Err(TryRecvError::Disconnected) => {
                    // try_recv drains buffered messages before reporting a
                    // disconnect, so the sought frame can no longer arrive.
                    return Err(Error::transport(format!(
                        "rank {from} disconnected (recv tag {tag})"
                    )));
                }
            }
            backoff.snooze();
            if backoff.is_yielding() {
                self.check_abort()?;
                if backoff.expired() {
                    return Err(Error::timeout(vec![(from, tag)]));
                }
            }
        }
    }

    fn try_complete(&mut self, h: &mut RecvHandle) -> Result<bool> {
        if h.done.is_some() || h.delivered {
            return Ok(true);
        }
        if let Some(m) = &h.failed {
            return Err(Error::transport(m.clone()));
        }
        loop {
            if let Some(m) = self.take_unmatched(h.from, h.tag) {
                match self.deliver(h.from, h.tag, m) {
                    Ok(Some(payload)) => {
                        h.done = Some(payload);
                        return Ok(true);
                    }
                    Ok(None) => continue, // duplicate dropped
                    Err(e) => {
                        // The matching frame was consumed by verification;
                        // latch so later polls replay instead of hanging.
                        h.failed = Some(format!(
                            "receive from rank {} tag {} failed: {e}",
                            h.from, h.tag
                        ));
                        return Err(e);
                    }
                }
            }
            if !self.pump(h.from, h.tag)? {
                return Ok(false);
            }
        }
    }

    fn check_abort(&mut self) -> Result<()> {
        if let Some(m) = &self.aborted {
            return Err(Error::transport(m.clone()));
        }
        // Pull in anything newly arrived, then scan for poison — any tag
        // with the abort bit set (GroupTransport passes reserved-space
        // tags through untranslated, so group poison arrives on exactly
        // ABORT_TAG too).
        self.progress()?;
        loop {
            let Some(&(src, tag)) = self.unmatched.keys().find(|(_, t)| t & ABORT_TAG != 0)
            else {
                return Ok(());
            };
            let frame = self.take_unmatched(src, tag).expect("key just observed");
            let text = match self.deliver(src, tag, frame) {
                Ok(Some(payload)) => {
                    let text = String::from_utf8_lossy(&payload).into_owned();
                    self.pool.release(payload);
                    text
                }
                Ok(None) => continue, // duplicate poison: drop, rescan
                Err(_) => String::from("(unreadable abort payload)"),
            };
            let msg = format!("abort from rank {src}: {text}");
            self.wire.aborts_seen += 1;
            self.aborted = Some(msg.clone());
            return Err(Error::transport(msg));
        }
    }

    fn progress(&mut self) -> Result<()> {
        // Drain every peer's channel into the unmatched store. Unlike
        // `pump`, a disconnected peer is NOT an error here: progress is
        // called opportunistically from compute hooks, and a peer may
        // have legitimately finished its run already — any message it
        // did send was buffered by the channel before the disconnect.
        for src in 0..self.size {
            loop {
                match self.rx[src].try_recv() {
                    Ok((t, payload)) => {
                        self.unmatched.entry((src, t)).or_default().push_back(payload);
                    }
                    Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pingpong() {
        let results = MemFabric::run(2, |t| {
            if t.rank() == 0 {
                t.send(1, 7, b"ping").unwrap();
                t.recv(1, 8).unwrap()
            } else {
                let m = t.recv(0, 7).unwrap();
                assert_eq!(m, b"ping");
                t.send(0, 8, b"pong").unwrap();
                m
            }
        });
        assert_eq!(results[0], b"pong");
    }

    #[test]
    fn tag_matching_out_of_order() {
        let results = MemFabric::run(2, |t| {
            if t.rank() == 0 {
                t.send(1, 1, b"first").unwrap();
                t.send(1, 2, b"second").unwrap();
                vec![]
            } else {
                // Receive in reverse tag order.
                let b = t.recv(0, 2).unwrap();
                let a = t.recv(0, 1).unwrap();
                assert_eq!(a, b"first");
                assert_eq!(b, b"second");
                a
            }
        });
        assert_eq!(results[1], b"first");
    }

    #[test]
    fn same_tag_preserves_order() {
        let results = MemFabric::run(2, |t| {
            if t.rank() == 0 {
                for i in 0..10u8 {
                    t.send(1, 3, &[i]).unwrap();
                }
                0
            } else {
                for i in 0..10u8 {
                    assert_eq!(t.recv(0, 3).unwrap(), vec![i]);
                }
                1
            }
        });
        assert_eq!(results, vec![0, 1]);
    }

    #[test]
    fn irecv_poll_completes() {
        MemFabric::run(2, |t| {
            if t.rank() == 0 {
                // Delay so rank 1 actually polls a few times.
                std::thread::sleep(std::time::Duration::from_millis(5));
                t.send(1, 9, b"late").unwrap();
            } else {
                let mut h = t.irecv(0, 9);
                let mut polls = 0u64;
                while !t.try_complete(&mut h).unwrap() {
                    polls += 1;
                }
                assert_eq!(h.take().unwrap(), b"late");
                assert!(polls > 0, "expected at least one unfulfilled poll");
            }
        });
    }

    #[test]
    fn ring_pass_many_ranks() {
        let n = 8;
        let results = MemFabric::run(n, move |t| {
            let me = t.rank();
            let next = (me + 1) % n;
            let prev = (me + n - 1) % n;
            let mut token = vec![me as u8];
            for round in 0..n as u64 {
                t.send(next, round, &token).unwrap();
                token = t.recv(prev, round).unwrap();
            }
            token[0] as usize
        });
        // After n hops every token returns home.
        for (r, v) in results.iter().enumerate() {
            assert_eq!(*v, r);
        }
    }

    #[test]
    fn warm_recv_into_loop_stops_allocating() {
        // The zero-copy contract: once the fabric-wide pool is warm, an
        // iterated send/recv_into loop leases every packet from the pool.
        // Driven single-threaded for a deterministic interleaving: the
        // allocation counter must freeze after the warm-up iteration.
        let mut eps = MemFabric::endpoints(2);
        let (a, b) = eps.split_at_mut(1);
        let (t0, t1) = (&mut a[0], &mut b[0]);
        let mut buf0 = t0.lease();
        let mut buf1 = t1.lease();
        let mut warm = 0;
        for iter in 0..5u64 {
            t0.send(1, 100 + iter, &[0xAB; 4096]).unwrap();
            assert_eq!(t1.recv_into(0, 100 + iter, &mut buf1).unwrap(), 4096);
            t1.send(0, 200 + iter, &[0xCD; 4096]).unwrap();
            assert_eq!(t0.recv_into(1, 200 + iter, &mut buf0).unwrap(), 4096);
            if iter == 1 {
                warm = t0.packet_stats().allocated;
            }
        }
        let end = t0.packet_stats().allocated; // fabric-wide (shared pool)
        assert!(warm > 0, "cold iterations must have allocated");
        assert_eq!(end, warm, "warm iterations must not allocate packet buffers");
        t0.recycle(buf0);
        t1.recycle(buf1);
    }

    #[test]
    fn duplicate_frames_dropped_idempotently() {
        let mut eps = MemFabric::endpoints(2);
        let (a, b) = eps.split_at_mut(1);
        let (t0, t1) = (&mut a[0], &mut b[0]);
        // Seal once, put the identical frame on the wire twice.
        let frame = t0.seal_frame(1, 7, b"once".to_vec());
        t0.send_frame(1, 7, frame.clone()).unwrap();
        t0.send_frame(1, 7, frame).unwrap();
        t0.send(1, 7, b"next").unwrap();
        assert_eq!(t1.recv(0, 7).unwrap(), b"once");
        assert_eq!(t1.recv(0, 7).unwrap(), b"next", "the replay must be dropped, not delivered");
        assert_eq!(t1.wire_stats().dup_frames_dropped, 1);
    }

    #[test]
    fn corrupt_frame_detected_at_delivery_names_sender() {
        let mut eps = MemFabric::endpoints(2);
        let (a, b) = eps.split_at_mut(1);
        let (t0, t1) = (&mut a[0], &mut b[0]);
        let mut frame = t0.seal_frame(1, 9, b"payload".to_vec());
        frame[2] ^= 0x40;
        t0.send_frame(1, 9, frame).unwrap();
        let e = t1.recv(0, 9).unwrap_err();
        assert!(matches!(e, Error::Corrupt(_)), "got {e:?}");
        assert!(format!("{e}").contains("rank 0"), "error must name the sender");
        assert_eq!(t1.wire_stats().corrupt_frames, 1);
    }

    #[test]
    fn lost_frame_surfaces_as_sequence_gap() {
        // Sealing consumes sequence number 0, but the frame never ships;
        // the next frame on the same (peer, tag) stream arrives as seq 1
        // and the receiver reports the loss instead of delivering out of
        // order.
        let mut eps = MemFabric::endpoints(2);
        let (a, b) = eps.split_at_mut(1);
        let (t0, t1) = (&mut a[0], &mut b[0]);
        let _lost = t0.seal_frame(1, 3, b"lost".to_vec());
        t0.send(1, 3, b"after").unwrap();
        let e = t1.recv(0, 3).unwrap_err();
        assert!(format!("{e}").contains("lost frame from rank 0"), "got {e}");
        assert_eq!(t1.wire_stats().gaps_detected, 1);
    }

    #[test]
    fn node_partitioned_fabric_classifies_traffic() {
        // 2 nodes x 2 ranks: 0,1 on node 0; 2,3 on node 1. Drive the four
        // endpoints single-threaded and check the ledger.
        let topo = Topology::blocked(2, 2);
        let mut eps = MemFabric::endpoints_on_nodes(&topo);
        // intra: 0 -> 1 (4 bytes); inter: 0 -> 2 (2 bytes), 3 -> 1 (1 byte,
        // pooled).
        eps[0].send(1, 1, b"fast").unwrap();
        eps[0].send(2, 2, b"xx").unwrap();
        let mut pooled = eps[3].lease();
        pooled.extend_from_slice(b"y");
        eps[3].send_pooled(1, 3, pooled).unwrap();
        assert_eq!(eps[1].recv(0, 1).unwrap(), b"fast");
        assert_eq!(eps[2].recv(0, 2).unwrap(), b"xx");
        assert_eq!(eps[1].recv(3, 3).unwrap(), b"y");
        let report = eps[0].traffic().unwrap();
        assert_eq!(report.tier.intra_msgs, 1);
        assert_eq!(report.tier.intra_bytes, 4);
        assert_eq!(report.tier.inter_msgs, 2);
        assert_eq!(report.tier.inter_bytes, 3);
        assert_eq!(report.inter_pairs, vec![(0, 2), (3, 1)]);
        // Plain fabrics have no ledger.
        assert!(MemFabric::endpoints(2)[0].traffic().is_none());
    }

    #[test]
    fn traced_fabric_records_every_wire_message() {
        let (results, ledger) = MemFabric::run_traced(2, |t| {
            if t.rank() == 0 {
                t.send(1, 7, b"a").unwrap();
                t.send(1, 7, b"b").unwrap();
                let mut p = t.lease();
                p.extend_from_slice(b"c");
                t.send_pooled(1, 9, p).unwrap();
                0
            } else {
                t.recv(0, 7).unwrap();
                t.recv(0, 7).unwrap();
                t.recv(0, 9).unwrap();
                1
            }
        });
        assert_eq!(results, vec![0, 1]);
        let mut want = MessageLedger::new();
        want.insert((0, 1, 7), 2);
        want.insert((0, 1, 9), 1);
        assert_eq!(ledger, want, "plain and pooled sends must both hit the tape");
    }

    #[test]
    fn run_on_nodes_returns_results_and_report() {
        let topo = Topology::grouped(&[2, 1]).unwrap();
        let (results, report) = MemFabric::run_on_nodes(&topo, |t| {
            // Ring pass: every rank sends 8 bytes to its successor.
            let n = t.size();
            let me = t.rank();
            t.send((me + 1) % n, 7, &[me as u8; 8]).unwrap();
            let got = t.recv((me + n - 1) % n, 7).unwrap();
            got[0] as usize
        });
        assert_eq!(results, vec![2, 0, 1]);
        // Links 1->2 and 2->0 cross nodes; 0->1 stays inside node 0.
        assert_eq!(report.tier.intra_msgs, 1);
        assert_eq!(report.tier.inter_msgs, 2);
        assert_eq!(report.tier.inter_bytes, 16);
        assert_eq!(report.inter_pairs, vec![(1, 2), (2, 0)]);
    }
}
