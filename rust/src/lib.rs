//! # ZCCL — compression-accelerated collective communication
//!
//! A from-scratch reproduction of *"ZCCL: Significantly Improving Collective
//! Communication With Error-Bounded Lossy Compression"* (CS.DC 2025).
//!
//! The crate is organised bottom-up:
//!
//! - [`ops`] — the elementwise reduction operators, shared by
//!   [`collectives`] and the fused decompress–reduce kernels in
//!   [`compress`].
//! - [`compress`] — error-bounded lossy compressors: a Rust `fZ-light`
//!   staged as quantize (Lorenzo-predicted error-bounded quantization) →
//!   pack (fixed-length bit-shifting encoding) → optional order-0 rANS
//!   entropy coding, with adaptive per-chunk stage selection
//!   (plain / fixed-width / entropy-coded, never worse than fixed-width)
//!   behind an opt-in frame version; its pipelined variant
//!   `PIPE-fZ-light`, an `SZx`-style constant-block compressor, and a
//!   ZFP-like fixed-rate baseline.
//! - [`data`] — seeded synthetic scientific-field generators standing in for
//!   the paper's RTM / NYX / CESM-ATM / Hurricane datasets.
//! - [`transport`] — a mini-MPI substrate: blocking and nonblocking
//!   point-to-point messaging with explicit progress polling, over
//!   in-process channels or TCP.
//! - [`topology`] — ring and binomial-tree communication schedules, plus
//!   the two-level `Topology` layer (rank→node maps, leader election,
//!   group-mapped schedule generators) behind the hierarchical modes.
//! - [`collectives`] — the paper's contribution: Allgather, Reduce-scatter,
//!   Allreduce, Bcast, Scatter, Gather, Reduce in `Plain` / `Cprp2p` /
//!   `CColl` / `Zccl` modes, with topology-aware two-level `Hier`
//!   schedules that compress only at node leaders. Each collective has a
//!   blocking call and a nonblocking `icollective` twin (`iallreduce`,
//!   `iallgather`, …) returning a persistent request handle whose
//!   progress is driven cooperatively by `test()`/`wait()` — the
//!   compute/communication-overlap API used by the DDP trainer.
//! - [`sim`] — a calibrated virtual-time cost model reproducing the paper's
//!   128-node Broadwell + 100 Gbps Omni-Path testbed (this container has a
//!   single core, so scaling figures run on the simulator; real-transport
//!   runs at small rank counts cross-check it).
//! - [`runtime`] — PJRT executor for AOT-compiled JAX/Pallas artifacts
//!   (HLO text), used by the data-parallel training example.
//! - [`coordinator`] — leader/worker orchestration, metrics breakdowns and
//!   the benchmark harness behind `zccl bench <table|figure>`.
//! - [`apps`] — the paper's image-stacking use case and a DDP trainer.
//!
//! ## Quickstart
//!
//! ```
//! use zccl::collectives::{CollCtx, Mode, ReduceOp};
//! use zccl::compress::{CompressorKind, ErrorBound};
//!
//! // Four in-process ranks allreduce a vector with error-bounded
//! // compression, through the persistent per-rank context (codec built
//! // once, scratch buffers pooled across calls).
//! let mode = Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(1e-4));
//! let results = zccl::collectives::run_ranks(4, move |comm| {
//!     let mut ctx = CollCtx::over(comm, mode);
//!     let x = vec![ctx.rank() as f32; 1024];
//!     ctx.allreduce(&x, ReduceOp::Sum).unwrap()
//! });
//! for r in &results {
//!     for v in r { assert!((v - 6.0).abs() < 5.0 * 1e-4); } // 0+1+2+3
//! }
//! ```
//!
//! ## Nonblocking: launch → compute → wait
//!
//! The `icollective` API overlaps communication with the caller's own
//! compute: start a request, keep computing (each `test()` poll advances
//! every in-flight collective), and only the final `wait()` blocks — the
//! time it reports is the communication the overlap failed to hide.
//!
//! ```
//! use zccl::collectives::{CollCtx, Mode, ReduceOp};
//! use zccl::compress::{CompressorKind, ErrorBound};
//!
//! let mode = Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(1e-4));
//! let results = zccl::collectives::run_ranks(4, move |comm| {
//!     let mut ctx = CollCtx::over(comm, mode);
//!     let x = vec![ctx.rank() as f32; 1024];
//!     let req = ctx.iallreduce(&x, ReduceOp::Sum).unwrap(); // launch
//!     let mut acc = 0.0f32;
//!     for i in 0..64 {
//!         acc += (i as f32).sqrt(); // overlapped compute
//!         let _done = ctx.test(&req).unwrap(); // drives progress
//!     }
//!     (ctx.wait(req).unwrap().values, acc) // block only here
//! });
//! for (r, _) in &results {
//!     for v in r { assert!((v - 6.0).abs() < 5.0 * 1e-4); }
//! }
//! ```
//!
//! ## Failure semantics
//!
//! The transport is chaos-hardened (the full contract lives in the
//! [`transport`] module docs). Every frame carries a CRC32C checksum and
//! a per-(peer, tag) sequence number, verified on receive *before* any
//! byte reaches a codec: a flipped bit surfaces as [`Error::Corrupt`]
//! naming the sending rank, a replayed frame is dropped idempotently,
//! and a lost frame shows up as a sequence gap ([`Error::Transport`]) or
//! a timeout. Deadlines are per-context —
//! [`collectives::CollCtx::set_timeout`] arms every blocking collective
//! and nonblocking `wait()` (the TCP transport defaults to 60 s, the
//! in-process fabric to none) — and a stalled operation converts into
//! [`Error::Timeout`] listing the `(peer, tag)` receives still pending.
//! A rank that fails mid-collective broadcasts a poison frame on a
//! reserved tag so its peers fail fast with [`Error::Transport`] instead
//! of waiting out their own deadlines; [`Error::is_recoverable`]
//! separates deadline expiries (retryable) from integrity and abort
//! failures (not). Deterministic fault injection for tests lives in
//! [`transport::fault`], and `zccl bench chaos` prices the failure
//! paths (dead-peer detection latency, checksum overhead per element).
//!
//! ## Verified invariants
//!
//! Every collective's wire choreography is a deterministic function of
//! `(collective, Algo, nranks, Topology, root)`: executors derive peers
//! and tags from the pure plan descriptions in [`analysis::plan`] and
//! the schedule generators in [`topology`]. The [`analysis`] module
//! exploits this to *statically* rebuild the full per-rank message
//! graph of any collective shape and prove, without spawning a thread:
//!
//! - **deadlock-freedom** — a dataflow simulation of the blocking
//!   wait-for order terminates with every script drained;
//! - **match completeness** — every send has exactly one receive and
//!   vice versa (no orphan messages leaking across operations);
//! - **tag-space safety** — reservations from the shared counter are
//!   disjoint, every edge (after `GroupTransport` translation,
//!   including segment fan-out) stays inside its operation's reserved
//!   window, barrier/abort namespaces are never crossed, and no two
//!   transfers on one link overlap tag windows;
//! - **buffer-window disjointness** — chunk partitions tile exactly and
//!   hierarchical subtree bundles cover every rank exactly once.
//!
//! `zccl verify` sweeps all of this across every algorithm arm,
//! topology shape, and rank count (enforced in CI), and
//! `tests/schedule_verifier.rs` closes the loop against reality: a
//! traced in-memory fabric must record exactly the per-`(src, dst,
//! tag)` message counts the symbolic graph predicts.

#![forbid(unsafe_code)]
#![deny(
    clippy::dbg_macro,
    clippy::todo,
    clippy::unimplemented,
    clippy::mem_forget,
    clippy::exit
)]

pub mod analysis;
pub mod apps;
pub mod collectives;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod ops;
pub mod runtime;
pub mod sim;
pub mod topology;
pub mod transport;
pub mod util;

pub use error::{Error, Result};
