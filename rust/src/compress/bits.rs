//! Fast bit-level packing used by the fixed-length ("bit-shifting")
//! encoding stages of fZ-light and SZx.
//!
//! Both compressors emit, per small block, a run of `width`-bit magnitudes.
//! The writer keeps a 64-bit accumulator and spills whole bytes, which is
//! the hot loop of compression; the reader mirrors it.

/// Append-only bit writer over a byte vector.
pub struct BitWriter {
    out: Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl BitWriter {
    /// Create a writer with the given byte-capacity hint.
    pub fn with_capacity(cap: usize) -> Self {
        BitWriter { out: Vec::with_capacity(cap), acc: 0, nbits: 0 }
    }

    /// Number of whole bytes emitted so far (excluding a partial tail).
    #[inline]
    pub fn byte_len(&self) -> usize {
        self.out.len()
    }

    /// Write the low `width` bits of `v` (LSB-first into the stream).
    /// `width` must be <= 57 so the accumulator never overflows.
    #[inline]
    pub fn put(&mut self, v: u64, width: u32) {
        debug_assert!(width <= 57);
        debug_assert!(width == 64 || v < (1u64 << width));
        self.acc |= v << self.nbits;
        self.nbits += width;
        while self.nbits >= 8 {
            self.out.push((self.acc & 0xff) as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    /// Write a wide value (up to 64 bits) as two limbs.
    #[inline]
    pub fn put_wide(&mut self, v: u64, width: u32) {
        if width <= 57 {
            self.put(v, width);
        } else {
            self.put(v & ((1u64 << 32) - 1), 32);
            self.put(v >> 32, width - 32);
        }
    }

    /// Flush the partial byte (zero-padded) and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.out.push((self.acc & 0xff) as u8);
        }
        self.out
    }

    /// Flush the partial byte into the buffer and continue writing on a
    /// byte boundary (used between blocks so each block is byte-aligned).
    #[inline]
    pub fn align(&mut self) {
        if self.nbits > 0 {
            self.out.push((self.acc & 0xff) as u8);
            self.acc = 0;
            self.nbits = 0;
        }
    }
}

/// LSB-first bit reader over a byte slice.
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize,
    acc: u64,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    /// Create a reader over `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0, acc: 0, nbits: 0 }
    }

    /// Byte offset of the next unread byte, counting the bits currently
    /// held in the accumulator as consumed.
    #[inline]
    pub fn byte_pos_aligned(&self) -> usize {
        self.pos
    }

    /// Read `width` bits (<= 57). Returns 0 bits past the end (the caller
    /// validates stream length up front).
    #[inline]
    pub fn get(&mut self, width: u32) -> u64 {
        debug_assert!(width <= 57);
        while self.nbits < width {
            let b = if self.pos < self.buf.len() { self.buf[self.pos] } else { 0 };
            self.pos += 1;
            self.acc |= (b as u64) << self.nbits;
            self.nbits += 8;
        }
        let v = self.acc & (((1u64 << width) - 1) | if width == 64 { u64::MAX } else { 0 });
        self.acc >>= width;
        self.nbits -= width;
        v
    }

    /// Read a wide value (up to 64 bits) as two limbs.
    #[inline]
    pub fn get_wide(&mut self, width: u32) -> u64 {
        if width <= 57 {
            self.get(width)
        } else {
            let lo = self.get(32);
            let hi = self.get(width - 32);
            lo | (hi << 32)
        }
    }

    /// Discard buffered bits and continue from the next byte boundary.
    #[inline]
    pub fn align(&mut self) {
        self.acc = 0;
        self.nbits = 0;
    }
}

/// Zero-allocation fixed-width packer: append `vals[..cnt]` as `width`-bit
/// little-endian codes directly onto `out` (byte-aligned at the end).
/// Layout is identical to a [`BitWriter`] `put_wide` sequence + `align`.
/// This is the compression hot loop — no per-block allocations.
#[inline]
pub fn pack_fixed(out: &mut Vec<u8>, vals: &[u64], width: u32) {
    debug_assert!(width >= 1 && width <= 64);
    let mut acc = 0u64;
    let mut nb = 0u32;
    if width <= 57 {
        for &v in vals {
            debug_assert!(width == 64 || v < (1u64 << width));
            acc |= v << nb;
            nb += width;
            // Spill a word at a time when possible (amortises the Vec
            // bookkeeping), then bytes.
            if nb >= 32 {
                out.extend_from_slice(&(acc as u32).to_le_bytes());
                acc >>= 32;
                nb -= 32;
            }
            while nb >= 8 {
                out.push(acc as u8);
                acc >>= 8;
                nb -= 8;
            }
        }
    } else {
        for &v in vals {
            acc |= (v & 0xFFFF_FFFF) << nb;
            nb += 32;
            while nb >= 8 {
                out.push(acc as u8);
                acc >>= 8;
                nb -= 8;
            }
            acc |= (v >> 32) << nb;
            nb += width - 32;
            while nb >= 8 {
                out.push(acc as u8);
                acc >>= 8;
                nb -= 8;
            }
        }
    }
    if nb > 0 {
        out.push(acc as u8);
    }
}

/// Zero-allocation fixed-width unpacker matching [`pack_fixed`]: calls
/// `f(index, value)` for each of `cnt` `width`-bit codes in `bytes`.
#[inline]
pub fn unpack_fixed(bytes: &[u8], cnt: usize, width: u32, mut f: impl FnMut(usize, u64)) {
    debug_assert!(width >= 1 && width <= 64);
    if width <= 57 {
        let mask = (1u64 << width) - 1;
        let mut acc = 0u64;
        let mut nb = 0u32;
        let mut ptr = 0usize;
        for j in 0..cnt {
            while nb < width {
                let b = if ptr < bytes.len() { bytes[ptr] } else { 0 };
                acc |= (b as u64) << nb;
                nb += 8;
                ptr += 1;
            }
            f(j, acc & mask);
            acc >>= width;
            nb -= width;
        }
    } else {
        // Rare path (codes wider than 57 bits): lean on BitReader.
        let mut r = BitReader::new(bytes);
        for j in 0..cnt {
            f(j, r.get_wide(width));
        }
    }
}

/// Little-endian primitive read/write helpers for frame headers.
pub mod le {
    use crate::{Error, Result};

    /// Append a `u32` little-endian.
    pub fn put_u32(out: &mut Vec<u8>, v: u32) {
        out.extend_from_slice(&v.to_le_bytes());
    }
    /// Append a `u64` little-endian.
    pub fn put_u64(out: &mut Vec<u8>, v: u64) {
        out.extend_from_slice(&v.to_le_bytes());
    }
    /// Append an `f64` little-endian.
    pub fn put_f64(out: &mut Vec<u8>, v: f64) {
        out.extend_from_slice(&v.to_le_bytes());
    }
    /// Append an `f32` little-endian.
    pub fn put_f32(out: &mut Vec<u8>, v: f32) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    /// Read a `u32` at `*pos`, advancing it.
    pub fn get_u32(buf: &[u8], pos: &mut usize) -> Result<u32> {
        let end = *pos + 4;
        let b = buf.get(*pos..end).ok_or_else(|| Error::corrupt("u32 past end"))?;
        *pos = end;
        Ok(u32::from_le_bytes(b.try_into().unwrap()))
    }
    /// Read a `u64` at `*pos`, advancing it.
    pub fn get_u64(buf: &[u8], pos: &mut usize) -> Result<u64> {
        let end = *pos + 8;
        let b = buf.get(*pos..end).ok_or_else(|| Error::corrupt("u64 past end"))?;
        *pos = end;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }
    /// Read an `f64` at `*pos`, advancing it.
    pub fn get_f64(buf: &[u8], pos: &mut usize) -> Result<f64> {
        let end = *pos + 8;
        let b = buf.get(*pos..end).ok_or_else(|| Error::corrupt("f64 past end"))?;
        *pos = end;
        Ok(f64::from_le_bytes(b.try_into().unwrap()))
    }
    /// Read an `f32` at `*pos`, advancing it.
    pub fn get_f32(buf: &[u8], pos: &mut usize) -> Result<f32> {
        let end = *pos + 4;
        let b = buf.get(*pos..end).ok_or_else(|| Error::corrupt("f32 past end"))?;
        *pos = end;
        Ok(f32::from_le_bytes(b.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_mixed_widths() {
        let mut w = BitWriter::with_capacity(64);
        let vals: Vec<(u64, u32)> = vec![
            (0b1, 1),
            (0b1011, 4),
            (0x7f, 7),
            (0x1_0000, 17),
            (0, 3),
            (0x1f_ffff, 21),
            ((1u64 << 57) - 1, 57),
        ];
        for &(v, n) in &vals {
            w.put(v, n);
        }
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for &(v, n) in &vals {
            assert_eq!(r.get(n), v, "width {n}");
        }
    }

    #[test]
    fn roundtrip_wide() {
        let mut w = BitWriter::with_capacity(64);
        let vals = [u64::MAX, 0, 1, 0xdead_beef_cafe_f00d];
        for &v in &vals {
            w.put_wide(v, 64);
        }
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for &v in &vals {
            assert_eq!(r.get_wide(64), v);
        }
    }

    #[test]
    fn align_is_byte_boundary() {
        let mut w = BitWriter::with_capacity(16);
        w.put(0b101, 3);
        w.align();
        w.put(0xab, 8);
        let buf = w.finish();
        assert_eq!(buf.len(), 2);
        assert_eq!(buf[1], 0xab);
        let mut r = BitReader::new(&buf);
        assert_eq!(r.get(3), 0b101);
        r.align();
        assert_eq!(r.get(8), 0xab);
    }

    #[test]
    fn zero_width_is_noop() {
        let mut w = BitWriter::with_capacity(4);
        w.put(0, 0);
        w.put(0b11, 2);
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        assert_eq!(r.get(0), 0);
        assert_eq!(r.get(2), 0b11);
    }

    #[test]
    fn le_roundtrip() {
        let mut out = Vec::new();
        le::put_u32(&mut out, 0xdeadbeef);
        le::put_u64(&mut out, 42);
        le::put_f64(&mut out, -1.5);
        le::put_f32(&mut out, 3.25);
        let mut pos = 0;
        assert_eq!(le::get_u32(&out, &mut pos).unwrap(), 0xdeadbeef);
        assert_eq!(le::get_u64(&out, &mut pos).unwrap(), 42);
        assert_eq!(le::get_f64(&out, &mut pos).unwrap(), -1.5);
        assert_eq!(le::get_f32(&out, &mut pos).unwrap(), 3.25);
        assert!(le::get_u32(&out, &mut pos).is_err());
    }
}
