//! Word-parallel bit-level packing used by the fixed-length
//! ("bit-shifting") encoding stages of fZ-light and SZx.
//!
//! Both compressors emit, per small block, a run of `width`-bit magnitudes
//! (LSB-first, byte-aligned at the end of each block). Two kernel families
//! implement that layout:
//!
//! - **Word-parallel kernels** — the hot path. [`pack_fixed`] keeps a
//!   64-bit accumulator and spills **whole 8-byte words** per overflow
//!   (one amortised `extend_from_slice` instead of up to eight `push`es),
//!   and [`unpack_fixed`] decodes a caller-sized batch of codes with
//!   whole-`u64` refills (`u64::from_le_bytes` on full words, a masked
//!   tail load at the end of the slice). The decode side is
//!   block-batched: callers hand it a stack array per block instead of a
//!   per-value closure, so the surrounding sign/reconstruct/dequantize
//!   stages run as straight-line loops the compiler can vectorize.
//! - **Scalar reference** — [`BitWriter`] / [`BitReader`] and the thin
//!   [`pack_fixed_reference`] / [`unpack_fixed_reference`] wrappers over
//!   them. One bit-accumulator step per byte, kept deliberately simple:
//!   this is the executable specification of the stream layout. The
//!   property suite (`tests/codec_kernels.rs`) checks the word-parallel
//!   kernels against it for every width 1..=64, and `zccl bench codec`
//!   reports `speedup_vs_reference` in `BENCH_codec.json` so the gap is
//!   tracked from PR to PR.
//!
//! Both families produce bit-identical streams; the layout is the spec
//! and existing frames must decode unchanged.

/// Append-only bit writer over a byte vector — the **scalar reference**
/// encoder (see the module docs). Production encode goes through
/// [`pack_fixed`].
pub struct BitWriter {
    out: Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl BitWriter {
    /// Create a writer with the given byte-capacity hint.
    pub fn with_capacity(cap: usize) -> Self {
        BitWriter { out: Vec::with_capacity(cap), acc: 0, nbits: 0 }
    }

    /// Number of whole bytes emitted so far (excluding a partial tail).
    #[inline]
    pub fn byte_len(&self) -> usize {
        self.out.len()
    }

    /// Write the low `width` bits of `v` (LSB-first into the stream).
    ///
    /// `width` must be <= 57 — the single-limb invariant shared with
    /// [`BitReader::get`]: the 64-bit accumulator holds at most 7 leftover
    /// bits, so 57 more always fit. Wider values go through
    /// [`BitWriter::put_wide`], which splits them into two limbs.
    #[inline]
    pub fn put(&mut self, v: u64, width: u32) {
        debug_assert!(width <= 57);
        debug_assert!(v < (1u64 << width));
        self.acc |= v << self.nbits;
        self.nbits += width;
        while self.nbits >= 8 {
            self.out.push((self.acc & 0xff) as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    /// Write a wide value (up to 64 bits) as two limbs.
    #[inline]
    pub fn put_wide(&mut self, v: u64, width: u32) {
        if width <= 57 {
            self.put(v, width);
        } else {
            self.put(v & ((1u64 << 32) - 1), 32);
            self.put(v >> 32, width - 32);
        }
    }

    /// Flush the partial byte (zero-padded) and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.out.push((self.acc & 0xff) as u8);
        }
        self.out
    }

    /// Flush the partial byte into the buffer and continue writing on a
    /// byte boundary (used between blocks so each block is byte-aligned).
    #[inline]
    pub fn align(&mut self) {
        if self.nbits > 0 {
            self.out.push((self.acc & 0xff) as u8);
            self.acc = 0;
            self.nbits = 0;
        }
    }
}

/// LSB-first bit reader over a byte slice — the **scalar reference**
/// decoder (see the module docs). Production decode goes through
/// [`unpack_fixed`].
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize,
    acc: u64,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    /// Create a reader over `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0, acc: 0, nbits: 0 }
    }

    /// Byte offset of the next unread byte, counting the bits currently
    /// held in the accumulator as consumed.
    #[inline]
    pub fn byte_pos_aligned(&self) -> usize {
        self.pos
    }

    /// Read `width` bits. Returns 0 bits past the end (the caller
    /// validates stream length up front).
    ///
    /// `width` must be <= 57 — the single-limb invariant shared with
    /// [`BitWriter::put`] (at most 7 leftover accumulator bits + 57 never
    /// overflow 64, and the mask below never needs the full-word case).
    /// Wider values go through [`BitReader::get_wide`].
    #[inline]
    pub fn get(&mut self, width: u32) -> u64 {
        debug_assert!(width <= 57);
        while self.nbits < width {
            let b = if self.pos < self.buf.len() { self.buf[self.pos] } else { 0 };
            self.pos += 1;
            self.acc |= (b as u64) << self.nbits;
            self.nbits += 8;
        }
        let v = self.acc & ((1u64 << width) - 1);
        self.acc >>= width;
        self.nbits -= width;
        v
    }

    /// Read a wide value (up to 64 bits) as two limbs.
    #[inline]
    pub fn get_wide(&mut self, width: u32) -> u64 {
        if width <= 57 {
            self.get(width)
        } else {
            let lo = self.get(32);
            let hi = self.get(width - 32);
            lo | (hi << 32)
        }
    }

    /// Discard buffered bits and continue from the next byte boundary.
    #[inline]
    pub fn align(&mut self) {
        self.acc = 0;
        self.nbits = 0;
    }
}

/// Word-parallel fixed-width packer: append `vals` as `width`-bit
/// little-endian codes onto `out` (byte-aligned at the end). The layout
/// is identical to a [`BitWriter`] `put_wide` sequence + `align` — see
/// [`pack_fixed_reference`] for that executable spec.
///
/// This is the compression hot loop: the 64-bit accumulator spills a
/// **whole 8-byte word** per overflow (`extend_from_slice` of
/// `acc.to_le_bytes()`, one amortised memcpy) instead of draining byte
/// by byte, and only the sub-word tail is pushed per byte. Zero
/// allocations beyond the single up-front `reserve`.
#[inline]
pub fn pack_fixed(out: &mut Vec<u8>, vals: &[u64], width: u32) {
    debug_assert!(width >= 1 && width <= 64);
    out.reserve((vals.len() * width as usize).div_ceil(8));
    let mut acc = 0u64;
    let mut nb = 0u32;
    if width <= 57 {
        // Single-limb path. Invariant: bits >= nb of `acc` are zero, and
        // nb <= 63 at the top of each iteration, so `v << nb` keeps every
        // bit that belongs below the spill boundary; the bits it sheds
        // (positions >= 64) are exactly the ones restored from `v` after
        // the word is written out.
        for &v in vals {
            debug_assert!(v < (1u64 << width));
            acc |= v << nb;
            nb += width;
            if nb >= 64 {
                out.extend_from_slice(&acc.to_le_bytes());
                nb -= 64;
                acc = if nb > 0 { v >> (width - nb) } else { 0 };
            }
        }
    } else {
        // Two-limb path (codes wider than 57 bits): low 32 bits, then the
        // remaining `width - 32`, matching `BitWriter::put_wide`.
        let hiw = width - 32;
        for &v in vals {
            let lo = v & 0xFFFF_FFFF;
            acc |= lo << nb;
            nb += 32;
            if nb >= 64 {
                out.extend_from_slice(&acc.to_le_bytes());
                nb -= 64;
                acc = if nb > 0 { lo >> (32 - nb) } else { 0 };
            }
            let hi = v >> 32;
            acc |= hi << nb;
            nb += hiw;
            if nb >= 64 {
                out.extend_from_slice(&acc.to_le_bytes());
                nb -= 64;
                acc = if nb > 0 { hi >> (hiw - nb) } else { 0 };
            }
        }
    }
    // Sub-word tail: whole leftover bytes, then the zero-padded partial.
    while nb >= 8 {
        out.push(acc as u8);
        acc >>= 8;
        nb -= 8;
    }
    if nb > 0 {
        out.push(acc as u8);
    }
}

/// Scalar reference for [`pack_fixed`]: the same stream via [`BitWriter`]
/// (`put_wide` each value, `align`). Kept as the executable layout spec
/// for the property suite and the `BENCH_codec.json`
/// `speedup_vs_reference` baseline — not a hot path.
pub fn pack_fixed_reference(out: &mut Vec<u8>, vals: &[u64], width: u32) {
    debug_assert!(width >= 1 && width <= 64);
    let mut w = BitWriter::with_capacity((vals.len() * width as usize).div_ceil(8));
    for &v in vals {
        w.put_wide(v, width);
    }
    out.extend_from_slice(&w.finish());
}

/// Load the 8 bytes at `ptr` as a little-endian word, zero-padding past
/// the end of `bytes` (the tail load of [`unpack_fixed`]).
#[inline]
fn word_at(bytes: &[u8], ptr: usize) -> u64 {
    match bytes.get(ptr..ptr + 8) {
        Some(s) => u64::from_le_bytes(s.try_into().unwrap()),
        None => {
            let mut tmp = [0u8; 8];
            if let Some(rest) = bytes.get(ptr..) {
                tmp[..rest.len()].copy_from_slice(rest);
            }
            u64::from_le_bytes(tmp)
        }
    }
}

/// Word-parallel fixed-width unpacker matching [`pack_fixed`]: decode
/// `out.len()` `width`-bit codes from `bytes` into `out` — the
/// block-batch decode kernel (callers pass one block's stack array at a
/// time). Refills load a **whole `u64`** per step and advance by however
/// many full bytes fit the accumulator, so the per-value work is one
/// mask/shift pair.
///
/// # Contract
///
/// `bytes` must hold all `out.len() * width` bits
/// (`debug_assert`-checked). Codes read past the end of a too-short
/// buffer silently decode as zero in release builds — callers validate
/// payload length up front (as the frame decoders do) rather than
/// relying on that.
#[inline]
pub fn unpack_fixed(bytes: &[u8], width: u32, out: &mut [u64]) {
    debug_assert!(width >= 1 && width <= 64);
    debug_assert!(
        bytes.len() >= (out.len() * width as usize).div_ceil(8),
        "unpack_fixed: {} bytes cannot hold {} {width}-bit codes (would zero-fill)",
        bytes.len(),
        out.len(),
    );
    if width > 57 {
        // Rare path (codes wider than 57 bits): two limbs via the scalar
        // reference reader.
        let mut r = BitReader::new(bytes);
        for slot in out.iter_mut() {
            *slot = r.get_wide(width);
        }
        return;
    }
    let mask = (1u64 << width) - 1;
    let mut acc = 0u64;
    let mut nb = 0u32;
    let mut ptr = 0usize;
    for slot in out.iter_mut() {
        if nb < width {
            // Whole-word refill: consume as many full bytes as fit. The
            // word's top bits that do NOT fit are still ORed in — they
            // are the true next stream bits, and the next refill rereads
            // the byte they came from, so the OR is idempotent.
            let w = word_at(bytes, ptr);
            acc |= w << nb;
            let took = (64 - nb) >> 3;
            ptr += took as usize;
            nb += took * 8;
        }
        *slot = acc & mask;
        acc >>= width;
        nb -= width;
    }
}

/// Scalar reference for [`unpack_fixed`] via [`BitReader`] (`get_wide`
/// per value). The executable layout spec for the property suite and the
/// `BENCH_codec.json` `speedup_vs_reference` baseline — not a hot path.
pub fn unpack_fixed_reference(bytes: &[u8], width: u32, out: &mut [u64]) {
    debug_assert!(width >= 1 && width <= 64);
    let mut r = BitReader::new(bytes);
    for slot in out.iter_mut() {
        *slot = r.get_wide(width);
    }
}

/// Little-endian primitive read/write helpers for frame headers.
pub mod le {
    use crate::{Error, Result};

    /// Append a `u32` little-endian.
    pub fn put_u32(out: &mut Vec<u8>, v: u32) {
        out.extend_from_slice(&v.to_le_bytes());
    }
    /// Append a `u64` little-endian.
    pub fn put_u64(out: &mut Vec<u8>, v: u64) {
        out.extend_from_slice(&v.to_le_bytes());
    }
    /// Append an `f64` little-endian.
    pub fn put_f64(out: &mut Vec<u8>, v: f64) {
        out.extend_from_slice(&v.to_le_bytes());
    }
    /// Append an `f32` little-endian.
    pub fn put_f32(out: &mut Vec<u8>, v: f32) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    /// Read a `u32` at `*pos`, advancing it.
    pub fn get_u32(buf: &[u8], pos: &mut usize) -> Result<u32> {
        let end = *pos + 4;
        let b = buf.get(*pos..end).ok_or_else(|| Error::corrupt("u32 past end"))?;
        *pos = end;
        Ok(u32::from_le_bytes(b.try_into().unwrap()))
    }
    /// Read a `u64` at `*pos`, advancing it.
    pub fn get_u64(buf: &[u8], pos: &mut usize) -> Result<u64> {
        let end = *pos + 8;
        let b = buf.get(*pos..end).ok_or_else(|| Error::corrupt("u64 past end"))?;
        *pos = end;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }
    /// Read an `f64` at `*pos`, advancing it.
    pub fn get_f64(buf: &[u8], pos: &mut usize) -> Result<f64> {
        let end = *pos + 8;
        let b = buf.get(*pos..end).ok_or_else(|| Error::corrupt("f64 past end"))?;
        *pos = end;
        Ok(f64::from_le_bytes(b.try_into().unwrap()))
    }
    /// Read an `f32` at `*pos`, advancing it.
    pub fn get_f32(buf: &[u8], pos: &mut usize) -> Result<f32> {
        let end = *pos + 4;
        let b = buf.get(*pos..end).ok_or_else(|| Error::corrupt("f32 past end"))?;
        *pos = end;
        Ok(f32::from_le_bytes(b.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_mixed_widths() {
        let mut w = BitWriter::with_capacity(64);
        let vals: Vec<(u64, u32)> = vec![
            (0b1, 1),
            (0b1011, 4),
            (0x7f, 7),
            (0x1_0000, 17),
            (0, 3),
            (0x1f_ffff, 21),
            ((1u64 << 57) - 1, 57),
        ];
        for &(v, n) in &vals {
            w.put(v, n);
        }
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for &(v, n) in &vals {
            assert_eq!(r.get(n), v, "width {n}");
        }
    }

    #[test]
    fn roundtrip_wide() {
        let mut w = BitWriter::with_capacity(64);
        let vals = [u64::MAX, 0, 1, 0xdead_beef_cafe_f00d];
        for &v in &vals {
            w.put_wide(v, 64);
        }
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for &v in &vals {
            assert_eq!(r.get_wide(64), v);
        }
    }

    #[test]
    fn align_is_byte_boundary() {
        let mut w = BitWriter::with_capacity(16);
        w.put(0b101, 3);
        w.align();
        w.put(0xab, 8);
        let buf = w.finish();
        assert_eq!(buf.len(), 2);
        assert_eq!(buf[1], 0xab);
        let mut r = BitReader::new(&buf);
        assert_eq!(r.get(3), 0b101);
        r.align();
        assert_eq!(r.get(8), 0xab);
    }

    #[test]
    fn zero_width_is_noop() {
        let mut w = BitWriter::with_capacity(4);
        w.put(0, 0);
        w.put(0b11, 2);
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        assert_eq!(r.get(0), 0);
        assert_eq!(r.get(2), 0b11);
    }

    #[test]
    fn pack_matches_reference_and_roundtrips() {
        let mut rng = crate::data::rng::Rng::new(5);
        for width in 1..=64u32 {
            let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
            for cnt in [1usize, 7, 32, 61] {
                let vals: Vec<u64> = (0..cnt).map(|_| rng.next_u64() & mask).collect();
                let mut fast = Vec::new();
                pack_fixed(&mut fast, &vals, width);
                let mut reference = Vec::new();
                pack_fixed_reference(&mut reference, &vals, width);
                assert_eq!(fast, reference, "width {width} cnt {cnt}");
                let mut dec = vec![0u64; cnt];
                unpack_fixed(&fast, width, &mut dec);
                assert_eq!(dec, vals, "width {width} cnt {cnt}");
                let mut dec_ref = vec![0u64; cnt];
                unpack_fixed_reference(&fast, width, &mut dec_ref);
                assert_eq!(dec_ref, vals, "width {width} cnt {cnt} (reference)");
            }
        }
    }

    #[test]
    fn pack_appends_after_existing_bytes() {
        let mut out = vec![0xEE, 0xFF];
        pack_fixed(&mut out, &[0b101, 0b011], 3);
        assert_eq!(&out[..2], &[0xEE, 0xFF]);
        let mut dec = [0u64; 2];
        unpack_fixed(&out[2..], 3, &mut dec);
        assert_eq!(dec, [0b101, 0b011]);
    }

    #[test]
    fn empty_input_emits_nothing() {
        let mut out = Vec::new();
        pack_fixed(&mut out, &[], 13);
        assert!(out.is_empty());
        unpack_fixed(&out, 13, &mut []);
    }

    #[test]
    fn le_roundtrip() {
        let mut out = Vec::new();
        le::put_u32(&mut out, 0xdeadbeef);
        le::put_u64(&mut out, 42);
        le::put_f64(&mut out, -1.5);
        le::put_f32(&mut out, 3.25);
        let mut pos = 0;
        assert_eq!(le::get_u32(&out, &mut pos).unwrap(), 0xdeadbeef);
        assert_eq!(le::get_u64(&out, &mut pos).unwrap(), 42);
        assert_eq!(le::get_f64(&out, &mut pos).unwrap(), -1.5);
        assert_eq!(le::get_f32(&out, &mut pos).unwrap(), 3.25);
        assert!(le::get_u32(&out, &mut pos).is_err());
    }
}
