//! ZFP-like 1-D block-transform baseline, in the two modes the paper
//! benchmarks against (Fig. 9): **fixed-rate** (`ZFP(FXR)`) and
//! **fixed-accuracy** (`ZFP(ABS)`).
//!
//! This is *not* a bit-exact ZFP reimplementation — the paper only needs it
//! as a losing baseline with (a) a real block *transform* (hence lower
//! throughput than the bitwise codecs), (b) a fixed-rate mode with
//! **unbounded** error, and (c) a fixed-accuracy mode with bounded error
//! but mediocre ratio. We use 64-value blocks with a full Haar lifting
//! pyramid (6 levels) followed by uniform scalar quantization of the
//! coefficients:
//!
//! - `ZfpFixedRate(rate)`: every coefficient gets `rate` bits against the
//!   block's coefficient range — the per-value error depends on the data
//!   and is NOT bounded (the paper's criticism of fixed-rate pipelines).
//! - `ZfpAbs(eb)`: the quantization step is chosen so the worst-case
//!   reconstruction error after the inverse transform stays within `eb`.
//!
//! ## Frame body layout
//!
//! ```text
//! u8  mode (0 = ABS, 1 = FXR)   u8 rate (FXR only; 0 otherwise)
//! u16 reserved
//! per 64-block: f32 lo, f32 hi (coefficient range), u8 bits,
//!               then 64 × `bits`-bit magnitudes (uniform code)
//! ```

use super::bits::{le, BitReader, BitWriter};
use super::traits::{
    read_header, write_header, CompressionStats, Compressor, CompressorKind, ErrorBound,
    HEADER_LEN,
};
use crate::{Error, Result};

/// Values per transform block.
pub const BLOCK: usize = 64;
/// Lifting levels (`log2(BLOCK)`).
const LEVELS: u32 = 6;

/// Forward Haar lifting pyramid in place (orthonormal-ish scaling kept
/// simple: s=(a+b)/2, d=(b-a)/2 — synthesis error grows by at most 1 per
/// level, which the ABS step accounts for).
fn fwd(block: &mut [f64; BLOCK]) {
    let mut half = BLOCK / 2;
    let mut tmp = [0.0f64; BLOCK];
    while half >= 1 {
        for i in 0..half {
            let a = block[2 * i];
            let b = block[2 * i + 1];
            tmp[i] = 0.5 * (a + b);
            tmp[half + i] = 0.5 * (b - a);
        }
        block[..2 * half].copy_from_slice(&tmp[..2 * half]);
        half /= 2;
    }
}

/// Inverse of [`fwd`].
fn inv(block: &mut [f64; BLOCK]) {
    let mut half = 1;
    let mut tmp = [0.0f64; BLOCK];
    while half <= BLOCK / 2 {
        for i in 0..half {
            let s = block[i];
            let d = block[half + i];
            tmp[2 * i] = s - d;
            tmp[2 * i + 1] = s + d;
        }
        block[..2 * half].copy_from_slice(&tmp[..2 * half]);
        half *= 2;
    }
}

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Abs,
    FixedRate(u8),
}

fn compress_impl(
    data: &[f32],
    eb_abs: f64,
    mode: Mode,
    bytes: &mut Vec<u8>,
) -> Result<CompressionStats> {
    let kind = match mode {
        Mode::Abs => CompressorKind::ZfpAbs,
        Mode::FixedRate(_) => CompressorKind::ZfpFixedRate,
    };
    let base = bytes.len();
    bytes.reserve(HEADER_LEN + 8 + data.len() * 2);
    write_header(bytes, kind, data.len(), eb_abs);
    match mode {
        Mode::Abs => {
            bytes.push(0);
            bytes.push(0);
        }
        Mode::FixedRate(r) => {
            bytes.push(1);
            bytes.push(r);
        }
    }
    bytes.extend_from_slice(&[0, 0]);

    // The ABS quantization step: each synthesis level can add the
    // coefficient error once, so divide the budget by (LEVELS + 1).
    let abs_step = 2.0 * eb_abs / (LEVELS as f64 + 1.0);

    let mut stats = CompressionStats { raw_bytes: data.len() * 4, ..Default::default() };
    let mut buf = [0.0f64; BLOCK];
    for chunk in data.chunks(BLOCK) {
        stats.blocks += 1;
        // Zero-pad the tail block (padding decodes but is dropped).
        for (i, slot) in buf.iter_mut().enumerate() {
            *slot = chunk.get(i).copied().unwrap_or(0.0) as f64;
        }
        fwd(&mut buf);
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for &c in buf.iter() {
            lo = lo.min(c);
            hi = hi.max(c);
        }
        // Round the range endpoints through f32 *before* computing the
        // scale so encoder and decoder agree bit-for-bit.
        let lo = lo as f32 as f64;
        let hi = hi as f32 as f64;
        let range = hi - lo;
        let bits: u32 = match mode {
            Mode::FixedRate(r) => r as u32,
            Mode::Abs => {
                if range <= abs_step {
                    0
                } else {
                    // 2^bits - 1 levels must make the step <= abs_step.
                    (((range / abs_step + 1.0).log2().ceil()) as u32).clamp(1, 32)
                }
            }
        };
        le::put_f32(bytes, lo as f32);
        le::put_f32(bytes, hi as f32);
        bytes.push(bits as u8);
        if bits == 0 {
            stats.constant_blocks += 1;
            continue;
        }
        let levels = (1u64 << bits) - 1;
        let scale = if range > 0.0 { levels as f64 / range } else { 0.0 };
        let mut w = BitWriter::with_capacity(BLOCK * bits as usize / 8 + 9);
        for &c in buf.iter() {
            let q = ((c - lo) * scale).round() as u64;
            w.put_wide(q.min(levels), bits);
        }
        bytes.extend_from_slice(&w.finish());
    }
    stats.compressed_bytes = bytes.len() - base;
    Ok(stats)
}

fn decompress_impl(bytes: &[u8], expect: CompressorKind, out: &mut Vec<f32>) -> Result<usize> {
    let h = read_header(bytes)?;
    if h.codec != expect {
        return Err(Error::corrupt("zfp frame codec mismatch"));
    }
    let mut pos = HEADER_LEN + 4; // skip mode/rate/reserved
    let nblocks = h.n.div_ceil(BLOCK);
    let start = out.len();
    out.reserve(nblocks * BLOCK);
    let mut buf = [0.0f64; BLOCK];
    for _ in 0..nblocks {
        let lo = le::get_f32(bytes, &mut pos)? as f64;
        let hi = le::get_f32(bytes, &mut pos)? as f64;
        let bits = *bytes.get(pos).ok_or_else(|| Error::corrupt("zfp bits past end"))? as u32;
        pos += 1;
        if bits == 0 {
            // The whole coefficient set lies within one quantization step:
            // every coefficient collapses to the midpoint (error <= range/2).
            let mid = 0.5 * (lo + hi);
            buf = [mid; BLOCK];
        } else {
            if bits > 32 {
                return Err(Error::corrupt("zfp bits > 32"));
            }
            let nbytes = (BLOCK * bits as usize).div_ceil(8);
            let end = pos + nbytes;
            if end > bytes.len() {
                return Err(Error::corrupt("zfp block past end"));
            }
            let levels = (1u64 << bits) - 1;
            let step = if levels > 0 { (hi - lo) / levels as f64 } else { 0.0 };
            let mut r = BitReader::new(&bytes[pos..end]);
            for slot in buf.iter_mut() {
                *slot = lo + r.get_wide(bits) as f64 * step;
            }
            pos = end;
        }
        inv(&mut buf);
        for &v in buf.iter() {
            out.push(v as f32);
        }
    }
    out.truncate(start + h.n);
    if out.len() - start != h.n {
        return Err(Error::corrupt("zfp short output"));
    }
    Ok(h.n)
}

/// Fixed-accuracy (error-bounded) mode.
#[derive(Debug, Clone, Default)]
pub struct ZfpAbs;

impl Compressor for ZfpAbs {
    fn kind(&self) -> CompressorKind {
        CompressorKind::ZfpAbs
    }
    fn compress_into(
        &self,
        data: &[f32],
        eb: ErrorBound,
        out: &mut Vec<u8>,
    ) -> Result<CompressionStats> {
        let eb_abs = eb.resolve(data);
        if !(eb_abs > 0.0) || !eb_abs.is_finite() {
            return Err(Error::invalid("error bound must be positive"));
        }
        compress_impl(data, eb_abs, Mode::Abs, out)
    }
    fn decompress_into(&self, bytes: &[u8], out: &mut Vec<f32>) -> Result<usize> {
        decompress_impl(bytes, CompressorKind::ZfpAbs, out)
    }
}

/// Fixed-rate mode: `rate` bits per value, error **not** bounded.
#[derive(Debug, Clone)]
pub struct ZfpFixedRate {
    /// Bits per value (1..=32).
    pub rate: u8,
}

impl Default for ZfpFixedRate {
    fn default() -> Self {
        ZfpFixedRate { rate: 8 }
    }
}

impl Compressor for ZfpFixedRate {
    fn kind(&self) -> CompressorKind {
        CompressorKind::ZfpFixedRate
    }
    fn compress_into(
        &self,
        data: &[f32],
        eb: ErrorBound,
        out: &mut Vec<u8>,
    ) -> Result<CompressionStats> {
        // The error bound is recorded but NOT honoured — fixed-rate mode is
        // the paper's counterexample.
        let eb_abs = eb.resolve(data);
        compress_impl(data, eb_abs, Mode::FixedRate(self.rate.clamp(1, 32)), out)
    }
    fn decompress_into(&self, bytes: &[u8], out: &mut Vec<f32>) -> Result<usize> {
        decompress_impl(bytes, CompressorKind::ZfpFixedRate, out)
    }
    fn is_error_bounded(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::fields::{Field, FieldKind};

    #[test]
    fn haar_roundtrip_exact() {
        let mut rng = crate::data::rng::Rng::new(5);
        let mut b = [0.0f64; BLOCK];
        for v in b.iter_mut() {
            *v = rng.normal();
        }
        let orig = b;
        fwd(&mut b);
        inv(&mut b);
        for (a, o) in b.iter().zip(orig.iter()) {
            assert!((a - o).abs() < 1e-12);
        }
    }

    #[test]
    fn abs_mode_is_error_bounded() {
        for kind in FieldKind::ALL {
            let f = Field::generate(kind, 10_000, 33);
            let eb = ErrorBound::Rel(1e-3).resolve(&f.values);
            let c = ZfpAbs.compress(&f.values, ErrorBound::Rel(1e-3)).unwrap();
            let d = ZfpAbs.decompress(&c.bytes).unwrap();
            for (i, (a, b)) in f.values.iter().zip(&d).enumerate() {
                let err = (*a as f64 - *b as f64).abs();
                assert!(err <= eb * 1.001 + 1e-6, "{kind:?} idx {i}: err {err} > {eb}");
            }
        }
    }

    #[test]
    fn fixed_rate_is_fixed_rate_but_unbounded() {
        let f = Field::generate(FieldKind::Nyx, 8192, 17);
        let c = ZfpFixedRate { rate: 4 }.compress(&f.values, ErrorBound::Abs(1e-12)).unwrap();
        // Rate ~4 bits/value + block headers.
        let bitrate = c.stats.bitrate();
        assert!(bitrate < 6.5, "bitrate {bitrate}");
        let d = ZfpFixedRate { rate: 4 }.decompress(&c.bytes).unwrap();
        // The absurd 1e-12 "bound" is definitely violated: fixed rate
        // cannot honour it.
        let max_err = f
            .values
            .iter()
            .zip(&d)
            .map(|(a, b)| (*a as f64 - *b as f64).abs())
            .fold(0.0f64, f64::max);
        assert!(max_err > 1e-12, "fixed-rate error should exceed the requested bound");
    }

    #[test]
    fn partial_tail_block() {
        let data: Vec<f32> = (0..100).map(|i| i as f32 * 0.5).collect();
        let c = ZfpAbs.compress(&data, ErrorBound::Abs(1e-2)).unwrap();
        let d = ZfpAbs.decompress(&c.bytes).unwrap();
        assert_eq!(d.len(), 100);
        for (a, b) in data.iter().zip(&d) {
            assert!((a - b).abs() <= 1e-2 * 1.01 + 1e-6);
        }
    }

    #[test]
    fn zfp_slower_path_has_lower_ratio_than_fzlight() {
        let f = Field::generate(FieldKind::Rtm, 1 << 15, 3);
        let eb = ErrorBound::Rel(1e-3);
        let z = ZfpAbs.compress(&f.values, eb).unwrap();
        let fz = crate::compress::FzLight::default().compress(&f.values, eb).unwrap();
        assert!(fz.stats.ratio() > z.stats.ratio());
    }
}
