//! Common compressor interface, frame header and error-bound modes.
//!
//! Every codec in this crate emits a self-describing frame:
//!
//! ```text
//! offset  size  field
//! 0       4     magic  = b"ZCCL"
//! 4       1     version = 1 (fixed-width body) or 2 (staged fZ-light body)
//! 5       1     codec   (CompressorKind discriminant)
//! 6       2     reserved
//! 8       8     element count (u64)
//! 16      8     absolute error bound actually used (f64; 0 for fixed-rate)
//! 24      ...   codec-specific body
//! ```
//!
//! The header makes [`crate::compress::decompress`] codec-agnostic, which
//! the collectives rely on: a rank can decode chunks produced by any peer
//! without out-of-band metadata.
//!
//! Version [`VERSION_STAGED`] marks the adaptive two-stage fZ-light
//! body (per-chunk plain / fixed-width / entropy selection — see
//! `compress::fzlight`); it is defined **only** for
//! [`CompressorKind::FzLight`], and [`read_header`] rejects the
//! combination of version 2 with any other codec centrally so no
//! downstream decoder needs its own check.

use super::bits::le;
use crate::ops::ReduceOp;
use crate::{Error, Result};

/// Frame magic bytes.
pub const MAGIC: [u8; 4] = *b"ZCCL";
/// Frame format version: fixed-width chunk payloads (every codec).
pub const VERSION: u8 = 1;
/// Frame format version: staged fZ-light chunk payloads — each chunk
/// carries a stage tag (plain / fixed-width / entropy-coded) ahead of
/// its body. fZ-light only; see `compress::fzlight` for the layout.
pub const VERSION_STAGED: u8 = 2;
/// Byte length of the common frame header.
pub const HEADER_LEN: usize = 24;

/// Receive-side density bound for [`VERSION_STAGED`] frames, replacing
/// the per-codec [`CompressorKind::max_values_per_byte`] in
/// [`checked_count`]: an entropy-coded chunk can beat fixed-width's
/// best case (an all-zero-delta chunk collapses to a 2-byte blob behind
/// a 5-byte stage header, ~730 values/byte at the default chunk size),
/// so a forged version-2 header gets this looser — but still frame-
/// proportional — cap before any buffer is sized from it. The staged
/// *encoder* enforces the same bound as a wire invariant (a chunk that
/// would exceed it ships fixed-width instead), so the guard never
/// rejects a legitimate frame.
pub const STAGED_MAX_VALUES_PER_BYTE: usize = 1024;

/// Error-bound specification, matching the paper's "fixed-accuracy" mode.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ErrorBound {
    /// Absolute bound: `|x - x̂| <= eb` for every element.
    Abs(f64),
    /// Value-range-relative bound: `eb_abs = rel * (max(x) - min(x))`.
    Rel(f64),
}

impl ErrorBound {
    /// Resolve to an absolute bound for the given data.
    ///
    /// A degenerate (constant or empty) input resolves a relative bound
    /// against a unit range so the bound stays positive.
    pub fn resolve(&self, data: &[f32]) -> f64 {
        match *self {
            ErrorBound::Abs(e) => e,
            ErrorBound::Rel(r) => {
                let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
                for &v in data {
                    lo = lo.min(v);
                    hi = hi.max(v);
                }
                let range = (hi - lo) as f64;
                if range.is_finite() && range > 0.0 {
                    r * range
                } else {
                    r
                }
            }
        }
    }
}

/// Codec identifiers (stored in the frame header).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CompressorKind {
    /// fZ-light / SZp: Lorenzo + quantization + bit-shifting encoding.
    FzLight,
    /// SZx: constant-block + fixed-length residual coding.
    Szx,
    /// ZFP-like block transform, fixed-accuracy (error-bounded) mode.
    ZfpAbs,
    /// ZFP-like block transform, fixed-rate mode (NOT error-bounded).
    ZfpFixedRate,
}

impl CompressorKind {
    /// All codecs, for sweep harnesses.
    pub const ALL: [CompressorKind; 4] = [
        CompressorKind::FzLight,
        CompressorKind::Szx,
        CompressorKind::ZfpAbs,
        CompressorKind::ZfpFixedRate,
    ];

    /// Frame-header discriminant.
    pub fn id(self) -> u8 {
        match self {
            CompressorKind::FzLight => 1,
            CompressorKind::Szx => 2,
            CompressorKind::ZfpAbs => 3,
            CompressorKind::ZfpFixedRate => 4,
        }
    }

    /// Inverse of [`CompressorKind::id`].
    pub fn from_id(id: u8) -> Result<Self> {
        Ok(match id {
            1 => CompressorKind::FzLight,
            2 => CompressorKind::Szx,
            3 => CompressorKind::ZfpAbs,
            4 => CompressorKind::ZfpFixedRate,
            _ => return Err(Error::corrupt(format!("unknown codec id {id}"))),
        })
    }

    /// Upper bound on how many values this codec can encode per frame
    /// body byte — the invariant [`checked_count`] enforces before a
    /// receiver sizes a destination from a frame header. Each bound
    /// lives here, next to the codec id, and leaves ~2× headroom over
    /// the encoder's actual best case; a codec change that beats its
    /// bound must raise it in the same commit.
    pub fn max_values_per_byte(self) -> usize {
        match self {
            // All-constant chunks: 1 tag byte per 32-value block
            // (≈32 v/B, amortizing the per-chunk outlier + table entry).
            CompressorKind::FzLight => 64,
            // Constant blocks: 5 bytes (tag + f32 mean) per 128 values
            // (≈25.6 v/B).
            CompressorKind::Szx => 64,
            // Best case: 9 bytes (lo, hi, bits=0) per 64-value block
            // (≈7.1 v/B).
            CompressorKind::ZfpAbs | CompressorKind::ZfpFixedRate => 16,
        }
    }

    /// Short display name used in benchmark tables.
    pub fn name(self) -> &'static str {
        match self {
            CompressorKind::FzLight => "fZ-light",
            CompressorKind::Szx => "SZx",
            CompressorKind::ZfpAbs => "ZFP(ABS)",
            CompressorKind::ZfpFixedRate => "ZFP(FXR)",
        }
    }
}

impl std::str::FromStr for CompressorKind {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "fzlight" | "fz-light" | "fz" | "szp" => CompressorKind::FzLight,
            "szx" => CompressorKind::Szx,
            "zfp-abs" | "zfpabs" => CompressorKind::ZfpAbs,
            "zfp-fxr" | "zfpfixedrate" | "zfp" => CompressorKind::ZfpFixedRate,
            other => return Err(Error::invalid(format!("unknown compressor '{other}'"))),
        })
    }
}

/// Per-compression statistics (Table 3 reports ratio + constant-block %).
#[derive(Debug, Clone, Copy, Default)]
pub struct CompressionStats {
    /// Total small blocks examined.
    pub blocks: usize,
    /// Blocks encoded as "constant" (code length 0 / within-bound).
    pub constant_blocks: usize,
    /// Input bytes.
    pub raw_bytes: usize,
    /// Output bytes (whole frame, header included).
    pub compressed_bytes: usize,
    /// Chunks examined by the staged (version-2) fZ-light encoder; zero
    /// for version-1 frames and non-fZ-light codecs.
    pub chunks: usize,
    /// Staged chunks that shipped an entropy-coded body.
    pub entropy_chunks: usize,
    /// Staged chunks that shipped raw `f32` values (fixed-width would
    /// have expanded them).
    pub plain_chunks: usize,
}

impl CompressionStats {
    /// Compression ratio `raw/compressed`.
    pub fn ratio(&self) -> f64 {
        if self.compressed_bytes == 0 {
            0.0
        } else {
            self.raw_bytes as f64 / self.compressed_bytes as f64
        }
    }
    /// Fraction of constant blocks in `[0, 1]`.
    pub fn constant_fraction(&self) -> f64 {
        if self.blocks == 0 {
            0.0
        } else {
            self.constant_blocks as f64 / self.blocks as f64
        }
    }
    /// Bit rate in bits per value (the paper plots `32 / ratio`).
    pub fn bitrate(&self) -> f64 {
        if self.raw_bytes == 0 {
            0.0
        } else {
            self.compressed_bytes as f64 * 8.0 / (self.raw_bytes as f64 / 4.0)
        }
    }
    /// Merge statistics from another (e.g. per-chunk) compression.
    pub fn merge(&mut self, other: &CompressionStats) {
        self.blocks += other.blocks;
        self.constant_blocks += other.constant_blocks;
        self.raw_bytes += other.raw_bytes;
        self.compressed_bytes += other.compressed_bytes;
        self.chunks += other.chunks;
        self.entropy_chunks += other.entropy_chunks;
        self.plain_chunks += other.plain_chunks;
    }
}

/// A compressed frame plus its statistics.
#[derive(Debug, Clone)]
pub struct Compressed {
    /// Self-describing frame (header + body).
    pub bytes: Vec<u8>,
    /// Statistics gathered while compressing.
    pub stats: CompressionStats,
}

/// The compressor interface shared by all codecs.
///
/// The required methods are the **zero-alloc** `*_into` variants: they
/// write into caller-owned buffers so repeated collectives (e.g. a DDP
/// training loop driving [`crate::collectives::CollCtx`]) can recycle
/// scratch storage instead of paying allocator traffic per call. The
/// allocating [`Compressor::compress`] / [`Compressor::decompress`] are
/// default-impl conveniences layered on top.
pub trait Compressor: Send + Sync {
    /// Codec identifier.
    fn kind(&self) -> CompressorKind;

    /// Compress `data` under the given error bound, **appending** the
    /// self-describing frame to `out`. Callers reusing a scratch buffer
    /// should `clear()` it first; append semantics let several frames be
    /// packed back to back (as the scatter/gather bundles do).
    fn compress_into(&self, data: &[f32], eb: ErrorBound, out: &mut Vec<u8>)
        -> Result<CompressionStats>;

    /// Decompress a frame, **appending** the decoded values to `out` and
    /// returning how many were appended. Callers reusing a scratch buffer
    /// should `clear()` it first.
    fn decompress_into(&self, bytes: &[u8], out: &mut Vec<f32>) -> Result<usize>;

    /// **Placement decode**: reconstruct the frame's values directly at
    /// their final positions in `out`, returning the element count —
    /// the movement collectives' receive kernel. `out.len()` must equal
    /// the frame's element count (the caller carves the destination
    /// window out of the assembled output). Pairing this with a pooled
    /// [`crate::transport::Transport::recv_into`] makes the receive path
    /// copy-free: wire bytes land once, decoded values land once.
    ///
    /// The default implementation is decompress-then-copy, correct for
    /// every codec. Codecs whose frame layout permits it (fZ-light and
    /// its pipelined / multithreaded wrappers) override it with a true
    /// in-place kernel — each chunk decodes straight into its disjoint
    /// window — and advertise that via
    /// [`Compressor::supports_placement_decode`].
    ///
    /// # Error semantics
    ///
    /// On `Err`, `out` may already contain decoded values from an
    /// unspecified subset of the frame's chunks (a prefix for the serial
    /// kernels; any subset for the multithreaded one). Callers must treat
    /// the window as poisoned and discard it (the collectives abandon the
    /// whole call).
    fn decompress_into_slice(&self, bytes: &[u8], out: &mut [f32]) -> Result<usize> {
        let mut tmp = Vec::with_capacity(out.len());
        let n = self.decompress_into(bytes, &mut tmp)?;
        if n != out.len() {
            return Err(Error::invalid(format!(
                "placement decode: frame holds {n} values but destination holds {}",
                out.len()
            )));
        }
        out.copy_from_slice(&tmp);
        Ok(n)
    }

    /// Whether [`Compressor::decompress_into_slice`] is a native in-place
    /// kernel (`true`) or the decompress-then-copy default (`false`). The
    /// collective layer routes codecs without a native kernel through its
    /// pooled scratch instead of the default impl's per-call temporary.
    fn supports_placement_decode(&self) -> bool {
        false
    }

    /// Decode a frame and fold every reconstructed value straight into
    /// `acc` (`acc[i] = op(acc[i], x̂[i])`), returning the element count —
    /// the **fused decompress–reduce kernel** the reduction collectives
    /// run on their receive side (paper §3.4–§3.5, Fig. 4). `acc.len()`
    /// must equal the frame's element count.
    ///
    /// The default implementation is decompress-then-fold, correct for
    /// every codec. Codecs whose frame layout permits it (fZ-light and
    /// its pipelined / multithreaded wrappers) override it with a true
    /// single-pass kernel — constant blocks fold as one broadcast over
    /// the run with no per-value decode — and advertise that via
    /// [`Compressor::supports_fused_fold`].
    ///
    /// # Error semantics
    ///
    /// On `Err`, `acc` may already contain folded contributions from an
    /// unspecified subset of the frame's chunks (a prefix for the serial
    /// kernels; any subset for the multithreaded one) — each slot is
    /// either untouched or folded exactly once. Callers must treat the
    /// accumulator as poisoned and discard it (the collectives abandon
    /// the whole call).
    fn decompress_fold_into(&self, bytes: &[u8], op: ReduceOp, acc: &mut [f32]) -> Result<usize> {
        let mut tmp = Vec::with_capacity(acc.len());
        let n = self.decompress_into(bytes, &mut tmp)?;
        if n != acc.len() {
            return Err(Error::invalid(format!(
                "fused fold: frame holds {n} values but accumulator holds {}",
                acc.len()
            )));
        }
        op.fold(acc, &tmp);
        Ok(n)
    }

    /// Whether [`Compressor::decompress_fold_into`] is a native
    /// single-pass kernel (`true`) or the decompress-then-fold default
    /// (`false`). The collective layer routes codecs without a native
    /// kernel through its pooled scratch instead of the default's
    /// per-call temporary.
    fn supports_fused_fold(&self) -> bool {
        false
    }

    /// Compress `data` into a freshly allocated frame.
    fn compress(&self, data: &[f32], eb: ErrorBound) -> Result<Compressed> {
        let mut bytes = Vec::new();
        let stats = self.compress_into(data, eb, &mut bytes)?;
        Ok(Compressed { bytes, stats })
    }

    /// Decompress a frame into a freshly allocated vector.
    fn decompress(&self, bytes: &[u8]) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.decompress_into(bytes, &mut out)?;
        Ok(out)
    }

    /// Whether the codec honours the error bound (`ZfpFixedRate` does not —
    /// that is exactly the paper's criticism of fixed-rate baselines).
    fn is_error_bounded(&self) -> bool {
        true
    }
}

/// Write the common frame header at [`VERSION`] (fixed-width body).
pub fn write_header(out: &mut Vec<u8>, codec: CompressorKind, n: usize, eb_abs: f64) {
    write_header_with_version(out, codec, n, eb_abs, VERSION);
}

/// Write the common frame header with an explicit format version
/// ([`VERSION`] or [`VERSION_STAGED`]).
pub fn write_header_with_version(
    out: &mut Vec<u8>,
    codec: CompressorKind,
    n: usize,
    eb_abs: f64,
    version: u8,
) {
    debug_assert!(version == VERSION || version == VERSION_STAGED);
    debug_assert!(version != VERSION_STAGED || codec == CompressorKind::FzLight);
    out.extend_from_slice(&MAGIC);
    out.push(version);
    out.push(codec.id());
    out.extend_from_slice(&[0, 0]);
    le::put_u64(out, n as u64);
    le::put_f64(out, eb_abs);
}

/// Parsed frame header.
#[derive(Debug, Clone, Copy)]
pub struct Header {
    /// Frame format version ([`VERSION`] or [`VERSION_STAGED`]).
    pub version: u8,
    /// Codec that produced the frame.
    pub codec: CompressorKind,
    /// Element count.
    pub n: usize,
    /// Absolute error bound used at compression time.
    pub eb_abs: f64,
}

/// Parse and validate the common frame header. Accepts [`VERSION`] for
/// every codec and [`VERSION_STAGED`] for fZ-light only — the staged
/// body is an fZ-light layout, so any other codec id under version 2 is
/// a forgery and is rejected here, once, for all decoders.
pub fn read_header(bytes: &[u8]) -> Result<Header> {
    if bytes.len() < HEADER_LEN {
        return Err(Error::corrupt("frame shorter than header"));
    }
    if bytes[0..4] != MAGIC {
        return Err(Error::corrupt("bad magic"));
    }
    let version = bytes[4];
    if version != VERSION && version != VERSION_STAGED {
        return Err(Error::corrupt(format!("unsupported version {version}")));
    }
    let codec = CompressorKind::from_id(bytes[5])?;
    if version == VERSION_STAGED && codec != CompressorKind::FzLight {
        return Err(Error::corrupt(format!(
            "staged frame version {VERSION_STAGED} is defined only for fZ-light, got {codec:?}"
        )));
    }
    let mut pos = 8;
    let n = le::get_u64(bytes, &mut pos)? as usize;
    let eb_abs = le::get_f64(bytes, &mut pos)?;
    Ok(Header { version, codec, n, eb_abs })
}

/// Peek the codec of a frame without decoding it.
pub fn peek_codec(bytes: &[u8]) -> Result<CompressorKind> {
    Ok(read_header(bytes)?.codec)
}

/// Parse the header and sanity-check its element count against the
/// frame's *physical* size, for callers that size a destination buffer
/// **before** decoding: a corrupt or forged header claiming billions of
/// values in a tiny frame is rejected here (cheaply, like PR 2's
/// `validate_frame_count`) instead of committing pages for a bogus
/// length. The density bound dispatches on the header's version: the
/// codec's own [`CompressorKind::max_values_per_byte`] for version-1
/// frames, [`STAGED_MAX_VALUES_PER_BYTE`] for staged frames (whose
/// entropy chunks pack denser than any fixed-width body can);
/// codec-specific decoders still run their exact validation.
pub fn checked_count(bytes: &[u8]) -> Result<usize> {
    let h = read_header(bytes)?;
    let density = if h.version == VERSION_STAGED {
        STAGED_MAX_VALUES_PER_BYTE
    } else {
        h.codec.max_values_per_byte()
    };
    let cap = bytes.len().saturating_sub(HEADER_LEN).saturating_mul(density);
    if h.n > cap {
        return Err(Error::corrupt(format!(
            "frame claims {} values but its {} bytes can hold at most {cap}",
            h.n,
            bytes.len()
        )));
    }
    Ok(h.n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_roundtrip() {
        let mut out = Vec::new();
        write_header(&mut out, CompressorKind::Szx, 12345, 1e-4);
        let h = read_header(&out).unwrap();
        assert_eq!(h.version, VERSION);
        assert_eq!(h.codec, CompressorKind::Szx);
        assert_eq!(h.n, 12345);
        assert_eq!(h.eb_abs, 1e-4);
    }

    #[test]
    fn staged_header_roundtrip_and_codec_restriction() {
        let mut out = Vec::new();
        write_header_with_version(&mut out, CompressorKind::FzLight, 77, 1e-3, VERSION_STAGED);
        let h = read_header(&out).unwrap();
        assert_eq!(h.version, VERSION_STAGED);
        assert_eq!(h.codec, CompressorKind::FzLight);
        assert_eq!(h.n, 77);
        // Version 2 is defined only for fZ-light: forging any other
        // codec id under it must fail at the header, before a decoder
        // ever sees the body.
        for kind in [CompressorKind::Szx, CompressorKind::ZfpAbs, CompressorKind::ZfpFixedRate] {
            let mut forged = out.clone();
            forged[5] = kind.id();
            assert!(read_header(&forged).is_err(), "{kind:?} under version 2 must be rejected");
        }
        // Versions other than 1 and 2 stay rejected.
        let mut bad = out.clone();
        bad[4] = 3;
        assert!(read_header(&bad).is_err());
    }

    #[test]
    fn header_rejects_garbage() {
        assert!(read_header(b"nope").is_err());
        let mut out = Vec::new();
        write_header(&mut out, CompressorKind::FzLight, 1, 1.0);
        out[0] = b'X';
        assert!(read_header(&out).is_err());
        let mut out2 = Vec::new();
        write_header(&mut out2, CompressorKind::FzLight, 1, 1.0);
        out2[5] = 99; // bad codec id
        assert!(read_header(&out2).is_err());
    }

    #[test]
    fn relative_bound_resolves_to_range() {
        let data = vec![0.0f32, 10.0, 5.0];
        let eb = ErrorBound::Rel(1e-2).resolve(&data);
        assert!((eb - 0.1).abs() < 1e-12);
        // Degenerate range falls back to the raw relative value.
        let flat = vec![3.0f32; 8];
        assert_eq!(ErrorBound::Rel(1e-2).resolve(&flat), 1e-2);
        assert_eq!(ErrorBound::Abs(0.5).resolve(&data), 0.5);
    }

    #[test]
    fn checked_count_rejects_counts_the_frame_cannot_hold() {
        // A tiny frame claiming a billion values must fail before any
        // caller sizes a destination from it.
        let mut forged = Vec::new();
        write_header(&mut forged, CompressorKind::FzLight, 1_000_000_000, 1e-3);
        forged.extend_from_slice(&[0u8; 16]);
        assert!(checked_count(&forged).is_err());
        // Plausible densities pass (64 values over 8 body bytes is within
        // even the all-constant-block bound).
        let mut ok = Vec::new();
        write_header(&mut ok, CompressorKind::Szx, 64, 1e-3);
        ok.extend_from_slice(&[0u8; 8]);
        assert_eq!(checked_count(&ok).unwrap(), 64);
        // Empty frames are fine.
        let mut empty = Vec::new();
        write_header(&mut empty, CompressorKind::FzLight, 0, 1e-3);
        assert_eq!(checked_count(&empty).unwrap(), 0);
        // The bound dispatches on the header's codec: 1000 values over 16
        // body bytes is plausible for fZ-light (≤ 64 v/B) but impossible
        // for the transform-based ZFP (≤ 16 v/B).
        let mut fz = Vec::new();
        write_header(&mut fz, CompressorKind::FzLight, 1000, 1e-3);
        fz.extend_from_slice(&[0u8; 16]);
        assert_eq!(checked_count(&fz).unwrap(), 1000);
        let mut zfp = Vec::new();
        write_header(&mut zfp, CompressorKind::ZfpAbs, 1000, 1e-3);
        zfp.extend_from_slice(&[0u8; 16]);
        assert!(checked_count(&zfp).is_err());
    }

    #[test]
    fn staged_checked_count_uses_entropy_density_bound() {
        // A staged frame legitimately packs denser than fixed-width: 700
        // values over 16 body bytes exceeds fZ-light's version-1 bound
        // (64 v/B) but is within the staged bound (1024 v/B).
        let mut ok = Vec::new();
        write_header_with_version(&mut ok, CompressorKind::FzLight, 700, 1e-3, VERSION_STAGED);
        ok.extend_from_slice(&[0u8; 16]);
        assert_eq!(checked_count(&ok).unwrap(), 700);
        // The same claim under version 1 is rejected — the looser bound
        // applies only to frames that announce the staged layout.
        let mut v1 = Vec::new();
        write_header(&mut v1, CompressorKind::FzLight, 700, 1e-3);
        v1.extend_from_slice(&[0u8; 16]);
        assert!(checked_count(&v1).is_err());
        // And a staged header is still frame-proportional: a forged
        // count past even the entropy density fails before any caller
        // sizes a destination (the PR 3 guard, version-2 edition).
        let mut forged = Vec::new();
        write_header_with_version(
            &mut forged,
            CompressorKind::FzLight,
            1_000_000_000,
            1e-3,
            VERSION_STAGED,
        );
        forged.extend_from_slice(&[0u8; 16]);
        assert!(checked_count(&forged).is_err());
    }

    #[test]
    fn kind_ids_roundtrip() {
        for k in CompressorKind::ALL {
            assert_eq!(CompressorKind::from_id(k.id()).unwrap(), k);
        }
    }
}
