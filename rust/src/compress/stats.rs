//! Compression-quality metrics and error-distribution tooling.
//!
//! Backs Table 4 (NRMSE + std), Figure 7 (rate-distortion: bitrate vs
//! PSNR), and Figures 5–6 (compression errors are ~normally distributed,
//! verified with a moment-based MLE fit).

/// Pointwise reconstruction-quality metrics between `orig` and `dec`.
#[derive(Debug, Clone, Copy)]
pub struct Quality {
    /// Root mean square error.
    pub rmse: f64,
    /// RMSE normalised by the value range (Table 4's NRMSE).
    pub nrmse: f64,
    /// Standard deviation of the pointwise absolute error (Table 4's STD).
    pub err_std: f64,
    /// Peak signal-to-noise ratio in dB (Fig. 7's y-axis).
    pub psnr: f64,
    /// Maximum absolute error (must stay <= eb for bounded codecs).
    pub max_err: f64,
    /// Value range of the original data.
    pub range: f64,
}

/// Compute [`Quality`] between original and reconstructed data.
pub fn quality(orig: &[f32], dec: &[f32]) -> Quality {
    assert_eq!(orig.len(), dec.len(), "length mismatch");
    let n = orig.len().max(1) as f64;
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    let mut sq = 0.0f64;
    let mut sum_abs = 0.0f64;
    let mut sum_abs2 = 0.0f64;
    let mut max_err = 0.0f64;
    for (&a, &b) in orig.iter().zip(dec) {
        let a = a as f64;
        let e = a - b as f64;
        lo = lo.min(a);
        hi = hi.max(a);
        sq += e * e;
        let ae = e.abs();
        sum_abs += ae;
        sum_abs2 += ae * ae;
        max_err = max_err.max(ae);
    }
    let range = if orig.is_empty() { 0.0 } else { hi - lo };
    let rmse = (sq / n).sqrt();
    let mean_abs = sum_abs / n;
    let var_abs = (sum_abs2 / n - mean_abs * mean_abs).max(0.0);
    Quality {
        rmse,
        nrmse: if range > 0.0 { rmse / range } else { 0.0 },
        err_std: var_abs.sqrt(),
        psnr: if rmse > 0.0 && range > 0.0 {
            20.0 * (range / rmse).log10()
        } else {
            f64::INFINITY
        },
        max_err,
        range,
    }
}

/// Histogram of signed pointwise errors with a Gaussian MLE fit
/// (Figures 5–6: compression errors follow ~N(μ, σ²) within ±ê).
#[derive(Debug, Clone)]
pub struct ErrorHistogram {
    /// Bin left edges (uniform width).
    pub edges: Vec<f64>,
    /// Normalised density per bin.
    pub density: Vec<f64>,
    /// MLE mean of the errors.
    pub mu: f64,
    /// MLE standard deviation of the errors.
    pub sigma: f64,
    /// Goodness of fit: sup-norm distance between the empirical CDF and
    /// the fitted normal CDF (a Kolmogorov–Smirnov statistic).
    pub ks: f64,
    /// Excess kurtosis (0 for a perfect normal).
    pub excess_kurtosis: f64,
}

/// Build an [`ErrorHistogram`] from original/reconstructed data.
pub fn error_histogram(orig: &[f32], dec: &[f32], bins: usize) -> ErrorHistogram {
    assert_eq!(orig.len(), dec.len());
    let mut errs: Vec<f64> =
        orig.iter().zip(dec).map(|(&a, &b)| a as f64 - b as f64).collect();
    let n = errs.len().max(1) as f64;
    let mu = errs.iter().sum::<f64>() / n;
    let var = errs.iter().map(|e| (e - mu) * (e - mu)).sum::<f64>() / n;
    let sigma = var.sqrt();
    let m4 = errs.iter().map(|e| (e - mu).powi(4)).sum::<f64>() / n;
    let excess_kurtosis = if var > 0.0 { m4 / (var * var) - 3.0 } else { 0.0 };

    let (lo, hi) = errs
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &e| (l.min(e), h.max(e)));
    let width = ((hi - lo) / bins as f64).max(f64::MIN_POSITIVE);
    let mut counts = vec![0usize; bins];
    for &e in &errs {
        let b = (((e - lo) / width) as usize).min(bins - 1);
        counts[b] += 1;
    }
    let density: Vec<f64> = counts.iter().map(|&c| c as f64 / (n * width)).collect();
    let edges: Vec<f64> = (0..bins).map(|i| lo + i as f64 * width).collect();

    // KS statistic against N(mu, sigma).
    errs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut ks = 0.0f64;
    if sigma > 0.0 {
        for (i, &e) in errs.iter().enumerate() {
            let f = normal_cdf((e - mu) / sigma);
            let emp_hi = (i + 1) as f64 / n;
            let emp_lo = i as f64 / n;
            ks = ks.max((f - emp_lo).abs()).max((f - emp_hi).abs());
        }
    }
    ErrorHistogram { edges, density, mu, sigma, ks, excess_kurtosis }
}

/// Standard normal CDF via the Abramowitz–Stegun erf approximation
/// (max abs error ~1.5e-7 — plenty for a KS statistic).
pub fn normal_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

fn erf(x: f64) -> f64 {
    let s = x.signum();
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    s * y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, ErrorBound, FzLight};
    use crate::data::fields::{Field, FieldKind};
    use crate::data::rng::Rng;

    #[test]
    fn quality_identity_is_perfect() {
        let x = vec![1.0f32, 2.0, 3.0];
        let q = quality(&x, &x);
        assert_eq!(q.rmse, 0.0);
        assert_eq!(q.max_err, 0.0);
        assert!(q.psnr.is_infinite());
    }

    #[test]
    fn quality_known_values() {
        let a = vec![0.0f32, 1.0];
        let b = vec![0.5f32, 1.0];
        let q = quality(&a, &b);
        assert!((q.rmse - (0.125f64).sqrt()).abs() < 1e-12);
        assert!((q.nrmse - (0.125f64).sqrt()).abs() < 1e-12);
        assert_eq!(q.max_err, 0.5);
    }

    #[test]
    fn erf_sane() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((normal_cdf(1.96) - 0.975).abs() < 1e-3);
        assert!((normal_cdf(-1.96) - 0.025).abs() < 1e-3);
    }

    #[test]
    fn gaussian_sample_fits() {
        let mut rng = Rng::new(3);
        let orig: Vec<f32> = (0..50_000).map(|_| rng.normal() as f32).collect();
        let dec: Vec<f32> =
            orig.iter().map(|&v| v + 0.01 * rng.normal() as f32).collect();
        let h = error_histogram(&orig, &dec, 64);
        assert!(h.mu.abs() < 1e-3);
        assert!((h.sigma - 0.01).abs() < 1e-3, "sigma {}", h.sigma);
        assert!(h.ks < 0.02, "ks {}", h.ks);
        assert!(h.excess_kurtosis.abs() < 0.2);
    }

    #[test]
    fn fig5_fzlight_errors_are_normal_ish() {
        // The paper's Fig. 5 premise: compression errors on real-ish fields
        // fit a normal curve well. Verify the KS distance is small.
        let f = Field::generate(FieldKind::Cesm, 1 << 16, 6);
        let eb = ErrorBound::Rel(1e-3);
        let c = FzLight::default().compress(&f.values, eb).unwrap();
        let d = FzLight::default().decompress(&c.bytes).unwrap();
        let h = error_histogram(&f.values, &d, 64);
        // Quantization errors are bounded and roughly symmetric.
        let ebv = eb.resolve(&f.values);
        assert!(h.mu.abs() < 0.2 * ebv);
        assert!(h.sigma < ebv);
        assert!(h.ks < 0.15, "ks {}", h.ks);
    }
}
