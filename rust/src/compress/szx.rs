//! SZx-style error-bounded lossy compressor (the C-Coll baseline's codec).
//!
//! Algorithm (paper §3.3, following Yu et al. HPDC'22): the input is split
//! into 128-value blocks. Per block the mid-range mean
//! `μ = (min + max) / 2` is computed; if every value lies within
//! `(μ − eb, μ + eb)` the block is a **constant block** stored as `μ`
//! alone (this flattening is what produces the Fig. 8 stripe artifacts).
//! Otherwise the block is **non-constant**: the residuals `x − μ` are
//! quantized with step `2·eb` and stored with sign bits + fixed-length
//! magnitudes — a bitwise-cheap stand-in for SZx's IEEE-754
//! leading-zero analysis with identical error behaviour (`|x − x̂| <= eb`).
//!
//! Unlike fZ-light there is **no Lorenzo prediction**: coding operates on
//! raw residuals, so smooth data compresses noticeably worse (Table 3) —
//! exactly the property the paper's compressor study turns on.
//!
//! ## Frame body layout (after the common header)
//!
//! ```text
//! u32 chunk_values
//! u32 nchunks
//! u32 chunk_bytes[nchunks]
//! u8  payload[...]
//! ```
//!
//! Chunk payloads hold a sequence of blocks:
//! `u8 tag (0 = constant, else code length L)`, `f32 μ`, and for
//! non-constant blocks `ceil(cnt/8)` sign bytes + `cnt` `L`-bit magnitudes.

use super::bits::le;
use super::traits::{
    read_header, write_header, CompressionStats, Compressor, CompressorKind, ErrorBound,
    HEADER_LEN,
};
use crate::{Error, Result};

/// Values per SZx block (the reference implementation's default).
pub const BLOCK: usize = 128;
/// Default values per chunk (multithread/pipeline granularity).
pub const DEFAULT_CHUNK: usize = 5120;

/// The SZx-style compressor.
#[derive(Debug, Clone)]
pub struct Szx {
    /// Values per chunk.
    pub chunk_values: usize,
}

impl Default for Szx {
    fn default() -> Self {
        Szx { chunk_values: DEFAULT_CHUNK }
    }
}

impl Szx {
    /// Construct with an explicit chunk size (values).
    pub fn with_chunk(chunk_values: usize) -> Self {
        assert!(chunk_values > 0);
        Szx { chunk_values }
    }
}

/// Compress one chunk into a fresh payload vector (the multithread path
/// needs independently owned payloads).
pub(crate) fn compress_chunk(data: &[f32], eb: f64) -> (Vec<u8>, usize, usize) {
    let mut payload = Vec::with_capacity(8 + data.len());
    let (blocks, constant) = compress_chunk_into(data, eb, &mut payload);
    (payload, blocks, constant)
}

/// Compress one chunk, appending to `payload`. Returns
/// (blocks, constant_blocks).
///
/// Hot path (tracked by `benches/compressors.rs` / `BENCH_codec.json`):
/// per block the min/max, residual-quantize, and sign/magnitude stages
/// run as separate straight-line loops, and the magnitudes spill through
/// the word-parallel [`super::bits::pack_fixed`] — zero allocations per
/// block.
pub(crate) fn compress_chunk_into(data: &[f32], eb: f64, payload: &mut Vec<u8>) -> (usize, usize) {
    let twoeb = 2.0 * eb;
    let inv = 1.0 / twoeb;
    payload.reserve(8 + data.len());
    let mut blocks = 0usize;
    let mut constant = 0usize;
    let mut qs = [0i64; BLOCK];
    let mut mags = [0u64; BLOCK];
    for block in data.chunks(BLOCK) {
        blocks += 1;
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &v in block {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        let mu = (lo as f64 + hi as f64) * 0.5;
        if (hi as f64 - mu) <= eb {
            payload.push(0u8);
            payload.extend_from_slice(&(mu as f32).to_le_bytes());
            constant += 1;
            continue;
        }
        // Non-constant: quantize the residuals in one pass, then derive
        // signs / magnitudes / running max in a second.
        for (slot, &v) in qs.iter_mut().zip(block) {
            *slot = ((v as f64 - mu) * inv).round() as i64;
        }
        let mut maxmag: u64 = 0;
        let mut sign = 0u128; // BLOCK = 128 sign bits
        for j in 0..block.len() {
            mags[j] = qs[j].unsigned_abs();
            sign |= u128::from(qs[j] < 0) << j;
            maxmag |= mags[j];
        }
        let bits = (64 - maxmag.leading_zeros()).max(1);
        payload.push(bits as u8);
        payload.extend_from_slice(&(mu as f32).to_le_bytes());
        payload.extend_from_slice(&sign.to_le_bytes()[..block.len().div_ceil(8)]);
        super::bits::pack_fixed(payload, &mags[..block.len()], bits);
    }
    (blocks, constant)
}

/// Decompress one chunk of `cn` values into `out`.
///
/// Block-batched like the fZ-light walk: magnitudes unpack into a stack
/// array via the word-parallel [`super::bits::unpack_fixed`], signs
/// apply branchlessly, dequantization is one multiply pass, and the
/// decoded block lands in `out` as a single `extend_from_slice`.
pub(crate) fn decompress_chunk(
    payload: &[u8],
    cn: usize,
    eb: f64,
    out: &mut Vec<f32>,
) -> Result<()> {
    let twoeb = 2.0 * eb;
    let mut pos = 0usize;
    let mut remaining = cn;
    let mut mags = [0u64; BLOCK];
    let mut vals = [0f32; BLOCK];
    while remaining > 0 {
        let cnt = BLOCK.min(remaining);
        let tag = *payload
            .get(pos)
            .ok_or_else(|| Error::corrupt("szx block tag past end"))? as u32;
        pos += 1;
        let mu = le::get_f32(payload, &mut pos)? as f64;
        if tag == 0 {
            out.resize(out.len() + cnt, mu as f32);
        } else {
            if tag > 64 {
                return Err(Error::corrupt(format!("szx code length {tag} > 64")));
            }
            let sign_bytes = cnt.div_ceil(8);
            let mag_bytes = (cnt * tag as usize).div_ceil(8);
            let end = pos + sign_bytes + mag_bytes;
            if end > payload.len() {
                return Err(Error::corrupt("szx block body past end"));
            }
            let mut sign = 0u128;
            for (k, &byte) in payload[pos..pos + sign_bytes].iter().enumerate() {
                sign |= (byte as u128) << (8 * k);
            }
            super::bits::unpack_fixed(&payload[pos + sign_bytes..end], tag, &mut mags[..cnt]);
            // Branchless sign application (m is 0 or -1); wrapping so a
            // corrupt 2^63 magnitude cannot panic a debug build.
            for j in 0..cnt {
                let m = -(((sign >> j) & 1) as i64);
                let q = (mags[j] as i64 ^ m).wrapping_sub(m);
                vals[j] = (mu + q as f64 * twoeb) as f32;
            }
            out.extend_from_slice(&vals[..cnt]);
            pos = end;
        }
        remaining -= cnt;
    }
    Ok(())
}

impl Compressor for Szx {
    fn kind(&self) -> CompressorKind {
        CompressorKind::Szx
    }

    fn compress_into(
        &self,
        data: &[f32],
        eb: ErrorBound,
        out: &mut Vec<u8>,
    ) -> Result<CompressionStats> {
        let eb_abs = eb.resolve(data);
        if !(eb_abs > 0.0) || !eb_abs.is_finite() {
            return Err(Error::invalid(format!("error bound must be positive, got {eb_abs}")));
        }
        // Same backfilled-chunk-table trick as fZ-light: the table length
        // is known up front, so the frame is built in place with zero
        // intermediate allocations.
        let chunk = self.chunk_values.max(1);
        let nchunks = data.len().div_ceil(chunk);
        let mut stats = CompressionStats { raw_bytes: data.len() * 4, ..Default::default() };
        let base = out.len();
        out.reserve(HEADER_LEN + 8 + 4 * nchunks + data.len());
        write_header(out, CompressorKind::Szx, data.len(), eb_abs);
        le::put_u32(out, super::fzlight::frame_u32(chunk, "chunk_values")?);
        le::put_u32(out, super::fzlight::frame_u32(nchunks, "chunk count")?);
        let table = out.len();
        out.resize(table + 4 * nchunks, 0);
        for (i, c) in data.chunks(chunk).enumerate() {
            let start = out.len();
            let (blocks, constant) = compress_chunk_into(c, eb_abs, out);
            stats.blocks += blocks;
            stats.constant_blocks += constant;
            let sz = super::fzlight::frame_u32(out.len() - start, "chunk payload size")?;
            out[table + 4 * i..table + 4 * i + 4].copy_from_slice(&sz.to_le_bytes());
        }
        stats.compressed_bytes = out.len() - base;
        Ok(stats)
    }

    fn decompress_into(&self, bytes: &[u8], out: &mut Vec<f32>) -> Result<usize> {
        let h = read_header(bytes)?;
        if h.codec != CompressorKind::Szx {
            return Err(Error::corrupt("not an szx frame"));
        }
        let mut pos = HEADER_LEN;
        let chunk_values = le::get_u32(bytes, &mut pos)? as usize;
        let nchunks = le::get_u32(bytes, &mut pos)? as usize;
        if chunk_values == 0 && nchunks > 0 {
            return Err(Error::corrupt("zero chunk_values"));
        }
        let mut sizes = Vec::with_capacity(nchunks);
        for _ in 0..nchunks {
            sizes.push(le::get_u32(bytes, &mut pos)? as usize);
        }
        let start = out.len();
        out.reserve(h.n);
        for (i, s) in sizes.iter().enumerate() {
            let end = pos + s;
            if end > bytes.len() {
                return Err(Error::corrupt("szx chunk past frame end"));
            }
            let cn = super::fzlight::chunk_value_count(i, nchunks, h.n, chunk_values)?;
            decompress_chunk(&bytes[pos..end], cn, h.eb_abs, out)?;
            pos = end;
        }
        if out.len() - start != h.n {
            return Err(Error::corrupt(format!(
                "decoded {} of {} values",
                out.len() - start,
                h.n
            )));
        }
        Ok(h.n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::FzLight;
    use crate::data::fields::{Field, FieldKind};

    fn check_bound(orig: &[f32], dec: &[f32], eb: f64) {
        assert_eq!(orig.len(), dec.len());
        for (i, (a, b)) in orig.iter().zip(dec).enumerate() {
            let err = (*a as f64 - *b as f64).abs();
            let tol = eb * (1.0 + 1e-5) + a.abs() as f64 * 1e-6;
            assert!(err <= tol, "idx {i}: |{a} - {b}| = {err} > {eb}");
        }
    }

    #[test]
    fn roundtrip_all_kinds_and_bounds() {
        for kind in FieldKind::ALL {
            for rel in [1e-1, 1e-3] {
                let f = Field::generate(kind, 10_000, 21);
                let eb_abs = ErrorBound::Rel(rel).resolve(&f.values);
                let c = Szx::default().compress(&f.values, ErrorBound::Rel(rel)).unwrap();
                let d = Szx::default().decompress(&c.bytes).unwrap();
                check_bound(&f.values, &d, eb_abs);
            }
        }
    }

    #[test]
    fn constant_field_collapses() {
        let data = vec![-3.25f32; 4096];
        let c = Szx::default().compress(&data, ErrorBound::Abs(1e-3)).unwrap();
        assert_eq!(c.stats.constant_blocks, c.stats.blocks);
        let d = Szx::default().decompress(&c.bytes).unwrap();
        check_bound(&data, &d, 1e-3);
    }

    #[test]
    fn fzlight_beats_szx_on_smooth_data() {
        // Table 3's key relationship: Lorenzo prediction gives fZ-light a
        // higher ratio than SZx on the same field and bound.
        let f = Field::generate(FieldKind::Cesm, 1 << 16, 12);
        let eb = ErrorBound::Rel(1e-3);
        let fz = FzLight::default().compress(&f.values, eb).unwrap();
        let sz = Szx::default().compress(&f.values, eb).unwrap();
        assert!(
            fz.stats.ratio() > sz.stats.ratio(),
            "fzlight {:.2} should beat szx {:.2}",
            fz.stats.ratio(),
            sz.stats.ratio()
        );
    }

    #[test]
    fn tiny_and_partial_blocks() {
        for n in [1usize, 127, 128, 129, 4095, 4097] {
            let data: Vec<f32> = (0..n).map(|i| (i as f32 * 0.11).cos() * 5.0).collect();
            let c = Szx::default().compress(&data, ErrorBound::Abs(1e-4)).unwrap();
            let d = Szx::default().decompress(&c.bytes).unwrap();
            check_bound(&data, &d, 1e-4);
        }
    }

    #[test]
    fn truncated_frame_rejected() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0];
        let c = Szx::default().compress(&data, ErrorBound::Abs(1e-2)).unwrap();
        assert!(Szx::default().decompress(&c.bytes[..c.bytes.len() - 1]).is_err());
    }
}
