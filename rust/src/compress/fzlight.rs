//! `fZ-light` (SZp-style) ultra-fast error-bounded lossy compressor.
//!
//! Algorithm (paper §3.3): the input is split into *chunks* (the paper's
//! thread-blocks; also the pipelining granularity of §3.5.2), each chunk is
//! quantized and Lorenzo-predicted in one fused pass —
//!
//! ```text
//! q[i] = round(x[i] / (2·eb))          (error-bounded quantization)
//! d[i] = q[i] - q[i-1]                 (1-D Lorenzo prediction)
//! ```
//!
//! — the chunk's first quantized value is stored verbatim as an *outlier*,
//! and the deltas are grouped into 32-value *blocks*. Per block the encoder
//! stores one `code length` byte `L = bits(max |d|)`; `L == 0` marks a
//! **constant block** (all deltas zero — the dominant case on smooth
//! scientific fields), otherwise the block's sign bits and `L`-bit
//! magnitudes follow (the paper's "ultra-fast bit-shifting encoding").
//!
//! Reconstruction is `x̂[i] = 2·eb · q[i]`, so `|x - x̂| <= eb` for every
//! element — the fixed-accuracy guarantee the collectives build on.
//!
//! ## Frame body layout (after the common header)
//!
//! ```text
//! u32 chunk_values              values per chunk (last chunk may be short)
//! u32 nchunks
//! u32 chunk_bytes[nchunks]      compressed size of each chunk payload
//! u8  payload[...]              chunk payloads, concatenated
//! ```
//!
//! The chunk-size index at the *head* of the buffer is exactly the §3.5.2
//! customization: it lets [`super::pipe::PipeFzLight`] interleave
//! communication progress between chunks, and lets
//! [`super::multithread`] compress/decompress chunks in parallel.
//!
//! ## Staged chunks (frame version 2)
//!
//! With [`FzLight::with_staged`] the encoder emits
//! [`super::traits::VERSION_STAGED`] frames: the chunk table is
//! unchanged, but each chunk payload starts with a one-byte **stage
//! tag** selecting how the rest of the chunk is coded:
//!
//! ```text
//! STAGE_FIXED   (0): body = the version-1 chunk payload, unchanged
//! STAGE_ENTROPY (1): body = u32 raw_len LE, then an order-0 rANS blob
//!                    (super::entropy) that decodes to exactly raw_len
//!                    bytes — the version-1 chunk payload
//! STAGE_PLAIN   (2): body = the chunk's 4·cn original f32 values LE
//!                    (reconstruction error is exactly zero; the values
//!                    are NOT round-tripped through the quantizer)
//! ```
//!
//! Selection is per chunk, at encode time, by measured size — and it is
//! **never worse**: the encoder always builds the fixed-width payload
//! first, grants the entropy stage a budget of
//! `min(fixed, plain) - margin - 5` bytes (margin =
//! `max(8, fixed/32)`, 5 = tag + raw_len overhead), and ships the
//! fixed-width bytes unchanged when the entropy blob misses that budget
//! — so a staged frame never exceeds its version-1 twin by more than
//! one tag byte per chunk, on any input. A chunk whose fixed-width
//! payload would *expand* past the raw values (adversarial noise under
//! a tiny bound) ships plain. The encoder also refuses entropy blobs so
//! small they would beat [`super::traits::STAGED_MAX_VALUES_PER_BYTE`]
//! — the receive-side sizing guard's density bound is a wire invariant,
//! not a hope.
//!
//! Decode dispatches per chunk on the tag ([`walk_chunk_staged`]), so
//! every decode surface — Vec decode, placement decode, the fused
//! decompress–reduce kernel, pipelined and multithreaded wrappers —
//! inherits all three stages from the one walker. Version-1 frames
//! decode through the exact same paths with the staged dispatch off:
//! existing frames are bit-compatible.
//!
//! ## The fused decompress–reduce kernel
//!
//! The reduction collectives never materialize a decoded partial:
//! [`decompress_fold_chunk`] walks a chunk's blocks and folds each
//! reconstructed value straight into an accumulator slice (paper
//! §3.4–§3.5, Fig. 4). A constant block — the dominant case on smooth
//! fields — folds as a single `q·2eb` broadcast add/max/min over the run
//! with **no per-value decode**, and non-constant blocks fold deltas as
//! they are unpacked, so the intermediate partial vector and its second
//! memory pass disappear entirely. Exposed through
//! [`Compressor::decompress_fold_into`].

use super::bits::le;
use super::entropy;
use super::traits::{
    read_header, write_header_with_version, CompressionStats, Compressor, CompressorKind,
    ErrorBound, HEADER_LEN, STAGED_MAX_VALUES_PER_BYTE, VERSION, VERSION_STAGED,
};
use crate::ops::ReduceOp;
use crate::{Error, Result};

/// Values per small encoding block (sign-bit + fixed-length group).
pub const BLOCK: usize = 32;
/// Default values per chunk (the paper's PIPE-fZ-light uses 5120).
pub const DEFAULT_CHUNK: usize = 5120;

/// Staged chunk stage tag: the body is a version-1 fixed-width payload.
pub const STAGE_FIXED: u8 = 0;
/// Staged chunk stage tag: the body is `u32 raw_len` + an order-0 rANS
/// blob decoding to the version-1 payload bytes.
pub const STAGE_ENTROPY: u8 = 1;
/// Staged chunk stage tag: the body is the chunk's raw `f32` values.
pub const STAGE_PLAIN: u8 = 2;

/// The fZ-light compressor. `chunk_values` controls the pipelining /
/// parallelism granularity; numerics are identical for any value.
#[derive(Debug, Clone)]
pub struct FzLight {
    /// Values per chunk.
    pub chunk_values: usize,
    /// Emit staged (version-2) frames: per-chunk plain / fixed-width /
    /// entropy selection. Off by default — version-1 frames byte-for-
    /// byte identical to previous releases. Decode always accepts both.
    pub staged: bool,
}

impl Default for FzLight {
    fn default() -> Self {
        FzLight { chunk_values: DEFAULT_CHUNK, staged: false }
    }
}

impl FzLight {
    /// Construct with an explicit chunk size (values).
    pub fn with_chunk(chunk_values: usize) -> Self {
        assert!(chunk_values > 0, "chunk_values must be positive");
        FzLight { chunk_values, staged: false }
    }

    /// Toggle staged (version-2) encoding — see the module docs.
    pub fn with_staged(mut self, staged: bool) -> Self {
        self.staged = staged;
        self
    }
}

/// Compress one chunk into a fresh payload vector (the multithread path
/// needs independently owned payloads; everything else should prefer
/// [`compress_chunk_into`]). The quantize scratch is thread-local so a
/// worker pays one allocation for all the chunks it processes, not one
/// per chunk.
pub(crate) fn compress_chunk(data: &[f32], twoeb: f64) -> (Vec<u8>, usize, usize) {
    thread_local! {
        static QBUF: std::cell::RefCell<Vec<i64>> =
            const { std::cell::RefCell::new(Vec::new()) };
    }
    let mut payload = Vec::with_capacity(16 + data.len() * 2);
    let (blocks, constant) = QBUF
        .with(|q| compress_chunk_into(data, twoeb, &mut payload, &mut q.borrow_mut()));
    (payload, blocks, constant)
}

/// Compress one chunk (outlier + delta blocks), appending to `payload`.
/// `qbuf` is caller-owned scratch for the quantized chunk (cleared here;
/// reuse it across chunks for a zero-alloc warm path). Returns the
/// (blocks, constant_blocks) counts.
///
/// Hot path (tracked by `benches/compressors.rs` / `BENCH_codec.json`):
/// the stages run as **separate whole-chunk / whole-block loops** — one
/// quantize pass over the chunk into `qbuf`, then per block a delta
/// pass, a sign/magnitude pass, and the word-parallel
/// [`super::bits::pack_fixed`] spill — so each loop is straight-line and
/// auto-vectorizable, with zero allocations per block.
pub(crate) fn compress_chunk_into(
    data: &[f32],
    twoeb: f64,
    payload: &mut Vec<u8>,
    qbuf: &mut Vec<i64>,
) -> (usize, usize) {
    debug_assert!(!data.is_empty());
    let inv = 1.0 / twoeb;
    // Stage 1: quantize the whole chunk in one pass (the Lorenzo delta
    // has a serial dependency; the quantize does not).
    qbuf.clear();
    qbuf.extend(data.iter().map(|&x| quantize(x, inv)));
    let q0 = qbuf[0];
    payload.reserve(16 + data.len() * 2);
    payload.extend_from_slice(&q0.to_le_bytes());

    let n_deltas = data.len() - 1;
    let mut blocks = 0usize;
    let mut constant = 0usize;
    let mut deltas = [0i64; BLOCK];
    let mut mags = [0u64; BLOCK];
    let mut b = 0;
    while b < n_deltas {
        let cnt = BLOCK.min(n_deltas - b);
        // Stage 2: the block's Lorenzo deltas from the quantized chunk.
        let qs = &qbuf[b..b + cnt + 1];
        for j in 0..cnt {
            deltas[j] = qs[j + 1] - qs[j];
        }
        // Stage 3: signs, magnitudes and the running max in one pass.
        let mut maxmag: u64 = 0;
        let mut sign = 0u32;
        for j in 0..cnt {
            mags[j] = deltas[j].unsigned_abs();
            sign |= u32::from(deltas[j] < 0) << j;
            maxmag |= mags[j];
        }
        blocks += 1;
        if maxmag == 0 {
            constant += 1;
            payload.push(0u8);
        } else {
            let bits = 64 - maxmag.leading_zeros();
            payload.push(bits as u8);
            // Stage 4: sign section (byte-aligned; LSB-first ==
            // BitWriter layout), then word-parallel fixed-length
            // magnitudes.
            payload.extend_from_slice(&sign.to_le_bytes()[..cnt.div_ceil(8)]);
            super::bits::pack_fixed(payload, &mags[..cnt], bits);
        }
        b += cnt;
    }
    (blocks, constant)
}

/// Compress one chunk in **staged** form (stage tag + selected body),
/// appending to `out`. `fixed` and `qbuf` are caller-owned scratch
/// (cleared here). Returns `(blocks, constant_blocks, stage_tag)`.
///
/// The selection contract (see the module docs): the fixed-width
/// payload is always built; the entropy stage must undercut
/// `min(fixed, plain)` by `max(8, fixed/32) + 5` bytes to be chosen,
/// and its blob must stay large enough that the chunk respects the
/// [`STAGED_MAX_VALUES_PER_BYTE`] receive-side density bound; otherwise
/// the smaller of fixed-width and plain ships. A staged chunk is thus
/// never more than one tag byte larger than its version-1 twin.
pub(crate) fn compress_chunk_staged_into(
    data: &[f32],
    twoeb: f64,
    out: &mut Vec<u8>,
    fixed: &mut Vec<u8>,
    qbuf: &mut Vec<i64>,
) -> (usize, usize, u8) {
    fixed.clear();
    let (blocks, constant) = compress_chunk_into(data, twoeb, fixed, qbuf);
    let fixed_len = fixed.len();
    let plain_len = data.len() * 4;
    let margin = (fixed_len / 32).max(8);
    let budget = fixed_len.min(plain_len).saturating_sub(margin + 5);
    // Wire invariant behind the sizing guard: the chunk's total bytes
    // (tag + raw_len + blob) must keep values-per-byte under the staged
    // density bound, so the blob may not shrink below this floor.
    let min_blob = data.len().div_ceil(STAGED_MAX_VALUES_PER_BYTE).saturating_sub(5);
    let base = out.len();
    if budget > 0 && fixed_len <= u32::MAX as usize {
        out.push(STAGE_ENTROPY);
        le::put_u32(out, fixed_len as u32);
        match entropy::encode_if_smaller(fixed, budget, out) {
            Some(blob_len) if blob_len >= min_blob => return (blocks, constant, STAGE_ENTROPY),
            _ => out.truncate(base),
        }
    }
    if fixed_len <= plain_len {
        out.push(STAGE_FIXED);
        out.extend_from_slice(fixed);
        (blocks, constant, STAGE_FIXED)
    } else {
        out.push(STAGE_PLAIN);
        out.reserve(plain_len);
        for &x in data {
            le::put_f32(out, x);
        }
        (blocks, constant, STAGE_PLAIN)
    }
}

/// Staged twin of [`compress_chunk`] for the multithread path: compress
/// one chunk into a fresh owned payload, with the quantize and
/// fixed-width scratch thread-local so a worker pays one allocation for
/// all its chunks. Returns `(payload, blocks, constant_blocks, tag)`.
pub(crate) fn compress_chunk_staged(data: &[f32], twoeb: f64) -> (Vec<u8>, usize, usize, u8) {
    thread_local! {
        static SCRATCH: std::cell::RefCell<(Vec<i64>, Vec<u8>)> =
            const { std::cell::RefCell::new((Vec::new(), Vec::new())) };
    }
    let mut payload = Vec::with_capacity(16 + data.len() * 2);
    let (blocks, constant, tag) = SCRATCH.with(|s| {
        let mut s = s.borrow_mut();
        let (qbuf, fixed) = &mut *s;
        compress_chunk_staged_into(data, twoeb, &mut payload, fixed, qbuf)
    });
    (payload, blocks, constant, tag)
}

/// Largest possible version-1 chunk payload for a chunk of `cn` values:
/// the 8-byte outlier plus, per 32-delta block, a header byte, 4 sign
/// bytes and 64-bit magnitudes. An entropy chunk claiming a `raw_len`
/// beyond this is forged — checked before any scratch is sized from it.
pub(crate) fn max_fixed_payload_bytes(cn: usize) -> usize {
    let deltas = cn.saturating_sub(1);
    deltas
        .div_ceil(BLOCK)
        .saturating_mul(5)
        .saturating_add(deltas.saturating_mul(8))
        .saturating_add(8)
}

/// Decompress one chunk of `cn` values, appending to `out`. Thin wrapper
/// over [`decompress_chunk_into_slice`] kept for Vec-building callers
/// (the PIPE decode loop grows one Vec across chunks). `staged` selects
/// the version-2 (stage-tagged) chunk layout.
pub(crate) fn decompress_chunk(
    payload: &[u8],
    cn: usize,
    twoeb: f64,
    staged: bool,
    out: &mut Vec<f32>,
) -> Result<()> {
    let start = out.len();
    out.resize(start + cn, 0.0);
    let res = decompress_chunk_into_slice(payload, cn, twoeb, staged, &mut out[start..]);
    if res.is_err() {
        out.truncate(start);
    }
    res
}

/// Destination of one reconstructed chunk: the plain decoder writes
/// values in place, the fused kernel folds them into an accumulator.
/// [`walk_chunk`] monomorphizes over the sink, so both kernels compile to
/// the same block walk with a different innermost store — one copy of the
/// frame-walking logic to maintain.
trait ChunkSink {
    /// Deliver a batch of reconstructed values for slots
    /// `idx..idx + xs.len()` — one whole decoded block at a time, so the
    /// sink's inner loop runs over a slice (copy or elementwise fold)
    /// instead of a per-value call.
    fn values(&mut self, idx: usize, xs: &[f32]);
    /// Deliver a constant run: slots `idx..idx + cnt` all reconstruct to
    /// `x` (the constant-block fast path — no per-value decode).
    fn run(&mut self, idx: usize, cnt: usize, x: f32);
}

/// Plain decode: copy each decoded block to its final offset.
struct WriteSink<'a>(&'a mut [f32]);

impl ChunkSink for WriteSink<'_> {
    #[inline]
    fn values(&mut self, idx: usize, xs: &[f32]) {
        self.0[idx..idx + xs.len()].copy_from_slice(xs);
    }
    #[inline]
    fn run(&mut self, idx: usize, cnt: usize, x: f32) {
        self.0[idx..idx + cnt].fill(x);
    }
}

/// Fused decompress–reduce: fold each decoded block into the accumulator.
struct FoldSink<'a> {
    op: ReduceOp,
    acc: &'a mut [f32],
}

impl ChunkSink for FoldSink<'_> {
    #[inline]
    fn values(&mut self, idx: usize, xs: &[f32]) {
        self.op.apply_slice(&mut self.acc[idx..idx + xs.len()], xs);
    }
    #[inline]
    fn run(&mut self, idx: usize, cnt: usize, x: f32) {
        self.op.apply_run(&mut self.acc[idx..idx + cnt], x);
    }
}

/// Reconstruct one chunk of `cn` (>= 1) values block by block, handing
/// each decoded block (or constant run) to `sink`. The single source of
/// truth for the chunk payload format on the decode side.
///
/// The block decode is **batched** (tracked by `benches/compressors.rs`
/// / `BENCH_codec.json`): the block's magnitudes land in a stack array
/// via the word-parallel [`super::bits::unpack_fixed`], signs apply
/// branchlessly, the Lorenzo chain reconstructs as a log-step prefix sum
/// over the deltas, and dequantization is one multiply pass — four
/// straight-line loops the compiler can vectorize, where the scalar
/// kernel ran a serial `q += d` closure per value.
fn walk_chunk(payload: &[u8], cn: usize, twoeb: f64, sink: &mut impl ChunkSink) -> Result<()> {
    debug_assert!(cn >= 1);
    if payload.len() < 8 {
        return Err(Error::corrupt("fzlight chunk shorter than outlier"));
    }
    let q0 = i64::from_le_bytes(payload[0..8].try_into().unwrap());
    sink.values(0, &[(q0 as f64 * twoeb) as f32]);
    let mut q = q0;
    let mut pos = 8usize;
    let mut idx = 1usize;
    let mut mags = [0u64; BLOCK];
    let mut deltas = [0i64; BLOCK];
    let mut vals = [0f32; BLOCK];
    while idx < cn {
        let cnt = BLOCK.min(cn - idx);
        let bits = *payload
            .get(pos)
            .ok_or_else(|| Error::corrupt("fzlight block header past end"))? as u32;
        pos += 1;
        if bits == 0 {
            sink.run(idx, cnt, (q as f64 * twoeb) as f32);
        } else {
            if bits > 64 {
                return Err(Error::corrupt(format!("fzlight code length {bits} > 64")));
            }
            let sign_bytes = cnt.div_ceil(8);
            let mag_bytes = (cnt * bits as usize).div_ceil(8);
            let end = pos + sign_bytes + mag_bytes;
            if end > payload.len() {
                return Err(Error::corrupt("fzlight block body past end"));
            }
            let mut sign = 0u32;
            for (k, &byte) in payload[pos..pos + sign_bytes].iter().enumerate() {
                sign |= (byte as u32) << (8 * k);
            }
            // Whole-block magnitude unpack (word-parallel refills).
            super::bits::unpack_fixed(&payload[pos + sign_bytes..end], bits, &mut mags[..cnt]);
            // Branchless sign application: m is 0 or -1, and
            // `(x ^ m) - m` is x or -x.
            for j in 0..cnt {
                let m = -(((sign >> j) & 1) as i64);
                deltas[j] = (mags[j] as i64 ^ m).wrapping_sub(m);
            }
            // Lorenzo reconstruction: in-place log-step (Hillis–Steele)
            // prefix sum turns the deltas into offsets from `q`. The
            // descending inner loop reads only lanes not yet updated in
            // the current step. Wrapping adds: a log-step intermediate
            // can exceed i64 even when every true prefix fits (e.g. two
            // adjacent +2^62 deltas that the serial chain would cancel
            // against earlier terms); the wraps cancel in the final
            // two's-complement sums, so valid frames reconstruct exactly
            // and corrupt ones stay panic-free.
            for sh in [1usize, 2, 4, 8, 16] {
                for j in (sh..cnt).rev() {
                    deltas[j] = deltas[j].wrapping_add(deltas[j - sh]);
                }
            }
            // Dequantize in one multiply pass.
            for j in 0..cnt {
                vals[j] = (q.wrapping_add(deltas[j]) as f64 * twoeb) as f32;
            }
            q = q.wrapping_add(deltas[cnt - 1]);
            sink.values(idx, &vals[..cnt]);
            pos = end;
        }
        idx += cnt;
    }
    Ok(())
}

/// Reconstruct one **staged** (version-2) chunk: read the stage tag and
/// dispatch — fixed-width bodies go straight to [`walk_chunk`], entropy
/// bodies decode to the version-1 payload in a thread-local scratch
/// first (its claimed `raw_len` is bounded by
/// [`max_fixed_payload_bytes`] before the scratch is sized), and plain
/// bodies feed the sink `f32` values in block-sized batches.
fn walk_chunk_staged(
    payload: &[u8],
    cn: usize,
    twoeb: f64,
    sink: &mut impl ChunkSink,
) -> Result<()> {
    let (&tag, body) = payload
        .split_first()
        .ok_or_else(|| Error::corrupt("staged chunk missing stage tag"))?;
    match tag {
        STAGE_FIXED => walk_chunk(body, cn, twoeb, sink),
        STAGE_PLAIN => {
            if body.len() != cn.saturating_mul(4) {
                return Err(Error::corrupt(format!(
                    "plain chunk holds {} bytes but {cn} values need {}",
                    body.len(),
                    cn.saturating_mul(4)
                )));
            }
            let mut vals = [0f32; BLOCK];
            let mut idx = 0usize;
            for batch in body.chunks(4 * BLOCK) {
                let cnt = batch.len() / 4;
                for (j, b) in batch.chunks_exact(4).enumerate() {
                    vals[j] = f32::from_le_bytes(b.try_into().unwrap());
                }
                sink.values(idx, &vals[..cnt]);
                idx += cnt;
            }
            Ok(())
        }
        STAGE_ENTROPY => {
            let mut pos = 0usize;
            let raw_len = le::get_u32(body, &mut pos)? as usize;
            // Sizing guard: the blob's claimed decoded length may not
            // exceed the largest version-1 payload this chunk's value
            // count could need — a forged raw_len fails here instead of
            // sizing an oversized scratch buffer.
            if raw_len > max_fixed_payload_bytes(cn) {
                return Err(Error::corrupt(format!(
                    "entropy chunk claims {raw_len} payload bytes but {cn} values \
                     need at most {}",
                    max_fixed_payload_bytes(cn)
                )));
            }
            thread_local! {
                static SCRATCH: std::cell::RefCell<Vec<u8>> =
                    const { std::cell::RefCell::new(Vec::new()) };
            }
            SCRATCH.with(|s| {
                let mut s = s.borrow_mut();
                s.clear();
                entropy::decode(&body[pos..], raw_len, &mut s)?;
                walk_chunk(&s, cn, twoeb, sink)
            })
        }
        t => Err(Error::corrupt(format!("unknown stage tag {t}"))),
    }
}

/// Decompress one chunk of `cn` values into a pre-sized slice — the
/// non-fused hot path: writes land directly at their final offsets, no
/// per-value `push` bookkeeping. `out.len()` must equal `cn` (>= 1).
/// `staged` selects the version-2 (stage-tagged) chunk layout.
pub(crate) fn decompress_chunk_into_slice(
    payload: &[u8],
    cn: usize,
    twoeb: f64,
    staged: bool,
    out: &mut [f32],
) -> Result<()> {
    debug_assert_eq!(out.len(), cn);
    if staged {
        walk_chunk_staged(payload, cn, twoeb, &mut WriteSink(out))
    } else {
        walk_chunk(payload, cn, twoeb, &mut WriteSink(out))
    }
}

/// The fused decompress–reduce kernel over one chunk: reconstruct each of
/// the chunk's `cn` values and fold it into the matching slot of `acc`
/// via `op`, in one pass. Constant blocks apply a single broadcast
/// `op(acc[i], q·2eb)` over the run — no per-value decode; non-constant
/// blocks fold deltas in the integer-quantized domain as they are
/// unpacked. `acc.len()` must equal `cn` (>= 1).
///
/// On `Err`, blocks preceding the error have already been folded into
/// `acc` (see [`Compressor::decompress_fold_into`] error semantics).
pub(crate) fn decompress_fold_chunk(
    payload: &[u8],
    cn: usize,
    twoeb: f64,
    staged: bool,
    op: ReduceOp,
    acc: &mut [f32],
) -> Result<()> {
    debug_assert_eq!(acc.len(), cn);
    if staged {
        walk_chunk_staged(payload, cn, twoeb, &mut FoldSink { op, acc })
    } else {
        walk_chunk(payload, cn, twoeb, &mut FoldSink { op, acc })
    }
}

#[inline]
fn quantize(x: f32, inv_twoeb: f64) -> i64 {
    // `as` saturates on overflow, which keeps absurd bound/value
    // combinations from UB; realistic bounds never get near the limit.
    (x as f64 * inv_twoeb).round() as i64
}

/// Guard for every quantity the chunked-frame layout stores as `u32`
/// (chunk size, chunk count, per-chunk payload bytes): a silent `as u32`
/// truncation here would produce an undecodable frame, so oversized
/// values are an explicit [`Error::invalid`] instead. (The PR-1
/// `exchange_sizes` u64 widening removed the *transport* 4 GiB limit;
/// this closes the matching hole in the frame writer.)
#[inline]
pub(crate) fn frame_u32(value: usize, what: &str) -> Result<u32> {
    u32::try_from(value)
        .map_err(|_| Error::invalid(format!("{what} {value} exceeds the frame format's u32 limit")))
}

/// Append a chunked frame (header, chunk table, payloads) to `out`. The
/// chunked layout is shared by fZ-light and SZx, so the codec id is a
/// parameter; `version` selects between the fixed-width and staged
/// chunk payload layouts (staged is fZ-light-only, which the header
/// writer asserts).
pub(crate) fn assemble_frame_into(
    codec: CompressorKind,
    n: usize,
    eb_abs: f64,
    chunk_values: usize,
    payloads: &[Vec<u8>],
    version: u8,
    out: &mut Vec<u8>,
) -> Result<()> {
    // Validate every u32-bound quantity before touching `out`, so an
    // oversize error leaves the buffer exactly as it came in.
    let chunk_values = frame_u32(chunk_values, "chunk_values")?;
    let nchunks = frame_u32(payloads.len(), "chunk count")?;
    let mut sizes = Vec::with_capacity(payloads.len());
    for p in payloads {
        sizes.push(frame_u32(p.len(), "chunk payload size")?);
    }
    let total: usize = payloads.iter().map(Vec::len).sum();
    out.reserve(HEADER_LEN + 8 + 4 * payloads.len() + total);
    write_header_with_version(out, codec, n, eb_abs, version);
    le::put_u32(out, chunk_values);
    le::put_u32(out, nchunks);
    for s in sizes {
        le::put_u32(out, s);
    }
    for p in payloads {
        out.extend_from_slice(p);
    }
    Ok(())
}

/// Compress directly into `out` (append): the chunk table is reserved up
/// front — its length is known from the chunk count — and backfilled as
/// each chunk's payload lands, so the whole frame is built with zero
/// intermediate allocations. Shared by [`FzLight`] and
/// [`super::pipe::PipeFzLight`] (whose `progress` hook runs per chunk).
pub(crate) fn compress_frame_into(
    chunk_values: usize,
    data: &[f32],
    eb: ErrorBound,
    staged: bool,
    out: &mut Vec<u8>,
    progress: &mut dyn FnMut(usize),
) -> Result<CompressionStats> {
    let eb_abs = eb.resolve(data);
    if !(eb_abs > 0.0) || !eb_abs.is_finite() {
        return Err(Error::invalid(format!("error bound must be positive, got {eb_abs}")));
    }
    let base = out.len();
    let res = write_frame(chunk_values, data, eb_abs, staged, out, progress);
    if res.is_err() {
        // An oversize-chunk error must not leave a half-written frame.
        out.truncate(base);
    }
    res
}

/// [`compress_frame_into`]'s body, split out so the caller can restore
/// `out` on error.
fn write_frame(
    chunk_values: usize,
    data: &[f32],
    eb_abs: f64,
    staged: bool,
    out: &mut Vec<u8>,
    progress: &mut dyn FnMut(usize),
) -> Result<CompressionStats> {
    let twoeb = 2.0 * eb_abs;
    let chunk = chunk_values.max(1);
    let nchunks = data.len().div_ceil(chunk);
    let base = out.len();
    let mut stats = CompressionStats { raw_bytes: data.len() * 4, ..Default::default() };
    out.reserve(HEADER_LEN + 8 + 4 * nchunks + data.len() * 2);
    let version = if staged { VERSION_STAGED } else { VERSION };
    write_header_with_version(out, CompressorKind::FzLight, data.len(), eb_abs, version);
    le::put_u32(out, frame_u32(chunk, "chunk_values")?);
    le::put_u32(out, frame_u32(nchunks, "chunk count")?);
    let table = out.len();
    out.resize(table + 4 * nchunks, 0);
    let mut done = 0usize;
    // Quantization + staged fixed-width scratch, reused across every
    // chunk of the frame.
    let mut qbuf: Vec<i64> = Vec::with_capacity(chunk.min(data.len()));
    let mut fixed: Vec<u8> = Vec::new();
    for (i, c) in data.chunks(chunk).enumerate() {
        let start = out.len();
        let (blocks, constant) = if staged {
            let (blocks, constant, tag) =
                compress_chunk_staged_into(c, twoeb, out, &mut fixed, &mut qbuf);
            stats.chunks += 1;
            stats.entropy_chunks += usize::from(tag == STAGE_ENTROPY);
            stats.plain_chunks += usize::from(tag == STAGE_PLAIN);
            (blocks, constant)
        } else {
            compress_chunk_into(c, twoeb, out, &mut qbuf)
        };
        stats.blocks += blocks;
        stats.constant_blocks += constant;
        let sz = frame_u32(out.len() - start, "chunk payload size")?;
        out[table + 4 * i..table + 4 * i + 4].copy_from_slice(&sz.to_le_bytes());
        done += c.len();
        progress(done);
    }
    stats.compressed_bytes = out.len() - base;
    Ok(stats)
}

/// Geometry of a parsed fZ-light frame: everything the chunk walkers
/// need besides the payload ranges themselves.
#[derive(Debug, Clone, Copy)]
pub(crate) struct FrameGeom {
    /// Nominal values per chunk (last chunk may be short).
    pub chunk_values: usize,
    /// Absolute error bound from the header.
    pub eb_abs: f64,
    /// Total element count from the header.
    pub n: usize,
    /// Whether chunk payloads use the staged (version-2) layout.
    pub staged: bool,
}

/// Parsed view over a frame's chunk table: geometry + payload ranges.
pub(crate) fn frame_chunks(bytes: &[u8]) -> Result<(FrameGeom, Vec<std::ops::Range<usize>>)> {
    let h = read_header(bytes)?;
    if h.codec != CompressorKind::FzLight {
        return Err(Error::corrupt("not an fzlight frame"));
    }
    let mut pos = HEADER_LEN;
    let chunk_values = le::get_u32(bytes, &mut pos)? as usize;
    let nchunks = le::get_u32(bytes, &mut pos)? as usize;
    if chunk_values == 0 && nchunks > 0 {
        return Err(Error::corrupt("zero chunk_values"));
    }
    let mut sizes = Vec::with_capacity(nchunks);
    for _ in 0..nchunks {
        sizes.push(le::get_u32(bytes, &mut pos)? as usize);
    }
    let mut ranges = Vec::with_capacity(nchunks);
    for s in sizes {
        let end = pos + s;
        if end > bytes.len() {
            return Err(Error::corrupt("fzlight chunk table past frame end"));
        }
        ranges.push(pos..end);
        pos = end;
    }
    let geom = FrameGeom {
        chunk_values,
        eb_abs: h.eb_abs,
        n: h.n,
        staged: h.version == VERSION_STAGED,
    };
    Ok((geom, ranges))
}

/// Values in chunk `i` of a frame holding `n` values in `nchunks` chunks
/// of nominally `chunk_values` each — every chunk is full except the
/// last, whose count is validated against the header. Shared by the
/// plain, pipelined, multithreaded and fused decode walkers.
pub(crate) fn chunk_value_count(
    i: usize,
    nchunks: usize,
    n: usize,
    chunk_values: usize,
) -> Result<usize> {
    if i + 1 == nchunks {
        chunk_values
            .checked_mul(nchunks - 1)
            .and_then(|prior| n.checked_sub(prior))
            .filter(|&c| c >= 1 && c <= chunk_values)
            .ok_or_else(|| Error::corrupt("chunk table inconsistent with count"))
    } else {
        Ok(chunk_values)
    }
}

/// Cheap consistency check of the header's element count against the
/// chunk table, run **before** sizing any destination buffer: a corrupt
/// `n` (e.g. a flipped header bit, or a crafted tiny frame claiming
/// billions of values) must fail cleanly rather than commit pages for a
/// bogus length. Cross-checks `n` against the full-chunk arithmetic AND
/// against the payload bytes actually present — a version-1 chunk
/// payload of `L` bytes can encode at most `1 + (L − 8)·BLOCK` values
/// (outlier plus one header byte per all-constant 32-value block). For
/// staged frames the cap dispatches on each chunk's stage tag: fixed
/// bodies get the version-1 cap, plain bodies exactly `(L − 1) / 4`,
/// entropy bodies the [`STAGED_MAX_VALUES_PER_BYTE`] density the
/// encoder enforces as a wire invariant; an unknown tag fails here.
pub(crate) fn validate_frame_count(
    bytes: &[u8],
    ranges: &[std::ops::Range<usize>],
    geom: &FrameGeom,
) -> Result<()> {
    let n = geom.n;
    match ranges.len().checked_sub(1) {
        Some(last) => {
            chunk_value_count(last, ranges.len(), n, geom.chunk_values)?;
            let mut cap = 0usize;
            for r in ranges {
                let per_chunk = if geom.staged {
                    staged_chunk_value_cap(bytes, r)?
                } else {
                    r.len().saturating_sub(8).saturating_mul(BLOCK).saturating_add(1)
                };
                cap = cap.saturating_add(per_chunk);
            }
            if n > cap {
                return Err(Error::corrupt(format!(
                    "frame claims {n} values but its payload can hold at most {cap}"
                )));
            }
        }
        None if n != 0 => {
            return Err(Error::corrupt(format!("frame claims {n} values but has no chunks")));
        }
        None => {}
    }
    Ok(())
}

/// Per-stage value cap for one staged chunk payload, from its stage tag
/// (the first payload byte — `r` is already bounds-checked against the
/// frame by [`frame_chunks`]).
fn staged_chunk_value_cap(bytes: &[u8], r: &std::ops::Range<usize>) -> Result<usize> {
    if r.is_empty() {
        return Err(Error::corrupt("staged chunk missing stage tag"));
    }
    let body_len = r.len() - 1;
    match bytes[r.start] {
        STAGE_FIXED => Ok(body_len.saturating_sub(8).saturating_mul(BLOCK).saturating_add(1)),
        STAGE_PLAIN => Ok(body_len / 4),
        STAGE_ENTROPY => Ok(r.len().saturating_mul(STAGED_MAX_VALUES_PER_BYTE)),
        t => Err(Error::corrupt(format!("unknown stage tag {t}"))),
    }
}

/// Walk a parsed frame's chunks over their disjoint windows of `dst`
/// (`dst.len() == n`), validating the chunk table as it goes: `kernel`
/// decodes one chunk payload into its window, and `progress` runs after
/// each chunk (the §3.5.2 hook). The single frame walk shared by the
/// plain and fused decode paths.
fn walk_frame_chunks(
    bytes: &[u8],
    ranges: &[std::ops::Range<usize>],
    geom: &FrameGeom,
    dst: &mut [f32],
    progress: &mut dyn FnMut(usize),
    kernel: &mut dyn FnMut(&[u8], usize, &mut [f32]) -> Result<()>,
) -> Result<()> {
    let n = geom.n;
    debug_assert_eq!(dst.len(), n);
    let mut done = 0usize;
    for (i, r) in ranges.iter().enumerate() {
        let cn = chunk_value_count(i, ranges.len(), n, geom.chunk_values)?;
        let d = dst
            .get_mut(done..done + cn)
            .ok_or_else(|| Error::corrupt("chunk table exceeds element count"))?;
        kernel(&bytes[r.clone()], cn, d)?;
        done += cn;
        progress(done);
    }
    if done != n {
        return Err(Error::corrupt(format!("decoded {done} of {n} values")));
    }
    Ok(())
}

/// Parse an fZ-light frame for a placement decode into a destination of
/// `out_len` values: [`frame_chunks`] + destination-length check +
/// [`validate_frame_count`], the shared prelude of the serial and
/// multithreaded in-place kernels.
pub(crate) fn frame_chunks_for_slice(
    bytes: &[u8],
    out_len: usize,
) -> Result<(FrameGeom, Vec<std::ops::Range<usize>>)> {
    let (geom, ranges) = frame_chunks(bytes)?;
    if out_len != geom.n {
        return Err(Error::invalid(format!(
            "placement decode: frame holds {} values but destination holds {out_len}",
            geom.n
        )));
    }
    validate_frame_count(bytes, &ranges, &geom)?;
    Ok((geom, ranges))
}

/// Placement decode of a whole fZ-light frame: every chunk reconstructs
/// straight into its disjoint window of `out` (`out.len()` must equal the
/// frame's element count), with `progress` running after each chunk — the
/// §3.5.2 hook, shared by [`FzLight`] (no-op hook) and
/// [`super::pipe::PipeFzLight`] (polls outstanding communication).
///
/// On `Err`, a prefix of `out` may already be written — the window is
/// poisoned (see [`Compressor::decompress_into_slice`] error semantics).
pub(crate) fn decompress_frame_into_slice(
    bytes: &[u8],
    out: &mut [f32],
    progress: &mut dyn FnMut(usize),
) -> Result<usize> {
    let (geom, ranges) = frame_chunks_for_slice(bytes, out.len())?;
    let twoeb = 2.0 * geom.eb_abs;
    let staged = geom.staged;
    walk_frame_chunks(bytes, &ranges, &geom, out, progress, &mut |p, cn, d| {
        decompress_chunk_into_slice(p, cn, twoeb, staged, d)
    })?;
    Ok(geom.n)
}

/// Walk an fZ-light frame applying the fused decompress–reduce kernel
/// chunk by chunk, calling `progress` (with the values folded so far)
/// after each chunk — the §3.5.2 hook, shared by [`FzLight`] (no-op
/// hook) and [`super::pipe::PipeFzLight`] (polls outstanding
/// communication). `acc.len()` must equal the frame's element count.
pub(crate) fn decompress_fold_frame(
    bytes: &[u8],
    op: ReduceOp,
    acc: &mut [f32],
    progress: &mut dyn FnMut(usize),
) -> Result<usize> {
    let (geom, ranges) = frame_chunks(bytes)?;
    if acc.len() != geom.n {
        return Err(Error::invalid(format!(
            "fused fold: frame holds {} values but accumulator holds {}",
            geom.n,
            acc.len()
        )));
    }
    let twoeb = 2.0 * geom.eb_abs;
    let staged = geom.staged;
    walk_frame_chunks(bytes, &ranges, &geom, acc, progress, &mut |p, cn, d| {
        decompress_fold_chunk(p, cn, twoeb, staged, op, d)
    })?;
    Ok(geom.n)
}

impl Compressor for FzLight {
    fn kind(&self) -> CompressorKind {
        CompressorKind::FzLight
    }

    fn compress_into(
        &self,
        data: &[f32],
        eb: ErrorBound,
        out: &mut Vec<u8>,
    ) -> Result<CompressionStats> {
        compress_frame_into(self.chunk_values, data, eb, self.staged, out, &mut |_| {})
    }

    fn decompress_into(&self, bytes: &[u8], out: &mut Vec<f32>) -> Result<usize> {
        let (geom, ranges) = frame_chunks(bytes)?;
        let twoeb = 2.0 * geom.eb_abs;
        let staged = geom.staged;
        validate_frame_count(bytes, &ranges, &geom)?;
        // Pre-size once from the header; each chunk then decodes straight
        // into its final slice (no per-value push). On error the buffer
        // is restored to its incoming length.
        let start = out.len();
        out.resize(start + geom.n, 0.0);
        let res = walk_frame_chunks(
            bytes,
            &ranges,
            &geom,
            &mut out[start..],
            &mut |_| {},
            &mut |p, cn, d| decompress_chunk_into_slice(p, cn, twoeb, staged, d),
        );
        match res {
            Ok(()) => Ok(geom.n),
            Err(e) => {
                out.truncate(start);
                Err(e)
            }
        }
    }

    fn decompress_into_slice(&self, bytes: &[u8], out: &mut [f32]) -> Result<usize> {
        decompress_frame_into_slice(bytes, out, &mut |_| {})
    }

    fn supports_placement_decode(&self) -> bool {
        true
    }

    fn decompress_fold_into(&self, bytes: &[u8], op: ReduceOp, acc: &mut [f32]) -> Result<usize> {
        decompress_fold_frame(bytes, op, acc, &mut |_| {})
    }

    fn supports_fused_fold(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::traits::write_header;
    use crate::data::fields::{Field, FieldKind};

    fn check_bound(orig: &[f32], dec: &[f32], eb: f64) {
        assert_eq!(orig.len(), dec.len());
        for (i, (a, b)) in orig.iter().zip(dec).enumerate() {
            let err = (*a as f64 - *b as f64).abs();
            // f32 rounding of the reconstruction adds at most ~1 ulp.
            let tol = eb * (1.0 + 1e-5) + a.abs() as f64 * 1e-6;
            assert!(err <= tol, "idx {i}: |{a} - {b}| = {err} > {eb}");
        }
    }

    #[test]
    fn roundtrip_smooth_field_abs_bound() {
        let f = Field::generate(FieldKind::Rtm, 20_000, 3);
        let c = FzLight::default().compress(&f.values, ErrorBound::Abs(1e-3)).unwrap();
        let d = FzLight::default().decompress(&c.bytes).unwrap();
        check_bound(&f.values, &d, 1e-3);
        assert!(
            c.stats.ratio() > 4.0,
            "smooth field should compress well, got {}",
            c.stats.ratio()
        );
    }

    #[test]
    fn roundtrip_all_field_kinds_rel_bounds() {
        for kind in FieldKind::ALL {
            for rel in [1e-1, 1e-2, 1e-3, 1e-4] {
                let f = Field::generate(kind, 8192, 11);
                let eb_abs = ErrorBound::Rel(rel).resolve(&f.values);
                let c = FzLight::default().compress(&f.values, ErrorBound::Rel(rel)).unwrap();
                let d = FzLight::default().decompress(&c.bytes).unwrap();
                check_bound(&f.values, &d, eb_abs);
            }
        }
    }

    #[test]
    fn tiny_inputs() {
        for n in [1usize, 2, 3, 31, 32, 33, 5119, 5120, 5121] {
            let data: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
            let c = FzLight::default().compress(&data, ErrorBound::Abs(1e-4)).unwrap();
            let d = FzLight::default().decompress(&c.bytes).unwrap();
            check_bound(&data, &d, 1e-4);
        }
    }

    #[test]
    fn empty_input() {
        let c = FzLight::default().compress(&[], ErrorBound::Abs(1e-4)).unwrap();
        let d = FzLight::default().decompress(&c.bytes).unwrap();
        assert!(d.is_empty());
    }

    #[test]
    fn constant_input_is_all_constant_blocks() {
        let data = vec![2.5f32; 10_000];
        let c = FzLight::default().compress(&data, ErrorBound::Abs(1e-4)).unwrap();
        assert_eq!(c.stats.constant_blocks, c.stats.blocks);
        assert!(c.stats.ratio() > 100.0, "ratio {}", c.stats.ratio());
        let d = FzLight::default().decompress(&c.bytes).unwrap();
        check_bound(&data, &d, 1e-4);
    }

    #[test]
    fn noise_still_bounded() {
        // Worst case for Lorenzo: white noise.
        let mut rng = crate::data::rng::Rng::new(99);
        let data: Vec<f32> = (0..4096).map(|_| rng.normal() as f32).collect();
        let eb = 1e-5;
        let c = FzLight::default().compress(&data, ErrorBound::Abs(eb)).unwrap();
        let d = FzLight::default().decompress(&c.bytes).unwrap();
        check_bound(&data, &d, eb);
    }

    #[test]
    fn rejects_nonpositive_bound() {
        assert!(FzLight::default().compress(&[1.0], ErrorBound::Abs(0.0)).is_err());
        assert!(FzLight::default().compress(&[1.0], ErrorBound::Abs(-1.0)).is_err());
    }

    #[test]
    fn rejects_truncated_frame() {
        let data = vec![1.0f32; 1000];
        let c = FzLight::default().compress(&data, ErrorBound::Abs(1e-3)).unwrap();
        for cut in [10, HEADER_LEN, c.bytes.len() - 1] {
            assert!(FzLight::default().decompress(&c.bytes[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn chunk_size_does_not_change_numerics() {
        let f = Field::generate(FieldKind::Nyx, 12_345, 5);
        let a = FzLight::with_chunk(512).compress(&f.values, ErrorBound::Abs(1e-3)).unwrap();
        let b = FzLight::with_chunk(5120).compress(&f.values, ErrorBound::Abs(1e-3)).unwrap();
        let da = FzLight::default().decompress(&a.bytes).unwrap();
        let db = FzLight::default().decompress(&b.bytes).unwrap();
        assert_eq!(da, db);
    }

    #[test]
    fn fused_fold_matches_decode_then_fold_bitwise() {
        use crate::ops::ReduceOp;
        let f = Field::generate(FieldKind::Hurricane, 12_345, 21);
        let codec = FzLight::with_chunk(512);
        let c = codec.compress(&f.values, ErrorBound::Abs(1e-3)).unwrap();
        let dec = codec.decompress(&c.bytes).unwrap();
        let base = Field::generate(FieldKind::Nyx, 12_345, 22).values;
        for op in [ReduceOp::Sum, ReduceOp::Max, ReduceOp::Min] {
            let mut unfused = base.clone();
            op.fold(&mut unfused, &dec);
            let mut fused = base.clone();
            assert_eq!(codec.decompress_fold_into(&c.bytes, op, &mut fused).unwrap(), 12_345);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&fused), bits(&unfused), "{op:?}");
        }
    }

    #[test]
    fn fused_fold_rejects_wrong_accumulator_length() {
        use crate::ops::ReduceOp;
        let data = vec![1.0f32; 100];
        let c = FzLight::default().compress(&data, ErrorBound::Abs(1e-3)).unwrap();
        let mut acc = vec![0.0f32; 99];
        let before = acc.clone();
        assert!(FzLight::default()
            .decompress_fold_into(&c.bytes, ReduceOp::Sum, &mut acc)
            .is_err());
        assert_eq!(acc, before, "length mismatch is detected before any fold");
    }

    #[test]
    fn chunked_decode_restores_buffer_on_error() {
        let data: Vec<f32> = (0..3000).map(|i| (i as f32 * 0.11).sin()).collect();
        let c = FzLight::with_chunk(1000).compress(&data, ErrorBound::Abs(1e-4)).unwrap();
        let mut out = vec![7.0f32; 3];
        assert!(FzLight::default()
            .decompress_into(&c.bytes[..c.bytes.len() - 1], &mut out)
            .is_err());
        assert_eq!(out, vec![7.0, 7.0, 7.0], "error path must not leave partial decodes");
    }

    #[test]
    fn huge_claimed_count_rejected_before_allocation() {
        // A crafted ~50-byte frame claiming u32::MAX values in one chunk
        // must fail in validation, not commit a multi-GB destination.
        let mut bytes = Vec::new();
        write_header(&mut bytes, CompressorKind::FzLight, u32::MAX as usize, 1e-3);
        le::put_u32(&mut bytes, u32::MAX); // chunk_values
        le::put_u32(&mut bytes, 1); // nchunks
        le::put_u32(&mut bytes, 8); // chunk payload size
        bytes.extend_from_slice(&0i64.to_le_bytes()); // outlier-only payload
        let mut out = Vec::new();
        assert!(FzLight::default().decompress_into(&bytes, &mut out).is_err());
        assert!(out.capacity() < 1 << 20, "destination must not be sized from the corrupt header");
        let mt = crate::compress::MtCompressor::new(CompressorKind::FzLight);
        let mut out2 = Vec::new();
        assert!(mt.decompress_into(&bytes, &mut out2).is_err());
        assert!(out2.capacity() < 1 << 20);
    }

    #[test]
    fn frame_u32_guard() {
        assert_eq!(frame_u32(12, "x").unwrap(), 12);
        assert_eq!(frame_u32(u32::MAX as usize, "x").unwrap(), u32::MAX);
        assert!(frame_u32(u32::MAX as usize + 1, "chunk payload size").is_err());
    }

    #[test]
    fn assemble_frame_rejects_oversize_table_entries() {
        // An oversized chunk_values must be refused, not truncated.
        let payloads = vec![vec![0u8; 4]];
        let mut out = Vec::new();
        assert!(assemble_frame_into(
            CompressorKind::FzLight,
            8,
            1e-3,
            u32::MAX as usize + 1,
            &payloads,
            VERSION,
            &mut out,
        )
        .is_err());
    }

    #[test]
    fn smaller_bound_lower_ratio() {
        let f = Field::generate(FieldKind::Hurricane, 32_768, 2);
        let hi = FzLight::default().compress(&f.values, ErrorBound::Rel(1e-1)).unwrap();
        let lo = FzLight::default().compress(&f.values, ErrorBound::Rel(1e-4)).unwrap();
        assert!(
            hi.stats.ratio() > lo.stats.ratio(),
            "ratio(1e-1)={} should exceed ratio(1e-4)={}",
            hi.stats.ratio(),
            lo.stats.ratio()
        );
        assert!(hi.stats.constant_fraction() >= lo.stats.constant_fraction());
    }

    #[test]
    fn staged_roundtrip_all_field_kinds_rel_bounds() {
        let codec = FzLight::default().with_staged(true);
        for kind in FieldKind::ALL {
            for rel in [1e-1, 1e-3] {
                let f = Field::generate(kind, 8192, 13);
                let eb_abs = ErrorBound::Rel(rel).resolve(&f.values);
                let c = codec.compress(&f.values, ErrorBound::Rel(rel)).unwrap();
                // The decoder dispatches on the frame version byte, so a
                // plainly-constructed codec decodes staged frames too.
                let d = FzLight::default().decompress(&c.bytes).unwrap();
                check_bound(&f.values, &d, eb_abs);
            }
        }
    }

    #[test]
    fn staged_tiny_and_empty_inputs() {
        let codec = FzLight::default().with_staged(true);
        let c = codec.compress(&[], ErrorBound::Abs(1e-4)).unwrap();
        assert!(FzLight::default().decompress(&c.bytes).unwrap().is_empty());
        for n in [1usize, 2, 31, 32, 33, 5119, 5120, 5121] {
            let data: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
            let c = codec.compress(&data, ErrorBound::Abs(1e-4)).unwrap();
            let d = FzLight::default().decompress(&c.bytes).unwrap();
            check_bound(&data, &d, 1e-4);
        }
    }

    #[test]
    fn staged_never_worse_than_fixed_plus_tag_bytes() {
        // Adaptive selection may only cost the per-chunk stage tag: a
        // staged frame is never larger than the version-1 frame plus one
        // byte per chunk, on any dataset.
        for kind in FieldKind::ALL {
            for eb in [1e-2, 1e-6] {
                let f = Field::generate(kind, 20_000, 7);
                let v1 = FzLight::default().compress(&f.values, ErrorBound::Abs(eb)).unwrap();
                let st = FzLight::default()
                    .with_staged(true)
                    .compress(&f.values, ErrorBound::Abs(eb))
                    .unwrap();
                let nchunks = f.values.len().div_ceil(DEFAULT_CHUNK);
                assert!(
                    st.bytes.len() <= v1.bytes.len() + nchunks,
                    "{kind:?} eb {eb}: staged {} vs fixed {} (+{nchunks} tags)",
                    st.bytes.len(),
                    v1.bytes.len()
                );
            }
        }
    }

    #[test]
    fn staged_constant_field_picks_entropy_and_shrinks() {
        let data = vec![5.0f32; 10_000];
        let v1 = FzLight::default().compress(&data, ErrorBound::Abs(1e-4)).unwrap();
        let st =
            FzLight::default().with_staged(true).compress(&data, ErrorBound::Abs(1e-4)).unwrap();
        assert_eq!(st.stats.chunks, 2);
        assert_eq!(
            st.stats.entropy_chunks, st.stats.chunks,
            "constant chunks are the easiest entropy win"
        );
        assert!(
            st.bytes.len() < v1.bytes.len(),
            "staged {} should beat fixed {}",
            st.bytes.len(),
            v1.bytes.len()
        );
        let d = FzLight::default().decompress(&st.bytes).unwrap();
        check_bound(&data, &d, 1e-4);
    }

    #[test]
    fn staged_noise_with_tiny_bound_ships_plain_bit_exact() {
        // White noise at eb 1e-12 makes fixed-width wider than the raw
        // f32s, so every chunk falls back to the plain stage — which
        // stores the original values exactly.
        let mut rng = crate::data::rng::Rng::new(4242);
        let data: Vec<f32> = (0..6000).map(|_| (rng.normal() * 1e3) as f32).collect();
        let st =
            FzLight::default().with_staged(true).compress(&data, ErrorBound::Abs(1e-12)).unwrap();
        assert_eq!(st.stats.plain_chunks, st.stats.chunks);
        assert!(st.bytes.len() < data.len() * 4 + 64, "plain stage stays near raw size");
        let d = FzLight::default().decompress(&st.bytes).unwrap();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&d), bits(&data), "plain chunks reproduce the input bit-exactly");
    }

    #[test]
    fn staged_fused_and_placement_match_plain_decode() {
        use crate::ops::ReduceOp;
        let f = Field::generate(FieldKind::Rtm, 12_345, 9);
        let codec = FzLight::with_chunk(512).with_staged(true);
        let c = codec.compress(&f.values, ErrorBound::Abs(1e-3)).unwrap();
        assert!(c.stats.entropy_chunks > 0, "smooth field should take the entropy stage");
        let dec = codec.decompress(&c.bytes).unwrap();
        let mut placed = vec![0.0f32; 12_345];
        assert_eq!(codec.decompress_into_slice(&c.bytes, &mut placed).unwrap(), 12_345);
        assert_eq!(placed, dec);
        let mut acc = vec![0.0f32; 12_345];
        assert_eq!(codec.decompress_fold_into(&c.bytes, ReduceOp::Sum, &mut acc).unwrap(), 12_345);
        let mut want = vec![0.0f32; 12_345];
        ReduceOp::Sum.fold(&mut want, &dec);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&acc), bits(&want));
    }

    #[test]
    fn forged_entropy_raw_len_rejected_before_allocation() {
        // A staged chunk whose entropy header claims a multi-GB decoded
        // payload must fail against the worst-case fixed-payload bound
        // before any scratch is sized from the forged length.
        let mut payload = vec![STAGE_ENTROPY];
        le::put_u32(&mut payload, u32::MAX);
        payload.extend_from_slice(&[0u8; 16]);
        let mut bytes = Vec::new();
        assemble_frame_into(
            CompressorKind::FzLight,
            100,
            1e-3,
            100,
            &[payload],
            VERSION_STAGED,
            &mut bytes,
        )
        .unwrap();
        let mut out = Vec::new();
        let err = FzLight::default().decompress_into(&bytes, &mut out).unwrap_err();
        assert!(matches!(err, Error::Corrupt(_)), "{err:?}");
        assert!(out.capacity() < 1 << 20, "corrupt raw_len must not size buffers");
    }

    #[test]
    fn staged_unknown_stage_tag_is_corrupt() {
        let data: Vec<f32> = (0..256).map(|i| i as f32).collect();
        let st =
            FzLight::default().with_staged(true).compress(&data, ErrorBound::Abs(1e-3)).unwrap();
        let (geom, ranges) = frame_chunks(&st.bytes).unwrap();
        assert!(geom.staged);
        let mut forged = st.bytes.clone();
        forged[ranges[0].start] = 7; // no such stage
        let mut out = Vec::new();
        let err = FzLight::default().decompress_into(&forged, &mut out).unwrap_err();
        assert!(matches!(err, Error::Corrupt(_)), "{err:?}");
    }
}
