//! `fZ-light` (SZp-style) ultra-fast error-bounded lossy compressor.
//!
//! Algorithm (paper §3.3): the input is split into *chunks* (the paper's
//! thread-blocks; also the pipelining granularity of §3.5.2), each chunk is
//! quantized and Lorenzo-predicted in one fused pass —
//!
//! ```text
//! q[i] = round(x[i] / (2·eb))          (error-bounded quantization)
//! d[i] = q[i] - q[i-1]                 (1-D Lorenzo prediction)
//! ```
//!
//! — the chunk's first quantized value is stored verbatim as an *outlier*,
//! and the deltas are grouped into 32-value *blocks*. Per block the encoder
//! stores one `code length` byte `L = bits(max |d|)`; `L == 0` marks a
//! **constant block** (all deltas zero — the dominant case on smooth
//! scientific fields), otherwise the block's sign bits and `L`-bit
//! magnitudes follow (the paper's "ultra-fast bit-shifting encoding").
//!
//! Reconstruction is `x̂[i] = 2·eb · q[i]`, so `|x - x̂| <= eb` for every
//! element — the fixed-accuracy guarantee the collectives build on.
//!
//! ## Frame body layout (after the common header)
//!
//! ```text
//! u32 chunk_values              values per chunk (last chunk may be short)
//! u32 nchunks
//! u32 chunk_bytes[nchunks]      compressed size of each chunk payload
//! u8  payload[...]              chunk payloads, concatenated
//! ```
//!
//! The chunk-size index at the *head* of the buffer is exactly the §3.5.2
//! customization: it lets [`super::pipe::PipeFzLight`] interleave
//! communication progress between chunks, and lets
//! [`super::multithread`] compress/decompress chunks in parallel.

use super::bits::le;
use super::traits::{
    read_header, write_header, CompressionStats, Compressor, CompressorKind, ErrorBound,
    HEADER_LEN,
};
use crate::{Error, Result};

/// Values per small encoding block (sign-bit + fixed-length group).
pub const BLOCK: usize = 32;
/// Default values per chunk (the paper's PIPE-fZ-light uses 5120).
pub const DEFAULT_CHUNK: usize = 5120;

/// The fZ-light compressor. `chunk_values` controls the pipelining /
/// parallelism granularity; numerics are identical for any value.
#[derive(Debug, Clone)]
pub struct FzLight {
    /// Values per chunk.
    pub chunk_values: usize,
}

impl Default for FzLight {
    fn default() -> Self {
        FzLight { chunk_values: DEFAULT_CHUNK }
    }
}

impl FzLight {
    /// Construct with an explicit chunk size (values).
    pub fn with_chunk(chunk_values: usize) -> Self {
        assert!(chunk_values > 0, "chunk_values must be positive");
        FzLight { chunk_values }
    }
}

/// Compress one chunk into a fresh payload vector (the multithread path
/// needs independently owned payloads; everything else should prefer
/// [`compress_chunk_into`]).
pub(crate) fn compress_chunk(data: &[f32], twoeb: f64) -> (Vec<u8>, usize, usize) {
    let mut payload = Vec::with_capacity(16 + data.len() * 2);
    let (blocks, constant) = compress_chunk_into(data, twoeb, &mut payload);
    (payload, blocks, constant)
}

/// Compress one chunk (outlier + delta blocks), appending to `payload`.
/// Returns the (blocks, constant_blocks) counts.
///
/// Hot path (see EXPERIMENTS.md §Perf): sign words and magnitudes are
/// packed straight into the payload via [`super::bits::pack_fixed`] —
/// zero allocations per block.
pub(crate) fn compress_chunk_into(data: &[f32], twoeb: f64, payload: &mut Vec<u8>) -> (usize, usize) {
    debug_assert!(!data.is_empty());
    let inv = 1.0 / twoeb;
    let q0 = quantize(data[0], inv);
    payload.reserve(16 + data.len() * 2);
    payload.extend_from_slice(&q0.to_le_bytes());

    let n_deltas = data.len() - 1;
    let mut blocks = 0usize;
    let mut constant = 0usize;
    let mut prev = q0;
    let mut mags = [0u64; BLOCK];
    let mut b = 0;
    while b < n_deltas {
        let cnt = BLOCK.min(n_deltas - b);
        let mut maxmag: u64 = 0;
        let mut sign = 0u32;
        // Two passes so the quantization loop auto-vectorises (the Lorenzo
        // delta has a serial dependency; the quantize does not).
        let mut qbuf = [0i64; BLOCK + 1];
        qbuf[0] = prev;
        for (slot, &x) in qbuf[1..1 + cnt].iter_mut().zip(&data[1 + b..1 + b + cnt]) {
            *slot = quantize(x, inv);
        }
        prev = qbuf[cnt];
        for j in 0..cnt {
            let d = qbuf[j + 1] - qbuf[j];
            mags[j] = d.unsigned_abs();
            sign |= u32::from(d < 0) << j;
            maxmag |= mags[j];
        }
        blocks += 1;
        if maxmag == 0 {
            constant += 1;
            payload.push(0u8);
        } else {
            let bits = 64 - maxmag.leading_zeros();
            payload.push(bits as u8);
            // Sign section (byte-aligned; LSB-first == BitWriter layout),
            // then fixed-length magnitudes.
            payload.extend_from_slice(&sign.to_le_bytes()[..cnt.div_ceil(8)]);
            super::bits::pack_fixed(payload, &mags[..cnt], bits);
        }
        b += cnt;
    }
    (blocks, constant)
}

/// Decompress one chunk of `cn` values into `out`.
pub(crate) fn decompress_chunk(payload: &[u8], cn: usize, twoeb: f64, out: &mut Vec<f32>) -> Result<()> {
    if payload.len() < 8 {
        return Err(Error::corrupt("fzlight chunk shorter than outlier"));
    }
    let q0 = i64::from_le_bytes(payload[0..8].try_into().unwrap());
    out.push((q0 as f64 * twoeb) as f32);
    let mut q = q0;
    let mut pos = 8usize;
    let mut remaining = cn - 1;
    while remaining > 0 {
        let cnt = BLOCK.min(remaining);
        let bits = *payload
            .get(pos)
            .ok_or_else(|| Error::corrupt("fzlight block header past end"))? as u32;
        pos += 1;
        if bits == 0 {
            let x = (q as f64 * twoeb) as f32;
            out.resize(out.len() + cnt, x);
        } else {
            if bits > 64 {
                return Err(Error::corrupt(format!("fzlight code length {bits} > 64")));
            }
            let sign_bytes = cnt.div_ceil(8);
            let mag_bytes = (cnt * bits as usize).div_ceil(8);
            let end = pos + sign_bytes + mag_bytes;
            if end > payload.len() {
                return Err(Error::corrupt("fzlight block body past end"));
            }
            let mut sign = 0u32;
            for (k, &byte) in payload[pos..pos + sign_bytes].iter().enumerate() {
                sign |= (byte as u32) << (8 * k);
            }
            super::bits::unpack_fixed(&payload[pos + sign_bytes..end], cnt, bits, |j, mag| {
                let d = mag as i64;
                q += if sign >> j & 1 == 1 { -d } else { d };
                out.push((q as f64 * twoeb) as f32);
            });
            pos = end;
        }
        remaining -= cnt;
    }
    Ok(())
}

#[inline]
fn quantize(x: f32, inv_twoeb: f64) -> i64 {
    // `as` saturates on overflow, which keeps absurd bound/value
    // combinations from UB; realistic bounds never get near the limit.
    (x as f64 * inv_twoeb).round() as i64
}

/// Append a chunked frame (header, chunk table, payloads) to `out`. The
/// chunked layout is shared by fZ-light and SZx, so the codec id is a
/// parameter.
pub(crate) fn assemble_frame_into(
    codec: CompressorKind,
    n: usize,
    eb_abs: f64,
    chunk_values: usize,
    payloads: &[Vec<u8>],
    out: &mut Vec<u8>,
) {
    let total: usize = payloads.iter().map(Vec::len).sum();
    out.reserve(HEADER_LEN + 8 + 4 * payloads.len() + total);
    write_header(out, codec, n, eb_abs);
    le::put_u32(out, chunk_values as u32);
    le::put_u32(out, payloads.len() as u32);
    for p in payloads {
        le::put_u32(out, p.len() as u32);
    }
    for p in payloads {
        out.extend_from_slice(p);
    }
}

/// Compress directly into `out` (append): the chunk table is reserved up
/// front — its length is known from the chunk count — and backfilled as
/// each chunk's payload lands, so the whole frame is built with zero
/// intermediate allocations. Shared by [`FzLight`] and
/// [`super::pipe::PipeFzLight`] (whose `progress` hook runs per chunk).
pub(crate) fn compress_frame_into(
    chunk_values: usize,
    data: &[f32],
    eb: ErrorBound,
    out: &mut Vec<u8>,
    progress: &mut dyn FnMut(usize),
) -> Result<CompressionStats> {
    let eb_abs = eb.resolve(data);
    if !(eb_abs > 0.0) || !eb_abs.is_finite() {
        return Err(Error::invalid(format!("error bound must be positive, got {eb_abs}")));
    }
    let twoeb = 2.0 * eb_abs;
    let chunk = chunk_values.max(1);
    let nchunks = data.len().div_ceil(chunk);
    let mut stats = CompressionStats { raw_bytes: data.len() * 4, ..Default::default() };
    let base = out.len();
    out.reserve(HEADER_LEN + 8 + 4 * nchunks + data.len() * 2);
    write_header(out, CompressorKind::FzLight, data.len(), eb_abs);
    le::put_u32(out, chunk as u32);
    le::put_u32(out, nchunks as u32);
    let table = out.len();
    out.resize(table + 4 * nchunks, 0);
    let mut done = 0usize;
    for (i, c) in data.chunks(chunk).enumerate() {
        let start = out.len();
        let (blocks, constant) = compress_chunk_into(c, twoeb, out);
        stats.blocks += blocks;
        stats.constant_blocks += constant;
        let sz = (out.len() - start) as u32;
        out[table + 4 * i..table + 4 * i + 4].copy_from_slice(&sz.to_le_bytes());
        done += c.len();
        progress(done);
    }
    stats.compressed_bytes = out.len() - base;
    Ok(stats)
}

/// Parsed view over a frame's chunk table: `(chunk_values, payload ranges)`.
pub(crate) fn frame_chunks(bytes: &[u8]) -> Result<(usize, f64, usize, Vec<std::ops::Range<usize>>)> {
    let h = read_header(bytes)?;
    if h.codec != CompressorKind::FzLight {
        return Err(Error::corrupt("not an fzlight frame"));
    }
    let mut pos = HEADER_LEN;
    let chunk_values = le::get_u32(bytes, &mut pos)? as usize;
    let nchunks = le::get_u32(bytes, &mut pos)? as usize;
    if chunk_values == 0 && nchunks > 0 {
        return Err(Error::corrupt("zero chunk_values"));
    }
    let mut sizes = Vec::with_capacity(nchunks);
    for _ in 0..nchunks {
        sizes.push(le::get_u32(bytes, &mut pos)? as usize);
    }
    let mut ranges = Vec::with_capacity(nchunks);
    for s in sizes {
        let end = pos + s;
        if end > bytes.len() {
            return Err(Error::corrupt("fzlight chunk table past frame end"));
        }
        ranges.push(pos..end);
        pos = end;
    }
    Ok((chunk_values, h.eb_abs, h.n, ranges))
}

impl Compressor for FzLight {
    fn kind(&self) -> CompressorKind {
        CompressorKind::FzLight
    }

    fn compress_into(
        &self,
        data: &[f32],
        eb: ErrorBound,
        out: &mut Vec<u8>,
    ) -> Result<CompressionStats> {
        compress_frame_into(self.chunk_values, data, eb, out, &mut |_| {})
    }

    fn decompress_into(&self, bytes: &[u8], out: &mut Vec<f32>) -> Result<usize> {
        let (chunk_values, eb_abs, n, ranges) = frame_chunks(bytes)?;
        let twoeb = 2.0 * eb_abs;
        let start = out.len();
        out.reserve(n);
        for (i, r) in ranges.iter().enumerate() {
            let cn = if i + 1 == ranges.len() {
                n.checked_sub(chunk_values * (ranges.len() - 1))
                    .filter(|&c| c >= 1 && c <= chunk_values)
                    .ok_or_else(|| Error::corrupt("chunk table inconsistent with count"))?
            } else {
                chunk_values
            };
            decompress_chunk(&bytes[r.clone()], cn, twoeb, out)?;
        }
        if out.len() - start != n {
            return Err(Error::corrupt(format!("decoded {} of {n} values", out.len() - start)));
        }
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::fields::{Field, FieldKind};

    fn check_bound(orig: &[f32], dec: &[f32], eb: f64) {
        assert_eq!(orig.len(), dec.len());
        for (i, (a, b)) in orig.iter().zip(dec).enumerate() {
            let err = (*a as f64 - *b as f64).abs();
            // f32 rounding of the reconstruction adds at most ~1 ulp.
            let tol = eb * (1.0 + 1e-5) + a.abs() as f64 * 1e-6;
            assert!(err <= tol, "idx {i}: |{a} - {b}| = {err} > {eb}");
        }
    }

    #[test]
    fn roundtrip_smooth_field_abs_bound() {
        let f = Field::generate(FieldKind::Rtm, 20_000, 3);
        let c = FzLight::default().compress(&f.values, ErrorBound::Abs(1e-3)).unwrap();
        let d = FzLight::default().decompress(&c.bytes).unwrap();
        check_bound(&f.values, &d, 1e-3);
        assert!(c.stats.ratio() > 4.0, "smooth field should compress well, got {}", c.stats.ratio());
    }

    #[test]
    fn roundtrip_all_field_kinds_rel_bounds() {
        for kind in FieldKind::ALL {
            for rel in [1e-1, 1e-2, 1e-3, 1e-4] {
                let f = Field::generate(kind, 8192, 11);
                let eb_abs = ErrorBound::Rel(rel).resolve(&f.values);
                let c = FzLight::default().compress(&f.values, ErrorBound::Rel(rel)).unwrap();
                let d = FzLight::default().decompress(&c.bytes).unwrap();
                check_bound(&f.values, &d, eb_abs);
            }
        }
    }

    #[test]
    fn tiny_inputs() {
        for n in [1usize, 2, 3, 31, 32, 33, 5119, 5120, 5121] {
            let data: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
            let c = FzLight::default().compress(&data, ErrorBound::Abs(1e-4)).unwrap();
            let d = FzLight::default().decompress(&c.bytes).unwrap();
            check_bound(&data, &d, 1e-4);
        }
    }

    #[test]
    fn empty_input() {
        let c = FzLight::default().compress(&[], ErrorBound::Abs(1e-4)).unwrap();
        let d = FzLight::default().decompress(&c.bytes).unwrap();
        assert!(d.is_empty());
    }

    #[test]
    fn constant_input_is_all_constant_blocks() {
        let data = vec![2.5f32; 10_000];
        let c = FzLight::default().compress(&data, ErrorBound::Abs(1e-4)).unwrap();
        assert_eq!(c.stats.constant_blocks, c.stats.blocks);
        assert!(c.stats.ratio() > 100.0, "ratio {}", c.stats.ratio());
        let d = FzLight::default().decompress(&c.bytes).unwrap();
        check_bound(&data, &d, 1e-4);
    }

    #[test]
    fn noise_still_bounded() {
        // Worst case for Lorenzo: white noise.
        let mut rng = crate::data::rng::Rng::new(99);
        let data: Vec<f32> = (0..4096).map(|_| rng.normal() as f32).collect();
        let eb = 1e-5;
        let c = FzLight::default().compress(&data, ErrorBound::Abs(eb)).unwrap();
        let d = FzLight::default().decompress(&c.bytes).unwrap();
        check_bound(&data, &d, eb);
    }

    #[test]
    fn rejects_nonpositive_bound() {
        assert!(FzLight::default().compress(&[1.0], ErrorBound::Abs(0.0)).is_err());
        assert!(FzLight::default().compress(&[1.0], ErrorBound::Abs(-1.0)).is_err());
    }

    #[test]
    fn rejects_truncated_frame() {
        let data = vec![1.0f32; 1000];
        let c = FzLight::default().compress(&data, ErrorBound::Abs(1e-3)).unwrap();
        for cut in [10, HEADER_LEN, c.bytes.len() - 1] {
            assert!(FzLight::default().decompress(&c.bytes[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn chunk_size_does_not_change_numerics() {
        let f = Field::generate(FieldKind::Nyx, 12_345, 5);
        let a = FzLight::with_chunk(512).compress(&f.values, ErrorBound::Abs(1e-3)).unwrap();
        let b = FzLight::with_chunk(5120).compress(&f.values, ErrorBound::Abs(1e-3)).unwrap();
        let da = FzLight::default().decompress(&a.bytes).unwrap();
        let db = FzLight::default().decompress(&b.bytes).unwrap();
        assert_eq!(da, db);
    }

    #[test]
    fn smaller_bound_lower_ratio() {
        let f = Field::generate(FieldKind::Hurricane, 32_768, 2);
        let hi = FzLight::default().compress(&f.values, ErrorBound::Rel(1e-1)).unwrap();
        let lo = FzLight::default().compress(&f.values, ErrorBound::Rel(1e-4)).unwrap();
        assert!(
            hi.stats.ratio() > lo.stats.ratio(),
            "ratio(1e-1)={} should exceed ratio(1e-4)={}",
            hi.stats.ratio(),
            lo.stats.ratio()
        );
        assert!(hi.stats.constant_fraction() >= lo.stats.constant_fraction());
    }
}
