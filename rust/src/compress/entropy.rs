//! Order-0 byte rANS — the optional second compression stage over the
//! fixed-width-packed fZ-light chunk payloads (frame version 2).
//!
//! The staged encoder (`fzlight.rs`) treats a chunk's version-1 payload
//! bytes as an opaque byte string and asks this module to shrink it.
//! Low-entropy scientific fields quantize to deltas whose packed bytes
//! are heavily skewed (zeros from constant runs, short codes reusing a
//! few byte values), which an order-0 model captures well; high-entropy
//! chunks fail [`encode_if_smaller`]'s budget and ship fixed-width
//! unchanged, so the stage is never worse than the budget the caller
//! grants.
//!
//! ## Blob layout
//!
//! ```text
//! mode u8 | table | state u32 LE | stream bytes (decoder reads forward)
//! ```
//!
//! - [`MODE_SINGLE`]: the whole blob is `[2, sym]` — the source was one
//!   repeated byte (or empty); no table, no stream.
//! - [`MODE_LIST`] (2..=32 distinct bytes): `k u8`, then `k` symbol
//!   bytes strictly ascending, then `k` 12-bit frequencies packed
//!   LSB-first ([`bits::pack_fixed`], width 12).
//! - [`MODE_BITMAP`] (33..=256 distinct bytes): a 32-byte presence
//!   bitmap (bit `s & 7` of byte `s >> 3`), then the packed 12-bit
//!   frequencies for the set bits in ascending symbol order.
//!
//! Frequencies are the normalized counts: each in `1..=4095`, summing
//! to exactly [`PROB_SCALE`]. The decoder rejects anything else.
//!
//! ## Coder
//!
//! Standard byte-wise rANS with a 12-bit probability resolution and
//! renormalization interval `[RANS_L, 256 * RANS_L)`. The encoder walks
//! the source **backward** (pre-symbol renorm emits low bytes to a
//! scratch stack), flushes its final state as the `u32`, and appends
//! the scratch reversed so the decoder consumes bytes strictly forward.
//! The decoder's post-symbol refill mirrors the renorm exactly, so
//! after `raw_len` symbols a well-formed blob ends with `state ==
//! RANS_L` and every byte consumed — both are checked, and a failed
//! check is a typed [`Error::Corrupt`], never a panic.
//!
//! Two decoders share one stream walker ([`decode_stream`]):
//! [`decode`] resolves slots through a 4096-entry lookup table (the hot
//! path), [`decode_reference`] linearly scans the cumulative table —
//! the executable spec in the PR 5 style, pinned equal to the fast
//! path by the property tests below and the `tests/codec_kernels.rs`
//! suite.
//!
//! ## Caller contract
//!
//! `raw_len` (the decoded byte count) travels outside the blob — the
//! staged chunk header stores it — and [`decode`] sizes its output from
//! it, so callers must bound it from trusted frame geometry *before*
//! calling (fzlight caps it at the largest possible version-1 chunk
//! payload for the chunk's value count).

use super::bits;
use crate::{Error, Result};

/// Probability resolution in bits: frequencies live on a `1 << 12` grid.
pub const PROB_BITS: u32 = 12;
/// Frequency sum every table must hit exactly (`1 << PROB_BITS`).
pub const PROB_SCALE: u32 = 1 << PROB_BITS;
/// Lower bound of the rANS renormalization interval `[L, 256 * L)`.
const RANS_L: u32 = 1 << 23;

/// Table mode: explicit ascending symbol list (2..=32 distinct bytes).
pub const MODE_LIST: u8 = 0;
/// Table mode: 32-byte presence bitmap (33..=256 distinct bytes).
pub const MODE_BITMAP: u8 = 1;
/// Table mode: single repeated symbol; blob is exactly `[2, sym]`.
pub const MODE_SINGLE: u8 = 2;

/// Largest symbol count encoded as an explicit list; beyond this the
/// 32-byte bitmap is smaller.
const LIST_MAX: usize = 32;

/// Parsed frequency table: ascending symbols with their normalized
/// frequencies and exclusive cumulative offsets.
struct Table {
    syms: Vec<u8>,
    freqs: Vec<u32>,
    cums: Vec<u32>,
}

/// Normalize per-symbol counts onto the [`PROB_SCALE`] grid: every
/// present symbol keeps a frequency `>= 1`, the sum lands exactly on
/// `PROB_SCALE`. Surplus goes to the most frequent symbol (which the
/// floor always leaves headroom for when `k >= 2`); a deficit is walked
/// off the largest frequencies one unit at a time (bounded: at most
/// `k - 1` clamp-ups created it).
fn normalize(hist: &[u32; 256], syms: &[u8], total: usize) -> Vec<u16> {
    debug_assert!(syms.len() >= 2);
    let mut freqs: Vec<u16> = syms
        .iter()
        .map(|&s| {
            let exact = hist[s as usize] as u64 * PROB_SCALE as u64 / total as u64;
            exact.clamp(1, PROB_SCALE as u64 - 1) as u16
        })
        .collect();
    let sum: i64 = freqs.iter().map(|&f| f as i64).sum();
    let mut diff = PROB_SCALE as i64 - sum;
    if diff > 0 {
        let top = (0..freqs.len()).max_by_key(|&i| freqs[i]).unwrap_or(0);
        freqs[top] += diff as u16;
    }
    while diff < 0 {
        let top = (0..freqs.len()).filter(|&i| freqs[i] > 1).max_by_key(|&i| freqs[i]);
        let top = top.expect("deficit exceeds reducible mass");
        freqs[top] -= 1;
        diff += 1;
    }
    debug_assert_eq!(freqs.iter().map(|&f| f as u32).sum::<u32>(), PROB_SCALE);
    freqs
}

/// Byte length of the serialized table (mode byte included) for `k`
/// distinct symbols, `k >= 2`.
fn table_bytes(k: usize) -> usize {
    let head = if k <= LIST_MAX { 2 + k } else { 1 + 32 };
    head + (k * PROB_BITS as usize).div_ceil(8)
}

/// Serialize the mode byte + table for `syms`/`freqs` (`k >= 2`).
fn write_table(out: &mut Vec<u8>, syms: &[u8], freqs: &[u16]) {
    if syms.len() <= LIST_MAX {
        out.push(MODE_LIST);
        out.push(syms.len() as u8);
        out.extend_from_slice(syms);
    } else {
        out.push(MODE_BITMAP);
        let mut bm = [0u8; 32];
        for &s in syms {
            bm[(s >> 3) as usize] |= 1 << (s & 7);
        }
        out.extend_from_slice(&bm);
    }
    let packed: Vec<u64> = freqs.iter().map(|&f| f as u64).collect();
    bits::pack_fixed(out, &packed, PROB_BITS);
}

/// Parse and validate the table at the head of `blob` (modes LIST and
/// BITMAP — the caller handles [`MODE_SINGLE`] first). Returns the
/// table and the offset of the `u32` state word. Every malformation —
/// unknown mode, out-of-range symbol count, non-ascending list, zero
/// frequency, wrong frequency sum, truncation — is a typed error.
fn parse_table(blob: &[u8]) -> Result<(Table, usize)> {
    let mode = *blob.first().ok_or_else(|| Error::corrupt("empty entropy blob"))?;
    let (syms, mut pos) = match mode {
        MODE_LIST => {
            let k = *blob.get(1).ok_or_else(|| Error::corrupt("entropy list count past end"))?
                as usize;
            if !(2..=LIST_MAX).contains(&k) {
                return Err(Error::corrupt(format!("entropy list count {k} out of range")));
            }
            let syms = blob
                .get(2..2 + k)
                .ok_or_else(|| Error::corrupt("entropy symbol list past end"))?
                .to_vec();
            if !syms.windows(2).all(|w| w[0] < w[1]) {
                return Err(Error::corrupt("entropy symbol list not ascending"));
            }
            (syms, 2 + k)
        }
        MODE_BITMAP => {
            let bm = blob
                .get(1..33)
                .ok_or_else(|| Error::corrupt("entropy bitmap past end"))?;
            let syms: Vec<u8> = (0u16..256)
                .filter(|&s| bm[(s >> 3) as usize] & (1 << (s & 7)) != 0)
                .map(|s| s as u8)
                .collect();
            if syms.len() < 2 {
                return Err(Error::corrupt("entropy bitmap needs >= 2 symbols"));
            }
            (syms, 33)
        }
        m => return Err(Error::corrupt(format!("unknown entropy table mode {m}"))),
    };
    let nbytes = (syms.len() * PROB_BITS as usize).div_ceil(8);
    let packed = blob
        .get(pos..pos + nbytes)
        .ok_or_else(|| Error::corrupt("entropy freq table past end"))?;
    pos += nbytes;
    let mut raw = vec![0u64; syms.len()];
    bits::unpack_fixed(packed, PROB_BITS, &mut raw);
    let mut freqs = Vec::with_capacity(syms.len());
    let mut cums = Vec::with_capacity(syms.len());
    let mut cum = 0u32;
    for f in raw {
        if f == 0 {
            return Err(Error::corrupt("entropy frequency of zero"));
        }
        cums.push(cum);
        cum += f as u32;
        freqs.push(f as u32);
    }
    if cum != PROB_SCALE {
        return Err(Error::corrupt(format!("entropy freq sum {cum} != {PROB_SCALE}")));
    }
    Ok((Table { syms, freqs, cums }, pos))
}

/// Append the rANS stream (state word + bytes) for `src` under the
/// per-byte `(freq, cum)` model in `f_of`/`c_of`.
fn encode_stream(src: &[u8], f_of: &[u32; 256], c_of: &[u32; 256], out: &mut Vec<u8>) {
    let mut state: u32 = RANS_L;
    let mut tail: Vec<u8> = Vec::with_capacity(src.len() / 2 + 8);
    for &b in src.iter().rev() {
        let f = f_of[b as usize];
        debug_assert!(f >= 1);
        // Pre-symbol renorm keeps the post-encode state inside
        // [RANS_L, 256 * RANS_L), so it always fits the u32 flush.
        let x_max = ((RANS_L >> PROB_BITS) << 8) * f;
        while state >= x_max {
            tail.push(state as u8);
            state >>= 8;
        }
        state = ((state / f) << PROB_BITS) + (state % f) + c_of[b as usize];
    }
    bits::le::put_u32(out, state);
    out.extend(tail.iter().rev());
}

/// Shared stream walker for both decoders: read the state word at
/// `pos`, emit `raw_len` symbols resolving each 12-bit slot through
/// `lookup` (returns the symbol byte, its frequency, and its cumulative
/// offset), refilling byte-by-byte after each symbol. Enforces the
/// final-state and all-bytes-consumed integrity checks.
fn decode_stream(
    blob: &[u8],
    mut pos: usize,
    raw_len: usize,
    out: &mut Vec<u8>,
    mut lookup: impl FnMut(u32) -> (u8, u32, u32),
) -> Result<()> {
    let mut state = bits::le::get_u32(blob, &mut pos)?;
    if state < RANS_L {
        return Err(Error::corrupt("entropy state below renorm interval"));
    }
    out.reserve(raw_len);
    for _ in 0..raw_len {
        let slot = state & (PROB_SCALE - 1);
        let (sym, f, c) = lookup(slot);
        // slot >= c by table construction, and f * (state >> 12) tops
        // out below 2^32 even for a forged state — no overflow.
        state = f * (state >> PROB_BITS) + slot - c;
        out.push(sym);
        while state < RANS_L {
            let b = *blob
                .get(pos)
                .ok_or_else(|| Error::corrupt("entropy stream exhausted"))?;
            pos += 1;
            state = (state << 8) | b as u32;
        }
    }
    if state != RANS_L {
        return Err(Error::corrupt("entropy final state mismatch"));
    }
    if pos != blob.len() {
        return Err(Error::corrupt("entropy trailing bytes"));
    }
    Ok(())
}

/// Entropy-code `src`, appending the blob to `out`. Always succeeds
/// (single-symbol and empty sources collapse to the 2-byte
/// [`MODE_SINGLE`] blob). Prefer [`encode_if_smaller`] when the caller
/// has a size budget to beat.
pub fn encode(src: &[u8], out: &mut Vec<u8>) {
    let n = encode_if_smaller(src, usize::MAX, out);
    debug_assert!(n.is_some());
}

/// Entropy-code `src` only if the blob fits in `budget` bytes: returns
/// the appended blob length, or `None` with `out` untouched. A cheap
/// conservative size estimate (information content under the
/// normalized model) skips hopeless high-entropy chunks before any
/// encoding work; the final length check on the real blob is
/// authoritative either way.
pub fn encode_if_smaller(src: &[u8], budget: usize, out: &mut Vec<u8>) -> Option<usize> {
    let base = out.len();
    let mut hist = [0u32; 256];
    for &b in src {
        hist[b as usize] += 1;
    }
    let syms: Vec<u8> = (0u16..256).filter(|&s| hist[s as usize] > 0).map(|s| s as u8).collect();
    if syms.len() <= 1 {
        if budget < 2 {
            return None;
        }
        out.push(MODE_SINGLE);
        out.push(syms.first().copied().unwrap_or(0));
        return Some(2);
    }
    let freqs = normalize(&hist, &syms, src.len());
    // Estimate: header + state word + the stream's information content
    // under the code. The real stream recovers up to ~4 bytes from the
    // flushed state, so the +8 slack keeps the skip strictly
    // conservative — a chunk skipped here could never have fit.
    let mut ideal_bits = 0.0f64;
    for (i, &s) in syms.iter().enumerate() {
        let c = hist[s as usize] as f64;
        ideal_bits += c * (PROB_SCALE as f64 / freqs[i] as f64).log2();
    }
    let est = table_bytes(syms.len()) + 4 + (ideal_bits / 8.0) as usize;
    if est > budget.saturating_add(8) {
        return None;
    }
    let mut f_of = [0u32; 256];
    let mut c_of = [0u32; 256];
    let mut cum = 0u32;
    for (i, &s) in syms.iter().enumerate() {
        f_of[s as usize] = freqs[i] as u32;
        c_of[s as usize] = cum;
        cum += freqs[i] as u32;
    }
    write_table(out, &syms, &freqs);
    encode_stream(src, &f_of, &c_of, out);
    let len = out.len() - base;
    if len > budget {
        out.truncate(base);
        return None;
    }
    Some(len)
}

/// Decode a blob produced by [`encode`] back into exactly `raw_len`
/// bytes appended to `out` — the fast path (4096-entry slot lookup
/// table). `raw_len` is trusted sizing input; see the module docs for
/// the caller's bounding contract. Any malformation is a typed
/// [`Error::Corrupt`]; on error `out` may hold a partial suffix (frame
/// callers decode into scratch and discard on error).
pub fn decode(blob: &[u8], raw_len: usize, out: &mut Vec<u8>) -> Result<()> {
    let mode = *blob.first().ok_or_else(|| Error::corrupt("empty entropy blob"))?;
    if mode == MODE_SINGLE {
        if blob.len() != 2 {
            return Err(Error::corrupt("entropy single-symbol blob must be 2 bytes"));
        }
        out.resize(out.len() + raw_len, blob[1]);
        return Ok(());
    }
    let (t, pos) = parse_table(blob)?;
    let mut lut = [0u8; PROB_SCALE as usize];
    for (i, (&f, &c)) in t.freqs.iter().zip(&t.cums).enumerate() {
        for slot in c..c + f {
            lut[slot as usize] = i as u8;
        }
    }
    decode_stream(blob, pos, raw_len, out, |slot| {
        let i = lut[slot as usize] as usize;
        (t.syms[i], t.freqs[i], t.cums[i])
    })
}

/// Scalar reference decoder: identical stream walk, but each slot is
/// resolved by a linear scan of the cumulative table. The executable
/// spec for the blob layout (PR 5 style) — pinned bit-equal to
/// [`decode`] by the property suite; not a hot path.
pub fn decode_reference(blob: &[u8], raw_len: usize, out: &mut Vec<u8>) -> Result<()> {
    let mode = *blob.first().ok_or_else(|| Error::corrupt("empty entropy blob"))?;
    if mode == MODE_SINGLE {
        if blob.len() != 2 {
            return Err(Error::corrupt("entropy single-symbol blob must be 2 bytes"));
        }
        out.resize(out.len() + raw_len, blob[1]);
        return Ok(());
    }
    let (t, pos) = parse_table(blob)?;
    decode_stream(blob, pos, raw_len, out, |slot| {
        let mut i = 0usize;
        while i + 1 < t.cums.len() && t.cums[i + 1] <= slot {
            i += 1;
        }
        (t.syms[i], t.freqs[i], t.cums[i])
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    fn roundtrip(src: &[u8]) {
        let mut blob = Vec::new();
        encode(src, &mut blob);
        let mut fast = Vec::new();
        decode(&blob, src.len(), &mut fast).expect("fast decode");
        assert_eq!(fast, src, "fast roundtrip ({} bytes)", src.len());
        let mut reference = Vec::new();
        decode_reference(&blob, src.len(), &mut reference).expect("reference decode");
        assert_eq!(reference, src, "reference roundtrip ({} bytes)", src.len());
    }

    #[test]
    fn roundtrips_across_source_shapes() {
        let mut rng = Rng::new(0xE27);
        roundtrip(&[]);
        roundtrip(&[42]);
        roundtrip(&[7; 1000]); // single symbol
        // Two skewed symbols.
        let two: Vec<u8> = (0..4096).map(|_| if rng.below(16) == 0 { 1 } else { 0 }).collect();
        roundtrip(&two);
        // <= 32 symbols (list table).
        let list: Vec<u8> = (0..3000).map(|_| (rng.below(20) * 3) as u8).collect();
        roundtrip(&list);
        // > 32 symbols (bitmap table), geometric-ish skew.
        let bm: Vec<u8> = (0..5000)
            .map(|_| {
                let r = rng.below(256) as u8;
                r & (rng.below(256) as u8) // biased toward small values
            })
            .collect();
        roundtrip(&bm);
        // Full-range uniform (worst case: ratio ~1, still exact).
        let uni: Vec<u8> = (0..2048).map(|_| rng.below(256) as u8).collect();
        roundtrip(&uni);
        // All 256 symbols present at least once.
        let mut all: Vec<u8> = (0u16..256).map(|s| s as u8).collect();
        all.extend((0..1000).map(|_| (rng.below(256)) as u8));
        roundtrip(&all);
    }

    #[test]
    fn single_symbol_blob_is_two_bytes() {
        let mut blob = Vec::new();
        encode(&[9u8; 500], &mut blob);
        assert_eq!(blob, vec![MODE_SINGLE, 9]);
        let mut out = Vec::new();
        decode(&blob, 500, &mut out).unwrap();
        assert_eq!(out, vec![9u8; 500]);
        // Empty source: same shape, symbol 0, decodes to nothing.
        let mut blob = Vec::new();
        encode(&[], &mut blob);
        assert_eq!(blob, vec![MODE_SINGLE, 0]);
        let mut out = Vec::new();
        decode(&blob, 0, &mut out).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn encode_if_smaller_budget_semantics() {
        let mut rng = Rng::new(3);
        let skewed: Vec<u8> = (0..4096).map(|_| if rng.below(8) == 0 { 3 } else { 0 }).collect();
        let mut full = Vec::new();
        encode(&skewed, &mut full);
        assert!(full.len() < skewed.len() / 2, "skewed source must shrink well");
        // Exactly-fitting budget succeeds and appends after a prefix.
        let mut out = vec![0xAA, 0xBB];
        let got = encode_if_smaller(&skewed, full.len(), &mut out);
        assert_eq!(got, Some(full.len()));
        assert_eq!(&out[..2], &[0xAA, 0xBB]);
        assert_eq!(&out[2..], &full[..]);
        // One byte under the real size: refused, out untouched.
        let mut out = vec![0xCC];
        assert_eq!(encode_if_smaller(&skewed, full.len() - 1, &mut out), None);
        assert_eq!(out, vec![0xCC]);
        // Uniform bytes can never beat their own length.
        let uni: Vec<u8> = (0..2048).map(|_| rng.below(256) as u8).collect();
        let mut out = Vec::new();
        assert_eq!(encode_if_smaller(&uni, uni.len() - 1, &mut out), None);
        assert!(out.is_empty());
        // Single-symbol source under a 1-byte budget: refused.
        let mut out = Vec::new();
        assert_eq!(encode_if_smaller(&[5; 100], 1, &mut out), None);
        assert_eq!(encode_if_smaller(&[5; 100], 2, &mut out), Some(2));
    }

    #[test]
    fn corrupt_blobs_error_cleanly() {
        let mut rng = Rng::new(0xBAD);
        let src: Vec<u8> = (0..2000).map(|_| (rng.below(40) * 2) as u8).collect();
        let mut blob = Vec::new();
        encode(&src, &mut blob);
        // Every single-bit flip: Err, or Ok with the right length.
        for pos in 0..blob.len() {
            for bit in 0..8 {
                let mut bad = blob.clone();
                bad[pos] ^= 1 << bit;
                let mut out = Vec::new();
                if decode(&bad, src.len(), &mut out).is_ok() {
                    assert_eq!(out.len(), src.len(), "flip at {pos}.{bit}");
                }
                let mut out = Vec::new();
                if decode_reference(&bad, src.len(), &mut out).is_ok() {
                    assert_eq!(out.len(), src.len(), "flip at {pos}.{bit} (reference)");
                }
            }
        }
        // Every truncation point: must error (stream exhausts or table
        // parse fails — never a panic).
        for cut in 0..blob.len() {
            let mut out = Vec::new();
            assert!(decode(&blob[..cut], src.len(), &mut out).is_err(), "cut {cut}");
        }
        // Trailing garbage is rejected by the all-consumed check.
        let mut padded = blob.clone();
        padded.push(0);
        let mut out = Vec::new();
        assert!(decode(&padded, src.len(), &mut out).is_err());
        // A wrong raw_len must never pass the final state checks.
        for wrong in [src.len() - 1, src.len() + 1, 0] {
            let mut out = Vec::new();
            assert!(decode(&blob, wrong, &mut out).is_err(), "raw_len {wrong}");
        }
    }

    #[test]
    fn forged_tables_are_rejected() {
        // Unknown mode byte.
        let mut out = Vec::new();
        assert!(decode(&[9, 0, 0, 0, 0], 4, &mut out).is_err());
        // List count out of range (0, 1, 33).
        for k in [0u8, 1, 33] {
            assert!(decode(&[MODE_LIST, k, 0, 0, 0, 0, 0], 4, &mut out).is_err());
        }
        // Non-ascending symbol list.
        let mut bad = vec![MODE_LIST, 2, 5, 5];
        bits::pack_fixed(&mut bad, &[2048, 2048], PROB_BITS);
        bits::le::put_u32(&mut bad, RANS_L);
        assert!(decode(&bad, 1, &mut out).is_err());
        // Frequency sum off the grid.
        let mut bad = vec![MODE_LIST, 2, 0, 1];
        bits::pack_fixed(&mut bad, &[2048, 2047], PROB_BITS);
        bits::le::put_u32(&mut bad, RANS_L);
        assert!(decode(&bad, 1, &mut out).is_err());
        // Zero frequency (rejected before the sum check).
        let mut bad = vec![MODE_LIST, 2, 0, 1];
        bits::pack_fixed(&mut bad, &[0, 4095], PROB_BITS);
        bits::le::put_u32(&mut bad, RANS_L);
        assert!(decode(&bad, 1, &mut out).is_err());
        // State below the renorm interval.
        let mut bad = vec![MODE_LIST, 2, 0, 1];
        bits::pack_fixed(&mut bad, &[2048, 2048], PROB_BITS);
        bits::le::put_u32(&mut bad, RANS_L - 1);
        assert!(decode(&bad, 1, &mut out).is_err());
        // Single-symbol blob with trailing bytes.
        assert!(decode(&[MODE_SINGLE, 7, 0], 3, &mut out).is_err());
    }

    #[test]
    fn ratio_beats_fixed_on_skewed_bytes() {
        // The staged selector's whole premise: heavily-skewed payload
        // bytes (what low-entropy fields quantize to) shrink well.
        let mut rng = Rng::new(11);
        let src: Vec<u8> = (0..8192).map(|_| if rng.below(10) == 0 { 1 } else { 0 }).collect();
        let mut blob = Vec::new();
        encode(&src, &mut blob);
        assert!(
            blob.len() * 2 < src.len(),
            "skewed bytes must shrink >= 2x, got {} -> {}",
            src.len(),
            blob.len()
        );
    }
}
