//! Multi-thread compression mode (the paper's "ZCCL (multi-thread)").
//!
//! fZ-light's and SZx's chunked frame layout makes chunks independent, so
//! compression and decompression parallelise over chunks with rayon.
//! Numerics and the emitted frame are **bit-identical** to the
//! single-thread path — only wall-clock changes. Each worker runs the
//! same word-parallel block-batched kernels as the serial codecs, so
//! thread scaling stacks on top of the single-core codec speedups
//! tracked in `BENCH_codec.json`.
//!
//! NOTE (DESIGN.md §2): this container exposes a single core, so measured
//! multi-thread speedup here is ~1×. The virtual-time simulator applies a
//! calibrated thread-scaling model for the paper's multi-thread figures;
//! this module keeps the *implementation* real and testable.

use crate::util::par::{default_threads, par_map_chunks, par_map_own};

use super::fzlight::{self};
use super::szx::{self};
use super::traits::{
    CompressionStats, Compressor, CompressorKind, ErrorBound, VERSION, VERSION_STAGED,
};
use crate::ops::ReduceOp;
use crate::{Error, Result};

/// Multi-threaded wrapper over a chunk-parallel codec.
#[derive(Debug, Clone)]
pub struct MtCompressor {
    /// Underlying codec (FzLight and Szx parallelise; others run serially).
    pub kind: CompressorKind,
    /// Values per chunk.
    pub chunk_values: usize,
    /// Worker threads (defaults to the host's parallelism).
    pub threads: usize,
    /// Emit staged (version-2) fZ-light frames — see
    /// [`super::fzlight`]'s module docs. Ignored for other codecs; decode
    /// always accepts both versions.
    pub staged: bool,
}

impl MtCompressor {
    /// Construct for `kind` with the codec's default chunk size.
    pub fn new(kind: CompressorKind) -> Self {
        MtCompressor {
            kind,
            chunk_values: fzlight::DEFAULT_CHUNK,
            threads: default_threads(),
            staged: false,
        }
    }

    /// Construct with an explicit chunk size and default threads.
    pub fn with_chunk(kind: CompressorKind, chunk_values: usize) -> Self {
        MtCompressor { kind, chunk_values, threads: default_threads(), staged: false }
    }

    /// Toggle staged (version-2) fZ-light encoding.
    pub fn with_staged(mut self, staged: bool) -> Self {
        self.staged = staged;
        self
    }
}

impl Compressor for MtCompressor {
    fn kind(&self) -> CompressorKind {
        self.kind
    }

    fn compress_into(
        &self,
        data: &[f32],
        eb: ErrorBound,
        out: &mut Vec<u8>,
    ) -> Result<CompressionStats> {
        let eb_abs = eb.resolve(data);
        if !(eb_abs > 0.0) || !eb_abs.is_finite() {
            return Err(Error::invalid(format!("error bound must be positive, got {eb_abs}")));
        }
        match self.kind {
            CompressorKind::FzLight | CompressorKind::Szx => {
                // Chunks compress in parallel into independently owned
                // payloads (inherent to the fan-out), then one pass
                // assembles the shared chunked frame layout into `out`.
                let kind = self.kind;
                let staged = self.staged && kind == CompressorKind::FzLight;
                let parts: Vec<(Vec<u8>, usize, usize, u8)> =
                    par_map_chunks(data, self.chunk_values, self.threads, |chunk| {
                        match kind {
                            CompressorKind::FzLight if staged => {
                                fzlight::compress_chunk_staged(chunk, 2.0 * eb_abs)
                            }
                            CompressorKind::FzLight => {
                                let (p, b, c) = fzlight::compress_chunk(chunk, 2.0 * eb_abs);
                                (p, b, c, fzlight::STAGE_FIXED)
                            }
                            _ => {
                                let (p, b, c) = szx::compress_chunk(chunk, eb_abs);
                                (p, b, c, fzlight::STAGE_FIXED)
                            }
                        }
                    });
                let mut stats =
                    CompressionStats { raw_bytes: data.len() * 4, ..Default::default() };
                let payloads: Vec<Vec<u8>> = parts
                    .into_iter()
                    .map(|(p, b, c, tag)| {
                        stats.blocks += b;
                        stats.constant_blocks += c;
                        if staged {
                            stats.chunks += 1;
                            stats.entropy_chunks += usize::from(tag == fzlight::STAGE_ENTROPY);
                            stats.plain_chunks += usize::from(tag == fzlight::STAGE_PLAIN);
                        }
                        p
                    })
                    .collect();
                let base = out.len();
                fzlight::assemble_frame_into(
                    kind,
                    data.len(),
                    eb_abs,
                    self.chunk_values,
                    &payloads,
                    if staged { VERSION_STAGED } else { VERSION },
                    out,
                )?;
                stats.compressed_bytes = out.len() - base;
                Ok(stats)
            }
            other => super::build(other).compress_into(data, ErrorBound::Abs(eb_abs), out),
        }
    }

    fn decompress_into(&self, bytes: &[u8], out: &mut Vec<f32>) -> Result<usize> {
        match self.kind {
            CompressorKind::FzLight => {
                let (geom, ranges) = fzlight::frame_chunks(bytes)?;
                fzlight::validate_frame_count(bytes, &ranges, &geom)?;
                // Pre-size once; chunks then decode in parallel straight
                // into their disjoint windows of the destination (no
                // per-chunk temporaries, no gather copy).
                let start = out.len();
                out.resize(start + geom.n, 0.0);
                let res = mt_decode_chunks(bytes, &ranges, &geom, self.threads, &mut out[start..]);
                match res {
                    Ok(()) => Ok(geom.n),
                    Err(e) => {
                        out.truncate(start);
                        Err(e)
                    }
                }
            }
            other => super::build(other).decompress_into(bytes, out),
        }
    }

    fn decompress_into_slice(&self, bytes: &[u8], out: &mut [f32]) -> Result<usize> {
        match self.kind {
            CompressorKind::FzLight => {
                let (geom, ranges) = fzlight::frame_chunks_for_slice(bytes, out.len())?;
                // Chunks decode in parallel straight into their disjoint
                // windows of the destination — same walk as the plain MT
                // decode, minus the Vec bookkeeping. On Err an arbitrary
                // subset of windows is written (poisoned; see the trait).
                mt_decode_chunks(bytes, &ranges, &geom, self.threads, out)?;
                Ok(geom.n)
            }
            other => super::build(other).decompress_into_slice(bytes, out),
        }
    }

    fn supports_placement_decode(&self) -> bool {
        self.kind == CompressorKind::FzLight
    }

    fn decompress_fold_into(&self, bytes: &[u8], op: ReduceOp, acc: &mut [f32]) -> Result<usize> {
        match self.kind {
            CompressorKind::FzLight => {
                let (geom, ranges) = fzlight::frame_chunks(bytes)?;
                let n = geom.n;
                if acc.len() != n {
                    return Err(Error::invalid(format!(
                        "fused fold: frame holds {n} values but accumulator holds {}",
                        acc.len()
                    )));
                }
                let twoeb = 2.0 * geom.eb_abs;
                let staged = geom.staged;
                // Chunks map to disjoint accumulator windows, so the fused
                // kernel parallelises with no synchronisation on `acc`;
                // per-element fold order inside a window is serial, so the
                // result is bit-identical to the single-thread kernel.
                let items = chunk_windows(&ranges, geom.chunk_values, n, acc)?;
                let parts = par_map_own(items, self.threads, |_, (r, cn, dst)| {
                    fzlight::decompress_fold_chunk(&bytes[r], cn, twoeb, staged, op, dst)
                });
                for p in parts {
                    p?;
                }
                Ok(n)
            }
            other => super::build(other).decompress_fold_into(bytes, op, acc),
        }
    }

    fn supports_fused_fold(&self) -> bool {
        self.kind == CompressorKind::FzLight
    }
}

/// Decode every chunk of a parsed fZ-light frame into `dst`
/// (`dst.len() == n`), chunks in parallel across disjoint windows.
fn mt_decode_chunks(
    bytes: &[u8],
    ranges: &[std::ops::Range<usize>],
    geom: &fzlight::FrameGeom,
    threads: usize,
    dst: &mut [f32],
) -> Result<()> {
    let twoeb = 2.0 * geom.eb_abs;
    let staged = geom.staged;
    let items = chunk_windows(ranges, geom.chunk_values, geom.n, dst)?;
    let parts = par_map_own(items, threads, |_, (r, cn, d)| {
        fzlight::decompress_chunk_into_slice(&bytes[r], cn, twoeb, staged, d)
    });
    for p in parts {
        p?;
    }
    Ok(())
}

/// Pair each chunk's payload range (and value count) with its disjoint
/// window of `dst`, validating the chunk table against the element count
/// while splitting. The windows are handed to workers **by value** via
/// [`par_map_own`].
fn chunk_windows<'d>(
    ranges: &[std::ops::Range<usize>],
    chunk_values: usize,
    n: usize,
    mut dst: &'d mut [f32],
) -> Result<Vec<(std::ops::Range<usize>, usize, &'d mut [f32])>> {
    debug_assert_eq!(dst.len(), n);
    let mut items = Vec::with_capacity(ranges.len());
    for (i, r) in ranges.iter().enumerate() {
        let cn = fzlight::chunk_value_count(i, ranges.len(), n, chunk_values)?;
        if cn > dst.len() {
            return Err(Error::corrupt("chunk table exceeds element count"));
        }
        let (head, tail) = std::mem::take(&mut dst).split_at_mut(cn);
        items.push((r.clone(), cn, head));
        dst = tail;
    }
    if !dst.is_empty() {
        return Err(Error::corrupt("chunk table short of element count"));
    }
    Ok(items)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::fields::{Field, FieldKind};
    use crate::compress::{FzLight, Szx};

    #[test]
    fn mt_fzlight_bit_identical_to_st() {
        let f = Field::generate(FieldKind::Nyx, 40_000, 77);
        let st = FzLight::default().compress(&f.values, ErrorBound::Rel(1e-3)).unwrap();
        let mt = MtCompressor::new(CompressorKind::FzLight)
            .compress(&f.values, ErrorBound::Rel(1e-3))
            .unwrap();
        assert_eq!(st.bytes, mt.bytes);
        assert_eq!(st.stats.blocks, mt.stats.blocks);
        assert_eq!(st.stats.constant_blocks, mt.stats.constant_blocks);
    }

    #[test]
    fn mt_szx_bit_identical_to_st() {
        let f = Field::generate(FieldKind::Cesm, 33_000, 78);
        let st = Szx::default().compress(&f.values, ErrorBound::Rel(1e-2)).unwrap();
        let mt = MtCompressor::new(CompressorKind::Szx)
            .compress(&f.values, ErrorBound::Rel(1e-2))
            .unwrap();
        assert_eq!(st.bytes, mt.bytes);
    }

    #[test]
    fn mt_decode_matches_st_decode() {
        let f = Field::generate(FieldKind::Rtm, 50_000, 79);
        let c = FzLight::default().compress(&f.values, ErrorBound::Abs(1e-4)).unwrap();
        let st = FzLight::default().decompress(&c.bytes).unwrap();
        let mt = MtCompressor::new(CompressorKind::FzLight).decompress(&c.bytes).unwrap();
        assert_eq!(st, mt);
    }

    #[test]
    fn mt_fused_fold_bit_identical_to_st_fused() {
        use crate::ops::ReduceOp;
        let f = Field::generate(FieldKind::Hurricane, 50_000, 80);
        let c = FzLight::default().compress(&f.values, ErrorBound::Abs(1e-4)).unwrap();
        let base = Field::generate(FieldKind::Cesm, 50_000, 81).values;
        for op in [ReduceOp::Sum, ReduceOp::Max, ReduceOp::Min] {
            let mut st = base.clone();
            FzLight::default().decompress_fold_into(&c.bytes, op, &mut st).unwrap();
            let mut mt = base.clone();
            MtCompressor::new(CompressorKind::FzLight)
                .decompress_fold_into(&c.bytes, op, &mut mt)
                .unwrap();
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&st), bits(&mt), "{op:?}");
        }
    }

    #[test]
    fn mt_staged_bit_identical_to_st_staged() {
        let f = Field::generate(FieldKind::Nyx, 40_000, 82);
        let st = FzLight::default()
            .with_staged(true)
            .compress(&f.values, ErrorBound::Rel(1e-3))
            .unwrap();
        let mt = MtCompressor::new(CompressorKind::FzLight)
            .with_staged(true)
            .compress(&f.values, ErrorBound::Rel(1e-3))
            .unwrap();
        assert_eq!(st.bytes, mt.bytes, "staged MT frame must be bit-identical to ST");
        assert_eq!(st.stats.chunks, mt.stats.chunks);
        assert_eq!(st.stats.entropy_chunks, mt.stats.entropy_chunks);
        assert_eq!(st.stats.plain_chunks, mt.stats.plain_chunks);
        let d_st = FzLight::default().decompress(&st.bytes).unwrap();
        let d_mt = MtCompressor::new(CompressorKind::FzLight).decompress(&mt.bytes).unwrap();
        assert_eq!(d_st, d_mt);
        let mut placed = vec![0.0f32; f.values.len()];
        MtCompressor::new(CompressorKind::FzLight)
            .decompress_into_slice(&mt.bytes, &mut placed)
            .unwrap();
        assert_eq!(placed, d_st);
    }

    #[test]
    fn mt_staged_szx_ignores_flag() {
        let f = Field::generate(FieldKind::Cesm, 20_000, 83);
        let plain = MtCompressor::new(CompressorKind::Szx)
            .compress(&f.values, ErrorBound::Rel(1e-2))
            .unwrap();
        let flagged = MtCompressor::new(CompressorKind::Szx)
            .with_staged(true)
            .compress(&f.values, ErrorBound::Rel(1e-2))
            .unwrap();
        assert_eq!(plain.bytes, flagged.bytes, "staged is fZ-light-only");
    }
}
