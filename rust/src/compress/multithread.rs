//! Multi-thread compression mode (the paper's "ZCCL (multi-thread)").
//!
//! fZ-light's and SZx's chunked frame layout makes chunks independent, so
//! compression and decompression parallelise over chunks with rayon.
//! Numerics and the emitted frame are **bit-identical** to the
//! single-thread path — only wall-clock changes.
//!
//! NOTE (DESIGN.md §2): this container exposes a single core, so measured
//! multi-thread speedup here is ~1×. The virtual-time simulator applies a
//! calibrated thread-scaling model for the paper's multi-thread figures;
//! this module keeps the *implementation* real and testable.

use crate::util::par::{default_threads, par_map, par_map_chunks};

use super::fzlight::{self};
use super::szx::{self};
use super::traits::{Compressed, CompressionStats, Compressor, CompressorKind, ErrorBound};
use crate::{Error, Result};

/// Multi-threaded wrapper over a chunk-parallel codec.
#[derive(Debug, Clone)]
pub struct MtCompressor {
    /// Underlying codec (FzLight and Szx parallelise; others run serially).
    pub kind: CompressorKind,
    /// Values per chunk.
    pub chunk_values: usize,
    /// Worker threads (defaults to the host's parallelism).
    pub threads: usize,
}

impl MtCompressor {
    /// Construct for `kind` with the codec's default chunk size.
    pub fn new(kind: CompressorKind) -> Self {
        MtCompressor { kind, chunk_values: fzlight::DEFAULT_CHUNK, threads: default_threads() }
    }

    /// Construct with an explicit chunk size and default threads.
    pub fn with_chunk(kind: CompressorKind, chunk_values: usize) -> Self {
        MtCompressor { kind, chunk_values, threads: default_threads() }
    }
}

impl Compressor for MtCompressor {
    fn kind(&self) -> CompressorKind {
        self.kind
    }

    fn compress(&self, data: &[f32], eb: ErrorBound) -> Result<Compressed> {
        let eb_abs = eb.resolve(data);
        if !(eb_abs > 0.0) || !eb_abs.is_finite() {
            return Err(Error::invalid(format!("error bound must be positive, got {eb_abs}")));
        }
        match self.kind {
            CompressorKind::FzLight => {
                let twoeb = 2.0 * eb_abs;
                let parts: Vec<(Vec<u8>, usize, usize)> =
                    par_map_chunks(data, self.chunk_values, self.threads, |chunk| {
                        fzlight::compress_chunk(chunk, twoeb)
                    });
                let mut stats =
                    CompressionStats { raw_bytes: data.len() * 4, ..Default::default() };
                let payloads: Vec<Vec<u8>> = parts
                    .into_iter()
                    .map(|(p, b, c)| {
                        stats.blocks += b;
                        stats.constant_blocks += c;
                        p
                    })
                    .collect();
                let bytes =
                    fzlight::assemble_frame(data.len(), eb_abs, self.chunk_values, &payloads);
                stats.compressed_bytes = bytes.len();
                Ok(Compressed { bytes, stats })
            }
            CompressorKind::Szx => {
                // SZx chunks are independent too; reuse the serial encoder
                // per chunk and assemble the same frame layout.
                let parts: Vec<(Vec<u8>, usize, usize)> =
                    par_map_chunks(data, self.chunk_values, self.threads, |chunk| {
                        szx::compress_chunk(chunk, eb_abs)
                    });
                let mut stats =
                    CompressionStats { raw_bytes: data.len() * 4, ..Default::default() };
                let mut payloads = Vec::with_capacity(parts.len());
                for (p, b, c) in parts {
                    stats.blocks += b;
                    stats.constant_blocks += c;
                    payloads.push(p);
                }
                // Frame assembly mirrors Szx::compress.
                use super::bits::le;
                use super::traits::{write_header, HEADER_LEN};
                let total: usize = payloads.iter().map(Vec::len).sum();
                let mut bytes =
                    Vec::with_capacity(HEADER_LEN + 8 + 4 * payloads.len() + total);
                write_header(&mut bytes, CompressorKind::Szx, data.len(), eb_abs);
                le::put_u32(&mut bytes, self.chunk_values as u32);
                le::put_u32(&mut bytes, payloads.len() as u32);
                for p in &payloads {
                    le::put_u32(&mut bytes, p.len() as u32);
                }
                for p in &payloads {
                    bytes.extend_from_slice(p);
                }
                stats.compressed_bytes = bytes.len();
                Ok(Compressed { bytes, stats })
            }
            other => super::build(other).compress(data, ErrorBound::Abs(eb_abs)),
        }
    }

    fn decompress(&self, bytes: &[u8]) -> Result<Vec<f32>> {
        match self.kind {
            CompressorKind::FzLight => {
                let (chunk_values, eb_abs, n, ranges) = fzlight::frame_chunks(bytes)?;
                let twoeb = 2.0 * eb_abs;
                let nchunks = ranges.len();
                let parts: Vec<Result<Vec<f32>>> =
                    par_map(&ranges, self.threads, |i, r| {
                        let cn = if i + 1 == nchunks {
                            n.checked_sub(chunk_values * (nchunks - 1))
                                .filter(|&c| c >= 1 && c <= chunk_values)
                                .ok_or_else(|| Error::corrupt("chunk table inconsistent"))?
                        } else {
                            chunk_values
                        };
                        let mut out = Vec::with_capacity(cn);
                        fzlight::decompress_chunk(&bytes[r.clone()], cn, twoeb, &mut out)?;
                        Ok(out)
                    });
                let mut out = Vec::with_capacity(n);
                for p in parts {
                    out.extend_from_slice(&p?);
                }
                if out.len() != n {
                    return Err(Error::corrupt("mt decode length mismatch"));
                }
                Ok(out)
            }
            other => super::build(other).decompress(bytes),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::fields::{Field, FieldKind};
    use crate::compress::{FzLight, Szx};

    #[test]
    fn mt_fzlight_bit_identical_to_st() {
        let f = Field::generate(FieldKind::Nyx, 40_000, 77);
        let st = FzLight::default().compress(&f.values, ErrorBound::Rel(1e-3)).unwrap();
        let mt = MtCompressor::new(CompressorKind::FzLight)
            .compress(&f.values, ErrorBound::Rel(1e-3))
            .unwrap();
        assert_eq!(st.bytes, mt.bytes);
        assert_eq!(st.stats.blocks, mt.stats.blocks);
        assert_eq!(st.stats.constant_blocks, mt.stats.constant_blocks);
    }

    #[test]
    fn mt_szx_bit_identical_to_st() {
        let f = Field::generate(FieldKind::Cesm, 33_000, 78);
        let st = Szx::default().compress(&f.values, ErrorBound::Rel(1e-2)).unwrap();
        let mt = MtCompressor::new(CompressorKind::Szx)
            .compress(&f.values, ErrorBound::Rel(1e-2))
            .unwrap();
        assert_eq!(st.bytes, mt.bytes);
    }

    #[test]
    fn mt_decode_matches_st_decode() {
        let f = Field::generate(FieldKind::Rtm, 50_000, 79);
        let c = FzLight::default().compress(&f.values, ErrorBound::Abs(1e-4)).unwrap();
        let st = FzLight::default().decompress(&c.bytes).unwrap();
        let mt = MtCompressor::new(CompressorKind::FzLight).decompress(&c.bytes).unwrap();
        assert_eq!(st, mt);
    }
}
