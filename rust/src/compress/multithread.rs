//! Multi-thread compression mode (the paper's "ZCCL (multi-thread)").
//!
//! fZ-light's and SZx's chunked frame layout makes chunks independent, so
//! compression and decompression parallelise over chunks with rayon.
//! Numerics and the emitted frame are **bit-identical** to the
//! single-thread path — only wall-clock changes.
//!
//! NOTE (DESIGN.md §2): this container exposes a single core, so measured
//! multi-thread speedup here is ~1×. The virtual-time simulator applies a
//! calibrated thread-scaling model for the paper's multi-thread figures;
//! this module keeps the *implementation* real and testable.

use crate::util::par::{default_threads, par_map, par_map_chunks};

use super::fzlight::{self};
use super::szx::{self};
use super::traits::{CompressionStats, Compressor, CompressorKind, ErrorBound};
use crate::{Error, Result};

/// Multi-threaded wrapper over a chunk-parallel codec.
#[derive(Debug, Clone)]
pub struct MtCompressor {
    /// Underlying codec (FzLight and Szx parallelise; others run serially).
    pub kind: CompressorKind,
    /// Values per chunk.
    pub chunk_values: usize,
    /// Worker threads (defaults to the host's parallelism).
    pub threads: usize,
}

impl MtCompressor {
    /// Construct for `kind` with the codec's default chunk size.
    pub fn new(kind: CompressorKind) -> Self {
        MtCompressor { kind, chunk_values: fzlight::DEFAULT_CHUNK, threads: default_threads() }
    }

    /// Construct with an explicit chunk size and default threads.
    pub fn with_chunk(kind: CompressorKind, chunk_values: usize) -> Self {
        MtCompressor { kind, chunk_values, threads: default_threads() }
    }
}

impl Compressor for MtCompressor {
    fn kind(&self) -> CompressorKind {
        self.kind
    }

    fn compress_into(
        &self,
        data: &[f32],
        eb: ErrorBound,
        out: &mut Vec<u8>,
    ) -> Result<CompressionStats> {
        let eb_abs = eb.resolve(data);
        if !(eb_abs > 0.0) || !eb_abs.is_finite() {
            return Err(Error::invalid(format!("error bound must be positive, got {eb_abs}")));
        }
        match self.kind {
            CompressorKind::FzLight | CompressorKind::Szx => {
                // Chunks compress in parallel into independently owned
                // payloads (inherent to the fan-out), then one pass
                // assembles the shared chunked frame layout into `out`.
                let kind = self.kind;
                let parts: Vec<(Vec<u8>, usize, usize)> =
                    par_map_chunks(data, self.chunk_values, self.threads, |chunk| {
                        match kind {
                            CompressorKind::FzLight => {
                                fzlight::compress_chunk(chunk, 2.0 * eb_abs)
                            }
                            _ => szx::compress_chunk(chunk, eb_abs),
                        }
                    });
                let mut stats =
                    CompressionStats { raw_bytes: data.len() * 4, ..Default::default() };
                let payloads: Vec<Vec<u8>> = parts
                    .into_iter()
                    .map(|(p, b, c)| {
                        stats.blocks += b;
                        stats.constant_blocks += c;
                        p
                    })
                    .collect();
                let base = out.len();
                fzlight::assemble_frame_into(
                    kind,
                    data.len(),
                    eb_abs,
                    self.chunk_values,
                    &payloads,
                    out,
                );
                stats.compressed_bytes = out.len() - base;
                Ok(stats)
            }
            other => super::build(other).compress_into(data, ErrorBound::Abs(eb_abs), out),
        }
    }

    fn decompress_into(&self, bytes: &[u8], out: &mut Vec<f32>) -> Result<usize> {
        match self.kind {
            CompressorKind::FzLight => {
                let (chunk_values, eb_abs, n, ranges) = fzlight::frame_chunks(bytes)?;
                let twoeb = 2.0 * eb_abs;
                let nchunks = ranges.len();
                let parts: Vec<Result<Vec<f32>>> =
                    par_map(&ranges, self.threads, |i, r| {
                        let cn = if i + 1 == nchunks {
                            n.checked_sub(chunk_values * (nchunks - 1))
                                .filter(|&c| c >= 1 && c <= chunk_values)
                                .ok_or_else(|| Error::corrupt("chunk table inconsistent"))?
                        } else {
                            chunk_values
                        };
                        let mut out = Vec::with_capacity(cn);
                        fzlight::decompress_chunk(&bytes[r.clone()], cn, twoeb, &mut out)?;
                        Ok(out)
                    });
                let start = out.len();
                out.reserve(n);
                for p in parts {
                    out.extend_from_slice(&p?);
                }
                if out.len() - start != n {
                    return Err(Error::corrupt("mt decode length mismatch"));
                }
                Ok(n)
            }
            other => super::build(other).decompress_into(bytes, out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::fields::{Field, FieldKind};
    use crate::compress::{FzLight, Szx};

    #[test]
    fn mt_fzlight_bit_identical_to_st() {
        let f = Field::generate(FieldKind::Nyx, 40_000, 77);
        let st = FzLight::default().compress(&f.values, ErrorBound::Rel(1e-3)).unwrap();
        let mt = MtCompressor::new(CompressorKind::FzLight)
            .compress(&f.values, ErrorBound::Rel(1e-3))
            .unwrap();
        assert_eq!(st.bytes, mt.bytes);
        assert_eq!(st.stats.blocks, mt.stats.blocks);
        assert_eq!(st.stats.constant_blocks, mt.stats.constant_blocks);
    }

    #[test]
    fn mt_szx_bit_identical_to_st() {
        let f = Field::generate(FieldKind::Cesm, 33_000, 78);
        let st = Szx::default().compress(&f.values, ErrorBound::Rel(1e-2)).unwrap();
        let mt = MtCompressor::new(CompressorKind::Szx)
            .compress(&f.values, ErrorBound::Rel(1e-2))
            .unwrap();
        assert_eq!(st.bytes, mt.bytes);
    }

    #[test]
    fn mt_decode_matches_st_decode() {
        let f = Field::generate(FieldKind::Rtm, 50_000, 79);
        let c = FzLight::default().compress(&f.values, ErrorBound::Abs(1e-4)).unwrap();
        let st = FzLight::default().decompress(&c.bytes).unwrap();
        let mt = MtCompressor::new(CompressorKind::FzLight).decompress(&c.bytes).unwrap();
        assert_eq!(st, mt);
    }
}
