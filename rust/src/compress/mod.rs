//! Error-bounded lossy compressors for collective communication.
//!
//! This module is the paper's "performance optimization layer" substrate:
//! from-scratch Rust implementations of the compressors the paper studies
//! in §3.3 plus the pipelined customization of §3.5.2.
//!
//! ## The zero-alloc `*_into` API
//!
//! The [`Compressor`] trait's required methods are
//! [`Compressor::compress_into`] and [`Compressor::decompress_into`]:
//! they append to caller-owned buffers, so a long-lived caller — above
//! all [`crate::collectives::CollCtx`], which pairs one codec instance
//! with a scratch-buffer pool — performs **no allocation per call** once
//! warm. The allocating [`Compressor::compress`] /
//! [`Compressor::decompress`] remain as thin default-impl wrappers for
//! one-shot use:
//!
//! ```
//! use zccl::compress::{Compressor, CompressorKind, ErrorBound};
//!
//! let codec = zccl::compress::build(CompressorKind::FzLight);
//! let data = vec![1.0f32; 4096];
//! let (mut frame, mut restored) = (Vec::new(), Vec::new());
//! for _ in 0..3 {
//!     frame.clear();
//!     restored.clear();
//!     codec.compress_into(&data, ErrorBound::Abs(1e-4), &mut frame).unwrap();
//!     codec.decompress_into(&frame, &mut restored).unwrap(); // reuses capacity
//! }
//! assert_eq!(restored.len(), data.len());
//! ```
//!
//! The receive-side counterpart is the **placement decode**
//! [`Compressor::decompress_into_slice`]: values reconstruct directly at
//! their final positions in a caller-carved window, so the movement
//! collectives never stage-and-copy a decoded frame. fZ-light (and its
//! PIPE / multithreaded wrappers) run native in-place kernels; SZx and
//! ZFP fall back to decompress-then-copy and say so via
//! [`Compressor::supports_placement_decode`].
//!
//! ## The staged pipeline: quantize → pack → entropy
//!
//! fZ-light compression is organised as separable stages. Stage one
//! **quantizes** (`q[i] = round(x[i]/2eb)`, then 1-D Lorenzo deltas);
//! stage two **packs** each 32-delta block at its measured fixed bit
//! width (the paper's bit-shifting encoding); stage three — new with
//! frame version 2 — optionally **entropy-codes** the packed chunk
//! payload with the order-0 rANS coder in [`entropy`], squeezing the
//! redundancy fixed-width packing leaves on low-entropy scientific
//! fields (the NCCLZ decoupled-stage design). Stage three is governed by
//! an adaptive **per-chunk selection contract**: at encode time each
//! chunk measures plain / fixed-width / entropy-coded sizes and records
//! the winner in a one-byte stage tag, and selection is *never worse* —
//! entropy must undercut the alternatives by a margin or the fixed-width
//! bytes ship unchanged, so a staged frame costs at most one tag byte
//! per chunk over its version-1 twin on any input (see [`fzlight`]'s
//! module docs for the exact byte layout and margins). Decoders
//! dispatch per chunk on the tag; version-1 frames decode through the
//! same paths unchanged.
//!
//! ## Word-parallel codec kernels
//!
//! The paper's §3.4 vectorized bit-shifting encoding is realised in
//! [`bits`]: the fixed-length packer spills **whole 8-byte words** from
//! its 64-bit accumulator and the unpacker refills with whole-`u64`
//! loads, while the fZ-light / SZx stages around them run
//! **block-batched** — quantize, delta/sign/magnitude, prefix-sum
//! reconstruction, and dequantize each execute as separate
//! straight-line loops over a whole chunk or block rather than
//! interleaved per-value work. Every collective receive path (plain,
//! placement, fused decompress–reduce, pipelined, multithreaded)
//! inherits these kernels. The scalar `BitWriter`/`BitReader` pair is
//! retained in [`bits`] as the executable layout spec — as is
//! [`entropy`]'s linear-scan reference decoder beside its table-driven
//! twin; `zccl bench codec` (and `cargo bench --bench compressors`)
//! emits `BENCH_codec.json` with comp/decomp GB/s per codec × dataset ×
//! bound, per-stage GB/s (quantize+pack / entropy), staged-vs-fixed
//! ratio rows, and a `speedup_vs_reference` field tracking the
//! word-parallel kernels against that reference from PR to PR.
//!
//! ## Codecs
//!
//! - [`fzlight`] — `fZ-light` (a.k.a. SZp): fused 1-D Lorenzo prediction +
//!   error-bounded quantization + ultra-fast fixed-length bit-shifting
//!   encoding, with the optional staged (version-2) per-chunk
//!   plain/fixed/entropy selection. The paper's chosen compressor.
//! - [`entropy`] — byte-oriented order-0 rANS coder: the staged frames'
//!   second-stage entropy coder (fast table-driven decode, linear-scan
//!   reference decoder retained as the spec).
//! - [`pipe`] — `PIPE-fZ-light`: the §3.5.2 redesign that splits the stream
//!   into fixed 5120-value chunks with a size index at the head of the
//!   buffer so communication progress can be polled between chunks.
//! - [`szx`] — SZx-style compressor: 128-value blocks classified as
//!   constant (stored as the mid-range mean) or non-constant (fixed-length
//!   coded residuals). Used by the C-Coll baseline.
//! - [`zfp_like`] — a fixed-rate block-transform baseline standing in for
//!   1-D ZFP in its fixed-rate (FXR) and fixed-accuracy (ABS) modes.
//! - [`multithread`] — rayon-parallel wrappers (the paper's multi-thread
//!   mode; thread scaling is *modeled* in [`crate::sim`] on this 1-core
//!   host, see DESIGN.md §2).
//! - [`stats`] — NRMSE / PSNR / bitrate / error-distribution tooling used
//!   by Tables 3–4 and Figures 5–8.

pub mod bits;
pub mod entropy;
pub mod fzlight;
pub mod multithread;
pub mod pipe;
pub mod stats;
pub mod szx;
pub mod traits;
pub mod zfp_like;

pub use fzlight::FzLight;
pub use multithread::MtCompressor;
pub use pipe::PipeFzLight;
pub use szx::Szx;
pub use traits::{
    checked_count, peek_codec, read_header, Compressed, CompressionStats, Compressor,
    CompressorKind, ErrorBound, Header,
};
pub use zfp_like::{ZfpAbs, ZfpFixedRate};

use crate::Result;

/// Instantiate a compressor by kind with default tuning parameters.
pub fn build(kind: CompressorKind) -> Box<dyn Compressor> {
    match kind {
        CompressorKind::FzLight => Box::new(FzLight::default()),
        CompressorKind::Szx => Box::new(Szx::default()),
        CompressorKind::ZfpAbs => Box::new(ZfpAbs::default()),
        CompressorKind::ZfpFixedRate => Box::new(ZfpFixedRate::default()),
    }
}

/// Compress with `kind`, returning the framed byte stream.
pub fn compress(kind: CompressorKind, data: &[f32], eb: ErrorBound) -> Result<Compressed> {
    build(kind).compress(data, eb)
}

/// Compress with `kind`, appending the frame to `out`.
pub fn compress_into(
    kind: CompressorKind,
    data: &[f32],
    eb: ErrorBound,
    out: &mut Vec<u8>,
) -> Result<CompressionStats> {
    build(kind).compress_into(data, eb, out)
}

/// Decompress a framed byte stream produced by any compressor in this
/// module (the frame header records the codec).
pub fn decompress(bytes: &[u8]) -> Result<Vec<f32>> {
    let codec = traits::peek_codec(bytes)?;
    build(codec).decompress(bytes)
}

/// Codec-agnostic [`decompress`] into a caller-owned buffer (appends;
/// returns the decoded count). Note this builds a transient codec per
/// call; hot paths with a known codec should hold a [`Compressor`]
/// instance (see [`crate::collectives::CollCtx`]) instead.
pub fn decompress_into(bytes: &[u8], out: &mut Vec<f32>) -> Result<usize> {
    let codec = traits::peek_codec(bytes)?;
    build(codec).decompress_into(bytes, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::fields::{Field, FieldKind};

    #[test]
    fn dispatch_roundtrip_all_codecs() {
        let f = Field::generate(FieldKind::Cesm, 4096, 7);
        for kind in CompressorKind::ALL {
            let c = compress(kind, &f.values, ErrorBound::Rel(1e-3)).unwrap();
            let d = decompress(&c.bytes).unwrap();
            assert_eq!(d.len(), f.values.len(), "{kind:?} length");
        }
    }

    #[test]
    fn into_roundtrip_all_codecs_matches_allocating_path() {
        let f = Field::generate(FieldKind::Nyx, 8192, 17);
        let (mut frame, mut vals) = (Vec::new(), Vec::new());
        for kind in CompressorKind::ALL {
            frame.clear();
            vals.clear();
            let st = compress_into(kind, &f.values, ErrorBound::Rel(1e-3), &mut frame).unwrap();
            let c = compress(kind, &f.values, ErrorBound::Rel(1e-3)).unwrap();
            assert_eq!(frame, c.bytes, "{kind:?}: into-frame must be bit-identical");
            assert_eq!(st.compressed_bytes, c.stats.compressed_bytes, "{kind:?} stats");
            let n = decompress_into(&frame, &mut vals).unwrap();
            assert_eq!(n, f.values.len(), "{kind:?} count");
            assert_eq!(vals, decompress(&frame).unwrap(), "{kind:?} values");
        }
    }

    #[test]
    fn into_variants_append() {
        // Two frames packed back to back each decode from their own slice.
        let a = vec![1.0f32; 600];
        let b: Vec<f32> = (0..500).map(|i| (i as f32 * 0.11).cos()).collect();
        let mut buf = Vec::new();
        compress_into(CompressorKind::FzLight, &a, ErrorBound::Abs(1e-4), &mut buf).unwrap();
        let split = buf.len();
        compress_into(CompressorKind::Szx, &b, ErrorBound::Abs(1e-4), &mut buf).unwrap();
        let mut vals = Vec::new();
        let na = decompress_into(&buf[..split], &mut vals).unwrap();
        let nb = decompress_into(&buf[split..], &mut vals).unwrap();
        assert_eq!((na, nb), (600, 500));
        assert_eq!(vals.len(), 1100);
    }
}
