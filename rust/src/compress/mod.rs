//! Error-bounded lossy compressors for collective communication.
//!
//! This module is the paper's "performance optimization layer" substrate:
//! from-scratch Rust implementations of the compressors the paper studies
//! in §3.3 plus the pipelined customization of §3.5.2.
//!
//! - [`fzlight`] — `fZ-light` (a.k.a. SZp): fused 1-D Lorenzo prediction +
//!   error-bounded quantization + ultra-fast fixed-length bit-shifting
//!   encoding. The paper's chosen compressor.
//! - [`pipe`] — `PIPE-fZ-light`: the §3.5.2 redesign that splits the stream
//!   into fixed 5120-value chunks with a size index at the head of the
//!   buffer so communication progress can be polled between chunks.
//! - [`szx`] — SZx-style compressor: 128-value blocks classified as
//!   constant (stored as the mid-range mean) or non-constant (fixed-length
//!   coded residuals). Used by the C-Coll baseline.
//! - [`zfp_like`] — a fixed-rate block-transform baseline standing in for
//!   1-D ZFP in its fixed-rate (FXR) and fixed-accuracy (ABS) modes.
//! - [`multithread`] — rayon-parallel wrappers (the paper's multi-thread
//!   mode; thread scaling is *modeled* in [`crate::sim`] on this 1-core
//!   host, see DESIGN.md §2).
//! - [`stats`] — NRMSE / PSNR / bitrate / error-distribution tooling used
//!   by Tables 3–4 and Figures 5–8.

pub mod bits;
pub mod fzlight;
pub mod multithread;
pub mod pipe;
pub mod stats;
pub mod szx;
pub mod traits;
pub mod zfp_like;

pub use fzlight::FzLight;
pub use multithread::MtCompressor;
pub use pipe::PipeFzLight;
pub use szx::Szx;
pub use traits::{
    Compressed, CompressionStats, Compressor, CompressorKind, ErrorBound,
};
pub use zfp_like::{ZfpAbs, ZfpFixedRate};

use crate::Result;

/// Instantiate a compressor by kind with default tuning parameters.
pub fn build(kind: CompressorKind) -> Box<dyn Compressor> {
    match kind {
        CompressorKind::FzLight => Box::new(FzLight::default()),
        CompressorKind::Szx => Box::new(Szx::default()),
        CompressorKind::ZfpAbs => Box::new(ZfpAbs::default()),
        CompressorKind::ZfpFixedRate => Box::new(ZfpFixedRate::default()),
    }
}

/// Compress with `kind`, returning the framed byte stream.
pub fn compress(kind: CompressorKind, data: &[f32], eb: ErrorBound) -> Result<Compressed> {
    build(kind).compress(data, eb)
}

/// Decompress a framed byte stream produced by any compressor in this
/// module (the frame header records the codec).
pub fn decompress(bytes: &[u8]) -> Result<Vec<f32>> {
    let codec = traits::peek_codec(bytes)?;
    build(codec).decompress(bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::fields::{Field, FieldKind};

    #[test]
    fn dispatch_roundtrip_all_codecs() {
        let f = Field::generate(FieldKind::Cesm, 4096, 7);
        for kind in CompressorKind::ALL {
            let c = compress(kind, &f.values, ErrorBound::Rel(1e-3)).unwrap();
            let d = decompress(&c.bytes).unwrap();
            assert_eq!(d.len(), f.values.len(), "{kind:?} length");
        }
    }
}
