//! `PIPE-fZ-light` — the paper's §3.5.2 customization: compression and
//! decompression proceed in fixed 5120-value chunks, and a caller-supplied
//! *progress hook* runs between chunks. The collective computation
//! framework passes a closure that polls nonblocking `Isend`/`Irecv`
//! progress, hiding communication inside (de)compression.
//!
//! The emitted frame is bit-identical to [`super::FzLight`]'s: the chunk
//! size index lives at the head of the buffer ("essentially a kind of
//! index", §3.5.2), so either implementation decodes the other's output.

use super::fzlight::{self, DEFAULT_CHUNK};
use super::traits::{Compressed, CompressionStats, Compressor, CompressorKind, ErrorBound};
use crate::{Error, Result};

/// Pipelined fZ-light. See the module docs.
#[derive(Debug, Clone)]
pub struct PipeFzLight {
    /// Values per pipeline chunk (paper: 5120).
    pub chunk_values: usize,
}

impl Default for PipeFzLight {
    fn default() -> Self {
        PipeFzLight { chunk_values: DEFAULT_CHUNK }
    }
}

impl PipeFzLight {
    /// Construct with an explicit chunk size.
    pub fn with_chunk(chunk_values: usize) -> Self {
        assert!(chunk_values > 0);
        PipeFzLight { chunk_values }
    }

    /// Compress `data`, invoking `progress` after every chunk.
    ///
    /// The hook receives the number of values compressed so far; the
    /// collective layer ignores the argument and simply polls its
    /// outstanding nonblocking operations.
    pub fn compress_with_progress(
        &self,
        data: &[f32],
        eb: ErrorBound,
        progress: &mut dyn FnMut(usize),
    ) -> Result<Compressed> {
        let eb_abs = eb.resolve(data);
        if !(eb_abs > 0.0) || !eb_abs.is_finite() {
            return Err(Error::invalid(format!("error bound must be positive, got {eb_abs}")));
        }
        let twoeb = 2.0 * eb_abs;
        let mut payloads = Vec::with_capacity(data.len().div_ceil(self.chunk_values));
        let mut stats = CompressionStats { raw_bytes: data.len() * 4, ..Default::default() };
        let mut done = 0usize;
        for chunk in data.chunks(self.chunk_values) {
            let (p, blocks, constant) = fzlight::compress_chunk(chunk, twoeb);
            stats.blocks += blocks;
            stats.constant_blocks += constant;
            payloads.push(p);
            done += chunk.len();
            progress(done);
        }
        let bytes = fzlight::assemble_frame(data.len(), eb_abs, self.chunk_values, &payloads);
        stats.compressed_bytes = bytes.len();
        Ok(Compressed { bytes, stats })
    }

    /// Decompress, invoking `progress` after every chunk. The
    /// chunk-starting-location pointer walks the size index recorded at
    /// the head of the frame.
    pub fn decompress_with_progress(
        &self,
        bytes: &[u8],
        progress: &mut dyn FnMut(usize),
    ) -> Result<Vec<f32>> {
        let (chunk_values, eb_abs, n, ranges) = fzlight::frame_chunks(bytes)?;
        let twoeb = 2.0 * eb_abs;
        let mut out = Vec::with_capacity(n);
        for (i, r) in ranges.iter().enumerate() {
            let cn = if i + 1 == ranges.len() {
                n.checked_sub(chunk_values * (ranges.len() - 1))
                    .filter(|&c| c >= 1 && c <= chunk_values)
                    .ok_or_else(|| Error::corrupt("chunk table inconsistent with count"))?
            } else {
                chunk_values
            };
            fzlight::decompress_chunk(&bytes[r.clone()], cn, twoeb, &mut out)?;
            progress(out.len());
        }
        if out.len() != n {
            return Err(Error::corrupt(format!("decoded {} of {} values", out.len(), n)));
        }
        Ok(out)
    }
}

impl Compressor for PipeFzLight {
    fn kind(&self) -> CompressorKind {
        CompressorKind::FzLight
    }
    fn compress(&self, data: &[f32], eb: ErrorBound) -> Result<Compressed> {
        self.compress_with_progress(data, eb, &mut |_| {})
    }
    fn decompress(&self, bytes: &[u8]) -> Result<Vec<f32>> {
        self.decompress_with_progress(bytes, &mut |_| {})
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::FzLight;
    use crate::data::fields::{Field, FieldKind};

    #[test]
    fn identical_frames_to_fzlight() {
        let f = Field::generate(FieldKind::Hurricane, 23_456, 8);
        let a = FzLight::default().compress(&f.values, ErrorBound::Abs(1e-3)).unwrap();
        let b = PipeFzLight::default().compress(&f.values, ErrorBound::Abs(1e-3)).unwrap();
        assert_eq!(a.bytes, b.bytes, "pipe frame must be bit-identical");
    }

    #[test]
    fn cross_decode() {
        let f = Field::generate(FieldKind::Nyx, 9_000, 8);
        let c = PipeFzLight::default().compress(&f.values, ErrorBound::Abs(1e-3)).unwrap();
        let d1 = FzLight::default().decompress(&c.bytes).unwrap();
        let d2 = PipeFzLight::default().decompress(&c.bytes).unwrap();
        assert_eq!(d1, d2);
    }

    #[test]
    fn progress_called_per_chunk() {
        let f = Field::generate(FieldKind::Rtm, 5120 * 3 + 100, 8);
        let pipe = PipeFzLight::default();
        let mut calls = Vec::new();
        let c = pipe
            .compress_with_progress(&f.values, ErrorBound::Abs(1e-3), &mut |done| calls.push(done))
            .unwrap();
        assert_eq!(calls, vec![5120, 10240, 15360, 15460]);
        let mut dcalls = 0;
        let d = pipe.decompress_with_progress(&c.bytes, &mut |_| dcalls += 1).unwrap();
        assert_eq!(dcalls, 4);
        assert_eq!(d.len(), f.values.len());
    }

    #[test]
    fn custom_chunk_size() {
        let f = Field::generate(FieldKind::Cesm, 10_000, 8);
        let pipe = PipeFzLight::with_chunk(1000);
        let mut calls = 0;
        pipe.compress_with_progress(&f.values, ErrorBound::Abs(1e-3), &mut |_| calls += 1).unwrap();
        assert_eq!(calls, 10);
    }
}
