//! `PIPE-fZ-light` — the paper's §3.5.2 customization: compression and
//! decompression proceed in fixed 5120-value chunks, and a caller-supplied
//! *progress hook* runs between chunks. The collective computation
//! framework passes a closure that polls nonblocking `Isend`/`Irecv`
//! progress, hiding communication inside (de)compression.
//!
//! The emitted frame is bit-identical to [`super::FzLight`]'s: the chunk
//! size index lives at the head of the buffer ("essentially a kind of
//! index", §3.5.2), so either implementation decodes the other's output.
//!
//! Every entry point has an `_into` form writing into a caller-owned
//! buffer; [`crate::collectives::CollCtx`] pairs those with its scratch
//! pool so iterated collectives run allocation-free after warm-up. All
//! paths delegate to [`super::fzlight`]'s word-parallel block-batched
//! kernels, so pipelined (de)compression is exactly as fast per chunk
//! as the plain codec — only the progress hook differs.

use super::fzlight::{self, DEFAULT_CHUNK};
use super::traits::{Compressed, CompressionStats, Compressor, CompressorKind, ErrorBound};
use crate::ops::ReduceOp;
use crate::{Error, Result};

/// Pipelined fZ-light. See the module docs.
#[derive(Debug, Clone)]
pub struct PipeFzLight {
    /// Values per pipeline chunk (paper: 5120).
    pub chunk_values: usize,
    /// Emit staged (version-2) frames — see [`super::fzlight`]'s module
    /// docs. Off by default; decode always accepts both versions.
    pub staged: bool,
}

impl Default for PipeFzLight {
    fn default() -> Self {
        PipeFzLight { chunk_values: DEFAULT_CHUNK, staged: false }
    }
}

impl PipeFzLight {
    /// Construct with an explicit chunk size.
    pub fn with_chunk(chunk_values: usize) -> Self {
        assert!(chunk_values > 0);
        PipeFzLight { chunk_values, staged: false }
    }

    /// Toggle staged (version-2) encoding.
    pub fn with_staged(mut self, staged: bool) -> Self {
        self.staged = staged;
        self
    }

    /// Compress `data`, invoking `progress` after every chunk.
    ///
    /// The hook receives the number of values compressed so far; the
    /// collective layer ignores the argument and simply polls its
    /// outstanding nonblocking operations.
    pub fn compress_with_progress(
        &self,
        data: &[f32],
        eb: ErrorBound,
        progress: &mut dyn FnMut(usize),
    ) -> Result<Compressed> {
        let mut bytes = Vec::new();
        let stats = self.compress_into_with_progress(data, eb, &mut bytes, progress)?;
        Ok(Compressed { bytes, stats })
    }

    /// [`PipeFzLight::compress_with_progress`], appending the frame to a
    /// caller-owned buffer (zero allocations when `out` has capacity).
    pub fn compress_into_with_progress(
        &self,
        data: &[f32],
        eb: ErrorBound,
        out: &mut Vec<u8>,
        progress: &mut dyn FnMut(usize),
    ) -> Result<CompressionStats> {
        fzlight::compress_frame_into(self.chunk_values, data, eb, self.staged, out, progress)
    }

    /// Decompress, invoking `progress` after every chunk. The
    /// chunk-starting-location pointer walks the size index recorded at
    /// the head of the frame.
    pub fn decompress_with_progress(
        &self,
        bytes: &[u8],
        progress: &mut dyn FnMut(usize),
    ) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.decompress_into_with_progress(bytes, &mut out, progress)?;
        Ok(out)
    }

    /// [`PipeFzLight::decompress_with_progress`], appending decoded values
    /// to a caller-owned buffer. Returns the decoded value count.
    pub fn decompress_into_with_progress(
        &self,
        bytes: &[u8],
        out: &mut Vec<f32>,
        progress: &mut dyn FnMut(usize),
    ) -> Result<usize> {
        let (geom, ranges) = fzlight::frame_chunks(bytes)?;
        let n = geom.n;
        let twoeb = 2.0 * geom.eb_abs;
        fzlight::validate_frame_count(bytes, &ranges, &geom)?;
        let start = out.len();
        out.reserve(n);
        for (i, r) in ranges.iter().enumerate() {
            let cn = fzlight::chunk_value_count(i, ranges.len(), n, geom.chunk_values)?;
            fzlight::decompress_chunk(&bytes[r.clone()], cn, twoeb, geom.staged, out)?;
            progress(out.len() - start);
        }
        if out.len() - start != n {
            return Err(Error::corrupt(format!("decoded {} of {n} values", out.len() - start)));
        }
        Ok(n)
    }

    /// Placement decode with the §3.5.2 progress hook: each chunk
    /// reconstructs straight into its final window of `out` (`out.len()`
    /// must equal the frame's element count), and `progress` runs between
    /// chunks so the collective layer can keep polling outstanding
    /// nonblocking communication while it decodes into place.
    ///
    /// Error semantics match [`Compressor::decompress_into_slice`]: on
    /// `Err` a prefix of `out` may already be written — discard it.
    pub fn decompress_into_slice_with_progress(
        &self,
        bytes: &[u8],
        out: &mut [f32],
        progress: &mut dyn FnMut(usize),
    ) -> Result<usize> {
        fzlight::decompress_frame_into_slice(bytes, out, progress)
    }

    /// The fused decompress–reduce kernel with the §3.5.2 progress hook:
    /// each chunk's reconstructed values are folded straight into `acc`
    /// via `op`, and `progress` runs between chunks so the collective
    /// layer can keep polling outstanding nonblocking communication while
    /// it reduces. `acc.len()` must equal the frame's element count.
    ///
    /// Error semantics match [`Compressor::decompress_fold_into`]: on
    /// `Err` a prefix of `acc` may already be folded — discard it.
    pub fn decompress_fold_into_with_progress(
        &self,
        bytes: &[u8],
        op: ReduceOp,
        acc: &mut [f32],
        progress: &mut dyn FnMut(usize),
    ) -> Result<usize> {
        fzlight::decompress_fold_frame(bytes, op, acc, progress)
    }
}

impl Compressor for PipeFzLight {
    fn kind(&self) -> CompressorKind {
        CompressorKind::FzLight
    }
    fn compress_into(
        &self,
        data: &[f32],
        eb: ErrorBound,
        out: &mut Vec<u8>,
    ) -> Result<CompressionStats> {
        self.compress_into_with_progress(data, eb, out, &mut |_| {})
    }
    fn decompress_into(&self, bytes: &[u8], out: &mut Vec<f32>) -> Result<usize> {
        self.decompress_into_with_progress(bytes, out, &mut |_| {})
    }
    fn decompress_into_slice(&self, bytes: &[u8], out: &mut [f32]) -> Result<usize> {
        self.decompress_into_slice_with_progress(bytes, out, &mut |_| {})
    }
    fn supports_placement_decode(&self) -> bool {
        true
    }
    fn decompress_fold_into(&self, bytes: &[u8], op: ReduceOp, acc: &mut [f32]) -> Result<usize> {
        self.decompress_fold_into_with_progress(bytes, op, acc, &mut |_| {})
    }
    fn supports_fused_fold(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::FzLight;
    use crate::data::fields::{Field, FieldKind};

    #[test]
    fn identical_frames_to_fzlight() {
        let f = Field::generate(FieldKind::Hurricane, 23_456, 8);
        let a = FzLight::default().compress(&f.values, ErrorBound::Abs(1e-3)).unwrap();
        let b = PipeFzLight::default().compress(&f.values, ErrorBound::Abs(1e-3)).unwrap();
        assert_eq!(a.bytes, b.bytes, "pipe frame must be bit-identical");
    }

    #[test]
    fn cross_decode() {
        let f = Field::generate(FieldKind::Nyx, 9_000, 8);
        let c = PipeFzLight::default().compress(&f.values, ErrorBound::Abs(1e-3)).unwrap();
        let d1 = FzLight::default().decompress(&c.bytes).unwrap();
        let d2 = PipeFzLight::default().decompress(&c.bytes).unwrap();
        assert_eq!(d1, d2);
    }

    #[test]
    fn progress_called_per_chunk() {
        let f = Field::generate(FieldKind::Rtm, 5120 * 3 + 100, 8);
        let pipe = PipeFzLight::default();
        let mut calls = Vec::new();
        let c = pipe
            .compress_with_progress(&f.values, ErrorBound::Abs(1e-3), &mut |done| calls.push(done))
            .unwrap();
        assert_eq!(calls, vec![5120, 10240, 15360, 15460]);
        let mut dcalls = 0;
        let d = pipe.decompress_with_progress(&c.bytes, &mut |_| dcalls += 1).unwrap();
        assert_eq!(dcalls, 4);
        assert_eq!(d.len(), f.values.len());
    }

    #[test]
    fn custom_chunk_size() {
        let f = Field::generate(FieldKind::Cesm, 10_000, 8);
        let pipe = PipeFzLight::with_chunk(1000);
        let mut calls = 0;
        pipe.compress_with_progress(&f.values, ErrorBound::Abs(1e-3), &mut |_| calls += 1).unwrap();
        assert_eq!(calls, 10);
    }

    #[test]
    fn fused_fold_with_progress_matches_and_polls_per_chunk() {
        use crate::ops::ReduceOp;
        let f = Field::generate(FieldKind::Rtm, 5120 * 2 + 77, 8);
        let pipe = PipeFzLight::default();
        let c = pipe.compress(&f.values, ErrorBound::Abs(1e-3)).unwrap();
        let dec = pipe.decompress(&c.bytes).unwrap();
        let base = vec![0.5f32; f.values.len()];
        let mut want = base.clone();
        ReduceOp::Sum.fold(&mut want, &dec);
        let mut acc = base;
        let mut calls = Vec::new();
        let n = pipe
            .decompress_fold_into_with_progress(&c.bytes, ReduceOp::Sum, &mut acc, &mut |done| {
                calls.push(done)
            })
            .unwrap();
        assert_eq!(n, f.values.len());
        assert_eq!(calls, vec![5120, 10240, 10317], "hook must run between chunks");
        assert_eq!(acc, want);
    }

    #[test]
    fn into_variants_append_and_reuse_capacity() {
        let f = Field::generate(FieldKind::Nyx, 12_000, 9);
        let pipe = PipeFzLight::default();
        let mut buf = Vec::new();
        pipe.compress_into_with_progress(&f.values, ErrorBound::Abs(1e-3), &mut buf, &mut |_| {})
            .unwrap();
        let cap = buf.capacity();
        let first = buf.clone();
        buf.clear();
        pipe.compress_into_with_progress(&f.values, ErrorBound::Abs(1e-3), &mut buf, &mut |_| {})
            .unwrap();
        assert_eq!(buf, first, "recompression must be deterministic");
        assert_eq!(buf.capacity(), cap, "second compress must not reallocate");
        let mut vals = Vec::new();
        let n = pipe.decompress_into_with_progress(&buf, &mut vals, &mut |_| {}).unwrap();
        assert_eq!(n, f.values.len());
        assert_eq!(vals.len(), n);
    }

    #[test]
    fn staged_frames_identical_to_staged_fzlight() {
        let f = Field::generate(FieldKind::Hurricane, 23_456, 8);
        let a = FzLight::default()
            .with_staged(true)
            .compress(&f.values, ErrorBound::Abs(1e-3))
            .unwrap();
        let b = PipeFzLight::default()
            .with_staged(true)
            .compress(&f.values, ErrorBound::Abs(1e-3))
            .unwrap();
        assert_eq!(a.bytes, b.bytes, "staged pipe frame must be bit-identical");
        let d1 = FzLight::default().decompress(&a.bytes).unwrap();
        let d2 = PipeFzLight::default().decompress(&b.bytes).unwrap();
        assert_eq!(d1, d2);
    }

    #[test]
    fn staged_decode_still_polls_per_chunk() {
        let f = Field::generate(FieldKind::Rtm, 5120 * 3 + 100, 8);
        let pipe = PipeFzLight::default().with_staged(true);
        let c = pipe.compress(&f.values, ErrorBound::Abs(1e-3)).unwrap();
        assert_eq!(c.stats.chunks, 4);
        let mut calls = Vec::new();
        let d = pipe.decompress_with_progress(&c.bytes, &mut |done| calls.push(done)).unwrap();
        assert_eq!(calls, vec![5120, 10240, 15360, 15460], "§3.5.2 hook runs per staged chunk");
        assert_eq!(d.len(), f.values.len());
        let mut placed = vec![0.0f32; f.values.len()];
        let mut pcalls = 0usize;
        pipe.decompress_into_slice_with_progress(&c.bytes, &mut placed, &mut |_| pcalls += 1)
            .unwrap();
        assert_eq!(pcalls, 4);
        assert_eq!(placed, d);
    }
}
