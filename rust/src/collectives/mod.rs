//! Compression-accelerated collective operations — the paper's core
//! contribution.
//!
//! ## Start here: [`CollCtx`]
//!
//! The primary API is the persistent per-rank collective context. It owns
//! the codec (built **once**), a scratch-buffer pool, and the
//! [`crate::coordinator::Metrics`] sink, so iterated collectives — a DDP
//! training loop, an image-stacking sweep — pay no per-call codec
//! construction and, after one warm-up call, no scratch allocation:
//!
//! ```
//! use zccl::collectives::{CollCtx, Mode, ReduceOp};
//! use zccl::compress::{CompressorKind, ErrorBound};
//!
//! let mode = Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(1e-4));
//! let results = zccl::collectives::run_ranks(4, move |comm| {
//!     let mut ctx = CollCtx::over(comm, mode);
//!     let x = vec![ctx.rank() as f32; 1024];
//!     let mut out = Vec::new();
//!     for _ in 0..3 {
//!         // `_into` reuses `out`; the pool reuses every internal buffer.
//!         ctx.allreduce_into(&x, ReduceOp::Sum, &mut out).unwrap();
//!     }
//!     out
//! });
//! for r in &results {
//!     for v in r { assert!((v - 6.0).abs() < 5.0 * 1e-4); } // 0+1+2+3
//! }
//! ```
//!
//! The free functions ([`allreduce`], [`allgather`], …) are kept as
//! **compatibility shims**: each builds a transient context per call and
//! merges its timings into the caller's `Metrics`. They are fine for
//! one-shot calls; anything iterated should hold a [`CollCtx`].
//!
//! ## The dual API: blocking calls and `icollective` requests
//!
//! Every context offers each collective in two forms. The **blocking**
//! form above runs the whole schedule before returning. The
//! **nonblocking** (`icollective`) form — [`CollCtx::iallreduce`],
//! [`CollCtx::iallgather`], [`CollCtx::ireduce_scatter`],
//! [`CollCtx::ibcast`] — *starts* the schedule and returns a
//! [`CollRequest`] handle; the caller interleaves its own compute with
//! [`CollCtx::test`] polls (each poll drives *every* in-flight request
//! through the per-rank progress engine) and completes with
//! [`CollCtx::wait`] / [`CollCtx::wait_into`]. Results are **bit
//! identical** to the blocking call: the request machines run the same
//! schedules over the same pooled buffers and fused kernels, merely
//! rearranged into resumable form (see [`nonblocking`]).
//!
//! Quickstart — launch, compute, wait:
//!
//! ```
//! use zccl::collectives::{CollCtx, Mode, ReduceOp};
//! use zccl::compress::{CompressorKind, ErrorBound};
//!
//! let mode = Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(1e-4));
//! let results = zccl::collectives::run_ranks(4, move |comm| {
//!     let mut ctx = CollCtx::over(comm, mode);
//!     let x = vec![ctx.rank() as f32; 1024];
//!     // 1. Launch: reserves the tag slice, posts receives, returns.
//!     let req = ctx.iallreduce(&x, ReduceOp::Sum).unwrap();
//!     // 2. Compute: poll between blocks of your own work — each test()
//!     //    pulls communication progress (§3.5.2), hiding comm time.
//!     let mut acc = 0.0f32;
//!     for i in 0..8 {
//!         acc += (i as f32).sqrt(); // ... a slice of app compute ...
//!         let _done = ctx.test(&req).unwrap();
//!     }
//!     // 3. Wait: blocks only for whatever communication is left.
//!     let out = ctx.wait(req).unwrap();
//!     assert!(acc > 0.0);
//!     out.values
//! });
//! for r in &results {
//!     for v in r { assert!((v - 6.0).abs() < 5.0 * 1e-4); } // 0+1+2+3
//! }
//! ```
//!
//! Multiple requests may be in flight on one context; each reserves its
//! own tag-namespace slice up front ([`Communicator::try_fresh_tags`]),
//! so concurrent requests can never cross-match messages. All ranks must
//! *start* the same requests in the same order (SPMD), but may
//! `test`/`wait` them in any order. The [`crate::coordinator::Metrics`]
//! sink splits nonblocking wall time into hidden (inside `test`,
//! overlapped with compute) and exposed (blocked in `wait`) components.
//!
//! ## The zero-copy receive path
//!
//! Every collective's receive side follows one discipline —
//! **lease → recv_into → decode in place**:
//!
//! 1. wire buffers are leased from the transport's
//!    [`crate::transport::PacketPool`] (never freshly allocated);
//! 2. [`crate::transport::Transport::recv_into`] delivers each arrived
//!    packet by buffer *swap* — the payload's allocation changes hands
//!    and the old capacity returns to the pool;
//! 3. the frame decodes **directly into its final window** of the
//!    output via the placement kernel
//!    ([`crate::compress::Compressor::decompress_into_slice`], routed
//!    through the capability-aware `CollState::decode_into_slice`), so
//!    no decoded value is ever staged and re-copied.
//!
//! After one warm-up call, an iterated ring allgather therefore performs
//! **zero byte-buffer allocations and zero post-decode copies** on the
//! receive path — observable through [`PoolStats`]
//! (`placement_decodes` / `staged_decodes`) and
//! [`crate::transport::PacketPoolStats`]. Codecs without a native
//! placement kernel (SZx, ZFP) stage through pooled scratch instead, so
//! they stay allocation-free even though they pay one copy.
//!
//! ## The fused decompress–reduce receive path
//!
//! The reduction collectives ([`reduce_scatter`], [`reduce`], and through
//! them [`allreduce`]) never materialize a received partial: the receive
//! side calls [`crate::compress::Compressor::decompress_fold_into`],
//! which folds every reconstructed value straight into the accumulator
//! (§3.4–§3.5, Fig. 4). For fZ-light frames, constant blocks — the
//! dominant case on smooth fields — become one broadcast add/max/min over
//! the run with no per-value decode; the `Plain` mode folds directly from
//! the wire bytes. Time spent there is attributed to
//! [`crate::coordinator::Phase::DecompressReduce`], since decode and
//! reduce are no longer separable once fused.
//!
//! ## Modes
//!
//! Every collective is implemented in the paper's four flat modes
//! (Table 6) plus the two-level hierarchical mode:
//!
//! | mode       | data movement (§3.1.1)            | computation (§3.1.2)              |
//! |------------|-----------------------------------|-----------------------------------|
//! | `Plain`    | no compression (original MPI)     | no compression                    |
//! | `Cprp2p`   | compress before EVERY send, decompress after EVERY recv (Zhou et al.) |
//! | `CColl`    | compress-once framework, SZx      | compressed RS, no overlap (IPDPS'24 C-Coll) |
//! | `Zccl`     | compress-once + balanced pipeline | PIPE-fZ-light overlap (§3.5.2)    |
//! | `Hier`     | two-level: raw `f32` windows on the fast intra-node tier (optionally compressed via [`CollCtx::set_intra_mode`]), ZCCL compressed frames between node **leaders** only (gZCCL-style; see [`hier`]) | intra-node reduce → inter-leader ZCCL reduce-scatter → intra-node bcast |
//!
//! `Hier` consumes a [`crate::topology::Topology`] from the context
//! ([`CollCtx::over_nodes`] / [`CollCtx::set_topology`]); without one it
//! defaults to [`crate::topology::Topology::flat`] and degenerates to
//! flat `Zccl`. Every non-barrier collective has a genuine two-level
//! schedule under `Hier` — allreduce, reduce-scatter, allgather,
//! alltoall, bcast, scatter, gather, and reduce all keep inter-node
//! traffic strictly leader↔leader, with the inter-leader bundle paths
//! segmented by the §3.5.1 fixed pipeline
//! ([`Mode::pipeline_bytes`], sized per tier by
//! [`crate::sim::calibrate::pick_segment_bytes`]); there are no flat
//! fallbacks.
//!
//! The collectives are SPMD operations over a [`Communicator`]: all
//! ranks of the communicator must issue the same operations (blocking
//! calls and request *starts*) in the same order (MPI semantics). Timing
//! is attributed per phase through [`crate::coordinator::Metrics`].

pub mod allgather;
pub mod allreduce;
pub mod alltoall;
pub mod bcast;
pub mod ctx;
pub mod gather;
pub mod hier;
pub mod nonblocking;
pub mod progress;
pub mod reduce;
pub mod reduce_scatter;
pub mod scatter;

pub use allgather::allgather;
pub use allreduce::allreduce;
pub use ctx::{CollCtx, PoolStats, ScratchPool};
pub use alltoall::alltoall;
pub use nonblocking::{CollOutput, CollRequest};
pub use bcast::bcast;
pub use gather::gather;
pub use reduce::reduce;
pub use reduce_scatter::reduce_scatter;
pub use scatter::scatter;

use crate::compress::{CompressorKind, ErrorBound};
use crate::transport::memchan::MemFabric;
use crate::transport::Transport;
use crate::Result;

/// The reduction operators the paper analyses (§3.2). Defined in
/// [`crate::ops`] — a leaf module below both the collective and the
/// compression layer, because the fused decompress–reduce kernels
/// ([`crate::compress::Compressor::decompress_fold_into`]) need the fold
/// semantics too; this remains the canonical public path.
pub use crate::ops::ReduceOp;

/// Which collective framework to run (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algo {
    /// Original MPI — no compression.
    Plain,
    /// Compression-enabled point-to-point (compress/decompress every hop).
    Cprp2p,
    /// The IPDPS'24 C-Coll baseline (SZx, compress-once, no overlap).
    CColl,
    /// This paper: compress-once + balanced pipeline + PIPE overlap.
    Zccl,
    /// Two-level topology-aware schedules: raw exchanges inside a node,
    /// ZCCL compressed frames between node leaders only (see [`hier`]).
    Hier,
}

/// Full mode description for a collective call.
#[derive(Debug, Clone, Copy)]
pub struct Mode {
    /// Framework.
    pub algo: Algo,
    /// Codec for the compressed modes.
    pub kind: CompressorKind,
    /// Error bound (fixed-accuracy).
    pub eb: ErrorBound,
    /// Use the rayon multi-thread codec wrappers.
    pub multithread: bool,
    /// PIPE-fZ-light chunk size in values (paper: 5120).
    pub pipe_chunk: usize,
    /// Fixed pipeline segment size in bytes for the balanced allgather
    /// (§3.5.1 "fixed pipeline size").
    pub pipeline_bytes: usize,
    /// Emit staged (version-2) fZ-light frames: per-chunk plain /
    /// fixed-width / entropy-coded selection
    /// (see [`crate::compress::fzlight`]). Ignored for other codecs;
    /// every decode path accepts both frame versions regardless.
    pub staged: bool,
}

impl Mode {
    /// Original MPI, no compression.
    pub fn plain() -> Mode {
        Mode {
            algo: Algo::Plain,
            kind: CompressorKind::FzLight,
            eb: ErrorBound::Abs(0.0),
            multithread: false,
            pipe_chunk: crate::compress::fzlight::DEFAULT_CHUNK,
            pipeline_bytes: 1 << 16,
            staged: false,
        }
    }
    /// CPRP2P with the given codec.
    pub fn cprp2p(kind: CompressorKind, eb: ErrorBound) -> Mode {
        Mode { algo: Algo::Cprp2p, kind, eb, ..Mode::plain() }
    }
    /// The C-Coll baseline (always SZx, per the paper).
    pub fn ccoll(eb: ErrorBound) -> Mode {
        Mode { algo: Algo::CColl, kind: CompressorKind::Szx, eb, ..Mode::plain() }
    }
    /// ZCCL with the given codec.
    pub fn zccl(kind: CompressorKind, eb: ErrorBound) -> Mode {
        Mode { algo: Algo::Zccl, kind, eb, ..Mode::plain() }
    }
    /// Hierarchical two-level mode: the inter-leader tier runs ZCCL with
    /// the given codec, the intra-node tier ships raw `f32`. Pair with
    /// [`CollCtx::over_nodes`] or [`CollCtx::set_topology`]; without a
    /// topology it degenerates to flat ZCCL.
    pub fn hier(kind: CompressorKind, eb: ErrorBound) -> Mode {
        Mode { algo: Algo::Hier, kind, eb, ..Mode::plain() }
    }
    /// Toggle the multi-thread codec wrappers.
    pub fn with_multithread(mut self, mt: bool) -> Mode {
        self.multithread = mt;
        self
    }
    /// Override the PIPE chunk size (values).
    pub fn with_pipe_chunk(mut self, values: usize) -> Mode {
        self.pipe_chunk = values;
        self
    }
    /// Override the fixed pipeline segment size in bytes for the balanced
    /// allgather (§3.5.1). Counterpart of [`Mode::with_pipe_chunk`]; the
    /// field existed without a builder before.
    pub fn with_pipeline_bytes(mut self, bytes: usize) -> Mode {
        self.pipeline_bytes = bytes;
        self
    }
    /// Toggle staged (version-2) fZ-light frames with adaptive per-chunk
    /// plain / fixed-width / entropy-coded selection.
    pub fn with_staged(mut self, staged: bool) -> Mode {
        self.staged = staged;
        self
    }

    /// Whether this mode compresses at all.
    pub fn compresses(&self) -> bool {
        self.algo != Algo::Plain
    }

    /// Build the (possibly multithreaded) codec for this mode.
    pub fn codec(&self) -> Box<dyn crate::compress::Compressor> {
        if self.multithread {
            Box::new(
                crate::compress::multithread::MtCompressor::with_chunk(self.kind, self.pipe_chunk)
                    .with_staged(self.staged),
            )
        } else {
            match self.kind {
                CompressorKind::FzLight => Box::new(
                    crate::compress::FzLight::with_chunk(self.pipe_chunk)
                        .with_staged(self.staged),
                ),
                CompressorKind::Szx => {
                    Box::new(crate::compress::Szx::with_chunk(self.pipe_chunk))
                }
                other => crate::compress::build(other),
            }
        }
    }
}

/// A communicator: a transport endpoint plus collective-call tag
/// sequencing. All ranks must issue collectives in the same order.
pub struct Communicator<'a> {
    t: &'a mut dyn Transport,
    next_tag: u64,
}

impl<'a> Communicator<'a> {
    /// Wrap a transport endpoint.
    pub fn new(t: &'a mut dyn Transport) -> Self {
        Communicator { t, next_tag: 0 }
    }
    /// This rank.
    pub fn rank(&self) -> usize {
        self.t.rank()
    }
    /// Communicator size.
    pub fn size(&self) -> usize {
        self.t.size()
    }
    /// Reserve a tag range for one collective call (deterministic across
    /// ranks because call order is identical).
    ///
    /// Panics if the reservation would run into the transport's reserved
    /// barrier namespace; fallible callers (the nonblocking request
    /// starts) use [`Communicator::try_fresh_tags`].
    pub fn fresh_tags(&mut self, count: u64) -> u64 {
        self.try_fresh_tags(count).expect("collective tag space exhausted")
    }
    /// Fallible [`Communicator::fresh_tags`]: reserve `count` tags, or
    /// refuse (committing nothing) if the reservation would overflow into
    /// [`crate::transport::BARRIER_TAG_BASE`]'s reserved namespace. Every
    /// in-flight nonblocking request holds its own slice from this
    /// sequence, so two requests on one context can never cross-match
    /// tags — the guard turns an eventual silent collision into an error
    /// at start time.
    pub fn try_fresh_tags(&mut self, count: u64) -> Result<u64> {
        let base = self.next_tag;
        let end = base.checked_add(count).ok_or_else(|| {
            crate::Error::invalid("collective tag space exhausted (tag counter overflow)")
        })?;
        if end > crate::transport::BARRIER_TAG_BASE {
            return Err(crate::Error::invalid(format!(
                "collective tag space exhausted: reserving {count} tags at {base} would \
                 cross the barrier namespace at {}",
                crate::transport::BARRIER_TAG_BASE
            )));
        }
        self.next_tag = end;
        Ok(base)
    }
    /// Access the raw transport.
    pub fn transport(&mut self) -> &mut dyn Transport {
        self.t
    }
    /// Lease a wire buffer from the transport's packet pool (see
    /// [`crate::transport::PacketPool`]). Pair with
    /// [`Communicator::recycle`].
    pub fn lease(&mut self) -> Vec<u8> {
        self.t.lease()
    }
    /// Return a wire buffer to the transport's packet pool.
    pub fn recycle(&mut self, buf: Vec<u8>) {
        self.t.recycle(buf)
    }
    /// The transport's packet-pool counters.
    pub fn packet_stats(&self) -> crate::transport::PacketPoolStats {
        self.t.packet_stats()
    }
    /// Synchronise all ranks. The barrier's generation is a
    /// [`crate::transport::BARRIER_GEN_SPAN`]-wide slice of this
    /// communicator's tag counter, so distinct barrier calls — and
    /// barriers of sub-communicators, whose group translation offsets the
    /// low bits by a counter-allocated base — use disjoint wire tags
    /// ([`crate::transport::barrier_tag`]).
    pub fn barrier(&mut self) -> Result<()> {
        let gen = self.fresh_tags(crate::transport::BARRIER_GEN_SPAN);
        self.t.barrier(gen)
    }
}

/// Spawn `n` in-process ranks, each running `f` over its own
/// [`Communicator`]; returns the per-rank results in rank order.
pub fn run_ranks<R, F>(n: usize, f: F) -> Vec<R>
where
    R: Send + 'static,
    F: Fn(&mut Communicator) -> R + Send + Sync + 'static,
{
    MemFabric::run(n, move |t| {
        let mut comm = Communicator::new(t);
        f(&mut comm)
    })
}

/// [`run_ranks`] over a node-partitioned fabric: one rank per entry of
/// `topo`, with every message tier-classified. Returns the per-rank
/// results plus the fabric's [`crate::transport::memchan::TrafficReport`]
/// (bytes crossing the slow tier, which rank pairs crossed it).
pub fn run_ranks_on<R, F>(
    topo: &crate::topology::Topology,
    f: F,
) -> (Vec<R>, crate::transport::memchan::TrafficReport)
where
    R: Send + 'static,
    F: Fn(&mut Communicator) -> R + Send + Sync + 'static,
{
    MemFabric::run_on_nodes(topo, move |t| {
        let mut comm = Communicator::new(t);
        f(&mut comm)
    })
}

/// [`run_ranks`] with every wire message recorded: returns the per-rank
/// results plus the exact per-`(src, dst, tag)` message counts
/// ([`crate::transport::memchan::MessageLedger`]). The schedule
/// verifier's property tests run real collectives under this and assert
/// the ledger equals the analyzer's predicted message graph.
pub fn run_ranks_traced<R, F>(
    n: usize,
    f: F,
) -> (Vec<R>, crate::transport::memchan::MessageLedger)
where
    R: Send + 'static,
    F: Fn(&mut Communicator) -> R + Send + Sync + 'static,
{
    MemFabric::run_traced(n, move |t| {
        let mut comm = Communicator::new(t);
        f(&mut comm)
    })
}

/// [`run_ranks_traced`] over a node-partitioned fabric — the traced twin
/// of [`run_ranks_on`], used to ledger-check the hierarchical schedules.
pub fn run_ranks_traced_on<R, F>(
    topo: &crate::topology::Topology,
    f: F,
) -> (Vec<R>, crate::transport::memchan::MessageLedger)
where
    R: Send + 'static,
    F: Fn(&mut Communicator) -> R + Send + Sync + 'static,
{
    MemFabric::run_traced_on_nodes(topo, move |t| {
        let mut comm = Communicator::new(t);
        f(&mut comm)
    })
}

/// Split `total` elements into `n` contiguous chunks (first `total % n`
/// chunks get one extra element — MPI's standard partitioning).
pub fn chunk_ranges(total: usize, n: usize) -> Vec<std::ops::Range<usize>> {
    let base = total / n;
    let rem = total % n;
    let mut out = Vec::with_capacity(n);
    let mut start = 0;
    for i in 0..n {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Encode an `f32` slice little-endian.
pub fn f32s_to_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    f32s_to_bytes_into(v, &mut out);
    out
}

/// Encode an `f32` slice little-endian, appending to `out`.
pub fn f32s_to_bytes_into(v: &[f32], out: &mut Vec<u8>) {
    out.reserve(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Decode a little-endian `f32` buffer.
pub fn bytes_to_f32s(b: &[u8]) -> Result<Vec<f32>> {
    let mut out = Vec::with_capacity(b.len() / 4);
    bytes_to_f32s_into(b, &mut out)?;
    Ok(out)
}

/// Decode a little-endian `f32` buffer, appending to `out`; returns the
/// decoded count.
pub fn bytes_to_f32s_into(b: &[u8], out: &mut Vec<f32>) -> Result<usize> {
    if b.len() % 4 != 0 {
        return Err(crate::Error::corrupt(format!("byte length {} not 4-aligned", b.len())));
    }
    out.reserve(b.len() / 4);
    out.extend(b.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())));
    Ok(b.len() / 4)
}

/// Decode a little-endian `f32` wire buffer straight into its final
/// window of the output — the `Plain` mode's placement decode. The buffer
/// must hold exactly `out.len()` values. Returns the decoded count.
pub(crate) fn bytes_to_f32s_into_slice(b: &[u8], out: &mut [f32]) -> Result<usize> {
    if b.len() % 4 != 0 {
        return Err(crate::Error::corrupt(format!("byte length {} not 4-aligned", b.len())));
    }
    if b.len() / 4 != out.len() {
        return Err(crate::Error::corrupt(format!(
            "wire buffer holds {} values but destination holds {}",
            b.len() / 4,
            out.len()
        )));
    }
    for (slot, c) in out.iter_mut().zip(b.chunks_exact(4)) {
        *slot = f32::from_le_bytes(c.try_into().unwrap());
    }
    Ok(out.len())
}

/// Fold a little-endian `f32` wire buffer straight into `acc` — the
/// `Plain` mode's fused receive side: decode and reduce in one pass with
/// no intermediate vector. The buffer must hold exactly `acc.len()`
/// values. Returns the folded count.
pub(crate) fn fold_f32_bytes(op: ReduceOp, b: &[u8], acc: &mut [f32]) -> Result<usize> {
    if b.len() % 4 != 0 {
        return Err(crate::Error::corrupt(format!("byte length {} not 4-aligned", b.len())));
    }
    let n = b.len() / 4;
    if n != acc.len() {
        return Err(crate::Error::corrupt(format!(
            "partial holds {n} values but accumulator expects {}",
            acc.len()
        )));
    }
    for (a, c) in acc.iter_mut().zip(b.chunks_exact(4)) {
        op.apply(a, f32::from_le_bytes(c.try_into().unwrap()));
    }
    Ok(n)
}

/// Exchange one `u64` per rank over the ring — the §3.5.1 size
/// synchronisation. The paper sends 4-byte sizes ("as the compressed data
/// size only has four bytes, this step is very fast"); we widen to 8 bytes
/// so compressed chunks ≥ 4 GiB cannot silently truncate — still a
/// trivially small message per rank. Returns the value from every rank.
pub(crate) fn exchange_sizes(
    comm: &mut Communicator,
    mine: u64,
    tag_base: u64,
) -> Result<Vec<u64>> {
    let n = comm.size();
    let me = comm.rank();
    let mut sizes = vec![0u64; n];
    sizes[me] = mine;
    let ring = crate::topology::ring(me, n);
    let plan = crate::analysis::plan::RingPlan::at(tag_base, n);
    let mut buf = comm.t.lease();
    for round in 0..n.saturating_sub(1) {
        let send_idx = crate::topology::ring_send_chunk(me, round, n);
        let recv_idx = crate::topology::ring_recv_chunk(me, round, n);
        comm.t.send(ring.next, plan.round_tag(round), &sizes[send_idx].to_le_bytes())?;
        comm.t.recv_into(ring.prev, plan.round_tag(round), &mut buf)?;
        sizes[recv_idx] =
            u64::from_le_bytes(buf.as_slice().try_into().map_err(|_| {
                crate::Error::corrupt("size exchange message must be 8 bytes")
            })?);
    }
    comm.t.recycle(buf);
    Ok(sizes)
}

/// Maximum tags a single segmented transfer may consume (tag arithmetic
/// budget per round). Transfers needing more segments are rejected by
/// [`send_segmented`] / [`recv_segmented_into`] — silently exceeding the
/// span would collide with the next round's (or the next collective's)
/// tag space and cross-match messages. Public because the tag-layout
/// plans in [`crate::analysis::plan`] ration rounds by this span and the
/// schedule verifier checks every fan against it.
pub const SEG_TAG_SPAN: u64 = 1 << 20;

/// Number of segments a `total`-byte transfer splits into, validated
/// against the [`SEG_TAG_SPAN`] tag budget.
pub(crate) fn segment_count(total: usize, segment: usize) -> Result<usize> {
    let nseg = total.div_ceil(segment.max(1)).max(1);
    if nseg as u64 > SEG_TAG_SPAN {
        return Err(crate::Error::corrupt(format!(
            "segmented transfer of {total} bytes at segment {segment} needs {nseg} tags, \
             exceeding the per-round budget of {SEG_TAG_SPAN}"
        )));
    }
    Ok(nseg)
}

/// Send `data` as fixed-size pipeline segments (§3.5.1's balanced
/// communication). The receiver knows the total from the size table.
pub(crate) fn send_segmented(
    t: &mut dyn Transport,
    to: usize,
    tag_base: u64,
    data: &[u8],
    segment: usize,
) -> Result<u64> {
    segment_count(data.len(), segment)?;
    let mut sent = 0u64;
    if data.is_empty() {
        t.send(to, tag_base, &[])?;
        return Ok(0);
    }
    for (i, seg) in data.chunks(segment.max(1)).enumerate() {
        t.send(to, tag_base + i as u64, seg)?;
        sent += seg.len() as u64;
    }
    Ok(sent)
}

/// Receive a `total`-byte message sent by [`send_segmented`] into `out`
/// (overwritten). Single-segment transfers — the common case for
/// compressed chunks under the pipeline size — arrive by zero-copy buffer
/// swap ([`Transport::recv_into`]); multi-segment transfers assemble into
/// `out` through one pooled segment buffer.
pub(crate) fn recv_segmented_into(
    t: &mut dyn Transport,
    from: usize,
    tag_base: u64,
    total: usize,
    segment: usize,
    out: &mut Vec<u8>,
) -> Result<()> {
    let nseg = segment_count(total, segment)?;
    if nseg == 1 {
        t.recv_into(from, tag_base, out)?;
    } else {
        out.clear();
        out.reserve(total);
        let mut seg_buf = t.lease();
        for i in 0..nseg {
            t.recv_into(from, tag_base + i as u64, &mut seg_buf)?;
            out.extend_from_slice(&seg_buf);
        }
        t.recycle(seg_buf);
    }
    if out.len() != total {
        return Err(crate::Error::corrupt(format!(
            "segmented recv got {} of {total} bytes",
            out.len()
        )));
    }
    Ok(())
}

/// Receive a `total`-byte message sent by [`send_segmented`] into a fresh
/// vector. Wrapper over [`recv_segmented_into`]; the collectives lease a
/// wire buffer and use the `_into` form.
#[cfg(test)]
pub(crate) fn recv_segmented(
    t: &mut dyn Transport,
    from: usize,
    tag_base: u64,
    total: usize,
    segment: usize,
) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    recv_segmented_into(t, from, tag_base, total, segment, &mut out)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_ranges_cover() {
        for (total, n) in [(10usize, 3usize), (9, 3), (1, 4), (0, 2), (100, 7)] {
            let r = chunk_ranges(total, n);
            assert_eq!(r.len(), n);
            assert_eq!(r[0].start, 0);
            assert_eq!(r[n - 1].end, total);
            for w in r.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
            // Sizes differ by at most 1.
            let lens: Vec<usize> = r.iter().map(|x| x.len()).collect();
            let (lo, hi) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(hi - lo <= 1);
        }
    }

    #[test]
    fn f32_bytes_roundtrip() {
        let v = vec![1.5f32, -2.25, 0.0, f32::MIN_POSITIVE];
        assert_eq!(bytes_to_f32s(&f32s_to_bytes(&v)).unwrap(), v);
        assert!(bytes_to_f32s(&[0u8; 3]).is_err());
    }

    #[test]
    fn size_exchange_all_ranks() {
        let n = 5;
        let out = run_ranks(n, move |c| {
            let tag = c.fresh_tags(n as u64);
            exchange_sizes(c, (c.rank() * 10) as u64, tag).unwrap()
        });
        for sizes in out {
            assert_eq!(sizes, vec![0, 10, 20, 30, 40]);
        }
    }

    #[test]
    fn size_exchange_carries_over_4gib_values() {
        // The u64 widening exists exactly for this: a compressed chunk
        // larger than u32::MAX bytes must survive the exchange intact.
        let n = 3;
        let big = (u32::MAX as u64) + 12345;
        let out = run_ranks(n, move |c| {
            let tag = c.fresh_tags(n as u64);
            exchange_sizes(c, big + c.rank() as u64, tag).unwrap()
        });
        for sizes in out {
            assert_eq!(sizes, vec![big, big + 1, big + 2]);
        }
    }

    #[test]
    fn segmented_roundtrip() {
        let out = run_ranks(2, |c| {
            let tag = c.fresh_tags(SEG_TAG_SPAN);
            if c.rank() == 0 {
                let data: Vec<u8> = (0..1000u32).map(|i| i as u8).collect();
                send_segmented(c.t, 1, tag, &data, 64).unwrap();
                Vec::new()
            } else {
                recv_segmented(c.t, 0, tag, 1000, 64).unwrap()
            }
        });
        assert_eq!(out[1].len(), 1000);
        assert_eq!(out[1][999], (999u32 & 0xff) as u8);
    }

    #[test]
    fn segmented_transfer_rejects_tag_budget_overflow() {
        // Satellite regression: a transfer needing more than SEG_TAG_SPAN
        // segments used to run straight past its tag budget and collide
        // with the next round's tags; now both sides refuse up front
        // (before any message moves).
        let mut eps = crate::transport::memchan::MemFabric::endpoints(2);
        let too_many = (SEG_TAG_SPAN as usize + 1) * 2; // 2-byte segments
        let data = vec![0u8; too_many];
        assert!(send_segmented(&mut eps[0], 1, 0, &data, 2).is_err());
        let mut out = Vec::new();
        assert!(recv_segmented_into(&mut eps[1], 0, 0, too_many, 2, &mut out).is_err());
        // The largest in-budget segment count is still accepted.
        assert!(segment_count(SEG_TAG_SPAN as usize * 2, 2).is_ok());
        assert!(segment_count(SEG_TAG_SPAN as usize * 2 + 1, 2).is_err());
    }

    #[test]
    fn recv_segmented_single_segment_is_a_buffer_swap() {
        // total <= segment: the payload must arrive through the zero-copy
        // recv_into path — warm packet-pool allocations freeze.
        let mut eps = MemFabric::endpoints(2);
        let (a, b) = eps.split_at_mut(1);
        let (t0, t1) = (&mut a[0], &mut b[0]);
        let mut wire = t1.lease();
        let mut warm = 0;
        for i in 0..4u64 {
            send_segmented(t0, 1, i * 10, &[9u8; 512], usize::MAX).unwrap();
            recv_segmented_into(t1, 0, i * 10, 512, usize::MAX, &mut wire).unwrap();
            assert_eq!(wire.len(), 512);
            if i == 1 {
                warm = t1.packet_stats().allocated;
            }
        }
        assert_eq!(t1.packet_stats().allocated, warm, "warm swaps must not allocate");
        t1.recycle(wire);
    }

    #[test]
    fn fresh_tags_budget_guard_refuses_barrier_collision() {
        // Satellite regression: every in-flight request's tag slice comes
        // from this counter; the guard must hand out disjoint slices and
        // refuse (without committing) once a reservation would run into
        // the transport's reserved barrier namespace.
        let mut eps = MemFabric::endpoints(1);
        let mut c = Communicator::new(&mut eps[0]);
        let a = c.try_fresh_tags(10).unwrap();
        let b = c.try_fresh_tags(10).unwrap();
        assert_eq!(b, a + 10, "reservations must be disjoint and ordered");
        assert!(c.try_fresh_tags(u64::MAX).is_err(), "overflow must be refused");
        let left = crate::transport::BARRIER_TAG_BASE - (b + 10);
        assert!(c.try_fresh_tags(left + 1).is_err(), "crossing the barrier base must fail");
        // A refused reservation commits nothing: the exact remainder
        // still fits...
        let d = c.try_fresh_tags(left).unwrap();
        assert_eq!(d, b + 10);
        // ...and afterwards the space is genuinely exhausted.
        assert!(c.try_fresh_tags(1).is_err());
    }

    #[test]
    fn reduce_op_folds() {
        let mut acc = vec![1.0f32, 5.0, -2.0];
        ReduceOp::Sum.fold(&mut acc, &[1.0, 1.0, 1.0]);
        assert_eq!(acc, vec![2.0, 6.0, -1.0]);
        ReduceOp::Max.fold(&mut acc, &[0.0, 10.0, 0.0]);
        assert_eq!(acc, vec![2.0, 10.0, 0.0]);
        ReduceOp::Min.fold(&mut acc, &[-5.0, 100.0, 0.5]);
        assert_eq!(acc, vec![-5.0, 10.0, 0.0]);
        let mut avg = vec![10.0f32, 20.0];
        ReduceOp::Avg.finish(&mut avg, 4);
        assert_eq!(avg, vec![2.5, 5.0]);
    }

    #[test]
    fn apply_and_apply_run_match_fold_bitwise() {
        let base = vec![1.5f32, -0.25, 3.0e-7, -9.75, 0.0];
        let src = vec![0.1f32, -2.0, 4.5e-7, -9.75, -0.0];
        for op in [ReduceOp::Sum, ReduceOp::Avg, ReduceOp::Max, ReduceOp::Min] {
            let mut folded = base.clone();
            op.fold(&mut folded, &src);
            let mut applied = base.clone();
            for (a, &v) in applied.iter_mut().zip(&src) {
                op.apply(a, v);
            }
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&folded), bits(&applied), "{op:?}");
            // apply_run == fold against a constant source.
            let mut run = base.clone();
            op.apply_run(&mut run, 0.75);
            let constant = vec![0.75f32; base.len()];
            let mut want = base.clone();
            op.fold(&mut want, &constant);
            assert_eq!(bits(&run), bits(&want), "{op:?} run");
        }
    }

    #[test]
    fn fold_f32_bytes_matches_decode_then_fold() {
        let src = vec![2.0f32, -1.5, 0.25];
        let wire = f32s_to_bytes(&src);
        let mut fused = vec![1.0f32, 1.0, 1.0];
        assert_eq!(fold_f32_bytes(ReduceOp::Sum, &wire, &mut fused).unwrap(), 3);
        let mut unfused = vec![1.0f32, 1.0, 1.0];
        ReduceOp::Sum.fold(&mut unfused, &bytes_to_f32s(&wire).unwrap());
        assert_eq!(fused, unfused);
        // Misaligned and mis-sized buffers are rejected.
        assert!(fold_f32_bytes(ReduceOp::Sum, &wire[..5], &mut fused).is_err());
        assert!(fold_f32_bytes(ReduceOp::Sum, &wire, &mut fused[..2]).is_err());
    }
}
