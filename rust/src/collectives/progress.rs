//! The per-rank cooperative progress engine behind the nonblocking
//! collectives ([`super::nonblocking`]).
//!
//! There is no progress *thread*: the transport endpoint is `&mut`-owned
//! by the rank's [`super::Communicator`], so all progress is pulled
//! cooperatively from the application thread — exactly the §3.5.2
//! discipline the blocking schedules already use, generalised to many
//! outstanding operations. Every call to [`super::CollCtx::test`] /
//! [`super::CollCtx::wait`] steps **all** resident state machines
//! round-robin, so a request keeps moving even while the caller polls a
//! different one.
//!
//! The engine is a slab: starting a request inserts its
//! [`super::nonblocking::Machine`] and hands back a slot index (wrapped
//! in a [`super::nonblocking::CollRequest`]); completion parks the output
//! in the slot until the caller collects it. Slots are generation-tagged
//! so a stale request handle can never observe a recycled slot.

use super::ctx::CollState;
use super::nonblocking::{CollOutput, Machine};
use super::Communicator;
use crate::coordinator::Metrics;
use crate::transport::{RecvHandle, Transport};
use crate::{Error, Result};

/// One resumable receive: a posted [`RecvHandle`] plus the leased wire
/// buffer its payload will swap into. The state machines park one of
/// these per outstanding message and poll it on every step.
pub(crate) struct RecvSlot {
    h: RecvHandle,
    /// Transport-leased wire buffer; the payload arrives here by swap.
    pub(crate) buf: Vec<u8>,
    done: bool,
}

impl RecvSlot {
    /// Post a nonblocking receive and lease its landing buffer.
    pub(crate) fn post(t: &mut dyn Transport, from: usize, tag: u64) -> RecvSlot {
        RecvSlot { h: t.irecv(from, tag), buf: t.lease(), done: false }
    }

    /// Poll the receive (idempotent after completion). `Ok(true)` means
    /// the payload is in [`RecvSlot::buf`].
    pub(crate) fn poll(&mut self, t: &mut dyn Transport) -> Result<bool> {
        if !self.done && t.try_complete_into(&mut self.h, &mut self.buf)? {
            self.done = true;
        }
        Ok(self.done)
    }

    /// Split-borrow accessor for progress hooks: the handle, the landing
    /// buffer and the completion flag as three disjoint `&mut`s.
    pub(crate) fn parts(&mut self) -> (&mut RecvHandle, &mut Vec<u8>, &mut bool) {
        (&mut self.h, &mut self.buf, &mut self.done)
    }

    /// The `(source rank, tag)` this slot is still waiting on, or `None`
    /// once the payload has arrived — the unit of [`crate::Error::Timeout`]
    /// pending reports.
    pub(crate) fn pending_origin(&self) -> Option<(usize, u64)> {
        (!self.done).then_some((self.h.from, self.h.tag))
    }

    /// Consume the slot, returning the payload buffer (the receive must
    /// have completed).
    pub(crate) fn into_buf(self) -> Vec<u8> {
        debug_assert!(self.done, "into_buf on an incomplete receive");
        self.buf
    }

    /// Consume the slot after its payload has been copied out, returning
    /// the buffer to the transport pool.
    pub(crate) fn recycle(self, t: &mut dyn Transport) {
        t.recycle(self.buf);
    }
}

/// A slab slot: a running machine, a parked result, or a parked error.
enum Entry {
    Running(Machine),
    Done(CollOutput),
    Failed(Error),
}

/// The slab of in-flight nonblocking collectives owned by a
/// [`super::CollCtx`]. See the module docs.
#[derive(Default)]
pub(crate) struct ProgressEngine {
    slots: Vec<Option<Entry>>,
    /// Per-slot generation, bumped when a slot's result is taken; stale
    /// [`super::nonblocking::CollRequest`]s are rejected instead of
    /// aliasing a recycled slot.
    gens: Vec<u64>,
}

impl ProgressEngine {
    fn claim(&mut self, e: Entry) -> (usize, u64) {
        for (i, s) in self.slots.iter_mut().enumerate() {
            if s.is_none() {
                *s = Some(e);
                return (i, self.gens[i]);
            }
        }
        self.slots.push(Some(e));
        self.gens.push(0);
        (self.slots.len() - 1, 0)
    }

    /// Register a running machine; returns `(slot, generation)`.
    pub(crate) fn insert(&mut self, m: Machine) -> (usize, u64) {
        self.claim(Entry::Running(m))
    }

    /// Register an already-finished operation (immediate completions:
    /// single-rank shortcuts and the hierarchical blocking fallback).
    pub(crate) fn insert_done(&mut self, r: Result<CollOutput>) -> (usize, u64) {
        self.claim(match r {
            Ok(out) => Entry::Done(out),
            Err(e) => Entry::Failed(e),
        })
    }

    /// Step every running machine once (each makes maximal progress and
    /// yields only on an un-arrived receive). A machine that errors is
    /// dropped — its pooled buffers are abandoned per the crate-wide
    /// error-path policy (see [`super::ScratchPool`]) — and the error is
    /// parked for the owner's `wait`.
    pub(crate) fn step_all(
        &mut self,
        comm: &mut Communicator,
        st: &mut CollState,
        m: &mut Metrics,
    ) -> Result<()> {
        for slot in self.slots.iter_mut() {
            if let Some(Entry::Running(machine)) = slot {
                match machine.step(comm, st, m) {
                    Ok(Some(out)) => *slot = Some(Entry::Done(out)),
                    Ok(None) => {}
                    Err(e) => *slot = Some(Entry::Failed(e)),
                }
            }
        }
        Ok(())
    }

    /// Whether the slot has finished (successfully or not).
    pub(crate) fn is_done(&self, slot: usize, gen: u64) -> bool {
        matches!(
            self.slots.get(slot),
            Some(Some(Entry::Done(_) | Entry::Failed(_))) if self.gens[slot] == gen
        )
    }

    /// Collect a finished slot's result, freeing the slot. `None` while
    /// still running; `Some(Err(..))` for a stale handle or a failed
    /// machine.
    pub(crate) fn take(&mut self, slot: usize, gen: u64) -> Option<Result<CollOutput>> {
        if slot >= self.slots.len() || self.gens[slot] != gen {
            return Some(Err(Error::invalid("stale or unknown collective request handle")));
        }
        match self.slots[slot] {
            Some(Entry::Running(_)) => None,
            Some(_) => {
                let e = self.slots[slot].take().unwrap();
                self.gens[slot] += 1;
                Some(match e {
                    Entry::Done(out) => Ok(out),
                    Entry::Failed(err) => Err(err),
                    Entry::Running(_) => unreachable!(),
                })
            }
            None => Some(Err(Error::invalid("collective request already collected"))),
        }
    }

    /// Number of requests still in flight (running or uncollected).
    pub(crate) fn in_flight(&self) -> usize {
        self.slots.iter().flatten().count()
    }

    /// The `(source rank, tag)` receives the request is still waiting on —
    /// the payload of the [`crate::Error::Timeout`] a deadline-expired
    /// `wait` reports. Empty for finished, stale or unknown requests.
    pub(crate) fn pending_recvs(&self, slot: usize, gen: u64) -> Vec<(usize, u64)> {
        match self.slots.get(slot) {
            Some(Some(Entry::Running(m))) if self.gens[slot] == gen => m.pending(),
            _ => Vec::new(),
        }
    }
}
