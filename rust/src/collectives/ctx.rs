//! Persistent per-rank collective context — the crate's primary API.
//!
//! ZCCL's premise is that a collective's hot path is bandwidth plus
//! (de)compression; everything else is overhead. The free-function API
//! paid two avoidable costs on every call: a fresh `Box<dyn Compressor>`
//! (`Mode::codec()`), and fresh `Vec`s for every compressed frame,
//! decoded partial and accumulator. C-Coll (arXiv:2304.03890) and gZCCL
//! (arXiv:2308.05199) both stress reusing pre-registered buffers across
//! iterations; [`CollCtx`] is that idea as an API:
//!
//! - the codec (and, for ZCCL's fZ-light, the PIPE codec) is built once
//!   at construction and reused for every call;
//! - a [`ScratchPool`] lends out byte / f32 buffers per call and takes
//!   them back, so after one warm-up call iterated collectives perform
//!   **zero pool growth** (observable through [`PoolStats`]);
//! - the [`Metrics`] sink lives in the context, so callers stop threading
//!   `&mut Metrics` through every call site.
//!
//! The long-standing free functions ([`super::allreduce`] etc.) remain as
//! compatibility shims that build a transient context per call.

use std::ops::Range;
use std::time::{Duration, Instant};

use super::nonblocking::{
    AllgatherSm, AllreduceSm, BcastSm, CollOutput, CollRequest, Machine, ReduceScatterSm,
};
use super::progress::ProgressEngine;
use super::{allgather, allreduce, alltoall, bcast, gather, reduce, reduce_scatter, scatter};
use super::{bytes_to_f32s_into, f32s_to_bytes_into, fold_f32_bytes};
use super::{Algo, Communicator, Mode, ReduceOp};
use crate::analysis::plan::{AllgatherPlan, RingPlan, TreePlan};
use crate::compress::{Compressor, CompressorKind, PipeFzLight};
use crate::coordinator::Metrics;
use crate::transport::{Backoff, Transport, WireStats};
use crate::{Error, Result};

/// Counters exposing the scratch pool's behaviour, for regression tests
/// and capacity planning. All values are cumulative over the pool's life.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Byte buffers newly created because the free list was empty.
    pub byte_buffers_created: u64,
    /// f32 buffers newly created because the free list was empty.
    pub f32_buffers_created: u64,
    /// Checkouts served from the free list instead of the allocator.
    pub reuses: u64,
    /// High-water mark: the largest byte-buffer capacity ever checked in.
    pub byte_capacity_hwm: usize,
    /// High-water mark: the largest f32-buffer capacity ever checked in.
    pub f32_capacity_hwm: usize,
    /// Receive-path decodes that landed directly in the output's final
    /// window (native placement kernel — zero post-decode copies).
    pub placement_decodes: u64,
    /// Receive-path decodes staged through pooled scratch and then
    /// copied into place (codecs without a native placement kernel —
    /// SZx / ZFP behind the `supports_placement_decode` capability gate).
    pub staged_decodes: u64,
}

/// A check-out / check-in free list of scratch buffers. Checked-out
/// buffers are plain owned `Vec`s (so they never fight the borrow
/// checker); checking one back in clears it but keeps its capacity for
/// the next caller.
///
/// Error-path policy: collectives that bail out mid-call simply drop any
/// checked-out buffers instead of returning them — a failed collective
/// leaves the communicator out of sync, so the next successful call (if
/// any) re-populates the pool with one extra allocation rather than
/// every call paying an unwind guard.
#[derive(Debug, Default)]
pub struct ScratchPool {
    bytes: Vec<Vec<u8>>,
    f32s: Vec<Vec<f32>>,
    stats: PoolStats,
}

impl ScratchPool {
    /// Free-list depth cap per type; buffers checked in beyond this are
    /// dropped rather than hoarded. Sized so the widest per-call fan-out
    /// (alltoall checks out one byte buffer per peer) stays fully pooled
    /// at the rank counts this in-process substrate runs; beyond it the
    /// pool degrades gracefully to per-call allocation for the overflow.
    const MAX_FREE: usize = 64;

    /// Check out a cleared byte buffer (reusing capacity when available).
    pub fn take_bytes(&mut self) -> Vec<u8> {
        match self.bytes.pop() {
            Some(b) => {
                self.stats.reuses += 1;
                b
            }
            None => {
                self.stats.byte_buffers_created += 1;
                Vec::new()
            }
        }
    }

    /// Check a byte buffer back in.
    pub fn put_bytes(&mut self, mut b: Vec<u8>) {
        self.stats.byte_capacity_hwm = self.stats.byte_capacity_hwm.max(b.capacity());
        if self.bytes.len() < Self::MAX_FREE {
            b.clear();
            self.bytes.push(b);
        }
    }

    /// Check out a cleared f32 buffer (reusing capacity when available).
    pub fn take_f32(&mut self) -> Vec<f32> {
        match self.f32s.pop() {
            Some(b) => {
                self.stats.reuses += 1;
                b
            }
            None => {
                self.stats.f32_buffers_created += 1;
                Vec::new()
            }
        }
    }

    /// Check an f32 buffer back in.
    pub fn put_f32(&mut self, mut b: Vec<f32>) {
        self.stats.f32_capacity_hwm = self.stats.f32_capacity_hwm.max(b.capacity());
        if self.f32s.len() < Self::MAX_FREE {
            b.clear();
            self.f32s.push(b);
        }
    }

    /// Record a placement decode (receive frame decoded straight into its
    /// final output window).
    pub(crate) fn note_placement_decode(&mut self) {
        self.stats.placement_decodes += 1;
    }

    /// Record a staged decode (receive frame decoded into pooled scratch,
    /// then copied into place — the capability-gated fallback).
    pub(crate) fn note_staged_decode(&mut self) {
        self.stats.staged_decodes += 1;
    }

    /// Current counters.
    pub fn stats(&self) -> PoolStats {
        self.stats
    }
}

/// The reusable (communicator-independent) half of a [`CollCtx`]: mode,
/// instantiated codec(s), scratch pool, and the codec-construction
/// counter. Collective implementations receive `&mut CollState` so both
/// the persistent context and the per-call compatibility shims share one
/// code path.
pub struct CollState {
    pub(crate) mode: Mode,
    pub(crate) codec: Box<dyn Compressor>,
    /// Pre-built PIPE codec for the §3.5.2 overlap (ZCCL/Hier +
    /// fZ-light, single-thread only — same condition the reduce-scatter
    /// used to evaluate per call).
    pub(crate) pipe: Option<PipeFzLight>,
    pub(crate) pool: ScratchPool,
    pub(crate) codec_builds: u64,
    /// Codec compression invocations (every frame built by this state) —
    /// the leader-side counter the hierarchical acceptance tests pin:
    /// under [`Algo::Hier`] only leaders (and tree roots) may compress.
    pub(crate) compress_calls: u64,
    /// Rank→node topology for the hierarchical schedules, shared by
    /// reference so every hierarchical call clones an `Arc`, not the
    /// node tables. `None` under [`Algo::Hier`] means
    /// [`crate::topology::Topology::flat`] — every rank its own node,
    /// degenerating to flat ZCCL.
    pub(crate) topo: Option<std::sync::Arc<crate::topology::Topology>>,
    /// The intra-node tier's mode. [`Algo::Plain`] (the default) ships
    /// raw `f32` windows over the fast tier; a compressing mode makes
    /// every fast-tier hop a single bounded-error compression (see
    /// [`CollCtx::set_intra_mode`]).
    pub(crate) intra: Mode,
    /// Codec for a compressing intra tier, built once when
    /// [`CollCtx::set_intra_mode`] installs one; `None` means raw.
    pub(crate) intra_codec: Option<Box<dyn Compressor>>,
    /// Compression invocations on the intra tier — kept separate from
    /// [`CollState::compress_calls`] so the "leaders-only" acceptance
    /// counters stay meaningful when the fast tier compresses too.
    pub(crate) intra_compress_calls: u64,
}

impl CollState {
    /// Build the state for `mode`, constructing the codec exactly once.
    pub fn new(mode: Mode) -> CollState {
        let codec = mode.codec();
        let pipe = ((mode.algo == Algo::Zccl || mode.algo == Algo::Hier)
            && mode.kind == CompressorKind::FzLight
            && !mode.multithread)
            .then(|| PipeFzLight::with_chunk(mode.pipe_chunk).with_staged(mode.staged));
        CollState {
            mode,
            codec,
            pipe,
            pool: ScratchPool::default(),
            codec_builds: 1,
            compress_calls: 0,
            topo: None,
            intra: Mode::plain(),
            intra_codec: None,
            intra_compress_calls: 0,
        }
    }

    /// Whether the intra tier compresses (a non-raw mode was installed
    /// via [`CollCtx::set_intra_mode`]).
    pub(crate) fn intra_compresses(&self) -> bool {
        self.intra_codec.is_some()
    }

    /// Serialise `vals` for a fast-tier hop: one compressed frame under
    /// a compressing intra mode (compress-once-per-hop — forwarded
    /// verbatim, never recompressed), plain `f32` bytes otherwise.
    pub(crate) fn intra_encode(&mut self, vals: &[f32], out: &mut Vec<u8>) -> Result<()> {
        match self.intra_codec.as_deref_mut() {
            Some(c) => {
                self.intra_compress_calls += 1;
                c.compress_into(vals, self.intra.eb, out)?;
                Ok(())
            }
            None => {
                f32s_to_bytes_into(vals, out);
                Ok(())
            }
        }
    }

    /// Decode a fast-tier hop's payload into `out` (cleared, then
    /// filled): codec decompression under a compressing intra mode, a
    /// plain `f32` deserialisation otherwise.
    pub(crate) fn intra_decode_into(&mut self, bytes: &[u8], out: &mut Vec<f32>) -> Result<()> {
        out.clear();
        match self.intra_codec.as_deref_mut() {
            Some(c) => {
                c.decompress_into(bytes, out)?;
                Ok(())
            }
            None => bytes_to_f32s_into(bytes, out).map(|_| ()),
        }
    }

    /// Fold a fast-tier hop's payload into `acc` via `op`: pooled
    /// decompress-then-fold under a compressing intra mode, an exact raw
    /// fold otherwise.
    pub(crate) fn intra_fold(&mut self, op: ReduceOp, bytes: &[u8], acc: &mut [f32]) -> Result<()> {
        match self.intra_codec.as_deref_mut() {
            Some(c) => {
                let mut partial = self.pool.take_f32();
                let cnt = c.decompress_into(bytes, &mut partial)?;
                if cnt != acc.len() {
                    return Err(crate::Error::invalid(format!(
                        "intra fold: payload holds {cnt} values but accumulator holds {}",
                        acc.len()
                    )));
                }
                op.fold(acc, &partial);
                self.pool.put_f32(partial);
                Ok(())
            }
            None => fold_f32_bytes(op, bytes, acc).map(|_| ()),
        }
    }

    /// Compress with the context's codec and error bound, appending to
    /// `out`.
    pub(crate) fn compress_into(
        &mut self,
        data: &[f32],
        out: &mut Vec<u8>,
    ) -> Result<crate::compress::CompressionStats> {
        self.compress_calls += 1;
        self.codec.compress_into(data, self.mode.eb, out)
    }

    /// Codec-agnostic decode, appending to `out` and returning the count.
    /// Frames from peers running the same mode hit the resident codec; a
    /// foreign codec id falls back to a transient build (counted).
    pub(crate) fn decode_into(&mut self, bytes: &[u8], out: &mut Vec<f32>) -> Result<usize> {
        let kind = crate::compress::peek_codec(bytes)?;
        if kind == self.codec.kind() {
            self.codec.decompress_into(bytes, out)
        } else {
            self.codec_builds += 1;
            crate::compress::build(kind).decompress_into(bytes, out)
        }
    }

    /// Codec-agnostic **placement decode**: reconstruct the frame's
    /// values directly into `out`, their final window of the assembled
    /// output — the movement collectives' receive path. `out.len()` must
    /// equal the frame's element count; on `Err`, `out` is poisoned (see
    /// [`crate::compress::Compressor::decompress_into_slice`]).
    ///
    /// Codecs with a native in-place kernel run it directly; codecs on
    /// the decompress-then-copy default are routed through the scratch
    /// pool instead, so they keep the zero-alloc warm path rather than
    /// paying the default impl's per-call temporary. Both outcomes are
    /// counted in [`PoolStats`] (`placement_decodes` / `staged_decodes`).
    pub(crate) fn decode_into_slice(&mut self, bytes: &[u8], out: &mut [f32]) -> Result<usize> {
        let kind = crate::compress::peek_codec(bytes)?;
        if kind != self.codec.kind() {
            self.codec_builds += 1;
            return crate::compress::build(kind).decompress_into_slice(bytes, out);
        }
        if self.codec.supports_placement_decode() {
            self.pool.note_placement_decode();
            return self.codec.decompress_into_slice(bytes, out);
        }
        // Pooled decompress-then-copy. Error paths drop the buffer per the
        // crate-wide pool policy (see [`ScratchPool`] docs).
        self.pool.note_staged_decode();
        let mut staged = self.pool.take_f32();
        let cnt = self.codec.decompress_into(bytes, &mut staged)?;
        if cnt != out.len() {
            return Err(crate::Error::invalid(format!(
                "placement decode: frame holds {cnt} values but destination holds {}",
                out.len()
            )));
        }
        out.copy_from_slice(&staged);
        self.pool.put_f32(staged);
        Ok(cnt)
    }

    /// Codec-agnostic **fused decompress–reduce**: fold the frame's values
    /// straight into `acc` via `op` — the reduction collectives' receive
    /// path. `acc.len()` must equal the frame's element count; on `Err`,
    /// `acc` is poisoned (see
    /// [`crate::compress::Compressor::decompress_fold_into`]).
    ///
    /// Codecs with a native single-pass kernel run it directly; codecs on
    /// the decompress-then-fold default are routed through the scratch
    /// pool instead, so they keep the zero-alloc warm path rather than
    /// paying the default impl's per-call temporary.
    pub(crate) fn decode_fold_into(
        &mut self,
        bytes: &[u8],
        op: ReduceOp,
        acc: &mut [f32],
    ) -> Result<usize> {
        let kind = crate::compress::peek_codec(bytes)?;
        if kind != self.codec.kind() {
            self.codec_builds += 1;
            return crate::compress::build(kind).decompress_fold_into(bytes, op, acc);
        }
        if self.codec.supports_fused_fold() {
            return self.codec.decompress_fold_into(bytes, op, acc);
        }
        // Pooled decompress-then-fold. Error paths drop the buffer per the
        // crate-wide pool policy (see [`ScratchPool`] docs).
        let mut partial = self.pool.take_f32();
        let cnt = self.codec.decompress_into(bytes, &mut partial)?;
        if cnt != acc.len() {
            return Err(crate::Error::invalid(format!(
                "fused fold: frame holds {cnt} values but accumulator holds {}",
                acc.len()
            )));
        }
        op.fold(acc, &partial);
        self.pool.put_f32(partial);
        Ok(cnt)
    }

    /// How many codec instances this state has constructed (1 after
    /// [`CollState::new`]; stable across iterated collectives — the
    /// regression test for "no per-iteration codec construction").
    pub fn codec_builds(&self) -> u64 {
        self.codec_builds
    }

    /// Codec compression invocations performed by this state (one per
    /// frame built). Under [`Algo::Hier`], non-leader ranks stay at 0.
    pub fn compress_calls(&self) -> u64 {
        self.compress_calls
    }

    /// Scratch pool counters.
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }
}

/// Persistent per-rank collective context: a [`Communicator`] plus the
/// reusable [`CollState`] and the [`Metrics`] sink. See the module docs
/// for the motivation and [`crate::collectives`] for a usage example.
pub struct CollCtx<'c, 'a> {
    comm: &'c mut Communicator<'a>,
    state: CollState,
    metrics: Metrics,
    /// Slab of in-flight nonblocking requests (see [`super::progress`]).
    engine: ProgressEngine,
    /// Transport wire-counter snapshot at the last [`CollCtx::observe`];
    /// the delta since then is folded into [`Metrics`].
    last_wire: WireStats,
}

impl<'c, 'a> CollCtx<'c, 'a> {
    /// Wrap an existing communicator (keeps its collective-tag sequence,
    /// so contexts and free functions can interleave on one communicator).
    pub fn over(comm: &'c mut Communicator<'a>, mode: Mode) -> Self {
        let last_wire = comm.transport().wire_stats();
        CollCtx {
            comm,
            state: CollState::new(mode),
            metrics: Metrics::default(),
            engine: ProgressEngine::default(),
            last_wire,
        }
    }

    /// [`CollCtx::over`] with a rank→node [`Topology`] for the
    /// hierarchical schedules ([`Algo::Hier`]). Errors if the topology's
    /// rank count does not match the communicator.
    pub fn over_nodes(
        comm: &'c mut Communicator<'a>,
        mode: Mode,
        topo: crate::topology::Topology,
    ) -> Result<Self> {
        let mut ctx = CollCtx::over(comm, mode);
        ctx.set_topology(topo)?;
        Ok(ctx)
    }

    /// Install (or replace) the rank→node topology consumed by
    /// [`Algo::Hier`]. Flat modes ignore it.
    pub fn set_topology(&mut self, topo: crate::topology::Topology) -> Result<()> {
        if topo.ranks() != self.comm.size() {
            return Err(crate::Error::invalid(format!(
                "topology covers {} ranks but the communicator has {}",
                topo.ranks(),
                self.comm.size()
            )));
        }
        self.state.topo = Some(std::sync::Arc::new(topo));
        Ok(())
    }

    /// The installed topology, if any.
    pub fn topology(&self) -> Option<&crate::topology::Topology> {
        self.state.topo.as_deref()
    }

    /// Set the intra-node tier's mode. [`Mode::plain`] (the default)
    /// ships raw `f32` over the fast tier, keeping it exact and the
    /// hierarchical movement collectives bit-identical to flat ZCCL. A
    /// compressing mode turns every fast-tier hop into a **single**
    /// bounded-error compression — each payload is compressed once by
    /// its producer and forwarded verbatim down the member binomial,
    /// never recompressed by the leader — for transports whose
    /// shared-memory tier is slow enough that the codec pays for itself
    /// ([`crate::sim::calibrate::pick_intra_mode`] decides from the
    /// two-tier cost model). A compressed intra tier makes the fast-tier
    /// hops lossy (one extra error bound per hop); `Algo::Hier` cannot
    /// nest as an intra mode.
    pub fn set_intra_mode(&mut self, intra: Mode) -> Result<()> {
        if intra.algo == Algo::Hier {
            return Err(crate::Error::invalid(
                "the intra tier is a leaf of the hierarchy: Algo::Hier cannot nest",
            ));
        }
        self.state.intra_codec = if intra.compresses() {
            self.state.codec_builds += 1;
            Some(intra.codec())
        } else {
            None
        };
        self.state.intra = intra;
        Ok(())
    }

    /// Compression invocations on the intra tier (zero unless a
    /// compressing mode was installed via [`CollCtx::set_intra_mode`]).
    /// Tracked apart from [`CollCtx::compress_calls`] so the
    /// leaders-only inter-tier counters stay meaningful.
    pub fn intra_compress_calls(&self) -> u64 {
        self.state.intra_compress_calls
    }

    /// The intra-node tier's mode (see [`CollCtx::set_intra_mode`]).
    pub fn intra_mode(&self) -> &Mode {
        &self.state.intra
    }

    /// This rank.
    pub fn rank(&self) -> usize {
        self.comm.rank()
    }

    /// Communicator size.
    pub fn size(&self) -> usize {
        self.comm.size()
    }

    /// The mode this context was built for.
    pub fn mode(&self) -> &Mode {
        &self.state.mode
    }

    /// The resident codec (built once at construction).
    pub fn codec(&self) -> &dyn Compressor {
        self.state.codec.as_ref()
    }

    /// Access the underlying communicator (e.g. for point-to-point calls
    /// between collectives).
    pub fn comm(&mut self) -> &mut Communicator<'a> {
        &mut *self.comm
    }

    /// Raw transport escape hatch.
    pub fn transport(&mut self) -> &mut dyn Transport {
        self.comm.transport()
    }

    /// Synchronise all ranks.
    pub fn barrier(&mut self) -> Result<()> {
        self.comm.barrier()
    }

    /// Arm every blocking collective and nonblocking `wait`/`test` on
    /// this context with a deadline (`None` disarms). Forwards to
    /// [`Transport::set_timeout`]; on expiry calls return
    /// [`crate::Error::Timeout`] naming the `(source rank, tag)` receives
    /// that were still pending.
    pub fn set_timeout(&mut self, timeout: Option<Duration>) {
        self.comm.transport().set_timeout(timeout);
    }

    /// The currently armed deadline, if any.
    pub fn timeout(&mut self) -> Option<Duration> {
        self.comm.transport().timeout()
    }

    /// Classify a finished call's result and keep the failure counters
    /// honest: fold the transport's wire-counter deltas into [`Metrics`],
    /// count timeouts, and — for any communication failure — raise the
    /// abort fence so peers blocked on this rank fail fast instead of
    /// riding out their own timeouts. Local argument errors
    /// ([`crate::Error::Invalid`]) are raised before any traffic and do
    /// not poison the fabric.
    fn observe<T>(&mut self, r: Result<T>) -> Result<T> {
        let now = self.comm.transport().wire_stats();
        self.metrics.corrupt_frames += now.corrupt_frames - self.last_wire.corrupt_frames;
        self.metrics.dup_frames_dropped +=
            now.dup_frames_dropped - self.last_wire.dup_frames_dropped;
        self.metrics.aborts_observed += now.aborts_seen - self.last_wire.aborts_seen;
        self.last_wire = now;
        if let Err(e) = &r {
            match e {
                Error::Timeout { .. } => {
                    self.metrics.timeouts += 1;
                    let me = self.comm.rank();
                    self.comm.transport().send_abort(&format!("rank {me}: {e}"));
                }
                Error::Invalid(_) => {}
                _ => {
                    let me = self.comm.rank();
                    self.comm.transport().send_abort(&format!("rank {me}: {e}"));
                }
            }
        }
        r
    }

    /// Accumulated per-phase timings across every call on this context.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Mutable metrics access (e.g. to attribute app-side compute time).
    pub fn metrics_mut(&mut self) -> &mut Metrics {
        &mut self.metrics
    }

    /// Take the accumulated metrics, resetting the sink.
    pub fn take_metrics(&mut self) -> Metrics {
        std::mem::take(&mut self.metrics)
    }

    /// Scratch-pool counters (see [`PoolStats`]).
    pub fn pool_stats(&self) -> PoolStats {
        self.state.pool_stats()
    }

    /// The transport packet pool's counters — the other half of the
    /// receive path's zero-alloc story (wire buffers are leased from the
    /// transport, scratch from [`ScratchPool`]).
    pub fn packet_stats(&self) -> crate::transport::PacketPoolStats {
        self.comm.packet_stats()
    }

    /// Codec constructions performed by this context (see
    /// [`CollState::codec_builds`]).
    pub fn codec_builds(&self) -> u64 {
        self.state.codec_builds()
    }

    /// Codec compression invocations performed by this context (see
    /// [`CollState::compress_calls`]): the hierarchical tests assert
    /// leaders compress and followers never do.
    pub fn compress_calls(&self) -> u64 {
        self.state.compress_calls()
    }

    /// Elementwise-reduce `input` across all ranks; every rank returns the
    /// full reduced vector.
    pub fn allreduce(&mut self, input: &[f32], op: ReduceOp) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(input.len());
        self.allreduce_into(input, op, &mut out)?;
        Ok(out)
    }

    /// [`CollCtx::allreduce`] into a caller-owned destination (cleared,
    /// then filled — capacity is reused across iterations).
    pub fn allreduce_into(
        &mut self,
        input: &[f32],
        op: ReduceOp,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let r = allreduce::allreduce_with(
            self.comm,
            &mut self.state,
            input,
            op,
            &mut self.metrics,
            out,
        );
        self.observe(r)
    }

    /// Reduce + scatter: rank `r` returns `(range, values)` for the chunk
    /// of the reduced vector it owns.
    pub fn reduce_scatter(
        &mut self,
        input: &[f32],
        op: ReduceOp,
    ) -> Result<(Range<usize>, Vec<f32>)> {
        let mut owned = Vec::new();
        let r = reduce_scatter::reduce_scatter_with(
            self.comm,
            &mut self.state,
            input,
            op,
            &mut self.metrics,
            &mut owned,
        );
        let range = self.observe(r)?;
        Ok((range, owned))
    }

    /// Gather every rank's `my_chunk` onto every rank, concatenated in
    /// rank order.
    pub fn allgather(&mut self, my_chunk: &[f32]) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.allgather_into(my_chunk, &mut out)?;
        Ok(out)
    }

    /// [`CollCtx::allgather`] into a caller-owned destination.
    pub fn allgather_into(&mut self, my_chunk: &[f32], out: &mut Vec<f32>) -> Result<()> {
        let r = allgather::allgather_chunks_with(
            self.comm,
            &mut self.state,
            my_chunk,
            0,
            &mut self.metrics,
            out,
        );
        self.observe(r)
    }

    /// Pairwise exchange: chunk `j` of `input` goes to rank `j`.
    pub fn alltoall(&mut self, input: &[f32]) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        let r =
            alltoall::alltoall_with(self.comm, &mut self.state, input, &mut self.metrics, &mut out);
        self.observe(r)?;
        Ok(out)
    }

    /// Broadcast `data` (significant at `root`) to every rank.
    pub fn bcast(&mut self, data: Option<&[f32]>, root: usize) -> Result<Vec<f32>> {
        let r = bcast::bcast_with(self.comm, &mut self.state, data, root, &mut self.metrics);
        self.observe(r)
    }

    /// Scatter `data` (significant at `root`): rank `r` receives chunk `r`.
    pub fn scatter(&mut self, data: Option<&[f32]>, root: usize) -> Result<Vec<f32>> {
        let r = scatter::scatter_with(self.comm, &mut self.state, data, root, &mut self.metrics);
        self.observe(r)
    }

    /// Gather each rank's `my_chunk` to `root` (others return `None`).
    pub fn gather(&mut self, my_chunk: &[f32], root: usize) -> Result<Option<Vec<f32>>> {
        let r = gather::gather_with(self.comm, &mut self.state, my_chunk, root, &mut self.metrics);
        self.observe(r)
    }

    /// Reduce `input` elementwise onto `root`.
    pub fn reduce(
        &mut self,
        input: &[f32],
        op: ReduceOp,
        root: usize,
    ) -> Result<Option<Vec<f32>>> {
        let r = reduce::reduce_with(self.comm, &mut self.state, input, op, root, &mut self.metrics);
        self.observe(r)
    }

    // -- nonblocking (`icollective`) API ---------------------------------
    //
    // Each `i*` start reserves the operation's whole tag slice, posts its
    // first receives, and parks a resumable machine in the progress
    // engine; results are bit-identical to the blocking calls (see
    // [`super::nonblocking`]). SPMD contract: all ranks start the same
    // requests in the same order; `test`/`wait` order is free.

    fn park(&mut self, m: Machine) -> CollRequest {
        let (slot, gen) = self.engine.insert(m);
        CollRequest { slot, gen }
    }

    fn park_done(&mut self, r: Result<CollOutput>) -> CollRequest {
        let (slot, gen) = self.engine.insert_done(r);
        CollRequest { slot, gen }
    }

    /// Start a nonblocking [`CollCtx::allreduce`]. The result's `values`
    /// is the full reduced vector.
    pub fn iallreduce(&mut self, input: &[f32], op: ReduceOp) -> Result<CollRequest> {
        let n = self.comm.size();
        if n == 1 {
            let mut out = self.state.pool.take_f32();
            out.extend_from_slice(input);
            op.finish(&mut out, 1);
            return Ok(self.park_done(Ok(CollOutput { values: out, range: None })));
        }
        if self.state.mode.algo == Algo::Hier {
            // The two-level schedule is leader-synchronous; run it eagerly
            // through the blocking path and park the finished result.
            let mut out = self.state.pool.take_f32();
            let r = allreduce::allreduce_with(
                self.comm,
                &mut self.state,
                input,
                op,
                &mut self.metrics,
                &mut out,
            )
            .map(|()| CollOutput { values: out, range: None });
            return Ok(self.park_done(r));
        }
        // Reserve BOTH stages' tag slices up front so the reduce-scatter →
        // allgather hand-off needs no mid-flight reservation (which would
        // race other requests' starts for ordering).
        let rs_plan = RingPlan::at(self.comm.try_fresh_tags(RingPlan::span(n))?, n);
        let ag_plan = AllgatherPlan::at(self.comm.try_fresh_tags(AllgatherPlan::span(n))?, n);
        let rs = ReduceScatterSm::new(
            self.comm,
            &mut self.state,
            &mut self.metrics,
            input,
            op,
            rs_plan,
        );
        Ok(self.park(Machine::Allreduce(Box::new(AllreduceSm::new(op, ag_plan, rs)))))
    }

    /// Start a nonblocking [`CollCtx::reduce_scatter`]. The result's
    /// `range` is the chunk of the reduced vector this rank owns.
    pub fn ireduce_scatter(&mut self, input: &[f32], op: ReduceOp) -> Result<CollRequest> {
        let n = self.comm.size();
        if n == 1 {
            let mut owned = self.state.pool.take_f32();
            owned.extend_from_slice(input);
            let len = input.len();
            return Ok(self.park_done(Ok(CollOutput { values: owned, range: Some(0..len) })));
        }
        if self.state.mode.algo == Algo::Hier {
            // Leader-synchronous two-level schedule: run it eagerly
            // through the blocking path and park the finished result
            // (same contract as the other Hier `i*` starts).
            let mut owned = self.state.pool.take_f32();
            let r = reduce_scatter::reduce_scatter_with(
                self.comm,
                &mut self.state,
                input,
                op,
                &mut self.metrics,
                &mut owned,
            )
            .map(|range| CollOutput { values: owned, range: Some(range) });
            return Ok(self.park_done(r));
        }
        let plan = RingPlan::at(self.comm.try_fresh_tags(RingPlan::span(n))?, n);
        let rs = ReduceScatterSm::new(
            self.comm,
            &mut self.state,
            &mut self.metrics,
            input,
            op,
            plan,
        );
        Ok(self.park(Machine::ReduceScatter(Box::new(rs))))
    }

    /// Start a nonblocking [`CollCtx::allgather`].
    pub fn iallgather(&mut self, my_chunk: &[f32]) -> Result<CollRequest> {
        let n = self.comm.size();
        if n == 1 {
            let mut out = self.state.pool.take_f32();
            out.extend_from_slice(my_chunk);
            return Ok(self.park_done(Ok(CollOutput { values: out, range: None })));
        }
        if self.state.mode.algo == Algo::Hier {
            let mut out = self.state.pool.take_f32();
            let r = allgather::allgather_chunks_with(
                self.comm,
                &mut self.state,
                my_chunk,
                0,
                &mut self.metrics,
                &mut out,
            )
            .map(|()| CollOutput { values: out, range: None });
            return Ok(self.park_done(r));
        }
        let plan = AllgatherPlan::at(self.comm.try_fresh_tags(AllgatherPlan::span(n))?, n);
        let mut mine = self.state.pool.take_f32();
        mine.extend_from_slice(my_chunk);
        let ag = AllgatherSm::new(self.comm, &mut self.state, mine, 0, plan);
        Ok(self.park(Machine::Allgather(Box::new(ag))))
    }

    /// Start a nonblocking [`CollCtx::bcast`] (`data` significant at
    /// `root`).
    pub fn ibcast(&mut self, data: Option<&[f32]>, root: usize) -> Result<CollRequest> {
        let n = self.comm.size();
        let me = self.comm.rank();
        if root >= n {
            return Err(crate::Error::invalid(format!("root {root} out of {n}")));
        }
        if me == root && data.is_none() {
            return Err(crate::Error::invalid("root must supply data"));
        }
        if n == 1 {
            let mut out = self.state.pool.take_f32();
            out.extend_from_slice(data.expect("validated: the root supplied data"));
            return Ok(self.park_done(Ok(CollOutput { values: out, range: None })));
        }
        if self.state.mode.algo == Algo::Hier {
            let r = bcast::bcast_with(self.comm, &mut self.state, data, root, &mut self.metrics)
                .map(|values| CollOutput { values, range: None });
            return Ok(self.park_done(r));
        }
        let plan = TreePlan::at(self.comm.try_fresh_tags(TreePlan::span(n))?, n);
        let payload = (me == root).then(|| {
            let mut d = self.state.pool.take_f32();
            d.extend_from_slice(data.expect("validated: the root supplied data"));
            d
        });
        let sm = BcastSm::new(self.comm, plan, root, payload);
        Ok(self.park(Machine::Bcast(Box::new(sm))))
    }

    /// Poll: drive **every** in-flight request forward, then report
    /// whether `req` has finished. Time spent here is communication
    /// *hidden* behind the caller's compute
    /// ([`Metrics::note_hidden_comm`]). Never surfaces schedule errors —
    /// a failed request reports done and parks its error for
    /// [`CollCtx::wait`].
    pub fn test(&mut self, req: &CollRequest) -> Result<bool> {
        let t0 = Instant::now();
        self.engine.step_all(self.comm, &mut self.state, &mut self.metrics)?;
        self.metrics.note_hidden_comm(t0.elapsed().as_secs_f64());
        Ok(self.engine.is_done(req.slot, req.gen))
    }

    /// Complete a request, copying its values into a caller-owned
    /// destination (cleared, then filled — capacity is reused across
    /// iterations, keeping warm requests allocation-free). Returns the
    /// owned range for reduce-scatter requests, `None` otherwise. Time
    /// blocked here is *exposed* communication
    /// ([`Metrics::note_exposed_comm`]).
    pub fn wait_into(
        &mut self,
        req: CollRequest,
        out: &mut Vec<f32>,
    ) -> Result<Option<Range<usize>>> {
        let t0 = Instant::now();
        let mut backoff = Backoff::until(self.comm.transport().timeout());
        loop {
            self.engine.step_all(self.comm, &mut self.state, &mut self.metrics)?;
            if let Some(res) = self.engine.take(req.slot, req.gen) {
                self.metrics.note_exposed_comm(t0.elapsed().as_secs_f64());
                let o = self.observe(res)?;
                out.clear();
                out.extend_from_slice(&o.values);
                let range = o.range;
                self.state.pool.put_f32(o.values);
                return Ok(range);
            }
            backoff.snooze();
            if backoff.is_yielding() {
                if let Some(e) = self.wait_failure(&req, &backoff) {
                    self.metrics.note_exposed_comm(t0.elapsed().as_secs_f64());
                    return self.observe(Err(e));
                }
            }
        }
    }

    /// Complete a request, taking ownership of its [`CollOutput`] (the
    /// values vector leaves the scratch pool). Prefer
    /// [`CollCtx::wait_into`] in iterated loops.
    pub fn wait(&mut self, req: CollRequest) -> Result<CollOutput> {
        let t0 = Instant::now();
        let mut backoff = Backoff::until(self.comm.transport().timeout());
        loop {
            self.engine.step_all(self.comm, &mut self.state, &mut self.metrics)?;
            if let Some(res) = self.engine.take(req.slot, req.gen) {
                self.metrics.note_exposed_comm(t0.elapsed().as_secs_f64());
                return self.observe(res);
            }
            backoff.snooze();
            if backoff.is_yielding() {
                if let Some(e) = self.wait_failure(&req, &backoff) {
                    self.metrics.note_exposed_comm(t0.elapsed().as_secs_f64());
                    return self.observe(Err(e));
                }
            }
        }
    }

    /// Yield-phase failure poll shared by the nonblocking waits: the
    /// abort fence first (a failed peer beats a timeout to the punch),
    /// then the deadline — reporting exactly which `(source rank, tag)`
    /// receives the request was still parked on.
    fn wait_failure(&mut self, req: &CollRequest, backoff: &Backoff) -> Option<Error> {
        if let Err(e) = self.comm.transport().check_abort() {
            return Some(e);
        }
        if backoff.expired() {
            return Some(Error::timeout(self.engine.pending_recvs(req.slot, req.gen)));
        }
        None
    }

    /// Number of nonblocking requests currently in flight (running or
    /// finished-but-uncollected).
    pub fn pending_requests(&self) -> usize {
        self.engine.in_flight()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::run_ranks;
    use crate::compress::ErrorBound;
    use crate::data::fields::{Field, FieldKind};

    #[test]
    fn pool_checkout_checkin_reuses_capacity() {
        let mut p = ScratchPool::default();
        let mut b = p.take_bytes();
        b.extend_from_slice(&[1, 2, 3, 4]);
        let cap = b.capacity();
        p.put_bytes(b);
        let b2 = p.take_bytes();
        assert!(b2.is_empty());
        assert_eq!(b2.capacity(), cap);
        let s = p.stats();
        assert_eq!(s.byte_buffers_created, 1);
        assert_eq!(s.reuses, 1);
        assert_eq!(s.byte_capacity_hwm, cap);
    }

    #[test]
    fn pool_free_list_is_bounded() {
        let mut p = ScratchPool::default();
        let many: Vec<Vec<f32>> = (0..ScratchPool::MAX_FREE + 5).map(|_| p.take_f32()).collect();
        for b in many {
            p.put_f32(b);
        }
        assert!(p.f32s.len() <= ScratchPool::MAX_FREE);
    }

    #[test]
    fn state_builds_codec_once() {
        let st = CollState::new(Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(1e-3)));
        assert_eq!(st.codec_builds(), 1);
        assert!(st.pipe.is_some(), "zccl + fzlight must pre-build the PIPE codec");
        let st2 = CollState::new(Mode::ccoll(ErrorBound::Abs(1e-3)));
        assert!(st2.pipe.is_none(), "ccoll has no PIPE overlap");
    }

    #[test]
    fn decode_fold_pools_default_impl_codecs_and_matches_unfused() {
        // CColl runs SZx, which has no native fused kernel: the fold must
        // go through pooled scratch (one f32 buffer ever created) and
        // still equal decompress-then-fold exactly.
        let mut st = CollState::new(Mode::ccoll(crate::compress::ErrorBound::Abs(1e-3)));
        assert!(!st.codec.supports_fused_fold());
        let data = Field::generate(FieldKind::Cesm, 4096, 11).values;
        let mut frame = Vec::new();
        st.compress_into(&data, &mut frame).unwrap();
        let mut acc = vec![1.0f32; data.len()];
        st.decode_fold_into(&frame, ReduceOp::Sum, &mut acc).unwrap();
        let first = st.pool_stats();
        let mut acc2 = vec![1.0f32; data.len()];
        st.decode_fold_into(&frame, ReduceOp::Sum, &mut acc2).unwrap();
        let second = st.pool_stats();
        assert_eq!(second.f32_buffers_created, first.f32_buffers_created);
        assert!(second.reuses > first.reuses, "warm fold must reuse pooled scratch");
        let mut partial = Vec::new();
        st.decode_into(&frame, &mut partial).unwrap();
        let mut want = vec![1.0f32; data.len()];
        ReduceOp::Sum.fold(&mut want, &partial);
        assert_eq!(acc, want);
        assert_eq!(acc2, want);
        // The ZCCL/fZ-light state runs the native kernel instead.
        let stz = CollState::new(Mode::zccl(
            CompressorKind::FzLight,
            crate::compress::ErrorBound::Abs(1e-3),
        ));
        assert!(stz.codec.supports_fused_fold());
    }

    #[test]
    fn ctx_collectives_match_free_functions() {
        let n = 4;
        let len = 2500;
        let mode = Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(1e-3));
        let via_ctx = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, mode);
            let f = Field::generate(FieldKind::Cesm, len, 40 + ctx.rank() as u64);
            ctx.allreduce(&f.values, ReduceOp::Sum).unwrap()
        });
        let via_free = run_ranks(n, move |c| {
            let f = Field::generate(FieldKind::Cesm, len, 40 + c.rank() as u64);
            let mut m = Metrics::default();
            super::super::allreduce(c, &f.values, ReduceOp::Sum, &mode, &mut m).unwrap()
        });
        assert_eq!(via_ctx, via_free, "ctx path and shim must agree bit-for-bit");
    }

    #[test]
    fn ctx_accumulates_metrics_and_interleaves_with_free_functions() {
        let n = 3;
        let mode = Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(1e-3));
        let ok = run_ranks(n, move |c| {
            let f = Field::generate(FieldKind::Rtm, 4096, 7 + c.rank() as u64);
            // Free function first, then a context on the same communicator:
            // the shared tag sequence must keep the ranks matched up.
            let mut m = Metrics::default();
            let a = super::super::allreduce(c, &f.values, ReduceOp::Sum, &mode, &mut m).unwrap();
            let mut ctx = CollCtx::over(c, mode);
            let b = ctx.allreduce(&f.values, ReduceOp::Sum).unwrap();
            assert!(ctx.metrics().compress_s > 0.0, "ctx must record phase time");
            assert!(ctx.take_metrics().total_s() > 0.0);
            assert_eq!(ctx.metrics().total_s(), 0.0, "take_metrics resets");
            a == b
        });
        assert!(ok.into_iter().all(|x| x));
    }
}
