//! Hierarchical topology-aware collectives ([`Algo::Hier`]).
//!
//! Real clusters are two-tier: cheap intra-node links, expensive
//! inter-node links. Flat compressed schedules ignore that and make every
//! rank compress, so a 4-rank node compresses the same wire payloads four
//! times and ships them over the slow tier from four NICs. The
//! hierarchical schedules (gZCCL, arXiv:2308.05199; C-Coll,
//! arXiv:2304.03890 stresses keeping codec cost off the inter-node
//! critical path) split every collective across the tiers of a
//! [`Topology`]:
//!
//! - **intra-node tier** — raw `f32` windows over the fast links; only
//!   computation (reduction folds), never compression;
//! - **inter-node tier** — the unchanged flat ZCCL schedules run over the
//!   node **leaders** only (via [`GroupTransport`]), carrying compressed
//!   frames that are forwarded verbatim: compress-once extended across
//!   tiers. Each node's data is compressed exactly once, by its leader,
//!   and every frame that crosses the slow tier travels leader↔leader.
//!
//! Per collective — every non-barrier collective runs a two-level
//! schedule (no flat fallbacks remain):
//!
//! | collective       | intra up                        | inter (leaders)                                                      | intra down              |
//! |------------------|---------------------------------|----------------------------------------------------------------------|-------------------------|
//! | `allreduce`      | raw partials → leader fold      | flat ZCCL reduce-scatter + allgather (group view)                    | raw result, binomial    |
//! | `allgather`      | raw chunks → leader             | per-rank frame bundles over the **segmented** ring (§3.5.1)          | raw result, binomial    |
//! | `bcast`          | root's frame → its leader       | frame over the **segmented** binomial tree                           | raw payload, binomial   |
//! | `scatter`        | root's frame bundle → its leader| subtree bundles over the **segmented** tree ([`binomial_subtree_into`]) | raw chunk per member |
//! | `gather`         | raw chunks → leader             | merged per-member frame-record bundles up the **segmented** tree     | bundle hop to a follower root |
//! | `reduce_scatter` | raw partials → leader fold      | flat ZCCL reduce-scatter (group view) + raw chunk redistribution     | raw owned chunk per member |
//! | `alltoall`       | raw full inputs → leader        | pairwise compressed per-chunk frame bundle lanes                     | raw assembled output per member |
//! | `reduce`         | raw partials → leader fold      | flat ZCCL reduce toward the root's leader (group view)               | raw result hop to a follower root |
//!
//! The inter-leader bundle paths (allgather ring; bcast / scatter /
//! gather trees) ship through [`super::send_segmented`] /
//! [`super::recv_segmented_into`] with the §3.5.1 fixed pipeline segment
//! ([`super::Mode::pipeline_bytes`];
//! [`crate::sim::calibrate::pick_segment_bytes`] picks a per-tier value
//! from the cost model), so consecutive leader segments overlap
//! send/recv the way flat ZCCL rings already do.
//!
//! Because the leader tier reuses the flat code verbatim and per-rank
//! frame boundaries are preserved, `allgather`, `bcast`, `scatter`,
//! `gather` and `alltoall` return **bit-identical** results to flat
//! [`Algo::Zccl`] on the same communicator, while `allreduce`,
//! `reduce_scatter` and `reduce` are bit-identical to flat `Zccl` run
//! over the leader group on the node-reduced inputs (and therefore to
//! flat `Zccl` outright whenever every node holds one rank).
//!
//! The intra tier defaults to raw `f32` (exact). Installing a
//! compressing intra mode ([`super::CollCtx::set_intra_mode`]) turns
//! each fast-tier hop into a single bounded-error compression — once per
//! hop, forwarded verbatim, never recompressed by the leader — for
//! transports whose shared-memory tier is slow enough that the codec
//! pays for itself ([`crate::sim::calibrate::pick_intra_mode`]).
//!
//! Without an installed topology ([`super::CollCtx::set_topology`]),
//! [`Topology::flat`] is assumed and everything degenerates to flat ZCCL.

use std::ops::Range;
use std::sync::Arc;

use super::allgather::allgather_chunks_with;
use super::ctx::CollState;
use super::gather::{encode_records_into, parse_records};
use super::reduce::reduce_impl;
use super::reduce_scatter::reduce_scatter_with;
use super::scatter::{encode_bundle_into, parse_bundle};
use super::{
    bytes_to_f32s_into_slice, chunk_ranges, f32s_to_bytes_into, recv_segmented_into,
    send_segmented, Algo, Communicator, ReduceOp,
};
use crate::analysis::plan::{
    HierAllgatherPlan, HierAllreducePlan, HierAlltoallPlan, HierBcastPlan, HierGatherPlan,
    HierReducePlan, HierReduceScatterPlan, HierScatterPlan, HIER_GROUP_SPAN,
};
use crate::compress::bits::le;
use crate::compress::fzlight::frame_u32;
use crate::coordinator::{Metrics, Phase};
use crate::topology::{
    binomial_bcast_in_group, binomial_subtree_into, ring_in_group, ring_recv_chunk,
    ring_send_chunk, Topology,
};
use crate::transport::GroupTransport;
use crate::{Error, Result};

/// The topology the hierarchical schedules run over: the installed one
/// (an `Arc` clone — the node tables are shared, not copied, so warm
/// iterated calls stay allocation-light), validated against the
/// communicator, or the flat (rank-per-node) degenerate default. The
/// intra tier may be raw (default, exact) or a compressing mode
/// installed via `set_intra_mode` — `set_intra_mode` already rejected
/// the only invalid nesting ([`Algo::Hier`] inside the intra tier).
fn resolve_topo(st: &mut CollState, n: usize) -> Result<Arc<Topology>> {
    if st.topo.is_none() {
        // Cache the degenerate rank-per-node default so iterated calls
        // without an installed topology stay allocation-light too.
        st.topo = Some(Arc::new(Topology::flat(n)));
    }
    let topo = {
        let t = st.topo.as_ref().expect("installed above");
        if t.ranks() != n {
            return Err(Error::invalid(format!(
                "topology covers {} ranks but the communicator has {n}",
                t.ranks()
            )));
        }
        Arc::clone(t)
    };
    // Tag-budget guard: the leader tier's inner flat collectives reserve
    // up to `(L + 2) * SEG_TAG_SPAN + L` tags out of the
    // [`HIER_GROUP_SPAN`] window; more leaders than fit would silently
    // spill into the parent's subsequent tag windows and cross-match
    // unrelated messages — the same silent-collision class
    // `segment_count` guards against on the segmented path.
    let worst = (topo.nodes() as u64 + 3) * super::SEG_TAG_SPAN;
    if worst > HIER_GROUP_SPAN {
        return Err(Error::invalid(format!(
            "hierarchical schedules support at most {} nodes (leader-tier tag budget)",
            HIER_GROUP_SPAN / super::SEG_TAG_SPAN - 3
        )));
    }
    Ok(topo)
}

/// Intra-node broadcast of the leader's `out` to every member over the
/// fast tier (binomial over the member group, rooted at the leader). On
/// entry the leader's `out` holds the values; on exit every member's
/// `out` holds them. With the default raw intra mode the wire is a plain
/// `f32` serialisation (bit-identical); a compressing intra mode encodes
/// **once** at the leader and the frame is forwarded verbatim down the
/// member binomial — one bounded-error hop, never recompressed.
fn intra_bcast_result(
    comm: &mut Communicator,
    st: &mut CollState,
    members: &[usize],
    local_idx: usize,
    tag_base: u64,
    m: &mut Metrics,
    out: &mut Vec<f32>,
) -> Result<()> {
    if members.len() == 1 {
        return Ok(());
    }
    let (recv_step, send_steps) = binomial_bcast_in_group(members, local_idx, 0);
    let (buf, pooled) = if local_idx == 0 {
        let mut b = st.pool.take_bytes();
        st.intra_encode(out, &mut b)?;
        (b, true)
    } else {
        let step = recv_step.expect("non-leader member receives");
        let mut got = comm.t.lease();
        let t0 = std::time::Instant::now();
        comm.t.recv_into(step.peer, tag_base + step.round as u64, &mut got)?;
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        m.bytes_recv += got.len() as u64;
        (got, false)
    };
    for s in send_steps {
        let t0 = std::time::Instant::now();
        comm.t.send(s.peer, tag_base + s.round as u64, &buf)?;
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        m.bytes_sent += buf.len() as u64;
    }
    if local_idx != 0 {
        st.intra_decode_into(&buf, out)?;
    }
    if pooled {
        st.pool.put_bytes(buf);
    } else {
        comm.t.recycle(buf);
    }
    Ok(())
}

/// Receive one intra-tier (fast-tier) payload and decode it into `out`
/// per the installed intra mode (raw `f32` by default).
fn intra_recv_into(
    comm: &mut Communicator,
    st: &mut CollState,
    from: usize,
    tag: u64,
    m: &mut Metrics,
    out: &mut Vec<f32>,
) -> Result<()> {
    let mut got = comm.t.lease();
    let t0 = std::time::Instant::now();
    comm.t.recv_into(from, tag, &mut got)?;
    m.add(Phase::Comm, t0.elapsed().as_secs_f64());
    m.bytes_recv += got.len() as u64;
    st.intra_decode_into(&got, out)?;
    comm.t.recycle(got);
    Ok(())
}

/// Encode `vals` per the installed intra mode into a transport-leased
/// buffer and ship it to `to` over the fast tier (pooled one-shot send).
fn intra_send(
    comm: &mut Communicator,
    st: &mut CollState,
    to: usize,
    tag: u64,
    vals: &[f32],
    m: &mut Metrics,
) -> Result<()> {
    let mut wire = comm.t.lease();
    st.intra_encode(vals, &mut wire)?;
    m.bytes_sent += wire.len() as u64;
    let t0 = std::time::Instant::now();
    comm.t.send_pooled(to, tag, wire)?;
    m.add(Phase::Comm, t0.elapsed().as_secs_f64());
    Ok(())
}

/// Send one `u64` size pre-message (little-endian) — the segmented
/// receiver on a bundle path needs the total byte count up front.
fn send_size(comm: &mut Communicator, to: usize, tag: u64, size: u64, m: &mut Metrics) -> Result<()> {
    let t0 = std::time::Instant::now();
    comm.t.send(to, tag, &size.to_le_bytes())?;
    m.add(Phase::Comm, t0.elapsed().as_secs_f64());
    m.bytes_sent += 8;
    Ok(())
}

/// Receive one `u64` size pre-message sent by [`send_size`].
fn recv_size(comm: &mut Communicator, from: usize, tag: u64, m: &mut Metrics) -> Result<u64> {
    let mut got = comm.t.lease();
    let t0 = std::time::Instant::now();
    comm.t.recv_into(from, tag, &mut got)?;
    m.add(Phase::Comm, t0.elapsed().as_secs_f64());
    m.bytes_recv += got.len() as u64;
    let bytes: [u8; 8] = got
        .as_slice()
        .try_into()
        .map_err(|_| Error::corrupt(format!("size pre-message holds {} bytes, want 8", got.len())))?;
    comm.t.recycle(got);
    Ok(u64::from_le_bytes(bytes))
}

/// The inter tier of the hierarchical allreduce: the unchanged flat ZCCL
/// reduce-scatter + allgather over the leader group. The caller has
/// already switched `st.mode.algo` to [`Algo::Zccl`].
#[allow(clippy::too_many_arguments)]
fn leader_tier_allreduce(
    comm: &mut Communicator,
    st: &mut CollState,
    topo: &Topology,
    group_base: u64,
    acc: &[f32],
    op: ReduceOp,
    total_ranks: usize,
    m: &mut Metrics,
    out: &mut Vec<f32>,
) -> Result<()> {
    let mut owned = st.pool.take_f32();
    let mut gt = GroupTransport::new(&mut *comm.t, topo.leaders(), group_base)?;
    let mut gc = Communicator::new(&mut gt);
    reduce_scatter_with(&mut gc, st, acc, op, m, &mut owned)?;
    // Finish with the TOTAL rank count: the node partials already hold
    // every member's contribution (matters for Avg).
    op.finish(&mut owned, total_ranks);
    allgather_chunks_with(&mut gc, st, &owned, 1, m, out)?;
    st.pool.put_f32(owned);
    Ok(())
}

/// Hierarchical allreduce: intra-node raw reduce onto the leader →
/// inter-leader compressed ring reduce-scatter/allgather → intra-node raw
/// bcast. Only leaders touch the codec; each compressed frame crosses the
/// slow tier leader↔leader and is forwarded without recompression.
pub(crate) fn allreduce_hier(
    comm: &mut Communicator,
    st: &mut CollState,
    input: &[f32],
    op: ReduceOp,
    m: &mut Metrics,
    out: &mut Vec<f32>,
) -> Result<()> {
    let n = comm.size();
    let me = comm.rank();
    let topo = resolve_topo(st, n)?;
    if n == 1 {
        out.clear();
        out.extend_from_slice(input);
        op.finish(out, 1);
        return Ok(());
    }
    // Tag plan — one contiguous reservation, identical on every rank.
    let plan = HierAllreducePlan::at(comm.fresh_tags(HierAllreducePlan::span(n)), n);
    let up_tag = plan.up_tag();
    let group_base = plan.group_base();
    let down_base = plan.down().base;

    let node = topo.node_of(me);
    let members = topo.members(node);
    let local_idx = topo.local_index(me);
    m.raw_bytes += (input.len() * 4) as u64;

    if local_idx == 0 {
        // (1) Intra tier: fold member partials in ascending member order
        //     — deterministic, exact, raw over the fast tier.
        let mut acc = st.pool.take_f32();
        acc.extend_from_slice(input);
        let mut wire = comm.t.lease();
        for &mr in &members[1..] {
            let t0 = std::time::Instant::now();
            comm.t.recv_into(mr, up_tag, &mut wire)?;
            m.add(Phase::Comm, t0.elapsed().as_secs_f64());
            m.bytes_recv += wire.len() as u64;
            let t0 = std::time::Instant::now();
            st.intra_fold(op, &wire, &mut acc)?;
            m.add(Phase::Compute, t0.elapsed().as_secs_f64());
        }
        comm.t.recycle(wire);

        // (2) Inter tier (leaders only).
        if topo.nodes() == 1 {
            out.clear();
            out.extend_from_slice(&acc);
            op.finish(out, n);
        } else {
            let saved = st.mode.algo;
            st.mode.algo = Algo::Zccl;
            let inter =
                leader_tier_allreduce(comm, st, &topo, group_base, &acc, op, n, m, out);
            st.mode.algo = saved;
            inter?;
        }
        st.pool.put_f32(acc);
    } else {
        // Follower: partial up (pooled one-shot send), result down; the
        // inter-tier codec never runs here (the intra codec may).
        intra_send(comm, st, topo.leader_of(me), up_tag, input, m)?;
    }

    // (3) Intra tier: the full result down the member binomial.
    intra_bcast_result(comm, st, members, local_idx, down_base, m, out)
}

/// Hierarchical allgather. Members ship raw chunks to their leader; the
/// leader compresses each member chunk **individually** (preserving the
/// flat per-rank frame boundaries, so results are bit-identical to flat
/// ZCCL) and the leaders ring node bundles of frames around the slow
/// tier, forwarding them verbatim; each leader then decodes every frame
/// exactly once and broadcasts the raw gathered vector down the fast
/// tier.
pub(crate) fn allgather_hier(
    comm: &mut Communicator,
    st: &mut CollState,
    my_chunk: &[f32],
    m: &mut Metrics,
    out: &mut Vec<f32>,
) -> Result<()> {
    let n = comm.size();
    let me = comm.rank();
    let topo = resolve_topo(st, n)?;
    if n == 1 {
        out.clear();
        out.extend_from_slice(my_chunk);
        return Ok(());
    }
    let plan = HierAllgatherPlan::at(comm.fresh_tags(HierAllgatherPlan::span(n)), n);
    let up_tag = plan.up_tag();
    let lring_plan = plan.leader_ring(); // sized for n ranks >= nodes - 1 rounds
    let down_base = plan.down().base;

    let node = topo.node_of(me);
    let members = topo.members(node);
    let local_idx = topo.local_index(me);
    m.raw_bytes += (my_chunk.len() * 4) as u64;

    if local_idx != 0 {
        // Follower: chunk up, gathered vector down (fast tier).
        intra_send(comm, st, topo.leader_of(me), up_tag, my_chunk, m)?;
        return intra_bcast_result(comm, st, members, local_idx, down_base, m, out);
    }

    let nnodes = topo.nodes();
    // (1) Collect member chunks (raw, fast tier) and compress each one
    //     individually — one compression per rank, all at the leader.
    let mut store = st.pool.take_bytes();
    let mut frames: Vec<Range<usize>> = Vec::with_capacity(members.len());
    {
        let mut wire = comm.t.lease();
        let mut vals = st.pool.take_f32();
        for (k, &mr) in members.iter().enumerate() {
            let start = store.len();
            if k == 0 {
                let t0 = std::time::Instant::now();
                st.compress_into(my_chunk, &mut store)?;
                m.add(Phase::Compress, t0.elapsed().as_secs_f64());
            } else {
                let t0 = std::time::Instant::now();
                comm.t.recv_into(mr, up_tag, &mut wire)?;
                m.add(Phase::Comm, t0.elapsed().as_secs_f64());
                m.bytes_recv += wire.len() as u64;
                st.intra_decode_into(&wire, &mut vals)?;
                let t0 = std::time::Instant::now();
                st.compress_into(&vals, &mut store)?;
                m.add(Phase::Compress, t0.elapsed().as_secs_f64());
            }
            frames.push(start..store.len());
        }
        st.pool.put_f32(vals);
        comm.t.recycle(wire);
    }

    // (2) Ring the node bundles around the leader tier (compressed frames
    //     forwarded verbatim, leader↔leader only). Each round leads with
    //     a u64 bundle-size pre-message (the segmented receiver needs the
    //     total up front) and ships the bundle as §3.5.1 fixed pipeline
    //     segments on the round's tag fan, so consecutive slow-tier
    //     segments overlap send/recv exactly like the flat ZCCL rings.
    let lring = ring_in_group(topo.leaders(), node);
    let sizes_ring = plan.sizes_ring();
    let seg = st.mode.pipeline_bytes;
    let mut bundles: Vec<Option<Vec<u8>>> = vec![None; nnodes];
    {
        let mut mine = st.pool.take_bytes();
        let parts: Vec<&[u8]> = frames.iter().map(|r| &store[r.clone()]).collect();
        encode_bundle_into(my_chunk.len(), &parts, &mut mine)?;
        bundles[node] = Some(mine);
    }
    st.pool.put_bytes(store);
    for t in 0..nnodes - 1 {
        let s = ring_send_chunk(node, t, nnodes);
        let r = ring_recv_chunk(node, t, nnodes);
        let send_buf = bundles[s].take().expect("ring schedule owns sent bundle");
        send_size(comm, lring.next, sizes_ring.round_tag(t), send_buf.len() as u64, m)?;
        let t0 = std::time::Instant::now();
        m.bytes_sent += send_segmented(comm.t, lring.next, lring_plan.round_tag(t), &send_buf, seg)?;
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        bundles[s] = Some(send_buf);
        let total = recv_size(comm, lring.prev, sizes_ring.round_tag(t), m)? as usize;
        let mut got = comm.t.lease();
        let t0 = std::time::Instant::now();
        recv_segmented_into(comm.t, lring.prev, lring_plan.round_tag(t), total, seg, &mut got)?;
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        m.bytes_recv += got.len() as u64;
        bundles[r] = Some(got);
    }

    // (3) Size the output from the (size-bounded) frame headers, then
    //     placement-decode every frame — each exactly once, all here.
    let mut parsed: Vec<(Vec<u8>, Vec<Range<usize>>)> = Vec::with_capacity(nnodes);
    let mut counts = vec![0usize; n];
    for (j, slot) in bundles.iter_mut().enumerate() {
        let buf = slot.take().expect("all bundles gathered");
        let (_, ranges) = parse_bundle(&buf, topo.members(j).len())?;
        for (k, &rank) in topo.members(j).iter().enumerate() {
            counts[rank] = crate::compress::checked_count(&buf[ranges[k].clone()])?;
        }
        parsed.push((buf, ranges));
    }
    let mut offsets = Vec::with_capacity(n + 1);
    offsets.push(0usize);
    for &c in &counts {
        offsets.push(offsets.last().unwrap() + c);
    }
    out.resize(offsets[n], 0.0);
    for (j, (buf, ranges)) in parsed.into_iter().enumerate() {
        for (k, &rank) in topo.members(j).iter().enumerate() {
            let t0 = std::time::Instant::now();
            st.decode_into_slice(
                &buf[ranges[k].clone()],
                &mut out[offsets[rank]..offsets[rank + 1]],
            )
            .map_err(|e| Error::corrupt(format!("hier allgather rank {rank}: {e}")))?;
            m.add(Phase::Decompress, t0.elapsed().as_secs_f64());
        }
        if j == node {
            st.pool.put_bytes(buf);
        } else {
            comm.t.recycle(buf);
        }
    }

    // (4) Intra tier: raw gathered vector down the member binomial.
    intra_bcast_result(comm, st, members, 0, down_base, m, out)
}

/// Hierarchical broadcast: the root compresses **once**; the frame hops
/// to the root's node leader (if distinct), travels the leader binomial
/// tree verbatim over the slow tier, is decoded once per node by the
/// leader, and fans out raw over the fast tier. Output is bit-identical
/// to flat ZCCL (`D(C(data))` everywhere).
pub(crate) fn bcast_hier(
    comm: &mut Communicator,
    st: &mut CollState,
    data: Option<&[f32]>,
    root: usize,
    m: &mut Metrics,
) -> Result<Vec<f32>> {
    let n = comm.size();
    let me = comm.rank();
    let topo = resolve_topo(st, n)?;
    let plan = HierBcastPlan::at(comm.fresh_tags(HierBcastPlan::span(n)), n);
    let hop_tag = plan.hop_tag();
    let ltree = plan.leader_tree();
    let down_base = plan.down().base;

    let node = topo.node_of(me);
    let members = topo.members(node);
    let local_idx = topo.local_index(me);
    let root_node = topo.node_of(root);
    let root_leader = topo.leader_of(root);

    // (1) The root compresses once. A follower root hops the frame to its
    //     leader over the fast tier and rejoins as a plain member.
    let mut own_frame: Option<Vec<u8>> = None;
    if me == root {
        let d = data.unwrap();
        m.raw_bytes += (d.len() * 4) as u64;
        if me == root_leader {
            let mut f = st.pool.take_bytes();
            let t0 = std::time::Instant::now();
            st.compress_into(d, &mut f)?;
            m.add(Phase::Compress, t0.elapsed().as_secs_f64());
            own_frame = Some(f);
        } else {
            let mut f = comm.t.lease();
            let t0 = std::time::Instant::now();
            st.compress_into(d, &mut f)?;
            m.add(Phase::Compress, t0.elapsed().as_secs_f64());
            m.bytes_sent += f.len() as u64;
            let t0 = std::time::Instant::now();
            comm.t.send_pooled(root_leader, hop_tag, f)?;
            m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        }
    }

    if local_idx == 0 {
        // Leader: obtain the frame, forward it verbatim down the leader
        // tree (slow tier, segmented §3.5.1 per edge), decode exactly
        // once, fan out over the fast tier.
        let seg = st.mode.pipeline_bytes;
        let (recv_step, send_steps) = binomial_bcast_in_group(topo.leaders(), node, root_node);
        let (frame, pooled) = match own_frame {
            Some(f) => (f, true),
            None => {
                let mut got = comm.t.lease();
                let t0 = std::time::Instant::now();
                if node == root_node {
                    comm.t.recv_into(root, hop_tag, &mut got)?;
                    m.add(Phase::Comm, t0.elapsed().as_secs_f64());
                } else {
                    let step = recv_step.expect("non-root-node leader receives");
                    let total =
                        recv_size(comm, step.peer, ltree.size_tag(step.round), m)? as usize;
                    let t0 = std::time::Instant::now();
                    recv_segmented_into(
                        comm.t,
                        step.peer,
                        ltree.step_tag(step.round),
                        total,
                        seg,
                        &mut got,
                    )?;
                    m.add(Phase::Comm, t0.elapsed().as_secs_f64());
                }
                m.bytes_recv += got.len() as u64;
                (got, false)
            }
        };
        for s in send_steps {
            send_size(comm, s.peer, ltree.size_tag(s.round), frame.len() as u64, m)?;
            let t0 = std::time::Instant::now();
            m.bytes_sent += send_segmented(comm.t, s.peer, ltree.step_tag(s.round), &frame, seg)?;
            m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        }
        let cnt = crate::compress::checked_count(&frame)?;
        let mut out = vec![0.0f32; cnt];
        let t0 = std::time::Instant::now();
        st.decode_into_slice(&frame, &mut out)?;
        m.add(Phase::Decompress, t0.elapsed().as_secs_f64());
        if pooled {
            st.pool.put_bytes(frame);
        } else {
            comm.t.recycle(frame);
        }
        intra_bcast_result(comm, st, members, 0, down_base, m, &mut out)?;
        Ok(out)
    } else {
        let mut out = Vec::new();
        intra_bcast_result(comm, st, members, local_idx, down_base, m, &mut out)?;
        Ok(out)
    }
}

/// The global ranks covered by the leader-tree subtree rooted at node
/// `at` (tree rooted at node `lroot`): subtree nodes in breadth-first
/// order via the iterative [`binomial_subtree_into`], then each node's
/// members. Sender and receiver compute the same enumeration, so bundle
/// positions need no rank table.
fn subtree_ranks(topo: &Topology, lroot: usize, at: usize, out: &mut Vec<usize>) {
    let mut nodes = Vec::new();
    binomial_subtree_into(at, lroot, topo.nodes(), &mut nodes);
    out.clear();
    for &j in &nodes {
        out.extend_from_slice(topo.members(j));
    }
}

/// Hierarchical scatter: the root compresses each rank's chunk **once**;
/// bundles of frames travel the leader binomial tree (each leader
/// forwarding its children's node-subtree bundles, slow tier,
/// leader↔leader); each leader decodes its members' frames — the node's
/// only decompressions — and hands every member its raw chunk over the
/// fast tier. Outputs are bit-identical to flat ZCCL.
pub(crate) fn scatter_hier(
    comm: &mut Communicator,
    st: &mut CollState,
    data: Option<&[f32]>,
    root: usize,
    m: &mut Metrics,
) -> Result<Vec<f32>> {
    let n = comm.size();
    let me = comm.rank();
    let topo = resolve_topo(st, n)?;
    let plan = HierScatterPlan::at(comm.fresh_tags(HierScatterPlan::span(n)), n);
    let hop_tag = plan.hop_tag();
    let ltree = plan.leader_tree();
    let down_tag = plan.down_tag();

    let node = topo.node_of(me);
    let members = topo.members(node);
    let local_idx = topo.local_index(me);
    let root_node = topo.node_of(root);
    let root_leader = topo.leader_of(root);

    // (1) The root compresses every rank's chunk once, packed in the
    //     root-leader subtree enumeration (= all ranks).
    let mut root_bundle: Option<(Vec<u8>, Vec<Range<usize>>, usize)> = None;
    if me == root {
        let d = data.unwrap();
        m.raw_bytes += (d.len() * 4) as u64;
        let ranges = chunk_ranges(d.len(), n);
        let mut order = Vec::new();
        subtree_ranks(&topo, root_node, root_node, &mut order);
        let mut store = st.pool.take_bytes();
        let mut frames = Vec::with_capacity(n);
        for &r in &order {
            let start = store.len();
            let t0 = std::time::Instant::now();
            st.compress_into(&d[ranges[r].clone()], &mut store)?;
            m.add(Phase::Compress, t0.elapsed().as_secs_f64());
            frames.push(start..store.len());
        }
        if me == root_leader {
            root_bundle = Some((store, frames, d.len()));
        } else {
            let mut wire = comm.t.lease();
            let parts: Vec<&[u8]> = frames.iter().map(|r| &store[r.clone()]).collect();
            encode_bundle_into(d.len(), &parts, &mut wire)?;
            m.bytes_sent += wire.len() as u64;
            let t0 = std::time::Instant::now();
            comm.t.send_pooled(root_leader, hop_tag, wire)?;
            m.add(Phase::Comm, t0.elapsed().as_secs_f64());
            st.pool.put_bytes(store);
        }
    }

    if local_idx == 0 {
        // Leader: obtain the bundle covering my node subtree, forward
        // each child leader its sub-bundle, deliver member chunks raw.
        let seg = st.mode.pipeline_bytes;
        let mut my_ranks = Vec::new();
        subtree_ranks(&topo, root_node, node, &mut my_ranks);
        let (recv_step, send_steps) = binomial_bcast_in_group(topo.leaders(), node, root_node);
        let (store, frames, total, pooled) = match root_bundle {
            Some((s, f, t)) => (s, f, t, true),
            None => {
                let mut got = comm.t.lease();
                if node == root_node {
                    let t0 = std::time::Instant::now();
                    comm.t.recv_into(root, hop_tag, &mut got)?;
                    m.add(Phase::Comm, t0.elapsed().as_secs_f64());
                } else {
                    let step = recv_step.expect("non-root-node leader receives");
                    let total =
                        recv_size(comm, step.peer, ltree.size_tag(step.round), m)? as usize;
                    let t0 = std::time::Instant::now();
                    recv_segmented_into(
                        comm.t,
                        step.peer,
                        ltree.step_tag(step.round),
                        total,
                        seg,
                        &mut got,
                    )?;
                    m.add(Phase::Comm, t0.elapsed().as_secs_f64());
                }
                m.bytes_recv += got.len() as u64;
                let (total, ranges) = parse_bundle(&got, my_ranks.len())?;
                (got, ranges, total, false)
            }
        };
        let mut child_ranks = Vec::new();
        let mut wire = st.pool.take_bytes();
        for s in send_steps {
            let child_node = topo.node_of(s.peer);
            subtree_ranks(&topo, root_node, child_node, &mut child_ranks);
            let parts: Vec<&[u8]> = child_ranks
                .iter()
                .map(|r| {
                    let idx =
                        my_ranks.iter().position(|x| x == r).expect("child rank in subtree");
                    &store[frames[idx].clone()]
                })
                .collect();
            wire.clear();
            encode_bundle_into(total, &parts, &mut wire)?;
            send_size(comm, s.peer, ltree.size_tag(s.round), wire.len() as u64, m)?;
            let t0 = std::time::Instant::now();
            m.bytes_sent += send_segmented(comm.t, s.peer, ltree.step_tag(s.round), &wire, seg)?;
            m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        }
        st.pool.put_bytes(wire);

        // Deliver: my node's ranks lead the enumeration (BFS starts at
        // the own node). Decode each member frame once — validated
        // against the frame's physical size first — and ship raw chunks.
        let ranges = chunk_ranges(total, n);
        let mut own = Vec::new();
        let mut vals = st.pool.take_f32();
        for (k, &mr) in members.iter().enumerate() {
            let frame = &store[frames[k].clone()];
            let want = ranges[mr].len();
            let physical = crate::compress::checked_count(frame)?;
            if physical != want {
                return Err(Error::corrupt(format!(
                    "hier scatter rank {mr}: frame holds {physical} values, want {want}"
                )));
            }
            if mr == me {
                own = vec![0.0f32; want];
                let t0 = std::time::Instant::now();
                st.decode_into_slice(frame, &mut own)
                    .map_err(|e| Error::corrupt(format!("hier scatter rank {mr}: {e}")))?;
                m.add(Phase::Decompress, t0.elapsed().as_secs_f64());
            } else {
                vals.clear();
                vals.resize(want, 0.0);
                let t0 = std::time::Instant::now();
                st.decode_into_slice(frame, &mut vals)
                    .map_err(|e| Error::corrupt(format!("hier scatter rank {mr}: {e}")))?;
                m.add(Phase::Decompress, t0.elapsed().as_secs_f64());
                intra_send(comm, st, mr, down_tag, &vals, m)?;
            }
        }
        st.pool.put_f32(vals);
        if pooled {
            st.pool.put_bytes(store);
        } else {
            comm.t.recycle(store);
        }
        Ok(own)
    } else {
        // Member (a follower root rejoins here): its chunk from the
        // leader over the fast tier.
        let mut out = Vec::new();
        intra_recv_into(comm, st, topo.leader_of(me), down_tag, m, &mut out)?;
        Ok(out)
    }
}

/// Intersection of two index ranges (empty — `start..start` — when they
/// are disjoint).
fn intersect(a: &Range<usize>, b: &Range<usize>) -> Range<usize> {
    let start = a.start.max(b.start);
    let end = a.end.min(b.end);
    start..end.max(start)
}

/// Hierarchical reduce-scatter: intra star-reduce onto the leader (fast
/// tier), flat ZCCL reduce-scatter over the leader group on the node
/// partials, raw redistribution of the leader tier's L-chunks onto the
/// n-way ownership chunks, then each member's owned chunk down the fast
/// tier. The L-chunks do not align with the n-way chunks, so every
/// ordered leader pair exchanges exactly **one** (possibly empty)
/// redistribution message whose piece list both sides derive from chunk
/// arithmetic — the message graph stays payload-length independent.
/// Results are bit-identical to flat ZCCL reduce-scatter run over the
/// leader group on the node partials (sliced at the n-way ownership
/// boundaries), and no [`ReduceOp::finish`] runs (mirroring flat).
pub(crate) fn reduce_scatter_hier(
    comm: &mut Communicator,
    st: &mut CollState,
    input: &[f32],
    op: ReduceOp,
    m: &mut Metrics,
    owned: &mut Vec<f32>,
) -> Result<Range<usize>> {
    let n = comm.size();
    let me = comm.rank();
    let topo = resolve_topo(st, n)?;
    let plan = HierReduceScatterPlan::at(comm.fresh_tags(HierReduceScatterPlan::span(n)), n);
    let node = topo.node_of(me);
    let members = topo.members(node);
    let local_idx = topo.local_index(me);
    let nnodes = topo.nodes();
    let ranges = chunk_ranges(input.len(), n);
    let own = (me + 1) % n;
    m.raw_bytes += (input.len() * 4) as u64;

    if local_idx != 0 {
        // Follower: partial up, owned chunk down — fast tier only.
        intra_send(comm, st, topo.leader_of(me), plan.up_tag(), input, m)?;
        intra_recv_into(comm, st, topo.leader_of(me), plan.down_tag(), m, owned)?;
        return Ok(ranges[own].clone());
    }

    // (1) Intra tier: fold member partials in ascending member order —
    //     deterministic, same fold order as the hierarchical allreduce.
    let mut acc = st.pool.take_f32();
    acc.extend_from_slice(input);
    {
        let mut wire = comm.t.lease();
        for &mr in &members[1..] {
            let t0 = std::time::Instant::now();
            comm.t.recv_into(mr, plan.up_tag(), &mut wire)?;
            m.add(Phase::Comm, t0.elapsed().as_secs_f64());
            m.bytes_recv += wire.len() as u64;
            let t0 = std::time::Instant::now();
            st.intra_fold(op, &wire, &mut acc)?;
            m.add(Phase::Compute, t0.elapsed().as_secs_f64());
        }
        comm.t.recycle(wire);
    }

    // (2) Inter tier: flat ZCCL reduce-scatter over the leader group on
    //     the node partials — group rank j ends up owning L-chunk
    //     (j + 1) % L of the fully reduced vector.
    let lranges = chunk_ranges(input.len(), nnodes);
    let mut lchunk = st.pool.take_f32();
    let my_lrange = if nnodes == 1 {
        lchunk.extend_from_slice(&acc);
        0..input.len()
    } else {
        let saved = st.mode.algo;
        st.mode.algo = Algo::Zccl;
        let r = (|| -> Result<Range<usize>> {
            let mut gt = GroupTransport::new(&mut *comm.t, topo.leaders(), plan.group_base())?;
            let mut gc = Communicator::new(&mut gt);
            reduce_scatter_with(&mut gc, st, &acc, op, m, &mut lchunk)
        })();
        st.mode.algo = saved;
        r?
    };
    st.pool.put_f32(acc);

    // (3) Redistribution onto the n-way ownership chunks. `full` is only
    //     read at my own members' chunks, all of which are filled either
    //     locally or by an incoming piece.
    let owner_node = |c: usize| topo.node_of((c + n - 1) % n);
    let mut full = st.pool.take_f32();
    full.resize(input.len(), 0.0);
    for c in 0..n {
        if owner_node(c) == node {
            let inter = intersect(&my_lrange, &ranges[c]);
            if !inter.is_empty() {
                full[inter.clone()].copy_from_slice(
                    &lchunk[inter.start - my_lrange.start..inter.end - my_lrange.start],
                );
            }
        }
    }
    if nnodes > 1 {
        let leaders = topo.leaders();
        for k in 0..nnodes {
            if k == node {
                continue;
            }
            let mut wire = comm.t.lease();
            let mut count = 0u32;
            le::put_u32(&mut wire, 0); // piece count, patched below
            for c in 0..n {
                if owner_node(c) != k {
                    continue;
                }
                let inter = intersect(&my_lrange, &ranges[c]);
                if inter.is_empty() {
                    continue;
                }
                le::put_u32(&mut wire, frame_u32(c, "redist chunk index")?);
                le::put_u32(&mut wire, frame_u32(inter.len() * 4, "redist piece size")?);
                f32s_to_bytes_into(
                    &lchunk[inter.start - my_lrange.start..inter.end - my_lrange.start],
                    &mut wire,
                );
                count += 1;
            }
            wire[0..4].copy_from_slice(&count.to_le_bytes());
            m.bytes_sent += wire.len() as u64;
            let t0 = std::time::Instant::now();
            comm.t.send_pooled(leaders[k], plan.redist_tag(), wire)?;
            m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        }
        let mut wire = comm.t.lease();
        for k in 0..nnodes {
            if k == node {
                continue;
            }
            let t0 = std::time::Instant::now();
            comm.t.recv_into(leaders[k], plan.redist_tag(), &mut wire)?;
            m.add(Phase::Comm, t0.elapsed().as_secs_f64());
            m.bytes_recv += wire.len() as u64;
            let sender_lrange = &lranges[(k + 1) % nnodes];
            let mut pos = 0usize;
            let count = le::get_u32(&wire, &mut pos)?;
            for _ in 0..count {
                let c = le::get_u32(&wire, &mut pos)? as usize;
                let bytes = le::get_u32(&wire, &mut pos)? as usize;
                if c >= n {
                    return Err(Error::corrupt(format!("redist chunk {c} out of {n}")));
                }
                let inter = intersect(sender_lrange, &ranges[c]);
                if owner_node(c) != node || inter.len() * 4 != bytes {
                    return Err(Error::corrupt(format!(
                        "redist piece for chunk {c} from leader {k}: {bytes} bytes, \
                         expected {} for this pair",
                        inter.len() * 4
                    )));
                }
                let end = pos + bytes;
                if end > wire.len() {
                    return Err(Error::corrupt("redist piece past end"));
                }
                bytes_to_f32s_into_slice(&wire[pos..end], &mut full[inter])?;
                pos = end;
            }
        }
        comm.t.recycle(wire);
    }
    st.pool.put_f32(lchunk);

    // (4) Intra tier: each member's owned chunk down the fast tier.
    for &mr in &members[1..] {
        let chunk = ranges[(mr + 1) % n].clone();
        intra_send(comm, st, mr, plan.down_tag(), &full[chunk], m)?;
    }
    owned.extend_from_slice(&full[ranges[own].clone()]);
    st.pool.put_f32(full);
    Ok(ranges[own].clone())
}

/// Hierarchical gather: members ship raw chunks to their leader (fast
/// tier); the leader compresses each member chunk **individually** (the
/// same leaf frames flat ZCCL would produce) and the leaders merge
/// per-member frame-record bundles up the segmented binomial tree toward
/// the root's leader (slow tier, §3.5.1 pipeline per edge). A follower
/// root receives the full bundle from its leader over the fast tier.
/// Results are bit-identical to flat ZCCL.
pub(crate) fn gather_hier(
    comm: &mut Communicator,
    st: &mut CollState,
    my_chunk: &[f32],
    root: usize,
    m: &mut Metrics,
) -> Result<Option<Vec<f32>>> {
    let n = comm.size();
    let me = comm.rank();
    let topo = resolve_topo(st, n)?;
    let plan = HierGatherPlan::at(comm.fresh_tags(HierGatherPlan::span(n)), n);
    let ltree = plan.leader_tree();
    let seg = st.mode.pipeline_bytes;

    let node = topo.node_of(me);
    let members = topo.members(node);
    let local_idx = topo.local_index(me);
    let root_node = topo.node_of(root);
    let root_leader = topo.leader_of(root);
    m.raw_bytes += (my_chunk.len() * 4) as u64;

    if local_idx != 0 {
        // Follower: chunk up the fast tier; a follower root additionally
        // receives the assembled bundle back from its leader.
        intra_send(comm, st, topo.leader_of(me), plan.up_tag(), my_chunk, m)?;
        if me != root {
            return Ok(None);
        }
        let mut bundle = comm.t.lease();
        let t0 = std::time::Instant::now();
        comm.t.recv_into(root_leader, plan.hop_tag(), &mut bundle)?;
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        m.bytes_recv += bundle.len() as u64;
        let recs = parse_records(&bundle)?;
        let out = assemble_gather_records(st, &bundle, recs, n, m)?;
        comm.t.recycle(bundle);
        return Ok(Some(out));
    }

    // Leader: collect member chunks raw and compress each one
    // individually — one frame per rank, same boundaries as flat.
    let mut store = st.pool.take_bytes();
    let mut records: Vec<(u32, usize, Range<usize>)> = Vec::new();
    let mut stores: Vec<Vec<u8>> = Vec::new();
    {
        let mut wire = comm.t.lease();
        let mut vals = st.pool.take_f32();
        for (k, &mr) in members.iter().enumerate() {
            let start = store.len();
            if k == 0 {
                let t0 = std::time::Instant::now();
                st.compress_into(my_chunk, &mut store)?;
                m.add(Phase::Compress, t0.elapsed().as_secs_f64());
            } else {
                let t0 = std::time::Instant::now();
                comm.t.recv_into(mr, plan.up_tag(), &mut wire)?;
                m.add(Phase::Comm, t0.elapsed().as_secs_f64());
                m.bytes_recv += wire.len() as u64;
                st.intra_decode_into(&wire, &mut vals)?;
                let t0 = std::time::Instant::now();
                st.compress_into(&vals, &mut store)?;
                m.add(Phase::Compress, t0.elapsed().as_secs_f64());
            }
            records.push((mr as u32, 0, start..store.len()));
        }
        st.pool.put_f32(vals);
        comm.t.recycle(wire);
    }

    // Merge child leaders' bundles (reverse round order, same drain
    // order as the flat gather) — records reference the arrival buffers
    // in place.
    let (parent_step, child_steps) = binomial_bcast_in_group(topo.leaders(), node, root_node);
    for s in child_steps.iter().rev() {
        let total = recv_size(comm, s.peer, ltree.size_tag(s.round), m)? as usize;
        let mut msg = comm.t.lease();
        let t0 = std::time::Instant::now();
        recv_segmented_into(comm.t, s.peer, ltree.step_tag(s.round), total, seg, &mut msg)?;
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        m.bytes_recv += msg.len() as u64;
        let recs = parse_records(&msg)?;
        let idx = stores.len() + 1;
        records.extend(recs.into_iter().map(|(rank, r)| (rank, idx, r)));
        stores.push(msg);
    }

    let result = if node == root_node {
        // I am the root's leader and hold every record.
        if me == root {
            // Re-range the records against one merged buffer so the
            // shared assembly path sees a single base.
            let parts: Vec<(u32, &[u8])> = records
                .iter()
                .map(|(rank, si, r)| (*rank, record_bytes(&store, &stores, *si, r)))
                .collect();
            let mut merged = st.pool.take_bytes();
            encode_records_into(&parts, &mut merged)?;
            let recs = parse_records(&merged)?;
            let out = assemble_gather_records(st, &merged, recs, n, m)?;
            st.pool.put_bytes(merged);
            Some(out)
        } else {
            // Forward the whole bundle to the follower root over the
            // fast tier (monolithic — one cheap hop).
            let parts: Vec<(u32, &[u8])> = records
                .iter()
                .map(|(rank, si, r)| {
                    (*rank, record_bytes(&store, &stores, *si, r))
                })
                .collect();
            let mut wire = comm.t.lease();
            encode_records_into(&parts, &mut wire)?;
            m.bytes_sent += wire.len() as u64;
            let t0 = std::time::Instant::now();
            comm.t.send_pooled(root, plan.hop_tag(), wire)?;
            m.add(Phase::Comm, t0.elapsed().as_secs_f64());
            None
        }
    } else {
        // Interior / leaf leader: merged bundle up the segmented tree.
        let step = parent_step.expect("non-root-node leader has a parent");
        let parts: Vec<(u32, &[u8])> = records
            .iter()
            .map(|(rank, si, r)| (*rank, record_bytes(&store, &stores, *si, r)))
            .collect();
        let mut wire = st.pool.take_bytes();
        encode_records_into(&parts, &mut wire)?;
        send_size(comm, step.peer, ltree.size_tag(step.round), wire.len() as u64, m)?;
        let t0 = std::time::Instant::now();
        m.bytes_sent += send_segmented(comm.t, step.peer, ltree.step_tag(step.round), &wire, seg)?;
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        st.pool.put_bytes(wire);
        None
    };
    st.pool.put_bytes(store);
    for msg in stores {
        comm.t.recycle(msg);
    }
    Ok(result)
}

/// Resolve a gather record to its payload bytes: store index 0 is the
/// leader's own frame store, `i + 1` is arrival buffer `i`.
fn record_bytes<'a>(
    store: &'a [u8],
    stores: &'a [Vec<u8>],
    si: usize,
    r: &Range<usize>,
) -> &'a [u8] {
    if si == 0 {
        &store[r.clone()]
    } else {
        &stores[si - 1][r.clone()]
    }
}

/// Sort `(rank, payload range)` records by rank, size the output from
/// the frame headers and placement-decode every record into its final
/// window — the flat gather's root assembly, shared by the root-leader
/// and follower-root paths.
fn assemble_gather_records(
    st: &mut CollState,
    bundle: &[u8],
    mut recs: Vec<(u32, Range<usize>)>,
    n: usize,
    m: &mut Metrics,
) -> Result<Vec<f32>> {
    if recs.len() != n {
        return Err(Error::corrupt(format!(
            "hier gather assembled {} records for {n} ranks",
            recs.len()
        )));
    }
    recs.sort_by_key(|(rank, _)| *rank);
    let mut counts = Vec::with_capacity(recs.len());
    for (_, r) in &recs {
        counts.push(crate::compress::checked_count(&bundle[r.clone()])?);
    }
    let mut out = vec![0.0f32; counts.iter().sum()];
    let mut off = 0usize;
    for ((rank, r), &cnt) in recs.iter().zip(&counts) {
        let t0 = std::time::Instant::now();
        st.decode_into_slice(&bundle[r.clone()], &mut out[off..off + cnt])
            .map_err(|e| Error::corrupt(format!("hier gather rank {rank}: {e}")))?;
        m.add(Phase::Decompress, t0.elapsed().as_secs_f64());
        off += cnt;
    }
    Ok(out)
}

/// Hierarchical alltoall: every member ships its full input raw to its
/// leader (fast tier); the leader compresses each (source member →
/// destination rank) chunk exactly once and the leaders exchange bundle
/// lanes pairwise (round `t` pairs leader `j` with leader `(j + t) % L`,
/// slow tier, leader↔leader only); the destination leader decodes every
/// frame addressed to its node — including the node-local lanes, so
/// `D∘C` is applied to every chunk exactly as flat ZCCL applies it — and
/// hands each member its assembled output over the fast tier. Results
/// are bit-identical to flat ZCCL.
pub(crate) fn alltoall_hier(
    comm: &mut Communicator,
    st: &mut CollState,
    input: &[f32],
    m: &mut Metrics,
    out: &mut Vec<f32>,
) -> Result<()> {
    let n = comm.size();
    let me = comm.rank();
    let topo = resolve_topo(st, n)?;
    let plan = HierAlltoallPlan::at(comm.fresh_tags(HierAlltoallPlan::span(n)), n);
    let node = topo.node_of(me);
    let members = topo.members(node);
    let local_idx = topo.local_index(me);
    let nnodes = topo.nodes();
    m.raw_bytes += (input.len() * 4) as u64;

    if local_idx != 0 {
        // Follower: full input up, assembled output down — fast tier.
        intra_send(comm, st, topo.leader_of(me), plan.up_tag(), input, m)?;
        return intra_recv_into(comm, st, topo.leader_of(me), plan.down_tag(), m, out);
    }

    let mm = members.len();
    // (1) Collect member inputs raw over the fast tier.
    let mut member_vals: Vec<Vec<f32>> = Vec::with_capacity(mm);
    {
        let mut own = st.pool.take_f32();
        own.extend_from_slice(input);
        member_vals.push(own);
        let mut wire = comm.t.lease();
        for &mr in &members[1..] {
            let t0 = std::time::Instant::now();
            comm.t.recv_into(mr, plan.up_tag(), &mut wire)?;
            m.add(Phase::Comm, t0.elapsed().as_secs_f64());
            m.bytes_recv += wire.len() as u64;
            let mut vals = st.pool.take_f32();
            st.intra_decode_into(&wire, &mut vals)?;
            member_vals.push(vals);
        }
        comm.t.recycle(wire);
    }

    // (2) Compress every (source member, destination rank) chunk exactly
    //     once — member input lengths may differ, so each member gets its
    //     own n-way chunking (matching what flat would send).
    let mut store = st.pool.take_bytes();
    let mut frames: Vec<Vec<Range<usize>>> = Vec::with_capacity(mm);
    for vals in &member_vals {
        let r = chunk_ranges(vals.len(), n);
        let mut row = Vec::with_capacity(n);
        for dst in 0..n {
            let start = store.len();
            let t0 = std::time::Instant::now();
            st.compress_into(&vals[r[dst].clone()], &mut store)?;
            m.add(Phase::Compress, t0.elapsed().as_secs_f64());
            row.push(start..store.len());
        }
        frames.push(row);
    }
    for vals in member_vals {
        st.pool.put_f32(vals);
    }

    // (3) Pairwise bundle lanes between the leaders (slow tier). Lane
    //     order inside a bundle: source member ascending × destination
    //     member ascending — both sides derive it from the topology.
    let leaders = topo.leaders();
    let mut foreign: Vec<Option<Vec<u8>>> = vec![None; nnodes];
    for t in 1..nnodes {
        let to_node = (node + t) % nnodes;
        let from_node = (node + nnodes - t) % nnodes;
        let parts: Vec<&[u8]> = frames
            .iter()
            .flat_map(|row| {
                topo.members(to_node).iter().map(move |&dr| &store[row[dr].clone()])
            })
            .collect();
        let mut wire = comm.t.lease();
        encode_bundle_into(0, &parts, &mut wire)?;
        m.bytes_sent += wire.len() as u64;
        let t0 = std::time::Instant::now();
        comm.t.send_pooled(leaders[to_node], plan.lane_tag(t), wire)?;
        let mut got = comm.t.lease();
        comm.t.recv_into(leaders[from_node], plan.lane_tag(t), &mut got)?;
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        m.bytes_recv += got.len() as u64;
        foreign[from_node] = Some(got);
    }

    // (4) Parse foreign bundles and assemble each member's output in
    //     global source-rank order, decoding every frame exactly once.
    let mut parsed: Vec<Option<(Vec<u8>, Vec<Range<usize>>)>> =
        (0..nnodes).map(|_| None).collect();
    for (k, slot) in foreign.iter_mut().enumerate() {
        if let Some(buf) = slot.take() {
            let want = topo.members(k).len() * mm;
            let (_, ranges) = parse_bundle(&buf, want)?;
            parsed[k] = Some((buf, ranges));
        }
    }
    let mut vals = st.pool.take_f32();
    for (dst_idx, &mr) in members.iter().enumerate() {
        let mut counts = Vec::with_capacity(n);
        for src in 0..n {
            let sn = topo.node_of(src);
            let frame = if sn == node {
                &store[frames[topo.local_index(src)][mr].clone()]
            } else {
                let (buf, ranges) = parsed[sn].as_ref().expect("lane received");
                let pos = topo.local_index(src) * mm + dst_idx;
                &buf[ranges[pos].clone()]
            };
            counts.push(crate::compress::checked_count(frame)?);
        }
        let total: usize = counts.iter().sum();
        vals.clear();
        vals.resize(total, 0.0);
        let mut off = 0usize;
        for src in 0..n {
            let sn = topo.node_of(src);
            let frame = if sn == node {
                &store[frames[topo.local_index(src)][mr].clone()]
            } else {
                let (buf, ranges) = parsed[sn].as_ref().expect("lane received");
                let pos = topo.local_index(src) * mm + dst_idx;
                &buf[ranges[pos].clone()]
            };
            let cnt = counts[src];
            let t0 = std::time::Instant::now();
            st.decode_into_slice(frame, &mut vals[off..off + cnt])
                .map_err(|e| Error::corrupt(format!("hier alltoall src {src}: {e}")))?;
            m.add(Phase::Decompress, t0.elapsed().as_secs_f64());
            off += cnt;
        }
        if mr == me {
            out.clear();
            out.extend_from_slice(&vals);
        } else {
            intra_send(comm, st, mr, plan.down_tag(), &vals, m)?;
        }
    }
    st.pool.put_f32(vals);
    st.pool.put_bytes(store);
    for p in parsed.into_iter().flatten() {
        comm.t.recycle(p.0);
    }
    Ok(())
}

/// Hierarchical reduce: intra star-reduce onto the leader (fast tier),
/// flat ZCCL reduce over the leader group toward the root's leader with
/// the **total** rank count as the finish divisor (the node partials
/// already hold every member's contribution), then an optional
/// root-leader → follower-root hop over the fast tier. Results are
/// bit-identical to flat ZCCL reduce run over the leader group on the
/// node partials.
pub(crate) fn reduce_hier(
    comm: &mut Communicator,
    st: &mut CollState,
    input: &[f32],
    op: ReduceOp,
    root: usize,
    m: &mut Metrics,
) -> Result<Option<Vec<f32>>> {
    let n = comm.size();
    let me = comm.rank();
    let topo = resolve_topo(st, n)?;
    let plan = HierReducePlan::at(comm.fresh_tags(HierReducePlan::span(n)), n);
    let node = topo.node_of(me);
    let members = topo.members(node);
    let local_idx = topo.local_index(me);
    let nnodes = topo.nodes();
    let root_node = topo.node_of(root);
    m.raw_bytes += (input.len() * 4) as u64;

    if local_idx != 0 {
        // Follower: partial up; a follower root receives the finished
        // result back from its leader over the fast tier.
        intra_send(comm, st, topo.leader_of(me), plan.up_tag(), input, m)?;
        if me != root {
            return Ok(None);
        }
        let mut out = Vec::new();
        intra_recv_into(comm, st, topo.leader_of(me), plan.hop_tag(), m, &mut out)?;
        return Ok(Some(out));
    }

    // (1) Intra tier: fold member partials in ascending member order.
    let mut acc = st.pool.take_f32();
    acc.extend_from_slice(input);
    {
        let mut wire = comm.t.lease();
        for &mr in &members[1..] {
            let t0 = std::time::Instant::now();
            comm.t.recv_into(mr, plan.up_tag(), &mut wire)?;
            m.add(Phase::Comm, t0.elapsed().as_secs_f64());
            m.bytes_recv += wire.len() as u64;
            let t0 = std::time::Instant::now();
            st.intra_fold(op, &wire, &mut acc)?;
            m.add(Phase::Compute, t0.elapsed().as_secs_f64());
        }
        comm.t.recycle(wire);
    }

    // (2) Inter tier: flat ZCCL reduce over the leader group toward the
    //     root's leader, finishing with the total rank count.
    let result = if nnodes == 1 {
        let mut r = acc.clone();
        op.finish(&mut r, n);
        Some(r)
    } else {
        let saved = st.mode.algo;
        st.mode.algo = Algo::Zccl;
        let r = (|| -> Result<Option<Vec<f32>>> {
            let mut gt = GroupTransport::new(&mut *comm.t, topo.leaders(), plan.group_base())?;
            let mut gc = Communicator::new(&mut gt);
            reduce_impl(&mut gc, st, &acc, op, root_node, n, m)
        })();
        st.mode.algo = saved;
        r?
    };
    st.pool.put_f32(acc);

    if node == root_node {
        let result = result.expect("the root node's leader holds the result");
        if me == root {
            return Ok(Some(result));
        }
        intra_send(comm, st, root, plan.hop_tag(), &result, m)?;
    }
    Ok(None)
}
