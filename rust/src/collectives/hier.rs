//! Hierarchical topology-aware collectives ([`Algo::Hier`]).
//!
//! Real clusters are two-tier: cheap intra-node links, expensive
//! inter-node links. Flat compressed schedules ignore that and make every
//! rank compress, so a 4-rank node compresses the same wire payloads four
//! times and ships them over the slow tier from four NICs. The
//! hierarchical schedules (gZCCL, arXiv:2308.05199; C-Coll,
//! arXiv:2304.03890 stresses keeping codec cost off the inter-node
//! critical path) split every collective across the tiers of a
//! [`Topology`]:
//!
//! - **intra-node tier** — raw `f32` windows over the fast links; only
//!   computation (reduction folds), never compression;
//! - **inter-node tier** — the unchanged flat ZCCL schedules run over the
//!   node **leaders** only (via [`GroupTransport`]), carrying compressed
//!   frames that are forwarded verbatim: compress-once extended across
//!   tiers. Each node's data is compressed exactly once, by its leader,
//!   and every frame that crosses the slow tier travels leader↔leader.
//!
//! Per collective:
//!
//! | collective  | intra up            | inter (leaders)                   | intra down        |
//! |-------------|---------------------|-----------------------------------|-------------------|
//! | `allreduce` | raw partials → leader fold | flat ZCCL reduce-scatter + allgather | raw result, binomial |
//! | `allgather` | raw chunks → leader | per-rank frame bundles over the ring | raw result, binomial |
//! | `bcast`     | root's frame → its leader | frame over the binomial tree | raw payload, binomial |
//! | `scatter`   | root's frame bundle → its leader | subtree bundles over the binomial tree ([`binomial_subtree_into`]) | raw chunk per member |
//!
//! Because the leader tier reuses the flat code verbatim and per-rank
//! frame boundaries are preserved, `allgather`, `bcast` and `scatter`
//! return **bit-identical** results to flat [`Algo::Zccl`] on the same
//! communicator, and `allreduce` is bit-identical to flat `Zccl` run over
//! the leader group on the node-reduced inputs (and therefore to flat
//! `Zccl` outright whenever every node holds one rank). The remaining
//! collectives fall back to their flat `Zccl` form under `Hier`.
//!
//! Without an installed topology ([`super::CollCtx::set_topology`]),
//! [`Topology::flat`] is assumed and everything degenerates to flat ZCCL.

use std::ops::Range;
use std::sync::Arc;

use super::allgather::allgather_chunks_with;
use super::ctx::CollState;
use super::reduce_scatter::reduce_scatter_with;
use super::scatter::{encode_bundle_into, parse_bundle};
use super::{
    bytes_to_f32s_into, bytes_to_f32s_into_slice, chunk_ranges, f32s_to_bytes_into,
    fold_f32_bytes, Algo, Communicator, ReduceOp,
};
use crate::analysis::plan::{
    HierAllgatherPlan, HierAllreducePlan, HierBcastPlan, HierScatterPlan, HIER_GROUP_SPAN,
};
use crate::coordinator::{Metrics, Phase};
use crate::topology::{
    binomial_bcast_in_group, binomial_subtree_into, ring_in_group, ring_recv_chunk,
    ring_send_chunk, Topology,
};
use crate::transport::GroupTransport;
use crate::{Error, Result};

/// The topology the hierarchical schedules run over: the installed one
/// (an `Arc` clone — the node tables are shared, not copied, so warm
/// iterated calls stay allocation-light), validated against the
/// communicator, or the flat (rank-per-node) degenerate default. Also
/// holds the per-tier contract: the intra tier declared on the context
/// must be raw — `set_intra_mode` enforces it at the API boundary and
/// this re-check keeps crate-internal callers honest.
fn resolve_topo(st: &mut CollState, n: usize) -> Result<Arc<Topology>> {
    if st.intra.compresses() {
        return Err(Error::invalid(
            "hierarchical schedules ship raw f32 on the intra tier; \
             a compressed intra mode is not supported",
        ));
    }
    if st.topo.is_none() {
        // Cache the degenerate rank-per-node default so iterated calls
        // without an installed topology stay allocation-light too.
        st.topo = Some(Arc::new(Topology::flat(n)));
    }
    let topo = {
        let t = st.topo.as_ref().expect("installed above");
        if t.ranks() != n {
            return Err(Error::invalid(format!(
                "topology covers {} ranks but the communicator has {n}",
                t.ranks()
            )));
        }
        Arc::clone(t)
    };
    // Tag-budget guard: the leader tier's inner flat collectives reserve
    // up to `(L + 2) * SEG_TAG_SPAN + L` tags out of the
    // [`HIER_GROUP_SPAN`] window; more leaders than fit would silently
    // spill into the parent's subsequent tag windows and cross-match
    // unrelated messages — the same silent-collision class
    // `segment_count` guards against on the segmented path.
    let worst = (topo.nodes() as u64 + 3) * super::SEG_TAG_SPAN;
    if worst > HIER_GROUP_SPAN {
        return Err(Error::invalid(format!(
            "hierarchical schedules support at most {} nodes (leader-tier tag budget)",
            HIER_GROUP_SPAN / super::SEG_TAG_SPAN - 3
        )));
    }
    Ok(topo)
}

/// Intra-node raw broadcast of the leader's `out` to every member over
/// the fast tier (binomial over the member group, rooted at the leader).
/// On entry the leader's `out` holds the values; on exit every member's
/// `out` holds them (bit-identical — the wire is a plain `f32`
/// serialisation).
fn intra_bcast_result(
    comm: &mut Communicator,
    st: &mut CollState,
    members: &[usize],
    local_idx: usize,
    tag_base: u64,
    m: &mut Metrics,
    out: &mut Vec<f32>,
) -> Result<()> {
    if members.len() == 1 {
        return Ok(());
    }
    let (recv_step, send_steps) = binomial_bcast_in_group(members, local_idx, 0);
    let (buf, pooled) = if local_idx == 0 {
        let mut b = st.pool.take_bytes();
        f32s_to_bytes_into(out, &mut b);
        (b, true)
    } else {
        let step = recv_step.expect("non-leader member receives");
        let mut got = comm.t.lease();
        let t0 = std::time::Instant::now();
        comm.t.recv_into(step.peer, tag_base + step.round as u64, &mut got)?;
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        m.bytes_recv += got.len() as u64;
        (got, false)
    };
    for s in send_steps {
        let t0 = std::time::Instant::now();
        comm.t.send(s.peer, tag_base + s.round as u64, &buf)?;
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        m.bytes_sent += buf.len() as u64;
    }
    if local_idx != 0 {
        out.resize(buf.len() / 4, 0.0);
        bytes_to_f32s_into_slice(&buf, out.as_mut_slice())?;
    }
    if pooled {
        st.pool.put_bytes(buf);
    } else {
        comm.t.recycle(buf);
    }
    Ok(())
}

/// The inter tier of the hierarchical allreduce: the unchanged flat ZCCL
/// reduce-scatter + allgather over the leader group. The caller has
/// already switched `st.mode.algo` to [`Algo::Zccl`].
#[allow(clippy::too_many_arguments)]
fn leader_tier_allreduce(
    comm: &mut Communicator,
    st: &mut CollState,
    topo: &Topology,
    group_base: u64,
    acc: &[f32],
    op: ReduceOp,
    total_ranks: usize,
    m: &mut Metrics,
    out: &mut Vec<f32>,
) -> Result<()> {
    let mut owned = st.pool.take_f32();
    let mut gt = GroupTransport::new(&mut *comm.t, topo.leaders(), group_base)?;
    let mut gc = Communicator::new(&mut gt);
    reduce_scatter_with(&mut gc, st, acc, op, m, &mut owned)?;
    // Finish with the TOTAL rank count: the node partials already hold
    // every member's contribution (matters for Avg).
    op.finish(&mut owned, total_ranks);
    allgather_chunks_with(&mut gc, st, &owned, 1, m, out)?;
    st.pool.put_f32(owned);
    Ok(())
}

/// Hierarchical allreduce: intra-node raw reduce onto the leader →
/// inter-leader compressed ring reduce-scatter/allgather → intra-node raw
/// bcast. Only leaders touch the codec; each compressed frame crosses the
/// slow tier leader↔leader and is forwarded without recompression.
pub(crate) fn allreduce_hier(
    comm: &mut Communicator,
    st: &mut CollState,
    input: &[f32],
    op: ReduceOp,
    m: &mut Metrics,
    out: &mut Vec<f32>,
) -> Result<()> {
    let n = comm.size();
    let me = comm.rank();
    let topo = resolve_topo(st, n)?;
    if n == 1 {
        out.clear();
        out.extend_from_slice(input);
        op.finish(out, 1);
        return Ok(());
    }
    // Tag plan — one contiguous reservation, identical on every rank.
    let plan = HierAllreducePlan::at(comm.fresh_tags(HierAllreducePlan::span(n)), n);
    let up_tag = plan.up_tag();
    let group_base = plan.group_base();
    let down_base = plan.down().base;

    let node = topo.node_of(me);
    let members = topo.members(node);
    let local_idx = topo.local_index(me);
    m.raw_bytes += (input.len() * 4) as u64;

    if local_idx == 0 {
        // (1) Intra tier: fold member partials in ascending member order
        //     — deterministic, exact, raw over the fast tier.
        let mut acc = st.pool.take_f32();
        acc.extend_from_slice(input);
        let mut wire = comm.t.lease();
        for &mr in &members[1..] {
            let t0 = std::time::Instant::now();
            comm.t.recv_into(mr, up_tag, &mut wire)?;
            m.add(Phase::Comm, t0.elapsed().as_secs_f64());
            m.bytes_recv += wire.len() as u64;
            let t0 = std::time::Instant::now();
            fold_f32_bytes(op, &wire, &mut acc)?;
            m.add(Phase::Compute, t0.elapsed().as_secs_f64());
        }
        comm.t.recycle(wire);

        // (2) Inter tier (leaders only).
        if topo.nodes() == 1 {
            out.clear();
            out.extend_from_slice(&acc);
            op.finish(out, n);
        } else {
            let saved = st.mode.algo;
            st.mode.algo = Algo::Zccl;
            let inter =
                leader_tier_allreduce(comm, st, &topo, group_base, &acc, op, n, m, out);
            st.mode.algo = saved;
            inter?;
        }
        st.pool.put_f32(acc);
    } else {
        // Follower: raw partial up (pooled zero-copy send), raw result
        // down; the codec never runs here.
        let mut up = comm.t.lease();
        f32s_to_bytes_into(input, &mut up);
        m.bytes_sent += up.len() as u64;
        let t0 = std::time::Instant::now();
        comm.t.send_pooled(topo.leader_of(me), up_tag, up)?;
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());
    }

    // (3) Intra tier: the full result, raw, down the member binomial.
    intra_bcast_result(comm, st, members, local_idx, down_base, m, out)
}

/// Hierarchical allgather. Members ship raw chunks to their leader; the
/// leader compresses each member chunk **individually** (preserving the
/// flat per-rank frame boundaries, so results are bit-identical to flat
/// ZCCL) and the leaders ring node bundles of frames around the slow
/// tier, forwarding them verbatim; each leader then decodes every frame
/// exactly once and broadcasts the raw gathered vector down the fast
/// tier.
pub(crate) fn allgather_hier(
    comm: &mut Communicator,
    st: &mut CollState,
    my_chunk: &[f32],
    m: &mut Metrics,
    out: &mut Vec<f32>,
) -> Result<()> {
    let n = comm.size();
    let me = comm.rank();
    let topo = resolve_topo(st, n)?;
    if n == 1 {
        out.clear();
        out.extend_from_slice(my_chunk);
        return Ok(());
    }
    let plan = HierAllgatherPlan::at(comm.fresh_tags(HierAllgatherPlan::span(n)), n);
    let up_tag = plan.up_tag();
    let lring_plan = plan.leader_ring(); // sized for n ranks >= nodes - 1 rounds
    let down_base = plan.down().base;

    let node = topo.node_of(me);
    let members = topo.members(node);
    let local_idx = topo.local_index(me);
    m.raw_bytes += (my_chunk.len() * 4) as u64;

    if local_idx != 0 {
        // Follower: raw chunk up, raw gathered vector down.
        let mut up = comm.t.lease();
        f32s_to_bytes_into(my_chunk, &mut up);
        m.bytes_sent += up.len() as u64;
        let t0 = std::time::Instant::now();
        comm.t.send_pooled(topo.leader_of(me), up_tag, up)?;
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        return intra_bcast_result(comm, st, members, local_idx, down_base, m, out);
    }

    let nnodes = topo.nodes();
    // (1) Collect member chunks (raw, fast tier) and compress each one
    //     individually — one compression per rank, all at the leader.
    let mut store = st.pool.take_bytes();
    let mut frames: Vec<Range<usize>> = Vec::with_capacity(members.len());
    {
        let mut wire = comm.t.lease();
        let mut vals = st.pool.take_f32();
        for (k, &mr) in members.iter().enumerate() {
            let start = store.len();
            if k == 0 {
                let t0 = std::time::Instant::now();
                st.compress_into(my_chunk, &mut store)?;
                m.add(Phase::Compress, t0.elapsed().as_secs_f64());
            } else {
                let t0 = std::time::Instant::now();
                comm.t.recv_into(mr, up_tag, &mut wire)?;
                m.add(Phase::Comm, t0.elapsed().as_secs_f64());
                m.bytes_recv += wire.len() as u64;
                vals.clear();
                bytes_to_f32s_into(&wire, &mut vals)?;
                let t0 = std::time::Instant::now();
                st.compress_into(&vals, &mut store)?;
                m.add(Phase::Compress, t0.elapsed().as_secs_f64());
            }
            frames.push(start..store.len());
        }
        st.pool.put_f32(vals);
        comm.t.recycle(wire);
    }

    // (2) Ring the node bundles around the leader tier (compressed frames
    //     forwarded verbatim, leader↔leader only).
    let lring = ring_in_group(topo.leaders(), node);
    let mut bundles: Vec<Option<Vec<u8>>> = vec![None; nnodes];
    {
        let mut mine = st.pool.take_bytes();
        let parts: Vec<&[u8]> = frames.iter().map(|r| &store[r.clone()]).collect();
        encode_bundle_into(my_chunk.len(), &parts, &mut mine)?;
        bundles[node] = Some(mine);
    }
    st.pool.put_bytes(store);
    for t in 0..nnodes - 1 {
        let s = ring_send_chunk(node, t, nnodes);
        let r = ring_recv_chunk(node, t, nnodes);
        let tag = lring_plan.round_tag(t);
        let send_buf = bundles[s].as_ref().expect("ring schedule owns sent bundle");
        let t0 = std::time::Instant::now();
        comm.t.send(lring.next, tag, send_buf)?;
        m.bytes_sent += send_buf.len() as u64;
        let mut got = comm.t.lease();
        comm.t.recv_into(lring.prev, tag, &mut got)?;
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        m.bytes_recv += got.len() as u64;
        bundles[r] = Some(got);
    }

    // (3) Size the output from the (size-bounded) frame headers, then
    //     placement-decode every frame — each exactly once, all here.
    let mut parsed: Vec<(Vec<u8>, Vec<Range<usize>>)> = Vec::with_capacity(nnodes);
    let mut counts = vec![0usize; n];
    for (j, slot) in bundles.iter_mut().enumerate() {
        let buf = slot.take().expect("all bundles gathered");
        let (_, ranges) = parse_bundle(&buf, topo.members(j).len())?;
        for (k, &rank) in topo.members(j).iter().enumerate() {
            counts[rank] = crate::compress::checked_count(&buf[ranges[k].clone()])?;
        }
        parsed.push((buf, ranges));
    }
    let mut offsets = Vec::with_capacity(n + 1);
    offsets.push(0usize);
    for &c in &counts {
        offsets.push(offsets.last().unwrap() + c);
    }
    out.resize(offsets[n], 0.0);
    for (j, (buf, ranges)) in parsed.into_iter().enumerate() {
        for (k, &rank) in topo.members(j).iter().enumerate() {
            let t0 = std::time::Instant::now();
            st.decode_into_slice(
                &buf[ranges[k].clone()],
                &mut out[offsets[rank]..offsets[rank + 1]],
            )
            .map_err(|e| Error::corrupt(format!("hier allgather rank {rank}: {e}")))?;
            m.add(Phase::Decompress, t0.elapsed().as_secs_f64());
        }
        if j == node {
            st.pool.put_bytes(buf);
        } else {
            comm.t.recycle(buf);
        }
    }

    // (4) Intra tier: raw gathered vector down the member binomial.
    intra_bcast_result(comm, st, members, 0, down_base, m, out)
}

/// Hierarchical broadcast: the root compresses **once**; the frame hops
/// to the root's node leader (if distinct), travels the leader binomial
/// tree verbatim over the slow tier, is decoded once per node by the
/// leader, and fans out raw over the fast tier. Output is bit-identical
/// to flat ZCCL (`D(C(data))` everywhere).
pub(crate) fn bcast_hier(
    comm: &mut Communicator,
    st: &mut CollState,
    data: Option<&[f32]>,
    root: usize,
    m: &mut Metrics,
) -> Result<Vec<f32>> {
    let n = comm.size();
    let me = comm.rank();
    let topo = resolve_topo(st, n)?;
    let plan = HierBcastPlan::at(comm.fresh_tags(HierBcastPlan::span(n)), n);
    let hop_tag = plan.hop_tag();
    let ltree = plan.leader_tree();
    let down_base = plan.down().base;

    let node = topo.node_of(me);
    let members = topo.members(node);
    let local_idx = topo.local_index(me);
    let root_node = topo.node_of(root);
    let root_leader = topo.leader_of(root);

    // (1) The root compresses once. A follower root hops the frame to its
    //     leader over the fast tier and rejoins as a plain member.
    let mut own_frame: Option<Vec<u8>> = None;
    if me == root {
        let d = data.unwrap();
        m.raw_bytes += (d.len() * 4) as u64;
        if me == root_leader {
            let mut f = st.pool.take_bytes();
            let t0 = std::time::Instant::now();
            st.compress_into(d, &mut f)?;
            m.add(Phase::Compress, t0.elapsed().as_secs_f64());
            own_frame = Some(f);
        } else {
            let mut f = comm.t.lease();
            let t0 = std::time::Instant::now();
            st.compress_into(d, &mut f)?;
            m.add(Phase::Compress, t0.elapsed().as_secs_f64());
            m.bytes_sent += f.len() as u64;
            let t0 = std::time::Instant::now();
            comm.t.send_pooled(root_leader, hop_tag, f)?;
            m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        }
    }

    if local_idx == 0 {
        // Leader: obtain the frame, forward it verbatim down the leader
        // tree (slow tier), decode exactly once, fan out raw.
        let (recv_step, send_steps) = binomial_bcast_in_group(topo.leaders(), node, root_node);
        let (frame, pooled) = match own_frame {
            Some(f) => (f, true),
            None => {
                let mut got = comm.t.lease();
                let t0 = std::time::Instant::now();
                if node == root_node {
                    comm.t.recv_into(root, hop_tag, &mut got)?;
                } else {
                    let step = recv_step.expect("non-root-node leader receives");
                    comm.t.recv_into(step.peer, ltree.step_tag(step.round), &mut got)?;
                }
                m.add(Phase::Comm, t0.elapsed().as_secs_f64());
                m.bytes_recv += got.len() as u64;
                (got, false)
            }
        };
        for s in send_steps {
            let t0 = std::time::Instant::now();
            comm.t.send(s.peer, ltree.step_tag(s.round), &frame)?;
            m.add(Phase::Comm, t0.elapsed().as_secs_f64());
            m.bytes_sent += frame.len() as u64;
        }
        let cnt = crate::compress::checked_count(&frame)?;
        let mut out = vec![0.0f32; cnt];
        let t0 = std::time::Instant::now();
        st.decode_into_slice(&frame, &mut out)?;
        m.add(Phase::Decompress, t0.elapsed().as_secs_f64());
        if pooled {
            st.pool.put_bytes(frame);
        } else {
            comm.t.recycle(frame);
        }
        intra_bcast_result(comm, st, members, 0, down_base, m, &mut out)?;
        Ok(out)
    } else {
        let mut out = Vec::new();
        intra_bcast_result(comm, st, members, local_idx, down_base, m, &mut out)?;
        Ok(out)
    }
}

/// The global ranks covered by the leader-tree subtree rooted at node
/// `at` (tree rooted at node `lroot`): subtree nodes in breadth-first
/// order via the iterative [`binomial_subtree_into`], then each node's
/// members. Sender and receiver compute the same enumeration, so bundle
/// positions need no rank table.
fn subtree_ranks(topo: &Topology, lroot: usize, at: usize, out: &mut Vec<usize>) {
    let mut nodes = Vec::new();
    binomial_subtree_into(at, lroot, topo.nodes(), &mut nodes);
    out.clear();
    for &j in &nodes {
        out.extend_from_slice(topo.members(j));
    }
}

/// Hierarchical scatter: the root compresses each rank's chunk **once**;
/// bundles of frames travel the leader binomial tree (each leader
/// forwarding its children's node-subtree bundles, slow tier,
/// leader↔leader); each leader decodes its members' frames — the node's
/// only decompressions — and hands every member its raw chunk over the
/// fast tier. Outputs are bit-identical to flat ZCCL.
pub(crate) fn scatter_hier(
    comm: &mut Communicator,
    st: &mut CollState,
    data: Option<&[f32]>,
    root: usize,
    m: &mut Metrics,
) -> Result<Vec<f32>> {
    let n = comm.size();
    let me = comm.rank();
    let topo = resolve_topo(st, n)?;
    let plan = HierScatterPlan::at(comm.fresh_tags(HierScatterPlan::span(n)), n);
    let hop_tag = plan.hop_tag();
    let ltree = plan.leader_tree();
    let down_tag = plan.down_tag();

    let node = topo.node_of(me);
    let members = topo.members(node);
    let local_idx = topo.local_index(me);
    let root_node = topo.node_of(root);
    let root_leader = topo.leader_of(root);

    // (1) The root compresses every rank's chunk once, packed in the
    //     root-leader subtree enumeration (= all ranks).
    let mut root_bundle: Option<(Vec<u8>, Vec<Range<usize>>, usize)> = None;
    if me == root {
        let d = data.unwrap();
        m.raw_bytes += (d.len() * 4) as u64;
        let ranges = chunk_ranges(d.len(), n);
        let mut order = Vec::new();
        subtree_ranks(&topo, root_node, root_node, &mut order);
        let mut store = st.pool.take_bytes();
        let mut frames = Vec::with_capacity(n);
        for &r in &order {
            let start = store.len();
            let t0 = std::time::Instant::now();
            st.compress_into(&d[ranges[r].clone()], &mut store)?;
            m.add(Phase::Compress, t0.elapsed().as_secs_f64());
            frames.push(start..store.len());
        }
        if me == root_leader {
            root_bundle = Some((store, frames, d.len()));
        } else {
            let mut wire = comm.t.lease();
            let parts: Vec<&[u8]> = frames.iter().map(|r| &store[r.clone()]).collect();
            encode_bundle_into(d.len(), &parts, &mut wire)?;
            m.bytes_sent += wire.len() as u64;
            let t0 = std::time::Instant::now();
            comm.t.send_pooled(root_leader, hop_tag, wire)?;
            m.add(Phase::Comm, t0.elapsed().as_secs_f64());
            st.pool.put_bytes(store);
        }
    }

    if local_idx == 0 {
        // Leader: obtain the bundle covering my node subtree, forward
        // each child leader its sub-bundle, deliver member chunks raw.
        let mut my_ranks = Vec::new();
        subtree_ranks(&topo, root_node, node, &mut my_ranks);
        let (recv_step, send_steps) = binomial_bcast_in_group(topo.leaders(), node, root_node);
        let (store, frames, total, pooled) = match root_bundle {
            Some((s, f, t)) => (s, f, t, true),
            None => {
                let mut got = comm.t.lease();
                let t0 = std::time::Instant::now();
                if node == root_node {
                    comm.t.recv_into(root, hop_tag, &mut got)?;
                } else {
                    let step = recv_step.expect("non-root-node leader receives");
                    comm.t.recv_into(step.peer, ltree.step_tag(step.round), &mut got)?;
                }
                m.add(Phase::Comm, t0.elapsed().as_secs_f64());
                m.bytes_recv += got.len() as u64;
                let (total, ranges) = parse_bundle(&got, my_ranks.len())?;
                (got, ranges, total, false)
            }
        };
        let mut child_ranks = Vec::new();
        for s in send_steps {
            let child_node = topo.node_of(s.peer);
            subtree_ranks(&topo, root_node, child_node, &mut child_ranks);
            let parts: Vec<&[u8]> = child_ranks
                .iter()
                .map(|r| {
                    let idx =
                        my_ranks.iter().position(|x| x == r).expect("child rank in subtree");
                    &store[frames[idx].clone()]
                })
                .collect();
            // One-shot bundle: assemble straight in a transport-leased
            // wire buffer and send it by value — no packet_from copy.
            let mut wire = comm.t.lease();
            encode_bundle_into(total, &parts, &mut wire)?;
            let t0 = std::time::Instant::now();
            m.bytes_sent += wire.len() as u64;
            comm.t.send_pooled(s.peer, ltree.step_tag(s.round), wire)?;
            m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        }

        // Deliver: my node's ranks lead the enumeration (BFS starts at
        // the own node). Decode each member frame once — validated
        // against the frame's physical size first — and ship raw chunks.
        let ranges = chunk_ranges(total, n);
        let mut own = Vec::new();
        let mut vals = st.pool.take_f32();
        for (k, &mr) in members.iter().enumerate() {
            let frame = &store[frames[k].clone()];
            let want = ranges[mr].len();
            let physical = crate::compress::checked_count(frame)?;
            if physical != want {
                return Err(Error::corrupt(format!(
                    "hier scatter rank {mr}: frame holds {physical} values, want {want}"
                )));
            }
            if mr == me {
                own = vec![0.0f32; want];
                let t0 = std::time::Instant::now();
                st.decode_into_slice(frame, &mut own)
                    .map_err(|e| Error::corrupt(format!("hier scatter rank {mr}: {e}")))?;
                m.add(Phase::Decompress, t0.elapsed().as_secs_f64());
            } else {
                vals.clear();
                vals.resize(want, 0.0);
                let t0 = std::time::Instant::now();
                st.decode_into_slice(frame, &mut vals)
                    .map_err(|e| Error::corrupt(format!("hier scatter rank {mr}: {e}")))?;
                m.add(Phase::Decompress, t0.elapsed().as_secs_f64());
                let mut raw = comm.t.lease();
                f32s_to_bytes_into(&vals, &mut raw);
                m.bytes_sent += raw.len() as u64;
                let t0 = std::time::Instant::now();
                comm.t.send_pooled(mr, down_tag, raw)?;
                m.add(Phase::Comm, t0.elapsed().as_secs_f64());
            }
        }
        st.pool.put_f32(vals);
        if pooled {
            st.pool.put_bytes(store);
        } else {
            comm.t.recycle(store);
        }
        Ok(own)
    } else {
        // Member (a follower root rejoins here): raw chunk from the
        // leader over the fast tier.
        let mut got = comm.t.lease();
        let t0 = std::time::Instant::now();
        comm.t.recv_into(topo.leader_of(me), down_tag, &mut got)?;
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        m.bytes_recv += got.len() as u64;
        let mut out = vec![0.0f32; got.len() / 4];
        bytes_to_f32s_into_slice(&got, &mut out)?;
        comm.t.recycle(got);
        Ok(out)
    }
}
