//! Binomial-tree reduce (to root) — a collective *computation* operation:
//! each interior rank folds its children's partial results into its local
//! buffer before forwarding upward, so the transferred data is updated at
//! every level (compression cannot be hoisted; §3.1.2 applies).
//!
//! - `Plain`: raw partials, folded straight from the wire.
//! - `Cprp2p`/`CColl`: blocking compress → send per up-link.
//! - `Zccl`: the up-link compression runs PIPE-fZ-light and polls the
//!   outstanding child receives between chunks (the computation-framework
//!   overlap, same as the ring reduce-scatter).
//!
//! Child partials are consumed through the **fused decompress–reduce**
//! kernel ([`crate::compress::Compressor::decompress_fold_into`]): each
//! child's frame folds straight into the local accumulator with no
//! intermediate vector, timed as [`Phase::DecompressReduce`].

use super::ctx::CollState;
use super::{f32s_to_bytes_into, fold_f32_bytes, Algo, Communicator, Mode, ReduceOp};
use crate::analysis::plan::TreePlan;
use crate::coordinator::{Metrics, Phase};
use crate::topology::binomial_bcast;
use crate::{Error, Result};

/// Reduce `input` elementwise onto `root`; root returns `Some(result)`.
///
/// Compatibility shim: builds a transient codec per call. Iterated
/// callers should use [`super::CollCtx::reduce`].
pub fn reduce(
    comm: &mut Communicator,
    input: &[f32],
    op: ReduceOp,
    root: usize,
    mode: &Mode,
    m: &mut Metrics,
) -> Result<Option<Vec<f32>>> {
    let mut st = CollState::new(*mode);
    reduce_with(comm, &mut st, input, op, root, m)
}

/// [`reduce`] against a persistent [`CollState`] (codec built once).
pub(crate) fn reduce_with(
    comm: &mut Communicator,
    st: &mut CollState,
    input: &[f32],
    op: ReduceOp,
    root: usize,
    m: &mut Metrics,
) -> Result<Option<Vec<f32>>> {
    let n = comm.size();
    if root >= n {
        return Err(Error::invalid(format!("root {root} out of {n}")));
    }
    if st.mode.algo == Algo::Hier {
        return super::hier::reduce_hier(comm, st, input, op, root, m);
    }
    reduce_impl(comm, st, input, op, root, n, m)
}

/// The flat binomial reduce with an explicit `finish_n`: the divisor
/// handed to [`ReduceOp::finish`] at the root. Flat callers pass the
/// communicator size; the hierarchical leader tier runs this over the
/// leader group on node partials that already hold every member's
/// contribution, so it passes the **total** rank count (matters for
/// `Avg`).
pub(crate) fn reduce_impl(
    comm: &mut Communicator,
    st: &mut CollState,
    input: &[f32],
    op: ReduceOp,
    root: usize,
    finish_n: usize,
    m: &mut Metrics,
) -> Result<Option<Vec<f32>>> {
    let n = comm.size();
    let me = comm.rank();
    let mut acc = input.to_vec();
    if n == 1 {
        op.finish(&mut acc, finish_n);
        return Ok(Some(acc));
    }
    let plan = TreePlan::at(comm.fresh_tags(TreePlan::span(n)), n);
    let (parent_step, child_steps) = binomial_bcast(me, root, n);
    m.raw_bytes += (input.len() * 4) as u64;

    // Fold children (deepest subtree first = reverse round order). Every
    // child receive is posted up front, so while one child's partial is
    // being folded the other children's frames keep progressing — the
    // fused kernel's per-chunk hook polls the still-outstanding handles
    // (§3.5.2). The folds themselves stay in fixed reverse-round order:
    // folding in arrival order would make the result nondeterministic.
    let pipe = st.pipe.clone();
    let mut handles: Vec<crate::transport::RecvHandle> =
        child_steps.iter().rev().map(|s| comm.t.irecv(s.peer, plan.step_tag(s.round))).collect();
    let mut msg = comm.t.lease();
    for i in 0..handles.len() {
        let (h, rest) = handles[i..].split_first_mut().expect("index in range");
        let t0 = std::time::Instant::now();
        let mut backoff = crate::transport::Backoff::until(comm.t.timeout());
        while !comm.t.try_complete_into(h, &mut msg)? {
            backoff.snooze();
            if backoff.is_yielding() {
                comm.t.check_abort()?;
                if backoff.expired() {
                    return Err(Error::timeout(vec![(h.from, h.tag)]));
                }
            }
        }
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        m.bytes_recv += msg.len() as u64;
        match st.mode.algo {
            Algo::Plain => {
                let t0 = std::time::Instant::now();
                fold_f32_bytes(op, &msg, &mut acc)?;
                m.add(Phase::Compute, t0.elapsed().as_secs_f64());
            }
            _ => {
                let t0 = std::time::Instant::now();
                match &pipe {
                    // Same kernel as the resident codec's fused fold
                    // (both run `fzlight::decompress_fold_frame`, so the
                    // result is bit-identical) — but with a live hook
                    // pulling the remaining children's progress.
                    Some(p)
                        if crate::compress::peek_codec(&msg)?
                            == crate::compress::CompressorKind::FzLight =>
                    {
                        let tr = &mut *comm.t;
                        p.decompress_fold_into_with_progress(&msg, op, &mut acc, &mut |_| {
                            for nh in rest.iter_mut() {
                                let _ = tr.try_complete(nh);
                            }
                        })?;
                    }
                    _ => {
                        st.decode_fold_into(&msg, op, &mut acc)?;
                    }
                }
                m.add(Phase::DecompressReduce, t0.elapsed().as_secs_f64());
            }
        }
    }
    comm.t.recycle(msg);

    if me == root {
        op.finish(&mut acc, finish_n);
        return Ok(Some(acc));
    }

    // Send the partial up: serialise/compress straight into a
    // transport-leased wire buffer and hand it over by value — the
    // up-link frame is built once and sent once, with no packet_from
    // copy.
    let step = parent_step.expect("non-root has a parent");
    let tag = plan.step_tag(step.round);
    let mut wire = comm.t.lease();
    match st.mode.algo {
        Algo::Plain => f32s_to_bytes_into(&acc, &mut wire),
        _ => {
            let t0 = std::time::Instant::now();
            match &st.pipe {
                // All child receives are drained by now, but other
                // traffic (concurrent nonblocking requests, later
                // collectives' early arrivals) may be sitting in the
                // transport: the hook pulls transport-wide progress
                // between chunks instead of polling nothing.
                Some(p) => {
                    let tr = &mut *comm.t;
                    p.compress_into_with_progress(&acc, st.mode.eb, &mut wire, &mut |_| {
                        let _ = tr.progress();
                    })?;
                }
                None => {
                    st.codec.compress_into(&acc, st.mode.eb, &mut wire)?;
                }
            }
            st.compress_calls += 1; // direct codec calls bypass compress_into
            m.add(Phase::Compress, t0.elapsed().as_secs_f64());
        }
    }
    let t0 = std::time::Instant::now();
    m.bytes_sent += wire.len() as u64;
    comm.t.send_pooled(step.peer, tag, wire)?;
    m.add(Phase::Comm, t0.elapsed().as_secs_f64());
    Ok(None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::run_ranks;
    use crate::compress::{CompressorKind, ErrorBound};
    use crate::data::fields::{Field, FieldKind};

    fn rank_input(rank: usize, len: usize) -> Vec<f32> {
        Field::generate(FieldKind::Rtm, len, 60 + rank as u64).values
    }

    fn serial(n: usize, len: usize, op: ReduceOp) -> Vec<f32> {
        let mut acc = rank_input(0, len);
        for r in 1..n {
            op.fold(&mut acc, &rank_input(r, len));
        }
        op.finish(&mut acc, n);
        acc
    }

    #[test]
    fn plain_matches_serial() {
        for n in [2usize, 5, 8] {
            let out = run_ranks(n, move |c| {
                let mut m = Metrics::default();
                reduce(c, &rank_input(c.rank(), 512), ReduceOp::Sum, 0, &Mode::plain(), &mut m)
                    .unwrap()
            });
            let want = serial(n, 512, ReduceOp::Sum);
            let got = out[0].as_ref().unwrap();
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-3, "n={n}: {a} vs {b}");
            }
            assert!(out[1..].iter().all(Option::is_none));
        }
    }

    #[test]
    fn zccl_sum_bounded_by_tree_depth() {
        let n = 8;
        let eb = 1e-3f64;
        let out = run_ranks(n, move |c| {
            let mut m = Metrics::default();
            reduce(
                c,
                &rank_input(c.rank(), 4096),
                ReduceOp::Sum,
                0,
                &Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(eb)),
                &mut m,
            )
            .unwrap()
        });
        let want = serial(n, 4096, ReduceOp::Sum);
        let got = out[0].as_ref().unwrap();
        // Each of the n-1 up-links injects at most ê into the sum chain.
        let tol = (n as f64) * eb * 1.01 + 1e-5;
        for (a, b) in got.iter().zip(&want) {
            assert!(((a - b).abs() as f64) <= tol);
        }
    }

    #[test]
    fn avg_and_max() {
        let n = 4;
        for op in [ReduceOp::Avg, ReduceOp::Max] {
            let out = run_ranks(n, move |c| {
                let mut m = Metrics::default();
                reduce(c, &rank_input(c.rank(), 300), op, 1, &Mode::plain(), &mut m).unwrap()
            });
            let want = serial(n, 300, op);
            let got = out[1].as_ref().unwrap();
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4);
            }
        }
    }
}
