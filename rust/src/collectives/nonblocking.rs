//! Nonblocking (`icollective`) state machines: persistent request
//! handles over the same schedules, buffers and kernels as the blocking
//! collectives.
//!
//! ## Model
//!
//! [`super::CollCtx::iallreduce`] / [`super::CollCtx::iallgather`] /
//! [`super::CollCtx::ireduce_scatter`] / [`super::CollCtx::ibcast`]
//! *start* an operation: they reserve the operation's whole tag slice up
//! front (so concurrent requests can never cross-match messages), post
//! the first receives, and park a resumable [`Machine`] in the context's
//! [`super::progress::ProgressEngine`]. The returned [`CollRequest`] is a
//! lightweight handle; the data plane lives in the engine.
//!
//! Progress is **cooperative** — there is no progress thread, because
//! the transport endpoint is exclusively owned by the rank's
//! communicator. Every [`super::CollCtx::test`] and
//! [`super::CollCtx::wait`] steps *all* in-flight machines: each machine
//! makes maximal progress (compress, send, fold) and yields only on an
//! un-arrived receive, which it re-polls on the next step via
//! [`crate::transport::Transport::try_complete_into`]. Interleaving
//! compute with `test()` calls is exactly the §3.5.2 "pull communication
//! progress inside compute" discipline, lifted from inside one
//! collective to the application loop.
//!
//! ## Equivalence to the blocking calls
//!
//! Each machine performs the *same data operations in the same order* as
//! its blocking twin — same tag layout, same compress inputs, same fold
//! order, same pooled-buffer discipline — so its result is **bit
//! identical** to the blocking call on the same inputs (the
//! `nonblocking` integration tests pin this across modes, rank counts
//! and shapes). Only the waiting is rearranged. SPMD contract: all ranks
//! must *start* the same requests in the same order; they may then
//! `test`/`wait` them in any order, because every step drives every
//! in-flight machine.
//!
//! Per-phase codec timings are not attributed by the machines; instead
//! the context splits wall time into *hidden* communication (spent
//! inside `test`, overlapped with the caller's compute) and *exposed*
//! communication (spent blocked in `wait`) — see
//! [`crate::coordinator::Metrics::note_hidden_comm`] /
//! [`crate::coordinator::Metrics::note_exposed_comm`].
//!
//! `Hier` requests with a dedicated two-level schedule (allreduce,
//! allgather, bcast) complete eagerly through the blocking hierarchical
//! path at start; flat-fallback cases (reduce-scatter) run the normal
//! machine, mirroring the blocking dispatch.

use std::ops::Range;

use super::ctx::CollState;
use super::progress::RecvSlot;
use super::{
    bytes_to_f32s_into_slice, chunk_ranges, f32s_to_bytes_into, fold_f32_bytes, segment_count,
    send_segmented, Algo, Communicator, ReduceOp,
};
use crate::analysis::plan::{AllgatherPlan, RingPlan, TreePlan};
use crate::coordinator::Metrics;
use crate::topology::{binomial_bcast, ring, ring_recv_chunk, ring_send_chunk, TreeStep};
use crate::{Error, Result};

/// Handle to an in-flight nonblocking collective started on a
/// [`super::CollCtx`]. Poll with [`super::CollCtx::test`], complete with
/// [`super::CollCtx::wait`] / [`super::CollCtx::wait_into`].
#[must_use = "complete the request with CollCtx::wait()/wait_into()"]
#[derive(Debug)]
pub struct CollRequest {
    pub(crate) slot: usize,
    pub(crate) gen: u64,
}

/// A completed collective's result: the values, plus — for
/// reduce-scatter — the range of the logical vector they cover.
#[derive(Debug, Clone, PartialEq)]
pub struct CollOutput {
    /// The operation's output values (full vector for allreduce /
    /// allgather / bcast; the owned chunk for reduce-scatter).
    pub values: Vec<f32>,
    /// For reduce-scatter: the owned chunk's range of the logical
    /// result. `None` for the whole-vector collectives.
    pub range: Option<Range<usize>>,
}

/// One in-flight operation's resumable schedule. Stepped by the
/// [`super::progress::ProgressEngine`]; boxed so the engine slab stays
/// small.
pub(crate) enum Machine {
    ReduceScatter(Box<ReduceScatterSm>),
    Allgather(Box<AllgatherSm>),
    Allreduce(Box<AllreduceSm>),
    Bcast(Box<BcastSm>),
}

impl Machine {
    /// Make maximal progress; `Some(out)` when the operation completed.
    pub(crate) fn step(
        &mut self,
        comm: &mut Communicator,
        st: &mut CollState,
        m: &mut Metrics,
    ) -> Result<Option<CollOutput>> {
        match self {
            Machine::ReduceScatter(sm) => sm.step(comm, st, m),
            Machine::Allgather(sm) => sm.step(comm, st, m),
            Machine::Allreduce(sm) => sm.step(comm, st, m),
            Machine::Bcast(sm) => sm.step(comm, st, m),
        }
    }

    /// The `(source rank, tag)` receives this operation is parked on —
    /// what a deadline-expired `wait` reports in
    /// [`crate::Error::Timeout`]. Each machine yields on at most one
    /// outstanding receive, so this is its un-arrived slot's origin.
    pub(crate) fn pending(&self) -> Vec<(usize, u64)> {
        let slot = match self {
            Machine::ReduceScatter(sm) => &sm.slot,
            Machine::Allgather(sm) => &sm.slot,
            Machine::Allreduce(sm) => match &sm.stage {
                ArStage::Rs(rs) => &rs.slot,
                ArStage::Ag(ag) => &ag.slot,
            },
            Machine::Bcast(sm) => &sm.slot,
        };
        slot.as_ref().and_then(|s| s.pending_origin()).into_iter().collect()
    }
}

// ---------------------------------------------------------------------
// Reduce-scatter
// ---------------------------------------------------------------------

/// Resumable ring reduce-scatter — the nonblocking twin of
/// [`super::reduce_scatter::reduce_scatter_with`]. Rounds are inherently
/// sequential (round `t`'s fold produces round `t+1`'s compress input),
/// so the machine runs one round at a time, yielding only while that
/// round's partial has not arrived. Under ZCCL the per-round compression
/// polls the posted receive between PIPE chunks, and the fused fold
/// pulls transport-wide progress (§3.5.2) — the same overlap as the
/// blocking path, now also serving concurrent requests.
pub(crate) struct ReduceScatterSm {
    plan: RingPlan,
    op: ReduceOp,
    ranges: Vec<Range<usize>>,
    /// Pooled accumulator, seeded with this rank's input.
    acc: Vec<f32>,
    /// Next round to complete (fold).
    round: usize,
    /// Outstanding receive for `round`; `Some` once the round's frame
    /// has been compressed and sent.
    slot: Option<RecvSlot>,
}

impl ReduceScatterSm {
    /// Seed the accumulator and account the schedule's raw traffic. The
    /// caller has already reserved [`RingPlan::span`] tags at the plan's
    /// base.
    pub(crate) fn new(
        comm: &Communicator,
        st: &mut CollState,
        m: &mut Metrics,
        input: &[f32],
        op: ReduceOp,
        plan: RingPlan,
    ) -> ReduceScatterSm {
        let n = comm.size();
        let mut acc = st.pool.take_f32();
        acc.extend_from_slice(input);
        m.raw_bytes += (input.len() * 4) as u64 * (n as u64 - 1) / n as u64 * 2;
        ReduceScatterSm {
            plan,
            op,
            ranges: chunk_ranges(input.len(), n),
            acc,
            round: 0,
            slot: None,
        }
    }

    fn step(
        &mut self,
        comm: &mut Communicator,
        st: &mut CollState,
        m: &mut Metrics,
    ) -> Result<Option<CollOutput>> {
        let n = comm.size();
        let me = comm.rank();
        let nb = ring(me, n);
        while self.round < n - 1 {
            let t = self.round;
            let s = self.ranges[ring_send_chunk(me, t, n)].clone();
            let r = self.ranges[ring_recv_chunk(me, t, n)].clone();
            let tag = self.plan.round_tag(t);
            if self.slot.is_none() {
                // Begin the round: post the receive BEFORE compressing,
                // poll it from inside the compression loop, then send.
                self.slot = Some(RecvSlot::post(comm.t, nb.prev, tag));
                let mut frame = comm.t.lease();
                match st.mode.algo {
                    Algo::Plain => f32s_to_bytes_into(&self.acc[s.clone()], &mut frame),
                    _ => match st.pipe.clone() {
                        Some(p) => {
                            let (h, buf, done) = self.slot.as_mut().unwrap().parts();
                            let tr = &mut *comm.t;
                            p.compress_into_with_progress(
                                &self.acc[s.clone()],
                                st.mode.eb,
                                &mut frame,
                                &mut |_| {
                                    if !*done && tr.try_complete_into(h, buf).unwrap_or(false) {
                                        *done = true;
                                    }
                                },
                            )?;
                            st.compress_calls += 1; // PIPE bypasses compress_into
                        }
                        None => {
                            st.compress_into(&self.acc[s.clone()], &mut frame)?;
                        }
                    },
                }
                m.bytes_sent += frame.len() as u64;
                comm.t.send_pooled(nb.next, tag, frame)?;
            }
            if !self.slot.as_mut().unwrap().poll(comm.t)? {
                return Ok(None); // yield: this round's partial not here yet
            }
            let got = self.slot.take().unwrap().into_buf();
            m.bytes_recv += got.len() as u64;
            match st.mode.algo {
                Algo::Plain => {
                    fold_f32_bytes(self.op, &got, &mut self.acc[r.clone()])?;
                }
                _ => match st.pipe.clone() {
                    Some(p) => {
                        // Fused decompress–reduce; between chunks the hook
                        // pulls transport-wide progress so concurrent
                        // requests' arrivals drain while we fold.
                        let tr = &mut *comm.t;
                        p.decompress_fold_into_with_progress(
                            &got,
                            self.op,
                            &mut self.acc[r.clone()],
                            &mut |_| {
                                let _ = tr.progress();
                            },
                        )?;
                    }
                    None => {
                        st.decode_fold_into(&got, self.op, &mut self.acc[r.clone()])?;
                    }
                },
            }
            comm.t.recycle(got);
            self.round += 1;
        }
        let own = (me + 1) % n;
        let range = self.ranges[own].clone();
        let mut owned = st.pool.take_f32();
        owned.extend_from_slice(&self.acc[range.clone()]);
        st.pool.put_f32(std::mem::take(&mut self.acc));
        Ok(Some(CollOutput { values: owned, range: Some(range) }))
    }
}

// ---------------------------------------------------------------------
// Allgather
// ---------------------------------------------------------------------

enum AgPhase {
    /// The 8-byte value-count ring (mirror of `exchange_sizes`).
    Counts,
    /// The compressed-size ring (`CColl`/`Zccl` only).
    Sizes,
    /// The N−1 data rounds.
    Rounds,
    /// Final placement decode (`Plain`/`CColl`/`Zccl`).
    Decode,
}

/// Resumable ring allgather — the nonblocking twin of
/// [`super::allgather::allgather_chunks_with`], including the allreduce
/// stage's chunk-ownership `shift`. Same [`AllgatherPlan`] tag layout
/// (counts ring, size ring, per-round segment fans), same segmented
/// receive behaviour, same decode-once-at-the-end placement discipline.
pub(crate) struct AllgatherSm {
    plan: AllgatherPlan,
    shift: usize,
    /// Pooled copy of this rank's contribution (returned to the pool at
    /// completion).
    my_chunk: Vec<f32>,
    /// Value counts, indexed by actual rank while the ring runs.
    counts: Vec<u64>,
    /// Compressed sizes: actual-rank order during the size ring, logical
    /// chunk order afterwards.
    sizes: Vec<u64>,
    offsets: Vec<usize>,
    /// Pooled output (every chunk's final window).
    out: Vec<f32>,
    chunks: Vec<Option<Vec<u8>>>,
    round: usize,
    /// Whether the current data round's send has been issued.
    round_sent: bool,
    /// Current data round's expected byte total and segment bookkeeping.
    total: usize,
    nseg: usize,
    seg_idx: usize,
    /// Multi-segment assembly buffer (leased while in use).
    asm: Vec<u8>,
    slot: Option<RecvSlot>,
    phase: AgPhase,
}

impl AllgatherSm {
    /// `my_chunk` is an owned (pooled) vector; the caller has already
    /// reserved [`AllgatherPlan::span`] tags at the plan's base.
    pub(crate) fn new(
        comm: &Communicator,
        st: &mut CollState,
        my_chunk: Vec<f32>,
        shift: usize,
        plan: AllgatherPlan,
    ) -> AllgatherSm {
        let n = comm.size();
        let mut counts = vec![0u64; n];
        counts[comm.rank()] = my_chunk.len() as u64;
        AllgatherSm {
            plan,
            shift,
            my_chunk,
            counts,
            sizes: Vec::new(),
            offsets: Vec::new(),
            out: st.pool.take_f32(),
            chunks: Vec::new(),
            round: 0,
            round_sent: false,
            total: 0,
            nseg: 0,
            seg_idx: 0,
            asm: Vec::new(),
            slot: None,
            phase: AgPhase::Counts,
        }
    }

    /// One step of an 8-byte u64 ring exchange over `vals` (indexed by
    /// actual rank). `Ok(Some(true))` = ring finished, `Ok(Some(false))`
    /// = one round advanced, `Ok(None)` = waiting.
    fn ring_u64_step(
        &mut self,
        comm: &mut Communicator,
        tag_base: u64,
        sizes_ring: bool,
    ) -> Result<Option<bool>> {
        let n = comm.size();
        let me = comm.rank();
        let nb = ring(me, n);
        if self.round == n - 1 {
            return Ok(Some(true));
        }
        let tag = tag_base + self.round as u64;
        if self.slot.is_none() {
            let vals = if sizes_ring { &self.sizes } else { &self.counts };
            let v = vals[ring_send_chunk(me, self.round, n)];
            comm.t.send(nb.next, tag, &v.to_le_bytes())?;
            self.slot = Some(RecvSlot::post(comm.t, nb.prev, tag));
        }
        if !self.slot.as_mut().unwrap().poll(comm.t)? {
            return Ok(None);
        }
        let slot = self.slot.take().unwrap();
        let v = u64::from_le_bytes(
            slot.buf
                .as_slice()
                .try_into()
                .map_err(|_| Error::corrupt("size exchange message must be 8 bytes"))?,
        );
        slot.recycle(comm.t);
        let vals = if sizes_ring { &mut self.sizes } else { &mut self.counts };
        vals[ring_recv_chunk(me, self.round, n)] = v;
        self.round += 1;
        Ok(Some(false))
    }

    /// Counts are in: size the output, prepare this rank's chunk, and
    /// dispatch to the mode's round structure.
    fn setup(
        &mut self,
        comm: &mut Communicator,
        st: &mut CollState,
        m: &mut Metrics,
    ) -> Result<()> {
        let n = comm.size();
        let me = comm.rank();
        let vrank = me + self.shift;
        let own = vrank % n;
        let mut counts = vec![0u64; n];
        for (r, c) in self.counts.iter().enumerate() {
            counts[(r + self.shift) % n] = *c;
        }
        m.raw_bytes += counts.iter().map(|&c| c * 4).sum::<u64>();
        self.offsets.clear();
        self.offsets.reserve(n + 1);
        self.offsets.push(0);
        for &c in &counts {
            self.offsets.push(self.offsets.last().unwrap() + c as usize);
        }
        self.out.resize(self.offsets[n], 0.0);
        self.chunks = vec![None; n];
        self.round = 0;
        match st.mode.algo {
            Algo::Plain => {
                let mut mine = st.pool.take_bytes();
                f32s_to_bytes_into(&self.my_chunk, &mut mine);
                self.chunks[own] = Some(mine);
                self.phase = AgPhase::Rounds;
            }
            Algo::Cprp2p => {
                // The output doubles as the between-rounds store.
                self.out[window(&self.offsets, own)].copy_from_slice(&self.my_chunk);
                self.phase = AgPhase::Rounds;
            }
            Algo::CColl | Algo::Zccl => {
                // Compress the local chunk exactly once, then learn every
                // compressed size before the data rounds.
                let mut mine = st.pool.take_bytes();
                st.compress_into(&self.my_chunk, &mut mine)?;
                self.sizes = vec![0u64; n];
                self.sizes[me] = mine.len() as u64;
                self.chunks[own] = Some(mine);
                self.phase = AgPhase::Sizes;
            }
            Algo::Hier => unreachable!("hier iallgather completes via the blocking fallback"),
        }
        Ok(())
    }

    fn step(
        &mut self,
        comm: &mut Communicator,
        st: &mut CollState,
        m: &mut Metrics,
    ) -> Result<Option<CollOutput>> {
        let n = comm.size();
        let me = comm.rank();
        let nb = ring(me, n);
        let vrank = me + self.shift;
        loop {
            match self.phase {
                AgPhase::Counts => match self.ring_u64_step(comm, self.plan.counts_ring().base, false)? {
                    None => return Ok(None),
                    Some(false) => {}
                    Some(true) => self.setup(comm, st, m)?,
                },
                AgPhase::Sizes => match self.ring_u64_step(comm, self.plan.sizes_ring().base, true)? {
                    None => return Ok(None),
                    Some(false) => {}
                    Some(true) => {
                        // Actual-rank order → logical chunk order (the
                        // blocking path's `(r + vrank - me) % n` remap).
                        let mut logical = vec![0u64; n];
                        for (r, s) in self.sizes.iter().enumerate() {
                            logical[(r + self.shift) % n] = *s;
                        }
                        self.sizes = logical;
                        self.round = 0;
                        self.phase = AgPhase::Rounds;
                    }
                },
                AgPhase::Rounds => {
                    if self.round == n - 1 {
                        if st.mode.algo == Algo::Cprp2p {
                            // Decoded per round; the output is complete.
                            return Ok(Some(self.finish(st)));
                        }
                        self.phase = AgPhase::Decode;
                        continue;
                    }
                    let t = self.round;
                    let s = ring_send_chunk(vrank, t, n);
                    let r = ring_recv_chunk(vrank, t, n);
                    let tag = self.plan.round_tag(t);
                    if st.mode.algo == Algo::Cprp2p {
                        if !self.round_sent {
                            let mut frame = comm.t.lease();
                            st.compress_into(&self.out[window(&self.offsets, s)], &mut frame)?;
                            m.bytes_sent += frame.len() as u64;
                            comm.t.send_pooled(nb.next, tag, frame)?;
                            self.slot = Some(RecvSlot::post(comm.t, nb.prev, tag));
                            self.round_sent = true;
                        }
                        if !self.slot.as_mut().unwrap().poll(comm.t)? {
                            return Ok(None);
                        }
                        let got = self.slot.take().unwrap().into_buf();
                        m.bytes_recv += got.len() as u64;
                        st.decode_into_slice(&got, &mut self.out[window(&self.offsets, r)])
                            .map_err(|e| Error::corrupt(format!("cprp2p chunk {r}: {e}")))?;
                        comm.t.recycle(got);
                        self.round += 1;
                        self.round_sent = false;
                        continue;
                    }
                    // Plain / CColl / Zccl: forward a stored chunk, receive
                    // the next one (segmented under ZCCL's fixed pipeline).
                    let seg = if st.mode.algo == Algo::Zccl {
                        st.mode.pipeline_bytes
                    } else {
                        usize::MAX
                    };
                    if !self.round_sent {
                        let send_buf = self.chunks[s].as_ref().expect("ring schedule owns chunk");
                        m.bytes_sent += send_segmented(comm.t, nb.next, tag, send_buf, seg)?;
                        self.total = match st.mode.algo {
                            Algo::Plain => window(&self.offsets, r).len() * 4,
                            _ => self.sizes[r] as usize,
                        };
                        self.nseg = segment_count(self.total, seg)?;
                        self.seg_idx = 0;
                        if self.nseg > 1 {
                            self.asm = comm.t.lease();
                            self.asm.clear();
                            self.asm.reserve(self.total);
                        }
                        self.slot = Some(RecvSlot::post(comm.t, nb.prev, tag));
                        self.round_sent = true;
                    }
                    if !self.slot.as_mut().unwrap().poll(comm.t)? {
                        return Ok(None);
                    }
                    let got = if self.nseg == 1 {
                        // Single segment: the payload arrived by buffer
                        // swap — it IS the chunk.
                        self.slot.take().unwrap().into_buf()
                    } else {
                        let slot = self.slot.take().unwrap();
                        self.asm.extend_from_slice(&slot.buf);
                        slot.recycle(comm.t);
                        self.seg_idx += 1;
                        if self.seg_idx < self.nseg {
                            self.slot = Some(RecvSlot::post(
                                comm.t,
                                nb.prev,
                                tag + self.seg_idx as u64,
                            ));
                            continue;
                        }
                        std::mem::take(&mut self.asm)
                    };
                    if got.len() != self.total {
                        return Err(Error::corrupt(format!(
                            "segmented recv got {} of {} bytes",
                            got.len(),
                            self.total
                        )));
                    }
                    m.bytes_recv += got.len() as u64;
                    self.chunks[r] = Some(got);
                    self.round += 1;
                    self.round_sent = false;
                }
                AgPhase::Decode => {
                    let own = vrank % n;
                    for (r, c) in std::mem::take(&mut self.chunks).into_iter().enumerate() {
                        let buf = c.expect("all chunks gathered");
                        match st.mode.algo {
                            Algo::Plain => {
                                bytes_to_f32s_into_slice(
                                    &buf,
                                    &mut self.out[window(&self.offsets, r)],
                                )?;
                            }
                            _ => {
                                st.decode_into_slice(&buf, &mut self.out[window(&self.offsets, r)])
                                    .map_err(|e| Error::corrupt(format!("zccl chunk {r}: {e}")))?;
                            }
                        }
                        if r == own {
                            st.pool.put_bytes(buf);
                        } else {
                            comm.t.recycle(buf);
                        }
                    }
                    return Ok(Some(self.finish(st)));
                }
            }
        }
    }

    fn finish(&mut self, st: &mut CollState) -> CollOutput {
        st.pool.put_f32(std::mem::take(&mut self.my_chunk));
        CollOutput { values: std::mem::take(&mut self.out), range: None }
    }
}

/// The final window of logical chunk `r` in the output.
fn window(offsets: &[usize], r: usize) -> Range<usize> {
    offsets[r]..offsets[r + 1]
}

// ---------------------------------------------------------------------
// Allreduce
// ---------------------------------------------------------------------

enum ArStage {
    Rs(ReduceScatterSm),
    Ag(AllgatherSm),
}

/// Resumable ring allreduce — reduce-scatter then shift-1 allgather,
/// composed exactly like [`super::allreduce::allreduce_with`]. Both
/// stages' tag slices are reserved at start, so the stage transition
/// needs no communicator access beyond what the machines already hold.
pub(crate) struct AllreduceSm {
    op: ReduceOp,
    ag_plan: AllgatherPlan,
    stage: ArStage,
}

impl AllreduceSm {
    pub(crate) fn new(op: ReduceOp, ag_plan: AllgatherPlan, rs: ReduceScatterSm) -> AllreduceSm {
        AllreduceSm { op, ag_plan, stage: ArStage::Rs(rs) }
    }

    fn step(
        &mut self,
        comm: &mut Communicator,
        st: &mut CollState,
        m: &mut Metrics,
    ) -> Result<Option<CollOutput>> {
        if let ArStage::Rs(sm) = &mut self.stage {
            match sm.step(comm, st, m)? {
                None => return Ok(None),
                Some(mut rs_out) => {
                    self.op.finish(&mut rs_out.values, comm.size());
                    let ag = AllgatherSm::new(comm, st, rs_out.values, 1, self.ag_plan);
                    self.stage = ArStage::Ag(ag);
                }
            }
        }
        match &mut self.stage {
            ArStage::Ag(sm) => sm.step(comm, st, m),
            ArStage::Rs(_) => unreachable!("reduce-scatter stage handled above"),
        }
    }
}

// ---------------------------------------------------------------------
// Bcast
// ---------------------------------------------------------------------

/// Resumable binomial-tree broadcast — the nonblocking twin of
/// [`super::bcast::bcast_with`]. The root's work (compress once, eager
/// sends, decode) is entirely send-side and completes on its first step;
/// a non-root rank has exactly one yield point: its parent's frame.
pub(crate) struct BcastSm {
    plan: TreePlan,
    /// Pooled copy of the payload (root only).
    data: Option<Vec<f32>>,
    recv_step: Option<TreeStep>,
    send_steps: Vec<TreeStep>,
    /// Posted at start (non-root).
    slot: Option<RecvSlot>,
}

impl BcastSm {
    /// The caller has validated root/data and reserved
    /// [`TreePlan::span`] tags at the plan's base; `data` is a pooled
    /// copy, `Some` exactly at the root. Posts the parent receive
    /// immediately.
    pub(crate) fn new(
        comm: &mut Communicator,
        plan: TreePlan,
        root: usize,
        data: Option<Vec<f32>>,
    ) -> BcastSm {
        let (recv_step, send_steps) = binomial_bcast(comm.rank(), root, comm.size());
        let slot = recv_step
            .as_ref()
            .filter(|_| data.is_none())
            .map(|s| RecvSlot::post(comm.t, s.peer, plan.step_tag(s.round)));
        BcastSm { plan, data, recv_step, send_steps, slot }
    }

    fn step(
        &mut self,
        comm: &mut Communicator,
        st: &mut CollState,
        m: &mut Metrics,
    ) -> Result<Option<CollOutput>> {
        if let Some(d) = self.data.take() {
            // Root: compress/serialise once, eager-send to every child,
            // decode exactly what was sent (so all ranks agree bitwise).
            m.raw_bytes += (d.len() * 4) as u64;
            let values = match st.mode.algo {
                Algo::Plain => {
                    let mut b = st.pool.take_bytes();
                    f32s_to_bytes_into(&d, &mut b);
                    for s in &self.send_steps {
                        comm.t.send(s.peer, self.plan.step_tag(s.round), &b)?;
                        m.bytes_sent += b.len() as u64;
                    }
                    st.pool.put_bytes(b);
                    // The wire form round-trips f32s exactly: the decoded
                    // payload is bit-identical to `d`.
                    d
                }
                Algo::Cprp2p => {
                    for s in &self.send_steps {
                        let mut frame = comm.t.lease();
                        st.compress_into(&d, &mut frame)?;
                        m.bytes_sent += frame.len() as u64;
                        comm.t.send_pooled(s.peer, self.plan.step_tag(s.round), frame)?;
                    }
                    d
                }
                _ => {
                    let mut frame = st.pool.take_bytes();
                    st.compress_into(&d, &mut frame)?;
                    for s in &self.send_steps {
                        comm.t.send(s.peer, self.plan.step_tag(s.round), &frame)?;
                        m.bytes_sent += frame.len() as u64;
                    }
                    // Every rank returns the decompressed frame, the root
                    // included — MPI-consistent and bit-identical to the
                    // blocking call.
                    let cnt = crate::compress::checked_count(&frame)?;
                    let mut out = st.pool.take_f32();
                    out.resize(cnt, 0.0);
                    st.decode_into_slice(&frame, &mut out)?;
                    st.pool.put_bytes(frame);
                    st.pool.put_f32(d);
                    out
                }
            };
            return Ok(Some(CollOutput { values, range: None }));
        }
        // Non-root: wait for the parent's frame, forward, decode.
        let slot = self.slot.as_mut().expect("non-root bcast has a posted receive");
        if !slot.poll(comm.t)? {
            return Ok(None);
        }
        let got = self.slot.take().unwrap().into_buf();
        m.bytes_recv += got.len() as u64;
        debug_assert!(self.recv_step.is_some());
        let values = match st.mode.algo {
            Algo::Plain => {
                for s in &self.send_steps {
                    comm.t.send(s.peer, self.plan.step_tag(s.round), &got)?;
                    m.bytes_sent += got.len() as u64;
                }
                let mut out = st.pool.take_f32();
                out.resize(got.len() / 4, 0.0);
                bytes_to_f32s_into_slice(&got, &mut out)?;
                comm.t.recycle(got);
                out
            }
            Algo::Cprp2p => {
                let cnt = crate::compress::checked_count(&got)?;
                let mut out = st.pool.take_f32();
                out.resize(cnt, 0.0);
                st.decode_into_slice(&got, &mut out)?;
                comm.t.recycle(got);
                // Re-compress for every forward — the CPRP2P pathology.
                for s in &self.send_steps {
                    let mut frame = comm.t.lease();
                    st.compress_into(&out, &mut frame)?;
                    m.bytes_sent += frame.len() as u64;
                    comm.t.send_pooled(s.peer, self.plan.step_tag(s.round), frame)?;
                }
                out
            }
            _ => {
                // Forward the frame verbatim BEFORE decoding, so children
                // are not delayed behind our decompression.
                for s in &self.send_steps {
                    comm.t.send(s.peer, self.plan.step_tag(s.round), &got)?;
                    m.bytes_sent += got.len() as u64;
                }
                let cnt = crate::compress::checked_count(&got)?;
                let mut out = st.pool.take_f32();
                out.resize(cnt, 0.0);
                st.decode_into_slice(&got, &mut out)?;
                comm.t.recycle(got);
                out
            }
        };
        Ok(Some(CollOutput { values, range: None }))
    }
}
