//! Ring allreduce = reduce-scatter + allgather (§3.5, "Z-Allreduce").
//!
//! The composition is the paper's flagship: the reduce-scatter stage uses
//! the collective *computation* framework (PIPE overlap), the allgather
//! stage uses the collective *data movement* framework (compress-once +
//! balanced pipeline). Per-rank traffic is `2(N−1)/N · D` — bandwidth
//! optimal — and compression shrinks the constant.

use super::allgather::allgather_chunks_with;
use super::ctx::CollState;
use super::reduce_scatter::reduce_scatter_with;
use super::{Communicator, Mode, ReduceOp};
use crate::coordinator::Metrics;
use crate::Result;

/// Elementwise-reduce `input` across all ranks; every rank returns the
/// full reduced vector (identical on all ranks up to compression error).
///
/// Compatibility shim: builds a transient codec + pool per call. Iterated
/// callers should use [`super::CollCtx::allreduce`] /
/// [`super::CollCtx::allreduce_into`].
pub fn allreduce(
    comm: &mut Communicator,
    input: &[f32],
    op: ReduceOp,
    mode: &Mode,
    m: &mut Metrics,
) -> Result<Vec<f32>> {
    let mut st = CollState::new(*mode);
    let mut out = Vec::with_capacity(input.len());
    allreduce_with(comm, &mut st, input, op, m, &mut out)?;
    Ok(out)
}

/// [`allreduce`] against a persistent [`CollState`], writing the reduced
/// vector into `out` (overwritten; capacity reused across iterations).
pub(crate) fn allreduce_with(
    comm: &mut Communicator,
    st: &mut CollState,
    input: &[f32],
    op: ReduceOp,
    m: &mut Metrics,
    out: &mut Vec<f32>,
) -> Result<()> {
    let n = comm.size();
    if n == 1 {
        out.clear();
        out.extend_from_slice(input);
        op.finish(out, 1);
        return Ok(());
    }
    if st.mode.algo == super::Algo::Hier {
        // Two-level schedule: intra-node raw reduce → inter-leader
        // compressed ring reduce-scatter/allgather → intra-node raw bcast.
        return super::hier::allreduce_hier(comm, st, input, op, m, out);
    }
    // Stage 1: reduce-scatter (collective computation framework). Rank r
    // ends up owning fully-reduced chunk (r+1) mod n. The owned chunk
    // lives in pooled scratch so iterated calls reuse it. On error paths
    // pooled buffers are simply dropped (the crate-wide policy — a failed
    // collective leaves the communicator unusable anyway).
    let mut owned = st.pool.take_f32();
    reduce_scatter_with(comm, st, input, op, m, &mut owned)?;
    op.finish(&mut owned, n);

    // Stage 2: allgather of the owned chunks (collective data movement
    // framework), with ownership shifted by one.
    allgather_chunks_with(comm, st, &owned, 1, m, out)?;
    st.pool.put_f32(owned);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::run_ranks;
    use crate::compress::{CompressorKind, ErrorBound};
    use crate::data::fields::{Field, FieldKind};

    fn rank_input(rank: usize, len: usize) -> Vec<f32> {
        Field::generate(FieldKind::Nyx, len, 900 + rank as u64).values
    }

    fn serial(n: usize, len: usize, op: ReduceOp) -> Vec<f32> {
        let mut acc = rank_input(0, len);
        for r in 1..n {
            op.fold(&mut acc, &rank_input(r, len));
        }
        op.finish(&mut acc, n);
        acc
    }

    #[test]
    fn plain_matches_serial() {
        for n in [2usize, 3, 4, 7] {
            let len = 999;
            let out = run_ranks(n, move |c| {
                let mut m = Metrics::default();
                allreduce(c, &rank_input(c.rank(), len), ReduceOp::Sum, &Mode::plain(), &mut m)
                    .unwrap()
            });
            let want = serial(n, len, ReduceOp::Sum);
            for o in &out {
                for (a, b) in o.iter().zip(&want) {
                    assert!((a - b).abs() < 1e-4, "n={n}: {a} vs {b}");
                }
            }
            // Exact agreement across ranks (identical fold order).
            for o in &out[1..] {
                assert_eq!(o, &out[0]);
            }
        }
    }

    #[test]
    fn zccl_sum_bounded() {
        // End-to-end error: RS chain accumulates <= (n-1)ê, the allgather
        // adds one more compression of the reduced chunk -> <= n·ê + ê.
        let (n, len) = (5, 5000);
        let eb = 1e-3f64;
        let out = run_ranks(n, move |c| {
            let mut m = Metrics::default();
            allreduce(
                c,
                &rank_input(c.rank(), len),
                ReduceOp::Sum,
                &Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(eb)),
                &mut m,
            )
            .unwrap()
        });
        let want = serial(n, len, ReduceOp::Sum);
        let tol = (n as f64 + 1.0) * eb * 1.01 + 1e-5;
        for o in out {
            assert_eq!(o.len(), len);
            for (a, b) in o.iter().zip(&want) {
                assert!(((a - b).abs() as f64) <= tol, "{a} vs {b} tol {tol}");
            }
        }
    }

    #[test]
    fn avg_scaling() {
        let (n, len) = (4, 512);
        let out = run_ranks(n, move |c| {
            let mut m = Metrics::default();
            allreduce(c, &rank_input(c.rank(), len), ReduceOp::Avg, &Mode::plain(), &mut m)
                .unwrap()
        });
        let want = serial(n, len, ReduceOp::Avg);
        for o in out {
            for (a, b) in o.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn max_with_zccl_is_bounded_by_single_eb_chainwise() {
        // Max/Min: each hop either keeps the local (uncompressed) value or
        // adopts a once-compressed one; the theoretical variance shrinks
        // (Theorem 2). Deterministically the error stays <= (n)·ê but in
        // practice is ~ê; assert the deterministic envelope.
        let (n, len) = (6, 2048);
        let eb = 1e-3f64;
        let out = run_ranks(n, move |c| {
            let mut m = Metrics::default();
            allreduce(
                c,
                &rank_input(c.rank(), len),
                ReduceOp::Max,
                &Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(eb)),
                &mut m,
            )
            .unwrap()
        });
        let want = serial(n, len, ReduceOp::Max);
        let tol = (n as f64 + 1.0) * eb + 1e-5;
        for o in out {
            for (a, b) in o.iter().zip(&want) {
                assert!(((a - b).abs() as f64) <= tol);
            }
        }
    }

    #[test]
    fn all_modes_close_to_serial() {
        let (n, len) = (4, 3000);
        let eb = 1e-4f64;
        let want = serial(n, len, ReduceOp::Sum);
        for mode in [
            Mode::plain(),
            Mode::cprp2p(CompressorKind::FzLight, ErrorBound::Abs(eb)),
            Mode::ccoll(ErrorBound::Abs(eb)),
            Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(eb)),
            Mode::zccl(CompressorKind::Szx, ErrorBound::Abs(eb)),
            Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(eb)).with_multithread(true),
        ] {
            let out = run_ranks(n, move |c| {
                let mut m = Metrics::default();
                allreduce(c, &rank_input(c.rank(), len), ReduceOp::Sum, &mode, &mut m).unwrap()
            });
            // CPRP2P re-compresses forwarded data, so its envelope is
            // larger; use the generous 2n·ê bound for all modes.
            let tol = 2.0 * (n as f64) * eb + 1e-5;
            for o in out {
                for (a, b) in o.iter().zip(&want) {
                    assert!(
                        ((a - b).abs() as f64) <= tol,
                        "mode {:?} kind {:?}: {a} vs {b}",
                        mode.algo,
                        mode.kind
                    );
                }
            }
        }
    }

    #[test]
    fn tiny_inputs_smaller_than_ranks() {
        // len < n: some chunks are empty.
        let (n, len) = (6, 4);
        let out = run_ranks(n, move |c| {
            let mut m = Metrics::default();
            allreduce(c, &rank_input(c.rank(), len), ReduceOp::Sum, &Mode::plain(), &mut m)
                .unwrap()
        });
        let want = serial(n, len, ReduceOp::Sum);
        for o in out {
            assert_eq!(o.len(), len);
            for (a, b) in o.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4);
            }
        }
    }
}
