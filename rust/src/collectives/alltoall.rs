//! All-to-all ("allscatter", §2.1.1): rank `r` sends its `j`-th chunk to
//! rank `j` and receives rank `j`'s `r`-th chunk, via the standard
//! pairwise-exchange schedule (`n-1` rounds, peer `(r ± t) mod n`).
//!
//! Data movement framework applies directly: every chunk crosses exactly
//! one link, so each is compressed once and decompressed once; ZCCL adds
//! the size pre-exchange so receives post exact buffers (balanced), while
//! CPRP2P sends opaque frames of unknown size.
//!
//! Receive side (parent module docs): every peer's chunk arrives into a
//! leased wire buffer, the frame headers size the output exactly once,
//! and each frame placement-decodes straight into its final window.

use super::ctx::CollState;
use super::{
    bytes_to_f32s_into_slice, chunk_ranges, exchange_sizes, f32s_to_bytes_into, Algo,
    Communicator, Mode,
};
use crate::analysis::plan::AlltoallPlan;
use crate::coordinator::{Metrics, Phase};
use crate::{Error, Result};

/// Exchange chunks: `input` is split into `n` chunks (chunk `j` goes to
/// rank `j`); the result concatenates the chunk received from every rank
/// in rank order.
///
/// Compatibility shim: builds a transient codec + pool per call. Iterated
/// callers should use [`super::CollCtx::alltoall`].
pub fn alltoall(
    comm: &mut Communicator,
    input: &[f32],
    mode: &Mode,
    m: &mut Metrics,
) -> Result<Vec<f32>> {
    let mut st = CollState::new(*mode);
    let mut out = Vec::new();
    alltoall_with(comm, &mut st, input, m, &mut out)?;
    Ok(out)
}

/// [`alltoall`] against a persistent [`CollState`]; `out` is overwritten.
pub(crate) fn alltoall_with(
    comm: &mut Communicator,
    st: &mut CollState,
    input: &[f32],
    m: &mut Metrics,
    out: &mut Vec<f32>,
) -> Result<()> {
    let n = comm.size();
    let me = comm.rank();
    if n == 1 {
        out.clear();
        out.extend_from_slice(input);
        return Ok(());
    }
    if st.mode.algo == Algo::Hier {
        return super::hier::alltoall_hier(comm, st, input, m, out);
    }
    let plan = AlltoallPlan::at(comm.fresh_tags(AlltoallPlan::span(n)), n);
    let sizes_tag = plan.sizes_ring().base;
    let ranges = chunk_ranges(input.len(), n);
    m.raw_bytes += (input.len() * 4) as u64;

    // Compress (or serialise) each outgoing chunk exactly once, into
    // transport-leased wire buffers: every peer's chunk is sent by value
    // (send_pooled — no packet_from copy) and our own stays resident for
    // the in-place decode below.
    let compresses = st.mode.compresses();
    let mut outgoing: Vec<Vec<u8>> = Vec::with_capacity(n);
    for r in ranges.iter() {
        let chunk = &input[r.clone()];
        let mut buf = comm.t.lease();
        if compresses {
            let t0 = std::time::Instant::now();
            st.compress_into(chunk, &mut buf)?;
            m.add(Phase::Compress, t0.elapsed().as_secs_f64());
        } else {
            f32s_to_bytes_into(chunk, &mut buf);
        }
        outgoing.push(buf);
    }

    // ZCCL balances with a size pre-exchange (8 bytes/rank; here we ship
    // each peer the size of ITS chunk during the pairwise rounds' tag-0
    // message, so reuse exchange_sizes for the total only).
    if matches!(st.mode.algo, Algo::Zccl | Algo::Hier) {
        let t0 = std::time::Instant::now();
        let _ = exchange_sizes(comm, outgoing[me].len() as u64, sizes_tag)?;
        m.add(Phase::Other, t0.elapsed().as_secs_f64());
    }

    let mut incoming: Vec<Option<Vec<u8>>> = vec![None; n];
    for t in 1..n {
        let to = (me + t) % n;
        let from = (me + n - t) % n;
        let t0 = std::time::Instant::now();
        let buf = std::mem::take(&mut outgoing[to]);
        m.bytes_sent += buf.len() as u64;
        comm.t.send_pooled(to, plan.pair_tag(t), buf)?;
        let mut got = comm.t.lease();
        comm.t.recv_into(from, plan.pair_tag(t), &mut got)?;
        m.bytes_recv += got.len() as u64;
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        incoming[from] = Some(got);
    }

    // Decode in rank order, each chunk straight into its final window.
    // Every rank's input may have a different length, so counts come from
    // the frame headers (compressed) or the byte count (plain); the
    // output is sized exactly once from them. Our own chunk decodes from
    // `outgoing` directly (no copy).
    let mut counts = Vec::with_capacity(n);
    for r in 0..n {
        let buf: &[u8] = if r == me {
            &outgoing[me]
        } else {
            incoming[r]
                .as_deref()
                .ok_or_else(|| Error::corrupt(format!("missing chunk from {r}")))?
        };
        counts.push(if compresses {
            // Bounds-checked against the frame's physical size: a corrupt
            // header must not size the output.
            crate::compress::checked_count(buf)?
        } else {
            buf.len() / 4
        });
    }
    // Plain `resize` (no prior clear): warm same-size iterations neither
    // shrink nor zero-fill, and every element is overwritten below.
    out.resize(counts.iter().sum(), 0.0);
    let mut off = 0usize;
    for r in 0..n {
        let buf: &[u8] = if r == me { &outgoing[me] } else { incoming[r].as_deref().unwrap() };
        let dst = &mut out[off..off + counts[r]];
        if compresses {
            let t0 = std::time::Instant::now();
            st.decode_into_slice(buf, dst)?;
            m.add(Phase::Decompress, t0.elapsed().as_secs_f64());
        } else {
            bytes_to_f32s_into_slice(buf, dst)?;
        }
        off += counts[r];
    }
    for buf in outgoing {
        // Only our own buffer still holds capacity (the others were moved
        // to the wire); recycling an emptied Vec is a no-op.
        comm.t.recycle(buf);
    }
    for buf in incoming.into_iter().flatten() {
        comm.t.recycle(buf);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::run_ranks;
    use crate::compress::{CompressorKind, ErrorBound};
    use crate::data::fields::{Field, FieldKind};

    fn rank_input(rank: usize, len: usize) -> Vec<f32> {
        Field::generate(FieldKind::Cesm, len, 2000 + rank as u64).values
    }

    /// Expected output at `rank`: chunk `rank` of every peer's input.
    fn expected(rank: usize, n: usize, len: usize) -> Vec<f32> {
        let ranges = chunk_ranges(len, n);
        (0..n)
            .flat_map(|src| rank_input(src, len)[ranges[rank].clone()].to_vec())
            .collect()
    }

    #[test]
    fn plain_exact() {
        for n in [2usize, 3, 5, 8] {
            let len = 1000;
            let out = run_ranks(n, move |c| {
                let mut m = Metrics::default();
                alltoall(c, &rank_input(c.rank(), len), &Mode::plain(), &mut m).unwrap()
            });
            for (rank, o) in out.into_iter().enumerate() {
                assert_eq!(o, expected(rank, n, len), "n={n} rank={rank}");
            }
        }
    }

    #[test]
    fn zccl_bounded() {
        let (n, len) = (5, 4000);
        let eb = 1e-3f64;
        let out = run_ranks(n, move |c| {
            let mut m = Metrics::default();
            alltoall(
                c,
                &rank_input(c.rank(), len),
                &Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(eb)),
                &mut m,
            )
            .unwrap()
        });
        for (rank, o) in out.into_iter().enumerate() {
            let want = expected(rank, n, len);
            assert_eq!(o.len(), want.len());
            for (a, b) in o.iter().zip(&want) {
                assert!((a - b).abs() as f64 <= eb * 1.001 + 1e-6);
            }
        }
    }

    #[test]
    fn single_rank() {
        let out = run_ranks(1, |c| {
            let mut m = Metrics::default();
            alltoall(c, &[1.0, 2.0, 3.0], &Mode::plain(), &mut m).unwrap()
        });
        assert_eq!(out[0], vec![1.0, 2.0, 3.0]);
    }
}
