//! All-to-all ("allscatter", §2.1.1): rank `r` sends its `j`-th chunk to
//! rank `j` and receives rank `j`'s `r`-th chunk, via the standard
//! pairwise-exchange schedule (`n-1` rounds, peer `(r ± t) mod n`).
//!
//! Data movement framework applies directly: every chunk crosses exactly
//! one link, so each is compressed once and decompressed once; ZCCL adds
//! the size pre-exchange so receives post exact buffers (balanced), while
//! CPRP2P sends opaque frames of unknown size.

use super::{bytes_to_f32s, chunk_ranges, exchange_sizes, f32s_to_bytes, Algo, Communicator, Mode};
use crate::coordinator::{Metrics, Phase};
use crate::{Error, Result};

/// Exchange chunks: `input` is split into `n` chunks (chunk `j` goes to
/// rank `j`); the result concatenates the chunk received from every rank
/// in rank order.
pub fn alltoall(
    comm: &mut Communicator,
    input: &[f32],
    mode: &Mode,
    m: &mut Metrics,
) -> Result<Vec<f32>> {
    let n = comm.size();
    let me = comm.rank();
    if n == 1 {
        return Ok(input.to_vec());
    }
    let base = comm.fresh_tags(2 * n as u64);
    let sizes_tag = base + n as u64;
    let ranges = chunk_ranges(input.len(), n);
    m.raw_bytes += (input.len() * 4) as u64;

    // Compress (or serialise) each outgoing chunk exactly once.
    let codec = mode.compresses().then(|| mode.codec());
    let mut outgoing: Vec<Vec<u8>> = Vec::with_capacity(n);
    for r in ranges.iter() {
        let chunk = &input[r.clone()];
        outgoing.push(match &codec {
            Some(c) => m.time(Phase::Compress, || c.compress(chunk, mode.eb))?.bytes,
            None => f32s_to_bytes(chunk),
        });
    }

    // ZCCL balances with a size pre-exchange (4 bytes/rank; here we ship
    // each peer the size of ITS chunk during the pairwise rounds' tag-0
    // message, so reuse exchange_sizes for the total only).
    if mode.algo == Algo::Zccl {
        let t0 = std::time::Instant::now();
        let _ = exchange_sizes(comm, outgoing[me].len() as u32, sizes_tag)?;
        m.add(Phase::Other, t0.elapsed().as_secs_f64());
    }

    let mut incoming: Vec<Option<Vec<u8>>> = vec![None; n];
    incoming[me] = Some(outgoing[me].clone());
    for t in 1..n {
        let to = (me + t) % n;
        let from = (me + n - t) % n;
        let t0 = std::time::Instant::now();
        comm.t.send(to, base + t as u64, &outgoing[to])?;
        m.bytes_sent += outgoing[to].len() as u64;
        let got = comm.t.recv(from, base + t as u64)?;
        m.bytes_recv += got.len() as u64;
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        incoming[from] = Some(got);
    }

    // Decode in rank order. Every rank's input may have a different
    // length, so sizes come from the frames themselves (compressed) or
    // the byte count (plain).
    let mut out = Vec::new();
    for (r, buf) in incoming.into_iter().enumerate() {
        let buf = buf.ok_or_else(|| Error::corrupt(format!("missing chunk from {r}")))?;
        match &codec {
            Some(_) => {
                out.extend(m.time(Phase::Decompress, || crate::compress::decompress(&buf))?)
            }
            None => out.extend(bytes_to_f32s(&buf)?),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::run_ranks;
    use crate::compress::{CompressorKind, ErrorBound};
    use crate::data::fields::{Field, FieldKind};

    fn rank_input(rank: usize, len: usize) -> Vec<f32> {
        Field::generate(FieldKind::Cesm, len, 2000 + rank as u64).values
    }

    /// Expected output at `rank`: chunk `rank` of every peer's input.
    fn expected(rank: usize, n: usize, len: usize) -> Vec<f32> {
        let ranges = chunk_ranges(len, n);
        (0..n)
            .flat_map(|src| rank_input(src, len)[ranges[rank].clone()].to_vec())
            .collect()
    }

    #[test]
    fn plain_exact() {
        for n in [2usize, 3, 5, 8] {
            let len = 1000;
            let out = run_ranks(n, move |c| {
                let mut m = Metrics::default();
                alltoall(c, &rank_input(c.rank(), len), &Mode::plain(), &mut m).unwrap()
            });
            for (rank, o) in out.into_iter().enumerate() {
                assert_eq!(o, expected(rank, n, len), "n={n} rank={rank}");
            }
        }
    }

    #[test]
    fn zccl_bounded() {
        let (n, len) = (5, 4000);
        let eb = 1e-3f64;
        let out = run_ranks(n, move |c| {
            let mut m = Metrics::default();
            alltoall(
                c,
                &rank_input(c.rank(), len),
                &Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(eb)),
                &mut m,
            )
            .unwrap()
        });
        for (rank, o) in out.into_iter().enumerate() {
            let want = expected(rank, n, len);
            assert_eq!(o.len(), want.len());
            for (a, b) in o.iter().zip(&want) {
                assert!((a - b).abs() as f64 <= eb * 1.001 + 1e-6);
            }
        }
    }

    #[test]
    fn single_rank() {
        let out = run_ranks(1, |c| {
            let mut m = Metrics::default();
            alltoall(c, &[1.0, 2.0, 3.0], &Mode::plain(), &mut m).unwrap()
        });
        assert_eq!(out[0], vec![1.0, 2.0, 3.0]);
    }
}
