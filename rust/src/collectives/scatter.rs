//! Binomial-tree scatter — "Z-Scatter" (§4.5.2, evaluated Fig. 15).
//!
//! The root splits its buffer into `n` chunks; chunks travel down the
//! binomial tree, each interior rank peeling off its own chunk and
//! forwarding its children's subtree blocks.
//!
//! - `Plain`: raw subtree blocks.
//! - `Cprp2p`: every hop compresses the *whole subtree value block*
//!   before sending and decompresses it on arrival — repeated
//!   (de)compression of the same data plus per-hop error accumulation.
//! - `CColl`/`Zccl`: the root compresses **each rank's chunk once**,
//!   individually; interior ranks forward the per-rank frames verbatim
//!   and decompress only their own. One compression per chunk, one
//!   decompression per rank, single-`ê` error.

use super::ctx::CollState;
use super::{bytes_to_f32s, chunk_ranges, f32s_to_bytes, Algo, Communicator, Mode};
use crate::compress::bits::le;
use crate::coordinator::{Metrics, Phase};
use crate::topology::{binomial_bcast, binomial_subtree, tree_rounds};
use crate::{Error, Result};

/// Scatter `data` (significant at `root`) so rank `r` receives chunk `r`
/// of [`chunk_ranges`]`(data.len(), n)`.
///
/// Compatibility shim: builds a transient codec per call. Iterated
/// callers should use [`super::CollCtx::scatter`].
pub fn scatter(
    comm: &mut Communicator,
    data: Option<&[f32]>,
    root: usize,
    mode: &Mode,
    m: &mut Metrics,
) -> Result<Vec<f32>> {
    let mut st = CollState::new(*mode);
    scatter_with(comm, &mut st, data, root, m)
}

/// [`scatter`] against a persistent [`CollState`] (codec built once).
pub(crate) fn scatter_with(
    comm: &mut Communicator,
    st: &mut CollState,
    data: Option<&[f32]>,
    root: usize,
    m: &mut Metrics,
) -> Result<Vec<f32>> {
    let n = comm.size();
    let me = comm.rank();
    if root >= n {
        return Err(Error::invalid(format!("root {root} out of {n}")));
    }
    if me == root && data.is_none() {
        return Err(Error::invalid("root must supply data"));
    }
    if n == 1 {
        return Ok(data.unwrap().to_vec());
    }
    match st.mode.algo {
        Algo::Plain | Algo::Cprp2p => scatter_values(comm, st, data, root, m),
        Algo::CColl | Algo::Zccl => scatter_frames(comm, st, data, root, m),
    }
}

/// Plain / CPRP2P path: per-rank *values* travel the tree; CPRP2P
/// compresses the concatenated subtree block once per hop.
fn scatter_values(
    comm: &mut Communicator,
    st: &mut CollState,
    data: Option<&[f32]>,
    root: usize,
    m: &mut Metrics,
) -> Result<Vec<f32>> {
    let n = comm.size();
    let me = comm.rank();
    let base = comm.fresh_tags(tree_rounds(n) as u64 + 1);
    let (recv_step, send_steps) = binomial_bcast(me, root, n);
    let my_subtree = binomial_subtree(me, root, n);

    // Obtain (total, per-subtree-rank values).
    let (total, mut chunks): (usize, Vec<Vec<f32>>) = if me == root {
        let d = data.unwrap();
        m.raw_bytes += (d.len() * 4) as u64;
        let ranges = chunk_ranges(d.len(), n);
        (d.len(), my_subtree.iter().map(|&r| d[ranges[r].clone()].to_vec()).collect())
    } else {
        let step = recv_step.expect("non-root receives");
        let t0 = std::time::Instant::now();
        let msg = comm.t.recv(step.peer, base + step.round as u64)?;
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        m.bytes_recv += msg.len() as u64;
        let mut pos = 0usize;
        let total = le::get_u64(&msg, &mut pos)? as usize;
        let body = &msg[pos..];
        let values = match st.mode.algo {
            Algo::Plain => bytes_to_f32s(body)?,
            _ => {
                let mut dec = Vec::new();
                let t0 = std::time::Instant::now();
                st.decode_into(body, &mut dec)?;
                m.add(Phase::Decompress, t0.elapsed().as_secs_f64());
                dec
            }
        };
        // Split the concatenated block into per-subtree-rank chunks.
        let ranges = chunk_ranges(total, n);
        let mut chunks = Vec::with_capacity(my_subtree.len());
        let mut off = 0usize;
        for &r in &my_subtree {
            let len = ranges[r].len();
            if off + len > values.len() {
                return Err(Error::corrupt("scatter block shorter than subtree"));
            }
            chunks.push(values[off..off + len].to_vec());
            off += len;
        }
        (total, chunks)
    };

    for s in send_steps {
        let child_subtree = binomial_subtree(s.peer, root, n);
        let mut block: Vec<f32> = Vec::new();
        for r in &child_subtree {
            let idx = my_subtree.iter().position(|x| x == r).expect("child in subtree");
            block.extend_from_slice(&chunks[idx]);
        }
        let mut wire = Vec::with_capacity(12 + block.len() * 4);
        le::put_u64(&mut wire, total as u64);
        match st.mode.algo {
            Algo::Plain => wire.extend_from_slice(&f32s_to_bytes(&block)),
            _ => {
                let t0 = std::time::Instant::now();
                st.compress_into(&block, &mut wire)?;
                m.add(Phase::Compress, t0.elapsed().as_secs_f64());
            }
        }
        let t0 = std::time::Instant::now();
        comm.t.send(s.peer, base + s.round as u64, &wire)?;
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        m.bytes_sent += wire.len() as u64;
    }

    Ok(std::mem::take(&mut chunks[0]))
}

/// CColl / ZCCL path: per-rank compressed *frames* travel the tree
/// verbatim; only the owner decompresses.
fn scatter_frames(
    comm: &mut Communicator,
    st: &mut CollState,
    data: Option<&[f32]>,
    root: usize,
    m: &mut Metrics,
) -> Result<Vec<f32>> {
    let n = comm.size();
    let me = comm.rank();
    let base = comm.fresh_tags(tree_rounds(n) as u64 + 1);
    let (recv_step, send_steps) = binomial_bcast(me, root, n);
    let my_subtree = binomial_subtree(me, root, n);

    let (total, mut frames): (usize, Vec<Vec<u8>>) = if me == root {
        let d = data.unwrap();
        m.raw_bytes += (d.len() * 4) as u64;
        let ranges = chunk_ranges(d.len(), n);
        let mut fs = Vec::with_capacity(my_subtree.len());
        for &r in &my_subtree {
            let chunk = &d[ranges[r].clone()];
            let mut f = Vec::new();
            let t0 = std::time::Instant::now();
            st.compress_into(chunk, &mut f)?;
            m.add(Phase::Compress, t0.elapsed().as_secs_f64());
            fs.push(f);
        }
        (d.len(), fs)
    } else {
        let step = recv_step.expect("non-root receives");
        let t0 = std::time::Instant::now();
        let msg = comm.t.recv(step.peer, base + step.round as u64)?;
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        m.bytes_recv += msg.len() as u64;
        parse_bundle(&msg, my_subtree.len())?
    };

    for s in send_steps {
        let child_subtree = binomial_subtree(s.peer, root, n);
        let parts: Vec<&[u8]> = child_subtree
            .iter()
            .map(|r| {
                let idx = my_subtree.iter().position(|x| x == r).expect("child in subtree");
                frames[idx].as_slice()
            })
            .collect();
        let wire = encode_bundle(total, &parts);
        let t0 = std::time::Instant::now();
        comm.t.send(s.peer, base + s.round as u64, &wire)?;
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        m.bytes_sent += wire.len() as u64;
    }

    // Decompress ONLY our own chunk, exactly once.
    let mine = std::mem::take(&mut frames[0]);
    let mut out = Vec::new();
    let t0 = std::time::Instant::now();
    st.decode_into(&mine, &mut out)?;
    m.add(Phase::Decompress, t0.elapsed().as_secs_f64());
    let want_len = chunk_ranges(total, n)[me].len();
    if out.len() != want_len {
        return Err(Error::corrupt(format!(
            "scatter rank {me}: got {} values, want {want_len}",
            out.len()
        )));
    }
    Ok(out)
}

/// Bundle wire format: `u64 total`, `u32 count`, `u32 sizes[count]`,
/// payloads.
fn encode_bundle(total: usize, payloads: &[&[u8]]) -> Vec<u8> {
    let body: usize = payloads.iter().map(|p| p.len()).sum();
    let mut out = Vec::with_capacity(12 + 4 * payloads.len() + body);
    le::put_u64(&mut out, total as u64);
    le::put_u32(&mut out, payloads.len() as u32);
    for p in payloads {
        le::put_u32(&mut out, p.len() as u32);
    }
    for p in payloads {
        out.extend_from_slice(p);
    }
    out
}

fn parse_bundle(msg: &[u8], expect: usize) -> Result<(usize, Vec<Vec<u8>>)> {
    let mut pos = 0usize;
    let total = le::get_u64(msg, &mut pos)? as usize;
    let count = le::get_u32(msg, &mut pos)? as usize;
    if count != expect {
        return Err(Error::corrupt(format!("bundle count {count}, expected {expect}")));
    }
    let mut sizes = Vec::with_capacity(count);
    for _ in 0..count {
        sizes.push(le::get_u32(msg, &mut pos)? as usize);
    }
    let mut payloads = Vec::with_capacity(count);
    for s in sizes {
        let end = pos + s;
        if end > msg.len() {
            return Err(Error::corrupt("bundle payload past end"));
        }
        payloads.push(msg[pos..end].to_vec());
        pos = end;
    }
    Ok((total, payloads))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::run_ranks;
    use crate::compress::{CompressorKind, ErrorBound};
    use crate::data::fields::{Field, FieldKind};

    fn payload(len: usize) -> Vec<f32> {
        Field::generate(FieldKind::Cesm, len, 777).values
    }

    #[test]
    fn plain_exact() {
        for n in [2usize, 4, 5, 8, 11] {
            for root in [0usize, n - 1] {
                let len = 999;
                let out = run_ranks(n, move |c| {
                    let data = (c.rank() == root).then(|| payload(len));
                    let mut m = Metrics::default();
                    scatter(c, data.as_deref(), root, &Mode::plain(), &mut m).unwrap()
                });
                let want = payload(len);
                let ranges = chunk_ranges(len, n);
                for (rank, o) in out.into_iter().enumerate() {
                    assert_eq!(
                        o.as_slice(),
                        &want[ranges[rank].clone()],
                        "n={n} root={root} rank={rank}"
                    );
                }
            }
        }
    }

    #[test]
    fn zccl_single_eb_per_chunk() {
        let n = 8;
        let len = 8192;
        let eb = 1e-3f64;
        let out = run_ranks(n, move |c| {
            let data = (c.rank() == 0).then(|| payload(len));
            let mut m = Metrics::default();
            let r = scatter(
                c,
                data.as_deref(),
                0,
                &Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(eb)),
                &mut m,
            )
            .unwrap();
            (r, m)
        });
        let want = payload(len);
        let ranges = chunk_ranges(len, n);
        for (rank, (o, m)) in out.iter().enumerate() {
            for (a, b) in o.iter().zip(&want[ranges[rank].clone()]) {
                assert!((a - b).abs() as f64 <= eb * 1.001 + 1e-6, "rank {rank}");
            }
            if rank != 0 {
                assert_eq!(m.compress_s, 0.0, "only root compresses");
            }
        }
    }

    #[test]
    fn cprp2p_bounded_by_depth() {
        let n = 8; // depth 3
        let len = 4096;
        let eb = 1e-3f64;
        let out = run_ranks(n, move |c| {
            let data = (c.rank() == 0).then(|| payload(len));
            let mut m = Metrics::default();
            scatter(
                c,
                data.as_deref(),
                0,
                &Mode::cprp2p(CompressorKind::FzLight, ErrorBound::Abs(eb)),
                &mut m,
            )
            .unwrap()
        });
        let want = payload(len);
        let ranges = chunk_ranges(len, n);
        for (rank, o) in out.into_iter().enumerate() {
            for (a, b) in o.iter().zip(&want[ranges[rank].clone()]) {
                assert!((a - b).abs() as f64 <= 3.0 * eb * 1.01 + 1e-6, "rank {rank}");
            }
        }
    }

    #[test]
    fn ccoll_bounded() {
        let n = 6;
        let len = 3000;
        let eb = 1e-2f64;
        let out = run_ranks(n, move |c| {
            let data = (c.rank() == 2).then(|| payload(len));
            let mut m = Metrics::default();
            scatter(c, data.as_deref(), 2, &Mode::ccoll(ErrorBound::Abs(eb)), &mut m).unwrap()
        });
        let want = payload(len);
        let ranges = chunk_ranges(len, n);
        for (rank, o) in out.into_iter().enumerate() {
            for (a, b) in o.iter().zip(&want[ranges[rank].clone()]) {
                assert!((a - b).abs() as f64 <= eb * 1.001 + 1e-6);
            }
        }
    }

    #[test]
    fn uneven_total() {
        let n = 4;
        let len = 10; // 3,3,2,2
        let out = run_ranks(n, move |c| {
            let data = (c.rank() == 0).then(|| payload(len));
            let mut m = Metrics::default();
            scatter(c, data.as_deref(), 0, &Mode::plain(), &mut m).unwrap()
        });
        let want = payload(len);
        let ranges = chunk_ranges(len, n);
        for (rank, o) in out.into_iter().enumerate() {
            assert_eq!(o.as_slice(), &want[ranges[rank].clone()]);
        }
    }
}
