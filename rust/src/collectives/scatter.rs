//! Binomial-tree scatter — "Z-Scatter" (§4.5.2, evaluated Fig. 15).
//!
//! The root splits its buffer into `n` chunks; chunks travel down the
//! binomial tree, each interior rank peeling off its own chunk and
//! forwarding its children's subtree blocks.
//!
//! - `Plain`: raw subtree blocks.
//! - `Cprp2p`: every hop compresses the *whole subtree value block*
//!   before sending and decompresses it on arrival — repeated
//!   (de)compression of the same data plus per-hop error accumulation.
//! - `CColl`/`Zccl`: the root compresses **each rank's chunk once**,
//!   individually; interior ranks forward the per-rank frames verbatim
//!   and decompress only their own. One compression per chunk, one
//!   decompression per rank, single-`ê` error.
//!
//! Receive side (parent module docs): bundles arrive into leased wire
//! buffers and are parsed **in place** — per-rank frames are ranges into
//! the arrival buffer, never copied out — and the only decompression is
//! a placement decode of our own chunk into the once-sized result.

use super::ctx::CollState;
use super::{bytes_to_f32s_into_slice, chunk_ranges, f32s_to_bytes_into, Algo, Communicator, Mode};
use crate::analysis::plan::TreePlan;
use crate::compress::bits::le;
use crate::compress::fzlight::frame_u32;
use crate::coordinator::{Metrics, Phase};
use crate::topology::{binomial_bcast, binomial_subtree};
use crate::{Error, Result};

/// Scatter `data` (significant at `root`) so rank `r` receives chunk `r`
/// of [`chunk_ranges`]`(data.len(), n)`.
///
/// Compatibility shim: builds a transient codec per call. Iterated
/// callers should use [`super::CollCtx::scatter`].
pub fn scatter(
    comm: &mut Communicator,
    data: Option<&[f32]>,
    root: usize,
    mode: &Mode,
    m: &mut Metrics,
) -> Result<Vec<f32>> {
    let mut st = CollState::new(*mode);
    scatter_with(comm, &mut st, data, root, m)
}

/// [`scatter`] against a persistent [`CollState`] (codec built once).
pub(crate) fn scatter_with(
    comm: &mut Communicator,
    st: &mut CollState,
    data: Option<&[f32]>,
    root: usize,
    m: &mut Metrics,
) -> Result<Vec<f32>> {
    let n = comm.size();
    let me = comm.rank();
    if root >= n {
        return Err(Error::invalid(format!("root {root} out of {n}")));
    }
    if me == root && data.is_none() {
        return Err(Error::invalid("root must supply data"));
    }
    if n == 1 {
        return Ok(data.unwrap().to_vec());
    }
    match st.mode.algo {
        Algo::Plain | Algo::Cprp2p => scatter_values(comm, st, data, root, m),
        Algo::CColl | Algo::Zccl => scatter_frames(comm, st, data, root, m),
        Algo::Hier => super::hier::scatter_hier(comm, st, data, root, m),
    }
}

/// Plain / CPRP2P path: per-rank *values* travel the tree; CPRP2P
/// compresses the concatenated subtree block once per hop.
fn scatter_values(
    comm: &mut Communicator,
    st: &mut CollState,
    data: Option<&[f32]>,
    root: usize,
    m: &mut Metrics,
) -> Result<Vec<f32>> {
    let n = comm.size();
    let me = comm.rank();
    let plan = TreePlan::at(comm.fresh_tags(TreePlan::span(n)), n);
    let (recv_step, send_steps) = binomial_bcast(me, root, n);
    let my_subtree = binomial_subtree(me, root, n);

    // Our subtree's values live either in the caller's buffer (root) or
    // in pooled scratch the arriving block decodes into (non-root);
    // `offsets[i]` is subtree member i's slice of that storage.
    let mut values_buf = st.pool.take_f32();
    let (total, values, offsets): (usize, &[f32], Vec<std::ops::Range<usize>>) = if me == root {
        let d = data.unwrap();
        m.raw_bytes += (d.len() * 4) as u64;
        let ranges = chunk_ranges(d.len(), n);
        (d.len(), d, my_subtree.iter().map(|&r| ranges[r].clone()).collect())
    } else {
        let step = recv_step.expect("non-root receives");
        let mut msg = comm.t.lease();
        let t0 = std::time::Instant::now();
        comm.t.recv_into(step.peer, plan.step_tag(step.round), &mut msg)?;
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        m.bytes_recv += msg.len() as u64;
        let mut pos = 0usize;
        let total = le::get_u64(&msg, &mut pos)? as usize;
        let body = &msg[pos..];
        // The block holds our whole subtree's values back to back; its
        // layout is fixed by `total`, so the storage is sized once and
        // the block decodes straight into it.
        let ranges = chunk_ranges(total, n);
        let mut offsets = Vec::with_capacity(my_subtree.len());
        let mut off = 0usize;
        for &r in &my_subtree {
            offsets.push(off..off + ranges[r].len());
            off += ranges[r].len();
        }
        // Validate the expected value count against the block actually
        // received BEFORE sizing the destination — a corrupt `total`
        // must fail cleanly, not commit pages.
        let physical = match st.mode.algo {
            Algo::Plain => body.len() / 4,
            _ => crate::compress::checked_count(body)?,
        };
        if physical != off {
            return Err(Error::corrupt(format!(
                "scatter block holds {physical} values but the subtree expects {off}"
            )));
        }
        values_buf.resize(off, 0.0);
        match st.mode.algo {
            Algo::Plain => {
                bytes_to_f32s_into_slice(body, &mut values_buf)
                    .map_err(|_| Error::corrupt("scatter block shorter than subtree"))?;
            }
            _ => {
                let t0 = std::time::Instant::now();
                st.decode_into_slice(body, &mut values_buf)
                    .map_err(|e| Error::corrupt(format!("scatter block: {e}")))?;
                m.add(Phase::Decompress, t0.elapsed().as_secs_f64());
            }
        }
        comm.t.recycle(msg);
        (total, values_buf.as_slice(), offsets)
    };

    let mut block = st.pool.take_f32();
    for s in send_steps {
        let child_subtree = binomial_subtree(s.peer, root, n);
        block.clear();
        for r in &child_subtree {
            let idx = my_subtree.iter().position(|x| x == r).expect("child in subtree");
            block.extend_from_slice(&values[offsets[idx].clone()]);
        }
        // Each child's block is built straight in a transport-leased wire
        // buffer and sent by value — no packet_from copy.
        let mut wire = comm.t.lease();
        le::put_u64(&mut wire, total as u64);
        match st.mode.algo {
            Algo::Plain => f32s_to_bytes_into(&block, &mut wire),
            _ => {
                let t0 = std::time::Instant::now();
                st.compress_into(&block, &mut wire)?;
                m.add(Phase::Compress, t0.elapsed().as_secs_f64());
            }
        }
        let t0 = std::time::Instant::now();
        m.bytes_sent += wire.len() as u64;
        comm.t.send_pooled(s.peer, plan.step_tag(s.round), wire)?;
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());
    }
    st.pool.put_f32(block);

    let out = values[offsets[0].clone()].to_vec();
    st.pool.put_f32(values_buf);
    Ok(out)
}

/// CColl / ZCCL path: per-rank compressed *frames* travel the tree
/// verbatim; only the owner decompresses.
fn scatter_frames(
    comm: &mut Communicator,
    st: &mut CollState,
    data: Option<&[f32]>,
    root: usize,
    m: &mut Metrics,
) -> Result<Vec<f32>> {
    let n = comm.size();
    let me = comm.rank();
    let plan = TreePlan::at(comm.fresh_tags(TreePlan::span(n)), n);
    let (recv_step, send_steps) = binomial_bcast(me, root, n);
    let my_subtree = binomial_subtree(me, root, n);

    // One contiguous store for our subtree's frames: the root packs the
    // frames it compresses back to back (append semantics), a non-root
    // rank keeps the arrival buffer itself — frames are RANGES into the
    // store, never copied out of it.
    let (total, store, frames, pooled): (usize, Vec<u8>, Vec<std::ops::Range<usize>>, bool) =
        if me == root {
            let d = data.unwrap();
            m.raw_bytes += (d.len() * 4) as u64;
            let ranges = chunk_ranges(d.len(), n);
            let mut buf = st.pool.take_bytes();
            let mut frames = Vec::with_capacity(my_subtree.len());
            for &r in &my_subtree {
                let start = buf.len();
                let t0 = std::time::Instant::now();
                st.compress_into(&d[ranges[r].clone()], &mut buf)?;
                m.add(Phase::Compress, t0.elapsed().as_secs_f64());
                frames.push(start..buf.len());
            }
            (d.len(), buf, frames, true)
        } else {
            let step = recv_step.expect("non-root receives");
            let mut msg = comm.t.lease();
            let t0 = std::time::Instant::now();
            comm.t.recv_into(step.peer, plan.step_tag(step.round), &mut msg)?;
            m.add(Phase::Comm, t0.elapsed().as_secs_f64());
            m.bytes_recv += msg.len() as u64;
            let (total, frames) = parse_bundle(&msg, my_subtree.len())?;
            (total, msg, frames, false)
        };

    for s in send_steps {
        let child_subtree = binomial_subtree(s.peer, root, n);
        let parts: Vec<&[u8]> = child_subtree
            .iter()
            .map(|r| {
                let idx = my_subtree.iter().position(|x| x == r).expect("child in subtree");
                &store[frames[idx].clone()]
            })
            .collect();
        // Bundles assemble straight in transport-leased wire buffers and
        // travel by value — no packet_from copy per hop.
        let mut wire = comm.t.lease();
        encode_bundle_into(total, &parts, &mut wire)?;
        let t0 = std::time::Instant::now();
        m.bytes_sent += wire.len() as u64;
        comm.t.send_pooled(s.peer, plan.step_tag(s.round), wire)?;
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());
    }

    // Placement-decode ONLY our own chunk, exactly once, straight into
    // the once-sized result. A corrupt `total` must fail against the
    // frame's physical size before the destination is allocated.
    let want_len = chunk_ranges(total, n)[me].len();
    let physical = crate::compress::checked_count(&store[frames[0].clone()])?;
    if physical != want_len {
        return Err(Error::corrupt(format!(
            "scatter rank {me}: frame holds {physical} values, want {want_len}"
        )));
    }
    let mut out = vec![0.0f32; want_len];
    let t0 = std::time::Instant::now();
    st.decode_into_slice(&store[frames[0].clone()], &mut out)
        .map_err(|e| Error::corrupt(format!("scatter rank {me}: {e}")))?;
    m.add(Phase::Decompress, t0.elapsed().as_secs_f64());
    if pooled {
        st.pool.put_bytes(store);
    } else {
        comm.t.recycle(store);
    }
    Ok(out)
}

/// Bundle wire format: `u64 total`, `u32 count`, `u32 sizes[count]`,
/// payloads. Appended to `out`. Payload lengths ride u32 fields, so
/// oversized frames are an explicit error (same [`frame_u32`] guard the
/// codec frame tables use), not a silent wrap — validated before `out`
/// is touched. Shared with the hierarchical forwarding paths
/// ([`super::hier`]), whose leader-tree bundles use the same layout
/// (`total` is the operation's element count for scatter, the sender's
/// contribution count for the allgather node bundles).
pub(crate) fn encode_bundle_into(
    total: usize,
    payloads: &[&[u8]],
    out: &mut Vec<u8>,
) -> Result<()> {
    let count = frame_u32(payloads.len(), "scatter bundle count")?;
    let mut sizes = Vec::with_capacity(payloads.len());
    for p in payloads {
        sizes.push(frame_u32(p.len(), "scatter bundle payload size")?);
    }
    let body: usize = payloads.iter().map(|p| p.len()).sum();
    out.reserve(12 + 4 * payloads.len() + body);
    le::put_u64(out, total as u64);
    le::put_u32(out, count);
    for s in sizes {
        le::put_u32(out, s);
    }
    for p in payloads {
        out.extend_from_slice(p);
    }
    Ok(())
}

/// Parse a bundle **in place**: returns the total element count and each
/// payload's range within `msg` (no copies).
pub(crate) fn parse_bundle(
    msg: &[u8],
    expect: usize,
) -> Result<(usize, Vec<std::ops::Range<usize>>)> {
    let mut pos = 0usize;
    let total = le::get_u64(msg, &mut pos)? as usize;
    let count = le::get_u32(msg, &mut pos)? as usize;
    if count != expect {
        return Err(Error::corrupt(format!("bundle count {count}, expected {expect}")));
    }
    let mut sizes = Vec::with_capacity(count);
    for _ in 0..count {
        sizes.push(le::get_u32(msg, &mut pos)? as usize);
    }
    let mut payloads = Vec::with_capacity(count);
    for s in sizes {
        let end = pos + s;
        if end > msg.len() {
            return Err(Error::corrupt("bundle payload past end"));
        }
        payloads.push(pos..end);
        pos = end;
    }
    Ok((total, payloads))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::run_ranks;
    use crate::compress::{CompressorKind, ErrorBound};
    use crate::data::fields::{Field, FieldKind};

    fn payload(len: usize) -> Vec<f32> {
        Field::generate(FieldKind::Cesm, len, 777).values
    }

    #[test]
    fn plain_exact() {
        for n in [2usize, 4, 5, 8, 11] {
            for root in [0usize, n - 1] {
                let len = 999;
                let out = run_ranks(n, move |c| {
                    let data = (c.rank() == root).then(|| payload(len));
                    let mut m = Metrics::default();
                    scatter(c, data.as_deref(), root, &Mode::plain(), &mut m).unwrap()
                });
                let want = payload(len);
                let ranges = chunk_ranges(len, n);
                for (rank, o) in out.into_iter().enumerate() {
                    assert_eq!(
                        o.as_slice(),
                        &want[ranges[rank].clone()],
                        "n={n} root={root} rank={rank}"
                    );
                }
            }
        }
    }

    #[test]
    fn zccl_single_eb_per_chunk() {
        let n = 8;
        let len = 8192;
        let eb = 1e-3f64;
        let out = run_ranks(n, move |c| {
            let data = (c.rank() == 0).then(|| payload(len));
            let mut m = Metrics::default();
            let r = scatter(
                c,
                data.as_deref(),
                0,
                &Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(eb)),
                &mut m,
            )
            .unwrap();
            (r, m)
        });
        let want = payload(len);
        let ranges = chunk_ranges(len, n);
        for (rank, (o, m)) in out.iter().enumerate() {
            for (a, b) in o.iter().zip(&want[ranges[rank].clone()]) {
                assert!((a - b).abs() as f64 <= eb * 1.001 + 1e-6, "rank {rank}");
            }
            if rank != 0 {
                assert_eq!(m.compress_s, 0.0, "only root compresses");
            }
        }
    }

    #[test]
    fn cprp2p_bounded_by_depth() {
        let n = 8; // depth 3
        let len = 4096;
        let eb = 1e-3f64;
        let out = run_ranks(n, move |c| {
            let data = (c.rank() == 0).then(|| payload(len));
            let mut m = Metrics::default();
            scatter(
                c,
                data.as_deref(),
                0,
                &Mode::cprp2p(CompressorKind::FzLight, ErrorBound::Abs(eb)),
                &mut m,
            )
            .unwrap()
        });
        let want = payload(len);
        let ranges = chunk_ranges(len, n);
        for (rank, o) in out.into_iter().enumerate() {
            for (a, b) in o.iter().zip(&want[ranges[rank].clone()]) {
                assert!((a - b).abs() as f64 <= 3.0 * eb * 1.01 + 1e-6, "rank {rank}");
            }
        }
    }

    #[test]
    fn ccoll_bounded() {
        let n = 6;
        let len = 3000;
        let eb = 1e-2f64;
        let out = run_ranks(n, move |c| {
            let data = (c.rank() == 2).then(|| payload(len));
            let mut m = Metrics::default();
            scatter(c, data.as_deref(), 2, &Mode::ccoll(ErrorBound::Abs(eb)), &mut m).unwrap()
        });
        let want = payload(len);
        let ranges = chunk_ranges(len, n);
        for (rank, o) in out.into_iter().enumerate() {
            for (a, b) in o.iter().zip(&want[ranges[rank].clone()]) {
                assert!((a - b).abs() as f64 <= eb * 1.001 + 1e-6);
            }
        }
    }

    #[test]
    fn uneven_total() {
        let n = 4;
        let len = 10; // 3,3,2,2
        let out = run_ranks(n, move |c| {
            let data = (c.rank() == 0).then(|| payload(len));
            let mut m = Metrics::default();
            scatter(c, data.as_deref(), 0, &Mode::plain(), &mut m).unwrap()
        });
        let want = payload(len);
        let ranges = chunk_ranges(len, n);
        for (rank, o) in out.into_iter().enumerate() {
            assert_eq!(o.as_slice(), &want[ranges[rank].clone()]);
        }
    }
}
