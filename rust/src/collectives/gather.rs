//! Binomial-tree gather (inverse of scatter): chunks flow *up* the tree
//! to the root.
//!
//! - `Plain`: raw records.
//! - `Cprp2p`: every hop compresses its whole accumulated block and the
//!   parent decompresses — repeated work + error accumulation.
//! - `CColl`/`Zccl`: each rank compresses its own chunk **once** at the
//!   leaf step; interior ranks forward frames verbatim; only the root
//!   decompresses (once per rank).
//!
//! Record format: `u32 count`, then per record `u32 rank, u32 bytes,
//! payload`.

use super::ctx::CollState;
use super::{bytes_to_f32s, bytes_to_f32s_into, f32s_to_bytes, Algo, Communicator, Mode};
use crate::compress::bits::le;
use crate::coordinator::{Metrics, Phase};
use crate::topology::{binomial_bcast, tree_rounds};
use crate::{Error, Result};

/// Gather each rank's `my_chunk` to `root`, which returns the chunks
/// concatenated in rank order (other ranks return `None`). Chunk lengths
/// may differ.
///
/// Compatibility shim: builds a transient codec per call. Iterated
/// callers should use [`super::CollCtx::gather`].
pub fn gather(
    comm: &mut Communicator,
    my_chunk: &[f32],
    root: usize,
    mode: &Mode,
    m: &mut Metrics,
) -> Result<Option<Vec<f32>>> {
    let mut st = CollState::new(*mode);
    gather_with(comm, &mut st, my_chunk, root, m)
}

/// [`gather`] against a persistent [`CollState`] (codec built once).
pub(crate) fn gather_with(
    comm: &mut Communicator,
    st: &mut CollState,
    my_chunk: &[f32],
    root: usize,
    m: &mut Metrics,
) -> Result<Option<Vec<f32>>> {
    let n = comm.size();
    let me = comm.rank();
    if root >= n {
        return Err(Error::invalid(format!("root {root} out of {n}")));
    }
    if n == 1 {
        return Ok(Some(my_chunk.to_vec()));
    }
    let base = comm.fresh_tags(tree_rounds(n) as u64 + 1);
    // Gather runs the bcast tree in reverse: receive from "children"
    // (largest round first = deepest subtree last... order does not matter
    // for correctness; we use reverse round order so the longest chain
    // drains first), then send to the "parent".
    let (parent_step, child_steps) = binomial_bcast(me, root, n);

    m.raw_bytes += (my_chunk.len() * 4) as u64;
    // Records this rank will forward: own chunk first.
    let mut records: Vec<(u32, Vec<u8>)> = Vec::new();
    let own_payload = match st.mode.algo {
        Algo::Plain => f32s_to_bytes(my_chunk),
        Algo::Cprp2p => f32s_to_bytes(my_chunk), // compressed per hop below
        Algo::CColl | Algo::Zccl => {
            let mut f = Vec::new();
            let t0 = std::time::Instant::now();
            st.compress_into(my_chunk, &mut f)?;
            m.add(Phase::Compress, t0.elapsed().as_secs_f64());
            f
        }
    };
    records.push((me as u32, own_payload));

    // Receive children's bundles (reverse round order).
    for s in child_steps.iter().rev() {
        let t0 = std::time::Instant::now();
        let msg = comm.t.recv(s.peer, base + s.round as u64)?;
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        m.bytes_recv += msg.len() as u64;
        let child_records = if st.mode.algo == Algo::Cprp2p {
            // The child compressed each record's values for the hop;
            // decompress them back to raw bytes.
            let recs = parse_records(&msg)?;
            let mut out = Vec::with_capacity(recs.len());
            for (rank, payload) in recs {
                let mut vals = st.pool.take_f32();
                let t0 = std::time::Instant::now();
                st.decode_into(&payload, &mut vals)?;
                m.add(Phase::Decompress, t0.elapsed().as_secs_f64());
                out.push((rank, f32s_to_bytes(&vals)));
                st.pool.put_f32(vals);
            }
            out
        } else {
            parse_records(&msg)?
        };
        records.extend(child_records);
    }

    if me == root {
        // Assemble in rank order; decompress once per rank for Z modes.
        records.sort_by_key(|(r, _)| *r);
        let mut out = Vec::new();
        for (_, payload) in records {
            match st.mode.algo {
                Algo::Plain | Algo::Cprp2p => out.extend(bytes_to_f32s(&payload)?),
                Algo::CColl | Algo::Zccl => {
                    let t0 = std::time::Instant::now();
                    st.decode_into(&payload, &mut out)?;
                    m.add(Phase::Decompress, t0.elapsed().as_secs_f64());
                }
            }
        }
        return Ok(Some(out));
    }

    // Forward everything to the parent.
    let step = parent_step.expect("non-root has a parent");
    let wire = if st.mode.algo == Algo::Cprp2p {
        // Compress each record's values for this hop (CPRP2P re-compresses
        // at every level of the tree).
        let mut hop = Vec::with_capacity(records.len());
        for (rank, payload) in &records {
            let mut vals = st.pool.take_f32();
            bytes_to_f32s_into(payload, &mut vals)?;
            let mut frame = Vec::new();
            let t0 = std::time::Instant::now();
            st.compress_into(&vals, &mut frame)?;
            m.add(Phase::Compress, t0.elapsed().as_secs_f64());
            st.pool.put_f32(vals);
            hop.push((*rank, frame));
        }
        encode_records(&hop)
    } else {
        encode_records(&records)
    };
    let t0 = std::time::Instant::now();
    comm.t.send(step.peer, base + step.round as u64, &wire)?;
    m.add(Phase::Comm, t0.elapsed().as_secs_f64());
    m.bytes_sent += wire.len() as u64;
    Ok(None)
}

fn encode_records(records: &[(u32, Vec<u8>)]) -> Vec<u8> {
    let body: usize = records.iter().map(|(_, p)| p.len()).sum();
    let mut out = Vec::with_capacity(4 + records.len() * 8 + body);
    le::put_u32(&mut out, records.len() as u32);
    for (rank, p) in records {
        le::put_u32(&mut out, *rank);
        le::put_u32(&mut out, p.len() as u32);
    }
    for (_, p) in records {
        out.extend_from_slice(p);
    }
    out
}

fn parse_records(msg: &[u8]) -> Result<Vec<(u32, Vec<u8>)>> {
    let mut pos = 0usize;
    let count = le::get_u32(msg, &mut pos)? as usize;
    let mut heads = Vec::with_capacity(count);
    for _ in 0..count {
        let rank = le::get_u32(msg, &mut pos)?;
        let len = le::get_u32(msg, &mut pos)? as usize;
        heads.push((rank, len));
    }
    let mut out = Vec::with_capacity(count);
    for (rank, len) in heads {
        let end = pos + len;
        if end > msg.len() {
            return Err(Error::corrupt("gather record past end"));
        }
        out.push((rank, msg[pos..end].to_vec()));
        pos = end;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::run_ranks;
    use crate::compress::{CompressorKind, ErrorBound};
    use crate::data::fields::{Field, FieldKind};

    fn rank_chunk(rank: usize, len: usize) -> Vec<f32> {
        Field::generate(FieldKind::Hurricane, len, 40 + rank as u64).values
    }

    #[test]
    fn plain_exact() {
        for n in [2usize, 3, 6, 9] {
            for root in [0usize, n - 1] {
                let out = run_ranks(n, move |c| {
                    let mine = rank_chunk(c.rank(), 200 + c.rank() * 13);
                    let mut m = Metrics::default();
                    gather(c, &mine, root, &Mode::plain(), &mut m).unwrap()
                });
                let want: Vec<f32> =
                    (0..n).flat_map(|r| rank_chunk(r, 200 + r * 13)).collect();
                for (rank, o) in out.into_iter().enumerate() {
                    if rank == root {
                        assert_eq!(o.unwrap(), want, "n={n} root={root}");
                    } else {
                        assert!(o.is_none());
                    }
                }
            }
        }
    }

    #[test]
    fn zccl_bounded_and_leaf_compress_only() {
        let n = 8;
        let eb = 1e-3f64;
        let out = run_ranks(n, move |c| {
            let mine = rank_chunk(c.rank(), 2048);
            let mut m = Metrics::default();
            let r = gather(
                c,
                &mine,
                0,
                &Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(eb)),
                &mut m,
            )
            .unwrap();
            (r, m)
        });
        let want: Vec<f32> = (0..n).flat_map(|r| rank_chunk(r, 2048)).collect();
        let root_out = out[0].0.as_ref().unwrap();
        for (a, b) in root_out.iter().zip(&want) {
            assert!((a - b).abs() as f64 <= eb * 1.001 + 1e-6);
        }
        // Every rank compresses exactly its own chunk (compress_s > 0
        // everywhere), but only root decompresses.
        for (rank, (_, m)) in out.iter().enumerate() {
            assert!(m.compress_s > 0.0, "rank {rank} compresses its chunk");
            if rank != 0 {
                assert_eq!(m.decompress_s, 0.0, "rank {rank} must not decompress");
            }
        }
    }

    #[test]
    fn cprp2p_bounded_by_depth() {
        let n = 8;
        let eb = 1e-3f64;
        let out = run_ranks(n, move |c| {
            let mine = rank_chunk(c.rank(), 1024);
            let mut m = Metrics::default();
            gather(
                c,
                &mine,
                0,
                &Mode::cprp2p(CompressorKind::FzLight, ErrorBound::Abs(eb)),
                &mut m,
            )
            .unwrap()
        });
        let want: Vec<f32> = (0..n).flat_map(|r| rank_chunk(r, 1024)).collect();
        let root_out = out[0].as_ref().unwrap();
        for (a, b) in root_out.iter().zip(&want) {
            assert!((a - b).abs() as f64 <= 3.0 * eb * 1.01 + 1e-6);
        }
    }
}
