//! Binomial-tree gather (inverse of scatter): chunks flow *up* the tree
//! to the root.
//!
//! - `Plain`: raw records.
//! - `Cprp2p`: every hop compresses its whole accumulated block and the
//!   parent decompresses — repeated work + error accumulation.
//! - `CColl`/`Zccl`: each rank compresses its own chunk **once** at the
//!   leaf step; interior ranks forward frames verbatim; only the root
//!   decompresses (once per rank).
//!
//! Record format: `u32 count`, then per record `u32 rank, u32 bytes,
//! payload`.
//!
//! Receive side (parent module docs): child bundles arrive into leased
//! wire buffers that stay alive as record *stores* — records are ranges
//! into them, never copied out — and the root sizes its output once from
//! the record headers, placement-decoding every record straight into its
//! final window.

use super::ctx::CollState;
use super::{
    bytes_to_f32s_into, bytes_to_f32s_into_slice, f32s_to_bytes_into, Algo, Communicator, Mode,
};
use crate::analysis::plan::TreePlan;
use crate::compress::bits::le;
use crate::compress::fzlight::frame_u32;
use crate::coordinator::{Metrics, Phase};
use crate::topology::binomial_bcast;
use crate::{Error, Result};

/// Gather each rank's `my_chunk` to `root`, which returns the chunks
/// concatenated in rank order (other ranks return `None`). Chunk lengths
/// may differ.
///
/// Compatibility shim: builds a transient codec per call. Iterated
/// callers should use [`super::CollCtx::gather`].
pub fn gather(
    comm: &mut Communicator,
    my_chunk: &[f32],
    root: usize,
    mode: &Mode,
    m: &mut Metrics,
) -> Result<Option<Vec<f32>>> {
    let mut st = CollState::new(*mode);
    gather_with(comm, &mut st, my_chunk, root, m)
}

/// [`gather`] against a persistent [`CollState`] (codec built once).
pub(crate) fn gather_with(
    comm: &mut Communicator,
    st: &mut CollState,
    my_chunk: &[f32],
    root: usize,
    m: &mut Metrics,
) -> Result<Option<Vec<f32>>> {
    let n = comm.size();
    let me = comm.rank();
    if root >= n {
        return Err(Error::invalid(format!("root {root} out of {n}")));
    }
    if n == 1 {
        return Ok(Some(my_chunk.to_vec()));
    }
    if st.mode.algo == Algo::Hier {
        return super::hier::gather_hier(comm, st, my_chunk, root, m);
    }
    let plan = TreePlan::at(comm.fresh_tags(TreePlan::span(n)), n);
    // Gather runs the bcast tree in reverse: receive from "children"
    // (largest round first = deepest subtree last... order does not matter
    // for correctness; we use reverse round order so the longest chain
    // drains first), then send to the "parent".
    let (parent_step, child_steps) = binomial_bcast(me, root, n);

    m.raw_bytes += (my_chunk.len() * 4) as u64;
    // Record payloads live in `stores`: store 0 is pooled scratch holding
    // our own payload (and, for CPRP2P, every re-serialized child
    // record); the rest are leased arrival buffers kept alive so records
    // can reference them in place. A record is `(rank, store, range)`.
    let mut stores: Vec<Vec<u8>> = vec![st.pool.take_bytes()];
    let mut records: Vec<(u32, usize, std::ops::Range<usize>)> = Vec::new();
    match st.mode.algo {
        Algo::Plain | Algo::Cprp2p => f32s_to_bytes_into(my_chunk, &mut stores[0]),
        // Hier dispatched to its two-level schedule above — unreachable
        // here, but kept in the compressed arm for match exhaustiveness.
        Algo::CColl | Algo::Zccl | Algo::Hier => {
            let t0 = std::time::Instant::now();
            st.compress_into(my_chunk, &mut stores[0])?;
            m.add(Phase::Compress, t0.elapsed().as_secs_f64());
        }
    }
    records.push((me as u32, 0, 0..stores[0].len()));

    // Receive children's bundles (reverse round order).
    for s in child_steps.iter().rev() {
        let mut msg = comm.t.lease();
        let t0 = std::time::Instant::now();
        comm.t.recv_into(s.peer, plan.step_tag(s.round), &mut msg)?;
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        m.bytes_recv += msg.len() as u64;
        if st.mode.algo == Algo::Cprp2p {
            // The child compressed each record's values for the hop;
            // placement-decode them back to raw bytes in store 0.
            let recs = parse_records(&msg)?;
            let mut vals = st.pool.take_f32();
            for (rank, r) in recs {
                let frame = &msg[r];
                let cnt = crate::compress::checked_count(frame)?;
                vals.clear();
                vals.resize(cnt, 0.0);
                let t0 = std::time::Instant::now();
                st.decode_into_slice(frame, &mut vals)?;
                m.add(Phase::Decompress, t0.elapsed().as_secs_f64());
                let start = stores[0].len();
                f32s_to_bytes_into(&vals, &mut stores[0]);
                records.push((rank, 0, start..stores[0].len()));
            }
            st.pool.put_f32(vals);
            comm.t.recycle(msg);
        } else {
            let recs = parse_records(&msg)?;
            let idx = stores.len();
            records.extend(recs.into_iter().map(|(rank, r)| (rank, idx, r)));
            stores.push(msg);
        }
    }

    if me == root {
        // Assemble in rank order: size the output once from the record
        // headers (bounds-checked against each payload's physical size),
        // then placement-decode every record into its window.
        records.sort_by_key(|(r, _, _)| *r);
        let mut counts = Vec::with_capacity(records.len());
        for (_, si, r) in &records {
            let payload = &stores[*si][r.clone()];
            counts.push(match st.mode.algo {
                Algo::Plain | Algo::Cprp2p => payload.len() / 4,
                Algo::CColl | Algo::Zccl | Algo::Hier => {
                    crate::compress::checked_count(payload)?
                }
            });
        }
        let mut out = vec![0.0f32; counts.iter().sum()];
        let mut off = 0usize;
        for ((_, si, r), &cnt) in records.iter().zip(&counts) {
            let payload = &stores[*si][r.clone()];
            match st.mode.algo {
                Algo::Plain | Algo::Cprp2p => {
                    bytes_to_f32s_into_slice(payload, &mut out[off..off + cnt])?;
                }
                Algo::CColl | Algo::Zccl | Algo::Hier => {
                    let t0 = std::time::Instant::now();
                    st.decode_into_slice(payload, &mut out[off..off + cnt])?;
                    m.add(Phase::Decompress, t0.elapsed().as_secs_f64());
                }
            }
            off += cnt;
        }
        release_stores(comm, st, stores);
        return Ok(Some(out));
    }

    // Forward everything to the parent through a transport-leased wire
    // buffer handed over by value (send_pooled — no packet_from copy).
    let step = parent_step.expect("non-root has a parent");
    let mut wire = comm.t.lease();
    if st.mode.algo == Algo::Cprp2p {
        // Compress each record's values for this hop (CPRP2P re-compresses
        // at every level of the tree).
        let mut vals = st.pool.take_f32();
        let mut frames = st.pool.take_bytes();
        let mut franges: Vec<(u32, std::ops::Range<usize>)> = Vec::with_capacity(records.len());
        for (rank, si, r) in &records {
            vals.clear();
            bytes_to_f32s_into(&stores[*si][r.clone()], &mut vals)?;
            let start = frames.len();
            let t0 = std::time::Instant::now();
            st.compress_into(&vals, &mut frames)?;
            m.add(Phase::Compress, t0.elapsed().as_secs_f64());
            franges.push((*rank, start..frames.len()));
        }
        let parts: Vec<(u32, &[u8])> =
            franges.iter().map(|(rank, r)| (*rank, &frames[r.clone()])).collect();
        encode_records_into(&parts, &mut wire)?;
        st.pool.put_f32(vals);
        st.pool.put_bytes(frames);
    } else {
        let parts: Vec<(u32, &[u8])> =
            records.iter().map(|(rank, si, r)| (*rank, &stores[*si][r.clone()])).collect();
        encode_records_into(&parts, &mut wire)?;
    }
    let t0 = std::time::Instant::now();
    m.bytes_sent += wire.len() as u64;
    comm.t.send_pooled(step.peer, plan.step_tag(step.round), wire)?;
    m.add(Phase::Comm, t0.elapsed().as_secs_f64());
    release_stores(comm, st, stores);
    Ok(None)
}

/// Return record stores to their home pools: store 0 to the scratch
/// pool, arrival buffers to the transport's packet pool.
fn release_stores(comm: &mut Communicator, st: &mut CollState, stores: Vec<Vec<u8>>) {
    let mut it = stores.into_iter();
    if let Some(own) = it.next() {
        st.pool.put_bytes(own);
    }
    for msg in it {
        comm.t.recycle(msg);
    }
}

/// Append the record wire format to `out`. Payload lengths ride u32
/// fields, so oversized records are an explicit error (same
/// [`frame_u32`] guard the codec frame tables use), not a silent wrap —
/// validated before `out` is touched.
pub(crate) fn encode_records_into(records: &[(u32, &[u8])], out: &mut Vec<u8>) -> Result<()> {
    let count = frame_u32(records.len(), "gather record count")?;
    let mut sizes = Vec::with_capacity(records.len());
    for (_, p) in records {
        sizes.push(frame_u32(p.len(), "gather record size")?);
    }
    let body: usize = records.iter().map(|(_, p)| p.len()).sum();
    out.reserve(4 + records.len() * 8 + body);
    le::put_u32(out, count);
    for ((rank, _), size) in records.iter().zip(sizes) {
        le::put_u32(out, *rank);
        le::put_u32(out, size);
    }
    for (_, p) in records {
        out.extend_from_slice(p);
    }
    Ok(())
}

/// Parse a record bundle **in place**: `(rank, payload range)` per
/// record, ranges into `msg` (no copies).
pub(crate) fn parse_records(msg: &[u8]) -> Result<Vec<(u32, std::ops::Range<usize>)>> {
    let mut pos = 0usize;
    let count = le::get_u32(msg, &mut pos)? as usize;
    let mut heads = Vec::with_capacity(count);
    for _ in 0..count {
        let rank = le::get_u32(msg, &mut pos)?;
        let len = le::get_u32(msg, &mut pos)? as usize;
        heads.push((rank, len));
    }
    let mut out = Vec::with_capacity(count);
    for (rank, len) in heads {
        let end = pos + len;
        if end > msg.len() {
            return Err(Error::corrupt("gather record past end"));
        }
        out.push((rank, pos..end));
        pos = end;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::run_ranks;
    use crate::compress::{CompressorKind, ErrorBound};
    use crate::data::fields::{Field, FieldKind};

    fn rank_chunk(rank: usize, len: usize) -> Vec<f32> {
        Field::generate(FieldKind::Hurricane, len, 40 + rank as u64).values
    }

    #[test]
    fn plain_exact() {
        for n in [2usize, 3, 6, 9] {
            for root in [0usize, n - 1] {
                let out = run_ranks(n, move |c| {
                    let mine = rank_chunk(c.rank(), 200 + c.rank() * 13);
                    let mut m = Metrics::default();
                    gather(c, &mine, root, &Mode::plain(), &mut m).unwrap()
                });
                let want: Vec<f32> =
                    (0..n).flat_map(|r| rank_chunk(r, 200 + r * 13)).collect();
                for (rank, o) in out.into_iter().enumerate() {
                    if rank == root {
                        assert_eq!(o.unwrap(), want, "n={n} root={root}");
                    } else {
                        assert!(o.is_none());
                    }
                }
            }
        }
    }

    #[test]
    fn zccl_bounded_and_leaf_compress_only() {
        let n = 8;
        let eb = 1e-3f64;
        let out = run_ranks(n, move |c| {
            let mine = rank_chunk(c.rank(), 2048);
            let mut m = Metrics::default();
            let r = gather(
                c,
                &mine,
                0,
                &Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(eb)),
                &mut m,
            )
            .unwrap();
            (r, m)
        });
        let want: Vec<f32> = (0..n).flat_map(|r| rank_chunk(r, 2048)).collect();
        let root_out = out[0].0.as_ref().unwrap();
        for (a, b) in root_out.iter().zip(&want) {
            assert!((a - b).abs() as f64 <= eb * 1.001 + 1e-6);
        }
        // Every rank compresses exactly its own chunk (compress_s > 0
        // everywhere), but only root decompresses.
        for (rank, (_, m)) in out.iter().enumerate() {
            assert!(m.compress_s > 0.0, "rank {rank} compresses its chunk");
            if rank != 0 {
                assert_eq!(m.decompress_s, 0.0, "rank {rank} must not decompress");
            }
        }
    }

    #[test]
    fn cprp2p_bounded_by_depth() {
        let n = 8;
        let eb = 1e-3f64;
        let out = run_ranks(n, move |c| {
            let mine = rank_chunk(c.rank(), 1024);
            let mut m = Metrics::default();
            gather(
                c,
                &mine,
                0,
                &Mode::cprp2p(CompressorKind::FzLight, ErrorBound::Abs(eb)),
                &mut m,
            )
            .unwrap()
        });
        let want: Vec<f32> = (0..n).flat_map(|r| rank_chunk(r, 1024)).collect();
        let root_out = out[0].as_ref().unwrap();
        for (a, b) in root_out.iter().zip(&want) {
            assert!((a - b).abs() as f64 <= 3.0 * eb * 1.01 + 1e-6);
        }
    }

    #[test]
    fn uneven_chunks_compressed() {
        // Record headers (not counts exchange) size the root's output:
        // wildly different per-rank lengths, including an empty one.
        let n = 5;
        let eb = 1e-3f64;
        let out = run_ranks(n, move |c| {
            let len = if c.rank() == 2 { 0 } else { 100 + c.rank() * 37 };
            let mine = rank_chunk(c.rank(), len);
            let mut m = Metrics::default();
            gather(
                c,
                &mine,
                1,
                &Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(eb)),
                &mut m,
            )
            .unwrap()
        });
        let want: Vec<f32> = (0..n)
            .flat_map(|r| rank_chunk(r, if r == 2 { 0 } else { 100 + r * 37 }))
            .collect();
        let root_out = out[1].as_ref().unwrap();
        assert_eq!(root_out.len(), want.len());
        for (a, b) in root_out.iter().zip(&want) {
            assert!((a - b).abs() as f64 <= eb * 1.001 + 1e-6);
        }
    }
}
