//! Ring allgather — the paper's showcase for the **collective data
//! movement framework** (§3.1.1, Fig. 2, evaluated in Fig. 10).
//!
//! - `Plain`: the textbook N−1-round ring.
//! - `Cprp2p`: the received chunk is decompressed every round and
//!   re-compressed before being forwarded — `(N−1)·T_chunk` compression
//!   cost and `(N−1)×` worst-case error accumulation. This is the
//!   baseline the paper criticises.
//! - `CColl`/`Zccl`: each rank compresses its own chunk exactly **once**
//!   before the intensive communication, all ranks exchange the
//!   compressed sizes (8 bytes each; see `exchange_sizes` in the parent
//!   module), the ring then forwards *compressed* chunks (ZCCL
//!   additionally segments them into a fixed pipeline size so the
//!   communication is balanced despite unequal compressed sizes), and
//!   decompression happens exactly once after the last round.
//!
//! ## Receive side
//!
//! The chunk counts are known up front (the 8-byte count ring), so the
//! output is sized **once** and every received frame follows the pooled
//! zero-copy discipline (parent module docs): wire buffers are leased
//! from the transport's packet pool, arrive by `recv_into` buffer swap,
//! and decode **directly into their final window** of the output via the
//! placement kernel. A warm iterated allgather performs zero byte-buffer
//! allocations and zero post-decode copies on the receive path — the
//! `PoolStats` / `PacketPoolStats` regression tests pin this down.
//!
//! The implementation is written against [`super::ctx::CollState`]: the
//! persistent [`super::CollCtx`] passes its long-lived codec + scratch
//! pool, the free-function shim passes a transient one. The internal
//! entry point takes a chunk-ownership `shift` so the allgather stage of
//! the ring allreduce (where rank `r` owns chunk `(r+1) mod n` after
//! reduce-scatter) reuses the same code.

use super::ctx::CollState;
use super::{
    bytes_to_f32s_into_slice, exchange_sizes, f32s_to_bytes_into, recv_segmented_into,
    send_segmented, Algo, Communicator, Mode,
};
use crate::analysis::plan::AllgatherPlan;
use crate::coordinator::{Metrics, Phase};
use crate::topology::{ring, ring_recv_chunk, ring_send_chunk};
use crate::{Error, Result};

/// Gather every rank's `my_chunk` onto every rank, concatenated in rank
/// order. Chunk lengths may differ across ranks.
///
/// Compatibility shim: builds a transient codec + pool per call. Iterated
/// callers should use [`super::CollCtx::allgather`].
pub fn allgather(
    comm: &mut Communicator,
    my_chunk: &[f32],
    mode: &Mode,
    m: &mut Metrics,
) -> Result<Vec<f32>> {
    allgather_chunks(comm, my_chunk, 0, mode, m)
}

/// Mode-based variant of [`allgather_chunks_with`] (transient state).
pub(crate) fn allgather_chunks(
    comm: &mut Communicator,
    my_chunk: &[f32],
    shift: usize,
    mode: &Mode,
    m: &mut Metrics,
) -> Result<Vec<f32>> {
    let mut st = CollState::new(*mode);
    let mut out = Vec::new();
    allgather_chunks_with(comm, &mut st, my_chunk, shift, m, &mut out)?;
    Ok(out)
}

/// Ring allgather where rank `r` contributes the chunk with logical index
/// `(r + shift) mod n`; `out` is overwritten with the concatenation in
/// logical chunk order.
pub(crate) fn allgather_chunks_with(
    comm: &mut Communicator,
    st: &mut CollState,
    my_chunk: &[f32],
    shift: usize,
    m: &mut Metrics,
    out: &mut Vec<f32>,
) -> Result<()> {
    let n = comm.size();
    if n == 1 {
        out.clear();
        out.extend_from_slice(my_chunk);
        return Ok(());
    }
    if st.mode.algo == Algo::Hier {
        // The hierarchical arm runs its own tiered count exchange (a flat
        // count ring would cross the slow tier between non-leaders). The
        // allreduce stage never reaches here under Hier, so the ownership
        // shift is always zero.
        debug_assert_eq!(shift, 0, "hier allgather is only entered unshifted");
        return super::hier::allgather_hier(comm, st, my_chunk, m, out);
    }
    let plan = AllgatherPlan::at(comm.fresh_tags(AllgatherPlan::span(n)), n);
    let counts_tag = plan.counts_ring().base;
    let sizes_tag = plan.sizes_ring().base;
    let round_tag = |t: usize| plan.round_tag(t);
    let me = comm.rank();

    // Everyone learns every chunk's value count (cheap 8-byte ring).
    let t0 = std::time::Instant::now();
    let by_rank = exchange_sizes(comm, my_chunk.len() as u64, counts_tag)?;
    m.add(Phase::Other, t0.elapsed().as_secs_f64());
    let mut counts = vec![0u64; n];
    for (r, c) in by_rank.iter().enumerate() {
        counts[(r + shift) % n] = *c;
    }
    m.raw_bytes += counts.iter().map(|&c| c * 4).sum::<u64>();
    let vrank = me + shift; // virtual rank for the ring chunk schedule

    // The counts fix every chunk's final window, so the output is sized
    // exactly once and receives decode straight into place. `resize`
    // without a prior `clear()`: a warm same-size iteration truncates or
    // grows nothing and zero-fills nothing — every element is about to
    // be overwritten by its window's decode (or is poisoned on error).
    let mut offsets = Vec::with_capacity(n + 1);
    offsets.push(0usize);
    for &c in &counts {
        offsets.push(offsets.last().unwrap() + c as usize);
    }
    out.resize(offsets[n], 0.0);

    match st.mode.algo {
        Algo::Plain => allgather_plain(comm, st, my_chunk, vrank, &offsets, round_tag, m, out),
        Algo::Cprp2p => allgather_cprp2p(comm, st, my_chunk, vrank, &offsets, round_tag, m, out),
        Algo::CColl | Algo::Zccl => {
            allgather_zccl(comm, st, my_chunk, vrank, &offsets, sizes_tag, round_tag, m, out)
        }
        Algo::Hier => unreachable!("hier allgather dispatched above"),
    }
}

/// The final window of logical chunk `r` in the output.
fn window(offsets: &[usize], r: usize) -> std::ops::Range<usize> {
    offsets[r]..offsets[r + 1]
}

#[allow(clippy::too_many_arguments)]
fn allgather_plain(
    comm: &mut Communicator,
    st: &mut CollState,
    my_chunk: &[f32],
    vrank: usize,
    offsets: &[usize],
    round_tag: impl Fn(usize) -> u64,
    m: &mut Metrics,
    out: &mut Vec<f32>,
) -> Result<()> {
    let n = comm.size();
    let me = comm.rank();
    let nb = ring(me, n);
    let own = vrank % n;
    // Raw chunks forwarded over the ring: our serialisation lives in
    // CollState scratch, received chunks ride leased wire buffers.
    let mut chunks: Vec<Option<Vec<u8>>> = vec![None; n];
    let mut mine = st.pool.take_bytes();
    f32s_to_bytes_into(my_chunk, &mut mine);
    chunks[own] = Some(mine);
    for t in 0..n - 1 {
        let s = ring_send_chunk(vrank, t, n);
        let r = ring_recv_chunk(vrank, t, n);
        let tag = round_tag(t);
        let send_buf = chunks[s].as_ref().expect("ring schedule owns sent chunk");
        let t0 = std::time::Instant::now();
        m.bytes_sent += send_segmented(comm.t, nb.next, tag, send_buf, usize::MAX)?;
        let mut got = comm.t.lease();
        let total = window(offsets, r).len() * 4;
        recv_segmented_into(comm.t, nb.prev, tag, total, usize::MAX, &mut got)?;
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        m.bytes_recv += got.len() as u64;
        chunks[r] = Some(got);
    }
    let t0 = std::time::Instant::now();
    for (r, c) in chunks.into_iter().enumerate() {
        let buf = c.expect("all chunks gathered");
        bytes_to_f32s_into_slice(&buf, &mut out[window(offsets, r)])?;
        if r == own {
            st.pool.put_bytes(buf);
        } else {
            comm.t.recycle(buf);
        }
    }
    m.add(Phase::Other, t0.elapsed().as_secs_f64());
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn allgather_cprp2p(
    comm: &mut Communicator,
    st: &mut CollState,
    my_chunk: &[f32],
    vrank: usize,
    offsets: &[usize],
    round_tag: impl Fn(usize) -> u64,
    m: &mut Metrics,
    out: &mut Vec<f32>,
) -> Result<()> {
    let n = comm.size();
    let me = comm.rank();
    let nb = ring(me, n);
    // CPRP2P keeps chunks DECOMPRESSED between rounds, so every forward
    // re-compresses (and every hop re-lossy-fies) the data. The output
    // itself is the between-rounds store: each received frame decodes
    // straight into its final window, and forwards re-compress from
    // there — no per-chunk value vectors at all.
    let own = vrank % n;
    out[window(offsets, own)].copy_from_slice(my_chunk);
    let mut got = comm.t.lease();
    for t in 0..n - 1 {
        let s = ring_send_chunk(vrank, t, n);
        let r = ring_recv_chunk(vrank, t, n);
        let tag = round_tag(t);
        // Each round's re-compressed frame lands in a transport-leased
        // wire buffer and is sent by value — no packet_from copy.
        let mut frame = comm.t.lease();
        let t0 = std::time::Instant::now();
        st.compress_into(&out[window(offsets, s)], &mut frame)?;
        m.add(Phase::Compress, t0.elapsed().as_secs_f64());
        // The receiver cannot know the compressed size in advance: CPRP2P
        // sends the frame as one message (this is exactly the unbalanced
        // communication §3.1.1 calls out).
        let t0 = std::time::Instant::now();
        m.bytes_sent += frame.len() as u64;
        comm.t.send_pooled(nb.next, tag, frame)?;
        comm.t.recv_into(nb.prev, tag, &mut got)?;
        m.bytes_recv += got.len() as u64;
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        let t0 = std::time::Instant::now();
        st.decode_into_slice(&got, &mut out[window(offsets, r)])
            .map_err(|e| Error::corrupt(format!("cprp2p chunk {r}: {e}")))?;
        m.add(Phase::Decompress, t0.elapsed().as_secs_f64());
    }
    comm.t.recycle(got);
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn allgather_zccl(
    comm: &mut Communicator,
    st: &mut CollState,
    my_chunk: &[f32],
    vrank: usize,
    offsets: &[usize],
    sizes_tag: u64,
    round_tag: impl Fn(usize) -> u64,
    m: &mut Metrics,
    out: &mut Vec<f32>,
) -> Result<()> {
    let n = comm.size();
    let me = comm.rank();
    let nb = ring(me, n);

    // (1) Compress the local chunk exactly once, into pooled scratch.
    let mut mine = st.pool.take_bytes();
    let t0 = std::time::Instant::now();
    st.compress_into(my_chunk, &mut mine)?;
    m.add(Phase::Compress, t0.elapsed().as_secs_f64());

    // (2) Synchronise compressed sizes (8 bytes per rank) so every rank
    //     can run a *balanced*, fixed-pipeline communication schedule.
    let t0 = std::time::Instant::now();
    let by_rank = exchange_sizes(comm, mine.len() as u64, sizes_tag)?;
    m.add(Phase::Other, t0.elapsed().as_secs_f64());
    let mut sizes = vec![0u64; n];
    for (r, s) in by_rank.iter().enumerate() {
        sizes[(r + vrank - me) % n] = *s;
    }

    // (3) N-1 ring rounds forwarding COMPRESSED chunks in fixed segments,
    //     each received into a leased wire buffer.
    let own = vrank % n;
    let mut chunks: Vec<Option<Vec<u8>>> = vec![None; n];
    chunks[own] = Some(mine);
    let seg = if st.mode.algo == Algo::Zccl { st.mode.pipeline_bytes } else { usize::MAX };
    for t in 0..n - 1 {
        let s = ring_send_chunk(vrank, t, n);
        let r = ring_recv_chunk(vrank, t, n);
        let tag = round_tag(t);
        let send_buf = chunks[s].as_ref().expect("schedule");
        let t0 = std::time::Instant::now();
        m.bytes_sent += send_segmented(comm.t, nb.next, tag, send_buf, seg)?;
        let mut got = comm.t.lease();
        recv_segmented_into(comm.t, nb.prev, tag, sizes[r] as usize, seg, &mut got)?;
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());
        m.bytes_recv += got.len() as u64;
        chunks[r] = Some(got);
    }

    // (4) Placement-decode everything exactly once, after the last round
    //     (including our own frame, so every rank returns identical data —
    //     MPI allgather semantics), each frame straight into its final
    //     window of the output.
    for (r, c) in chunks.into_iter().enumerate() {
        let frame = c.expect("all chunks gathered");
        let t0 = std::time::Instant::now();
        st.decode_into_slice(&frame, &mut out[window(offsets, r)])
            .map_err(|e| Error::corrupt(format!("zccl chunk {r}: {e}")))?;
        m.add(Phase::Decompress, t0.elapsed().as_secs_f64());
        if r == own {
            // Our frame came from the scratch pool; received frames go
            // back to the transport's packet pool.
            st.pool.put_bytes(frame);
        } else {
            comm.t.recycle(frame);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::run_ranks;
    use crate::compress::{CompressorKind, ErrorBound};
    use crate::data::fields::{Field, FieldKind};

    fn rank_chunk(rank: usize, len: usize) -> Vec<f32> {
        Field::generate(FieldKind::Cesm, len, 100 + rank as u64).values
    }

    fn expected(n: usize, len: usize) -> Vec<f32> {
        (0..n).flat_map(|r| rank_chunk(r, len)).collect()
    }

    #[test]
    fn plain_exact() {
        for n in [2usize, 3, 5, 8] {
            let out = run_ranks(n, move |c| {
                let mine = rank_chunk(c.rank(), 1000);
                let mut m = Metrics::default();
                allgather(c, &mine, &Mode::plain(), &mut m).unwrap()
            });
            let want = expected(n, 1000);
            for o in out {
                assert_eq!(o, want);
            }
        }
    }

    #[test]
    fn plain_unequal_chunks() {
        let n = 4;
        let out = run_ranks(n, move |c| {
            let mine = rank_chunk(c.rank(), 100 + c.rank() * 37);
            let mut m = Metrics::default();
            allgather(c, &mine, &Mode::plain(), &mut m).unwrap()
        });
        let want: Vec<f32> = (0..n).flat_map(|r| rank_chunk(r, 100 + r * 37)).collect();
        for o in out {
            assert_eq!(o, want);
        }
    }

    #[test]
    fn shifted_ownership() {
        // Rank r holds the chunk with logical index (r+1) mod n — the
        // allreduce allgather stage's layout.
        let n = 5;
        let out = run_ranks(n, move |c| {
            let idx = (c.rank() + 1) % n;
            let mine = rank_chunk(idx, 64);
            let mut m = Metrics::default();
            allgather_chunks(c, &mine, 1, &Mode::plain(), &mut m).unwrap()
        });
        let want = expected(n, 64);
        for o in out {
            assert_eq!(o, want);
        }
    }

    #[test]
    fn zccl_bounded_single_compression() {
        let n = 6;
        let eb = 1e-3f64;
        let out = run_ranks(n, move |c| {
            let mine = rank_chunk(c.rank(), 2048);
            let mut m = Metrics::default();
            let r = allgather(
                c,
                &mine,
                &Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(eb)),
                &mut m,
            )
            .unwrap();
            (r, m)
        });
        let want = expected(n, 2048);
        for (o, _) in &out {
            assert_eq!(o.len(), want.len());
            // ZCCL data-movement guarantee: each datum compressed ONCE, so
            // error <= eb (not (N-1)·eb).
            for (a, b) in o.iter().zip(&want) {
                assert!((a - b).abs() as f64 <= eb * 1.001 + 1e-6, "|{a}-{b}| > {eb}");
            }
        }
        // All ranks produce identical output (MPI semantics).
        for (o, _) in &out[1..] {
            assert_eq!(o, &out[0].0);
        }
    }

    #[test]
    fn ccoll_uses_szx_and_is_bounded() {
        let n = 4;
        let eb = 1e-2f64;
        let out = run_ranks(n, move |c| {
            let mine = rank_chunk(c.rank(), 1500);
            let mut m = Metrics::default();
            allgather(c, &mine, &Mode::ccoll(ErrorBound::Abs(eb)), &mut m).unwrap()
        });
        let want = expected(n, 1500);
        for o in out {
            for (a, b) in o.iter().zip(&want) {
                assert!((a - b).abs() as f64 <= eb * 1.001 + 1e-6);
            }
        }
    }

    #[test]
    fn cprp2p_error_can_accumulate_but_stays_n_eb() {
        let n = 5;
        let eb = 1e-3f64;
        let out = run_ranks(n, move |c| {
            let mine = rank_chunk(c.rank(), 1024);
            let mut m = Metrics::default();
            allgather(
                c,
                &mine,
                &Mode::cprp2p(CompressorKind::FzLight, ErrorBound::Abs(eb)),
                &mut m,
            )
            .unwrap()
        });
        let want = expected(n, 1024);
        for o in out {
            for (a, b) in o.iter().zip(&want) {
                // Worst case (N-1)·eb per §3.1.1.
                assert!((a - b).abs() as f64 <= (n as f64 - 1.0) * eb * 1.001 + 1e-6);
            }
        }
    }

    #[test]
    fn zccl_compresses_once_cprp2p_many_times() {
        // The framework's core claim, observable through the metrics: the
        // ZCCL compression phase is ~1 chunk's worth, CPRP2P's is ~(N-1)×.
        let n = 6;
        let modes: Vec<(&str, Mode)> = vec![
            ("zccl", Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(1e-3))),
            ("cprp2p", Mode::cprp2p(CompressorKind::FzLight, ErrorBound::Abs(1e-3))),
        ];
        let mut compress_time = std::collections::HashMap::new();
        for (name, mode) in modes {
            let out = run_ranks(n, move |c| {
                let mine = rank_chunk(c.rank(), 1 << 15);
                let mut m = Metrics::default();
                allgather(c, &mine, &mode, &mut m).unwrap();
                m.compress_s
            });
            compress_time.insert(name, out.iter().sum::<f64>() / n as f64);
        }
        assert!(
            compress_time["cprp2p"] > 2.0 * compress_time["zccl"],
            "cprp2p {:.6}s should dwarf zccl {:.6}s",
            compress_time["cprp2p"],
            compress_time["zccl"]
        );
    }

    #[test]
    fn single_rank_identity() {
        let out = run_ranks(1, |c| {
            let mut m = Metrics::default();
            allgather(c, &[1.0, 2.0], &Mode::plain(), &mut m).unwrap()
        });
        assert_eq!(out[0], vec![1.0, 2.0]);
    }

    #[test]
    fn into_variant_reuses_destination() {
        let n = 3;
        let out = run_ranks(n, move |c| {
            let mut ctx = crate::collectives::CollCtx::over(
                c,
                Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(1e-3)),
            );
            let mine = rank_chunk(ctx.rank(), 512);
            let mut dst = Vec::new();
            ctx.allgather_into(&mine, &mut dst).unwrap();
            let cap = dst.capacity();
            ctx.allgather_into(&mine, &mut dst).unwrap();
            assert_eq!(dst.capacity(), cap, "second call must not regrow dst");
            dst
        });
        let want = expected(n, 512);
        for o in &out {
            for (a, b) in o.iter().zip(&want) {
                assert!((a - b).abs() as f64 <= 1e-3 * 1.001 + 1e-6);
            }
        }
    }

    #[test]
    fn empty_contributions_are_handled() {
        // Some ranks contribute nothing (the allreduce stage hits this
        // when len < n): their windows are empty and must not disturb the
        // placement decode of their neighbours. Covers all three receive
        // structures: raw ring (Plain), output-as-store with per-hop
        // recompression (Cprp2p), and compressed frames (Zccl).
        let n = 4;
        for mode in [
            Mode::plain(),
            Mode::cprp2p(CompressorKind::FzLight, ErrorBound::Abs(1e-3)),
            Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(1e-3)),
        ] {
            let out = run_ranks(n, move |c| {
                let mine = if c.rank() % 2 == 0 { rank_chunk(c.rank(), 33) } else { Vec::new() };
                let mut m = Metrics::default();
                allgather(c, &mine, &mode, &mut m).unwrap()
            });
            let want: Vec<f32> = (0..n)
                .flat_map(|r| if r % 2 == 0 { rank_chunk(r, 33) } else { Vec::new() })
                .collect();
            for o in out {
                assert_eq!(o.len(), want.len(), "{:?}", mode.algo);
                for (a, b) in o.iter().zip(&want) {
                    // CPRP2P may accumulate up to (n-1)·eb; the others stay
                    // within a single eb.
                    assert!(
                        (a - b).abs() as f64 <= (n as f64 - 1.0) * 1e-3 * 1.01 + 1e-6,
                        "{:?}: {a} vs {b}",
                        mode.algo
                    );
                }
            }
        }
    }
}
