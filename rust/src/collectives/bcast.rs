//! Binomial-tree broadcast — "Z-Bcast" (§3.1.1 Fig. 3, evaluated Fig. 14).
//!
//! - `Plain`: MPICH's binomial tree, raw payloads.
//! - `Cprp2p`: every hop decompresses on receive and re-compresses on
//!   forward: `log2(N)·(T_comp + T_decom)` cost and `log2(N)×` worst-case
//!   error accumulation.
//! - `CColl`/`Zccl`: the root compresses **once**; interior ranks forward
//!   the compressed frame verbatim; every rank decompresses once. Cost
//!   collapses to `T_comp + T_decom` and the error to a single `ê`.

use super::ctx::CollState;
use super::{bytes_to_f32s_into_slice, f32s_to_bytes_into, Algo, Communicator, Mode};
use crate::analysis::plan::TreePlan;
use crate::coordinator::{Metrics, Phase};
use crate::topology::binomial_bcast;
use crate::{Error, Result};

/// Broadcast `data` (significant at `root` only) to every rank.
///
/// Compatibility shim: builds a transient codec per call. Iterated
/// callers should use [`super::CollCtx::bcast`].
pub fn bcast(
    comm: &mut Communicator,
    data: Option<&[f32]>,
    root: usize,
    mode: &Mode,
    m: &mut Metrics,
) -> Result<Vec<f32>> {
    let mut st = CollState::new(*mode);
    bcast_with(comm, &mut st, data, root, m)
}

/// [`bcast`] against a persistent [`CollState`] (codec built once).
pub(crate) fn bcast_with(
    comm: &mut Communicator,
    st: &mut CollState,
    data: Option<&[f32]>,
    root: usize,
    m: &mut Metrics,
) -> Result<Vec<f32>> {
    let n = comm.size();
    let me = comm.rank();
    if root >= n {
        return Err(Error::invalid(format!("root {root} out of {n}")));
    }
    if me == root && data.is_none() {
        return Err(Error::invalid("root must supply data"));
    }
    if n == 1 {
        return Ok(data.unwrap().to_vec());
    }
    if st.mode.algo == Algo::Hier {
        // Two-level schedule: root compresses once, the frame travels the
        // leader tree over the slow tier, leaders decode once per node
        // and fan out raw over the fast tier.
        return super::hier::bcast_hier(comm, st, data, root, m);
    }
    let plan = TreePlan::at(comm.fresh_tags(TreePlan::span(n)), n);
    let (recv_step, send_steps) = binomial_bcast(me, root, n);

    match st.mode.algo {
        Algo::Plain => {
            let (buf, pooled): (Vec<u8>, bool) = if me == root {
                let d = data.unwrap();
                m.raw_bytes += (d.len() * 4) as u64;
                let mut b = st.pool.take_bytes();
                f32s_to_bytes_into(d, &mut b);
                (b, true)
            } else {
                let step = recv_step.expect("non-root receives");
                let mut got = comm.t.lease();
                let t0 = std::time::Instant::now();
                comm.t.recv_into(step.peer, plan.step_tag(step.round), &mut got)?;
                m.add(Phase::Comm, t0.elapsed().as_secs_f64());
                m.bytes_recv += got.len() as u64;
                (got, false)
            };
            for s in send_steps {
                let t0 = std::time::Instant::now();
                comm.t.send(s.peer, plan.step_tag(s.round), &buf)?;
                m.add(Phase::Comm, t0.elapsed().as_secs_f64());
                m.bytes_sent += buf.len() as u64;
            }
            let mut out = vec![0.0f32; buf.len() / 4];
            bytes_to_f32s_into_slice(&buf, &mut out)?;
            if pooled {
                st.pool.put_bytes(buf);
            } else {
                comm.t.recycle(buf);
            }
            Ok(out)
        }
        Algo::Cprp2p => {
            // Every rank holds DECOMPRESSED data between hops.
            let plain: Vec<f32> = if me == root {
                let d = data.unwrap();
                m.raw_bytes += (d.len() * 4) as u64;
                d.to_vec()
            } else {
                let step = recv_step.expect("non-root receives");
                let mut got = comm.t.lease();
                let t0 = std::time::Instant::now();
                comm.t.recv_into(step.peer, plan.step_tag(step.round), &mut got)?;
                m.add(Phase::Comm, t0.elapsed().as_secs_f64());
                m.bytes_recv += got.len() as u64;
                // Placement decode straight into the (once-sized) result;
                // `checked_count` bounds the claimed count against the
                // frame's physical size before anything is allocated.
                let cnt = crate::compress::checked_count(&got)?;
                let mut dec = vec![0.0f32; cnt];
                let t0 = std::time::Instant::now();
                st.decode_into_slice(&got, &mut dec)?;
                m.add(Phase::Decompress, t0.elapsed().as_secs_f64());
                comm.t.recycle(got);
                dec
            };
            for s in send_steps {
                // Re-compress for every forward (the CPRP2P pathology),
                // straight into a transport-leased buffer sent by value.
                let mut frame = comm.t.lease();
                let t0 = std::time::Instant::now();
                st.compress_into(&plain, &mut frame)?;
                m.add(Phase::Compress, t0.elapsed().as_secs_f64());
                let t0 = std::time::Instant::now();
                m.bytes_sent += frame.len() as u64;
                comm.t.send_pooled(s.peer, plan.step_tag(s.round), frame)?;
                m.add(Phase::Comm, t0.elapsed().as_secs_f64());
            }
            Ok(plain)
        }
        Algo::CColl | Algo::Zccl => {
            // Root compresses once; the frame travels the tree verbatim
            // (received into a leased wire buffer on every hop).
            let (frame, pooled): (Vec<u8>, bool) = if me == root {
                let d = data.unwrap();
                m.raw_bytes += (d.len() * 4) as u64;
                let mut f = st.pool.take_bytes();
                let t0 = std::time::Instant::now();
                st.compress_into(d, &mut f)?;
                m.add(Phase::Compress, t0.elapsed().as_secs_f64());
                (f, true)
            } else {
                let step = recv_step.expect("non-root receives");
                let mut got = comm.t.lease();
                let t0 = std::time::Instant::now();
                comm.t.recv_into(step.peer, plan.step_tag(step.round), &mut got)?;
                m.add(Phase::Comm, t0.elapsed().as_secs_f64());
                m.bytes_recv += got.len() as u64;
                (got, false)
            };
            for s in send_steps {
                let t0 = std::time::Instant::now();
                comm.t.send(s.peer, plan.step_tag(s.round), &frame)?;
                m.add(Phase::Comm, t0.elapsed().as_secs_f64());
                m.bytes_sent += frame.len() as u64;
            }
            // Placement-decode exactly once, after forwarding (so children
            // are not delayed behind our decompression): the header's
            // size-bounded element count sizes the result, the frame
            // decodes into it directly.
            let cnt = crate::compress::checked_count(&frame)?;
            let mut out = vec![0.0f32; cnt];
            let t0 = std::time::Instant::now();
            st.decode_into_slice(&frame, &mut out)?;
            m.add(Phase::Decompress, t0.elapsed().as_secs_f64());
            if pooled {
                st.pool.put_bytes(frame);
            } else {
                comm.t.recycle(frame);
            }
            Ok(out)
        }
        Algo::Hier => unreachable!("hier bcast dispatched above"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::run_ranks;
    use crate::compress::{CompressorKind, ErrorBound};
    use crate::data::fields::{Field, FieldKind};

    fn payload(len: usize) -> Vec<f32> {
        Field::generate(FieldKind::Rtm, len, 321).values
    }

    #[test]
    fn plain_exact_all_roots_and_sizes() {
        for n in [2usize, 3, 5, 8, 9] {
            for root in [0, n - 1, n / 2] {
                let out = run_ranks(n, move |c| {
                    let data = (c.rank() == root).then(|| payload(1234));
                    let mut m = Metrics::default();
                    bcast(c, data.as_deref(), root, &Mode::plain(), &mut m).unwrap()
                });
                let want = payload(1234);
                for o in out {
                    assert_eq!(o, want, "n={n} root={root}");
                }
            }
        }
    }

    #[test]
    fn zccl_single_eb_error() {
        let n = 8;
        let eb = 1e-3f64;
        let out = run_ranks(n, move |c| {
            let data = (c.rank() == 0).then(|| payload(10_000));
            let mut m = Metrics::default();
            let r = bcast(
                c,
                data.as_deref(),
                0,
                &Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(eb)),
                &mut m,
            )
            .unwrap();
            (r, m)
        });
        let want = payload(10_000);
        for (o, _) in &out {
            for (a, b) in o.iter().zip(&want) {
                // ZCCL bcast: exactly one compression regardless of depth.
                assert!((a - b).abs() as f64 <= eb * 1.001 + 1e-6);
            }
        }
        // All ranks identical (they decompress the same frame).
        for (o, _) in &out[1..] {
            assert_eq!(o, &out[0].0);
        }
        // Only the root compresses.
        for (rank, (_, m)) in out.iter().enumerate() {
            if rank == 0 {
                assert!(m.compress_s > 0.0);
            } else {
                assert_eq!(m.compress_s, 0.0, "rank {rank} must not compress");
            }
        }
    }

    #[test]
    fn cprp2p_error_grows_with_depth_bound() {
        let n = 8; // depth log2(8) = 3
        let eb = 1e-3f64;
        let out = run_ranks(n, move |c| {
            let data = (c.rank() == 0).then(|| payload(4096));
            let mut m = Metrics::default();
            bcast(
                c,
                data.as_deref(),
                0,
                &Mode::cprp2p(CompressorKind::FzLight, ErrorBound::Abs(eb)),
                &mut m,
            )
            .unwrap()
        });
        let want = payload(4096);
        for o in out {
            for (a, b) in o.iter().zip(&want) {
                assert!((a - b).abs() as f64 <= 3.0 * eb * 1.01 + 1e-6);
            }
        }
    }

    #[test]
    fn nonroot_without_data_ok_root_without_data_err() {
        let out = run_ranks(2, |c| {
            let mut m = Metrics::default();
            if c.rank() == 0 {
                bcast(c, Some(&[1.0, 2.0]), 0, &Mode::plain(), &mut m).unwrap()
            } else {
                bcast(c, None, 0, &Mode::plain(), &mut m).unwrap()
            }
        });
        assert_eq!(out[1], vec![1.0, 2.0]);
    }

    #[test]
    fn nonzero_root_compressed() {
        let n = 5;
        let eb = 1e-2f64;
        let root = 3;
        let out = run_ranks(n, move |c| {
            let data = (c.rank() == root).then(|| payload(2000));
            let mut m = Metrics::default();
            bcast(c, data.as_deref(), root, &Mode::ccoll(ErrorBound::Abs(eb)), &mut m).unwrap()
        });
        let want = payload(2000);
        for o in out {
            for (a, b) in o.iter().zip(&want) {
                assert!((a - b).abs() as f64 <= eb * 1.001 + 1e-6);
            }
        }
    }
}
