//! Ring reduce-scatter — the paper's showcase for the **collective
//! computation framework** (§3.1.2, Fig. 4, evaluated in Fig. 11).
//!
//! Unlike data movement, the transferred data is *updated* every round
//! (partial sums), so compression cannot be hoisted out of the loop.
//! Instead ZCCL hides communication inside compression: each round posts
//! the nonblocking receive first, then runs `PIPE-fZ-light`, whose
//! progress hook polls the receive between 5120-value chunks (§3.5.2).
//!
//! Mode behaviour per round:
//! - `Plain`: send raw partials, receive, fold straight from the wire.
//! - `Cprp2p`: blocking compress → send → recv → fused decompress–reduce.
//! - `CColl`: same structure as `Cprp2p` but with SZx (the IPDPS'24
//!   baseline had no compression/communication overlap in this stage).
//! - `Zccl`: irecv → PIPE-compress (polling) → send → wait →
//!   PIPE fused decompress–reduce (polling the next send's progress
//!   slot between chunks).
//!
//! Every receive side is **fused** (§3.4–§3.5, Fig. 4): received partials
//! are never materialized — the decoder folds each reconstructed value
//! straight into the accumulator via
//! [`crate::compress::Compressor::decompress_fold_into`], and constant
//! fZ-light blocks fold as one broadcast over the run. The per-hop cost
//! drops from decode-pass + reduce-pass (plus a pooled partial buffer) to
//! a single pass, timed as [`Phase::DecompressReduce`].

use super::ctx::CollState;
use super::{
    chunk_ranges, f32s_to_bytes_into, fold_f32_bytes, Algo, Communicator, Mode, ReduceOp,
};
use crate::analysis::plan::RingPlan;
use crate::coordinator::{Metrics, Phase};
use crate::topology::{ring, ring_recv_chunk, ring_send_chunk};
use crate::{Error, Result};

/// Reduce `input` (same length on every rank) elementwise with `op` and
/// scatter the result: rank `r` returns `(range, values)` where `range`
/// is the slice of the logical result it owns (chunk `(r+1) mod n`).
///
/// Compatibility shim: builds a transient codec + pool per call. Iterated
/// callers should use [`super::CollCtx::reduce_scatter`].
pub fn reduce_scatter(
    comm: &mut Communicator,
    input: &[f32],
    op: ReduceOp,
    mode: &Mode,
    m: &mut Metrics,
) -> Result<(std::ops::Range<usize>, Vec<f32>)> {
    let mut st = CollState::new(*mode);
    let mut owned = Vec::new();
    let range = reduce_scatter_with(comm, &mut st, input, op, m, &mut owned)?;
    Ok((range, owned))
}

/// [`reduce_scatter`] against a persistent [`CollState`]; the owned chunk
/// is written into `owned` (overwritten), and its range returned.
pub(crate) fn reduce_scatter_with(
    comm: &mut Communicator,
    st: &mut CollState,
    input: &[f32],
    op: ReduceOp,
    m: &mut Metrics,
    owned: &mut Vec<f32>,
) -> Result<std::ops::Range<usize>> {
    let n = comm.size();
    let me = comm.rank();
    owned.clear();
    if n == 1 {
        owned.extend_from_slice(input);
        return Ok(0..input.len());
    }
    if st.mode.algo == Algo::Hier {
        return super::hier::reduce_scatter_hier(comm, st, input, op, m, owned);
    }
    let plan = RingPlan::at(comm.fresh_tags(RingPlan::span(n)), n);
    let ranges = chunk_ranges(input.len(), n);
    let nb = ring(me, n);
    let mut acc = st.pool.take_f32();
    acc.extend_from_slice(input);
    m.raw_bytes += (input.len() * 4) as u64 * (n as u64 - 1) / n as u64 * 2;

    match st.mode.algo {
        Algo::Plain => {
            let mut got = comm.t.lease();
            for t in 0..n - 1 {
                let s = &ranges[ring_send_chunk(me, t, n)];
                let r = &ranges[ring_recv_chunk(me, t, n)];
                // Serialise into a transport-leased wire buffer and hand
                // it over by value: the packet IS the buffer (zero-copy
                // send); the pool keeps warm rounds allocation-free.
                let mut send_buf = comm.t.lease();
                f32s_to_bytes_into(&acc[s.clone()], &mut send_buf);
                let t0 = std::time::Instant::now();
                m.bytes_sent += send_buf.len() as u64;
                comm.t.send_pooled(nb.next, plan.round_tag(t), send_buf)?;
                comm.t.recv_into(nb.prev, plan.round_tag(t), &mut got)?;
                m.bytes_recv += got.len() as u64;
                m.add(Phase::Comm, t0.elapsed().as_secs_f64());
                // Fold straight from the wire bytes — no partial vector.
                let t0 = std::time::Instant::now();
                fold_f32_bytes(op, &got, &mut acc[r.clone()])?;
                m.add(Phase::Compute, t0.elapsed().as_secs_f64());
            }
            comm.t.recycle(got);
        }
        Algo::Cprp2p | Algo::CColl => {
            let mut got = comm.t.lease();
            for t in 0..n - 1 {
                let s = &ranges[ring_send_chunk(me, t, n)];
                let r = &ranges[ring_recv_chunk(me, t, n)];
                // Compress straight into a transport-leased wire buffer —
                // the frame is sent once, by value, with no packet_from
                // copy.
                let mut frame = comm.t.lease();
                let t0 = std::time::Instant::now();
                st.compress_into(&acc[s.clone()], &mut frame)?;
                m.add(Phase::Compress, t0.elapsed().as_secs_f64());
                let t0 = std::time::Instant::now();
                m.bytes_sent += frame.len() as u64;
                comm.t.send_pooled(nb.next, plan.round_tag(t), frame)?;
                comm.t.recv_into(nb.prev, plan.round_tag(t), &mut got)?;
                m.bytes_recv += got.len() as u64;
                m.add(Phase::Comm, t0.elapsed().as_secs_f64());
                // Fused decompress–reduce: the frame folds straight into
                // the owned accumulator range (length-checked inside).
                let t0 = std::time::Instant::now();
                st.decode_fold_into(&got, op, &mut acc[r.clone()])?;
                m.add(Phase::DecompressReduce, t0.elapsed().as_secs_f64());
            }
            comm.t.recycle(got);
        }
        // Hier dispatched to its two-level schedule above; its leader
        // tier re-enters here over a GroupTransport with the algo
        // switched to Zccl, so this arm carries both (the Hier pattern is
        // kept for match exhaustiveness).
        Algo::Zccl | Algo::Hier => {
            reduce_scatter_zccl(comm, st, &mut acc, &ranges, op, plan, m)?;
        }
    }

    let own = (me + 1) % n;
    owned.extend_from_slice(&acc[ranges[own].clone()]);
    st.pool.put_f32(acc);
    Ok(ranges[own].clone())
}

/// The §3.5.2 pipelined round: communication progress is pulled from
/// inside compression and decompression.
fn reduce_scatter_zccl(
    comm: &mut Communicator,
    st: &mut CollState,
    acc: &mut [f32],
    ranges: &[std::ops::Range<usize>],
    op: ReduceOp,
    plan: RingPlan,
    m: &mut Metrics,
) -> Result<()> {
    let n = comm.size();
    let me = comm.rank();
    let nb = ring(me, n);
    // PIPE overlap requires the chunked fZ-light codec (pre-built in the
    // context); other codecs fall back to the blocking structure (still
    // compress-per-round — that is inherent to collective computation).
    let pipe = st.pipe.clone();
    let mode = st.mode;
    let mut got = comm.t.lease();

    // Round 0's receive is posted before any compression, and every later
    // round's receive is posted before the *previous* round's fold — so
    // both the compression hook and the fold hook always have a live
    // handle to poll (§3.5.2).
    let mut h = comm.t.irecv(nb.prev, plan.round_tag(0));
    for t in 0..n - 1 {
        let s = &ranges[ring_send_chunk(me, t, n)];
        let r = &ranges[ring_recv_chunk(me, t, n)];
        let tag = plan.round_tag(t);
        // The per-round frame compresses straight into a transport-leased
        // wire buffer: it is sent once, by value (no packet_from copy),
        // and its capacity circulates back through the pool.
        let mut frame = comm.t.lease();

        match &pipe {
            Some(p) => {
                let t0 = std::time::Instant::now();
                {
                    let tr = &mut *comm.t;
                    p.compress_into_with_progress(&acc[s.clone()], mode.eb, &mut frame, &mut |_| {
                        let _ = tr.try_complete(&mut h);
                    })?;
                }
                st.compress_calls += 1; // PIPE path bypasses CollState::compress_into

                // Time spent here covers compression AND the polls it
                // absorbed — that is precisely the §3.5.2 effect (comm
                // hidden inside compression).
                m.add(Phase::Compress, t0.elapsed().as_secs_f64());
            }
            None => {
                let t0 = std::time::Instant::now();
                st.compress_into(&acc[s.clone()], &mut frame)?;
                m.add(Phase::Compress, t0.elapsed().as_secs_f64());
            }
        }

        let t0 = std::time::Instant::now();
        m.bytes_sent += frame.len() as u64;
        comm.t.send_pooled(nb.next, tag, frame)?;
        // Pool-aware completion: the payload lands in the leased wire
        // buffer by swap. Bounded spin then yield, so a straggling peer
        // does not pin a core.
        let mut backoff = crate::transport::Backoff::until(comm.t.timeout());
        while !comm.t.try_complete_into(&mut h, &mut got)? {
            backoff.snooze();
            if backoff.is_yielding() {
                comm.t.check_abort()?;
                if backoff.expired() {
                    return Err(Error::timeout(vec![(h.from, h.tag)]));
                }
            }
        }
        m.bytes_recv += got.len() as u64;
        m.add(Phase::Comm, t0.elapsed().as_secs_f64());

        // Post the NEXT round's receive before folding this one, so the
        // fold has real communication to pull forward.
        let mut next_h = (t + 1 < n - 1).then(|| comm.t.irecv(nb.prev, plan.round_tag(t + 1)));

        // Fused decompress–reduce straight into the accumulator. With
        // PIPE the per-chunk hook keeps the §3.5.2 overlap slot: it polls
        // the next round's already-posted receive (last round: it pulls
        // transport-wide progress instead, draining whatever concurrent
        // traffic has arrived).
        match &pipe {
            Some(p) => {
                let t0 = std::time::Instant::now();
                {
                    let tr = &mut *comm.t;
                    p.decompress_fold_into_with_progress(&got, op, &mut acc[r.clone()], &mut |_| {
                        match next_h.as_mut() {
                            Some(nh) => {
                                let _ = tr.try_complete(nh);
                            }
                            None => {
                                let _ = tr.progress();
                            }
                        }
                    })?;
                }
                m.add(Phase::DecompressReduce, t0.elapsed().as_secs_f64());
            }
            None => {
                let t0 = std::time::Instant::now();
                st.decode_fold_into(&got, op, &mut acc[r.clone()])?;
                m.add(Phase::DecompressReduce, t0.elapsed().as_secs_f64());
            }
        }
        if let Some(nh) = next_h {
            h = nh;
        }
    }
    comm.t.recycle(got);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::run_ranks;
    use crate::compress::{CompressorKind, ErrorBound};
    use crate::data::fields::{Field, FieldKind};

    fn rank_input(rank: usize, len: usize) -> Vec<f32> {
        Field::generate(FieldKind::Hurricane, len, 500 + rank as u64).values
    }

    fn serial_reduce(n: usize, len: usize, op: ReduceOp) -> Vec<f32> {
        let mut acc = rank_input(0, len);
        for r in 1..n {
            op.fold(&mut acc, &rank_input(r, len));
        }
        acc
    }

    #[test]
    fn plain_matches_serial_sum() {
        let (n, len) = (4, 1000);
        let out = run_ranks(n, move |c| {
            let input = rank_input(c.rank(), len);
            let mut m = Metrics::default();
            reduce_scatter(c, &input, ReduceOp::Sum, &Mode::plain(), &mut m).unwrap()
        });
        let want = serial_reduce(n, len, ReduceOp::Sum);
        for (rank, (range, vals)) in out.into_iter().enumerate() {
            assert_eq!(range, chunk_ranges(len, n)[(rank + 1) % n]);
            for (a, b) in vals.iter().zip(&want[range]) {
                assert!((a - b).abs() < 1e-4, "rank {rank}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn plain_max_min() {
        let (n, len) = (5, 777);
        for op in [ReduceOp::Max, ReduceOp::Min] {
            let out = run_ranks(n, move |c| {
                let input = rank_input(c.rank(), len);
                let mut m = Metrics::default();
                reduce_scatter(c, &input, op, &Mode::plain(), &mut m).unwrap()
            });
            let want = serial_reduce(n, len, op);
            for (range, vals) in out {
                assert_eq!(vals.as_slice(), &want[range]);
            }
        }
    }

    #[test]
    fn zccl_sum_within_aggregated_bound() {
        // Theorem 1 (worst case): the aggregated error of the sum chain is
        // at most (n-1)·ê deterministically.
        let (n, len) = (6, 4096);
        let eb = 1e-3f64;
        let out = run_ranks(n, move |c| {
            let input = rank_input(c.rank(), len);
            let mut m = Metrics::default();
            reduce_scatter(
                c,
                &input,
                ReduceOp::Sum,
                &Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(eb)),
                &mut m,
            )
            .unwrap()
        });
        let want = serial_reduce(n, len, ReduceOp::Sum);
        for (range, vals) in out {
            for (a, b) in vals.iter().zip(&want[range]) {
                let tol = (n as f64) * eb * 1.01 + 1e-5;
                assert!(((a - b).abs() as f64) <= tol, "{a} vs {b} tol {tol}");
            }
        }
    }

    #[test]
    fn all_modes_agree_on_smooth_data() {
        let (n, len) = (4, 2048);
        let eb = 1e-4f64;
        let want = serial_reduce(n, len, ReduceOp::Sum);
        for mode in [
            Mode::plain(),
            Mode::cprp2p(CompressorKind::FzLight, ErrorBound::Abs(eb)),
            Mode::ccoll(ErrorBound::Abs(eb)),
            Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(eb)),
            Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(eb)).with_multithread(true),
        ] {
            let out = run_ranks(n, move |c| {
                let input = rank_input(c.rank(), len);
                let mut m = Metrics::default();
                reduce_scatter(c, &input, ReduceOp::Sum, &mode, &mut m).unwrap()
            });
            for (range, vals) in out {
                for (a, b) in vals.iter().zip(&want[range]) {
                    assert!(
                        ((a - b).abs() as f64) <= (n as f64) * eb * 1.01 + 1e-5,
                        "mode {:?}: {a} vs {b}",
                        mode.algo
                    );
                }
            }
        }
    }

    #[test]
    fn uneven_length() {
        let (n, len) = (3, 1001); // not divisible
        let out = run_ranks(n, move |c| {
            let input = rank_input(c.rank(), len);
            let mut m = Metrics::default();
            reduce_scatter(c, &input, ReduceOp::Sum, &Mode::plain(), &mut m).unwrap()
        });
        let want = serial_reduce(n, len, ReduceOp::Sum);
        let mut covered = vec![false; len];
        for (range, vals) in out {
            for (i, v) in range.clone().zip(vals) {
                assert!((v - want[i]).abs() < 1e-4);
                covered[i] = true;
            }
        }
        assert!(covered.iter().all(|&c| c), "owned chunks must cover the input");
    }

    #[test]
    fn single_rank() {
        let out = run_ranks(1, |c| {
            let mut m = Metrics::default();
            reduce_scatter(c, &[3.0, 4.0], ReduceOp::Sum, &Mode::plain(), &mut m).unwrap()
        });
        assert_eq!(out[0].1, vec![3.0, 4.0]);
    }
}
