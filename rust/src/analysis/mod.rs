//! Static analysis of collective communication schedules.
//!
//! Every collective in this crate derives its wire choreography — peers,
//! tags, message order — from the pure plan descriptions in [`plan`]
//! plus the schedule generators in [`crate::topology`]. Because those
//! inputs are deterministic functions of `(collective, Algo, nranks,
//! Topology, root)`, the full message graph of any call can be computed
//! *without running it*. This module does exactly that and proves
//! schedule-safety properties over the result:
//!
//! - [`plan`] — tag-window layouts shared by the executors and the
//!   analyzer (the single source of truth; executors import these).
//! - [`graph`] — builds the symbolic per-rank send/recv scripts for any
//!   collective shape, including the hierarchical arm's inner leader
//!   communicator after `GroupTransport` tag translation.
//! - [`verify`] — checks deadlock-freedom, send/recv match
//!   completeness, tag-space safety (disjoint reservations, namespace
//!   separation, per-link fan-window disjointness), and buffer-window
//!   disjointness; sweeps all arms via [`verify::verify_all`].
//!
//! The sweep runs as `zccl verify` (an enforcing CI gate) and the graphs
//! are cross-validated against real traffic by the ledger property test
//! in `tests/schedule_verifier.rs`: a traced in-memory fabric must
//! record *exactly* the per-`(src, dst, tag)` message counts the graph
//! predicts.

pub mod graph;
pub mod plan;
pub mod verify;
