//! Static checks over symbolic schedules ([`crate::analysis::graph`]):
//! prove, for every swept `(collective, algorithm, ranks, topology,
//! root)` shape, that the wire choreography is deadlock-free, fully
//! matched, tag-safe, and buffer-disjoint — before any test spawns a
//! thread.
//!
//! Four families of checks run per case:
//!
//! 1. **Deadlock-freedom** — a dataflow simulation over the per-rank
//!    scripts: sends are buffered (both transports accept without
//!    rendezvous), receives block on a `(src, dst, tag)` count. If the
//!    simulation wedges with events outstanding, the real schedule can
//!    wedge too.
//! 2. **Match completeness** — every send is consumed by exactly one
//!    receive and vice versa (no orphan sends leaking buffers or stale
//!    messages into later ops, no receive waiting on a message nobody
//!    sends).
//! 3. **Tag-space safety** — reservations from the shared counter are
//!    disjoint and below [`BARRIER_TAG_BASE`]; every edge (after
//!    `GroupTransport` translation, including its segment fan) lands
//!    inside a window its op reserved; barrier traffic stays inside the
//!    generation namespace and nothing touches the abort bit; no two
//!    sends on one `(src, dst)` link have overlapping `tag .. tag+fan`
//!    windows — the check that catches tag aliasing of the kind fixed in
//!    `group_wire_tag`.
//! 4. **Buffer-window disjointness** — `chunk_ranges` tiles `0..total`
//!    exactly with balanced sizes, and the hierarchical scatter's
//!    binomial subtree enumeration covers every rank exactly once from
//!    any root.
//!
//! [`verify_all`] sweeps all of this (several hundred cases at the
//! default bound) and is enforced by `zccl verify` in CI and by
//! `tests/schedule_verifier.rs`.

use std::collections::BTreeMap;

use crate::analysis::graph::{self, Coll, Dir, OpGraph, Tags};
use crate::collectives::{chunk_ranges, Algo, SEG_TAG_SPAN};
use crate::topology::{binomial_subtree_into, Topology};
use crate::transport::{ABORT_TAG, BARRIER_TAG_BASE};

/// One verification failure: which case, which check, what went wrong.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Case label, e.g. `allgather/zccl/n5/root0`.
    pub case: String,
    /// Check family, e.g. `deadlock`, `tag-collision`.
    pub check: &'static str,
    /// Human-readable specifics.
    pub detail: String,
}

/// Aggregate result of a sweep.
#[derive(Debug, Default)]
pub struct Report {
    /// Schedules checked.
    pub cases: usize,
    /// Total messages across all checked schedules.
    pub messages: u64,
    /// Every failure found (empty = verified).
    pub findings: Vec<Finding>,
}

impl Report {
    /// True when no check failed.
    pub fn ok(&self) -> bool {
        self.findings.is_empty()
    }

    /// Single-line JSON verdict for CI logs.
    pub fn to_json(&self) -> String {
        fn esc(s: &str) -> String {
            s.replace('\\', "\\\\").replace('"', "\\\"")
        }
        let mut out = format!(
            "{{\"ok\":{},\"cases\":{},\"messages\":{},\"findings\":[",
            self.ok(),
            self.cases,
            self.messages
        );
        for (i, f) in self.findings.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"case\":\"{}\",\"check\":\"{}\",\"detail\":\"{}\"}}",
                esc(&f.case),
                esc(f.check),
                esc(&f.detail)
            ));
        }
        out.push_str("]}");
        out
    }
}

/// Most findings kept per case: one broken schedule fails loudly without
/// drowning the report.
const MAX_FINDINGS_PER_CASE: usize = 5;

/// Send/recv tallies per `(src, dst, tag)` edge.
type Balance = BTreeMap<(usize, usize, u64), (u64, u64)>;
/// Send fan-windows `(lo, hi, op)` per `(src, dst)` link.
type LinkWindows = BTreeMap<(usize, usize), Vec<(u64, u64, &'static str)>>;
/// Buffered-but-unreceived message counts per `(src, dst, tag)`.
type Pending = BTreeMap<(usize, usize, u64), u64>;

/// Whether `[logical, logical + fan)` lies inside some reserved window.
fn contained(ops: &[OpGraph], logical: u64, fan: u64) -> bool {
    for op in ops {
        for &(b, e) in &op.windows {
            if logical >= b && logical.checked_add(fan).is_some_and(|hi| hi <= e) {
                return true;
            }
        }
    }
    false
}

/// Run every check over one case — a sequence of ops issued on one
/// communicator (windows drawn from one shared [`Tags`] counter, scripts
/// executed per rank in order). Returns (message count, findings).
pub fn check_case(case: &str, ops: &[OpGraph]) -> (u64, Vec<Finding>) {
    let mut findings = Vec::new();
    let fail = |check: &'static str, detail: String, findings: &mut Vec<Finding>| {
        if findings.len() < MAX_FINDINGS_PER_CASE {
            findings.push(Finding { case: case.to_string(), check, detail });
        }
    };

    let messages: u64 = ops.iter().map(|op| op.send_count()).sum();
    let n = ops.first().map(|op| op.n).unwrap_or(0);
    for op in ops {
        if op.n != n {
            fail(
                "shape",
                format!("op {} has n={} but case has n={}", op.name, op.n, n),
                &mut findings,
            );
            return (messages, findings);
        }
    }

    // (1) Reservation windows: ascending, disjoint, under the barrier
    // namespace. Ops reserve in issue order from a monotonic counter, so
    // order violations are themselves findings.
    let mut prev_end = 0u64;
    for op in ops {
        for &(b, e) in &op.windows {
            if b < prev_end {
                fail(
                    "reservation",
                    format!("{}: window [{b},{e}) overlaps previous end {prev_end}", op.name),
                    &mut findings,
                );
            }
            if e > BARRIER_TAG_BASE {
                fail(
                    "reservation",
                    format!("{}: window [{b},{e}) crosses BARRIER_TAG_BASE", op.name),
                    &mut findings,
                );
            }
            prev_end = prev_end.max(e);
        }
    }

    // (2) Per-edge checks: endpoints, fan, namespaces, containment.
    for op in ops {
        for (me, sc) in op.scripts.iter().enumerate() {
            for ev in sc {
                if ev.peer >= n || ev.peer == me {
                    fail(
                        "endpoint",
                        format!("{}: rank {me} targets peer {} of {n}", op.name, ev.peer),
                        &mut findings,
                    );
                    continue;
                }
                if ev.fan == 0 || ev.fan > SEG_TAG_SPAN {
                    fail(
                        "fan",
                        format!("{}: rank {me} tag {:#x} fan {}", op.name, ev.tag, ev.fan),
                        &mut findings,
                    );
                }
                if ev.tag & ABORT_TAG != 0 {
                    fail(
                        "namespace",
                        format!("{}: rank {me} tag {:#x} sets the abort bit", op.name, ev.tag),
                        &mut findings,
                    );
                    continue;
                }
                let is_barrier_tag = ev.tag & BARRIER_TAG_BASE != 0;
                if is_barrier_tag != (ev.phase == "barrier") {
                    fail(
                        "namespace",
                        format!(
                            "{}: rank {me} phase {} tag {:#x} (barrier bit mismatch)",
                            op.name, ev.phase, ev.tag
                        ),
                        &mut findings,
                    );
                    continue;
                }
                let logical = ev.tag & !BARRIER_TAG_BASE;
                if !contained(ops, logical, ev.fan) {
                    fail(
                        "tag-containment",
                        format!(
                            "{}: rank {me} tag {:#x} fan {} outside every reserved window",
                            op.name, ev.tag, ev.fan
                        ),
                        &mut findings,
                    );
                }
            }
        }
    }

    // (3) Match completeness: per (src, dst, tag), sends == recvs.
    let mut balance = Balance::new();
    for op in ops {
        for (me, sc) in op.scripts.iter().enumerate() {
            for ev in sc {
                if ev.peer >= n || ev.peer == me {
                    continue; // already reported by (2)
                }
                match ev.dir {
                    Dir::Send => balance.entry((me, ev.peer, ev.tag)).or_default().0 += 1,
                    Dir::Recv => balance.entry((ev.peer, me, ev.tag)).or_default().1 += 1,
                }
            }
        }
    }
    for (&(src, dst, tag), &(s, r)) in &balance {
        if s > r {
            fail(
                "orphan-send",
                format!("{src}->{dst} tag {tag:#x}: {s} sends, {r} recvs"),
                &mut findings,
            );
        } else if r > s {
            fail(
                "unmatched-recv",
                format!("{src}->{dst} tag {tag:#x}: {s} sends, {r} recvs"),
                &mut findings,
            );
        }
    }

    // (4) Tag-collision: on each (src, dst) link, send fan-windows
    // [tag, tag+fan) must be pairwise disjoint — two transfers sharing a
    // link tag would interleave segments or steal each other's frames.
    let mut links = LinkWindows::new();
    for op in ops {
        for (me, sc) in op.scripts.iter().enumerate() {
            for ev in sc {
                if ev.dir == Dir::Send && ev.peer < n && ev.peer != me {
                    let hi = ev.tag.saturating_add(ev.fan);
                    links.entry((me, ev.peer)).or_default().push((ev.tag, hi, op.name));
                }
            }
        }
    }
    for (&(src, dst), windows) in links.iter_mut() {
        windows.sort_unstable();
        for w in windows.windows(2) {
            let (alo, ahi, aop) = w[0];
            let (blo, _bhi, bop) = w[1];
            if blo < ahi {
                fail(
                    "tag-collision",
                    format!(
                        "{src}->{dst}: {aop} window [{alo:#x},{ahi:#x}) overlaps {bop} at {blo:#x}"
                    ),
                    &mut findings,
                );
            }
        }
    }

    // (5) Deadlock-freedom: simulate. Sends never block; a receive
    // consumes one buffered message or blocks its rank. Fixed-point
    // iterate until quiescent; unfinished scripts are deadlocks.
    let mut merged: Vec<Vec<&graph::Ev>> = vec![Vec::new(); n];
    for op in ops {
        for (me, sc) in op.scripts.iter().enumerate() {
            merged[me].extend(sc.iter());
        }
    }
    let mut cursors = vec![0usize; n];
    let mut pending = Pending::new();
    loop {
        let mut progress = false;
        for (me, cur) in cursors.iter_mut().enumerate() {
            while *cur < merged[me].len() {
                let ev = merged[me][*cur];
                match ev.dir {
                    Dir::Send => {
                        *pending.entry((me, ev.peer, ev.tag)).or_insert(0) += 1;
                    }
                    Dir::Recv => {
                        let slot = pending.entry((ev.peer, me, ev.tag)).or_insert(0);
                        if *slot == 0 {
                            break;
                        }
                        *slot -= 1;
                    }
                }
                *cur += 1;
                progress = true;
            }
        }
        if !progress {
            break;
        }
    }
    for (me, &cur) in cursors.iter().enumerate() {
        if cur < merged[me].len() {
            let ev = merged[me][cur];
            fail(
                "deadlock",
                format!(
                    "rank {me} wedged at event {cur}/{} waiting on {} tag {:#x} ({})",
                    merged[me].len(),
                    ev.peer,
                    ev.tag,
                    ev.phase
                ),
                &mut findings,
            );
        }
    }

    (messages, findings)
}

/// `chunk_ranges` must tile `0..total` exactly: `n` consecutive windows
/// starting at 0, sizes within 1 of each other, the first `total % n`
/// taking the extra element. Executors index send/recv buffers straight
/// off these ranges, so a gap or overlap is silent data corruption.
fn check_partitions(max_n: usize, findings: &mut Vec<Finding>) -> usize {
    let mut cases = 0;
    for total in [0usize, 1, 5, 67, 1000] {
        for n in 1..=max_n {
            cases += 1;
            let case = format!("chunk_ranges/total{total}/n{n}");
            let ranges = chunk_ranges(total, n);
            let mut bad = |detail: String| {
                findings.push(Finding { case: case.clone(), check: "partition", detail });
            };
            if ranges.len() != n {
                bad(format!("{} windows for n={n}", ranges.len()));
                continue;
            }
            let mut cursor = 0usize;
            for (i, r) in ranges.iter().enumerate() {
                if r.start != cursor {
                    bad(format!("window {i} starts at {} not {cursor}", r.start));
                }
                cursor = r.end;
                let want = total / n + usize::from(i < total % n);
                if r.len() != want {
                    bad(format!("window {i} has {} elements, want {want}", r.len()));
                }
            }
            if cursor != total {
                bad(format!("windows cover 0..{cursor}, want 0..{total}"));
            }
        }
    }
    cases
}

/// The binomial subtree enumeration that the hierarchical scatter uses
/// to pack per-subtree bundles must cover every node exactly once from
/// any root (so the flattened member list covers every rank exactly once
/// — each element of the root bundle lands in exactly one final window).
fn check_subtree_cover(name: &str, topo: &Topology, findings: &mut Vec<Finding>) -> usize {
    let nnodes = topo.nodes();
    let mut cases = 0;
    let mut nodes_out = Vec::new();
    for root_node in 0..nnodes {
        cases += 1;
        let case = format!("subtree/{name}/root{root_node}");
        nodes_out.clear();
        binomial_subtree_into(root_node, root_node, nnodes, &mut nodes_out);
        let mut seen = nodes_out.clone();
        seen.sort_unstable();
        seen.dedup();
        if seen.len() != nnodes || nodes_out.len() != nnodes {
            findings.push(Finding {
                case,
                check: "subtree-cover",
                detail: format!("root subtree lists {nodes_out:?}, want 0..{nnodes} each once"),
            });
            continue;
        }
        let mut ranks: Vec<usize> =
            nodes_out.iter().flat_map(|&nd| topo.members(nd).iter().copied()).collect();
        ranks.sort_unstable();
        if ranks != (0..topo.ranks()).collect::<Vec<_>>() {
            findings.push(Finding {
                case,
                check: "subtree-cover",
                detail: format!("flattened members {:?} do not cover 0..{}", ranks, topo.ranks()),
            });
        }
    }
    cases
}

const FLAT_ALGOS: [Algo; 4] = [Algo::Plain, Algo::Cprp2p, Algo::CColl, Algo::Zccl];
const UNROOTED: [Coll; 4] = [Coll::ReduceScatter, Coll::Allgather, Coll::Allreduce, Coll::Alltoall];
const ROOTED: [Coll; 4] = [Coll::Bcast, Coll::Scatter, Coll::Gather, Coll::Reduce];

fn algo_name(algo: Algo) -> &'static str {
    match algo {
        Algo::Plain => "plain",
        Algo::Cprp2p => "cprp2p",
        Algo::CColl => "ccoll",
        Algo::Zccl => "zccl",
        Algo::Hier => "hier",
    }
}

/// Node shapes swept for the hierarchical arm at a given rank count:
/// rank-per-node (degenerates to the flat leader tier), everyone on one
/// node (no inter tier), an even two-node split, and a lopsided tail
/// with single-rank nodes.
fn hier_topos(n: usize) -> Vec<(&'static str, Topology)> {
    let mut out = vec![("flat", Topology::flat(n))];
    if n >= 2 {
        out.push(("one", Topology::grouped(&[n]).expect("single node")));
        out.push(("two", Topology::grouped(&[n - n / 2, n / 2]).expect("two-node split")));
    }
    if n >= 3 {
        out.push(("tail", Topology::grouped(&[n - 2, 1, 1]).expect("tail split")));
    }
    out
}

fn single_op_case(
    report: &mut Report,
    coll: Coll,
    algo: Algo,
    n: usize,
    root: usize,
    topo: Option<(&str, &Topology)>,
) {
    let mut tags = Tags::new();
    let g = graph::build(coll, algo, n, root, topo.map(|(_, t)| t), &mut tags);
    let mut case = format!("{}/{}/n{n}", coll.name(), algo_name(algo));
    if let Some((tn, _)) = topo {
        case.push_str(&format!("/{tn}"));
    }
    if coll.rooted() {
        case.push_str(&format!("/root{root}"));
    }
    let (msgs, findings) = check_case(&case, &[g]);
    report.cases += 1;
    report.messages += msgs;
    report.findings.extend(findings);
}

/// Sweep every collective × algorithm arm × rank count up to `max_n`
/// (× topology for `Hier`, × root ∈ {0, n-1} for rooted collectives),
/// plus multi-op cases mirroring the concurrent nonblocking reservation
/// order and barrier/data namespace separation, plus the partition and
/// subtree-cover invariants.
pub fn verify_sweep(max_n: usize) -> Report {
    let mut report = Report::default();
    for n in 1..=max_n {
        // Barrier is algorithm-independent.
        let mut tags = Tags::new();
        let g = graph::build(Coll::Barrier, Algo::Plain, n, 0, None, &mut tags);
        let (msgs, findings) = check_case(&format!("barrier/n{n}"), &[g]);
        report.cases += 1;
        report.messages += msgs;
        report.findings.extend(findings);

        let roots: &[usize] = if n == 1 { &[0] } else { &[0, n - 1] };
        for algo in FLAT_ALGOS {
            for coll in UNROOTED {
                single_op_case(&mut report, coll, algo, n, 0, None);
            }
            for coll in ROOTED {
                for &root in roots {
                    single_op_case(&mut report, coll, algo, n, root, None);
                }
            }
        }
        for (tname, topo) in hier_topos(n) {
            for coll in UNROOTED {
                single_op_case(&mut report, coll, Algo::Hier, n, 0, Some((tname, &topo)));
            }
            for coll in ROOTED {
                for &root in roots {
                    single_op_case(&mut report, coll, Algo::Hier, n, root, Some((tname, &topo)));
                }
            }
            let sub = check_subtree_cover(&format!("n{n}/{tname}"), &topo, &mut report.findings);
            report.cases += sub;
        }

        if n >= 2 {
            // Concurrent nonblocking collectives: the runtime reserves
            // each request's window up front from the shared counter, so
            // four in-flight schedules must interleave safely.
            let mut tags = Tags::new();
            let ops = [
                graph::build(Coll::Allreduce, Algo::Zccl, n, 0, None, &mut tags),
                graph::build(Coll::ReduceScatter, Algo::Zccl, n, 0, None, &mut tags),
                graph::build(Coll::Allgather, Algo::Zccl, n, 0, None, &mut tags),
                graph::build(Coll::Bcast, Algo::Zccl, n, 0, None, &mut tags),
            ];
            let (msgs, findings) = check_case(&format!("concurrent-izccl/n{n}"), &ops);
            report.cases += 1;
            report.messages += msgs;
            report.findings.extend(findings);

            // Data + barrier namespaces on one counter.
            let mut tags = Tags::new();
            let ops = [
                graph::build(Coll::Allreduce, Algo::Zccl, n, 0, None, &mut tags),
                graph::build(Coll::Barrier, Algo::Zccl, n, 0, None, &mut tags),
            ];
            let (msgs, findings) = check_case(&format!("allreduce+barrier/n{n}"), &ops);
            report.cases += 1;
            report.messages += msgs;
            report.findings.extend(findings);
        }
    }
    report.cases += check_partitions(max_n, &mut report.findings);
    report
}

/// [`verify_sweep`] at the default bound (covers non-power-of-two,
/// power-of-two, and odd rank counts through 9).
pub fn verify_all() -> Report {
    verify_sweep(9)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::graph::{Ev, Payload};

    fn ev(dir: Dir, peer: usize, tag: u64) -> Ev {
        Ev { dir, peer, tag, fan: 1, phase: "test", payload: Payload::Raw }
    }

    /// Hand-built broken schedules must trip the intended checks.
    #[test]
    fn detects_injected_faults() {
        // Orphan send + unmatched recv (which also wedges rank 1).
        let g = OpGraph {
            name: "bad",
            n: 2,
            scripts: vec![vec![ev(Dir::Send, 1, 3)], vec![ev(Dir::Recv, 0, 4)]],
            windows: vec![(0, 8)],
        };
        let (_, f) = check_case("t", &[g]);
        let checks: Vec<_> = f.iter().map(|f| f.check).collect();
        assert!(checks.contains(&"orphan-send"), "{checks:?}");
        assert!(checks.contains(&"unmatched-recv"), "{checks:?}");
        assert!(checks.contains(&"deadlock"), "{checks:?}");

        // Cyclic wait: both ranks receive before sending.
        let g = OpGraph {
            name: "cycle",
            n: 2,
            scripts: vec![
                vec![ev(Dir::Recv, 1, 0), ev(Dir::Send, 1, 1)],
                vec![ev(Dir::Recv, 0, 1), ev(Dir::Send, 0, 0)],
            ],
            windows: vec![(0, 2)],
        };
        let (_, f) = check_case("t", &[g]);
        assert!(f.iter().any(|f| f.check == "deadlock"), "{f:?}");

        // Overlapping fan-windows on one link.
        let mut a = ev(Dir::Send, 1, 10);
        a.fan = 4;
        let mut b = ev(Dir::Recv, 0, 10);
        b.fan = 4;
        let g = OpGraph {
            name: "clash",
            n: 2,
            scripts: vec![vec![a, ev(Dir::Send, 1, 12)], vec![b, ev(Dir::Recv, 0, 12)]],
            windows: vec![(0, 32)],
        };
        let (_, f) = check_case("t", &[g]);
        assert!(f.iter().any(|f| f.check == "tag-collision"), "{f:?}");

        // Tag outside every reserved window.
        let g = OpGraph {
            name: "stray",
            n: 2,
            scripts: vec![vec![ev(Dir::Send, 1, 99)], vec![ev(Dir::Recv, 0, 99)]],
            windows: vec![(0, 8)],
        };
        let (_, f) = check_case("t", &[g]);
        assert!(f.iter().any(|f| f.check == "tag-containment"), "{f:?}");
    }

    #[test]
    fn full_sweep_is_clean() {
        let r = verify_all();
        assert!(r.ok(), "{}", r.to_json());
        assert!(r.cases > 500, "swept only {} cases", r.cases);
        assert!(r.messages > 10_000, "counted only {} messages", r.messages);
    }

    #[test]
    fn json_is_single_line_and_escaped() {
        let f = Finding { case: "a\"b\\c".into(), check: "deadlock", detail: "x".into() };
        let r = Report { cases: 1, messages: 0, findings: vec![f] };
        let j = r.to_json();
        assert!(!j.contains('\n'));
        assert!(j.contains("a\\\"b\\\\c"));
        assert!(j.starts_with("{\"ok\":false"));
    }
}
