//! Symbolic message graphs — every collective's full communication
//! schedule as per-rank send/receive scripts, derived from the same
//! [`plan`](crate::analysis::plan) structs and [`crate::topology`]
//! schedule generators the executors run, so the graph cannot drift from
//! the wire.
//!
//! [`build`] produces an [`OpGraph`] for any `(collective, Algo, n,
//! root, Topology)` shape without touching a transport: a [`Tags`]
//! counter mirrors [`crate::collectives::Communicator::fresh_tags`], the
//! ring/tree peers come from the shared schedule generators, and the
//! hierarchical builders replay [`crate::collectives::hier`] exactly —
//! including the inner leader-tier communicator (its own tag counter
//! from zero) translated through [`crate::transport::group_wire_tag`],
//! so every edge carries the *wire* tag a traced fabric would record.
//!
//! Each [`Ev`] is one logical message: `(peer, tag, fan, phase,
//! payload)` in the order the rank posts (and blocks on) it. `fan` is
//! the width of the tag window a segmented send may occupy
//! (`tag .. tag + fan`); all sweeps and property tests size payloads so
//! one segment suffices, making [`message_counts`] exactly the
//! [`crate::transport::memchan::MessageLedger`] a traced run produces.

use crate::analysis::plan::{
    AllgatherPlan, AlltoallPlan, HierAllgatherPlan, HierAllreducePlan, HierAlltoallPlan,
    HierBcastPlan, HierGatherPlan, HierReducePlan, HierReduceScatterPlan, HierScatterPlan,
    RingPlan, TreePlan, HIER_GROUP_SPAN,
};
use crate::collectives::{Algo, SEG_TAG_SPAN};
use crate::topology::{binomial_bcast, binomial_bcast_in_group, ring_in_group, Topology};
use crate::transport::memchan::MessageLedger;
use crate::transport::{barrier_tag, group_wire_tag, BARRIER_GEN_SPAN, BARRIER_TAG_BASE};

/// Direction of one scripted event, from the owning rank's view.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    /// The rank posts a message to `peer` (never blocks: both transports
    /// buffer sends).
    Send,
    /// The rank blocks until a matching message from `peer` arrives.
    Recv,
}

/// What travels on the edge — diagnostic only; matching is by
/// `(src, dst, tag)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Payload {
    /// Zero-byte synchronisation frame (dissemination barrier).
    Empty,
    /// An 8-byte `u64` from a count/size exchange ring.
    SizeU64,
    /// Raw little-endian `f32` values.
    Raw,
    /// One compressed frame.
    Frame,
    /// A length-prefixed bundle of frames or records.
    Bundle,
}

/// One scripted message event on one rank.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Ev {
    /// Send or receive.
    pub dir: Dir,
    /// The other endpoint (global rank).
    pub peer: usize,
    /// Wire tag of the message (post-`GroupTransport` translation).
    pub tag: u64,
    /// Width of the tag window a segmented transfer may fan into
    /// (`tag .. tag + fan`); 1 for single-frame messages.
    pub fan: u64,
    /// Which stage of the schedule produced the edge (diagnostics).
    pub phase: &'static str,
    /// Payload class (diagnostics).
    pub payload: Payload,
}

impl Ev {
    fn snd(peer: usize, tag: u64, fan: u64, phase: &'static str, payload: Payload) -> Ev {
        Ev { dir: Dir::Send, peer, tag, fan, phase, payload }
    }
    fn rcv(peer: usize, tag: u64, fan: u64, phase: &'static str, payload: Payload) -> Ev {
        Ev { dir: Dir::Recv, peer, tag, fan, phase, payload }
    }
}

/// The full symbolic schedule of one collective call on one
/// communicator: per-rank ordered scripts plus the tag-counter windows
/// the call reserved.
#[derive(Debug, Clone)]
pub struct OpGraph {
    /// Short label ("allgather", "barrier", …).
    pub name: &'static str,
    /// Communicator size.
    pub n: usize,
    /// `scripts[r]` = rank `r`'s events in program order.
    pub scripts: Vec<Vec<Ev>>,
    /// `[base, end)` slices consumed from the communicator's monotonic
    /// tag counter (the barrier's slice holds its *generation*; its wire
    /// tags additionally carry [`BARRIER_TAG_BASE`]).
    pub windows: Vec<(u64, u64)>,
}

impl OpGraph {
    fn empty(name: &'static str, n: usize) -> OpGraph {
        OpGraph { name, n, scripts: vec![Vec::new(); n], windows: Vec::new() }
    }

    /// Total messages the schedule puts on the wire (send events).
    pub fn send_count(&self) -> u64 {
        self.scripts
            .iter()
            .map(|sc| sc.iter().filter(|e| e.dir == Dir::Send).count() as u64)
            .sum()
    }
}

/// Mirror of the communicator's monotonic tag counter
/// ([`crate::collectives::Communicator::fresh_tags`]): reservations are
/// contiguous, start at zero, and must stay below [`BARRIER_TAG_BASE`].
#[derive(Debug, Default, Clone)]
pub struct Tags {
    next: u64,
}

impl Tags {
    /// A fresh counter (a new communicator).
    pub fn new() -> Tags {
        Tags::default()
    }

    /// Reserve `span` consecutive tags, returning the slice base.
    pub fn reserve(&mut self, span: u64) -> u64 {
        let base = self.next;
        let end = base.checked_add(span).expect("tag counter overflow");
        assert!(end <= BARRIER_TAG_BASE, "reservation would cross BARRIER_TAG_BASE");
        self.next = end;
        base
    }
}

/// The nine collectives the verifier models.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Coll {
    /// Dissemination barrier.
    Barrier,
    /// Binomial-tree broadcast.
    Bcast,
    /// Binomial-tree scatter.
    Scatter,
    /// Binomial-tree gather.
    Gather,
    /// Binomial-tree reduce.
    Reduce,
    /// Ring reduce-scatter.
    ReduceScatter,
    /// Ring allgather.
    Allgather,
    /// Reduce-scatter + shifted allgather.
    Allreduce,
    /// Pairwise-exchange alltoall.
    Alltoall,
}

impl Coll {
    /// Every modeled collective.
    pub const ALL: [Coll; 9] = [
        Coll::Barrier,
        Coll::Bcast,
        Coll::Scatter,
        Coll::Gather,
        Coll::Reduce,
        Coll::ReduceScatter,
        Coll::Allgather,
        Coll::Allreduce,
        Coll::Alltoall,
    ];

    /// Short label.
    pub fn name(self) -> &'static str {
        match self {
            Coll::Barrier => "barrier",
            Coll::Bcast => "bcast",
            Coll::Scatter => "scatter",
            Coll::Gather => "gather",
            Coll::Reduce => "reduce",
            Coll::ReduceScatter => "reduce_scatter",
            Coll::Allgather => "allgather",
            Coll::Allreduce => "allreduce",
            Coll::Alltoall => "alltoall",
        }
    }

    /// Whether the collective takes a root rank.
    pub fn rooted(self) -> bool {
        matches!(self, Coll::Bcast | Coll::Scatter | Coll::Gather | Coll::Reduce)
    }
}

/// Build the symbolic schedule of one collective call.
///
/// `root` is ignored for unrooted collectives; `topo` is consumed only
/// by the `Hier` arms (absent = [`Topology::flat`], mirroring
/// `resolve_topo`). The dispatch order — degenerate single-rank returns
/// before or after tag reservation, hierarchical dispatch before
/// reservation — replays the executors line for line, so the tag
/// counter advances exactly as the runtime's does.
pub fn build(
    coll: Coll,
    algo: Algo,
    n: usize,
    root: usize,
    topo: Option<&Topology>,
    tags: &mut Tags,
) -> OpGraph {
    assert!(n >= 1, "empty communicator");
    if coll.rooted() {
        assert!(root < n, "root {root} out of {n}");
    }
    match coll {
        Coll::Barrier => barrier(n, tags),
        Coll::ReduceScatter => {
            if n == 1 {
                OpGraph::empty("reduce_scatter", n)
            } else if algo == Algo::Hier {
                reduce_scatter_hier(n, topo, tags)
            } else {
                reduce_scatter(algo, n, tags)
            }
        }
        Coll::Allgather => {
            if n == 1 {
                OpGraph::empty("allgather", n)
            } else if algo == Algo::Hier {
                allgather_hier(n, topo, tags)
            } else {
                allgather_flat(algo, n, tags)
            }
        }
        Coll::Allreduce => {
            if n == 1 {
                OpGraph::empty("allreduce", n)
            } else if algo == Algo::Hier {
                allreduce_hier(n, topo, tags)
            } else {
                let mut g = reduce_scatter(algo, n, tags);
                let ag = allgather_flat(algo, n, tags);
                append(&mut g, ag);
                g.name = "allreduce";
                g
            }
        }
        Coll::Alltoall => {
            if n == 1 {
                OpGraph::empty("alltoall", n)
            } else if algo == Algo::Hier {
                alltoall_hier(n, topo, tags)
            } else {
                alltoall(algo, n, tags)
            }
        }
        Coll::Bcast => {
            if n == 1 {
                OpGraph::empty("bcast", n)
            } else if algo == Algo::Hier {
                bcast_hier(n, root, topo, tags)
            } else {
                tree_down("bcast", n, root, wire_payload(algo), tags)
            }
        }
        Coll::Scatter => {
            if n == 1 {
                OpGraph::empty("scatter", n)
            } else if algo == Algo::Hier {
                scatter_hier(n, root, topo, tags)
            } else {
                tree_down("scatter", n, root, Payload::Bundle, tags)
            }
        }
        Coll::Gather => {
            if n == 1 {
                OpGraph::empty("gather", n)
            } else if algo == Algo::Hier {
                gather_hier(n, root, topo, tags)
            } else {
                tree_up("gather", n, root, Payload::Bundle, tags)
            }
        }
        Coll::Reduce => {
            if n == 1 {
                OpGraph::empty("reduce", n)
            } else if algo == Algo::Hier {
                reduce_hier(n, root, topo, tags)
            } else {
                tree_up("reduce", n, root, wire_payload(algo), tags)
            }
        }
    }
}

/// Exact per-`(src, dst, tag)` message counts the schedule produces —
/// comparable with a traced fabric's ledger when every transfer fits one
/// segment (payloads below `Mode::pipeline_bytes`).
pub fn message_counts(ops: &[OpGraph]) -> MessageLedger {
    let mut out = MessageLedger::new();
    for op in ops {
        for (me, sc) in op.scripts.iter().enumerate() {
            for ev in sc {
                if ev.dir == Dir::Send {
                    *out.entry((me, ev.peer, ev.tag)).or_insert(0) += 1;
                }
            }
        }
    }
    out
}

fn wire_payload(algo: Algo) -> Payload {
    if algo == Algo::Plain {
        Payload::Raw
    } else {
        Payload::Frame
    }
}

fn append(g: &mut OpGraph, other: OpGraph) {
    for (sc, extra) in g.scripts.iter_mut().zip(other.scripts) {
        sc.extend(extra);
    }
    g.windows.extend(other.windows);
}

/// `exchange_sizes`: `n - 1` ring rounds of one 8-byte message each.
fn push_size_ring(scripts: &mut [Vec<Ev>], ring: RingPlan, phase: &'static str) {
    let n = ring.n;
    for (me, sc) in scripts.iter_mut().enumerate() {
        for t in 0..n - 1 {
            sc.push(Ev::snd((me + 1) % n, ring.round_tag(t), 1, phase, Payload::SizeU64));
            sc.push(Ev::rcv((me + n - 1) % n, ring.round_tag(t), 1, phase, Payload::SizeU64));
        }
    }
}

/// Default [`crate::transport::Transport::barrier`]: dissemination over
/// `ceil(log2 n)` rounds of empty frames in the barrier tag namespace.
/// The generation is reserved even for a single rank (the communicator
/// reserves before the transport's early return).
fn barrier(n: usize, tags: &mut Tags) -> OpGraph {
    let generation = tags.reserve(BARRIER_GEN_SPAN);
    let mut g = OpGraph::empty("barrier", n);
    g.windows.push((generation, generation + BARRIER_GEN_SPAN));
    if n <= 1 {
        return g;
    }
    for (me, sc) in g.scripts.iter_mut().enumerate() {
        let mut dist = 1usize;
        let mut round = 0u64;
        while dist < n {
            let tag = barrier_tag(generation, round);
            sc.push(Ev::snd((me + dist) % n, tag, 1, "barrier", Payload::Empty));
            sc.push(Ev::rcv((me + n - dist) % n, tag, 1, "barrier", Payload::Empty));
            dist *= 2;
            round += 1;
        }
    }
    g
}

/// Ring reduce-scatter: `n - 1` rounds, one message per rank per round,
/// identical edges under every algorithm arm (`Zccl` only reorders the
/// irecv posting, not the messages).
fn reduce_scatter(algo: Algo, n: usize, tags: &mut Tags) -> OpGraph {
    let base = tags.reserve(RingPlan::span(n));
    let plan = RingPlan::at(base, n);
    let mut g = OpGraph::empty("reduce_scatter", n);
    g.windows.push((base, base + RingPlan::span(n)));
    let p = wire_payload(algo);
    for (me, sc) in g.scripts.iter_mut().enumerate() {
        for t in 0..n - 1 {
            sc.push(Ev::snd((me + 1) % n, plan.round_tag(t), 1, "rs-ring", p));
            sc.push(Ev::rcv((me + n - 1) % n, plan.round_tag(t), 1, "rs-ring", p));
        }
    }
    g
}

/// Flat ring allgather: a count-exchange ring (all arms), a compressed
/// size-exchange ring (`CColl`/`Zccl`), then `n - 1` data rounds. Only
/// `Zccl` pipelines, so only its rounds fan past one tag; the rank/tag
/// edges are otherwise arm-independent (the `shift` used by allreduce
/// moves chunk *ownership*, not messages).
fn allgather_flat(algo: Algo, n: usize, tags: &mut Tags) -> OpGraph {
    let base = tags.reserve(AllgatherPlan::span(n));
    let plan = AllgatherPlan::at(base, n);
    let mut g = OpGraph::empty("allgather", n);
    g.windows.push((base, base + AllgatherPlan::span(n)));
    push_size_ring(&mut g.scripts, plan.counts_ring(), "ag-counts");
    if matches!(algo, Algo::CColl | Algo::Zccl) {
        push_size_ring(&mut g.scripts, plan.sizes_ring(), "ag-sizes");
    }
    let fan = if algo == Algo::Zccl { plan.seg_fan() } else { 1 };
    let p = wire_payload(algo);
    for (me, sc) in g.scripts.iter_mut().enumerate() {
        for t in 0..n - 1 {
            sc.push(Ev::snd((me + 1) % n, plan.round_tag(t), fan, "ag-round", p));
            sc.push(Ev::rcv((me + n - 1) % n, plan.round_tag(t), fan, "ag-round", p));
        }
    }
    g
}

/// Pairwise-exchange alltoall: `Zccl`/`Hier` pre-exchange sizes over a
/// ring, then rounds `1..n` pair `me` with `(me ± t) mod n` on one tag.
fn alltoall(algo: Algo, n: usize, tags: &mut Tags) -> OpGraph {
    let base = tags.reserve(AlltoallPlan::span(n));
    let plan = AlltoallPlan::at(base, n);
    let mut g = OpGraph::empty("alltoall", n);
    g.windows.push((base, base + AlltoallPlan::span(n)));
    if matches!(algo, Algo::Zccl | Algo::Hier) {
        push_size_ring(&mut g.scripts, plan.sizes_ring(), "a2a-sizes");
    }
    let p = wire_payload(algo);
    for (me, sc) in g.scripts.iter_mut().enumerate() {
        for t in 1..n {
            sc.push(Ev::snd((me + t) % n, plan.pair_tag(t), 1, "a2a-pair", p));
            sc.push(Ev::rcv((me + n - t) % n, plan.pair_tag(t), 1, "a2a-pair", p));
        }
    }
    g
}

/// Binomial tree, root outward (bcast, scatter): non-roots receive from
/// their parent first, then forward to each child, largest subtree
/// first.
fn tree_down(
    name: &'static str,
    n: usize,
    root: usize,
    payload: Payload,
    tags: &mut Tags,
) -> OpGraph {
    let base = tags.reserve(TreePlan::span(n));
    let plan = TreePlan::at(base, n);
    let mut g = OpGraph::empty(name, n);
    g.windows.push((base, base + TreePlan::span(n)));
    for (me, sc) in g.scripts.iter_mut().enumerate() {
        let (recv_step, send_steps) = binomial_bcast(me, root, n);
        if me != root {
            let s = recv_step.expect("non-root receives from its parent");
            sc.push(Ev::rcv(s.peer, plan.step_tag(s.round), 1, "tree", payload));
        }
        for s in send_steps {
            sc.push(Ev::snd(s.peer, plan.step_tag(s.round), 1, "tree", payload));
        }
    }
    g
}

/// Binomial tree, leaves inward (gather, reduce): children are drained
/// in reverse round order (deepest subtree first), then the partial goes
/// up to the parent.
fn tree_up(
    name: &'static str,
    n: usize,
    root: usize,
    payload: Payload,
    tags: &mut Tags,
) -> OpGraph {
    let base = tags.reserve(TreePlan::span(n));
    let plan = TreePlan::at(base, n);
    let mut g = OpGraph::empty(name, n);
    g.windows.push((base, base + TreePlan::span(n)));
    for (me, sc) in g.scripts.iter_mut().enumerate() {
        let (parent_step, child_steps) = binomial_bcast(me, root, n);
        for s in child_steps.iter().rev() {
            sc.push(Ev::rcv(s.peer, plan.step_tag(s.round), 1, "tree", payload));
        }
        if me != root {
            let s = parent_step.expect("non-root has a parent");
            sc.push(Ev::snd(s.peer, plan.step_tag(s.round), 1, "tree", payload));
        }
    }
    g
}

/// Intra-node binomial broadcast of the leader's result (`Raw`, fast
/// tier). No-op for single-member nodes.
fn push_intra_down(sc: &mut Vec<Ev>, members: &[usize], local_idx: usize, tag_base: u64) {
    if members.len() == 1 {
        return;
    }
    let (recv_step, send_steps) = binomial_bcast_in_group(members, local_idx, 0);
    if local_idx != 0 {
        let s = recv_step.expect("non-leader member receives");
        sc.push(Ev::rcv(s.peer, tag_base + s.round as u64, 1, "intra-down", Payload::Raw));
    }
    for s in send_steps {
        sc.push(Ev::snd(s.peer, tag_base + s.round as u64, 1, "intra-down", Payload::Raw));
    }
}

/// Mirror of `hier::resolve_topo`'s leader-tier tag-budget guard.
fn assert_leader_budget(topo: &Topology) {
    assert!(
        (topo.nodes() as u64 + 3) * SEG_TAG_SPAN <= HIER_GROUP_SPAN,
        "leader tier exceeds HIER_GROUP_SPAN"
    );
}

/// Hierarchical allreduce: raw member partials up to the leader, the
/// flat ZCCL reduce-scatter + allgather over the leader group (an inner
/// communicator whose tags start at zero, translated onto
/// `group_base + tag` by the [`crate::transport::GroupTransport`] view),
/// then the raw result down each node's member binomial.
fn allreduce_hier(n: usize, topo: Option<&Topology>, tags: &mut Tags) -> OpGraph {
    let topo = topo.cloned().unwrap_or_else(|| Topology::flat(n));
    assert_eq!(topo.ranks(), n, "topology does not cover the communicator");
    assert_leader_budget(&topo);
    let base = tags.reserve(HierAllreducePlan::span(n));
    let plan = HierAllreducePlan::at(base, n);
    let mut g = OpGraph::empty("allreduce", n);
    g.windows.push((base, base + HierAllreducePlan::span(n)));

    for (me, sc) in g.scripts.iter_mut().enumerate() {
        let members = topo.members(topo.node_of(me));
        if topo.local_index(me) == 0 {
            for &mr in &members[1..] {
                sc.push(Ev::rcv(mr, plan.up_tag(), 1, "hier-up", Payload::Raw));
            }
        } else {
            sc.push(Ev::snd(topo.leader_of(me), plan.up_tag(), 1, "hier-up", Payload::Raw));
        }
    }

    if topo.nodes() > 1 {
        let leaders = topo.leaders();
        let mut inner_tags = Tags::new();
        let mut inner = reduce_scatter(Algo::Zccl, leaders.len(), &mut inner_tags);
        append(&mut inner, allgather_flat(Algo::Zccl, leaders.len(), &mut inner_tags));
        for (i, inner_sc) in inner.scripts.into_iter().enumerate() {
            let sc = &mut g.scripts[leaders[i]];
            for ev in inner_sc {
                sc.push(Ev {
                    peer: leaders[ev.peer],
                    tag: group_wire_tag(plan.group_base(), ev.tag),
                    phase: "hier-inter",
                    ..ev
                });
            }
        }
    }

    for (me, sc) in g.scripts.iter_mut().enumerate() {
        let members = topo.members(topo.node_of(me));
        push_intra_down(sc, members, topo.local_index(me), plan.down().base);
    }
    g
}

/// Hierarchical allgather: raw member chunks up, per-node frame bundles
/// around the **segmented** leader ring (each round ships an 8-byte
/// bundle-size pre-message, then the bundle over a `seg_fan`-wide tag
/// window), raw gathered vector down.
fn allgather_hier(n: usize, topo: Option<&Topology>, tags: &mut Tags) -> OpGraph {
    let topo = topo.cloned().unwrap_or_else(|| Topology::flat(n));
    assert_eq!(topo.ranks(), n, "topology does not cover the communicator");
    assert_leader_budget(&topo);
    let base = tags.reserve(HierAllgatherPlan::span(n));
    let plan = HierAllgatherPlan::at(base, n);
    let mut g = OpGraph::empty("allgather", n);
    g.windows.push((base, base + HierAllgatherPlan::span(n)));
    let nnodes = topo.nodes();

    for (me, sc) in g.scripts.iter_mut().enumerate() {
        let node = topo.node_of(me);
        let members = topo.members(node);
        let local_idx = topo.local_index(me);
        if local_idx != 0 {
            sc.push(Ev::snd(topo.leader_of(me), plan.up_tag(), 1, "hier-up", Payload::Raw));
            push_intra_down(sc, members, local_idx, plan.down().base);
            continue;
        }
        for &mr in &members[1..] {
            sc.push(Ev::rcv(mr, plan.up_tag(), 1, "hier-up", Payload::Raw));
        }
        let lring = ring_in_group(topo.leaders(), node);
        let lplan = plan.leader_ring();
        let sizes = plan.sizes_ring();
        let fan = lplan.seg_fan();
        for t in 0..nnodes - 1 {
            sc.push(Ev::snd(lring.next, sizes.round_tag(t), 1, "hier-sizes", Payload::SizeU64));
            sc.push(Ev::snd(lring.next, lplan.round_tag(t), fan, "hier-ring", Payload::Bundle));
            sc.push(Ev::rcv(lring.prev, sizes.round_tag(t), 1, "hier-sizes", Payload::SizeU64));
            sc.push(Ev::rcv(lring.prev, lplan.round_tag(t), fan, "hier-ring", Payload::Bundle));
        }
        push_intra_down(sc, members, 0, plan.down().base);
    }
    g
}

/// Hierarchical bcast: optional root → root-leader frame hop, the frame
/// verbatim down the **segmented** leader binomial (each edge ships an
/// 8-byte size pre-message, then the frame over a `seg_fan`-wide tag
/// window), raw fan-out inside each node.
fn bcast_hier(n: usize, root: usize, topo: Option<&Topology>, tags: &mut Tags) -> OpGraph {
    let topo = topo.cloned().unwrap_or_else(|| Topology::flat(n));
    assert_eq!(topo.ranks(), n, "topology does not cover the communicator");
    assert_leader_budget(&topo);
    let base = tags.reserve(HierBcastPlan::span(n));
    let plan = HierBcastPlan::at(base, n);
    let mut g = OpGraph::empty("bcast", n);
    g.windows.push((base, base + HierBcastPlan::span(n)));
    let root_node = topo.node_of(root);
    let root_leader = topo.leader_of(root);
    let ltree = plan.leader_tree();

    for (me, sc) in g.scripts.iter_mut().enumerate() {
        let node = topo.node_of(me);
        let members = topo.members(node);
        let local_idx = topo.local_index(me);
        if me == root && me != root_leader {
            sc.push(Ev::snd(root_leader, plan.hop_tag(), 1, "hier-hop", Payload::Frame));
        }
        if local_idx == 0 {
            let (recv_step, send_steps) = binomial_bcast_in_group(topo.leaders(), node, root_node);
            if me == root && me == root_leader {
                // Compresses its own frame — nothing to receive.
            } else if node == root_node {
                sc.push(Ev::rcv(root, plan.hop_tag(), 1, "hier-hop", Payload::Frame));
            } else {
                let s = recv_step.expect("non-root-node leader receives");
                sc.push(Ev::rcv(s.peer, ltree.size_tag(s.round), 1, "hier-sizes", Payload::SizeU64));
                sc.push(Ev::rcv(
                    s.peer,
                    ltree.step_tag(s.round),
                    ltree.seg_fan(),
                    "hier-tree",
                    Payload::Frame,
                ));
            }
            for s in send_steps {
                sc.push(Ev::snd(s.peer, ltree.size_tag(s.round), 1, "hier-sizes", Payload::SizeU64));
                sc.push(Ev::snd(
                    s.peer,
                    ltree.step_tag(s.round),
                    ltree.seg_fan(),
                    "hier-tree",
                    Payload::Frame,
                ));
            }
            push_intra_down(sc, members, 0, plan.down().base);
        } else {
            push_intra_down(sc, members, local_idx, plan.down().base);
        }
    }
    g
}

/// Hierarchical scatter: optional root → root-leader bundle hop, subtree
/// bundles down the **segmented** leader binomial (size pre-message +
/// `seg_fan`-wide window per edge), then one raw chunk per member on
/// the single down tag (distinct destinations, so one tag suffices).
fn scatter_hier(n: usize, root: usize, topo: Option<&Topology>, tags: &mut Tags) -> OpGraph {
    let topo = topo.cloned().unwrap_or_else(|| Topology::flat(n));
    assert_eq!(topo.ranks(), n, "topology does not cover the communicator");
    assert_leader_budget(&topo);
    let base = tags.reserve(HierScatterPlan::span(n));
    let plan = HierScatterPlan::at(base, n);
    let mut g = OpGraph::empty("scatter", n);
    g.windows.push((base, base + HierScatterPlan::span(n)));
    let root_node = topo.node_of(root);
    let root_leader = topo.leader_of(root);
    let ltree = plan.leader_tree();

    for (me, sc) in g.scripts.iter_mut().enumerate() {
        let node = topo.node_of(me);
        let members = topo.members(node);
        let local_idx = topo.local_index(me);
        if me == root && me != root_leader {
            sc.push(Ev::snd(root_leader, plan.hop_tag(), 1, "hier-hop", Payload::Bundle));
        }
        if local_idx == 0 {
            let (recv_step, send_steps) = binomial_bcast_in_group(topo.leaders(), node, root_node);
            if me == root && me == root_leader {
                // Holds the root bundle already.
            } else if node == root_node {
                sc.push(Ev::rcv(root, plan.hop_tag(), 1, "hier-hop", Payload::Bundle));
            } else {
                let s = recv_step.expect("non-root-node leader receives");
                sc.push(Ev::rcv(s.peer, ltree.size_tag(s.round), 1, "hier-sizes", Payload::SizeU64));
                sc.push(Ev::rcv(
                    s.peer,
                    ltree.step_tag(s.round),
                    ltree.seg_fan(),
                    "hier-tree",
                    Payload::Bundle,
                ));
            }
            for s in send_steps {
                sc.push(Ev::snd(s.peer, ltree.size_tag(s.round), 1, "hier-sizes", Payload::SizeU64));
                sc.push(Ev::snd(
                    s.peer,
                    ltree.step_tag(s.round),
                    ltree.seg_fan(),
                    "hier-tree",
                    Payload::Bundle,
                ));
            }
            for &mr in members {
                if mr != me {
                    sc.push(Ev::snd(mr, plan.down_tag(), 1, "hier-down", Payload::Raw));
                }
            }
        } else {
            sc.push(Ev::rcv(topo.leader_of(me), plan.down_tag(), 1, "hier-down", Payload::Raw));
        }
    }
    g
}

/// Hierarchical gather: raw member chunks up, merged per-member frame
/// record bundles up the **segmented** leader binomial toward the root's
/// leader (size pre-message + `seg_fan`-wide window per edge), and an
/// optional monolithic root-leader → follower-root bundle hop.
fn gather_hier(n: usize, root: usize, topo: Option<&Topology>, tags: &mut Tags) -> OpGraph {
    let topo = topo.cloned().unwrap_or_else(|| Topology::flat(n));
    assert_eq!(topo.ranks(), n, "topology does not cover the communicator");
    assert_leader_budget(&topo);
    let base = tags.reserve(HierGatherPlan::span(n));
    let plan = HierGatherPlan::at(base, n);
    let mut g = OpGraph::empty("gather", n);
    g.windows.push((base, base + HierGatherPlan::span(n)));
    let root_node = topo.node_of(root);
    let root_leader = topo.leader_of(root);
    let ltree = plan.leader_tree();

    for (me, sc) in g.scripts.iter_mut().enumerate() {
        let node = topo.node_of(me);
        let members = topo.members(node);
        if topo.local_index(me) != 0 {
            sc.push(Ev::snd(topo.leader_of(me), plan.up_tag(), 1, "hier-up", Payload::Raw));
            if me == root {
                sc.push(Ev::rcv(root_leader, plan.hop_tag(), 1, "hier-hop", Payload::Bundle));
            }
            continue;
        }
        for &mr in &members[1..] {
            sc.push(Ev::rcv(mr, plan.up_tag(), 1, "hier-up", Payload::Raw));
        }
        let (parent_step, child_steps) = binomial_bcast_in_group(topo.leaders(), node, root_node);
        for s in child_steps.iter().rev() {
            sc.push(Ev::rcv(s.peer, ltree.size_tag(s.round), 1, "hier-sizes", Payload::SizeU64));
            sc.push(Ev::rcv(
                s.peer,
                ltree.step_tag(s.round),
                ltree.seg_fan(),
                "hier-tree",
                Payload::Bundle,
            ));
        }
        if node == root_node {
            if me != root {
                sc.push(Ev::snd(root, plan.hop_tag(), 1, "hier-hop", Payload::Bundle));
            }
        } else {
            let s = parent_step.expect("non-root-node leader has a parent");
            sc.push(Ev::snd(s.peer, ltree.size_tag(s.round), 1, "hier-sizes", Payload::SizeU64));
            sc.push(Ev::snd(
                s.peer,
                ltree.step_tag(s.round),
                ltree.seg_fan(),
                "hier-tree",
                Payload::Bundle,
            ));
        }
    }
    g
}

/// Hierarchical reduce-scatter: raw member partials up, the flat ZCCL
/// reduce-scatter over the leader group (inner communicator translated
/// through [`group_wire_tag`]), one raw redistribution message per
/// ordered leader pair (all sends posted before any receive — memchan
/// buffers sends, so the all-pairs exchange cannot deadlock), then each
/// member's owned chunk down.
fn reduce_scatter_hier(n: usize, topo: Option<&Topology>, tags: &mut Tags) -> OpGraph {
    let topo = topo.cloned().unwrap_or_else(|| Topology::flat(n));
    assert_eq!(topo.ranks(), n, "topology does not cover the communicator");
    assert_leader_budget(&topo);
    let base = tags.reserve(HierReduceScatterPlan::span(n));
    let plan = HierReduceScatterPlan::at(base, n);
    let mut g = OpGraph::empty("reduce_scatter", n);
    g.windows.push((base, base + HierReduceScatterPlan::span(n)));
    let nnodes = topo.nodes();
    let leaders: Vec<usize> = topo.leaders().to_vec();

    for (me, sc) in g.scripts.iter_mut().enumerate() {
        let node = topo.node_of(me);
        let members = topo.members(node);
        if topo.local_index(me) != 0 {
            sc.push(Ev::snd(topo.leader_of(me), plan.up_tag(), 1, "hier-up", Payload::Raw));
            sc.push(Ev::rcv(topo.leader_of(me), plan.down_tag(), 1, "hier-down", Payload::Raw));
            continue;
        }
        for &mr in &members[1..] {
            sc.push(Ev::rcv(mr, plan.up_tag(), 1, "hier-up", Payload::Raw));
        }
    }

    if nnodes > 1 {
        let mut inner_tags = Tags::new();
        let inner = reduce_scatter(Algo::Zccl, nnodes, &mut inner_tags);
        for (i, inner_sc) in inner.scripts.into_iter().enumerate() {
            let sc = &mut g.scripts[leaders[i]];
            for ev in inner_sc {
                sc.push(Ev {
                    peer: leaders[ev.peer],
                    tag: group_wire_tag(plan.group_base(), ev.tag),
                    phase: "hier-inter",
                    ..ev
                });
            }
        }
        for (node, &leader) in leaders.iter().enumerate() {
            let sc = &mut g.scripts[leader];
            for k in 0..nnodes {
                if k != node {
                    sc.push(Ev::snd(leaders[k], plan.redist_tag(), 1, "hier-redist", Payload::Raw));
                }
            }
            for k in 0..nnodes {
                if k != node {
                    sc.push(Ev::rcv(leaders[k], plan.redist_tag(), 1, "hier-redist", Payload::Raw));
                }
            }
        }
    }

    for (node, &leader) in leaders.iter().enumerate() {
        let members = topo.members(node);
        let sc = &mut g.scripts[leader];
        for &mr in &members[1..] {
            sc.push(Ev::snd(mr, plan.down_tag(), 1, "hier-down", Payload::Raw));
        }
    }
    g
}

/// Hierarchical alltoall: raw member inputs up, pairwise frame-bundle
/// lanes between the leaders (round `t` pairs leader `j` with leader
/// `(j + t) mod L`), raw assembled outputs down.
fn alltoall_hier(n: usize, topo: Option<&Topology>, tags: &mut Tags) -> OpGraph {
    let topo = topo.cloned().unwrap_or_else(|| Topology::flat(n));
    assert_eq!(topo.ranks(), n, "topology does not cover the communicator");
    assert_leader_budget(&topo);
    let base = tags.reserve(HierAlltoallPlan::span(n));
    let plan = HierAlltoallPlan::at(base, n);
    let mut g = OpGraph::empty("alltoall", n);
    g.windows.push((base, base + HierAlltoallPlan::span(n)));
    let nnodes = topo.nodes();
    let leaders = topo.leaders();

    for (me, sc) in g.scripts.iter_mut().enumerate() {
        let node = topo.node_of(me);
        let members = topo.members(node);
        if topo.local_index(me) != 0 {
            sc.push(Ev::snd(topo.leader_of(me), plan.up_tag(), 1, "hier-up", Payload::Raw));
            sc.push(Ev::rcv(topo.leader_of(me), plan.down_tag(), 1, "hier-down", Payload::Raw));
            continue;
        }
        for &mr in &members[1..] {
            sc.push(Ev::rcv(mr, plan.up_tag(), 1, "hier-up", Payload::Raw));
        }
        for t in 1..nnodes {
            let to = leaders[(node + t) % nnodes];
            let from = leaders[(node + nnodes - t) % nnodes];
            sc.push(Ev::snd(to, plan.lane_tag(t), 1, "hier-lane", Payload::Bundle));
            sc.push(Ev::rcv(from, plan.lane_tag(t), 1, "hier-lane", Payload::Bundle));
        }
        for &mr in members {
            if mr != me {
                sc.push(Ev::snd(mr, plan.down_tag(), 1, "hier-down", Payload::Raw));
            }
        }
    }
    g
}

/// Hierarchical reduce: raw member partials up, the flat ZCCL binomial
/// reduce over the leader group toward the root's leader (inner
/// communicator translated through [`group_wire_tag`]), and an optional
/// raw root-leader → follower-root result hop over the fast tier.
fn reduce_hier(n: usize, root: usize, topo: Option<&Topology>, tags: &mut Tags) -> OpGraph {
    let topo = topo.cloned().unwrap_or_else(|| Topology::flat(n));
    assert_eq!(topo.ranks(), n, "topology does not cover the communicator");
    assert_leader_budget(&topo);
    let base = tags.reserve(HierReducePlan::span(n));
    let plan = HierReducePlan::at(base, n);
    let mut g = OpGraph::empty("reduce", n);
    g.windows.push((base, base + HierReducePlan::span(n)));
    let nnodes = topo.nodes();
    let leaders: Vec<usize> = topo.leaders().to_vec();
    let root_node = topo.node_of(root);
    let root_leader = topo.leader_of(root);

    for (me, sc) in g.scripts.iter_mut().enumerate() {
        let members = topo.members(topo.node_of(me));
        if topo.local_index(me) != 0 {
            sc.push(Ev::snd(topo.leader_of(me), plan.up_tag(), 1, "hier-up", Payload::Raw));
            if me == root {
                sc.push(Ev::rcv(root_leader, plan.hop_tag(), 1, "hier-hop", Payload::Raw));
            }
            continue;
        }
        for &mr in &members[1..] {
            sc.push(Ev::rcv(mr, plan.up_tag(), 1, "hier-up", Payload::Raw));
        }
    }

    if nnodes > 1 {
        let mut inner_tags = Tags::new();
        let inner = tree_up("reduce", nnodes, root_node, Payload::Frame, &mut inner_tags);
        for (i, inner_sc) in inner.scripts.into_iter().enumerate() {
            let sc = &mut g.scripts[leaders[i]];
            for ev in inner_sc {
                sc.push(Ev {
                    peer: leaders[ev.peer],
                    tag: group_wire_tag(plan.group_base(), ev.tag),
                    phase: "hier-inter",
                    ..ev
                });
            }
        }
    }

    if root != root_leader {
        g.scripts[root_leader].push(Ev::snd(root, plan.hop_tag(), 1, "hier-hop", Payload::Raw));
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_bcast_has_n_minus_1_messages() {
        for n in 2..=9usize {
            for root in [0, n - 1] {
                let mut t = Tags::new();
                let g = build(Coll::Bcast, Algo::Zccl, n, root, None, &mut t);
                assert_eq!(g.send_count(), n as u64 - 1, "n={n} root={root}");
            }
        }
    }

    #[test]
    fn barrier_rounds_are_log2() {
        for (n, rounds) in [(2usize, 1u64), (3, 2), (4, 2), (5, 3), (8, 3), (9, 4)] {
            let mut t = Tags::new();
            let g = build(Coll::Barrier, Algo::Plain, n, 0, None, &mut t);
            assert_eq!(g.send_count(), n as u64 * rounds, "n={n}");
            for sc in &g.scripts {
                for ev in sc {
                    assert!(ev.tag & BARRIER_TAG_BASE != 0);
                }
            }
        }
    }

    #[test]
    fn allgather_zccl_rounds_fan_wide() {
        let mut t = Tags::new();
        let g = build(Coll::Allgather, Algo::Zccl, 4, 0, None, &mut t);
        let fans: Vec<u64> = g.scripts[0]
            .iter()
            .filter(|e| e.phase == "ag-round" && e.dir == Dir::Send)
            .map(|e| e.fan)
            .collect();
        assert_eq!(fans, vec![SEG_TAG_SPAN; 3]);
        // Plain rounds stay single-tag.
        let mut t = Tags::new();
        let g = build(Coll::Allgather, Algo::Plain, 4, 0, None, &mut t);
        assert!(g.scripts[0].iter().all(|e| e.fan == 1));
    }

    #[test]
    fn hier_flat_topology_degenerates_to_flat_zccl_over_all_ranks() {
        // On a rank-per-node topology the up/down tiers vanish and the
        // leader tier is the whole communicator.
        let n = 5;
        let mut t = Tags::new();
        let g = build(Coll::Allreduce, Algo::Hier, n, 0, None, &mut t);
        let mut inner_tags = Tags::new();
        let mut flat = reduce_scatter(Algo::Zccl, n, &mut inner_tags);
        append(&mut flat, allgather_flat(Algo::Zccl, n, &mut inner_tags));
        assert_eq!(g.send_count(), flat.send_count());
        assert!(g.scripts.iter().flatten().all(|e| e.phase == "hier-inter"));
    }

    #[test]
    fn single_rank_is_silent_but_barrier_still_reserves() {
        for coll in Coll::ALL {
            let mut t = Tags::new();
            let g = build(coll, Algo::Zccl, 1, 0, None, &mut t);
            assert_eq!(g.send_count(), 0, "{}", coll.name());
            if coll == Coll::Barrier {
                assert_eq!(g.windows, vec![(0, BARRIER_GEN_SPAN)]);
            } else {
                assert!(g.windows.is_empty(), "{}", coll.name());
            }
        }
    }
}
