//! Pure tag-layout plans — the single source of truth for every
//! collective's tag arithmetic.
//!
//! Each collective reserves ONE contiguous slice of the communicator's
//! tag counter ([`crate::collectives::Communicator::fresh_tags`]) sized
//! by the plan's `span`, then derives every wire tag through the plan's
//! accessors. The executors ([`crate::collectives`]) and the static
//! schedule verifier ([`crate::analysis`]) both consume these plans, so
//! the verifier's predicted tags are — by construction — the tags the
//! runtime puts on the wire. Nothing in this module touches a transport:
//! plans are plain arithmetic over `(base, n)`.
//!
//! The hierarchical plans fold what used to be two or three consecutive
//! `fresh_tags` calls into one span. Because consecutive reservations on
//! a monotonic counter are contiguous, the resulting tag values are
//! identical to the historical layout — the fold only makes the layout
//! *inspectable*.

use crate::collectives::SEG_TAG_SPAN;
use crate::topology::tree_rounds;

/// Tag span reserved for one hierarchical collective's inter-leader
/// tier: the leader group wraps the fabric in a
/// [`crate::transport::GroupTransport`] based here, and the flat
/// collective run over it lands on `base + inner_tag`
/// ([`crate::transport::group_wire_tag`]). Sized so the leader tier's
/// largest flat reservation — an allgather's
/// `(nodes + 2) * SEG_TAG_SPAN` — fits for any plausible node count;
/// [`crate::collectives::hier`] rejects topologies that would not.
pub const HIER_GROUP_SPAN: u64 = 1 << 33;

/// Ring schedule over `n` ranks: one tag per round, `n - 1` rounds, with
/// one spare so the span is exactly `n`. Used by the flat reduce-scatter,
/// the `u64` size exchange, and the hierarchical allgather's
/// leader-bundle ring.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RingPlan {
    /// First tag of the reserved slice.
    pub base: u64,
    /// Ring size.
    pub n: usize,
}

impl RingPlan {
    /// Tags to reserve for a ring over `n` ranks.
    pub fn span(n: usize) -> u64 {
        n as u64
    }
    /// Bind a reserved `base` to a ring of `n` ranks.
    pub fn at(base: u64, n: usize) -> RingPlan {
        RingPlan { base, n }
    }
    /// Wire tag of ring round `t` (`t < n - 1`).
    pub fn round_tag(&self, t: usize) -> u64 {
        self.base + t as u64
    }
}

/// Binomial-tree schedule (bcast, scatter, gather, reduce, and the
/// hierarchical down/leader trees): one tag per tree round, spanning
/// `tree_rounds(n) + 1` so even the deepest step plus the root's spare
/// fit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TreePlan {
    /// First tag of the reserved slice.
    pub base: u64,
    /// Communicator size the rounds were sized for.
    pub n: usize,
}

impl TreePlan {
    /// Tags to reserve for a binomial tree over `n` ranks.
    pub fn span(n: usize) -> u64 {
        tree_rounds(n) as u64 + 1
    }
    /// Bind a reserved `base` to a tree over `n` ranks.
    pub fn at(base: u64, n: usize) -> TreePlan {
        TreePlan { base, n }
    }
    /// Wire tag of tree round `round`.
    pub fn step_tag(&self, round: usize) -> u64 {
        self.base + round as u64
    }
}

/// Segmented ring (§3.5.1 fixed pipeline over a ring): each of the up to
/// `n - 1` rounds owns a [`SEG_TAG_SPAN`]-wide fan so the round's
/// pipeline segments travel on consecutive tags and overlap send/recv.
/// Used by the hierarchical allgather's inter-leader bundle ring.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SegRingPlan {
    /// First tag of the reserved slice.
    pub base: u64,
    /// Ring size the rounds were sized for.
    pub n: usize,
}

impl SegRingPlan {
    /// Tags to reserve for a segmented ring over `n` ranks.
    pub fn span(n: usize) -> u64 {
        n as u64 * SEG_TAG_SPAN
    }
    /// Bind a reserved `base` to a segmented ring of `n` ranks.
    pub fn at(base: u64, n: usize) -> SegRingPlan {
        SegRingPlan { base, n }
    }
    /// First tag of round `t`'s segment fan (`t < n - 1`); segment `i`
    /// travels on `round_tag(t) + i`, `i <` [`Self::seg_fan`].
    pub fn round_tag(&self, t: usize) -> u64 {
        self.base + t as u64 * SEG_TAG_SPAN
    }
    /// Width of each round's segment fan.
    pub fn seg_fan(&self) -> u64 {
        SEG_TAG_SPAN
    }
}

/// Segmented binomial tree (§3.5.1 fixed pipeline over tree edges): each
/// tree round owns a `u64` size pre-message tag plus a
/// [`SEG_TAG_SPAN`]-wide fan for the payload segments. Used by the
/// hierarchical bcast / scatter / gather inter-leader trees, whose bundle
/// sizes (unlike the flat frames) are not derivable by the receiver.
///
/// Layout within the span (relative to `base`, with
/// `R = tree_rounds(n) + 1`):
///
/// ```text
/// [0, R)                          per-round u64 size pre-messages
/// [R + t*SEG_TAG_SPAN, +SEG_TAG_SPAN)  round-t segment fan
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SegTreePlan {
    /// First tag of the reserved slice.
    pub base: u64,
    /// Communicator size the rounds were sized for.
    pub n: usize,
}

impl SegTreePlan {
    fn rounds(n: usize) -> u64 {
        tree_rounds(n) as u64 + 1
    }
    /// Tags to reserve for a segmented binomial tree over `n` ranks.
    pub fn span(n: usize) -> u64 {
        Self::rounds(n) * (1 + SEG_TAG_SPAN)
    }
    /// Bind a reserved `base` to a segmented tree over `n` ranks.
    pub fn at(base: u64, n: usize) -> SegTreePlan {
        SegTreePlan { base, n }
    }
    /// Tag of round `round`'s `u64` total-size pre-message.
    pub fn size_tag(&self, round: usize) -> u64 {
        self.base + round as u64
    }
    /// First tag of round `round`'s segment fan; segment `i` travels on
    /// `step_tag(round) + i`, `i <` [`Self::seg_fan`].
    pub fn step_tag(&self, round: usize) -> u64 {
        self.base + Self::rounds(self.n) + round as u64 * SEG_TAG_SPAN
    }
    /// Width of each round's segment fan.
    pub fn seg_fan(&self) -> u64 {
        SEG_TAG_SPAN
    }
}

/// Ring allgather with segmented rounds (§3.5.1): a count exchange, a
/// compressed-size exchange, then `n - 1` ring rounds each owning a
/// [`SEG_TAG_SPAN`]-wide fan for its pipeline segments.
///
/// Layout within the span (relative to `base`):
///
/// ```text
/// [0, n)                               count-exchange ring
/// [n, 2n)                              size-exchange ring (compressed modes)
/// [(t+1)*SEG_TAG_SPAN, +SEG_TAG_SPAN)  round-t segment fan, t in 0..n-1
/// ```
///
/// The two exchange rings fit below the first round's fan because
/// `2n <= SEG_TAG_SPAN` for every rank count the transports support —
/// the schedule verifier checks the bound for every swept shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AllgatherPlan {
    /// First tag of the reserved slice.
    pub base: u64,
    /// Communicator size.
    pub n: usize,
}

impl AllgatherPlan {
    /// Tags to reserve for a segmented ring allgather over `n` ranks.
    pub fn span(n: usize) -> u64 {
        (n as u64 + 2) * SEG_TAG_SPAN
    }
    /// Bind a reserved `base` to an allgather over `n` ranks.
    pub fn at(base: u64, n: usize) -> AllgatherPlan {
        AllgatherPlan { base, n }
    }
    /// Ring plan of the element-count exchange.
    pub fn counts_ring(&self) -> RingPlan {
        RingPlan::at(self.base, self.n)
    }
    /// Ring plan of the compressed-size exchange.
    pub fn sizes_ring(&self) -> RingPlan {
        RingPlan::at(self.base + self.n as u64, self.n)
    }
    /// First tag of ring round `t`'s segment fan (`t < n - 1`); segments
    /// `i` of the round travel on `round_tag(t) + i`, `i <` [`Self::seg_fan`].
    pub fn round_tag(&self, t: usize) -> u64 {
        self.base + (t as u64 + 1) * SEG_TAG_SPAN
    }
    /// Width of each round's segment fan.
    pub fn seg_fan(&self) -> u64 {
        SEG_TAG_SPAN
    }
}

/// Pairwise-exchange alltoall: round `t` pairs each rank with
/// `(rank + t) % n` on one tag, plus a size-exchange ring for the
/// compressed modes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AlltoallPlan {
    /// First tag of the reserved slice.
    pub base: u64,
    /// Communicator size.
    pub n: usize,
}

impl AlltoallPlan {
    /// Tags to reserve for an alltoall over `n` ranks.
    pub fn span(n: usize) -> u64 {
        2 * n as u64
    }
    /// Bind a reserved `base` to an alltoall over `n` ranks.
    pub fn at(base: u64, n: usize) -> AlltoallPlan {
        AlltoallPlan { base, n }
    }
    /// Wire tag of pairwise round `t` (`1 <= t < n`).
    pub fn pair_tag(&self, t: usize) -> u64 {
        self.base + t as u64
    }
    /// Ring plan of the compressed-size exchange.
    pub fn sizes_ring(&self) -> RingPlan {
        RingPlan::at(self.base + self.n as u64, self.n)
    }
}

/// Two-level allreduce (`Algo::Hier`): intra-node raw up-links on one
/// tag, a [`HIER_GROUP_SPAN`]-wide leader tier (flat reduce-scatter +
/// allgather over a group view), then an intra-node result broadcast
/// down a binomial tree.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HierAllreducePlan {
    /// First tag of the reserved slice.
    pub base: u64,
    /// Total communicator size (not the leader count).
    pub n: usize,
}

impl HierAllreducePlan {
    /// Tags to reserve for a hierarchical allreduce over `n` ranks.
    pub fn span(n: usize) -> u64 {
        1 + HIER_GROUP_SPAN + TreePlan::span(n)
    }
    /// Bind a reserved `base` to a hierarchical allreduce over `n` ranks.
    pub fn at(base: u64, n: usize) -> HierAllreducePlan {
        HierAllreducePlan { base, n }
    }
    /// Tag of the member → leader raw partial up-link.
    pub fn up_tag(&self) -> u64 {
        self.base
    }
    /// Group-view tag base of the inter-leader tier.
    pub fn group_base(&self) -> u64 {
        self.base + 1
    }
    /// Tree plan of the intra-node result broadcast.
    pub fn down(&self) -> TreePlan {
        TreePlan::at(self.base + 1 + HIER_GROUP_SPAN, self.n)
    }
}

/// Two-level allgather: member chunks up on one tag, a bundle-size ring,
/// segmented compressed bundles around the leader ring (§3.5.1 fixed
/// pipeline, so leader frames overlap send/recv like the flat ring),
/// result broadcast down the intra-node tree.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HierAllgatherPlan {
    /// First tag of the reserved slice.
    pub base: u64,
    /// Total communicator size.
    pub n: usize,
}

impl HierAllgatherPlan {
    /// Tags to reserve for a hierarchical allgather over `n` ranks.
    pub fn span(n: usize) -> u64 {
        1 + RingPlan::span(n) + SegRingPlan::span(n) + TreePlan::span(n)
    }
    /// Bind a reserved `base` to a hierarchical allgather over `n` ranks.
    pub fn at(base: u64, n: usize) -> HierAllgatherPlan {
        HierAllgatherPlan { base, n }
    }
    /// Tag of the member → leader raw chunk up-link.
    pub fn up_tag(&self) -> u64 {
        self.base
    }
    /// Ring plan of the inter-leader bundle-size exchange (the segmented
    /// receiver needs each bundle's total bytes up front).
    pub fn sizes_ring(&self) -> RingPlan {
        RingPlan::at(self.base + 1, self.n)
    }
    /// Segmented ring plan of the inter-leader bundle ring (rounds
    /// indexed by node count; the span is sized for `n` ranks, an upper
    /// bound).
    pub fn leader_ring(&self) -> SegRingPlan {
        SegRingPlan::at(self.base + 1 + RingPlan::span(self.n), self.n)
    }
    /// Tree plan of the intra-node result broadcast.
    pub fn down(&self) -> TreePlan {
        TreePlan::at(self.base + 1 + RingPlan::span(self.n) + SegRingPlan::span(self.n), self.n)
    }
}

/// Two-level bcast: an optional root → root-leader hop, a segmented
/// binomial tree over the leaders (§3.5.1 pipeline per edge), then the
/// intra-node tree down.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HierBcastPlan {
    /// First tag of the reserved slice.
    pub base: u64,
    /// Total communicator size.
    pub n: usize,
}

impl HierBcastPlan {
    /// Tags to reserve for a hierarchical bcast over `n` ranks.
    pub fn span(n: usize) -> u64 {
        1 + SegTreePlan::span(n) + TreePlan::span(n)
    }
    /// Bind a reserved `base` to a hierarchical bcast over `n` ranks.
    pub fn at(base: u64, n: usize) -> HierBcastPlan {
        HierBcastPlan { base, n }
    }
    /// Tag of the non-leader-root → root-leader frame hop.
    pub fn hop_tag(&self) -> u64 {
        self.base
    }
    /// Segmented tree plan of the inter-leader frame broadcast.
    pub fn leader_tree(&self) -> SegTreePlan {
        SegTreePlan::at(self.base + 1, self.n)
    }
    /// Tree plan of the intra-node broadcast.
    pub fn down(&self) -> TreePlan {
        TreePlan::at(self.base + 1 + SegTreePlan::span(self.n), self.n)
    }
}

/// Two-level scatter: an optional root → root-leader bundle hop, subtree
/// bundles down the segmented leader tree, then one raw chunk per member
/// on a single tag.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HierScatterPlan {
    /// First tag of the reserved slice.
    pub base: u64,
    /// Total communicator size.
    pub n: usize,
}

impl HierScatterPlan {
    /// Tags to reserve for a hierarchical scatter over `n` ranks.
    pub fn span(n: usize) -> u64 {
        1 + SegTreePlan::span(n) + 1
    }
    /// Bind a reserved `base` to a hierarchical scatter over `n` ranks.
    pub fn at(base: u64, n: usize) -> HierScatterPlan {
        HierScatterPlan { base, n }
    }
    /// Tag of the non-leader-root → root-leader bundle hop.
    pub fn hop_tag(&self) -> u64 {
        self.base
    }
    /// Segmented tree plan of the inter-leader subtree-bundle forwarding.
    pub fn leader_tree(&self) -> SegTreePlan {
        SegTreePlan::at(self.base + 1, self.n)
    }
    /// Tag of the leader → member raw chunk down-link (one tag; each
    /// member's chunk is a distinct `(src, dst)` edge).
    pub fn down_tag(&self) -> u64 {
        self.base + 1 + SegTreePlan::span(self.n)
    }
}

/// Two-level gather: one raw chunk per member up to its leader, merged
/// per-member frame-record bundles up the segmented leader tree toward
/// the root's leader, then an optional root-leader → root bundle hop
/// over the fast tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HierGatherPlan {
    /// First tag of the reserved slice.
    pub base: u64,
    /// Total communicator size.
    pub n: usize,
}

impl HierGatherPlan {
    /// Tags to reserve for a hierarchical gather over `n` ranks.
    pub fn span(n: usize) -> u64 {
        1 + SegTreePlan::span(n) + 1
    }
    /// Bind a reserved `base` to a hierarchical gather over `n` ranks.
    pub fn at(base: u64, n: usize) -> HierGatherPlan {
        HierGatherPlan { base, n }
    }
    /// Tag of the member → leader raw chunk up-link.
    pub fn up_tag(&self) -> u64 {
        self.base
    }
    /// Segmented tree plan of the inter-leader record-bundle gather.
    pub fn leader_tree(&self) -> SegTreePlan {
        SegTreePlan::at(self.base + 1, self.n)
    }
    /// Tag of the root-leader → non-leader-root bundle hop.
    pub fn hop_tag(&self) -> u64 {
        self.base + 1 + SegTreePlan::span(self.n)
    }
}

/// Two-level reduce-scatter: intra-node raw up-links on one tag, a
/// [`HIER_GROUP_SPAN`]-wide leader tier (flat ZCCL reduce-scatter over a
/// group view), one raw redistribution message per ordered leader pair
/// (the leader tier's L-chunks do not align with the n-way ownership
/// chunks), then one raw owned chunk per member down.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HierReduceScatterPlan {
    /// First tag of the reserved slice.
    pub base: u64,
    /// Total communicator size (not the leader count).
    pub n: usize,
}

impl HierReduceScatterPlan {
    /// Tags to reserve for a hierarchical reduce-scatter over `n` ranks.
    pub fn span(_n: usize) -> u64 {
        3 + HIER_GROUP_SPAN
    }
    /// Bind a reserved `base` to a hierarchical reduce-scatter.
    pub fn at(base: u64, n: usize) -> HierReduceScatterPlan {
        HierReduceScatterPlan { base, n }
    }
    /// Tag of the member → leader raw partial up-link.
    pub fn up_tag(&self) -> u64 {
        self.base
    }
    /// Group-view tag base of the inter-leader tier.
    pub fn group_base(&self) -> u64 {
        self.base + 1
    }
    /// Tag of the leader ↔ leader raw chunk redistribution (one message
    /// per ordered leader pair; distinct `(src, dst)` edges).
    pub fn redist_tag(&self) -> u64 {
        self.base + 1 + HIER_GROUP_SPAN
    }
    /// Tag of the leader → member raw owned-chunk down-link.
    pub fn down_tag(&self) -> u64 {
        self.base + 2 + HIER_GROUP_SPAN
    }
}

/// Two-level alltoall: each member's full input raw up to its leader on
/// one tag, pairwise compressed bundle lanes between the leaders (round
/// `t` pairs leader `j` with leader `(j + t) % L`), then each member's
/// assembled output raw down on one tag.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HierAlltoallPlan {
    /// First tag of the reserved slice.
    pub base: u64,
    /// Total communicator size.
    pub n: usize,
}

impl HierAlltoallPlan {
    /// Tags to reserve for a hierarchical alltoall over `n` ranks.
    pub fn span(n: usize) -> u64 {
        n as u64 + 2
    }
    /// Bind a reserved `base` to a hierarchical alltoall over `n` ranks.
    pub fn at(base: u64, n: usize) -> HierAlltoallPlan {
        HierAlltoallPlan { base, n }
    }
    /// Tag of the member → leader raw full-input up-link.
    pub fn up_tag(&self) -> u64 {
        self.base
    }
    /// Wire tag of pairwise leader round `t` (`1 <= t < L <= n`).
    pub fn lane_tag(&self, t: usize) -> u64 {
        self.base + 1 + t as u64
    }
    /// Tag of the leader → member raw assembled-output down-link.
    pub fn down_tag(&self) -> u64 {
        self.base + 1 + self.n as u64
    }
}

/// Two-level reduce: intra-node raw up-links on one tag, a
/// [`HIER_GROUP_SPAN`]-wide leader tier (flat ZCCL reduce over a group
/// view toward the root's leader), then an optional root-leader → root
/// raw hop over the fast tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HierReducePlan {
    /// First tag of the reserved slice.
    pub base: u64,
    /// Total communicator size (not the leader count).
    pub n: usize,
}

impl HierReducePlan {
    /// Tags to reserve for a hierarchical reduce over `n` ranks.
    pub fn span(_n: usize) -> u64 {
        2 + HIER_GROUP_SPAN
    }
    /// Bind a reserved `base` to a hierarchical reduce.
    pub fn at(base: u64, n: usize) -> HierReducePlan {
        HierReducePlan { base, n }
    }
    /// Tag of the member → leader raw partial up-link.
    pub fn up_tag(&self) -> u64 {
        self.base
    }
    /// Group-view tag base of the inter-leader tier.
    pub fn group_base(&self) -> u64 {
        self.base + 1
    }
    /// Tag of the root-leader → non-leader-root raw result hop.
    pub fn hop_tag(&self) -> u64 {
        self.base + 1 + HIER_GROUP_SPAN
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_cover_their_accessors() {
        for n in 1..=16usize {
            let rs = RingPlan::at(0, n);
            assert!(rs.round_tag(n.saturating_sub(1)) < RingPlan::span(n).max(1) + 1);
            let tree = TreePlan::at(0, n);
            assert!(tree.step_tag(tree_rounds(n)) < TreePlan::span(n));
            let ag = AllgatherPlan::at(0, n);
            assert!(ag.counts_ring().round_tag(n.saturating_sub(1)) < AllgatherPlan::span(n));
            assert!(ag.sizes_ring().round_tag(n.saturating_sub(1)) < ag.round_tag(0));
            if n >= 2 {
                // Every round's full segment fan fits strictly before the
                // next round's fan — and the last fan ends at the span end.
                for t in 0..n - 2 {
                    assert_eq!(ag.round_tag(t) + ag.seg_fan(), ag.round_tag(t + 1));
                }
                assert_eq!(ag.round_tag(n - 2) + ag.seg_fan(), ag.base + AllgatherPlan::span(n));
            }
            let a2a = AlltoallPlan::at(0, n);
            assert!(a2a.pair_tag(n.saturating_sub(1)) < a2a.sizes_ring().base + n as u64);
            assert_eq!(a2a.sizes_ring().round_tag(0), n as u64);

            let sr = SegRingPlan::at(0, n);
            if n >= 2 {
                for t in 0..n - 2 {
                    assert_eq!(sr.round_tag(t) + sr.seg_fan(), sr.round_tag(t + 1));
                }
                assert!(sr.round_tag(n - 2) + sr.seg_fan() <= SegRingPlan::span(n));
            }
            let stp = SegTreePlan::at(0, n);
            let rounds = tree_rounds(n);
            assert!(stp.size_tag(rounds) < stp.step_tag(0));
            for t in 0..rounds {
                assert_eq!(stp.step_tag(t) + stp.seg_fan(), stp.step_tag(t + 1));
            }
            assert_eq!(stp.step_tag(rounds) + stp.seg_fan(), SegTreePlan::span(n));
        }
    }

    #[test]
    fn hier_spans_match_their_reservation_layout() {
        // The folded spans must reproduce the tag values the executors
        // derive — every accessor lands inside the span, in order.
        let n = 12;
        let h = HierAllreducePlan::at(100, n);
        assert_eq!(h.up_tag(), 100);
        assert_eq!(h.group_base(), 101);
        assert_eq!(h.down().base, 101 + HIER_GROUP_SPAN);
        assert_eq!(HierAllreducePlan::span(n), 1 + HIER_GROUP_SPAN + TreePlan::span(n));

        let g = HierAllgatherPlan::at(7, n);
        assert_eq!(g.up_tag(), 7);
        assert_eq!(g.sizes_ring().base, 8);
        assert_eq!(g.leader_ring().base, 8 + n as u64);
        assert_eq!(g.down().base, 8 + n as u64 + SegRingPlan::span(n));
        assert_eq!(
            HierAllgatherPlan::span(n),
            1 + n as u64 + SegRingPlan::span(n) + TreePlan::span(n)
        );

        let b = HierBcastPlan::at(3, n);
        assert_eq!(b.hop_tag(), 3);
        assert_eq!(b.leader_tree().base, 4);
        assert_eq!(b.down().base, 4 + SegTreePlan::span(n));

        let s = HierScatterPlan::at(5, n);
        assert_eq!(s.hop_tag(), 5);
        assert_eq!(s.leader_tree().base, 6);
        assert_eq!(s.down_tag(), 6 + SegTreePlan::span(n));
        assert_eq!(HierScatterPlan::span(n), s.down_tag() - 5 + 1);

        let ga = HierGatherPlan::at(9, n);
        assert_eq!(ga.up_tag(), 9);
        assert_eq!(ga.leader_tree().base, 10);
        assert_eq!(ga.hop_tag(), 10 + SegTreePlan::span(n));
        assert_eq!(HierGatherPlan::span(n), ga.hop_tag() - 9 + 1);

        let rs = HierReduceScatterPlan::at(11, n);
        assert_eq!(rs.up_tag(), 11);
        assert_eq!(rs.group_base(), 12);
        assert_eq!(rs.redist_tag(), 12 + HIER_GROUP_SPAN);
        assert_eq!(rs.down_tag(), 13 + HIER_GROUP_SPAN);
        assert_eq!(HierReduceScatterPlan::span(n), rs.down_tag() - 11 + 1);

        let a = HierAlltoallPlan::at(13, n);
        assert_eq!(a.up_tag(), 13);
        assert_eq!(a.lane_tag(1), 15);
        assert_eq!(a.lane_tag(n - 1), 13 + n as u64);
        assert_eq!(a.down_tag(), 14 + n as u64);
        assert_eq!(HierAlltoallPlan::span(n), a.down_tag() - 13 + 1);

        let r = HierReducePlan::at(17, n);
        assert_eq!(r.up_tag(), 17);
        assert_eq!(r.group_base(), 18);
        assert_eq!(r.hop_tag(), 18 + HIER_GROUP_SPAN);
        assert_eq!(HierReducePlan::span(n), r.hop_tag() - 17 + 1);
    }
}
