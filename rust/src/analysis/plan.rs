//! Pure tag-layout plans — the single source of truth for every
//! collective's tag arithmetic.
//!
//! Each collective reserves ONE contiguous slice of the communicator's
//! tag counter ([`crate::collectives::Communicator::fresh_tags`]) sized
//! by the plan's `span`, then derives every wire tag through the plan's
//! accessors. The executors ([`crate::collectives`]) and the static
//! schedule verifier ([`crate::analysis`]) both consume these plans, so
//! the verifier's predicted tags are — by construction — the tags the
//! runtime puts on the wire. Nothing in this module touches a transport:
//! plans are plain arithmetic over `(base, n)`.
//!
//! The hierarchical plans fold what used to be two or three consecutive
//! `fresh_tags` calls into one span. Because consecutive reservations on
//! a monotonic counter are contiguous, the resulting tag values are
//! identical to the historical layout — the fold only makes the layout
//! *inspectable*.

use crate::collectives::SEG_TAG_SPAN;
use crate::topology::tree_rounds;

/// Tag span reserved for one hierarchical collective's inter-leader
/// tier: the leader group wraps the fabric in a
/// [`crate::transport::GroupTransport`] based here, and the flat
/// collective run over it lands on `base + inner_tag`
/// ([`crate::transport::group_wire_tag`]). Sized so the leader tier's
/// largest flat reservation — an allgather's
/// `(nodes + 2) * SEG_TAG_SPAN` — fits for any plausible node count;
/// [`crate::collectives::hier`] rejects topologies that would not.
pub const HIER_GROUP_SPAN: u64 = 1 << 33;

/// Ring schedule over `n` ranks: one tag per round, `n - 1` rounds, with
/// one spare so the span is exactly `n`. Used by the flat reduce-scatter,
/// the `u64` size exchange, and the hierarchical allgather's
/// leader-bundle ring.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RingPlan {
    /// First tag of the reserved slice.
    pub base: u64,
    /// Ring size.
    pub n: usize,
}

impl RingPlan {
    /// Tags to reserve for a ring over `n` ranks.
    pub fn span(n: usize) -> u64 {
        n as u64
    }
    /// Bind a reserved `base` to a ring of `n` ranks.
    pub fn at(base: u64, n: usize) -> RingPlan {
        RingPlan { base, n }
    }
    /// Wire tag of ring round `t` (`t < n - 1`).
    pub fn round_tag(&self, t: usize) -> u64 {
        self.base + t as u64
    }
}

/// Binomial-tree schedule (bcast, scatter, gather, reduce, and the
/// hierarchical down/leader trees): one tag per tree round, spanning
/// `tree_rounds(n) + 1` so even the deepest step plus the root's spare
/// fit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TreePlan {
    /// First tag of the reserved slice.
    pub base: u64,
    /// Communicator size the rounds were sized for.
    pub n: usize,
}

impl TreePlan {
    /// Tags to reserve for a binomial tree over `n` ranks.
    pub fn span(n: usize) -> u64 {
        tree_rounds(n) as u64 + 1
    }
    /// Bind a reserved `base` to a tree over `n` ranks.
    pub fn at(base: u64, n: usize) -> TreePlan {
        TreePlan { base, n }
    }
    /// Wire tag of tree round `round`.
    pub fn step_tag(&self, round: usize) -> u64 {
        self.base + round as u64
    }
}

/// Ring allgather with segmented rounds (§3.5.1): a count exchange, a
/// compressed-size exchange, then `n - 1` ring rounds each owning a
/// [`SEG_TAG_SPAN`]-wide fan for its pipeline segments.
///
/// Layout within the span (relative to `base`):
///
/// ```text
/// [0, n)                               count-exchange ring
/// [n, 2n)                              size-exchange ring (compressed modes)
/// [(t+1)*SEG_TAG_SPAN, +SEG_TAG_SPAN)  round-t segment fan, t in 0..n-1
/// ```
///
/// The two exchange rings fit below the first round's fan because
/// `2n <= SEG_TAG_SPAN` for every rank count the transports support —
/// the schedule verifier checks the bound for every swept shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AllgatherPlan {
    /// First tag of the reserved slice.
    pub base: u64,
    /// Communicator size.
    pub n: usize,
}

impl AllgatherPlan {
    /// Tags to reserve for a segmented ring allgather over `n` ranks.
    pub fn span(n: usize) -> u64 {
        (n as u64 + 2) * SEG_TAG_SPAN
    }
    /// Bind a reserved `base` to an allgather over `n` ranks.
    pub fn at(base: u64, n: usize) -> AllgatherPlan {
        AllgatherPlan { base, n }
    }
    /// Ring plan of the element-count exchange.
    pub fn counts_ring(&self) -> RingPlan {
        RingPlan::at(self.base, self.n)
    }
    /// Ring plan of the compressed-size exchange.
    pub fn sizes_ring(&self) -> RingPlan {
        RingPlan::at(self.base + self.n as u64, self.n)
    }
    /// First tag of ring round `t`'s segment fan (`t < n - 1`); segments
    /// `i` of the round travel on `round_tag(t) + i`, `i <` [`Self::seg_fan`].
    pub fn round_tag(&self, t: usize) -> u64 {
        self.base + (t as u64 + 1) * SEG_TAG_SPAN
    }
    /// Width of each round's segment fan.
    pub fn seg_fan(&self) -> u64 {
        SEG_TAG_SPAN
    }
}

/// Pairwise-exchange alltoall: round `t` pairs each rank with
/// `(rank + t) % n` on one tag, plus a size-exchange ring for the
/// compressed modes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AlltoallPlan {
    /// First tag of the reserved slice.
    pub base: u64,
    /// Communicator size.
    pub n: usize,
}

impl AlltoallPlan {
    /// Tags to reserve for an alltoall over `n` ranks.
    pub fn span(n: usize) -> u64 {
        2 * n as u64
    }
    /// Bind a reserved `base` to an alltoall over `n` ranks.
    pub fn at(base: u64, n: usize) -> AlltoallPlan {
        AlltoallPlan { base, n }
    }
    /// Wire tag of pairwise round `t` (`1 <= t < n`).
    pub fn pair_tag(&self, t: usize) -> u64 {
        self.base + t as u64
    }
    /// Ring plan of the compressed-size exchange.
    pub fn sizes_ring(&self) -> RingPlan {
        RingPlan::at(self.base + self.n as u64, self.n)
    }
}

/// Two-level allreduce (`Algo::Hier`): intra-node raw up-links on one
/// tag, a [`HIER_GROUP_SPAN`]-wide leader tier (flat reduce-scatter +
/// allgather over a group view), then an intra-node result broadcast
/// down a binomial tree.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HierAllreducePlan {
    /// First tag of the reserved slice.
    pub base: u64,
    /// Total communicator size (not the leader count).
    pub n: usize,
}

impl HierAllreducePlan {
    /// Tags to reserve for a hierarchical allreduce over `n` ranks.
    pub fn span(n: usize) -> u64 {
        1 + HIER_GROUP_SPAN + TreePlan::span(n)
    }
    /// Bind a reserved `base` to a hierarchical allreduce over `n` ranks.
    pub fn at(base: u64, n: usize) -> HierAllreducePlan {
        HierAllreducePlan { base, n }
    }
    /// Tag of the member → leader raw partial up-link.
    pub fn up_tag(&self) -> u64 {
        self.base
    }
    /// Group-view tag base of the inter-leader tier.
    pub fn group_base(&self) -> u64 {
        self.base + 1
    }
    /// Tree plan of the intra-node result broadcast.
    pub fn down(&self) -> TreePlan {
        TreePlan::at(self.base + 1 + HIER_GROUP_SPAN, self.n)
    }
}

/// Two-level allgather: member chunks up on one tag, compressed bundles
/// around the leader ring, result broadcast down the intra-node tree.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HierAllgatherPlan {
    /// First tag of the reserved slice.
    pub base: u64,
    /// Total communicator size.
    pub n: usize,
}

impl HierAllgatherPlan {
    /// Tags to reserve for a hierarchical allgather over `n` ranks.
    pub fn span(n: usize) -> u64 {
        1 + RingPlan::span(n) + TreePlan::span(n)
    }
    /// Bind a reserved `base` to a hierarchical allgather over `n` ranks.
    pub fn at(base: u64, n: usize) -> HierAllgatherPlan {
        HierAllgatherPlan { base, n }
    }
    /// Tag of the member → leader raw chunk up-link.
    pub fn up_tag(&self) -> u64 {
        self.base
    }
    /// Ring plan of the inter-leader bundle ring (rounds indexed by
    /// node count; the span is sized for `n` ranks, an upper bound).
    pub fn leader_ring(&self) -> RingPlan {
        RingPlan::at(self.base + 1, self.n)
    }
    /// Tree plan of the intra-node result broadcast.
    pub fn down(&self) -> TreePlan {
        TreePlan::at(self.base + 1 + RingPlan::span(self.n), self.n)
    }
}

/// Two-level bcast: an optional root → root-leader hop, a binomial tree
/// over the leaders, then the intra-node tree down.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HierBcastPlan {
    /// First tag of the reserved slice.
    pub base: u64,
    /// Total communicator size.
    pub n: usize,
}

impl HierBcastPlan {
    /// Tags to reserve for a hierarchical bcast over `n` ranks.
    pub fn span(n: usize) -> u64 {
        1 + 2 * TreePlan::span(n)
    }
    /// Bind a reserved `base` to a hierarchical bcast over `n` ranks.
    pub fn at(base: u64, n: usize) -> HierBcastPlan {
        HierBcastPlan { base, n }
    }
    /// Tag of the non-leader-root → root-leader frame hop.
    pub fn hop_tag(&self) -> u64 {
        self.base
    }
    /// Tree plan of the inter-leader frame broadcast.
    pub fn leader_tree(&self) -> TreePlan {
        TreePlan::at(self.base + 1, self.n)
    }
    /// Tree plan of the intra-node broadcast.
    pub fn down(&self) -> TreePlan {
        TreePlan::at(self.base + 1 + TreePlan::span(self.n), self.n)
    }
}

/// Two-level scatter: an optional root → root-leader bundle hop, subtree
/// bundles down the leader tree, then one raw chunk per member on a
/// single tag.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HierScatterPlan {
    /// First tag of the reserved slice.
    pub base: u64,
    /// Total communicator size.
    pub n: usize,
}

impl HierScatterPlan {
    /// Tags to reserve for a hierarchical scatter over `n` ranks.
    pub fn span(n: usize) -> u64 {
        1 + TreePlan::span(n) + 1
    }
    /// Bind a reserved `base` to a hierarchical scatter over `n` ranks.
    pub fn at(base: u64, n: usize) -> HierScatterPlan {
        HierScatterPlan { base, n }
    }
    /// Tag of the non-leader-root → root-leader bundle hop.
    pub fn hop_tag(&self) -> u64 {
        self.base
    }
    /// Tree plan of the inter-leader subtree-bundle forwarding.
    pub fn leader_tree(&self) -> TreePlan {
        TreePlan::at(self.base + 1, self.n)
    }
    /// Tag of the leader → member raw chunk down-link (one tag; each
    /// member's chunk is a distinct `(src, dst)` edge).
    pub fn down_tag(&self) -> u64 {
        self.base + 1 + TreePlan::span(self.n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_cover_their_accessors() {
        for n in 1..=16usize {
            let rs = RingPlan::at(0, n);
            assert!(rs.round_tag(n.saturating_sub(1)) < RingPlan::span(n).max(1) + 1);
            let tree = TreePlan::at(0, n);
            assert!(tree.step_tag(tree_rounds(n)) < TreePlan::span(n));
            let ag = AllgatherPlan::at(0, n);
            assert!(ag.counts_ring().round_tag(n.saturating_sub(1)) < AllgatherPlan::span(n));
            assert!(ag.sizes_ring().round_tag(n.saturating_sub(1)) < ag.round_tag(0));
            if n >= 2 {
                // Every round's full segment fan fits strictly before the
                // next round's fan — and the last fan ends at the span end.
                for t in 0..n - 2 {
                    assert_eq!(ag.round_tag(t) + ag.seg_fan(), ag.round_tag(t + 1));
                }
                assert_eq!(ag.round_tag(n - 2) + ag.seg_fan(), ag.base + AllgatherPlan::span(n));
            }
            let a2a = AlltoallPlan::at(0, n);
            assert!(a2a.pair_tag(n.saturating_sub(1)) < a2a.sizes_ring().base + n as u64);
            assert_eq!(a2a.sizes_ring().round_tag(0), n as u64);
        }
    }

    #[test]
    fn hier_spans_match_the_historical_three_reservation_layout() {
        // The folded spans must reproduce the tag values the executors
        // produced when they issued consecutive fresh_tags calls.
        let n = 12;
        let h = HierAllreducePlan::at(100, n);
        assert_eq!(h.up_tag(), 100);
        assert_eq!(h.group_base(), 101);
        assert_eq!(h.down().base, 101 + HIER_GROUP_SPAN);
        assert_eq!(HierAllreducePlan::span(n), 1 + HIER_GROUP_SPAN + TreePlan::span(n));

        let g = HierAllgatherPlan::at(7, n);
        assert_eq!(g.up_tag(), 7);
        assert_eq!(g.leader_ring().base, 8);
        assert_eq!(g.down().base, 8 + n as u64);

        let b = HierBcastPlan::at(3, n);
        assert_eq!(b.hop_tag(), 3);
        assert_eq!(b.leader_tree().base, 4);
        assert_eq!(b.down().base, 4 + TreePlan::span(n));

        let s = HierScatterPlan::at(5, n);
        assert_eq!(s.hop_tag(), 5);
        assert_eq!(s.leader_tree().base, 6);
        assert_eq!(s.down_tag(), 6 + TreePlan::span(n));
        assert_eq!(HierScatterPlan::span(n), s.down_tag() - 5 + 1);
    }
}
