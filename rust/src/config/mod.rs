//! Configuration system: a TOML-subset file format plus CLI-flag
//! overrides (the offline stand-in for `toml` + `clap`).
//!
//! Supported file syntax: `[section]` headers, `key = value` with string
//! (quoted), number, and boolean values, `#` comments. That covers every
//! knob the runtime needs; see `zccl.toml.example` at the repo root.

use std::collections::BTreeMap;
use std::path::Path;

use crate::collectives::{Algo, Mode};
use crate::compress::{CompressorKind, ErrorBound};
use crate::{Error, Result};

/// Parsed config: `section.key -> raw value string`.
#[derive(Debug, Clone, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    /// Parse the TOML-subset text.
    pub fn parse(text: &str) -> Result<Config> {
        let mut section = String::new();
        let mut values = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| Error::invalid(format!("config line {}: no '='", lineno + 1)))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let v = v.trim();
            let v = v
                .strip_prefix('"')
                .and_then(|s| s.strip_suffix('"'))
                .unwrap_or(v)
                .to_string();
            values.insert(key, v);
        }
        Ok(Config { values })
    }

    /// Load from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Config> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Raw string lookup.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(String::as_str)
    }

    /// Typed lookups with defaults.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::invalid(format!("config {key}: '{v}' is not an integer"))),
        }
    }
    /// f64 lookup.
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::invalid(format!("config {key}: '{v}' is not a number"))),
        }
    }
    /// bool lookup.
    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") => Ok(true),
            Some("false") | Some("0") => Ok(false),
            Some(v) => Err(Error::invalid(format!("config {key}: '{v}' is not a bool"))),
        }
    }

    /// Apply `--section.key=value` style overrides.
    pub fn apply_overrides<'a>(&mut self, overrides: impl Iterator<Item = &'a str>) -> Result<()> {
        for o in overrides {
            let (k, v) = o
                .split_once('=')
                .ok_or_else(|| Error::invalid(format!("override '{o}': expected key=value")))?;
            self.values.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(())
    }

    /// Build a collective [`Mode`] from the `[collective]` section.
    pub fn mode(&self) -> Result<Mode> {
        let algo = match self.get("collective.algo").unwrap_or("zccl") {
            "plain" | "mpi" => Algo::Plain,
            "cprp2p" => Algo::Cprp2p,
            "ccoll" | "c-coll" => Algo::CColl,
            "zccl" => Algo::Zccl,
            "hier" | "hierarchical" => Algo::Hier,
            other => return Err(Error::invalid(format!("unknown algo '{other}'"))),
        };
        let kind: CompressorKind =
            self.get("collective.compressor").unwrap_or("fzlight").parse()?;
        let rel = self.get_f64("collective.rel_eb", f64::NAN)?;
        let abs = self.get_f64("collective.abs_eb", f64::NAN)?;
        let eb = if abs.is_finite() {
            ErrorBound::Abs(abs)
        } else if rel.is_finite() {
            ErrorBound::Rel(rel)
        } else {
            ErrorBound::Rel(1e-4)
        };
        let mut mode = Mode {
            algo,
            kind,
            eb,
            multithread: self.get_bool("collective.multithread", false)?,
            pipe_chunk: self.get_usize("collective.pipe_chunk", 5120)?,
            pipeline_bytes: self.get_usize("collective.pipeline_bytes", 1 << 16)?,
            staged: self.get_bool("collective.staged", false)?,
        };
        if algo == Algo::CColl {
            mode.kind = CompressorKind::Szx;
        }
        Ok(mode)
    }
}

/// Build a [`Mode`] directly from CLI-style args
/// (`--algo zccl --compressor fzlight --rel-eb 1e-4 --multithread --staged`).
pub fn mode_from_args(args: &[String]) -> Result<Mode> {
    let mut cfg = Config::default();
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        let key = match a.as_str() {
            "--algo" => "collective.algo",
            "--compressor" => "collective.compressor",
            "--rel-eb" => "collective.rel_eb",
            "--abs-eb" => "collective.abs_eb",
            "--pipe-chunk" => "collective.pipe_chunk",
            "--pipeline-bytes" => "collective.pipeline_bytes",
            "--multithread" => {
                cfg.values.insert("collective.multithread".into(), "true".into());
                continue;
            }
            "--staged" => {
                cfg.values.insert("collective.staged".into(), "true".into());
                continue;
            }
            other => return Err(Error::invalid(format!("unknown mode flag '{other}'"))),
        };
        let v = it
            .next()
            .ok_or_else(|| Error::invalid(format!("flag {a} needs a value")))?;
        cfg.values.insert(key.into(), v.clone());
    }
    cfg.mode()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(
            r#"
            # top comment
            name = "zccl"
            [collective]
            algo = "zccl"
            compressor = "szx"
            rel_eb = 1e-3
            multithread = true
            pipe_chunk = 1024
            staged = true
            "#,
        )
        .unwrap();
        assert_eq!(c.get("name"), Some("zccl"));
        let m = c.mode().unwrap();
        assert_eq!(m.algo, Algo::Zccl);
        assert_eq!(m.kind, CompressorKind::Szx);
        assert!(m.multithread);
        assert_eq!(m.pipe_chunk, 1024);
        assert_eq!(m.eb, ErrorBound::Rel(1e-3));
        assert!(m.staged);
    }

    #[test]
    fn overrides_win() {
        let mut c = Config::parse("[collective]\nalgo = \"plain\"\n").unwrap();
        c.apply_overrides(["collective.algo=cprp2p"].into_iter()).unwrap();
        assert_eq!(c.mode().unwrap().algo, Algo::Cprp2p);
    }

    #[test]
    fn hier_algo_parses() {
        let c = Config::parse("[collective]\nalgo = \"hier\"\n").unwrap();
        assert_eq!(c.mode().unwrap().algo, Algo::Hier);
    }

    #[test]
    fn ccoll_forces_szx() {
        let c = Config::parse("[collective]\nalgo = \"ccoll\"\ncompressor = \"fzlight\"\n")
            .unwrap();
        assert_eq!(c.mode().unwrap().kind, CompressorKind::Szx);
    }

    #[test]
    fn mode_from_cli_args() {
        let args: Vec<String> = [
            "--algo",
            "zccl",
            "--compressor",
            "fzlight",
            "--rel-eb",
            "1e-2",
            "--multithread",
            "--staged",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let m = mode_from_args(&args).unwrap();
        assert_eq!(m.algo, Algo::Zccl);
        assert!(m.multithread);
        assert!(m.staged);
        assert_eq!(m.eb, ErrorBound::Rel(1e-2));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Config::parse("novalue").is_err());
        let c = Config::parse("[collective]\nalgo = \"wat\"\n").unwrap();
        assert!(c.mode().is_err());
    }
}
