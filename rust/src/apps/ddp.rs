//! Data-parallel training of the AOT-compiled transformer with ZCCL
//! gradient allreduce — the end-to-end validation that all three layers
//! compose (DESIGN.md §6).
//!
//! Each worker thread owns a PJRT runtime executing the `grad_step`
//! artifact on its own shard of a synthetic next-token task; the
//! per-worker gradients are averaged with the collective under test
//! (`ReduceOp::Avg`), either flattened into one blocking allreduce or —
//! with [`DdpConfig::bucket_values`] set — bucketed into nonblocking
//! `iallreduce` requests that overlap gradient extraction with
//! communication, so only the final waits' time is exposed. The SGD
//! update is applied locally — identical across workers up to the
//! collective's error bound.

use std::path::PathBuf;

use crate::collectives::{run_ranks, CollCtx, Mode, ReduceOp};
use crate::coordinator::Metrics;
use crate::data::rng::Rng;
use crate::runtime::{literal_f32, literal_i32, literal_to_f32, Literal, Manifest, Runtime};
use crate::{Error, Result};

/// DDP run configuration.
#[derive(Debug, Clone)]
pub struct DdpConfig {
    /// Artifact directory (`artifacts/`).
    pub artifact_dir: PathBuf,
    /// Data-parallel workers (in-process ranks).
    pub workers: usize,
    /// Training steps.
    pub steps: usize,
    /// SGD learning rate.
    pub lr: f32,
    /// Gradient-allreduce mode (the experiment variable).
    pub mode: Mode,
    /// Which artifact computes gradients (`grad_step` or
    /// `grad_step_zccl` for the in-graph compression ablation).
    pub grad_artifact: String,
    /// Base data seed.
    pub seed: u64,
    /// `Some(values)`: bucketed nonblocking gradient allreduce — each
    /// bucket's `iallreduce` launches as soon as its gradients are
    /// extracted (reverse tensor order, mirroring backward-pass
    /// readiness) and overlaps with extracting the rest; only the final
    /// waits are exposed. `None`: the blocking single-bucket baseline.
    pub bucket_values: Option<usize>,
}

impl DdpConfig {
    /// Sensible defaults for this box.
    pub fn new(artifact_dir: impl Into<PathBuf>, workers: usize, steps: usize, mode: Mode) -> Self {
        DdpConfig {
            artifact_dir: artifact_dir.into(),
            workers,
            steps,
            lr: 0.3,
            mode,
            grad_artifact: "grad_step".into(),
            seed: 7,
            bucket_values: None,
        }
    }

    /// Enable the bucketed compute/communication-overlap path (see
    /// [`DdpConfig::bucket_values`]).
    pub fn with_bucket_values(mut self, values: usize) -> Self {
        self.bucket_values = Some(values);
        self
    }
}

/// Per-step record from rank 0.
#[derive(Debug, Clone, Copy)]
pub struct StepRecord {
    /// Step index.
    pub step: usize,
    /// Training loss on rank 0's shard.
    pub loss: f32,
    /// Wall seconds for the gradient allreduce.
    pub allreduce_s: f64,
}

/// Result of one DDP run.
#[derive(Debug, Clone)]
pub struct DdpReport {
    /// Loss curve (rank 0).
    pub steps: Vec<StepRecord>,
    /// Aggregated collective metrics over all ranks and steps.
    pub metrics: Metrics,
    /// Final parameters' L2 norm (cross-mode comparability check).
    pub final_param_norm: f64,
}

/// Generate one worker's batch for `step`: the learnable "shift" task
/// (next token = token + 1 mod vocab) on worker-disjoint random data.
fn batch(
    cfg_vocab: usize,
    batch: usize,
    seq: usize,
    worker: usize,
    step: usize,
    seed: u64,
) -> (Vec<i32>, Vec<i32>) {
    let mut rng = Rng::new(seed ^ ((worker as u64) << 32) ^ step as u64);
    let x: Vec<i32> = (0..batch * seq).map(|_| rng.below(cfg_vocab) as i32).collect();
    let y: Vec<i32> = x.iter().map(|&t| (t + 1) % cfg_vocab as i32).collect();
    (x, y)
}

/// Run data-parallel training; returns the rank-0 loss curve.
pub fn train(cfg: &DdpConfig) -> Result<DdpReport> {
    let manifest = Manifest::load(&cfg.artifact_dir)?;
    let params0 = manifest.load_params()?;
    let shapes: Vec<Vec<usize>> = params0.iter().map(|(_, s, _)| s.clone()).collect();
    let init: Vec<Vec<f32>> = params0.iter().map(|(_, _, v)| v.clone()).collect();
    let mcfg = manifest.config;
    let cfg2 = cfg.clone();
    let artifact = cfg.grad_artifact.clone();

    let results = run_ranks(cfg.workers, move |comm| -> Result<(Vec<StepRecord>, Metrics, f64)> {
        let rt = Runtime::cpu()?;
        let module = rt.load(&cfg2.artifact_dir, &artifact)?;
        let mut params: Vec<Vec<f32>> = init.clone();
        let mut records = Vec::new();
        // One persistent collective context for the whole training run:
        // the codec is built once and the gradient/scratch buffers are
        // reused every step (the allocator leaves the hot loop entirely).
        let mut ctx = CollCtx::over(comm, cfg2.mode);
        let mut flat: Vec<f32> = Vec::new();
        let mut avg: Vec<f32> = Vec::new();
        for step in 0..cfg2.steps {
            let (x, y) = batch(mcfg.vocab, mcfg.batch, mcfg.seq, ctx.rank(), step, cfg2.seed);
            let mut inputs: Vec<Literal> = Vec::with_capacity(params.len() + 2);
            for (p, s) in params.iter().zip(&shapes) {
                inputs.push(literal_f32(p, s)?);
            }
            inputs.push(literal_i32(&x, &[mcfg.batch, mcfg.seq])?);
            inputs.push(literal_i32(&y, &[mcfg.batch, mcfg.seq])?);
            let out = module.run(&inputs)?;
            let loss = literal_to_f32(&out[0])?[0];

            let grads = &out[1..];
            let allreduce_s = if let Some(bucket_values) = cfg2.bucket_values {
                // Bucketed overlap: walk gradients in reverse tensor
                // order (the order a backward pass produces them), launch
                // each full bucket's iallreduce immediately, and keep
                // extracting — every launch's test() poll pulls all
                // in-flight requests forward, so communication hides
                // behind the remaining extraction. Bucket boundaries
                // depend only on the (identical) shapes, keeping the
                // launch sequence SPMD-deterministic.
                let mut pending: Vec<(crate::collectives::CollRequest, Vec<usize>)> = Vec::new();
                let mut members: Vec<usize> = Vec::new();
                flat.clear();
                for gi in (0..grads.len()).rev() {
                    flat.extend(literal_to_f32(&grads[gi])?);
                    members.push(gi);
                    if flat.len() >= bucket_values {
                        let req = ctx.iallreduce(&flat, ReduceOp::Avg)?;
                        pending.push((req, std::mem::take(&mut members)));
                        flat.clear();
                        if let Some((first, _)) = pending.first() {
                            let _ = ctx.test(first)?; // drives every request
                        }
                    }
                }
                if !members.is_empty() {
                    let req = ctx.iallreduce(&flat, ReduceOp::Avg)?;
                    pending.push((req, members));
                }
                // Complete in launch order; only this blocked time is the
                // step's exposed allreduce cost. SGD applies per bucket.
                let mut exposed = 0.0f64;
                for (req, tensors) in pending {
                    let t0 = std::time::Instant::now();
                    ctx.wait_into(req, &mut avg)?;
                    exposed += t0.elapsed().as_secs_f64();
                    let mut off = 0;
                    for &gi in &tensors {
                        for v in params[gi].iter_mut() {
                            *v -= cfg2.lr * avg[off];
                            off += 1;
                        }
                    }
                }
                exposed
            } else {
                // Flatten grads -> one blocking allreduce (single bucket).
                flat.clear();
                for o in grads {
                    flat.extend(literal_to_f32(o)?);
                }
                let t0 = std::time::Instant::now();
                ctx.allreduce_into(&flat, ReduceOp::Avg, &mut avg)?;
                let s = t0.elapsed().as_secs_f64();

                // Local SGD.
                let mut off = 0;
                for p in params.iter_mut() {
                    for v in p.iter_mut() {
                        *v -= cfg2.lr * avg[off];
                        off += 1;
                    }
                }
                s
            };
            if ctx.rank() == 0 {
                records.push(StepRecord { step, loss, allreduce_s });
            }
        }
        let norm: f64 = params
            .iter()
            .flat_map(|p| p.iter())
            .map(|&v| v as f64 * v as f64)
            .sum::<f64>()
            .sqrt();
        Ok((records, ctx.take_metrics(), norm))
    });

    let mut steps = Vec::new();
    let mut metrics = Metrics::default();
    let mut norm = 0.0;
    for (rank, r) in results.into_iter().enumerate() {
        let (recs, m, n) = r?;
        metrics.merge(&m);
        if rank == 0 {
            steps = recs;
            norm = n;
        }
    }
    if steps.is_empty() && cfg.steps > 0 {
        return Err(Error::runtime("rank 0 produced no records"));
    }
    Ok(DdpReport { steps, metrics, final_param_norm: norm })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{CompressorKind, ErrorBound};

    fn artifacts() -> Option<PathBuf> {
        if !Runtime::available() {
            eprintln!("SKIP: built without the 'pjrt' feature");
            return None;
        }
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn ddp_two_workers_descends_plain_and_zccl() {
        let Some(dir) = artifacts() else {
            eprintln!("SKIP: artifacts/ not built");
            return;
        };
        for mode in [
            Mode::plain(),
            Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(1e-4)),
        ] {
            let cfg = DdpConfig::new(&dir, 2, 8, mode);
            let r = train(&cfg).unwrap();
            assert_eq!(r.steps.len(), 8);
            let first = r.steps[0].loss;
            let last = r.steps.last().unwrap().loss;
            assert!(
                last < first,
                "mode {:?}: loss must descend ({first} -> {last})",
                mode.algo
            );
        }
    }

    #[test]
    fn ddp_bucketed_overlap_trains_like_blocking() {
        let Some(dir) = artifacts() else {
            eprintln!("SKIP: artifacts/ not built");
            return;
        };
        let mode = Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(1e-4));
        let blocking = train(&DdpConfig::new(&dir, 2, 6, mode)).unwrap();
        let bucketed =
            train(&DdpConfig::new(&dir, 2, 6, mode).with_bucket_values(1 << 12)).unwrap();
        assert_eq!(bucketed.steps.len(), 6);
        let first = bucketed.steps[0].loss;
        let last = bucketed.steps.last().unwrap().loss;
        assert!(last < first, "bucketed loss must descend ({first} -> {last})");
        // Bucket boundaries change chunking (and thus rounding/codec
        // grouping), so trajectories agree to tolerance, not bitwise.
        let rel = (bucketed.final_param_norm - blocking.final_param_norm).abs()
            / blocking.final_param_norm.max(1e-12);
        assert!(rel < 1e-2, "bucketed param norm drifted {rel} from blocking");
        assert!(
            bucketed.metrics.exposed_comm_s >= 0.0 && bucketed.metrics.hidden_comm_s >= 0.0,
            "overlap accounting must populate"
        );
    }
}
