//! Image stacking (paper §4.6): combine many per-rank partial images into
//! a high-quality composite by summing them with Allreduce — the
//! real-world kernel of reverse-time-migration stacking [42].
//!
//! Each rank holds `images_per_rank` locally-generated partial images
//! (seeded RTM-like 2-D fields standing in for migrated shot gathers),
//! sums them locally, and the cross-rank sum runs through the collective
//! under test. The report carries the Table-7 ingredients: wall time,
//! per-phase breakdown, and PSNR/NRMSE of the compressed-stacked image
//! against the exact serial stack.

use crate::collectives::{run_ranks, CollCtx, Mode, ReduceOp};
use crate::compress::stats::{quality, Quality};
use crate::coordinator::Metrics;
use crate::data::fields::{Field, FieldKind};

/// Workload + result of one stacking run.
#[derive(Debug, Clone)]
pub struct StackReport {
    /// Image height.
    pub rows: usize,
    /// Image width.
    pub cols: usize,
    /// Ranks participating.
    pub ranks: usize,
    /// Stacked image from rank 0.
    pub image: Vec<f32>,
    /// Wall-clock seconds of the collective portion (max over ranks).
    pub wall_s: f64,
    /// Phase breakdown summed over ranks.
    pub metrics: Metrics,
    /// Quality vs the exact serial stack.
    pub quality: Quality,
}

/// The partial image a given rank contributes (deterministic).
pub fn partial_image(rank: usize, img: usize, rows: usize, cols: usize, seed: u64) -> Field {
    Field::generate_2d(
        FieldKind::Rtm,
        rows,
        cols,
        seed ^ ((rank as u64) << 24) ^ ((img as u64) << 8),
    )
}

/// Exact serial stack (the accuracy oracle).
pub fn exact_stack(
    ranks: usize,
    images_per_rank: usize,
    rows: usize,
    cols: usize,
    seed: u64,
) -> Vec<f32> {
    let mut acc = vec![0.0f32; rows * cols];
    for r in 0..ranks {
        for i in 0..images_per_rank {
            let f = partial_image(r, i, rows, cols, seed);
            for (a, v) in acc.iter_mut().zip(&f.values) {
                *a += v;
            }
        }
    }
    acc
}

/// Run the stacking workload under `mode` across `ranks` in-process ranks.
pub fn run(
    ranks: usize,
    images_per_rank: usize,
    rows: usize,
    cols: usize,
    mode: Mode,
    seed: u64,
) -> crate::Result<StackReport> {
    let results = run_ranks(ranks, move |comm| {
        // Persistent collective context; the app attributes its local
        // compute time into the same metrics sink.
        let mut ctx = CollCtx::over(comm, mode);
        let rank = ctx.rank();
        let local = ctx.metrics_mut().time(crate::coordinator::Phase::Compute, || {
            let mut acc = vec![0.0f32; rows * cols];
            for i in 0..images_per_rank {
                let f = partial_image(rank, i, rows, cols, seed);
                for (a, v) in acc.iter_mut().zip(&f.values) {
                    *a += v;
                }
            }
            acc
        });
        let t0 = std::time::Instant::now();
        let stacked = ctx.allreduce(&local, ReduceOp::Sum);
        let wall = t0.elapsed().as_secs_f64();
        stacked.map(|s| (s, ctx.take_metrics(), wall))
    });

    let mut metrics = Metrics::default();
    let mut wall: f64 = 0.0;
    let mut image = Vec::new();
    for (rank, r) in results.into_iter().enumerate() {
        let (img, m, w) = r?;
        metrics.merge(&m);
        wall = wall.max(w);
        if rank == 0 {
            image = img;
        }
    }
    let exact = exact_stack(ranks, images_per_rank, rows, cols, seed);
    let q = quality(&exact, &image);
    Ok(StackReport { rows, cols, ranks, image, wall_s: wall, metrics, quality: q })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{CompressorKind, ErrorBound};

    #[test]
    fn plain_stack_matches_exact() {
        let r = run(4, 2, 32, 48, Mode::plain(), 11).unwrap();
        assert_eq!(r.image.len(), 32 * 48);
        assert!(r.quality.max_err < 1e-4, "max err {}", r.quality.max_err);
    }

    #[test]
    fn zccl_stack_high_psnr() {
        // The paper reports PSNR 49.1 / NRMSE 3.5e-3 at eb 1e-4; with our
        // synthetic images the same order must hold.
        let mode = Mode::zccl(CompressorKind::FzLight, ErrorBound::Rel(1e-4));
        let r = run(4, 2, 48, 64, mode, 11).unwrap();
        assert!(r.quality.psnr > 40.0, "psnr {}", r.quality.psnr);
        assert!(r.quality.nrmse < 1e-2, "nrmse {}", r.quality.nrmse);
    }

    #[test]
    fn deterministic_partials() {
        let a = partial_image(1, 2, 16, 16, 9);
        let b = partial_image(1, 2, 16, 16, 9);
        assert_eq!(a.values, b.values);
        let c = partial_image(2, 2, 16, 16, 9);
        assert_ne!(a.values, c.values);
    }
}
