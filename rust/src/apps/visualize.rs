//! PGM image dumps for the visual-quality figures (Fig. 8: SZx stripe
//! artifacts vs fZ-light; Fig. 16: stacked-image comparison).

use std::io::Write;
use std::path::Path;

use crate::Result;

/// Write a grayscale PGM (P5), min-max normalised.
pub fn write_pgm(path: impl AsRef<Path>, values: &[f32], rows: usize, cols: usize) -> Result<()> {
    assert_eq!(values.len(), rows * cols, "dims mismatch");
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &v in values {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let range = if hi > lo { hi - lo } else { 1.0 };
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write!(f, "P5\n{cols} {rows}\n255\n")?;
    let mut buf = Vec::with_capacity(values.len());
    for &v in values {
        buf.push((((v - lo) / range) * 255.0).clamp(0.0, 255.0) as u8);
    }
    f.write_all(&buf)?;
    Ok(())
}

/// Absolute-difference image (for artifact visualisation), scaled by
/// `gain` before normalisation so subtle artifacts are visible.
pub fn diff_image(a: &[f32], b: &[f32], gain: f32) -> Vec<f32> {
    a.iter().zip(b).map(|(x, y)| (x - y).abs() * gain).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pgm_roundtrip_header() {
        let dir = std::env::temp_dir().join(format!("zccl-pgm-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.pgm");
        write_pgm(&p, &[0.0, 0.5, 1.0, 0.25], 2, 2).unwrap();
        let data = std::fs::read(&p).unwrap();
        assert!(data.starts_with(b"P5\n2 2\n255\n"));
        assert_eq!(data.len(), b"P5\n2 2\n255\n".len() + 4);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn diff_scales() {
        let d = diff_image(&[1.0, 2.0], &[1.5, 2.0], 2.0);
        assert_eq!(d, vec![1.0, 0.0]);
    }
}
