//! Application layer: the paper's real-world use case (image stacking,
//! §4.6) and a data-parallel trainer that drives the AOT-compiled
//! transformer through ZCCL collectives (the dist-train end-to-end
//! validation; DESIGN.md §6).

pub mod ddp;
pub mod image_stacking;
pub mod visualize;
