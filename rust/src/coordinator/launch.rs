//! Multi-process deployment: a leader spawns workers (or they are started
//! by hand on other machines) and all ranks meet over the TCP mesh.
//!
//! `zccl launch --ranks N ...` forks N-1 `zccl worker` processes on this
//! host and becomes rank 0 itself; `zccl worker --rank R --peers a:p,b:p`
//! joins an existing rendezvous. Each rank then runs the requested
//! collective workload and rank 0 prints the aggregate report.

use std::net::SocketAddr;
use std::process::{Child, Command, Stdio};
use std::time::Duration;

use crate::collectives::{allreduce, Communicator, Mode, ReduceOp};
use crate::coordinator::Metrics;
use crate::data::fields::{Field, FieldKind};
use crate::transport::tcp::TcpTransport;
use crate::{Error, Result};

/// Workload parameters shared by leader and workers.
#[derive(Debug, Clone)]
pub struct LaunchSpec {
    /// Rendezvous addresses, rank order.
    pub peers: Vec<SocketAddr>,
    /// This process's rank.
    pub rank: usize,
    /// Values per rank for the workload.
    pub values: usize,
    /// Collective mode.
    pub mode: Mode,
    /// Dataset kind.
    pub field: FieldKind,
}

/// Run the workload at this rank; returns (seconds, metrics, checksum).
pub fn run_rank(spec: &LaunchSpec) -> Result<(f64, Metrics, f64)> {
    let mut t = TcpTransport::connect(spec.rank, &spec.peers, Duration::from_secs(30))?;
    let mut comm = Communicator::new(&mut t);
    let f = Field::generate(spec.field, spec.values, 1000 + spec.rank as u64);
    let mut m = Metrics::default();
    comm.barrier()?;
    let t0 = std::time::Instant::now();
    let out = allreduce(&mut comm, &f.values, ReduceOp::Sum, &spec.mode, &mut m)?;
    let secs = t0.elapsed().as_secs_f64();
    comm.barrier()?;
    let checksum = out.iter().map(|&v| v as f64).sum::<f64>();
    Ok((secs, m, checksum))
}

/// Allocate `n` loopback rendezvous addresses starting at `base_port`.
pub fn local_peers(n: usize, base_port: u16) -> Vec<SocketAddr> {
    (0..n)
        .map(|i| format!("127.0.0.1:{}", base_port + i as u16).parse().unwrap())
        .collect()
}

/// Leader: spawn `n-1` local worker processes and run rank 0.
pub fn launch_local(n: usize, base_port: u16, values: usize, mode_args: &[String]) -> Result<()> {
    let peers = local_peers(n, base_port);
    let exe = std::env::current_exe()?;
    let peers_arg =
        peers.iter().map(|p| p.to_string()).collect::<Vec<_>>().join(",");
    let mut children: Vec<Child> = Vec::new();
    for rank in 1..n {
        let mut cmd = Command::new(&exe);
        cmd.arg("worker")
            .arg("--rank")
            .arg(rank.to_string())
            .arg("--peers")
            .arg(&peers_arg)
            .arg("--values")
            .arg(values.to_string())
            .args(mode_args)
            .stdout(Stdio::inherit())
            .stderr(Stdio::inherit());
        children.push(cmd.spawn()?);
    }
    let spec = LaunchSpec {
        peers,
        rank: 0,
        values,
        mode: super::super::config::mode_from_args(mode_args)?,
        field: FieldKind::Rtm,
    };
    let result = run_rank(&spec);
    for mut c in children {
        let status = c.wait()?;
        if !status.success() {
            return Err(Error::transport(format!("worker exited with {status}")));
        }
    }
    let (secs, m, checksum) = result?;
    println!("rank 0: allreduce {values} values in {secs:.4}s (checksum {checksum:.3e})");
    let (c, comm, compute, other) = m.breakdown_pct();
    println!(
        "breakdown: compress {c:.1}% comm {comm:.1}% compute {compute:.1}% other {other:.1}%"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_peer_allocation() {
        let peers = local_peers(3, 39000);
        assert_eq!(peers.len(), 3);
        assert_eq!(peers[2].port(), 39002);
    }
}
