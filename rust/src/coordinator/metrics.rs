//! Per-phase timing breakdown.
//!
//! The paper reports collective time split into *Compression /
//! Communication / Computation / Other* (Fig. 9–11, Table 7). Every
//! collective in this crate threads a [`Metrics`] through its hot path and
//! attributes wall-clock to exactly one phase at a time.

use std::time::Instant;

/// The phases the paper's breakdowns distinguish.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Lossy compression.
    Compress,
    /// Lossy decompression (standalone — data-movement collectives).
    Decompress,
    /// Fused decompress+reduce: the single-pass receive kernel of the
    /// reduction collectives (§3.4–§3.5, Fig. 4). Kept separate from
    /// [`Phase::Decompress`]/[`Phase::Compute`] so the breakdown stays
    /// honest — the two costs are no longer separable once fused.
    DecompressReduce,
    /// Send/recv/wait/progress time not hidden inside compression.
    Comm,
    /// Reduction arithmetic (the collective-computation operator).
    Compute,
    /// Size exchange, buffer management, everything else.
    Other,
}

/// Accumulated per-phase seconds and traffic counters for one rank's view
/// of one collective call (or a whole run; metrics are additive).
#[derive(Debug, Clone, Copy, Default)]
pub struct Metrics {
    /// Seconds in compression.
    pub compress_s: f64,
    /// Seconds in decompression.
    pub decompress_s: f64,
    /// Seconds in the fused decompress+reduce receive kernel.
    pub decompress_reduce_s: f64,
    /// Seconds in communication (not overlapped).
    pub comm_s: f64,
    /// Seconds in reduction arithmetic.
    pub compute_s: f64,
    /// Seconds in bookkeeping.
    pub other_s: f64,
    /// Bytes handed to the transport.
    pub bytes_sent: u64,
    /// Bytes received from the transport.
    pub bytes_recv: u64,
    /// Raw (uncompressed) bytes the collective moved logically.
    pub raw_bytes: u64,
    /// Seconds the application was *blocked* on nonblocking-collective
    /// completion (`wait`/`wait_into`). A subset of [`Metrics::comm_s`]
    /// — the communication the overlap failed to hide.
    pub exposed_comm_s: f64,
    /// Seconds spent driving nonblocking progress from inside `test()`
    /// polls — communication *hidden* behind the application's own
    /// compute. Informational: overlapped with compute by construction,
    /// so NOT part of [`Metrics::total_s`].
    pub hidden_comm_s: f64,
    /// Collective calls (or waits) that expired their deadline
    /// ([`crate::Error::Timeout`]).
    pub timeouts: u64,
    /// Wire frames whose CRC32C failed verification (observed via
    /// [`crate::transport::Transport::wire_stats`]).
    pub corrupt_frames: u64,
    /// Wire frames dropped idempotently as sequence-number duplicates.
    pub dup_frames_dropped: u64,
    /// Abort-fence poison messages observed from peers.
    pub aborts_observed: u64,
}

impl Metrics {
    /// Time `f`, attributing its wall-clock to `phase`.
    #[inline]
    pub fn time<R>(&mut self, phase: Phase, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        self.add(phase, t0.elapsed().as_secs_f64());
        r
    }

    /// Attribute `seconds` to `phase`.
    #[inline]
    pub fn add(&mut self, phase: Phase, seconds: f64) {
        match phase {
            Phase::Compress => self.compress_s += seconds,
            Phase::Decompress => self.decompress_s += seconds,
            Phase::DecompressReduce => self.decompress_reduce_s += seconds,
            Phase::Comm => self.comm_s += seconds,
            Phase::Compute => self.compute_s += seconds,
            Phase::Other => self.other_s += seconds,
        }
    }

    /// Total accounted seconds.
    pub fn total_s(&self) -> f64 {
        self.compress_s
            + self.decompress_s
            + self.decompress_reduce_s
            + self.comm_s
            + self.compute_s
            + self.other_s
    }

    /// Record `seconds` the application spent blocked in a nonblocking
    /// `wait`: exposed communication, counted in [`Metrics::comm_s`] (it
    /// is real critical-path time) and itemised in
    /// [`Metrics::exposed_comm_s`].
    #[inline]
    pub fn note_exposed_comm(&mut self, seconds: f64) {
        self.comm_s += seconds;
        self.exposed_comm_s += seconds;
    }

    /// Record `seconds` spent pulling nonblocking progress inside a
    /// `test()` poll: hidden communication. Tracked separately and NOT
    /// added to any phase — this wall-clock belongs to the caller's
    /// compute, which overlapped it.
    #[inline]
    pub fn note_hidden_comm(&mut self, seconds: f64) {
        self.hidden_comm_s += seconds;
    }

    /// Fold another rank's metrics in (taking per-phase sums; callers that
    /// want the critical path take maxima instead).
    pub fn merge(&mut self, o: &Metrics) {
        self.compress_s += o.compress_s;
        self.decompress_s += o.decompress_s;
        self.decompress_reduce_s += o.decompress_reduce_s;
        self.comm_s += o.comm_s;
        self.compute_s += o.compute_s;
        self.other_s += o.other_s;
        self.bytes_sent += o.bytes_sent;
        self.bytes_recv += o.bytes_recv;
        self.raw_bytes += o.raw_bytes;
        self.exposed_comm_s += o.exposed_comm_s;
        self.hidden_comm_s += o.hidden_comm_s;
        self.timeouts += o.timeouts;
        self.corrupt_frames += o.corrupt_frames;
        self.dup_frames_dropped += o.dup_frames_dropped;
        self.aborts_observed += o.aborts_observed;
    }

    /// Percentage breakdown in the paper's Table-7 column order
    /// `(compress+decompress, comm, compute, other)`. The fused
    /// decompress+reduce phase is attributed to the codec column: its
    /// cost is dominated by decoding, and the paper's own breakdowns fold
    /// the fused receive into "compression" time.
    pub fn breakdown_pct(&self) -> (f64, f64, f64, f64) {
        let t = self.total_s();
        if t <= 0.0 {
            return (0.0, 0.0, 0.0, 0.0);
        }
        (
            (self.compress_s + self.decompress_s + self.decompress_reduce_s) / t * 100.0,
            self.comm_s / t * 100.0,
            self.compute_s / t * 100.0,
            self.other_s / t * 100.0,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_attributes_phase() {
        let mut m = Metrics::default();
        let v = m.time(Phase::Compress, || {
            std::thread::sleep(std::time::Duration::from_millis(2));
            42
        });
        assert_eq!(v, 42);
        assert!(m.compress_s > 0.0);
        assert_eq!(m.comm_s, 0.0);
    }

    #[test]
    fn breakdown_sums_to_100() {
        let m = Metrics {
            compress_s: 1.0,
            decompress_s: 0.5,
            decompress_reduce_s: 0.5,
            comm_s: 1.0,
            compute_s: 0.5,
            other_s: 0.5,
            ..Default::default()
        };
        let (c, comm, compute, other) = m.breakdown_pct();
        assert!((c + comm + compute + other - 100.0).abs() < 1e-9);
        assert!((c - 50.0).abs() < 1e-9, "fused phase counts toward the codec column");
    }

    #[test]
    fn fused_phase_is_tracked() {
        let mut m = Metrics::default();
        m.add(Phase::DecompressReduce, 0.25);
        assert_eq!(m.decompress_reduce_s, 0.25);
        assert_eq!(m.decompress_s, 0.0);
        assert_eq!(m.total_s(), 0.25);
        let mut o = Metrics::default();
        o.merge(&m);
        assert_eq!(o.decompress_reduce_s, 0.25);
    }

    #[test]
    fn exposed_and_hidden_comm_accounting() {
        let mut m = Metrics::default();
        m.note_exposed_comm(0.5);
        m.note_hidden_comm(2.0);
        // Exposed time is real critical-path communication…
        assert_eq!(m.comm_s, 0.5);
        assert_eq!(m.exposed_comm_s, 0.5);
        assert_eq!(m.total_s(), 0.5);
        // …hidden time is informational only: overlapped with the
        // caller's compute, never double-counted into the total.
        assert_eq!(m.hidden_comm_s, 2.0);
        let mut o = Metrics::default();
        o.merge(&m);
        assert_eq!(o.exposed_comm_s, 0.5);
        assert_eq!(o.hidden_comm_s, 2.0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = Metrics { compress_s: 1.0, bytes_sent: 10, ..Default::default() };
        let b = Metrics { compress_s: 2.0, bytes_sent: 5, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.compress_s, 3.0);
        assert_eq!(a.bytes_sent, 15);
    }

    #[test]
    fn failure_counters_merge() {
        let mut a = Metrics { timeouts: 1, corrupt_frames: 2, ..Default::default() };
        let b = Metrics {
            timeouts: 3,
            dup_frames_dropped: 4,
            aborts_observed: 5,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.timeouts, 4);
        assert_eq!(a.corrupt_frames, 2);
        assert_eq!(a.dup_frames_dropped, 4);
        assert_eq!(a.aborts_observed, 5);
    }
}
